"""Fused Power-ψ iteration kernel: scatter + epilogue + gap in one pass.

One Alg. 2 step is ``s' = μ ⊙ (sᵀA-push) + c`` followed by the termination
gap ``‖s' − s‖₁``. Unfused, that is three extra O(N) HBM sweeps after the
scatter (scale, add, abs-diff-reduce). This kernel fuses them into the edge
scatter's epilogue: when the *last* edge block of a node tile completes, the
tile's μ/c/s slices are already in VMEM, the epilogue runs there, and a
per-kernel scalar accumulates the L1 gap — so s', and the gap cost zero
additional HBM traffic beyond the write of s' itself.

This is the paper-faithful iteration (identical math to
``core.power_psi.make_power_psi_step``) — only the schedule is new
(EXPERIMENTS.md §Perf, memory-term hillclimb).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["power_step_call"]


def _make_kernel(e1: int, tile: int):
    def kernel(block_tile_ref, first_ref, last_ref, s_pre_ref, idx_ref,
               dstl_ref, mu_ref, c_ref, s_old_ref, out_ref, gap_ref,
               acc_ref):
        b = pl.program_id(0)

        @pl.when(b == 0)
        def _zero_gap():
            gap_ref[...] = jnp.zeros_like(gap_ref)

        @pl.when(first_ref[b] == 1)
        def _zero_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        s_vec = s_pre_ref[0]
        idx = idx_ref[0]
        gathered = jnp.take(s_vec, idx, axis=0)
        dstl = dstl_ref[0]
        e2 = idx.shape[1]
        acc = acc_ref[...]
        for r in range(e1):
            onehot = (dstl[r][:, None] ==
                      jax.lax.broadcasted_iota(jnp.int32, (e2, tile), 1)
                      ).astype(s_vec.dtype)
            acc = acc + jnp.dot(gathered[r][None, :], onehot,
                                preferred_element_type=s_vec.dtype)
        acc_ref[...] = acc

        @pl.when(last_ref[b] == 1)
        def _epilogue():
            s_new = mu_ref[...] * acc_ref[...] + c_ref[...]   # [1, tile]
            out_ref[...] = s_new
            gap_ref[0, 0] += jnp.sum(jnp.abs(s_new - s_old_ref[...]))

    return kernel


@functools.partial(jax.jit, static_argnames=("tile", "e1", "e2", "num_tiles",
                                             "interpret"))
def power_step_call(s_pre_pad: jax.Array, src_idx: jax.Array,
                    dst_local: jax.Array, block_tile: jax.Array,
                    block_first: jax.Array, block_last: jax.Array,
                    mu_pad: jax.Array, c_pad: jax.Array, s_old_pad: jax.Array,
                    *, tile: int, e1: int, e2: int, num_tiles: int,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Fused iteration over a pre-built EdgeTileFormat.

    Args:
      s_pre_pad: f[1, n_gather] — s ⊙ 1/w with sentinel zeros.
      mu_pad / c_pad / s_old_pad: f[1, num_tiles*tile] node-tiled vectors.

    Returns:
      (s_new f[1, num_tiles*tile], gap f[1,1] = ‖s_new − s_old‖₁ over pads).
    """
    num_blocks = src_idx.shape[0]
    vec_spec = pl.BlockSpec((1, tile), lambda b, bt, bf, bl: (0, bt[b]))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, s_pre_pad.shape[1]), lambda b, *_: (0, 0)),
            pl.BlockSpec((1, e1, e2), lambda b, *_: (b, 0, 0)),
            pl.BlockSpec((1, e1, e2), lambda b, *_: (b, 0, 0)),
            vec_spec,                                   # mu
            vec_spec,                                   # c
            vec_spec,                                   # s_old
        ],
        out_specs=[
            vec_spec,                                   # s_new
            pl.BlockSpec((1, 1), lambda b, *_: (0, 0)),  # gap scalar
        ],
        scratch_shapes=[pltpu.VMEM((1, tile), s_pre_pad.dtype)],
    )
    return pl.pallas_call(
        _make_kernel(e1, tile),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((1, num_tiles * tile), s_pre_pad.dtype),
            jax.ShapeDtypeStruct((1, 1), s_pre_pad.dtype),
        ],
        interpret=interpret,
    )(block_tile, block_first, block_last, s_pre_pad, src_idx, dst_local,
      mu_pad, c_pad, s_old_pad)
