"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` consumes the same *logical* inputs as the kernel wrapper in
``ops.py`` and is used by the per-kernel allclose sweeps in
``tests/test_kernels.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["edge_spmv_ref", "bsr_spmv_ref", "power_step_ref", "seg_mm_ref"]


def edge_spmv_ref(s_pre: jax.Array, src: jax.Array, dst: jax.Array,
                  n: int, weights: jax.Array | None = None) -> jax.Array:
    """t_i = Σ_{(j→i)∈E} w_e · s_pre_j  (plain segment-sum scatter)."""
    contrib = s_pre[src]
    if weights is not None:
        contrib = contrib * weights
    return jax.ops.segment_sum(contrib, dst, n)


def bsr_spmv_ref(s_pre: jax.Array, dense_a: jax.Array) -> jax.Array:
    """t = s_preᵀ · A as a dense product (small graphs only)."""
    return s_pre @ dense_a


def power_step_ref(s: jax.Array, inv_w: jax.Array, mu: jax.Array,
                   c: jax.Array, src: jax.Array, dst: jax.Array, n: int
                   ) -> tuple[jax.Array, jax.Array]:
    """One Alg. 2 iteration + L1 gap: s' = μ ⊙ push(s) + c, gap = ‖s'−s‖₁."""
    t = jax.ops.segment_sum((s * inv_w)[src], dst, n)
    s_new = mu * t + c
    return s_new, jnp.sum(jnp.abs(s_new - s))


def seg_mm_ref(messages: jax.Array, dst: jax.Array, n: int) -> jax.Array:
    """Y[i] = Σ_{e: dst_e = i} M[e]  — segment-sum over feature rows."""
    return jax.ops.segment_sum(messages, dst, n)
