"""Regime autotuner: pick the cheapest execution plan per graph.

``kernels/formats.py`` keeps two TPU-native SpMV layouts — the edge-tile
format (VPU gathers + one-hot MXU scatter; right for hyper-sparse social
graphs) and the BSR format (dense ``ts × td`` MXU tiles; wins on clustered
operators with decent tile occupancy).  Until this module the engine
hardcoded the edge-tile regime and BSR was an ablation.  The planner makes
the choice per graph:

1. **Measured-occupancy cost model** (default).  One O(M) ``bincount`` /
   ``unique`` pass per candidate parameterization estimates the HBM bytes a
   single Power-ψ step moves under each regime — the quantity a bandwidth-
   bound SpMV is actually limited by:

     * edge-tile:  per block, two i32 index planes plus the gathered source
       floats (``12 B/slot``), padded to ``ceil(cnt_t / eblk)`` blocks per
       node tile, plus the 4 node-vector streams per output tile.
     * BSR:        every materialized block streams its dense ``ts·td``
       f32 tile (``4 B / slot`` ≡ ``4/occupancy`` bytes per edge), plus the
       output/epilogue vectors per dst tile.

2. **One-shot micro-benchmark** (``microbench=True``).  Builds *every*
   candidate of both regimes, times one jitted step of each (after a warmup
   compile), and picks the measured winner — the model can mis-rank
   parameterizations *within* a regime, not just between regimes.  Ground
   truth when the model's constants are off for a platform (e.g. interpret
   mode on CPU); costs one format build + step compile per candidate.

Plans are memoized in a process-level cache keyed by a *structural*
fingerprint of the graph (node/edge counts plus a strided edge sample) and
the candidate space — activity patches never touch the key, so serving-path
``patch_activity`` / warm re-``prepare`` cycles never re-plan.  See
docs/AUTOTUNE.md.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graphs.structure import Graph
from ..obs import calibrate as obs_calibrate
from ..obs import explain as obs_explain
from ..obs import log as obs_log
from ..obs import metrics as obs_metrics
from .formats import build_bsr, build_edge_tiles

__all__ = ["RegimePlan", "PlanCache", "PLAN_CACHE", "graph_fingerprint",
           "bucket_fingerprint", "estimate_edge_tile_cost",
           "estimate_bsr_cost", "bsr_occupancy", "plan_regime",
           "plan_for_bucket", "SolverChoice", "choose_solver"]


# Default candidate spaces. Lane dims stay multiples of 128 (TPU tiling);
# the sublane/edge-block dims trade padding waste against per-block overhead.
EDGE_TILE_CANDIDATES: tuple[tuple[int, int, int], ...] = (
    (256, 8, 128),            # (tile, e1, e2) — the historical default
    (128, 8, 128),
    (512, 8, 128),
)
BSR_CANDIDATES: tuple[tuple[int, int], ...] = (
    (128, 128),               # (ts, td) — one MXU pass per block
    (128, 256),
)

# Rough per-slot HBM traffic in bytes (see module docstring). Absolute
# values only matter relative to each other; microbench overrides both.
_EDGE_SLOT_BYTES = 12.0       # 2 × i32 index + 1 × f32 gather per edge slot
_BSR_SLOT_BYTES = 4.0         # f32 tile value per slot
_NODE_STREAM_BYTES = 16.0     # mu, c, s_old, s_new per output element

# BSR candidates whose tiles would be emptier than this are pruned *before*
# scoring or microbenching: on a hyper-sparse graph a 128×128 tile holding a
# handful of edges makes the format build + compile + timed step orders of
# magnitude slower than the edge-tile path, and the model already knows the
# regime cannot win — paying the microbench for it is pure waste.
BSR_MIN_OCCUPANCY = 0.02


@dataclasses.dataclass(frozen=True)
class RegimePlan:
    """A resolved execution plan for ``PallasEngine``."""

    regime: str               # "edge_tile" | "bsr"
    tile: int = 256           # edge-tile params (used when regime=edge_tile)
    e1: int = 8
    e2: int = 128
    ts: int = 128             # BSR params (used when regime=bsr)
    td: int = 128
    est_bytes: float = 0.0    # modeled HBM bytes per step for the winner
    measured_us: float = 0.0  # microbenchmark result (0 when model-only)
    # what ranked the winner: "model" (raw est_bytes), "microbench"
    # (measured µs), or "calibrated" (est_bytes × learned factors) —
    # measured_us == 0.0 alone cannot distinguish model-only from a
    # genuinely sub-µs bench
    source: str = "model"

    def params(self) -> dict:
        if self.regime == "edge_tile":
            return dict(tile=self.tile, e1=self.e1, e2=self.e2)
        return dict(ts=self.ts, td=self.td)

    def label(self) -> str:
        kv = ",".join(f"{k}={v}" for k, v in self.params().items())
        return f"{self.regime}({kv})"


# --------------------------------------------------------------------- #
# Cost model — one O(M) pass per candidate, no format materialization
# --------------------------------------------------------------------- #
def estimate_edge_tile_cost(graph: Graph, *, tile: int, e1: int, e2: int,
                            slot_bytes: float = _EDGE_SLOT_BYTES,
                            node_bytes: float = _NODE_STREAM_BYTES) -> float:
    """Modeled HBM bytes per fused step under the edge-tile regime."""
    eblk = e1 * e2
    num_tiles = max(1, -(-graph.n // tile))
    _, dst = graph.edges_by_dst
    counts = np.bincount(dst // tile, minlength=num_tiles)
    blocks = np.maximum(1, -(-counts // eblk))
    padded_slots = float(blocks.sum()) * eblk
    return padded_slots * slot_bytes + num_tiles * tile * node_bytes


def _bsr_blocks(graph: Graph, ts: int, td: int) -> int:
    """Materialized BSR block count (nonempty + explicit zero dst covers)."""
    nst = max(1, -(-graph.n // ts))
    ndt = max(1, -(-graph.n // td))
    src, dst = graph.edges_by_dst
    key = (dst // td).astype(np.int64) * nst + src // ts
    nonempty = np.unique(key).size if key.size else 0
    # uncovered dst tiles get an explicit zero block (see build_bsr)
    covered = np.unique(dst // td).size if dst.size else 0
    return max(1, nonempty + (ndt - covered))


def bsr_occupancy(graph: Graph, *, ts: int, td: int) -> float:
    """Edges per materialized block slot — ``m / (num_blocks·ts·td)``.

    The fraction of streamed tile values that are real edges; the rest is
    zero-fill the MXU multiplies for nothing. Matches
    ``build_bsr(graph).occupancy`` without materializing the format.
    """
    return graph.m / (_bsr_blocks(graph, ts, td) * ts * td)


def estimate_bsr_cost(graph: Graph, *, ts: int, td: int,
                      slot_bytes: float = _BSR_SLOT_BYTES,
                      node_bytes: float = _NODE_STREAM_BYTES) -> float:
    """Modeled HBM bytes per step under the BSR regime."""
    ndt = max(1, -(-graph.n // td))
    return float(_bsr_blocks(graph, ts, td)) * ts * td * slot_bytes + \
        ndt * td * node_bytes


# --------------------------------------------------------------------- #
# Plan cache — structural fingerprint, stable under activity patches
# --------------------------------------------------------------------- #
def graph_fingerprint(graph: Graph, *, sample: int = 64) -> tuple:
    """Cheap structural key: (n, m) plus a strided edge sample.

    Activity rates are deliberately absent — the regime choice depends only
    on sparsity structure, so ``patch_activity`` (and warm re-``prepare``
    with the same graph) hits the cache.  A fingerprint collision can only
    yield a valid-but-suboptimal plan, never a wrong answer.
    """
    src, dst = graph.edges_by_dst
    stride = max(1, graph.m // sample)
    return (graph.n, graph.m, tuple(np.asarray(src[::stride]).tolist()),
            tuple(np.asarray(dst[::stride]).tolist()))


def bucket_fingerprint(n_pad: int, e_pad: int, *, extra: tuple = ()) -> tuple:
    """Cache key for a fleet *bucket*: the padded shape, not any one graph.

    Every tenant admitted into the same ``(n_pad, e_pad)`` bucket shares a
    compiled batched solver, so they should share one plan too — the key
    deliberately ignores which member graph happened to trigger planning.
    """
    return ("bucket", int(n_pad), int(e_pad)) + extra


class PlanCache:
    """Process-level memo of :func:`plan_regime` results with hit stats.

    Every lookup/store also feeds the obs registry
    (``psi_plan_cache_{hits,misses}_total``; the process-level default
    cache additionally publishes ``psi_plan_cache_size``) so cache
    behaviour is observable in serving, not only assertable in tests.
    """

    def __init__(self):
        self._plans: dict[tuple, RegimePlan] = {}
        self.hits = 0
        self.misses = 0

    def _size_gauge(self) -> None:
        # only the shared process cache owns the gauge — per-test/private
        # caches would otherwise fight over one series
        if self is globals().get("PLAN_CACHE"):
            obs_metrics.gauge("psi_plan_cache_size",
                              "memoized plans in the process plan cache") \
                .set(float(len(self._plans)))

    def lookup(self, key: tuple) -> RegimePlan | None:
        plan = self._plans.get(key)
        if plan is not None:
            self.hits += 1
            obs_metrics.counter("psi_plan_cache_hits_total",
                                "autotune plan-cache hits").inc()
        return plan

    def store(self, key: tuple, plan: RegimePlan) -> None:
        self.misses += 1
        obs_metrics.counter("psi_plan_cache_misses_total",
                            "autotune plan-cache misses").inc()
        self._plans[key] = plan
        self._size_gauge()

    def clear(self) -> None:
        self._plans.clear()
        self.hits = self.misses = 0
        self._size_gauge()

    def __len__(self) -> int:
        return len(self._plans)


PLAN_CACHE = PlanCache()


# --------------------------------------------------------------------- #
# The planner
# --------------------------------------------------------------------- #
def _microbench_step(graph: Graph, plan: RegimePlan, dtype,
                     interpret: bool) -> float:
    """Median wall-time (µs) of one jitted Power-ψ push under ``plan``."""
    import time

    import jax
    import jax.numpy as jnp

    from .ops import DeviceBsr, DeviceEdgeTiles, bsr_spmv, edge_spmv

    s = jnp.asarray(np.random.default_rng(0).random(graph.n), dtype)
    if plan.regime == "edge_tile":
        fmt = DeviceEdgeTiles.from_format(
            build_edge_tiles(graph, tile=plan.tile, e1=plan.e1, e2=plan.e2))
        step = jax.jit(lambda v: edge_spmv(v, fmt, interpret=interpret))
    else:
        fmt = DeviceBsr.from_format(
            build_bsr(graph, ts=plan.ts, td=plan.td,
                      dtype=np.dtype(jnp.dtype(dtype).name)))
        step = jax.jit(lambda v: bsr_spmv(v, fmt, interpret=interpret))
    jax.block_until_ready(step(s))                     # compile
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(step(s))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


_USE_GLOBAL = object()        # sentinel: "the process calibration store"


def _misrank(site: str, model_winner: RegimePlan, best: RegimePlan,
             ratio: float, basis: str) -> None:
    """Count one modeled-winner ≠ measured-winner disagreement."""
    obs_metrics.gauge(
        "psi_plan_misprediction_ratio",
        "cost of the raw-model winner over the true winner "
        "(1.0 = model ranked correctly)").set(float(ratio))
    if model_winner.regime != best.regime or \
            model_winner.params() != best.params():
        obs_log.event("model_misranked",
                      f"{site}: model picked {model_winner.label()} but "
                      f"{basis} favors {best.label()} ({ratio:.2f}× dearer)",
                      level="warning", site=site, basis=basis,
                      model_winner=model_winner.label(),
                      winner=best.label(), ratio=float(ratio))


def plan_regime(graph: Graph, *, microbench: bool = False,
                dtype=None, interpret: bool | None = None,
                edge_tile_candidates=EDGE_TILE_CANDIDATES,
                bsr_candidates=BSR_CANDIDATES,
                cache: PlanCache | None = PLAN_CACHE,
                calibration=_USE_GLOBAL,
                slot_bytes: tuple | None = None,
                _ctx: dict | None = None) -> RegimePlan:
    """Choose edge-tile vs BSR (and their parameters) for ``graph``.

    The model pass scores every candidate of both regimes; with
    ``microbench=True`` every candidate is then timed once and the
    measured winner is returned.  Model-only picks consult the
    :mod:`repro.obs.calibrate` store: confident per-regime correction
    factors turn ``est_bytes`` into calibrated µs before ranking
    (``calibration=None`` opts out; pass a store to use a private one).
    ``slot_bytes=(edge, bsr, node)`` overrides the model constants — the
    calibration self-test injects skewed constants through it.  Results
    are memoized in ``cache`` (``cache=None`` bypasses); the key includes
    the calibration generation so a material recalibration replans.

    Every call records a :class:`repro.obs.explain.DecisionRecord` with
    the full candidate table, the density-gate prunes, and the cache
    state.
    """
    ctx = _ctx or {}
    kind = ctx.get("kind", "regime_plan")
    site = ctx.get("site", "plan_regime")
    inputs = dict(n=graph.n, m=graph.m, microbench=bool(microbench))
    inputs.update(ctx.get("inputs", ()))
    cal = obs_calibrate.get_store() if calibration is _USE_GLOBAL \
        else calibration
    eb, bb, nb = slot_bytes or (_EDGE_SLOT_BYTES, _BSR_SLOT_BYTES,
                                _NODE_STREAM_BYTES)

    # The calibration key component exists so a *material* recalibration
    # replans — but only when the store can actually change a ranking:
    # with no confident factors (or one uniform default) the multipliers
    # scale every candidate equally, so keying on the raw generation
    # would spuriously invalidate warm re-prepares (the no-replan/
    # no-retrace contract of test_engine.py) every time a sample lands.
    cal_sig = None
    if cal is not None:
        m0 = cal.multipliers({"edge_tile", "bsr"})
        if len(set(m0.values())) > 1:
            cal_sig = cal.generation

    key = None
    if cache is not None:
        key = graph_fingerprint(graph) + (
            bool(microbench), tuple(edge_tile_candidates),
            tuple(bsr_candidates), cal_sig, slot_bytes)
        hit = cache.lookup(key)
        if hit is not None:
            obs_explain.record_decision(
                kind, site, inputs=inputs, cache="hit",
                chosen=hit.label(), source=hit.source,
                candidates=[obs_explain.Candidate(
                    hit.label(), est=hit.est_bytes,
                    measured_us=hit.measured_us, chosen=True)])
            return hit

    # Density gate: drop BSR parameterizations whose tiles would stream
    # mostly zero-fill. Deterministic (structure-only), so it is safe under
    # the cache key above — the same graph always prunes the same set.
    dense_bsr, pruned = [], []
    for ts, td in bsr_candidates:
        occ = bsr_occupancy(graph, ts=ts, td=td)
        if occ >= BSR_MIN_OCCUPANCY:
            dense_bsr.append((ts, td))
        else:
            pruned.append(obs_explain.Pruned(
                f"bsr(ts={ts},td={td})", "BSR_MIN_OCCUPANCY",
                detail=dict(occupancy=round(occ, 6),
                            floor=BSR_MIN_OCCUPANCY)))

    candidates = [
        RegimePlan(regime="edge_tile", tile=t, e1=a, e2=b,
                   est_bytes=estimate_edge_tile_cost(
                       graph, tile=t, e1=a, e2=b,
                       slot_bytes=eb, node_bytes=nb))
        for t, a, b in edge_tile_candidates
    ] + [
        RegimePlan(regime="bsr", ts=ts, td=td,
                   est_bytes=estimate_bsr_cost(graph, ts=ts, td=td,
                                               slot_bytes=bb, node_bytes=nb))
        for ts, td in dense_bsr
    ]
    model_winner = min(candidates, key=lambda p: p.est_bytes)

    mults = cal.multipliers({p.regime for p in candidates}) \
        if cal is not None else {}
    cal_info = None
    calibrated_us: dict[int, float] = {}

    if microbench:
        # measured ground truth: one timed step per candidate — the model
        # only breaks exact ties (its constants are TPU-HBM oriented and
        # can mis-rank parameterizations on other platforms)
        import jax.numpy as jnp

        from .ops import default_interpret
        dtype = dtype or jnp.float32
        interpret = default_interpret() if interpret is None else interpret
        candidates = [dataclasses.replace(
            p, measured_us=_microbench_step(graph, p, dtype, interpret),
            source="microbench") for p in candidates]
        if cal is not None:
            for p in candidates:      # feed the loop-closing store
                cal.observe(p.regime, p.est_bytes, p.measured_us,
                            source="microbench")
        plan = min(candidates, key=lambda p: (p.measured_us, p.est_bytes))
        mw = min(candidates,          # the raw model's pick, now timed
                 key=lambda p: p.est_bytes)
        _misrank(site, mw, plan, mw.measured_us / max(plan.measured_us,
                                                      1e-12),
                 basis="microbench")
    elif len(set(mults.get(p.regime, 1.0) for p in candidates)) > 1:
        # distinct confident factors: rank by calibrated µs, not raw bytes
        calibrated_us = {i: p.est_bytes * mults[p.regime]
                         for i, p in enumerate(candidates)}
        best_i = min(calibrated_us, key=calibrated_us.get)
        plan = dataclasses.replace(candidates[best_i], source="calibrated")
        cal_info = dict(env=cal.env, generation=cal.generation,
                        factors=cal.factors())
        mw_us = model_winner.est_bytes * mults[model_winner.regime]
        _misrank(site, model_winner, plan,
                 mw_us / max(calibrated_us[best_i], 1e-12),
                 basis="calibration")
    else:
        plan = model_winner

    obs_explain.record_decision(
        kind, site, inputs=inputs,
        cache="miss" if cache is not None else ctx.get("cache", "bypass"),
        chosen=plan.label(), source=plan.source, calibration=cal_info,
        candidates=[obs_explain.Candidate(
            p.label(), est=p.est_bytes, measured_us=p.measured_us,
            calibrated_us=calibrated_us.get(i),
            chosen=(p.regime == plan.regime
                    and p.params() == plan.params()))
            for i, p in enumerate(candidates)],
        pruned=pruned)

    if cache is not None:
        cache.store(key, plan)
    return plan


def plan_for_bucket(graph: Graph, *, n_pad: int, e_pad: int,
                    microbench: bool = False, dtype=None,
                    interpret: bool | None = None,
                    edge_tile_candidates=EDGE_TILE_CANDIDATES,
                    cache: PlanCache | None = PLAN_CACHE,
                    calibration=_USE_GLOBAL) -> RegimePlan:
    """Plan the edge-tile parameters for one fleet bucket shape.

    ``graph`` is the member that triggered planning; it is re-padded to the
    bucket's node capacity so the plan reflects the shapes the batched
    solver will actually compile for.  The result is memoized under
    :func:`bucket_fingerprint` — **every** same-bucket tenant (current and
    future) reuses this one plan, which is what keeps admission O(tenant)
    instead of O(replan).

    Only edge-tile candidates are scored: the fleet vmaps the edge-tile
    kernel across lanes, and BSR's per-graph block table does not stack.
    """
    key = None
    if cache is not None:
        key = bucket_fingerprint(
            n_pad, e_pad,
            extra=(bool(microbench), tuple(edge_tile_candidates)))
        hit = cache.lookup(key)
        if hit is not None:
            obs_explain.record_decision(
                "bucket_plan", "plan_for_bucket",
                inputs=dict(n=graph.n, m=graph.m, n_pad=int(n_pad),
                            e_pad=int(e_pad)),
                cache="hit", chosen=hit.label(), source=hit.source,
                candidates=[obs_explain.Candidate(
                    hit.label(), est=hit.est_bytes,
                    measured_us=hit.measured_us, chosen=True)])
            return hit
    padded = Graph(int(n_pad), graph.src, graph.dst,
                   name=f"{graph.name}@bucket{n_pad}")
    plan = plan_regime(padded, microbench=microbench, dtype=dtype,
                       interpret=interpret,
                       edge_tile_candidates=edge_tile_candidates,
                       bsr_candidates=(), cache=None,
                       calibration=calibration,
                       _ctx=dict(kind="bucket_plan", site="plan_for_bucket",
                                 cache="miss" if cache is not None
                                 else "bypass",
                                 inputs=dict(n_pad=int(n_pad),
                                             e_pad=int(e_pad))))
    if cache is not None:
        cache.store(key, plan)
    return plan


# --------------------------------------------------------------------- #
# Solver-level choice: local residual push vs global sweep
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SolverChoice:
    """Which *solver* (not kernel format) a query should pay for.

    A global Power-ψ sweep moves every edge every iteration — O(sweeps·m)
    regardless of how little actually changed. The push backend
    (``repro.localpush``) only moves the frontier's out-edges, which wins
    when the dirty set is small and the query only needs a certified
    top-k, and loses once the frontier saturates the graph.
    """

    solver: str               # "push" | "global"
    push_edges: float         # modeled push edge-work for the query
    global_edges: float       # modeled global edge-work (sweeps · m)
    dirty_frac: float
    k_frac: float


def choose_solver(graph: Graph, *, dirty_frac: float, k_frac: float = 1.0,
                  sweeps: int = 50) -> SolverChoice:
    """Model whether local push beats a global sweep for this query.

    Frontier-growth model: a warm push starts from ``dirty_frac·n`` seed
    nodes and each round the frontier grows by the mean out-degree
    ``m/n`` (residual mass fans out along out-edges), saturating at ``n``.
    Rounds-to-target scales with how much of the vector the query needs:
    a certified top-k with ``k ≪ n`` stops as soon as the k-th margin
    clears the certificate, modeled as ``sweeps·(0.25 + 0.75·k_frac)``
    rounds. Each frontier node costs its mean out-degree in edge work.

    The model is deliberately coarse — it only has to rank two solvers
    whose costs differ by orders of magnitude in the regimes that matter
    (0.1% dirty vs 100% dirty), not predict wall time.
    """
    if not 0.0 <= dirty_frac <= 1.0:
        raise ValueError(f"dirty_frac must be in [0, 1]; got {dirty_frac}")
    if not 0.0 < k_frac <= 1.0:
        raise ValueError(f"k_frac must be in (0, 1]; got {k_frac}")
    if sweeps < 1:
        raise ValueError(f"sweeps must be >= 1; got {sweeps}")
    n = max(1, graph.n)
    deg = graph.m / n                       # mean out-degree = fan-out rate
    rounds = max(1, int(sweeps * (0.25 + 0.75 * k_frac)))
    frontier = max(1.0, dirty_frac * n)
    push_edges = 0.0
    for _ in range(rounds):
        push_edges += frontier * deg
        frontier = min(float(n), frontier * max(1.0, deg))
    global_edges = float(sweeps) * graph.m
    solver = "push" if push_edges < global_edges else "global"
    obs_explain.record_decision(
        "solver_choice", "choose_solver",
        inputs=dict(n=graph.n, m=graph.m, dirty_frac=float(dirty_frac),
                    k_frac=float(k_frac), sweeps=int(sweeps),
                    rounds=rounds),
        chosen=solver, source="model",
        candidates=[
            obs_explain.Candidate("push", est=push_edges, unit="edges",
                                  chosen=solver == "push",
                                  detail=dict(rounds=rounds)),
            obs_explain.Candidate("global", est=global_edges, unit="edges",
                                  chosen=solver == "global",
                                  detail=dict(sweeps=int(sweeps))),
        ])
    return SolverChoice(solver=solver, push_edges=push_edges,
                        global_edges=global_edges,
                        dirty_frac=float(dirty_frac),
                        k_frac=float(k_frac))
