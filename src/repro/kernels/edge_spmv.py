"""Edge-tile SpMV Pallas kernel — the ψ-score push as one-hot MXU matmuls.

TPU-native design (DESIGN.md §3): edges are pre-blocked so each block of
``e1 × e2`` edges writes a single output tile of ``tile`` nodes. Per block:

  1. gather ``s_pre[src_idx]``          — VPU dynamic load, [e1, e2]
  2. optional per-edge weights          — VPU multiply
  3. scatter-by-one-hot                 — e1 × ([1, e2] @ [e2, tile]) MXU
                                          mat-vecs accumulated into the
                                          output tile resident in VMEM

The output BlockSpec revisits the same tile for consecutive blocks of one
node tile (grid is ordered dst-major), so accumulation happens in VMEM and
each output tile is written to HBM exactly once. VMEM footprint per step:
s_pre (full shard) + 2·e1·e2 i32 + tile f32 — a few MB for N ≤ 10⁶ shards,
sized for v5e VMEM with 128-lane / 8-sublane alignment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["edge_spmv_call"]


def _make_kernel(e1: int, tile: int, weighted: bool):
    def kernel(block_tile_ref, first_ref, *refs):
        if weighted:
            s_ref, idx_ref, dstl_ref, w_ref, out_ref = refs
        else:
            s_ref, idx_ref, dstl_ref, out_ref = refs
            w_ref = None
        b = pl.program_id(0)

        @pl.when(first_ref[b] == 1)
        def _zero():
            out_ref[...] = jnp.zeros_like(out_ref)

        s_vec = s_ref[0]                                  # [n_pad]
        idx = idx_ref[0]                                  # [e1, e2] i32
        gathered = jnp.take(s_vec, idx, axis=0)           # VPU gather
        if w_ref is not None:
            gathered = gathered * w_ref[0]
        dstl = dstl_ref[0]                                # [e1, e2] i32
        e2 = idx.shape[1]
        acc = out_ref[...]                                # [1, tile]
        for r in range(e1):                               # static unroll
            onehot = (dstl[r][:, None] ==
                      jax.lax.broadcasted_iota(jnp.int32, (e2, tile), 1)
                      ).astype(s_vec.dtype)               # [e2, tile]
            acc = acc + jnp.dot(gathered[r][None, :], onehot,
                                preferred_element_type=s_vec.dtype)
        out_ref[...] = acc

    return kernel


@functools.partial(jax.jit, static_argnames=("tile", "e1", "e2", "num_tiles",
                                             "interpret"))
def edge_spmv_call(s_pre_pad: jax.Array, src_idx: jax.Array,
                   dst_local: jax.Array, block_tile: jax.Array,
                   block_first: jax.Array, weights: jax.Array | None = None,
                   *, tile: int, e1: int, e2: int, num_tiles: int,
                   interpret: bool = False) -> jax.Array:
    """Raw pallas_call over a pre-built EdgeTileFormat (arrays on device).

    Args:
      s_pre_pad: f[1, n_gather] gather source; sentinel slots hold 0.
      src_idx / dst_local: i32[num_blocks, e1, e2].
      block_tile / block_first: i32[num_blocks] scalar-prefetch tables.
      weights: optional f[num_blocks, e1, e2] per-edge weights.

    Returns:
      f[1, num_tiles * tile] scatter result; caller slices [:, :n].
    """
    num_blocks = src_idx.shape[0]
    in_specs = [
        pl.BlockSpec((1, s_pre_pad.shape[1]), lambda b, *_: (0, 0)),
        pl.BlockSpec((1, e1, e2), lambda b, *_: (b, 0, 0)),
        pl.BlockSpec((1, e1, e2), lambda b, *_: (b, 0, 0)),
    ]
    inputs = [s_pre_pad, src_idx, dst_local]
    if weights is not None:
        in_specs.append(pl.BlockSpec((1, e1, e2), lambda b, *_: (b, 0, 0)))
        inputs.append(weights)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_blocks,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, tile), lambda b, bt, bf: (0, bt[b])),
    )
    return pl.pallas_call(
        _make_kernel(e1, tile, weights is not None),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, num_tiles * tile),
                                       s_pre_pad.dtype),
        interpret=interpret,
    )(block_tile, block_first, *inputs)
