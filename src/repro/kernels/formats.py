"""Host-side sparse formats feeding the Pallas TPU kernels.

TPU adaptation of the paper's CSR SpMV (DESIGN.md §3): TPUs have no
global-memory atomics, so scatter-style SpMV is re-blocked into two
TPU-native layouts:

* **Edge-tile format** (``EdgeTileFormat``) — edges sorted by destination and
  grouped so every block of ``eblk`` edges scatters into a single output node
  tile of ``tile`` nodes. Inside the kernel the scatter becomes a dense
  one-hot matmul (MXU) over the edge block; gathers of the source vector are
  VPU dynamic loads. Zero padding waste beyond rounding each node tile's edge
  count up to ``eblk`` — the right regime for hyper-sparse social graphs
  (avg degree 2–13).

* **BSR format** (``BsrFormat``) — A is cut into dense ``ts × td`` tiles and
  only non-empty tiles are materialized, streamed HBM→VMEM with a
  scalar-prefetch block table (PagedAttention-style indirection) and consumed
  as MXU mat-vecs. Wins only when the graph is clustered enough for decent
  tile occupancy; kept as the MXU-regime ablation (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..graphs.structure import Graph

__all__ = ["EdgeTileFormat", "BsrFormat", "build_edge_tiles", "build_bsr",
           "pad_edge_tile_blocks"]


@dataclasses.dataclass(frozen=True)
class EdgeTileFormat:
    n: int                   # logical node count
    n_pad: int               # padded node count (multiple of tile, > n)
    tile: int                # output nodes per tile
    e1: int                  # edge-block sublane dim
    e2: int                  # edge-block lane dim
    src_idx: np.ndarray      # i32[num_blocks, e1, e2] — gather index (sentinel n)
    dst_local: np.ndarray    # i32[num_blocks, e1, e2] — dst − tile_base
    block_tile: np.ndarray   # i32[num_blocks] — output tile of each block
    block_first: np.ndarray  # i32[num_blocks] — 1 on a tile's first block
    block_last: np.ndarray   # i32[num_blocks] — 1 on a tile's last block
    num_tiles: int

    @property
    def num_blocks(self) -> int:
        return int(self.src_idx.shape[0])

    @property
    def eblk(self) -> int:
        return self.e1 * self.e2


def build_edge_tiles(graph: Graph, *, tile: int = 256, e1: int = 8,
                     e2: int = 128) -> EdgeTileFormat:
    """Blocked, dst-sorted edge layout (see module docstring)."""
    eblk = e1 * e2
    n = graph.n
    num_tiles = max(1, -(-n // tile))
    n_pad = num_tiles * tile
    src, dst = graph.edges_by_dst
    tile_of_edge = dst // tile
    counts = np.bincount(tile_of_edge, minlength=num_tiles)
    blocks_per_tile = np.maximum(1, -(-counts // eblk))
    padded = blocks_per_tile * eblk
    offsets = np.concatenate([[0], np.cumsum(padded)])[:-1]
    total = int(padded.sum())

    flat_src = np.full(total, n, np.int32)            # sentinel: s_pre[n] == 0
    flat_dstl = np.zeros(total, np.int32)
    # position of each edge inside its tile's padded span
    tile_start_edge = np.concatenate([[0], np.cumsum(counts)])[:-1]
    pos_in_tile = np.arange(graph.m) - tile_start_edge[tile_of_edge]
    slot = offsets[tile_of_edge] + pos_in_tile
    flat_src[slot] = src
    flat_dstl[slot] = dst - tile_of_edge * tile

    num_blocks = int(blocks_per_tile.sum())
    src_idx = flat_src.reshape(num_blocks, e1, e2)
    dst_local = flat_dstl.reshape(num_blocks, e1, e2)
    block_tile = np.repeat(np.arange(num_tiles, dtype=np.int32),
                           blocks_per_tile)
    first = np.ones(num_blocks, np.int32)
    first[1:] = (block_tile[1:] != block_tile[:-1]).astype(np.int32)
    last = np.ones(num_blocks, np.int32)
    last[:-1] = (block_tile[1:] != block_tile[:-1]).astype(np.int32)
    return EdgeTileFormat(n=n, n_pad=n_pad, tile=tile, e1=e1, e2=e2,
                          src_idx=src_idx, dst_local=dst_local,
                          block_tile=block_tile, block_first=first,
                          block_last=last, num_tiles=num_tiles)


def pad_edge_tile_blocks(fmt: EdgeTileFormat,
                         num_blocks: int) -> EdgeTileFormat:
    """Grow a format to exactly ``num_blocks`` blocks with inert padding.

    The multi-tenant fleet (:mod:`repro.serving`) stacks one format per
    tenant along a lane axis, which requires every member of a bucket to
    share the block count.  Padding appends all-sentinel blocks
    (``src_idx == n`` gathers the zero slot, so they scatter nothing) to
    the *last* node tile and moves that tile's ``block_last`` flag onto the
    final pad block — the tile's epilogue then runs after the inert blocks
    have accumulated zeros, leaving the kernel's output and gap unchanged.
    """
    extra = num_blocks - fmt.num_blocks
    if extra < 0:
        raise ValueError(f"format already has {fmt.num_blocks} blocks "
                         f"> requested {num_blocks}")
    if extra == 0:
        return fmt
    pad_shape = (extra, fmt.e1, fmt.e2)
    src_idx = np.concatenate(
        [fmt.src_idx, np.full(pad_shape, fmt.n, np.int32)])
    dst_local = np.concatenate(
        [fmt.dst_local, np.zeros(pad_shape, np.int32)])
    last_tile = fmt.num_tiles - 1
    block_tile = np.concatenate(
        [fmt.block_tile, np.full(extra, last_tile, np.int32)])
    block_first = np.concatenate(
        [fmt.block_first, np.zeros(extra, np.int32)])
    block_last = np.concatenate(
        [fmt.block_last, np.zeros(extra, np.int32)])
    block_last[block_tile == last_tile] = 0
    block_last[-1] = 1
    return dataclasses.replace(fmt, src_idx=src_idx, dst_local=dst_local,
                               block_tile=block_tile,
                               block_first=block_first,
                               block_last=block_last)


@dataclasses.dataclass(frozen=True)
class BsrFormat:
    n: int
    n_src_pad: int
    n_dst_pad: int
    ts: int                  # src-tile (contraction) size
    td: int                  # dst-tile (output) size
    tiles: np.ndarray        # f32[num_blocks, ts, td] dense tile values
    src_tile: np.ndarray     # i32[num_blocks]
    dst_tile: np.ndarray     # i32[num_blocks]
    block_first: np.ndarray  # i32[num_blocks]
    num_dst_tiles: int

    @property
    def num_blocks(self) -> int:
        return int(self.tiles.shape[0])

    @property
    def occupancy(self) -> float:
        return float((self.tiles != 0).mean()) if self.tiles.size else 0.0


def build_bsr(graph: Graph, *, ts: int = 128, td: int = 128,
              edge_values: np.ndarray | None = None,
              dtype=np.float32) -> BsrFormat:
    """Pack the non-empty (src-tile × dst-tile) blocks of the push matrix.

    ``edge_values`` defaults to 1.0 (adjacency); the ψ scaling (1/w_j, μ_i)
    is folded into the input/epilogue vectors by the caller.
    """
    n = graph.n
    nst = max(1, -(-n // ts))
    ndt = max(1, -(-n // td))
    src, dst = graph.edges_by_dst
    vals = (np.ones(graph.m, dtype) if edge_values is None
            else np.asarray(edge_values, dtype))
    st = src // ts
    dt = dst // td
    key = dt.astype(np.int64) * nst + st          # dst-major block ordering
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    uniq, start = np.unique(key_s, return_index=True)
    num_blocks = max(1, uniq.size)

    tiles = np.zeros((num_blocks, ts, td), dtype)
    if uniq.size:
        block_of_edge = np.searchsorted(uniq, key_s)
        r = (src[order] % ts).astype(np.int64)
        c = (dst[order] % td).astype(np.int64)
        np.add.at(tiles, (block_of_edge, r, c), vals[order])
        src_tile = (uniq % nst).astype(np.int32)
        dst_tile = (uniq // nst).astype(np.int32)
    else:  # empty graph — single zero block
        src_tile = np.zeros(1, np.int32)
        dst_tile = np.zeros(1, np.int32)
    # every dst tile must be visited at least once so its output block is
    # zero-initialized — insert an explicit zero block for uncovered tiles
    missing = np.setdiff1d(np.arange(ndt, dtype=np.int32), dst_tile)
    if missing.size:
        tiles = np.concatenate(
            [tiles, np.zeros((missing.size, ts, td), dtype)])
        src_tile = np.concatenate([src_tile, np.zeros(missing.size, np.int32)])
        dst_tile = np.concatenate([dst_tile, missing])
        order2 = np.argsort(dst_tile, kind="stable")
        tiles, src_tile, dst_tile = (tiles[order2], src_tile[order2],
                                     dst_tile[order2])
        num_blocks = tiles.shape[0]
    first = np.ones(num_blocks, np.int32)
    first[1:] = (dst_tile[1:] != dst_tile[:-1]).astype(np.int32)
    return BsrFormat(n=n, n_src_pad=nst * ts, n_dst_pad=ndt * td, ts=ts,
                     td=td, tiles=tiles, src_tile=src_tile, dst_tile=dst_tile,
                     block_first=first, num_dst_tiles=ndt)
