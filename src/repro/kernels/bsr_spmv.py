"""Block-sparse-row SpMV Pallas kernel — dense MXU tiles with a block table.

The MXU-regime alternative to ``edge_spmv``: A is cut into dense ts×td tiles,
only non-empty tiles are stored, and a scalar-prefetch block table drives the
BlockSpec index maps (the PagedAttention indirection pattern):

  out[dst_tile]  +=  s_pre[src_tile] @ tiles[b]        # [1,ts] @ [ts,td] MXU

Grid order is dst-major so each output tile stays resident in VMEM across
its inner accumulation. For hyper-sparse social graphs tile occupancy is
poor (EXPERIMENTS.md §Perf quantifies it); the kernel exists as the honest
MXU baseline and wins on clustered/banded operators.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bsr_spmv_call"]


def _kernel(src_tile_ref, dst_tile_ref, first_ref, s_ref, tiles_ref, out_ref):
    b = pl.program_id(0)

    @pl.when(first_ref[b] == 1)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(s_ref[...], tiles_ref[0],
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ts", "td", "num_dst_tiles",
                                             "interpret"))
def bsr_spmv_call(s_pre_pad: jax.Array, tiles: jax.Array,
                  src_tile: jax.Array, dst_tile: jax.Array,
                  block_first: jax.Array, *, ts: int, td: int,
                  num_dst_tiles: int, interpret: bool = False) -> jax.Array:
    """Raw pallas_call over a pre-built BsrFormat.

    Args:
      s_pre_pad: f[1, n_src_pad] input vector (already × 1/w).
      tiles: f[num_blocks, ts, td] packed dense tiles.
      src_tile / dst_tile / block_first: i32[num_blocks] block tables.

    Returns:
      f[1, num_dst_tiles * td]; caller slices [:, :n].
    """
    num_blocks = tiles.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, ts), lambda b, st, dt, bf: (0, st[b])),
            pl.BlockSpec((1, ts, td), lambda b, st, dt, bf: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, td), lambda b, st, dt, bf: (0, dt[b])),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, num_dst_tiles * td),
                                       s_pre_pad.dtype),
        interpret=interpret,
    )(src_tile, dst_tile, block_first, s_pre_pad, tiles)
