"""Pallas TPU kernels (validated in interpret mode on CPU) + formats."""
from .formats import EdgeTileFormat, BsrFormat, build_edge_tiles, build_bsr
from .ops import (DeviceEdgeTiles, DeviceBsr, edge_spmv, bsr_spmv, seg_mm,
                  power_step, PsiKernelEngine, default_interpret)
from . import ref

__all__ = ["EdgeTileFormat", "BsrFormat", "build_edge_tiles", "build_bsr",
           "DeviceEdgeTiles", "DeviceBsr", "edge_spmv", "bsr_spmv", "seg_mm",
           "power_step", "PsiKernelEngine", "default_interpret", "ref"]
