"""Segment-sum of feature rows as one-hot MXU matmuls (GNN aggregation).

The message-passing primitive Y[i] = Σ_{e: dst_e = i} M[e, :] shared by
GraphSAGE / PNA / NequIP / EquiformerV2 aggregation and by the EmbeddingBag
reduce in the recsys stack. Per edge block:

    out[dst_tile]  +=  one_hotᵀ @ M_block       # [tile, eblk] @ [eblk, d] MXU

with edges pre-sorted/padded by ``formats.build_edge_tiles`` bookkeeping so
each block maps to one output node tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["seg_mm_call"]


def _kernel(block_tile_ref, first_ref, msg_ref, dstl_ref, out_ref, *,
            tile: int):
    b = pl.program_id(0)

    @pl.when(first_ref[b] == 1)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    msg = msg_ref[0]                                   # [eblk, d]
    dstl = dstl_ref[0]                                 # [1, eblk] i32
    eblk = msg.shape[0]
    onehot_t = (jax.lax.broadcasted_iota(jnp.int32, (tile, eblk), 0) ==
                dstl).astype(msg.dtype)                # [tile, eblk]
    out_ref[0] += jnp.dot(onehot_t, msg,
                          preferred_element_type=msg.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "eblk", "num_tiles",
                                             "interpret"))
def seg_mm_call(messages: jax.Array, dst_local: jax.Array,
                block_tile: jax.Array, block_first: jax.Array, *,
                tile: int, eblk: int, num_tiles: int,
                interpret: bool = False) -> jax.Array:
    """Raw pallas_call: blocked segment-sum of message rows.

    Args:
      messages: f[num_blocks, eblk, d] — edge features, dst-sorted/padded
        (padding rows are zero).
      dst_local: i32[num_blocks, 1, eblk] — dst − tile_base per edge.
      block_tile / block_first: i32[num_blocks].

    Returns:
      f[num_tiles * tile, d].
    """
    num_blocks, _, d = messages.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((1, eblk, d), lambda b, *_: (b, 0, 0)),
            pl.BlockSpec((1, 1, eblk), lambda b, *_: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile, d), lambda b, bt, bf: (bt[b], 0, 0)),
    )
    out = pl.pallas_call(
        _kernel_wrapper(tile),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_tiles, tile, d), messages.dtype),
        interpret=interpret,
    )(block_tile, block_first, messages, dst_local)
    return out.reshape(num_tiles * tile, d)


def _kernel_wrapper(tile: int):
    return functools.partial(_kernel, tile=tile)
