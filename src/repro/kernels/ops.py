"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only — the
kernels are *targeted* at TPU v5e and *validated* in interpret mode, per
DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .formats import BsrFormat, EdgeTileFormat, build_bsr, build_edge_tiles
from .edge_spmv import edge_spmv_call
from .bsr_spmv import bsr_spmv_call
from .power_step import power_step_call
from .seg_mm import seg_mm_call

__all__ = [
    "default_interpret", "DeviceEdgeTiles", "DeviceBsr",
    "edge_spmv", "bsr_spmv", "seg_mm", "power_step", "PsiKernelEngine",
]


def default_interpret() -> bool:
    return jax.devices()[0].platform != "tpu"


# --------------------------------------------------------------------- #
# Device-resident format mirrors (pytrees: arrays data, sizes static)
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class DeviceEdgeTiles:
    n: int
    n_pad: int            # num_tiles * tile
    n_gather: int         # padded gather-source length (sentinel slots zero)
    tile: int
    e1: int
    e2: int
    num_tiles: int
    src_idx: jax.Array
    dst_local: jax.Array
    block_tile: jax.Array
    block_first: jax.Array
    block_last: jax.Array

    @classmethod
    def from_format(cls, fmt: EdgeTileFormat) -> "DeviceEdgeTiles":
        return cls(
            n=fmt.n, n_pad=fmt.num_tiles * fmt.tile,
            n_gather=fmt.num_tiles * fmt.tile + 128,
            tile=fmt.tile, e1=fmt.e1, e2=fmt.e2, num_tiles=fmt.num_tiles,
            src_idx=jnp.asarray(fmt.src_idx),
            dst_local=jnp.asarray(fmt.dst_local),
            block_tile=jnp.asarray(fmt.block_tile),
            block_first=jnp.asarray(fmt.block_first),
            block_last=jnp.asarray(fmt.block_last))

    def pad_gather_source(self, s_pre: jax.Array) -> jax.Array:
        """f[n] → f[1, n_gather] with zeros beyond n (sentinel = n)."""
        return jnp.pad(s_pre, (0, self.n_gather - s_pre.shape[0]))[None, :]

    def pad_node_vector(self, v: jax.Array) -> jax.Array:
        return jnp.pad(v, (0, self.n_pad - v.shape[0]))[None, :]


jax.tree_util.register_dataclass(
    DeviceEdgeTiles,
    data_fields=["src_idx", "dst_local", "block_tile", "block_first",
                 "block_last"],
    meta_fields=["n", "n_pad", "n_gather", "tile", "e1", "e2", "num_tiles"])


@dataclasses.dataclass(frozen=True)
class DeviceBsr:
    n: int
    n_src_pad: int
    ts: int
    td: int
    num_dst_tiles: int
    tiles: jax.Array
    src_tile: jax.Array
    dst_tile: jax.Array
    block_first: jax.Array

    @classmethod
    def from_format(cls, fmt: BsrFormat) -> "DeviceBsr":
        return cls(n=fmt.n, n_src_pad=fmt.n_src_pad, ts=fmt.ts, td=fmt.td,
                   num_dst_tiles=fmt.num_dst_tiles,
                   tiles=jnp.asarray(fmt.tiles),
                   src_tile=jnp.asarray(fmt.src_tile),
                   dst_tile=jnp.asarray(fmt.dst_tile),
                   block_first=jnp.asarray(fmt.block_first))


jax.tree_util.register_dataclass(
    DeviceBsr, data_fields=["tiles", "src_tile", "dst_tile", "block_first"],
    meta_fields=["n", "n_src_pad", "ts", "td", "num_dst_tiles"])


# --------------------------------------------------------------------- #
# Functional wrappers
# --------------------------------------------------------------------- #
def edge_spmv(s_pre: jax.Array, fmt: DeviceEdgeTiles,
              weights: jax.Array | None = None,
              interpret: bool | None = None) -> jax.Array:
    """t_i = Σ_{(j→i)} w_e s_pre_j via the edge-tile kernel. Returns f[n]."""
    interpret = default_interpret() if interpret is None else interpret
    out = edge_spmv_call(
        fmt.pad_gather_source(s_pre), fmt.src_idx, fmt.dst_local,
        fmt.block_tile, fmt.block_first, weights,
        tile=fmt.tile, e1=fmt.e1, e2=fmt.e2, num_tiles=fmt.num_tiles,
        interpret=interpret)
    return out[0, :fmt.n]


def bsr_spmv(s_pre: jax.Array, fmt: DeviceBsr,
             interpret: bool | None = None) -> jax.Array:
    """t = s_preᵀ A via dense MXU tiles. Returns f[n]."""
    interpret = default_interpret() if interpret is None else interpret
    s_pad = jnp.pad(s_pre, (0, fmt.n_src_pad - s_pre.shape[0]))[None, :]
    out = bsr_spmv_call(s_pad, fmt.tiles, fmt.src_tile, fmt.dst_tile,
                        fmt.block_first, ts=fmt.ts, td=fmt.td,
                        num_dst_tiles=fmt.num_dst_tiles, interpret=interpret)
    return out[0, :fmt.n]


def seg_mm(messages: jax.Array, fmt: DeviceEdgeTiles,
           interpret: bool | None = None) -> jax.Array:
    """Blocked segment-sum of rows. messages: f[num_blocks, e1*e2, d] in the
    fmt's padded edge order (padding rows zero). Returns f[n, d]."""
    interpret = default_interpret() if interpret is None else interpret
    eblk = fmt.e1 * fmt.e2
    dstl = fmt.dst_local.reshape(-1, 1, eblk)
    out = seg_mm_call(messages, dstl, fmt.block_tile, fmt.block_first,
                      tile=fmt.tile, eblk=eblk, num_tiles=fmt.num_tiles,
                      interpret=interpret)
    return out[:fmt.n]


def power_step(s: jax.Array, inv_w_gather: jax.Array, mu_pad: jax.Array,
               c_pad: jax.Array, fmt: DeviceEdgeTiles,
               interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """One fused Alg. 2 step on padded [1, n_pad] node vectors.

    Args:
      s: f[1, n_pad] current series vector (padded layout).
      inv_w_gather: f[1, n_gather] 1/w in gather layout (zeros in pads).
      mu_pad / c_pad: f[1, n_pad].
    Returns:
      (s_new f[1, n_pad], gap scalar ‖Δs‖₁).
    """
    interpret = default_interpret() if interpret is None else interpret
    s_pre = jnp.pad(s, ((0, 0), (0, fmt.n_gather - fmt.n_pad))) * inv_w_gather
    s_new, gap = power_step_call(
        s_pre, fmt.src_idx, fmt.dst_local, fmt.block_tile, fmt.block_first,
        fmt.block_last, mu_pad, c_pad, s,
        tile=fmt.tile, e1=fmt.e1, e2=fmt.e2, num_tiles=fmt.num_tiles,
        interpret=interpret)
    return s_new, gap[0, 0]


# --------------------------------------------------------------------- #
# Full Power-ψ on the fused kernel — absorbed by the unified engine
# --------------------------------------------------------------------- #
class PsiKernelEngine:
    """Back-compat shim: the fused-kernel solver now lives in
    ``repro.core.engine`` as the ``pallas`` backend — construct it with
    ``make_engine("pallas", graph=..., activity=...)``. This wrapper keeps
    the historical constructor/run signature working."""

    def __init__(self, graph, activity, *, tile: int = 256, e1: int = 8,
                 e2: int = 128, dtype=jnp.float32,
                 interpret: bool | None = None):
        from ..core.engine import make_engine
        self._engine = make_engine("pallas", graph=graph, activity=activity,
                                   tile=tile, e1=e1, e2=e2, dtype=dtype,
                                   interpret=interpret)
        self.ops = self._engine.ops
        self.fmt = self._engine.fmt
        self.interpret = self._engine.interpret

    def run(self, *, tol: float = 1e-9, max_iter: int = 10_000,
            s0=None):
        return self._engine.run(tol=tol, max_iter=max_iter, s0=s0)
