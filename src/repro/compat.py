"""Cross-version JAX API shims — the single home for version drift.

``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``
and renamed its replication-check kwarg ``check_rep`` → ``check_vma`` along the
way. Every sharded module routes through this wrapper so the rest of the code
is version-agnostic.
"""
from __future__ import annotations

import jax

try:                                      # jax >= 0.6: top-level export
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:                    # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    """Portable ``shard_map`` with the replication check disabled by default
    (all call sites in this repo pass explicit out_specs)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check})
