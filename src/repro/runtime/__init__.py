from .psi_driver import PsiDriver, DriverReport
