"""Fault-tolerant distributed Power-ψ drivers — shared machinery + the
synchronous bulk-chunk driver.

The fixed point s* is the *entire* algorithm state (O(N) floats) and the
iteration is a contraction, which yields unusually strong resilience
properties, all exercised here (and in tests/test_runtime.py):

  * **checkpoint/restart** — s is checkpointed every chunk; restart resumes
    the contraction exactly (no approximation, no lost work beyond the
    current chunk).
  * **elastic re-mesh** — s converts between meshes through the host layout
    (`Partition2D.from_src_layout` → new `to_src_layout`); a job can lose or
    gain pods between chunks and continue warm.
  * **straggler mitigation** — per-chunk deadline tracking flags slow
    devices with the measured duration and the deadline it exceeded; the
    escalation path is flag → re-mesh without the straggler (the elastic
    re-mesh above).

Because ρ(A) < 1 the iteration also tolerates bounded-stale partials
(asynchronous fixed-point theory) — that headroom is now implemented:
:class:`repro.asyncexec.AsyncPsiDriver` shares the checkpoint + deadline
machinery of :class:`PsiDriverBase` below but replaces the bulk-synchronous
chunk barrier with the overlapped bounded-staleness scheduler
(docs/ASYNC.md).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from ..ckpt import checkpoint
from ..core.distributed import DistributedPsi
from ..core.engine import ChunkExtrapolator
from ..core.incremental import RankingCache
from ..graphs.partition import partition_2d
from ..obs import convergence as obs_convergence
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace

__all__ = ["PsiDriver", "PsiDriverBase", "DriverReport", "SlowChunk"]


@dataclasses.dataclass(frozen=True)
class SlowChunk:
    """One deadline violation: which chunk, how slow, against what."""

    chunk: int           # chunk index (sync) / chunk-step index (async)
    duration: float      # measured wall seconds of the offending chunk
    deadline: float      # the deadline it exceeded (factor × running median)


@dataclasses.dataclass
class DriverReport:
    iterations: int
    gap: float
    chunks: int
    restarts: int
    slow_chunks: list[int]
    psi: np.ndarray
    # straggler forensics (satellite of the async-executor PR): not just
    # *which* chunks were slow but how slow, and the deadline that tripped
    chunk_durations: list[float] = dataclasses.field(default_factory=list)
    slow_chunk_events: list[SlowChunk] = dataclasses.field(
        default_factory=list)

    def queries(self) -> RankingCache:
        """Batched query layer over the converged ψ (shared with PsiService)."""
        return RankingCache(self.psi)


class PsiDriverBase:
    """Checkpoint + straggler-deadline machinery shared by the synchronous
    :class:`PsiDriver` and the asynchronous
    :class:`repro.asyncexec.AsyncPsiDriver`.

    Subclasses call :meth:`_note_duration` once per chunk (or chunk-step)
    and the :meth:`_ckpt_save` / :meth:`_ckpt_restore_latest` pair around
    their own state pytrees — what that state *is* (a src-layout vector vs
    a board + epoch vector) stays backend-specific.
    """

    def __init__(self, *, ckpt_dir: str | None = None,
                 deadline_factor: float = 3.0):
        self.ckpt_dir = ckpt_dir
        self.deadline_factor = deadline_factor
        self._reset_tracking()

    # -- straggler deadlines -------------------------------------------- #
    def _reset_tracking(self) -> None:
        self._durations: list[float] = []
        self._slow: list[int] = []
        self._slow_events: list[SlowChunk] = []

    def _note_duration(self, idx: int, dt: float) -> bool:
        """Record one chunk duration; returns True (and logs a
        :class:`SlowChunk`) when it exceeded ``deadline_factor`` × the
        running median.

        ``dt`` must come off the shared span clock (a
        :class:`repro.obs.trace.Span` around the chunk) so the
        :class:`SlowChunk` event, the ``psi_chunk_seconds`` histogram and
        the trace span all describe one measurement.
        """
        slow = False
        if self._durations:
            deadline = self.deadline_factor * float(
                np.median(self._durations))
            if dt > deadline:
                slow = True
                self._slow.append(int(idx))
                self._slow_events.append(
                    SlowChunk(int(idx), float(dt), float(deadline)))
                obs_metrics.counter(
                    "psi_slow_chunks_total",
                    "chunks exceeding deadline_factor x running median"
                ).inc()
        self._durations.append(float(dt))
        obs_metrics.histogram("psi_chunk_seconds",
                              "driver chunk wall seconds").observe(dt)
        return slow

    # -- checkpoints ----------------------------------------------------- #
    def _ckpt_save(self, step: int, tree: dict) -> None:
        if self.ckpt_dir:
            checkpoint.save(self.ckpt_dir, step, tree)

    def _ckpt_restore_latest(self, template: dict) -> dict | None:
        if not self.ckpt_dir:
            return None
        # restore_latest (not latest_step + restore): it skips corrupt /
        # torn steps and tolerates a concurrent save(keep=…) GC pruning the
        # step between listing and load, falling back to the previous
        # complete one instead of crashing the restart
        return checkpoint.restore_latest(self.ckpt_dir, template)


class PsiDriver(PsiDriverBase):
    """Bulk-synchronous chunk driver over :class:`DistributedPsi`."""

    def __init__(self, dist: DistributedPsi, *, ckpt_dir: str | None = None,
                 chunk_iters: int = 16, deadline_factor: float = 3.0,
                 accelerate: bool = False):
        super().__init__(ckpt_dir=ckpt_dir, deadline_factor=deadline_factor)
        self.dist = dist
        self.chunk_iters = chunk_iters
        self.accelerate = accelerate         # chunk-level Aitken jumps
        self._warm_s = None                  # set by remesh(): elastic resume

    @classmethod
    def from_engine(cls, engine, **kw) -> "PsiDriver":
        """Build a driver from a prepared ``distributed`` PsiEngine
        (inherits the engine's ``accelerate`` setting)."""
        if getattr(engine, "dist", None) is None:
            raise ValueError("engine has no distributed state; "
                             "use make_engine('distributed', graph=..., ...)")
        kw.setdefault("accelerate", getattr(engine, "accelerate", False))
        return cls(engine.dist, chunk_iters=engine.chunk_iters, **kw)

    def run(self, *, tol: float = 1e-8, max_iter: int = 2000,
            fail_hook: Callable[[int], bool] | None = None) -> DriverReport:
        """Iterate to convergence with checkpoint/restart.

        ``fail_hook(chunk_idx) → True`` injects a simulated failure: the
        driver drops its in-memory state and restores from the last
        checkpoint, exactly like a restarted job would.
        """
        dist = self.dist
        run_chunk = dist.make_run(chunk_iters=self.chunk_iters)
        epi = jax.jit(dist.make_epilogue())
        # consume the elastic-remesh warm vector when present: the re-meshed
        # job resumes the contraction instead of restarting from c (one-shot —
        # later runs must resume their own progress, not this stale snapshot)
        s = dist.arrays.c_src if self._warm_s is None else self._warm_s
        self._warm_s = None
        extrap = ChunkExtrapolator(tol) if self.accelerate else None
        it = 0
        chunk_idx = 0
        restarts = 0
        gap = float("inf")
        self._reset_tracking()
        self._ckpt_save(0, dict(s=s, it=np.int64(0)))
        rec = obs_convergence.begin("driver")
        while it < max_iter and gap > tol:
            # one measurement on the shared span clock: the SlowChunk
            # deadline check, chunk_durations, and the trace span all see
            # this span's duration (sync() keeps the block_until_ready)
            with obs_trace.span("driver.chunk", chunk=chunk_idx) as sp:
                s_new, gap_dev = run_chunk(s, dist.arrays)
                sp.sync(s_new)
            self._note_duration(chunk_idx, sp.duration_s)

            if fail_hook is not None and fail_hook(chunk_idx):
                restarts += 1
                data = self._ckpt_restore_latest(
                    dict(s=np.zeros(np.shape(s), np.float32), it=np.int64(0)))
                if data is not None:
                    s = jax.device_put(
                        data["s"], jax.sharding.NamedSharding(
                            dist.mesh, _src_spec(dist)))
                    it = int(data["it"])
                if extrap is not None:
                    extrap.reset()       # restored s breaks the Δ history
                chunk_idx += 1
                continue

            gap = float(gap_dev)
            it += self.chunk_iters
            obs_convergence.record_gap(it, certified=gap)
            # chunk-level Aitken jump (verified by the next chunk's plain
            # steps — Eq. 19 semantics preserved, see ChunkExtrapolator)
            s = extrap.advance(s, s_new, gap) if extrap else s_new
            chunk_idx += 1
            self._ckpt_save(it, dict(s=s, it=np.int64(it)))
        psi_piece = epi(s, dist.arrays)
        psi = dist.part.from_src_layout(
            np.asarray(psi_piece).reshape(dist.part.d, -1))
        obs_convergence.finish(rec, iterations=it, gap=gap,
                               converged=gap <= tol,
                               duration_s=float(sum(self._durations)))
        return DriverReport(iterations=it, gap=gap, chunks=chunk_idx,
                            restarts=restarts, slow_chunks=self._slow,
                            psi=psi, chunk_durations=self._durations,
                            slow_chunk_events=self._slow_events)

    # ------------------------------------------------------------------ #
    def remesh(self, new_mesh, graph, activity, s_current) -> "PsiDriver":
        """Elastic re-mesh: carry s across a mesh change (warm restart)."""
        old = self.dist
        s_host = old.part.from_src_layout(
            np.asarray(jax.device_get(s_current)))
        new_dist = DistributedPsi.from_graph(graph, activity, new_mesh,
                                             dtype=old.dtype)
        s_new = jax.device_put(
            new_dist.part.to_src_layout(s_host),
            jax.sharding.NamedSharding(new_mesh, _src_spec(new_dist)))
        driver = PsiDriver(new_dist, ckpt_dir=self.ckpt_dir,
                           chunk_iters=self.chunk_iters)
        driver._warm_s = s_new
        return driver


def _src_spec(dist: DistributedPsi):
    from jax.sharding import PartitionSpec as P
    return P(dist.src_axes, None)
