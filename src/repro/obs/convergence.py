"""Per-resolve gap/certificate trajectory recorder.

Answers "is the certificate bound tightening?" without adding host syncs:
only gaps a layer *already* reads on the host are recorded — chunk-loop
gaps (``distributed`` engine, the sync/async drivers), the push solver's
per-round raw gap and Neumann-tail certificate, and every resolve's final
(iterations, gap, converged) endpoint. Fully on-device loops (reference /
pallas ``lax.while_loop``) contribute their endpoint only: forcing their
intermediate gaps to the host would change the execution being measured.

One :class:`ResolveRecord` is opened per resolve (engine ``run``, driver
``run``, fleet ``solve``) on a per-thread stack, so nested resolves (a
supervisor's sync-sweep rung inside a supervised resolve) attribute their
points to the innermost record. Completed records land in per-tenant ring
buffers, queryable as a time series via :meth:`ConvergenceTracker.series`
and exported inside ``repro.obs.dump()``.

Each finish also feeds the metrics registry (``psi_resolves_total``,
``psi_resolve_seconds``, ``psi_resolve_iterations``, ``psi_resolve_gap``),
and Aitken jump accept/reject lands in ``psi_aitken_jumps_total{outcome=}``
— so the registry dump alone answers the coarse questions and the
trajectory answers the per-resolve one.
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque

from . import metrics

__all__ = ["ResolveRecord", "ConvergenceTracker", "get_tracker",
           "set_tracker", "begin", "finish", "record_gap", "record_aitken",
           "record_push", "current"]

_TLS = threading.local()


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


class ResolveRecord:
    """One resolve's trajectory (see module docstring)."""

    __slots__ = ("backend", "tenant", "index", "wall_start", "points",
                 "points_dropped", "aitken_accepted", "aitken_rejected",
                 "push", "iterations", "gap", "converged", "duration_s",
                 "psi_error_bound", "_max_points")

    def __init__(self, backend: str, tenant, index: int, max_points: int):
        self.backend = backend
        self.tenant = tenant
        self.index = index
        self.wall_start = time.time()
        self.points: list[dict] = []
        self.points_dropped = 0
        self.aitken_accepted = 0
        self.aitken_rejected = 0
        self.push: dict | None = None
        self.iterations = 0
        self.gap = math.nan
        self.converged: bool | None = None
        self.duration_s = 0.0
        self.psi_error_bound: float | None = None
        self._max_points = max_points

    def add_point(self, t: int, raw=None, certified=None) -> None:
        if len(self.points) >= self._max_points:
            self.points_dropped += 1
            return
        p: dict = {"t": int(t)}
        if raw is not None:
            p["raw"] = float(raw)
        if certified is not None:
            p["certified"] = float(certified)
        self.points.append(p)

    def to_json(self) -> dict:
        out = dict(backend=self.backend, tenant=self.tenant,
                   index=self.index, wall_start=self.wall_start,
                   iterations=self.iterations, gap=self.gap,
                   converged=self.converged, duration_s=self.duration_s,
                   points=self.points)
        if self.points_dropped:
            out["points_dropped"] = self.points_dropped
        if self.aitken_accepted or self.aitken_rejected:
            out["aitken_accepted"] = self.aitken_accepted
            out["aitken_rejected"] = self.aitken_rejected
        if self.push is not None:
            out["push"] = self.push
        if self.psi_error_bound is not None:
            out["psi_error_bound"] = self.psi_error_bound
        return out


class ConvergenceTracker:
    """Per-tenant ring buffers of completed :class:`ResolveRecord`\\ s."""

    enabled = True

    def __init__(self, *, keep: int = 256, max_points: int = 4096):
        self._lock = threading.Lock()
        self._series: dict = {}
        self.keep = int(keep)
        self.max_points = int(max_points)
        self._count = 0
        self._subscribers: list = []

    def subscribe(self, fn):
        """Call ``fn(record)`` after every finished resolve — the hook
        :class:`repro.obs.watch.ConvergenceWatch` rides on. Returns
        ``fn`` so it can be passed back to :meth:`unsubscribe`."""
        with self._lock:
            if fn not in self._subscribers:
                self._subscribers.append(fn)
        return fn

    def unsubscribe(self, fn) -> None:
        with self._lock:
            if fn in self._subscribers:
                self._subscribers.remove(fn)

    def begin(self, backend: str, tenant=None) -> ResolveRecord:
        with self._lock:
            self._count += 1
            idx = self._count
        rec = ResolveRecord(backend, tenant, idx, self.max_points)
        _stack().append(rec)
        return rec

    def finish(self, rec: ResolveRecord, *, iterations=None, gap=None,
               converged=None, duration_s=None,
               psi_error_bound=None) -> ResolveRecord:
        st = _stack()
        if rec in st:
            st.remove(rec)
        if iterations is not None:
            rec.iterations = int(iterations)
        if gap is not None:
            rec.gap = float(gap)
        if converged is not None:
            rec.converged = bool(converged)
        if duration_s is not None:
            rec.duration_s = float(duration_s)
        if psi_error_bound is not None:
            rec.psi_error_bound = float(psi_error_bound)
        key = rec.tenant if rec.tenant is not None else "_default"
        with self._lock:
            ring = self._series.get(key)
            if ring is None:
                ring = self._series[key] = deque(maxlen=self.keep)
            ring.append(rec)
        metrics.counter("psi_resolves_total", "resolves by backend",
                        labelnames=("backend",)) \
            .labels(backend=rec.backend).inc()
        metrics.histogram("psi_resolve_seconds", "resolve wall seconds",
                          labelnames=("backend",)) \
            .labels(backend=rec.backend).observe(rec.duration_s)
        metrics.histogram(
            "psi_resolve_iterations", "iterations per resolve",
            labelnames=("backend",),
            buckets=(1, 2, 5, 10, 20, 50, 100, 200, 500,
                     1000, 2000, 5000)) \
            .labels(backend=rec.backend).observe(rec.iterations)
        if math.isfinite(rec.gap):
            metrics.gauge("psi_resolve_gap",
                          "final Eq. 19 gap of the last resolve",
                          labelnames=("backend",)) \
                .labels(backend=rec.backend).set(rec.gap)
        with self._lock:
            subs = list(self._subscribers)
        for fn in subs:
            try:
                fn(rec)
            except Exception as exc:   # a broken observer must not fail
                from . import log     # the resolve it observes
                log.event("convergence_subscriber_error", str(exc),
                          level="error", backend=rec.backend)
        return rec

    def series(self, tenant=None) -> list[ResolveRecord]:
        key = tenant if tenant is not None else "_default"
        with self._lock:
            return list(self._series.get(key, ()))

    def tenants(self) -> list:
        with self._lock:
            return sorted(self._series, key=str)

    def to_json(self) -> dict:
        with self._lock:
            items = {str(k): [r.to_json() for r in ring]
                     for k, ring in self._series.items()}
        return items

    def reset(self) -> None:
        with self._lock:
            self._series.clear()
            self._count = 0


class _NullTracker:
    enabled = False

    def begin(self, backend, tenant=None):
        return None

    def finish(self, rec, **kw):
        return rec

    def subscribe(self, fn):
        return fn

    def unsubscribe(self, fn):
        pass

    def series(self, tenant=None):
        return []

    def tenants(self):
        return []

    def to_json(self):
        return {}

    def reset(self):
        pass


NULL_TRACKER = _NullTracker()
_TRACKER = ConvergenceTracker()


def get_tracker():
    return _TRACKER


def set_tracker(tracker):
    """Install the process tracker (NULL_TRACKER disables); returns the
    previous one."""
    global _TRACKER
    prev, _TRACKER = _TRACKER, tracker
    return prev


# -- instrumentation-site API (cheap no-ops when nothing is active) ----- #
def begin(backend: str, tenant=None):
    return _TRACKER.begin(backend, tenant)


def finish(rec, **kw):
    if rec is not None:
        _TRACKER.finish(rec, **kw)
    return rec


def current() -> ResolveRecord | None:
    """The innermost open resolve record on this thread, if any."""
    st = getattr(_TLS, "stack", None)
    return st[-1] if st else None


def record_gap(t: int, raw=None, certified=None) -> None:
    """Attach one host-visible gap sample to the current resolve."""
    st = getattr(_TLS, "stack", None)
    if st:
        st[-1].add_point(t, raw=raw, certified=certified)


def record_aitken(accepted: bool) -> None:
    """Count one Aitken jump decision (chunk-level extrapolation)."""
    st = getattr(_TLS, "stack", None)
    if st:
        rec = st[-1]
        if accepted:
            rec.aitken_accepted += 1
        else:
            rec.aitken_rejected += 1
    metrics.counter("psi_aitken_jumps_total",
                    "chunk-level Aitken jumps by outcome",
                    labelnames=("outcome",)) \
        .labels(outcome="accepted" if accepted else "rejected").inc()


def record_push(**stats) -> None:
    """Attach the push solver's run stats (edge_work, cert_edge_work, ...)
    to the current resolve and mirror the work counters to the registry."""
    st = getattr(_TLS, "stack", None)
    if st:
        st[-1].push = {k: (float(v) if isinstance(v, float) else v)
                       for k, v in stats.items()}
    for key in ("edge_work", "cert_edge_work"):
        if stats.get(key):
            metrics.counter(f"psi_push_{key}_total",
                            f"cumulative push {key}").inc(float(stats[key]))
