"""Declarative SLOs, error budgets, and multi-window burn-rate alerts.

The telemetry plane (:mod:`repro.obs.metrics`) measures; this module
*judges*. An :class:`SLO` names a signal (a callable reading the live
registry or convergence tracker), a target, and a compliance objective;
the :class:`SLOEngine` samples every SLO on each :meth:`SLOEngine.tick`,
keeps a compliance window per SLO, accounts the error budget, and fires
multi-window burn-rate alerts as countable :mod:`repro.obs.log` events.

Burn-rate math (classic SRE form, windows scaled to drill time):

* error budget = ``1 - objective`` (e.g. objective 0.99 → 1% budget);
* burn rate over a window = (fraction of non-compliant samples in the
  window) / budget — burn 1.0 spends the budget exactly at the rate the
  compliance period allows, burn ``B`` exhausts it ``B``× faster;
* an alert rule pairs a *fast* and a *slow* window with one threshold
  and fires only when **both** exceed it — the fast window gives low
  detection latency, the slow window suppresses one-tick blips.

Production rules use 5m/1h at burn 14.4 and 30m/6h at burn 6; the drill
catalog (:func:`default_slos`) keeps those ratios but compresses the
absolute spans via ``time_scale`` so a seconds-long chaos drill can
exercise the full alert path.

Signals read process-wide state lazily (``metrics.get_registry()`` at
call time), so an engine built before ``obs.configure`` still sees the
live registry. A signal returning ``None`` means "no data yet" and
counts as compliant — absence of traffic is not an outage.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Callable, Optional, Sequence

from . import log as obs_log
from . import metrics as obs_metrics
from . import trace as obs_trace

__all__ = ["SLO", "BurnRule", "SLOEngine", "default_slos",
           "histogram_quantile", "gauge_value", "counter_ratio",
           "DRILL_TIME_SCALE"]

#: canonical SRE burn-rate rules: (fast_window_s, slow_window_s, burn)
CANONICAL_RULES = ((300.0, 3600.0, 14.4), (1800.0, 21600.0, 6.0))

#: compression factor mapping the canonical hour-scale windows onto a
#: seconds-scale chaos drill (5m/1h → 1.5s/18s; 30m/6h → 9s/108s)
DRILL_TIME_SCALE = 1.0 / 200.0


# --------------------------------------------------------------------- #
# signal helpers — callables the SLO catalog is built from
# --------------------------------------------------------------------- #
def histogram_quantile(name: str, q: float) -> Callable[[], Optional[float]]:
    """Pooled (all-label) q-quantile of a live histogram, None if empty."""
    def read():
        fam = obs_metrics.get_registry().get(name)
        if fam is None or fam.kind != "histogram":
            return None
        pooled = fam.merged()
        return None if pooled.count == 0 else pooled.quantile(q)
    read.__name__ = f"{name}:p{int(q * 100)}"
    return read


def gauge_value(name: str, **labels) -> Callable[[], Optional[float]]:
    """Current gauge value, None while the gauge has never been set."""
    def read():
        return obs_metrics.get_registry().value(name, **labels)
    read.__name__ = name
    return read


def counter_ratio(numerator: str, denominator: str
                  ) -> Callable[[], Optional[float]]:
    """num/den over all-label sums of two counters; None until den > 0."""
    def total(name):
        fam = obs_metrics.get_registry().get(name)
        if fam is None:
            return 0.0
        return sum(child.value for _, child in fam.children())

    def read():
        den = total(denominator)
        if den <= 0:
            return None
        return total(numerator) / den
    read.__name__ = f"{numerator}/{denominator}"
    return read


# --------------------------------------------------------------------- #
# declarations
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class BurnRule:
    """Fire when burn rate exceeds ``burn`` over BOTH windows."""
    fast_s: float
    slow_s: float
    burn: float

    def scaled(self, time_scale: float) -> "BurnRule":
        return BurnRule(self.fast_s * time_scale,
                        self.slow_s * time_scale, self.burn)


@dataclasses.dataclass(frozen=True)
class SLO:
    """One objective: ``signal() <op> target`` should hold for at least
    ``objective`` of samples."""
    name: str
    signal: Callable[[], Optional[float]]
    target: float
    description: str = ""
    op: str = "<="                      # "<=" or ">="
    objective: float = 0.99
    rules: tuple = CANONICAL_RULES

    def compliant(self, value: Optional[float]) -> bool:
        if value is None:
            return True
        return value <= self.target if self.op == "<=" else \
            value >= self.target


class _SLOState:
    __slots__ = ("samples", "bad_total", "total", "last_value",
                 "active_rules", "alerts")

    def __init__(self, history: int):
        self.samples = deque(maxlen=history)   # (t, bad: 0/1)
        self.bad_total = 0
        self.total = 0
        self.last_value: Optional[float] = None
        self.active_rules: set = set()         # rising-edge dedupe
        self.alerts = 0


# --------------------------------------------------------------------- #
# the engine
# --------------------------------------------------------------------- #
class SLOEngine:
    """Samples a catalog of SLOs against the live telemetry plane.

    ``tick()`` is cheap (a handful of registry reads) and thread-safe;
    call it from a serving loop or a background ticker. Burn-rate alerts
    are emitted once per rising edge as ``obs.log`` events named
    ``slo_burn_alert`` (countable through ``obs_events_total``) plus the
    ``psi_slo_burn_alerts_total{slo,window}`` counter.
    """

    def __init__(self, slos: Sequence[SLO], *,
                 time_scale: float = 1.0,
                 clock: Callable[[], float] = obs_trace.now,
                 history: int = 4096):
        self.slos = list(slos)
        self.clock = clock
        self.time_scale = float(time_scale)
        self._lock = threading.Lock()
        self._state = {s.name: _SLOState(history) for s in self.slos}
        self._rules = {
            s.name: tuple(BurnRule(*r).scaled(self.time_scale)
                          for r in s.rules)
            for s in self.slos}
        self._installed_prev = None

    # -- sampling ------------------------------------------------------- #
    def tick(self, now: Optional[float] = None) -> None:
        t = self.clock() if now is None else float(now)
        for slo in self.slos:
            try:
                value = slo.signal()
            except Exception as exc:   # a broken signal is not an outage
                obs_log.event("slo_signal_error", f"{slo.name}: {exc}",
                              level="error", slo=slo.name)
                continue
            bad = 0 if slo.compliant(value) else 1
            st = self._state[slo.name]
            with self._lock:
                st.samples.append((t, bad))
                st.total += 1
                st.bad_total += bad
                st.last_value = value
                self._evaluate_rules(slo, st, t)
            if bad:
                obs_metrics.counter(
                    "psi_slo_violations_total",
                    "samples out of SLO target", ("slo",)
                ).labels(slo=slo.name).inc()
            if value is not None:
                obs_metrics.gauge(
                    "psi_slo_signal", "last sampled SLO signal value",
                    ("slo",)).labels(slo=slo.name).set(value)
            obs_metrics.gauge(
                "psi_slo_budget_remaining",
                "fraction of the error budget left", ("slo",)
            ).labels(slo=slo.name).set(self._budget_remaining(slo, st))

    def _bad_frac(self, st: _SLOState, t: float, window_s: float):
        n = bad = 0
        for ts, b in reversed(st.samples):
            if t - ts > window_s:
                break
            n += 1
            bad += b
        return None if n == 0 else bad / n

    def _burn(self, slo: SLO, st: _SLOState, t: float, window_s: float):
        frac = self._bad_frac(st, t, window_s)
        if frac is None:
            return None
        budget = max(1.0 - slo.objective, 1e-9)
        return frac / budget

    def _budget_remaining(self, slo: SLO, st: _SLOState) -> float:
        if st.total == 0:
            return 1.0
        budget = max(1.0 - slo.objective, 1e-9)
        spent = (st.bad_total / st.total) / budget
        return max(0.0, 1.0 - spent)

    def _evaluate_rules(self, slo: SLO, st: _SLOState, t: float) -> None:
        for rule in self._rules[slo.name]:
            fast = self._burn(slo, st, t, rule.fast_s)
            slow = self._burn(slo, st, t, rule.slow_s)
            firing = (fast is not None and slow is not None
                      and fast > rule.burn and slow > rule.burn)
            key = (rule.fast_s, rule.slow_s)
            if firing and key not in st.active_rules:
                st.active_rules.add(key)
                st.alerts += 1
                window = f"{rule.fast_s:g}s/{rule.slow_s:g}s"
                obs_log.event(
                    "slo_burn_alert",
                    f"SLO {slo.name}: burn {fast:.1f}x over {window} "
                    f"(threshold {rule.burn:g}x, value {st.last_value})",
                    level="warning", slo=slo.name, window=window,
                    burn_fast=round(fast, 3), burn_slow=round(slow, 3),
                    value=st.last_value)
                obs_metrics.counter(
                    "psi_slo_burn_alerts_total",
                    "multi-window burn-rate alerts fired",
                    ("slo", "window")).labels(
                        slo=slo.name, window=window).inc()
            elif not firing and key in st.active_rules:
                if fast is not None and fast <= rule.burn:
                    st.active_rules.discard(key)   # re-arm after recovery

    # -- reporting ------------------------------------------------------ #
    def report(self) -> dict:
        """Verdict document (also served at ``/slo`` once installed)."""
        out = {"slos": [], "ok": True,
               "alerts_total": 0, "time_scale": self.time_scale}
        with self._lock:
            for slo in self.slos:
                st = self._state[slo.name]
                remaining = self._budget_remaining(slo, st)
                meeting = slo.compliant(st.last_value)
                verdict = dict(
                    name=slo.name, description=slo.description,
                    target=slo.target, op=slo.op,
                    objective=slo.objective,
                    value=st.last_value, meeting_target=meeting,
                    samples=st.total, bad_samples=st.bad_total,
                    budget_remaining=round(remaining, 6),
                    alerts=st.alerts, alert_active=bool(st.active_rules))
                out["slos"].append(verdict)
                out["alerts_total"] += st.alerts
                if not meeting or remaining <= 0.0:
                    out["ok"] = False
        return out

    def summary(self) -> list[str]:
        """Human epilogue lines for ``serve --slo``."""
        rep = self.report()
        lines = []
        for v in rep["slos"]:
            value = ("n/a" if v["value"] is None
                     else f"{v['value']:.4g}")
            state = "OK" if v["meeting_target"] else "VIOLATED"
            if v["alert_active"]:
                state += " (burn alert active)"
            lines.append(
                f"{v['name']}: {value} {v['op']} {v['target']:g} "
                f"[{state}] budget={v['budget_remaining']:.0%} "
                f"alerts={v['alerts']}")
        lines.append(
            f"overall: {'OK' if rep['ok'] else 'OUT OF SLO'} "
            f"({rep['alerts_total']} burn-rate alert(s) fired)")
        return lines

    # -- /slo endpoint wiring ------------------------------------------- #
    def install(self) -> None:
        """Publish this engine's verdicts at the HTTP ``/slo`` endpoint."""
        self._installed_prev = obs_metrics.set_slo_provider(self.report)

    def uninstall(self) -> None:
        obs_metrics.set_slo_provider(self._installed_prev)
        self._installed_prev = None


# --------------------------------------------------------------------- #
# the default catalog
# --------------------------------------------------------------------- #
def default_slos(*, query_p99_s: float = 0.05,
                 staleness_s: float = 30.0,
                 error_bound: float = 1e-5,
                 degraded_ratio: float = 0.05) -> list[SLO]:
    """The four serving objectives the paper's trade-offs map onto:
    latency (as fast as PageRank), freshness (streaming watermark lag),
    certified error (Eq. 19 bound), and answer quality (degraded ratio).
    """
    return [
        SLO("query_p99_latency",
            histogram_quantile("psi_query_seconds", 0.99),
            query_p99_s,
            description="p99 of every ranked read (scores/top_k/rank_of)"),
        SLO("freshness_staleness",
            gauge_value("psi_stream_watermark_lag_seconds"),
            staleness_s,
            description="event-time lag: newest ingested event vs "
                        "last resolve"),
        SLO("certified_psi_error",
            gauge_value("psi_certified_error_bound"),
            error_bound,
            description="Eq. 19 certified sup-norm error bound of the "
                        "last served answer"),
        SLO("degraded_answer_ratio",
            counter_ratio("psi_resilience_degraded_served_total",
                          "psi_resilience_resolves_total"),
            degraded_ratio,
            description="last-known-good answers / supervised resolves"),
    ]
