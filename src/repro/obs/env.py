"""Environment fingerprint: make every recorded number interpretable.

A benchmark entry or metrics dump without the jax version, device kind,
x64 flag, and git SHA that produced it is noise across machines. Every
field is best-effort (``None`` on failure) so the fingerprint never
breaks a run.
"""
from __future__ import annotations

import datetime
import os
import platform
import subprocess

__all__ = ["environment_fingerprint"]


def _git_sha() -> str | None:
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    for cwd in (root, os.getcwd()):
        try:
            out = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=cwd, capture_output=True, text=True, timeout=5)
            if out.returncode == 0:
                return out.stdout.strip()
        except (OSError, subprocess.SubprocessError):
            pass
    return None


def environment_fingerprint() -> dict:
    """Everything needed to compare two runs: versions, device, flags,
    code revision, and a UTC timestamp."""
    fp: dict = {
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "git_sha": _git_sha(),
    }
    try:
        import jax
        fp["jax"] = jax.__version__
        fp["x64"] = bool(jax.config.read("jax_enable_x64"))
        devs = jax.devices()
        fp["device_platform"] = devs[0].platform if devs else None
        fp["device_kind"] = devs[0].device_kind if devs else None
        fp["device_count"] = len(devs)
    except Exception as e:                         # pragma: no cover
        fp["jax_error"] = f"{type(e).__name__}: {e}"
    return fp
