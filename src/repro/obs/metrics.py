"""Process-wide metrics registry: counters, gauges, histograms.

Zero-dependency (stdlib only) so every layer of the stack can import it —
including :mod:`repro.ckpt`, which must not drag jax into its error paths.
One :class:`MetricsRegistry` holds named metric *families*; a family plus a
label set yields a *child* carrying the actual value. All mutation goes
through one registry lock, so the async scheduler's worker threads and the
main serving thread can increment concurrently (the lock is held for a few
instructions per op; see tests/test_obs.py's thread-safety case).

Exposition:

* :meth:`MetricsRegistry.to_prometheus` — the Prometheus text format
  (``# HELP`` / ``# TYPE`` + one sample line per child; histograms expand
  to cumulative ``_bucket{le=...}`` series plus ``_sum`` / ``_count``).
* :meth:`MetricsRegistry.to_json` — a structured dump including histogram
  quantile estimates (p50/p90/p99 by linear interpolation inside the
  bucket the quantile falls in).
* :func:`start_http_server` — a stdlib ``http.server`` thread exposing
  ``/metrics`` (text) and ``/metrics.json`` for ``serve --metrics-port``.

Disabling: :func:`set_registry(NULL_REGISTRY)` swaps in a
:class:`NullRegistry` whose families and children are shared no-op
singletons — an instrumented hot path then costs one attribute access and
one no-op call per sample, and (critically) touches no locks and allocates
nothing, which is what the bitwise-parity contract of the observability
plane rests on (instrumentation only ever *reads* host-side values).
"""
from __future__ import annotations

import bisect
import json
import math
import threading
import time

__all__ = ["MetricsRegistry", "NullRegistry", "NULL_REGISTRY",
           "get_registry", "set_registry", "counter", "gauge", "histogram",
           "enabled", "start_http_server", "set_slo_provider",
           "DEFAULT_BUCKETS"]

#: log-spaced seconds buckets: 10 µs → 60 s (query latencies through
#: full chaos-drill resolves land inside the measurable range)
DEFAULT_BUCKETS = (1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
                   1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


# --------------------------------------------------------------------- #
# The disabled path: shared no-op singletons
# --------------------------------------------------------------------- #
class _Null:
    """Both the no-op family and the no-op child (labels() returns self)."""

    __slots__ = ()

    def labels(self, **kw):
        return self

    def inc(self, amount=1.0):
        pass

    def dec(self, amount=1.0):
        pass

    def set(self, value):
        pass

    def observe(self, value):
        pass

    @property
    def value(self):
        return 0.0

    def quantile(self, q):
        return 0.0


_NULL = _Null()


class NullRegistry:
    """API-compatible no-op registry (see module docstring)."""

    null = True

    def counter(self, name, help="", labelnames=()):
        return _NULL

    def gauge(self, name, help="", labelnames=()):
        return _NULL

    def histogram(self, name, help="", labelnames=(),
                  buckets=DEFAULT_BUCKETS):
        return _NULL

    def get(self, name):
        return None

    def value(self, name, **labels):
        return None

    def to_prometheus(self) -> str:
        return ""

    def to_json(self) -> dict:
        return {}

    def reset(self) -> None:
        pass


NULL_REGISTRY = NullRegistry()


# --------------------------------------------------------------------- #
# Live children
# --------------------------------------------------------------------- #
class _CounterChild:
    __slots__ = ("_lock", "_value")

    def __init__(self, lock):
        self._lock = lock
        self._value = 0.0

    def inc(self, amount=1.0):
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0; got {amount}")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value


class _GaugeChild:
    __slots__ = ("_lock", "_value")

    def __init__(self, lock):
        self._lock = lock
        self._value = 0.0

    def set(self, value):
        with self._lock:
            self._value = float(value)

    def inc(self, amount=1.0):
        with self._lock:
            self._value += amount

    def dec(self, amount=1.0):
        with self._lock:
            self._value -= amount

    @property
    def value(self) -> float:
        return self._value


class _HistogramChild:
    __slots__ = ("_lock", "bounds", "counts", "sum", "count", "_min", "_max")

    def __init__(self, lock, bounds):
        self._lock = lock
        self.bounds = bounds                       # sorted finite uppers
        self.counts = [0] * (len(bounds) + 1)      # + the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, value):
        v = float(value)
        i = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self.counts[i] += 1
            self.sum += v
            self.count += 1
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def value(self) -> float:
        """Histograms expose their observation count as the scalar value."""
        return float(self.count)

    def quantile(self, q: float) -> float:
        """Estimated q-quantile: linear interpolation inside the bucket the
        quantile falls in (exact min/max tighten the edge buckets)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1]; got {q}")
        with self._lock:
            total = self.count
            if total == 0:
                return 0.0
            target = q * total
            cum = 0
            for i, c in enumerate(self.counts):
                cum += c
                if cum >= target and c:
                    hi = (self._max if i == len(self.bounds)
                          else min(self.bounds[i], self._max))
                    lo = (self._min if i == 0
                          else max(self.bounds[i - 1], self._min))
                    frac = (target - (cum - c)) / c
                    return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            return self._max


# --------------------------------------------------------------------- #
# Families
# --------------------------------------------------------------------- #
class _Family:
    kind = "untyped"

    def __init__(self, name, help, labelnames, lock):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._children: dict[tuple, object] = {}

    def _make_child(self):                         # pragma: no cover
        raise NotImplementedError

    def labels(self, **labels):
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}; "
                f"got {tuple(sorted(labels))}")
        key = tuple(str(labels[n]) for n in self.labelnames)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._make_child())
        return child

    # label-less families act as their own single child
    def inc(self, amount=1.0):
        self.labels().inc(amount)

    def dec(self, amount=1.0):
        self.labels().dec(amount)

    def set(self, value):
        self.labels().set(value)

    def observe(self, value):
        self.labels().observe(value)

    def quantile(self, q):
        return self.labels().quantile(q)

    @property
    def value(self):
        return self.labels().value

    def children(self) -> list[tuple[tuple, object]]:
        with self._lock:
            return sorted(self._children.items())


class _CounterFamily(_Family):
    kind = "counter"

    def _make_child(self):
        return _CounterChild(self._lock)


class _GaugeFamily(_Family):
    kind = "gauge"

    def _make_child(self):
        return _GaugeChild(self._lock)


class _HistogramFamily(_Family):
    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames, lock)
        self.buckets = tuple(sorted(float(b) for b in buckets))

    def _make_child(self):
        return _HistogramChild(self._lock, self.buckets)

    def merged(self) -> _HistogramChild:
        """One child pooling every label combination — for summary readouts
        (e.g. query p99 across all ops). Children share bucket bounds, so
        pooling is exact at bucket resolution."""
        pooled = _HistogramChild(self._lock, self.buckets)
        with self._lock:
            for ch in self._children.values():
                pooled.counts = [a + b for a, b
                                 in zip(pooled.counts, ch.counts)]
                pooled.sum += ch.sum
                pooled.count += ch.count
                pooled._min = min(pooled._min, ch._min)
                pooled._max = max(pooled._max, ch._max)
        return pooled


# --------------------------------------------------------------------- #
# The registry
# --------------------------------------------------------------------- #
def _fmt(v: float) -> str:
    """Prometheus sample formatting: integral values render without a
    decimal point, everything else via repr (shortest round-trip)."""
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _label_str(labelnames, key, extra=()) -> str:
    pairs = [f'{n}="{_escape(v)}"' for n, v in zip(labelnames, key)]
    pairs += [f'{n}="{_escape(v)}"' for n, v in extra]
    return "{" + ",".join(pairs) + "}" if pairs else ""


def _escape(v) -> str:
    return (str(v).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


class MetricsRegistry:
    """One process-wide namespace of metric families (see module docstring).

    Families are created on first use and idempotent thereafter:
    re-declaring a name with the same kind + labelnames returns the
    existing family; a conflicting re-declaration raises.
    """

    null = False

    def __init__(self):
        self._lock = threading.RLock()
        self._families: dict[str, _Family] = {}

    def _family(self, cls, name, help, labelnames, **kw):
        fam = self._families.get(name)
        if fam is None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = cls(name, help, labelnames, self._lock, **kw)
                    self._families[name] = fam
        if not isinstance(fam, cls) or fam.labelnames != tuple(labelnames):
            raise ValueError(
                f"metric {name!r} already registered as {fam.kind} with "
                f"labels {fam.labelnames}; cannot re-register as "
                f"{cls.kind} with labels {tuple(labelnames)}")
        return fam

    def counter(self, name, help="", labelnames=()):
        return self._family(_CounterFamily, name, help, labelnames)

    def gauge(self, name, help="", labelnames=()):
        return self._family(_GaugeFamily, name, help, labelnames)

    def histogram(self, name, help="", labelnames=(),
                  buckets=DEFAULT_BUCKETS):
        return self._family(_HistogramFamily, name, help, labelnames,
                            buckets=buckets)

    def get(self, name):
        return self._families.get(name)

    def value(self, name, **labels):
        """Scalar read for tests / self-checks; None when absent."""
        fam = self._families.get(name)
        if fam is None:
            return None
        try:
            return fam.labels(**labels).value
        except (ValueError, KeyError):
            return None

    def reset(self) -> None:
        with self._lock:
            self._families.clear()

    # -- exposition ----------------------------------------------------- #
    def to_prometheus(self) -> str:
        lines = []
        with self._lock:
            families = sorted(self._families.items())
        for name, fam in families:
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key, child in fam.children():
                if fam.kind == "histogram":
                    cum = 0
                    for b, c in zip(fam.buckets, child.counts):
                        cum += c
                        lab = _label_str(fam.labelnames, key,
                                         extra=[("le", _fmt(b))])
                        lines.append(f"{name}_bucket{lab} {cum}")
                    cum += child.counts[-1]
                    lab = _label_str(fam.labelnames, key,
                                     extra=[("le", "+Inf")])
                    lines.append(f"{name}_bucket{lab} {cum}")
                    lab = _label_str(fam.labelnames, key)
                    lines.append(f"{name}_sum{lab} {_fmt(child.sum)}")
                    lines.append(f"{name}_count{lab} {child.count}")
                else:
                    lab = _label_str(fam.labelnames, key)
                    lines.append(f"{name}{lab} {_fmt(child.value)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> dict:
        out = {}
        with self._lock:
            families = sorted(self._families.items())
        for name, fam in families:
            series = []
            for key, child in fam.children():
                labels = dict(zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    series.append(dict(
                        labels=labels, count=child.count, sum=child.sum,
                        min=(None if child.count == 0 else child._min),
                        max=(None if child.count == 0 else child._max),
                        p50=child.quantile(0.50), p90=child.quantile(0.90),
                        p99=child.quantile(0.99),
                        buckets={_fmt(b): c for b, c in
                                 zip(fam.buckets, child.counts)},
                        overflow=child.counts[-1]))
                else:
                    series.append(dict(labels=labels, value=child.value))
            out[name] = dict(kind=fam.kind, help=fam.help, series=series)
        return out


# --------------------------------------------------------------------- #
# Process default + module-level convenience (the instrumentation API)
# --------------------------------------------------------------------- #
_REGISTRY = MetricsRegistry()


def get_registry():
    return _REGISTRY


def set_registry(registry):
    """Swap the process default (e.g. for NULL_REGISTRY); returns the
    previous one so callers can restore it."""
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, registry
    return prev


def enabled() -> bool:
    return not getattr(_REGISTRY, "null", False)


def counter(name, help="", labelnames=()):
    return _REGISTRY.counter(name, help, labelnames)


def gauge(name, help="", labelnames=()):
    return _REGISTRY.gauge(name, help, labelnames)


def histogram(name, help="", labelnames=(), buckets=DEFAULT_BUCKETS):
    return _REGISTRY.histogram(name, help, labelnames, buckets=buckets)


# --------------------------------------------------------------------- #
# /metrics over HTTP (serve --metrics-port)
# --------------------------------------------------------------------- #
_START_TIME = time.time()
_SLO_PROVIDER = None


def set_slo_provider(fn):
    """Install the callable the HTTP ``/slo`` endpoint serves (an
    ``SLOEngine.report``); None uninstalls. Returns the previous one."""
    global _SLO_PROVIDER
    prev, _SLO_PROVIDER = _SLO_PROVIDER, fn
    return prev


def start_http_server(port: int, registry=None, host: str = "127.0.0.1"):
    """Expose ``/metrics`` (Prometheus text), ``/metrics.json``,
    ``/healthz`` (cheap liveness for fleet probes) and ``/slo`` (verdicts
    of the installed :class:`repro.obs.slo.SLOEngine`) on a daemon
    thread; returns the server (``.shutdown()`` to stop; pass port 0 for
    an ephemeral port, read back via ``server.server_address``)."""
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    reg = registry

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            r = reg if reg is not None else get_registry()
            if self.path.rstrip("/") in ("", "/metrics"):
                body = r.to_prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path == "/metrics.json":
                body = json.dumps(r.to_json(), indent=1).encode()
                ctype = "application/json"
            elif self.path.rstrip("/") == "/healthz":
                body = json.dumps(dict(
                    status="ok",
                    uptime_s=round(time.time() - _START_TIME, 3),
                    metrics_enabled=not getattr(r, "null", False),
                    slo_installed=_SLO_PROVIDER is not None)).encode()
                ctype = "application/json"
            elif self.path.rstrip("/") == "/slo":
                if _SLO_PROVIDER is None:
                    body = json.dumps(
                        dict(error="no SLO engine installed")).encode()
                    self.send_response(404)
                    self.send_header("Content-Type", "application/json")
                    self.send_header("Content-Length", str(len(body)))
                    self.end_headers()
                    self.wfile.write(body)
                    return
                body = json.dumps(_SLO_PROVIDER(), indent=1).encode()
                ctype = "application/json"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):                 # quiet
            pass

    server = ThreadingHTTPServer((host, int(port)), _Handler)
    t = threading.Thread(target=server.serve_forever,
                         name="obs-metrics-http", daemon=True)
    t.start()
    return server
