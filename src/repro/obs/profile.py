"""Span-stream profiler: folded stacks, cost attribution, critical path.

Consumes the span records a :class:`repro.obs.trace.Tracer` retains (or
wrote to JSONL) and turns the raw stream into three judgements:

* **Folded stacks** (:meth:`Profile.folded`) — classic flamegraph input:
  ``root;child;leaf  self_time`` lines, where self time is a span's wall
  minus its direct children's wall (clipped at zero; children running on
  other threads — the async workers — attribute to their own roots).
* **Cost attribution** (:meth:`Profile.hotspots`,
  :meth:`Profile.attribution`) — per-frame totals split into self wall,
  dispatch (host) vs sync (device wait) where the span recorded a
  :meth:`Span.sync`, and per-backend/per-regime rollups keyed on the
  discriminating span attr (``engine.run{backend}``, ``fleet.solve
  {spec,regime}``, ``query{op}``).
* **Critical path** (:meth:`Profile.critical_path`) — for the async
  chunk pipeline: walk back from the last-finishing ``async.step``
  through its latest-finishing predecessor (the step it plausibly waited
  on) and report which chunk chain bounds wall-clock, so a low
  ``overlap_efficiency`` names the culprit instead of just scoring it.

Everything is stdlib-only and runs offline: records in, dicts out.
"""
from __future__ import annotations

import dataclasses
import json
from collections import defaultdict
from typing import Iterable, Optional

__all__ = ["Profile", "CriticalPath"]

#: attrs that discriminate otherwise-identical frames, in priority order
_FRAME_ATTRS = ("backend", "spec", "op", "chunk")


def _frame(rec: dict) -> str:
    """Display name for one span: ``name`` plus its discriminating attr."""
    attrs = rec.get("attrs") or {}
    for key in _FRAME_ATTRS:
        if key in attrs:
            return f"{rec['name']}[{key}={attrs[key]}]"
    return rec["name"]


@dataclasses.dataclass
class CriticalPath:
    """The chain of ``async.step`` spans bounding wall-clock."""
    steps: list              # span records, execution order
    length_s: float          # sum of step walls along the path
    wall_s: float            # first-start → last-end over ALL steps
    chunk_share: dict        # chunk id -> seconds of path time

    @property
    def coverage(self) -> float:
        """path length / wall — 1.0 means zero overlap hid the path."""
        return self.length_s / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def bounding_chunk(self) -> Optional[int]:
        """The chunk contributing the most critical-path seconds."""
        if not self.chunk_share:
            return None
        return max(self.chunk_share, key=self.chunk_share.get)

    def describe(self) -> str:
        if not self.steps:
            return "critical path: no async.step spans recorded"
        share = ", ".join(
            f"chunk {k}: {v:.4f}s ({v / self.length_s:.0%})"
            for k, v in sorted(self.chunk_share.items(),
                               key=lambda kv: -kv[1]))
        return (f"critical path: {len(self.steps)} steps, "
                f"{self.length_s:.4f}s of {self.wall_s:.4f}s wall "
                f"({self.coverage:.0%}); bounds: {share}")


class Profile:
    """Aggregated view over a finished span stream."""

    def __init__(self, records: Iterable[dict]):
        self.records = [r for r in records
                        if "dur" in r and "ts" in r]
        self._by_id = {r["id"]: r for r in self.records if "id" in r}
        # direct-children wall per parent id, same-thread only (cross-
        # thread "children" run concurrently and own their time)
        child_wall: dict = defaultdict(float)
        for r in self.records:
            p = r.get("parent")
            if p is not None and p in self._by_id \
                    and self._by_id[p].get("thread") == r.get("thread"):
                child_wall[p] += r["dur"]
        self._self_s = {
            r["id"]: max(0.0, r["dur"] - child_wall.get(r["id"], 0.0))
            for r in self.records if "id" in r}

    # -- constructors --------------------------------------------------- #
    @classmethod
    def from_tracer(cls, tracer) -> "Profile":
        return cls(list(getattr(tracer, "spans", ())))

    @classmethod
    def from_jsonl(cls, path: str) -> "Profile":
        with open(path) as f:
            return cls(json.loads(ln) for ln in f if ln.strip())

    # -- folded stacks --------------------------------------------------- #
    def _stack_of(self, rec: dict) -> str:
        frames = [_frame(rec)]
        seen = {rec.get("id")}
        p = rec.get("parent")
        while p is not None and p in self._by_id and p not in seen:
            seen.add(p)
            parent = self._by_id[p]
            frames.append(_frame(parent))
            p = parent.get("parent")
        return ";".join(reversed(frames))

    def folded(self) -> dict:
        """``stack -> self seconds`` over every span (flamegraph input)."""
        out: dict = defaultdict(float)
        for r in self.records:
            if "id" not in r:
                continue
            out[self._stack_of(r)] += self._self_s[r["id"]]
        return dict(out)

    def write_folded(self, path: str) -> str:
        """Write ``stack  microseconds`` lines (flamegraph.pl format)."""
        with open(path, "w") as f:
            for stack, secs in sorted(self.folded().items(),
                                      key=lambda kv: -kv[1]):
                f.write(f"{stack} {max(1, round(secs * 1e6))}\n")
        return path

    # -- hotspots / attribution ------------------------------------------ #
    def hotspots(self, n: int = 10) -> list[dict]:
        """Top-``n`` frames by self time, with the dispatch/sync split."""
        agg: dict = {}
        for r in self.records:
            key = _frame(r)
            a = agg.setdefault(key, dict(
                frame=key, count=0, total_s=0.0, self_s=0.0,
                dispatch_s=0.0, sync_s=0.0))
            a["count"] += 1
            a["total_s"] += r["dur"]
            a["self_s"] += self._self_s.get(r.get("id"), r["dur"])
            if "dispatch_s" in r:
                a["dispatch_s"] += r["dispatch_s"]
                a["sync_s"] += r["sync_s"]
        ranked = sorted(agg.values(), key=lambda a: -a["self_s"])
        for a in ranked:
            for k in ("total_s", "self_s", "dispatch_s", "sync_s"):
                a[k] = round(a[k], 6)
        return ranked[:n]

    def attribution(self) -> dict:
        """Wall per backend/spec/op attr value — where the seconds go
        across engines, fleet buckets, and query ops."""
        out: dict = {}
        for r in self.records:
            attrs = r.get("attrs") or {}
            for key in ("backend", "spec", "op"):
                if key in attrs:
                    bucket = out.setdefault(key, defaultdict(float))
                    bucket[str(attrs[key])] += r["dur"]
                    break
        return {k: dict(sorted(v.items(), key=lambda kv: -kv[1]))
                for k, v in out.items()}

    # -- critical path ---------------------------------------------------#
    def critical_path(self, name: str = "async.step") -> CriticalPath:
        """Walk the async chunk pipeline back from the last-finishing
        step through latest-finishing predecessors."""
        steps = [r for r in self.records if r["name"] == name]
        if not steps:
            return CriticalPath([], 0.0, 0.0, {})
        end = lambda r: r["ts"] + r["dur"]                     # noqa: E731
        wall = max(end(r) for r in steps) - min(r["ts"] for r in steps)
        by_end = sorted(steps, key=end)
        path = [by_end[-1]]
        eps = 1e-9
        while True:
            cur = path[-1]
            pred = None
            for r in reversed(by_end):       # latest end first
                if r is cur:
                    continue
                if end(r) <= cur["ts"] + eps:
                    pred = r
                    break
            if pred is None:
                break
            path.append(pred)
        path.reverse()
        share: dict = defaultdict(float)
        for r in path:
            chunk = (r.get("attrs") or {}).get("chunk", -1)
            share[chunk] += r["dur"]
        return CriticalPath(path, sum(r["dur"] for r in path),
                            wall, dict(share))

    # -- one-call export -------------------------------------------------#
    def to_json(self, top: int = 10) -> dict:
        cp = self.critical_path()
        return dict(
            spans=len(self.records),
            hotspots=self.hotspots(top),
            attribution=self.attribution(),
            critical_path=None if not cp.steps else dict(
                steps=len(cp.steps), length_s=round(cp.length_s, 6),
                wall_s=round(cp.wall_s, 6),
                coverage=round(cp.coverage, 4),
                bounding_chunk=cp.bounding_chunk,
                chunk_share={str(k): round(v, 6)
                             for k, v in cp.chunk_share.items()}))
