"""Self-calibrating cost model: measured correction factors per regime.

The PR 2 planner ranks kernel regimes by modeled HBM bytes
(``RegimePlan.est_bytes``); its constants are TPU-HBM oriented and the
benchmark trajectory proves they mis-rank on other platforms (edge-tile
1.1s vs reference 0.13s on clustered; BSR a pathological 34s on
hyper-sparse).  This module closes the loop: every measured step timing —
microbench candidates, the auto engine's per-step wall time — is recorded
as a ``measured_us / est_bytes`` ratio ("µs per modeled byte") keyed by
``(environment, regime)``.  The per-regime **median** ratio is a
correction factor: ``est_bytes × factor(regime)`` is a calibrated µs
estimate whose *relative* ordering reflects this machine rather than the
model's constants; the **MAD** around it is the confidence band.  A
factor only participates in planning once it has ``min_samples``
observations, and regimes without a confident factor inherit the median
of the confident ones — so partial calibration can never flip a ranking
it has no evidence about.

The store is deliberately independent of :func:`repro.obs.disable`: it is
a *planner input* (control plane), not telemetry, so arming or disarming
observability can never change which plan is chosen — the bitwise-ψ
parity contract of docs/OBSERVABILITY.md survives calibration.

Persistence lives alongside the benchmark trajectory:
:meth:`CalibrationStore.save` / :meth:`CalibrationStore.load` read and
write ``CALIB_power_psi.json`` (same directory convention as
``BENCH_power_psi.json``), keyed by a reduced environment fingerprint so
a store learned on CPU never corrects a TPU plan.
"""
from __future__ import annotations

import json
import statistics
import threading
from collections import deque

__all__ = ["CalibrationStore", "DEFAULT_PATH", "env_key", "get_store",
           "set_store"]

DEFAULT_PATH = "CALIB_power_psi.json"

# Median drift (relative) that republishes a factor and bumps the store
# generation — the plan cache keys on the generation, so only *material*
# recalibrations invalidate memoized plans, not every single sample.
_REPUBLISH_REL = 0.10


def env_key(fingerprint: dict | None = None) -> str:
    """Reduced environment key: platform / device kind / x64 flag.

    Follows the :mod:`repro.obs.regress` matching convention — correction
    factors are per-machine-class facts, so the volatile fingerprint
    fields (timestamp, git sha) stay out of the key.
    """
    if fingerprint is None:
        from .env import environment_fingerprint
        fingerprint = environment_fingerprint()
    return "|".join(str(fingerprint.get(k, "?")) for k in
                    ("device_platform", "device_kind", "x64"))


class CalibrationStore:
    """Per-(environment, regime) µs-per-modeled-byte correction factors."""

    def __init__(self, *, keep: int = 64, min_samples: int = 2,
                 env: str | None = None):
        self._lock = threading.Lock()
        self._samples: dict[tuple[str, str], deque] = {}
        self._published: dict[tuple[str, str], float] = {}
        self.keep = int(keep)
        self.min_samples = int(min_samples)
        self.generation = 0
        self._env = env          # lazy: resolving it imports jax

    @property
    def env(self) -> str:
        if self._env is None:
            self._env = env_key()
        return self._env

    # -- feeding ------------------------------------------------------- #
    def observe(self, regime: str, est_bytes: float, measured_us: float,
                *, env: str | None = None,
                source: str = "run") -> float | None:
        """Record one (modeled bytes, measured µs) pair; returns the ratio.

        Samples with a non-positive model estimate or measurement carry no
        information and are dropped.
        """
        est_bytes = float(est_bytes)
        measured_us = float(measured_us)
        if est_bytes <= 0.0 or measured_us <= 0.0:
            return None
        ratio = measured_us / est_bytes
        key = (env or self.env, str(regime))
        with self._lock:
            ring = self._samples.get(key)
            if ring is None:
                ring = self._samples[key] = deque(maxlen=self.keep)
            ring.append(ratio)
            if len(ring) >= self.min_samples:
                med = statistics.median(ring)
                old = self._published.get(key)
                if old is None or abs(med / old - 1.0) > _REPUBLISH_REL:
                    self._published[key] = med
                    self.generation += 1
        return ratio

    # -- querying ------------------------------------------------------ #
    def factor(self, regime: str, *, env: str | None = None) -> dict | None:
        """``{"median", "mad", "count"}`` for one regime, or ``None``
        until ``min_samples`` observations exist."""
        key = (env or self.env, str(regime))
        with self._lock:
            ring = self._samples.get(key)
            if ring is None or len(ring) < self.min_samples:
                return None
            xs = list(ring)
        med = statistics.median(xs)
        mad = statistics.median(abs(x - med) for x in xs)
        return {"median": med, "mad": mad, "count": len(xs)}

    def factors(self, *, env: str | None = None) -> dict[str, dict]:
        """Every confident regime factor for one environment."""
        env = env or self.env
        with self._lock:
            regimes = sorted({r for (e, r) in self._samples if e == env})
        out = {}
        for regime in regimes:
            f = self.factor(regime, env=env)
            if f is not None:
                out[regime] = f
        return out

    def multipliers(self, regimes, *, env: str | None = None) -> dict:
        """Cost multipliers for a candidate-regime set.

        Empty when no regime is confident (plain ``est_bytes`` ranking).
        Otherwise every requested regime gets its own median factor if
        confident, else the median of the confident factors — a uniform
        default that cannot flip rankings between uncalibrated regimes.
        """
        known = self.factors(env=env)
        if not known:
            return {}
        default = statistics.median(f["median"] for f in known.values())
        return {r: known[r]["median"] if r in known else default
                for r in regimes}

    def corrected_us(self, regime: str, est_bytes: float,
                     *, env: str | None = None) -> float | None:
        """Calibrated µs estimate for one plan, or ``None`` if unknown."""
        f = self.factor(regime, env=env)
        return None if f is None else float(est_bytes) * f["median"]

    # -- persistence --------------------------------------------------- #
    def to_json(self) -> dict:
        with self._lock:
            keys = sorted(self._samples)
            samples = {k: list(self._samples[k]) for k in keys}
        entries = []
        for (env, regime) in keys:
            xs = samples[(env, regime)]
            med = statistics.median(xs)
            entries.append({
                "env": env, "regime": regime, "samples": xs,
                "median": med,
                "mad": statistics.median(abs(x - med) for x in xs),
                "count": len(xs),
            })
        return {"version": 1, "keep": self.keep,
                "min_samples": self.min_samples, "entries": entries}

    def save(self, path: str = DEFAULT_PATH) -> dict:
        snap = self.to_json()
        with open(path, "w") as f:
            json.dump(snap, f, indent=1)
        return snap

    def load(self, path: str = DEFAULT_PATH) -> int:
        """Merge persisted samples into this store; returns entries read.

        Missing files are not an error — a fresh machine simply starts
        uncalibrated.
        """
        try:
            with open(path) as f:
                snap = json.load(f)
        except FileNotFoundError:
            return 0
        n = 0
        for e in snap.get("entries", ()):
            key = (str(e["env"]), str(e["regime"]))
            with self._lock:
                ring = self._samples.get(key)
                if ring is None:
                    ring = self._samples[key] = deque(maxlen=self.keep)
                for x in e.get("samples", ()):
                    ring.append(float(x))
                if len(ring) >= self.min_samples:
                    self._published[key] = statistics.median(ring)
                self.generation += 1
            n += 1
        return n

    def clear(self) -> None:
        with self._lock:
            self._samples.clear()
            self._published.clear()
            self.generation += 1

    def __len__(self) -> int:
        with self._lock:
            return sum(len(r) for r in self._samples.values())


_STORE = CalibrationStore()


def get_store() -> CalibrationStore:
    return _STORE


def set_store(store: CalibrationStore) -> CalibrationStore:
    """Install the process store; returns the previous one."""
    global _STORE
    prev, _STORE = _STORE, store
    return prev
