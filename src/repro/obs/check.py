"""``python -m repro.obs.check`` — end-to-end self-test of the telemetry
plane, runnable anywhere the repo imports (CI runs it as a smoke step and
uploads the artifacts it writes).

What it exercises, against a real streamed ψ resolve (powerlaw graph →
poisson event log → online rate estimation → PsiService queries):

1. **accounting** — every ingested event is counted exactly once
   (``psi_stream_events_total`` == len(log)), at least one resolve ran,
   and the resolve/convergence records agree with the metrics registry.
2. **latency plumbing** — the query histogram is populated and internally
   consistent (p50 ≤ p99 ≤ max).
3. **tracing** — the JSONL trace parses line by line, contains nested
   ``engine.run`` spans, and exports a loadable Chrome trace_event file.
4. **exposition** — the Prometheus text renders with HELP/TYPE headers
   and histogram bucket monotonicity; the JSON dump round-trips.
5. **analysis layer** — an :class:`~repro.obs.slo.SLOEngine` ticking over
   the live registry produces a sane report (and a forced violation
   counts), the span-stream profiler folds the recorded trace into
   stacks with positive self time, and the HTTP endpoints
   (``/healthz``, ``/slo``) answer on an ephemeral port.
6. **decision observability** — :func:`repro.kernels.autotune.plan_regime`
   records a full :class:`~repro.obs.explain.DecisionRecord` (candidate
   table, ``BSR_MIN_OCCUPANCY`` prunes, ``PLAN_CACHE`` hit/miss), the
   plan-cache counters land in the registry, and
   ``PsiService.explain()`` renders the EXPLAIN-ANALYZE tree.
7. **calibration loop** — the acceptance drill: skewed cost-model
   constants (injected via ``slot_bytes``) make the uncalibrated planner
   mis-rank; a microbench pass feeds the
   :class:`~repro.obs.calibrate.CalibrationStore`; the calibrated
   planner then recovers the measured winner, the ``model_misranked``
   event fires, and ``psi_plan_misprediction_ratio`` is published.
8. **parity** — the same workload re-run under ``obs.disable()`` (with
   the decision log nulled and the populated calibration store still
   armed — calibration is planner input, not telemetry) produces a
   bitwise-identical ψ vector, and a third run with the FULL analysis
   layer armed (convergence watch attached, SLO engine ticking, profiler
   consuming the tracer) is bitwise-identical too: analysis only reads.

Exit status is non-zero on the first failed check. Artifacts land in
``--out-dir``: ``metrics.prom``, ``metrics.json`` (the full obs dump),
``trace.jsonl``, ``trace.chrome.json``, ``profile.folded``,
``explain.txt`` (the rendered decision trail), ``calibration.json``
(the per-regime correction factors).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from .. import obs


def _build_and_stream(events: int, seed: int = 7):
    """One small streamed resolve; returns (service, ingestor, log)."""
    import jax.numpy as jnp

    from ..core import Activity, PsiService, RATE_FLOOR, heterogeneous
    from ..graphs import powerlaw_configuration
    from ..stream import FreshnessPolicy, StreamIngestor, poisson_stream

    n, m = 600, 3_600
    g = powerlaw_configuration(n, m, seed=seed)
    truth = heterogeneous(n, seed=seed + 1)
    horizon = events / float(truth.total.sum())
    log = poisson_stream(truth, horizon, seed=seed + 2, graph=g)
    cold = Activity(np.full(n, RATE_FLOOR), np.full(n, RATE_FLOOR))
    svc = PsiService(g, cold, tol=1e-8, backend="reference",
                     dtype=jnp.float64)
    ing = StreamIngestor(svc, half_life=horizon / 2, topk=3,
                         policy=FreshnessPolicy(coalesce=16,
                                                resolve_every=250))
    ing.ingest(log)
    rng = np.random.default_rng(0)
    for _ in range(8):
        users = rng.integers(0, n, 4)
        svc.scores_batch(users)
        svc.rank_of(users)
        svc.top_k(3)
    return svc, ing, log


def run_check(out_dir: str, *, events: int = 1_200) -> list[str]:
    """Run every check; returns the list of failure strings (empty = ok)."""
    os.makedirs(out_dir, exist_ok=True)
    failures: list[str] = []

    def check(cond: bool, msg: str) -> None:
        tag = "ok " if cond else "FAIL"
        print(f"[obs.check] {tag} {msg}")
        if not cond:
            failures.append(msg)

    trace_path = os.path.join(out_dir, "trace.jsonl")
    if os.path.exists(trace_path):
        os.remove(trace_path)
    prev = obs.configure(registry=obs.MetricsRegistry(),
                         tracer=obs.Tracer(trace_path),
                         tracker=obs.ConvergenceTracker())
    try:
        svc, ing, log = _build_and_stream(events)
        psi_live = np.array(svc.scores(), copy=True)

        reg = obs.metrics.get_registry()
        # 1. accounting
        ev_fam = reg.get("psi_stream_events_total")
        counted = (sum(ch.value for _, ch in ev_fam.children())
                   if ev_fam else 0)
        check(counted == len(log),
              f"event accounting: counted {int(counted)} == {len(log)}")
        resolves = reg.value("psi_stream_resolves_total") or 0
        check(resolves >= 1, f"resolves ran: {int(resolves)} >= 1")
        n_resolves = sum(len(obs.convergence.get_tracker().series(t))
                         for t in obs.convergence.get_tracker().tenants())
        check(n_resolves >= 1,
              f"convergence records: {n_resolves} resolve(s) recorded")
        rec_total = reg.get("psi_resolves_total")
        rec_count = (sum(ch.value for _, ch in rec_total.children())
                     if rec_total else 0)
        check(rec_count == n_resolves,
              f"registry/tracker agree: {int(rec_count)} == {n_resolves}")

        # 2. latency plumbing
        qfam = reg.get("psi_query_seconds")
        pooled = qfam.merged() if qfam is not None else None
        check(pooled is not None and pooled.count > 0,
              "query latency histogram populated")
        if pooled is not None and pooled.count:
            p50, p99 = pooled.quantile(0.5), pooled.quantile(0.99)
            check(0 <= p50 <= p99 <= pooled._max + 1e-12,
                  f"quantiles ordered: p50={p50:.2e} <= p99={p99:.2e}")

        # 3. tracing
        tracer = obs.trace.get_tracer()
        tracer.flush()
        with open(trace_path) as f:
            spans = [json.loads(line) for line in f if line.strip()]
        names = {s["name"] for s in spans}
        check(len(spans) > 0, f"trace JSONL parses ({len(spans)} spans)")
        check("engine.run" in names and "stream.resolve" in names,
              f"expected spans present: {sorted(names)}")
        depths = [s for s in spans if s.get("parent")]
        check(len(depths) > 0, "spans nest (parented spans recorded)")
        chrome = os.path.join(out_dir, "trace.chrome.json")
        tracer.export_chrome(chrome)
        with open(chrome) as f:
            doc = json.load(f)
        check(bool(doc.get("traceEvents")), "chrome export loads")

        # 4. exposition
        prom = reg.to_prometheus()
        check("# TYPE psi_query_seconds histogram" in prom
              and "# HELP" in prom, "prometheus text has HELP/TYPE headers")
        buckets = [int(ln.rsplit(" ", 1)[1]) for ln in prom.splitlines()
                   if ln.startswith("psi_query_seconds_bucket{op=\"top_k\"")]
        check(buckets == sorted(buckets),
              "histogram bucket counts are cumulative-monotone")
        with open(os.path.join(out_dir, "metrics.prom"), "w") as f:
            f.write(prom)
        snap = obs.dump(os.path.join(out_dir, "metrics.json"))
        check(bool(snap["fingerprint"].get("python"))
              and "psi_resolves_total" in snap["metrics"],
              "obs dump carries fingerprint + metrics + convergence")

        # 5a. SLO engine over the live registry
        from .slo import SLOEngine, default_slos
        engine = SLOEngine(default_slos())
        engine.tick()
        rep = engine.report()
        check(len(rep["slos"]) == 4 and rep["alerts_total"] == 0,
              f"slo engine reports 4 objectives, 0 alerts on a clean run")
        p99_row = next(s for s in rep["slos"]
                       if s["name"] == "query_p99_latency")
        check(p99_row["value"] is not None and p99_row["samples"] >= 1,
              "slo engine reads the live query-latency signal")
        from .slo import SLO
        strict = SLOEngine([SLO("impossible_latency",
                                lambda: 1.0, target=1e-9,
                                description="forced violation")])
        strict.tick()
        srow = strict.report()["slos"][0]
        check(srow["bad_samples"] == 1 and not srow["meeting_target"],
              "forced SLO violation is counted against the budget")

        # 5b. span-stream profiler over the recorded trace
        from .profile import Profile
        prof = Profile.from_tracer(obs.trace.get_tracer())
        folded = prof.folded()
        check(bool(folded) and all(v >= 0 for v in folded.values())
              and any("engine.run" in k for k in folded),
              f"profiler folds {len(folded)} stacks incl. engine.run")
        hot = prof.hotspots(3)
        check(bool(hot) and hot[0]["self_s"] > 0,
              "profiler hotspots carry positive self time")
        prof.write_folded(os.path.join(out_dir, "profile.folded"))

        # 5c. HTTP endpoints on an ephemeral port
        import urllib.request
        from . import metrics as obs_metrics
        prev_provider = obs_metrics.set_slo_provider(engine.report)
        server = obs.start_http_server(0)
        try:
            port = server.server_address[1]
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/healthz") as r:
                hz = json.load(r)
            check(hz.get("status") == "ok" and hz.get("slo_installed"),
                  "/healthz answers ok with slo installed")
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/slo") as r:
                sdoc = json.load(r)
            check(len(sdoc.get("slos", [])) == 4,
                  "/slo serves the engine report")
        finally:
            server.shutdown()
            obs_metrics.set_slo_provider(prev_provider)
    finally:
        obs.restore(prev)

    # 6. decision observability: the planner leaves a complete audit trail
    from ..graphs import clustered_blocks, powerlaw_configuration
    from ..kernels import autotune
    from . import calibrate as obs_calibrate
    from . import explain as obs_explain
    from . import log as obs_log
    prev = obs.configure(registry=obs.MetricsRegistry(),
                         tracker=obs.ConvergenceTracker(),
                         decisions=obs.DecisionLog())
    try:
        reg = obs.metrics.get_registry()
        g6 = powerlaw_configuration(500, 3_000, seed=11)
        cache = autotune.PlanCache()
        plan1 = autotune.plan_regime(g6, cache=cache, calibration=None)
        rec = obs_explain.get_log().last(kind="regime_plan")
        check(rec is not None and rec.cache == "miss"
              and len(rec.candidates) >= 2 and rec.chosen == plan1.label()
              and rec.source == "model",
              "plan_regime miss records the full candidate table")
        check(bool(rec.pruned)
              and all(p.reason == "BSR_MIN_OCCUPANCY" for p in rec.pruned),
              f"density gate prunes carry their reason "
              f"({len(rec.pruned or ())} pruned)")
        autotune.plan_regime(g6, cache=cache, calibration=None)
        rec2 = obs_explain.get_log().last(kind="regime_plan")
        check(rec2 is not None and rec2.cache == "hit",
              "plan cache hit is recorded as a decision")
        hits = reg.value("psi_plan_cache_hits_total") or 0
        misses = reg.value("psi_plan_cache_misses_total") or 0
        check(hits >= 1 and misses >= 1,
              f"plan-cache counters in registry: hits={int(hits)} "
              f"misses={int(misses)}")
        dec_n = reg.value("psi_plan_decisions_total", kind="regime_plan")
        check((dec_n or 0) >= 2,
              f"psi_plan_decisions_total counts records ({int(dec_n or 0)})")

        # an end-to-end service renders the tree
        import jax.numpy as jnp
        from ..core import Activity, PsiService, RATE_FLOOR
        svc_x = PsiService(
            g6, Activity(np.full(g6.n, RATE_FLOOR),
                         np.full(g6.n, RATE_FLOOR)),
            tol=1e-8, backend="reference", dtype=jnp.float64)
        svc_x.update_activity(np.asarray([0]), lam=np.asarray([2.0]))
        svc_x.top_k(3)
        tree = svc_x.explain()
        check("EXPLAIN ANALYZE" in tree and "solver_choice" in tree
              and "resolve" in tree,
              "PsiService.explain renders the decision trail")
        with open(os.path.join(out_dir, "explain.txt"), "w") as f:
            f.write(tree + "\n")

        # 7. calibration loop (the acceptance drill). Skewed constants
        # make edge_tile look ~free and BSR ruinous; a deterministic
        # bench plays measured ground truth (BSR actually wins), so the
        # uncalibrated skewed planner must mis-rank and the calibrated
        # one must recover.
        g7 = clustered_blocks(256, 12_000, block=128, p_in=1.0, seed=3)
        skew = (0.001, 1e5, 16.0)          # (edge, bsr, node) bytes/slot
        uncal = autotune.plan_regime(g7, cache=None, calibration=None,
                                     slot_bytes=skew)
        check(uncal.regime == "edge_tile",
              f"skewed uncalibrated planner mis-ranks "
              f"(picked {uncal.regime})")
        store = obs.CalibrationStore()
        real_bench = autotune._microbench_step
        autotune._microbench_step = \
            lambda graph, plan, dtype, interpret: \
            100.0 if plan.regime == "bsr" else 5_000.0
        try:
            bench = autotune.plan_regime(g7, cache=None, microbench=True,
                                         calibration=store,
                                         slot_bytes=skew)
        finally:
            autotune._microbench_step = real_bench
        check(bench.regime == "bsr" and bench.source == "microbench",
              f"microbench pass finds the measured winner "
              f"({bench.regime})")
        check(len(store) >= 2 and bool(store.factors()),
              f"calibration store fed ({len(store)} samples, "
              f"factors={sorted(store.factors())})")
        recovered = autotune.plan_regime(g7, cache=None, calibration=store,
                                         slot_bytes=skew)
        check(recovered.regime == bench.regime
              and recovered.source == "calibrated",
              f"calibrated planner recovers the measured winner "
              f"({recovered.regime}, source={recovered.source})")
        events_mis = obs_log.recent(name="model_misranked")
        check(len(events_mis) >= 1,
              f"model_misranked event fired ({len(events_mis)}x)")
        ratio = reg.value("psi_plan_misprediction_ratio")
        check(ratio is not None and ratio > 1.0,
              f"psi_plan_misprediction_ratio published ({ratio:.1f})")
        store.save(os.path.join(out_dir, "calibration.json"))
        with open(os.path.join(out_dir, "calibration.json")) as f:
            cal_doc = json.load(f)
        check(bool(cal_doc.get("entries"))
              and {e["regime"] for e in cal_doc["entries"]}
              >= {"bsr", "edge_tile"},
              "calibration store round-trips to JSON artifact")
    finally:
        obs.restore(prev)

    # 8. parity: the identical workload with every sink nulled — and the
    # populated calibration store left armed (it is planner input, not
    # telemetry, so obs.disable() must not touch it and ψ must not move)
    prev_store = obs_calibrate.get_store()
    obs_calibrate.set_store(store)
    prev = obs.disable()
    try:
        svc2, _, _ = _build_and_stream(events)
        psi_null = np.array(svc2.scores(), copy=True)
    finally:
        obs.restore(prev)
        obs_calibrate.set_store(prev_store)
    check(psi_live.shape == psi_null.shape
          and np.array_equal(psi_live, psi_null),
          "instrumented vs disabled psi bitwise-equal "
          "(explain + calibration armed)")

    # 8b. parity with the FULL analysis layer armed: watch subscribed to
    # the tracker, SLO engine ticking, profiler consuming the tracer
    from .slo import SLOEngine as _Eng, default_slos as _slos
    from .watch import ConvergenceWatch
    prev = obs.configure(registry=obs.MetricsRegistry(),
                         tracer=obs.Tracer(None),
                         tracker=obs.ConvergenceTracker())
    watch = ConvergenceWatch()
    watch.attach()
    try:
        eng = _Eng(_slos())
        svc3, _, _ = _build_and_stream(events)
        eng.tick()
        psi_armed = np.array(svc3.scores(), copy=True)
        prof3 = Profile.from_tracer(obs.trace.get_tracer())
        check(bool(prof3.records), "analysis-armed run recorded spans")
        check(watch.summary()["signals"] == 0,
              "healthy run raises no watch anomalies")
    finally:
        watch.detach()
        obs.restore(prev)
    check(np.array_equal(psi_live, psi_armed),
          "psi bitwise-equal with watch+slo+profiler armed")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="self-test the repro.obs telemetry plane")
    ap.add_argument("--out-dir", default="obs_check_out",
                    help="artifact directory (metrics.prom, metrics.json, "
                         "trace.jsonl, trace.chrome.json)")
    ap.add_argument("--events", type=int, default=1_200,
                    help="approximate synthetic stream size")
    args = ap.parse_args(argv)
    failures = run_check(args.out_dir, events=args.events)
    if failures:
        print(f"[obs.check] {len(failures)} check(s) FAILED:")
        for msg in failures:
            print(f"[obs.check]   - {msg}")
        return 1
    print(f"[obs.check] all checks passed; artifacts in "
          f"{os.path.abspath(args.out_dir)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
