"""repro.obs — the unified telemetry plane.

Three pillars, one switchboard:

- :mod:`repro.obs.metrics` — process-wide counters/gauges/histograms with
  Prometheus-text and JSON exposition (``NullRegistry`` when disabled).
- :mod:`repro.obs.trace` — nestable spans on one shared clock, JSONL +
  Chrome ``trace_event`` export, and the jit :func:`retrace_guard`.
- :mod:`repro.obs.convergence` — per-resolve gap/certificate trajectories.

The analysis-and-control layer on top of the measurements:

- :mod:`repro.obs.slo` — declarative SLOs, error budgets, multi-window
  burn-rate alerts (served at ``/slo`` on the exposition server).
- :mod:`repro.obs.profile` — span-stream profiler: folded flamegraph
  stacks, per-backend cost attribution, async critical-path extraction.
- :mod:`repro.obs.watch` — online convergence anomaly detection feeding
  pre-emptive advice into the resilience ladder.
- :mod:`repro.obs.regress` — noise-aware perf-regression gate over the
  benchmark trajectory (``python -m repro.obs.regress``).

Instrumentation sites throughout the stack call the cheap module-level
helpers (``metrics.counter(...)``, ``trace.span(...)``,
``convergence.record_gap(...)``); :func:`configure` swaps the process
sinks behind them. The default state is metrics ON (pure host-side
Python, no device syncs) with tracing and convergence recording ON in
their bounded in-memory forms — :func:`disable` swaps every sink for its
null twin so the hot path costs one attribute read + no-op call.

``python -m repro.obs.check`` self-tests the plane end to end.
"""
from __future__ import annotations

import json as _json

from . import calibrate, convergence, explain, log, metrics, profile, slo, \
    trace, watch
from .calibrate import CalibrationStore
from .convergence import ConvergenceTracker, NULL_TRACKER
from .env import environment_fingerprint
from .explain import DecisionLog, DecisionRecord, NULL_DECISIONS
from .metrics import MetricsRegistry, NullRegistry, start_http_server
from .profile import Profile
from .slo import SLO, SLOEngine, default_slos
from .trace import NULL_TRACER, Span, Tracer, retrace_guard, span
from .watch import ConvergenceWatch

__all__ = [
    "metrics", "trace", "convergence", "log",
    "slo", "profile", "watch", "regress", "explain", "calibrate",
    "MetricsRegistry", "NullRegistry", "Tracer", "Span",
    "ConvergenceTracker", "span", "retrace_guard",
    "SLO", "SLOEngine", "default_slos", "Profile", "ConvergenceWatch",
    "DecisionLog", "DecisionRecord", "CalibrationStore",
    "environment_fingerprint", "start_http_server",
    "configure", "disable", "enabled", "dump",
]


def __getattr__(name):
    # lazy: regress is a CLI module; importing it eagerly would trip the
    # runpy double-import warning under `python -m repro.obs.regress`
    if name == "regress":
        from . import regress
        return regress
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def enabled() -> bool:
    """True when the metrics plane is live (not the NullRegistry)."""
    return metrics.enabled()


def configure(*, registry: MetricsRegistry | None = None,
              trace_out: str | None = None,
              tracer: Tracer | None = None,
              tracker: ConvergenceTracker | None = None,
              decisions: DecisionLog | None = None) -> dict:
    """Install fresh sinks; returns the previous ones (for restoring).

    ``trace_out`` is a convenience: a path builds ``Tracer(trace_out)``.
    """
    prev = {"registry": metrics.get_registry(),
            "tracer": trace.get_tracer(),
            "tracker": convergence.get_tracker(),
            "decisions": explain.get_log()}
    if registry is not None:
        metrics.set_registry(registry)
    if tracer is None and trace_out is not None:
        tracer = Tracer(trace_out)
    if tracer is not None:
        trace.set_tracer(tracer)
    if tracker is not None:
        convergence.set_tracker(tracker)
    if decisions is not None:
        explain.set_log(decisions)
    return prev


def disable() -> dict:
    """Swap every sink for its null twin (one-branch hot path); returns
    the previous sinks so callers can restore them.

    The calibration store is *not* a sink: it is a planner input, so the
    plan chosen with observability disabled matches the instrumented one.
    """
    return configure(registry=NullRegistry(), tracer=NULL_TRACER,
                     tracker=NULL_TRACKER, decisions=NULL_DECISIONS)


def restore(prev: dict) -> None:
    """Undo a :func:`configure`/:func:`disable` using its return value."""
    metrics.set_registry(prev["registry"])
    trace.set_tracer(prev["tracer"])
    convergence.set_tracker(prev["tracker"])
    if "decisions" in prev:
        explain.set_log(prev["decisions"])


def dump(path: str | None = None) -> dict:
    """One self-describing snapshot: fingerprint + metrics + convergence
    trajectories (+ recent structured events). Optionally written to
    ``path`` as JSON."""
    snap = {
        "fingerprint": environment_fingerprint(),
        "metrics": metrics.get_registry().to_json(),
        "convergence": convergence.get_tracker().to_json(),
        "events": log.recent(200),
        "decisions": explain.get_log().to_json(),
        "calibration": calibrate.get_store().to_json(),
    }
    if path is not None:
        with open(path, "w") as f:
            _json.dump(snap, f, indent=1, default=str)
    return snap
