"""Structured event log: countable warnings and operational events.

``warnings.warn`` is for humans reading stderr; an operator needs the same
facts as *countable series*. :func:`event` records a named event into a
bounded in-memory ring and bumps ``obs_events_total{event=,level=}`` in
the metrics registry; :func:`warn` does that AND still emits the
``warnings.warn`` (the satellite contract: torn-checkpoint skips and
block-overflow regrows stay visible to ``-W error`` test rigs while
becoming queryable in the registry).

Zero-dependency and import-light on purpose: :mod:`repro.ckpt.checkpoint`
calls into here from its corruption-fallback paths.
"""
from __future__ import annotations

import threading
import time
import warnings as _warnings
from collections import deque

from . import metrics

__all__ = ["event", "warn", "recent", "clear"]

_LOCK = threading.Lock()
_EVENTS: deque[dict] = deque(maxlen=2048)


def event(name: str, message: str = "", *, level: str = "info",
          **fields) -> dict:
    """Record one structured event; returns the record."""
    rec = dict(name=str(name), level=str(level), message=str(message),
               wall_time=time.time(), **fields)
    with _LOCK:
        _EVENTS.append(rec)
    metrics.counter("obs_events_total",
                    "structured events by name and level",
                    labelnames=("event", "level")) \
        .labels(event=name, level=level).inc()
    return rec


def warn(name: str, message: str, *, category=RuntimeWarning,
         stacklevel: int = 3, **fields) -> dict:
    """A structured warning: counted + ringed via :func:`event`, then
    emitted through ``warnings.warn`` exactly as before (``stacklevel``
    defaults to 3 so the warning points at the caller of the caller —
    the site that used to call ``warnings.warn(..., stacklevel=2)``)."""
    rec = event(name, message, level="warning", **fields)
    _warnings.warn(message, category, stacklevel=stacklevel)
    return rec


def recent(n: int | None = None, *, name: str | None = None) -> list[dict]:
    """The newest events (filtered by name), oldest first."""
    with _LOCK:
        events = list(_EVENTS)
    if name is not None:
        events = [e for e in events if e["name"] == name]
    return events if n is None else events[-n:]


def clear() -> None:
    with _LOCK:
        _EVENTS.clear()
