"""Online convergence anomaly detection — the pre-emptive control signal.

The resilience sentinels (:mod:`repro.resilience.health`) are *tripwires*:
they fire when a run has already gone wrong (α ≥ 1, gap growing, a
certificate storm in a finished report). :class:`ConvergenceWatch` sits
upstream, watching the same host-visible evidence as it accumulates —
finished :class:`~repro.obs.convergence.ResolveRecord` trajectories, async
driver reports, contraction-modulus readings — and projects *trends*, so
the :class:`~repro.resilience.supervisor.ResilientResolver` can tighten τ
or schedule a verification sweep **before** a sentinel trips.

Detectors (each emits a :class:`WatchSignal`, counts
``psi_watch_signals_total{kind}`` and logs a ``watch_anomaly`` event):

* ``rho_drift`` — the per-resolve contraction estimate (median ratio of
  consecutive gap samples) drifting above its baseline, or past
  ``rho_cap``: convergence is stalling geometrically.
* ``gap_plateau`` — a large fraction of non-decreasing steps inside one
  trajectory: the iteration is treading water.
* ``aitken_shift`` — the chunk extrapolator's rejection rate jumping
  over its baseline: the iterate sequence stopped looking geometric.
* ``cert_storm_onset`` — rejected stale-corrected certificates in one
  async run reaching ``storm_frac`` of the sentinel's storm threshold:
  τ is too loose for the current epoch spread. Advice: tighten τ.
* ``alpha_drift`` — α measurements trending toward ``alpha_max``; the
  linear projection crosses the wall within ``alpha_horizon`` steps.
* ``attempt_failure`` — a timeout/fault observed by the supervisor;
  repeated attempts are unlikely to behave differently. Advice: sweep.

Advice is *latched*: :meth:`ConvergenceWatch.consume_advice` hands the
pending recommendation to the resolver exactly once and re-arms, so one
anomaly causes one pre-emption, not a pre-emption per resolve forever.
"""
from __future__ import annotations

import dataclasses
import statistics
import threading
import time
from collections import deque
from typing import Optional

from . import convergence as obs_convergence
from . import log as obs_log
from . import metrics as obs_metrics

__all__ = ["ConvergenceWatch", "WatchSignal", "WatchAdvice"]

#: signal kinds that recommend tightening τ (re-chunk to synchronous
#: epochs) vs scheduling a full verification sweep
_TIGHTEN_TAU = frozenset({"cert_storm_onset"})
_SYNC_SWEEP = frozenset({"rho_drift", "gap_plateau", "aitken_shift",
                         "alpha_drift", "attempt_failure"})


@dataclasses.dataclass(frozen=True)
class WatchSignal:
    kind: str
    value: float
    detail: str
    wall_time: float


@dataclasses.dataclass(frozen=True)
class WatchAdvice:
    """What the ladder should do before its next attempt."""
    tighten_tau: bool
    sync_sweep: bool
    reasons: tuple

    def __bool__(self) -> bool:
        return self.tighten_tau or self.sync_sweep


class ConvergenceWatch:
    """Online anomaly detector over the convergence stream (see module
    docstring). Thread-safe: resolves may finish on worker threads."""

    def __init__(self, *,
                 baseline: int = 5,
                 rho_drift: float = 0.05,
                 rho_cap: float = 0.985,
                 plateau_frac: float = 0.6,
                 plateau_min_points: int = 6,
                 aitken_shift: float = 0.35,
                 aitken_min_jumps: int = 4,
                 storm_frac: float = 0.5,
                 cert_storm: int = 50,
                 alpha_max: float = 1.0,
                 alpha_horizon: int = 3,
                 history: int = 128):
        self.baseline = int(baseline)
        self.rho_drift = float(rho_drift)
        self.rho_cap = float(rho_cap)
        self.plateau_frac = float(plateau_frac)
        self.plateau_min_points = int(plateau_min_points)
        self.aitken_shift = float(aitken_shift)
        self.aitken_min_jumps = int(aitken_min_jumps)
        self.storm_frac = float(storm_frac)
        self.cert_storm = int(cert_storm)
        self.alpha_max = float(alpha_max)
        self.alpha_horizon = int(alpha_horizon)

        self._lock = threading.Lock()
        self._rho_baseline: list = []
        self._aitken_baseline: list = []
        self._alphas: deque = deque(maxlen=16)
        self.signals: deque = deque(maxlen=history)
        self._pending: dict = {"tighten_tau": False, "sync_sweep": False,
                               "reasons": []}
        self._tracker = None
        self._hook = None

    # -- attach to the convergence stream -------------------------------- #
    def attach(self, tracker=None) -> "ConvergenceWatch":
        """Subscribe to finished resolves on ``tracker`` (default: the
        process tracker). Idempotent per tracker."""
        self.detach()
        self._tracker = (tracker if tracker is not None
                         else obs_convergence.get_tracker())
        self._hook = self._tracker.subscribe(self.observe_record)
        return self

    def detach(self) -> None:
        if self._tracker is not None and self._hook is not None:
            self._tracker.unsubscribe(self._hook)
        self._tracker = self._hook = None

    # -- detectors -------------------------------------------------------- #
    def observe_record(self, rec) -> None:
        """Digest one finished resolve trajectory."""
        values = [p.get("raw", p.get("certified"))
                  for p in getattr(rec, "points", ())]
        values = [v for v in values if v is not None and v > 0.0]
        self._check_rho(values, rec)
        self._check_plateau(values, rec)
        self._check_aitken(rec)

    def _check_rho(self, values, rec) -> None:
        if len(values) < 3:
            return
        ratios = [b / a for a, b in zip(values, values[1:])
                  if a > 0.0 and 0.0 < b / a < 10.0]
        if not ratios:
            return
        rho = min(max(statistics.median(ratios), 0.0), 10.0)
        with self._lock:
            if len(self._rho_baseline) < self.baseline:
                self._rho_baseline.append(rho)
                return
            base = statistics.median(self._rho_baseline)
        if rho >= self.rho_cap or rho - base > self.rho_drift:
            self._signal(
                "rho_drift", rho,
                f"contraction estimate {rho:.4f} vs baseline {base:.4f} "
                f"(backend {rec.backend})")

    def _check_plateau(self, values, rec) -> None:
        if len(values) < self.plateau_min_points:
            return
        flat = sum(1 for a, b in zip(values, values[1:]) if b >= a)
        frac = flat / (len(values) - 1)
        if frac >= self.plateau_frac:
            self._signal(
                "gap_plateau", frac,
                f"{flat}/{len(values) - 1} non-decreasing gap steps "
                f"(backend {rec.backend})")

    def _check_aitken(self, rec) -> None:
        acc = getattr(rec, "aitken_accepted", 0)
        rej = getattr(rec, "aitken_rejected", 0)
        total = acc + rej
        if total < self.aitken_min_jumps:
            return
        rate = rej / total
        with self._lock:
            if len(self._aitken_baseline) < self.baseline:
                self._aitken_baseline.append(rate)
                return
            base = statistics.median(self._aitken_baseline)
        if rate - base > self.aitken_shift:
            self._signal(
                "aitken_shift", rate,
                f"Aitken rejection rate {rate:.2f} vs baseline {base:.2f}")

    def observe_report(self, report) -> None:
        """Digest one async driver report (certificate-storm onset)."""
        rejected = getattr(report, "rejected_certificates", 0) or 0
        threshold = self.storm_frac * self.cert_storm
        if rejected >= max(threshold, 1):
            self._signal(
                "cert_storm_onset", float(rejected),
                f"{rejected} rejected certificates in one run "
                f"(sentinel storms at {self.cert_storm})")

    def observe_alpha(self, alpha: float) -> None:
        """Digest one contraction-modulus measurement; projects the recent
        trend ``alpha_horizon`` steps forward against ``alpha_max``."""
        a = float(alpha)
        with self._lock:
            self._alphas.append(a)
            recent = list(self._alphas)[-4:]
        if a >= self.alpha_max:
            self._signal("alpha_drift", a,
                         f"alpha {a:.5f} at/over the wall {self.alpha_max}")
            return
        if len(recent) < 3:
            return
        diffs = [b - x for x, b in zip(recent, recent[1:])]
        step = statistics.mean(diffs)
        if step <= 0:
            return
        projected = a + self.alpha_horizon * step
        if projected >= self.alpha_max:
            self._signal(
                "alpha_drift", a,
                f"alpha {a:.5f} rising {step:.5f}/step; projected "
                f"{projected:.5f} >= {self.alpha_max} within "
                f"{self.alpha_horizon} steps")

    def observe_failure(self, kind: str, detail: str = "") -> None:
        """Digest a supervised-attempt failure (timeout, fault, ...)."""
        self._signal("attempt_failure", 1.0,
                     f"{kind}: {detail}" if detail else kind)

    # -- signal plumbing --------------------------------------------------#
    def _signal(self, kind: str, value: float, detail: str) -> None:
        sig = WatchSignal(kind, value, detail, time.time())
        with self._lock:
            self.signals.append(sig)
            if kind in _TIGHTEN_TAU:
                self._pending["tighten_tau"] = True
            if kind in _SYNC_SWEEP:
                self._pending["sync_sweep"] = True
            if kind not in self._pending["reasons"]:
                self._pending["reasons"].append(kind)
        obs_metrics.counter(
            "psi_watch_signals_total",
            "convergence anomalies detected by the watch", ("kind",)
        ).labels(kind=kind).inc()
        obs_log.event("watch_anomaly", detail, level="warning",
                      kind=kind, value=value)

    def advice(self) -> WatchAdvice:
        """Peek at the pending recommendation without consuming it."""
        with self._lock:
            return WatchAdvice(self._pending["tighten_tau"],
                               self._pending["sync_sweep"],
                               tuple(self._pending["reasons"]))

    def consume_advice(self) -> WatchAdvice:
        """Hand the pending recommendation to the ladder and re-arm."""
        with self._lock:
            adv = WatchAdvice(self._pending["tighten_tau"],
                              self._pending["sync_sweep"],
                              tuple(self._pending["reasons"]))
            self._pending = {"tighten_tau": False, "sync_sweep": False,
                             "reasons": []}
        return adv

    def summary(self) -> dict:
        with self._lock:
            kinds: dict = {}
            for s in self.signals:
                kinds[s.kind] = kinds.get(s.kind, 0) + 1
            return dict(signals=len(self.signals), by_kind=kinds,
                        pending=dict(self._pending))
