"""Decision observability: EXPLAIN ANALYZE for the planner stack.

The repo picks its computation in several otherwise-hidden places — the
``plan_regime``/``plan_for_bucket`` HBM-bytes cost model, ``choose_solver``'s
push-vs-sweep planner, the fleet's per-bucket regime rule, and the push
backend's certified early stop.  The PR 8/9 telemetry plane records the
*outcome* of a resolve; this module records the *decision trail*: every
planner call appends a structured :class:`DecisionRecord` — the full
candidate table (modeled cost, measured µs, calibrated µs), the pruned
candidates with their prune reason, the plan-cache state, the inputs the
decision was made from, and the calibration factors consumed — linked to
the innermost open :class:`~repro.obs.convergence.ResolveRecord` when one
exists.  ``PsiService.explain()`` and ``serve --explain`` render the trail
as an EXPLAIN-ANALYZE tree.

Recording is telemetry: :func:`repro.obs.disable` swaps the log for its
null twin and the planner behaves identically either way (the records are
pure reads of values the planner already holds on the host).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

from . import convergence as _convergence
from . import metrics as _metrics

__all__ = ["Candidate", "Pruned", "DecisionRecord", "DecisionLog",
           "NULL_DECISIONS", "get_log", "set_log", "record_decision",
           "decisions_for", "format_cost", "render_decision",
           "explain_tree"]

KINDS = ("regime_plan", "bucket_plan", "bucket_regime", "solver_choice",
         "early_stop")


@dataclasses.dataclass
class Candidate:
    """One alternative the planner considered."""

    name: str                       # e.g. "edge_tile(tile=256,e1=8,e2=128)"
    est: float | None = None        # modeled cost (unit below)
    unit: str = "bytes"             # "bytes" | "edges" | ""
    measured_us: float = 0.0        # microbench result (0 = not timed)
    calibrated_us: float | None = None
    chosen: bool = False
    detail: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        out = dict(name=self.name, chosen=self.chosen)
        if self.est is not None:
            out["est"] = self.est
            out["unit"] = self.unit
        if self.measured_us:
            out["measured_us"] = self.measured_us
        if self.calibrated_us is not None:
            out["calibrated_us"] = self.calibrated_us
        if self.detail:
            out["detail"] = self.detail
        return out


@dataclasses.dataclass
class Pruned:
    """A candidate dropped before scoring, and why."""

    name: str
    reason: str                     # e.g. "BSR_MIN_OCCUPANCY"
    detail: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return dict(name=self.name, reason=self.reason, detail=self.detail)


class DecisionRecord:
    """One planner decision: inputs, alternatives, prunes, the winner."""

    __slots__ = ("kind", "site", "wall_time", "inputs", "candidates",
                 "pruned", "cache", "chosen", "source", "calibration",
                 "resolve_index", "note")

    def __init__(self, kind: str, site: str, *, inputs: dict | None = None,
                 candidates=(), pruned=(), cache: str | None = None,
                 chosen: str = "", source: str | None = None,
                 calibration: dict | None = None, note: str = ""):
        self.kind = kind
        self.site = site
        self.wall_time = time.time()
        self.inputs = dict(inputs or {})
        self.candidates = list(candidates)
        self.pruned = list(pruned)
        self.cache = cache              # "hit" | "miss" | "bypass" | None
        self.chosen = chosen
        self.source = source            # "model"|"microbench"|"calibrated"
        self.calibration = calibration
        self.note = note
        rec = _convergence.current()
        self.resolve_index = rec.index if rec is not None else None

    def to_json(self) -> dict:
        out = dict(kind=self.kind, site=self.site, wall_time=self.wall_time,
                   inputs=self.inputs, chosen=self.chosen,
                   candidates=[c.to_json() for c in self.candidates],
                   pruned=[p.to_json() for p in self.pruned])
        for k in ("cache", "source", "calibration", "resolve_index"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        if self.note:
            out["note"] = self.note
        return out


class DecisionLog:
    """Bounded process-wide ring of :class:`DecisionRecord`\\ s."""

    enabled = True

    def __init__(self, *, keep: int = 256):
        self._lock = threading.Lock()
        self._ring: deque[DecisionRecord] = deque(maxlen=int(keep))

    def record(self, rec: DecisionRecord) -> DecisionRecord:
        with self._lock:
            self._ring.append(rec)
        return rec

    def recent(self, n: int | None = None, *,
               kind: str | None = None) -> list[DecisionRecord]:
        with self._lock:
            recs = list(self._ring)
        if kind is not None:
            recs = [r for r in recs if r.kind == kind]
        return recs if n is None else recs[-n:]

    def last(self, *, kind: str | None = None) -> DecisionRecord | None:
        recs = self.recent(1, kind=kind)
        return recs[-1] if recs else None

    def to_json(self) -> list[dict]:
        return [r.to_json() for r in self.recent()]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)


class _NullDecisionLog:
    enabled = False

    def record(self, rec):
        return None

    def recent(self, n=None, *, kind=None):
        return []

    def last(self, *, kind=None):
        return None

    def to_json(self):
        return []

    def clear(self):
        pass

    def __len__(self):
        return 0


NULL_DECISIONS = _NullDecisionLog()
_LOG = DecisionLog()


def get_log():
    return _LOG


def set_log(log):
    """Install the process decision log (NULL_DECISIONS disables);
    returns the previous one."""
    global _LOG
    prev, _LOG = _LOG, log
    return prev


def record_decision(kind: str, site: str, **kw) -> DecisionRecord | None:
    """Build, count, and ring one decision (no-op when disabled)."""
    if not _LOG.enabled:
        return None
    rec = DecisionRecord(kind, site, **kw)
    _LOG.record(rec)
    _metrics.counter("psi_plan_decisions_total",
                     "planner decisions by kind",
                     labelnames=("kind",)).labels(kind=kind).inc()
    return rec


def decisions_for(*, n: int | None = None, m: int | None = None,
                  log: DecisionLog | None = None) -> list[DecisionRecord]:
    """The newest decision of each kind, preferring records whose inputs
    match the caller's graph shape ``(n, m)`` — the assembly step behind
    ``PsiService.explain``."""
    log = log or _LOG
    out = []
    for kind in KINDS:
        recs = log.recent(kind=kind)
        if not recs:
            continue
        match = [r for r in recs
                 if (n is None or r.inputs.get("n") in (None, n))
                 and (m is None or r.inputs.get("m") in (None, m))]
        out.append((match or recs)[-1])
    return out


# --------------------------------------------------------------------- #
# Rendering
# --------------------------------------------------------------------- #
def format_cost(value: float | None, unit: str) -> str:
    if value is None:
        return "-"
    if unit == "bytes":
        for thresh, suff in ((1 << 30, "GB"), (1 << 20, "MB"),
                             (1 << 10, "KB")):
            if value >= thresh:
                return f"{value / thresh:.2f}{suff}"
        return f"{value:.0f}B"
    if unit == "us":
        return f"{value / 1e3:.2f}ms" if value >= 1e3 else f"{value:.1f}µs"
    if unit == "edges":
        return f"{value:.3g} edges"
    return f"{value:.4g}{unit}"


def _candidate_line(c: Candidate, best_est: float | None) -> str:
    tag = "chosen" if c.chosen else "reject"
    parts = [f"{tag}  {c.name}"]
    if c.est is not None:
        parts.append(f"est={format_cost(c.est, c.unit)}")
        if (not c.chosen and best_est and c.unit in ("bytes", "edges")
                and c.est > 0):
            parts.append(f"(+{(c.est / best_est - 1.0) * 100:.0f}%)")
    if c.measured_us:
        parts.append(f"measured={format_cost(c.measured_us, 'us')}")
    if c.calibrated_us is not None:
        parts.append(f"calibrated={format_cost(c.calibrated_us, 'us')}")
    for k, v in c.detail.items():
        parts.append(f"{k}={v}")
    return "  ".join(parts)


def render_decision(rec: DecisionRecord) -> list[str]:
    """One decision as indented tree lines (no leading connectors)."""
    inputs = " ".join(f"{k}={v}" for k, v in rec.inputs.items())
    head = f"{rec.kind} via {rec.site}"
    if rec.cache:
        head += f" [PLAN_CACHE {rec.cache}]" if rec.kind in (
            "regime_plan", "bucket_plan") else f" [cache {rec.cache}]"
    if rec.source:
        head += f" source={rec.source}"
    if inputs:
        head += f"  ({inputs})"
    lines = [head]
    chosen = [c for c in rec.candidates if c.chosen]
    best = chosen[0].est if chosen and chosen[0].est else None
    for c in sorted(rec.candidates, key=lambda c: not c.chosen):
        lines.append("  " + _candidate_line(c, best))
    for p in rec.pruned:
        detail = "  ".join(f"{k}={v}" for k, v in p.detail.items())
        lines.append(f"  pruned  {p.name}  {p.reason}" +
                     (f"  {detail}" if detail else ""))
    if rec.calibration:
        factors = rec.calibration.get("factors", {})
        fstr = " ".join(
            f"{r}:{f['median']:.3g}×(±{f['mad']:.2g},n={f['count']})"
            for r, f in sorted(factors.items()))
        lines.append(f"  calibration env={rec.calibration.get('env')}  "
                     f"gen={rec.calibration.get('generation')}  {fstr}")
    if rec.note:
        lines.append(f"  note: {rec.note}")
    return lines


def _tree(blocks: list[list[str]]) -> list[str]:
    """Join rendered blocks with box-drawing connectors."""
    out = []
    for i, block in enumerate(blocks):
        last = i == len(blocks) - 1
        head, cont = ("└─ ", "   ") if last else ("├─ ", "│  ")
        for j, line in enumerate(block):
            out.append((head if j == 0 else cont) + line)
    return out


def explain_tree(*, header: str = "EXPLAIN ANALYZE — power-ψ resolve",
                 resolve=None, decisions=(), query: dict | None = None,
                 extra: dict | None = None) -> str:
    """Render the full decision trail for one resolve/query.

    ``resolve`` is a :class:`~repro.obs.convergence.ResolveRecord` (or
    ``None``); ``decisions`` an iterable of :class:`DecisionRecord`;
    ``query`` the last query-funnel facts (op, cache, staleness,
    err_bound, seconds).
    """
    blocks: list[list[str]] = []
    if resolve is not None:
        lines = [f"resolve #{resolve.index} backend={resolve.backend}"
                 + (f" tenant={resolve.tenant}"
                    if resolve.tenant is not None else "")]
        lines.append(f"  iterations={resolve.iterations} "
                     f"gap={resolve.gap:.3g} converged={resolve.converged} "
                     f"wall={resolve.duration_s * 1e3:.1f}ms")
        if resolve.psi_error_bound is not None:
            lines.append("  certified |ψ−ψ̂| ≤ "
                         f"{resolve.psi_error_bound:.3g}")
        if resolve.push:
            p = resolve.push
            lines.append(
                "  push rounds={rounds} edge_work={edge_work:.3g} "
                "touched_frac={touched_frac:.3g} certified={certified}"
                .format(rounds=p.get("rounds"),
                        edge_work=float(p.get("edge_work", 0.0)),
                        touched_frac=float(p.get("touched_frac", 0.0)),
                        certified=p.get("certified")))
        blocks.append(lines)
    for rec in decisions:
        blocks.append(render_decision(rec))
    if query:
        qline = "query"
        for k in ("op", "cache", "stale", "err_bound"):
            if query.get(k) is not None:
                qline += f" {k}={query[k]}"
        if query.get("seconds") is not None:
            qline += f" wall={query['seconds'] * 1e3:.2f}ms"
        blocks.append([qline])
    if extra:
        blocks.append([" ".join(f"{k}={v}" for k, v in extra.items())])
    if not blocks:
        blocks.append(["(no recorded decisions — run a resolve first)"])
    return "\n".join([header] + _tree(blocks))
