"""Noise-aware perf-regression gate over ``BENCH_power_psi.json``.

The benchmark trajectory is append-only history; this module turns it
into a *judgement*: is the newest run slower than the history says it
should be, beyond what machine noise explains?

Method:

* **Candidate** — the newest run (or ``--label``). **Baselines** — every
  other run whose environment fingerprint is *compatible* (fingerprint
  keys present in both runs must agree on ``device_platform``, ``x64``
  and ``device_count``; runs stamped before fingerprints existed have an
  empty environment and match anything) and whose ``quick`` flag matches
  (quick runs use smaller problem sizes).
* **Comparability** — entries pair up on the full workload identity
  ``(graph, backend, regime, n, m, dtype, tol)``; a scenario whose size
  changed between PRs silently gets fewer baselines, never a bogus one.
* **Robust threshold** — per (scenario, metric): ``median`` and ``MAD``
  over the baseline values, ``sigma = 1.4826 * MAD`` (the consistent
  normal estimate). A lower-is-better metric regresses when

      candidate > median + max(k * sigma, rel_floor * median, abs_floor)

  and symmetrically for higher-is-better. The relative floor is what
  makes the gate *noise-aware* with few baselines (MAD of one sample is
  0): timing metrics get a wide floor, deterministic counters (matvecs,
  work_frac) a tight one. Timing floors double for quick candidates
  (``--quick`` or a run stamped ``quick``) — small problems are
  dominated by constant overheads.
* **Self-proof** — ``--self-check`` re-runs the gate on an in-memory
  copy of the document with every candidate ``wall_s`` doubled and
  fails the process unless the gate catches the injected slowdown.

CLI (exit 0 = pass, 1 = regression, 2 = self-check failed to catch):

    python -m repro.obs.regress [--json BENCH_power_psi.json]
        [--label PR9] [--out verdict.json] [--quick] [--self-check]
"""
from __future__ import annotations

import argparse
import copy
import json
import statistics
import sys
from typing import Optional

__all__ = ["gate", "load_doc", "inject_slowdown", "main",
           "GATED_METRICS"]

#: metric -> (direction, relative floor, MAD multiplier)
GATED_METRICS = {
    "wall_s": ("lower", 0.40, 4.0),        # timing: noisy across machines
    "matvecs": ("lower", 0.05, 4.0),       # deterministic work counter
    "events_per_s": ("higher", 0.35, 4.0),  # ingest throughput (timing)
    "tenants_per_s": ("higher", 0.35, 4.0),
    "work_frac": ("lower", 0.10, 4.0),     # push locality (deterministic)
}

#: fingerprint keys that must agree when present in both runs
ENV_MATCH_KEYS = ("device_platform", "x64", "device_count")

ABS_FLOORS = {"wall_s": 0.005, "events_per_s": 0.0, "tenants_per_s": 0.0,
              "matvecs": 2.0, "work_frac": 0.01}


def load_doc(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _env_compatible(a: dict, b: dict) -> bool:
    a, b = a or {}, b or {}
    return all(a[k] == b[k] for k in ENV_MATCH_KEYS if k in a and k in b)


def _entry_key(entry: dict) -> tuple:
    return (entry.get("graph"), entry.get("backend"),
            str(entry.get("regime")), entry.get("n"), entry.get("m"),
            entry.get("dtype"), entry.get("tol"))


def _scenario(entry: dict) -> str:
    s = f"{entry.get('graph')}/{entry.get('backend')}"
    regime = entry.get("regime")
    if regime not in (None, "null"):
        s += f"[{regime}]"
    return s


def _pick_candidate(doc: dict, label: Optional[str]) -> dict:
    runs = doc.get("runs", [])
    if not runs:
        raise SystemExit("no runs in benchmark document")
    if label is None:
        return runs[-1]
    for run in runs:
        if run.get("label") == label:
            return run
    raise SystemExit(f"no run labelled {label!r} "
                     f"(have: {[r.get('label') for r in runs]})")


def gate(doc: dict, *, label: Optional[str] = None,
         quick: bool = False, min_baselines: int = 1) -> dict:
    """Evaluate the candidate run against fingerprint-matched history.

    Returns a verdict document: per-(scenario, metric) rows with
    ``status`` in ``ok`` / ``regression`` / ``improved`` / ``skipped``
    plus an overall ``ok`` flag and the named regressions.
    """
    candidate = _pick_candidate(doc, label)
    # quick runs use small problems whose timings are dominated by
    # constant overheads — widen the timing floors for them regardless
    # of how the gate itself was invoked
    quick = quick or bool(candidate.get("quick"))
    cand_env = candidate.get("environment") or {}
    baselines = [
        r for r in doc.get("runs", [])
        if r is not candidate
        and bool(r.get("quick")) == bool(candidate.get("quick"))
        and _env_compatible(cand_env, r.get("environment") or {})]

    # baseline values per (workload identity, metric)
    history: dict = {}
    for run in baselines:
        for entry in run.get("entries", []):
            for metric in GATED_METRICS:
                if metric in entry and entry[metric] is not None:
                    history.setdefault(
                        (_entry_key(entry), metric), []).append(
                            float(entry[metric]))

    rows, regressions = [], []
    for entry in candidate.get("entries", []):
        key = _entry_key(entry)
        scenario = _scenario(entry)
        for metric, (direction, rel_floor, mad_k) in GATED_METRICS.items():
            if metric not in entry or entry[metric] is None:
                continue
            value = float(entry[metric])
            base = history.get((key, metric), [])
            row = dict(scenario=scenario, metric=metric, value=value,
                       baselines=len(base), direction=direction)
            if len(base) < min_baselines:
                row["status"] = "skipped"
                rows.append(row)
                continue
            med = statistics.median(base)
            mad = statistics.median(abs(b - med) for b in base)
            sigma = 1.4826 * mad
            floor = rel_floor * (2.0 if quick and metric in
                                 ("wall_s", "events_per_s",
                                  "tenants_per_s") else 1.0)
            slack = max(mad_k * sigma, floor * abs(med),
                        ABS_FLOORS.get(metric, 0.0))
            if direction == "lower":
                limit = med + slack
                regressed, improved = value > limit, value < med - slack
            else:
                limit = med - slack
                regressed, improved = value < limit, value > med + slack
            row.update(median=med, sigma=sigma, limit=limit,
                       ratio=(value / med if med else None),
                       status=("regression" if regressed
                               else "improved" if improved else "ok"))
            rows.append(row)
            if regressed:
                regressions.append(
                    f"{scenario} {metric}: {value:.6g} vs limit "
                    f"{limit:.6g} (median {med:.6g}, "
                    f"x{value / med:.2f})" if med else
                    f"{scenario} {metric}: {value:.6g} vs {limit:.6g}")
    return dict(
        candidate=candidate.get("label"),
        baselines=[r.get("label") for r in baselines],
        quick=quick, rows=rows, regressions=regressions,
        ok=not regressions,
        counts={s: sum(1 for r in rows if r["status"] == s)
                for s in ("ok", "regression", "improved", "skipped")})


def inject_slowdown(doc: dict, *, label: Optional[str] = None,
                    metric: str = "wall_s", factor: float = 2.0) -> dict:
    """A deep copy of ``doc`` with the candidate's ``metric`` scaled by
    ``factor`` — the synthetic regression the gate must catch."""
    out = copy.deepcopy(doc)
    candidate = _pick_candidate(out, label)
    for entry in candidate.get("entries", []):
        if metric in entry and entry[metric] is not None:
            entry[metric] = float(entry[metric]) * factor
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.regress",
        description="noise-aware perf-regression gate over the "
                    "benchmark trajectory")
    ap.add_argument("--json", default="BENCH_power_psi.json",
                    help="benchmark trajectory document")
    ap.add_argument("--label", default=None,
                    help="candidate run label (default: newest run)")
    ap.add_argument("--out", default=None,
                    help="write the verdict JSON here")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: widen the timing floors 2x")
    ap.add_argument("--min-baselines", type=int, default=1)
    ap.add_argument("--self-check", action="store_true",
                    help="also prove the gate catches a synthetic 2x "
                         "wall_s slowdown (exit 2 if it does not)")
    args = ap.parse_args(argv)

    doc = load_doc(args.json)
    verdict = gate(doc, label=args.label, quick=args.quick,
                   min_baselines=args.min_baselines)
    c = verdict["counts"]
    print(f"[regress] candidate={verdict['candidate']} "
          f"baselines={verdict['baselines']}")
    print(f"[regress] {c['ok']} ok, {c['improved']} improved, "
          f"{c['skipped']} skipped, {c['regression']} regression(s)")
    for line in verdict["regressions"]:
        print(f"[regress] REGRESSION: {line}")

    if args.self_check:
        injected = gate(inject_slowdown(doc, label=args.label),
                        label=args.label, quick=args.quick,
                        min_baselines=args.min_baselines)
        caught = [r for r in injected["rows"]
                  if r["metric"] == "wall_s"
                  and r["status"] == "regression"]
        verdict["self_check"] = dict(
            injected="wall_s x2.0", caught=len(caught),
            example=(injected["regressions"][0]
                     if injected["regressions"] else None))
        if caught:
            print(f"[regress] self-check: injected 2x wall_s slowdown "
                  f"caught in {len(caught)} scenario(s), e.g. "
                  f"{injected['regressions'][0]}")
        else:
            print("[regress] SELF-CHECK FAILED: injected slowdown "
                  "was not caught", file=sys.stderr)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(verdict, f, indent=1)
        print(f"[regress] verdict -> {args.out}")

    if args.self_check and not verdict["self_check"]["caught"]:
        return 2
    return 0 if verdict["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
