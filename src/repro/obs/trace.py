"""Pipeline spans, the shared span clock, and the retrace guard.

Every duration the stack reports — chunk deadlines, query latencies,
resolve walls — is measured on ONE clock: :func:`now` (``perf_counter``),
read through :class:`Span`. A :class:`Span` always measures (two ``now()``
reads), and *emits* only when a live :class:`Tracer` is installed, so
``DriverReport.chunk_durations`` and the trace file can never disagree
about the same chunk: they are the same measurement.

JAX-aware timing: device work is dispatched asynchronously, so the wall
around a jitted call conflates host dispatch with device compute. Calling
:meth:`Span.sync` on the result splits them — host time up to the sync
point (``dispatch_s``) vs the ``block_until_ready`` wait (``sync_s``) —
and guarantees the span's total duration covers the compute, exactly like
the explicit ``block_until_ready`` the drivers used before.

Spans nest through a per-thread stack (each records its parent id + depth)
and are thread-safe: the async scheduler's workers each carry their own
stack, and completed spans funnel through one writer lock into a
replayable JSONL log plus an in-memory ring for the Chrome
``trace_event`` export (:meth:`Tracer.export_chrome` →
chrome://tracing / Perfetto).

:func:`retrace_guard` wraps a jitted entry point and counts *silent
recompiles* (the jit cache growing past its first entry — e.g. the known
``patch_edges`` format-rebuild retrace), surfacing them as the
``psi_retraces_total`` counter and a structured warning event.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time
from collections import deque

from . import metrics

__all__ = ["now", "Span", "Tracer", "NULL_TRACER", "get_tracer",
           "set_tracer", "span", "retrace_guard", "RetraceGuard"]

#: the shared span clock — monotonic seconds; every instrumented duration
#: in the repo is a difference of two now() reads
now = time.perf_counter

_TLS = threading.local()
_IDS = itertools.count(1)


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


class Span:
    """One timed region. Always measures; emits only when ``tracer`` is a
    live :class:`Tracer`. Use as a context manager:

        with span("resolve", tenant="acme") as sp:
            out = solve()
            sp.sync(out)          # dispatch/compute split (optional)
        sp.duration_s             # total, on the shared clock
    """

    __slots__ = ("name", "attrs", "tracer", "t0", "t1", "dispatch_s",
                 "sync_s", "span_id", "parent_id", "depth", "thread")

    def __init__(self, name: str, tracer, attrs: dict):
        self.name = name
        self.tracer = tracer
        self.attrs = attrs
        self.t0 = self.t1 = None
        self.dispatch_s = None
        self.sync_s = None
        self.span_id = next(_IDS)
        self.parent_id = None
        self.depth = 0
        self.thread = threading.current_thread().name

    def __enter__(self) -> "Span":
        st = _stack()
        if st:
            self.parent_id = st[-1].span_id
            self.depth = len(st)
        st.append(self)
        self.t0 = now()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.t1 = now()
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        elif self in st:                  # unbalanced exit (exception path)
            st.remove(self)
        if self.tracer is not None:
            self.tracer._finish(self, error=exc_type is not None)
        return False

    def sync(self, value):
        """Block until ``value``'s device buffers are ready, recording the
        dispatch/compute split; returns ``value`` unchanged."""
        t_sync = now()
        try:
            import jax
            jax.block_until_ready(value)
        except ImportError:                        # pragma: no cover
            pass
        self.dispatch_s = t_sync - self.t0
        self.sync_s = now() - t_sync
        return value

    @property
    def duration_s(self) -> float:
        """Elapsed seconds on the shared clock (live if not yet exited)."""
        return (now() if self.t1 is None else self.t1) - self.t0


class Tracer:
    """Span sink: JSONL writer + bounded in-memory ring.

    Args:
      jsonl_path: append each completed span as one JSON line (replayable;
        None keeps spans in memory only).
      keep: ring size for :attr:`spans` / :meth:`export_chrome`.
    """

    enabled = True

    def __init__(self, jsonl_path: str | None = None, *, keep: int = 8192):
        self._lock = threading.Lock()
        self.jsonl_path = jsonl_path
        self._file = open(jsonl_path, "a") if jsonl_path else None
        self.spans: deque[dict] = deque(maxlen=keep)
        self.t_origin = now()
        self.dropped = 0

    def span(self, name: str, **attrs) -> Span:
        return Span(name, self, attrs)

    def _finish(self, sp: Span, *, error: bool = False) -> None:
        rec = dict(name=sp.name, id=sp.span_id, parent=sp.parent_id,
                   depth=sp.depth, thread=sp.thread,
                   ts=sp.t0 - self.t_origin, dur=sp.t1 - sp.t0)
        if sp.dispatch_s is not None:
            rec["dispatch_s"] = sp.dispatch_s
            rec["sync_s"] = sp.sync_s
        if error:
            rec["error"] = True
        if sp.attrs:
            rec["attrs"] = sp.attrs
        with self._lock:
            if len(self.spans) == self.spans.maxlen:
                self.dropped += 1
            self.spans.append(rec)
            if self._file is not None:
                try:
                    self._file.write(json.dumps(rec, default=str) + "\n")
                except (TypeError, ValueError):    # unserializable attr
                    rec.pop("attrs", None)
                    self._file.write(json.dumps(rec) + "\n")

    def flush(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.flush()

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None

    def export_chrome(self, path: str) -> str:
        """Write the retained spans as a Chrome ``trace_event`` file
        (load in chrome://tracing or https://ui.perfetto.dev)."""
        pid = os.getpid()
        with self._lock:
            spans = list(self.spans)
        events = []
        tids = {}
        for rec in spans:
            tid = tids.setdefault(rec["thread"], len(tids) + 1)
            events.append(dict(
                name=rec["name"], ph="X", pid=pid, tid=tid,
                ts=rec["ts"] * 1e6, dur=rec["dur"] * 1e6,
                args={**rec.get("attrs", {}),
                      **({"dispatch_s": rec["dispatch_s"],
                          "sync_s": rec["sync_s"]}
                         if "dispatch_s" in rec else {})}))
        meta = [dict(name="thread_name", ph="M", pid=pid, tid=t,
                     args={"name": thread}) for thread, t in tids.items()]
        with open(path, "w") as f:
            json.dump(dict(traceEvents=meta + events,
                           displayTimeUnit="ms"), f, default=str)
        return path


class _NullTracer:
    """Spans still measure (drivers consume ``duration_s``) but nothing is
    recorded — the tracing-disabled default."""

    enabled = False

    def span(self, name: str, **attrs) -> Span:
        return Span(name, None, attrs)


NULL_TRACER = _NullTracer()
_TRACER = NULL_TRACER


def get_tracer():
    return _TRACER


def set_tracer(tracer):
    """Install the process tracer (NULL_TRACER disables); returns the
    previous one."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def span(name: str, **attrs) -> Span:
    """A span on the process tracer — the one instrumentation entry point."""
    return _TRACER.span(name, **attrs)


# --------------------------------------------------------------------- #
# Retrace guard
# --------------------------------------------------------------------- #
class RetraceGuard:
    """Callable wrapper counting silent recompiles of a jitted function.

    The first compile is expected (cache 0 → 1 per distinct signature seen
    up front is normal); any *growth after the first call* is a retrace —
    typically a shape change from a format rebuild (the known
    ``patch_edges`` retrace) or an accidental non-weak type promotion.
    Each one increments ``psi_retraces_total{fn=...}`` and logs a
    structured ``retrace`` warning event (:mod:`repro.obs.log`).
    """

    def __init__(self, fn, name: str | None = None, *, warn: bool = True):
        self.fn = fn
        self.name = name or getattr(fn, "__name__", "jit_fn")
        self.warn = warn
        self.retraces = 0
        self._last_size: int | None = None
        self.__name__ = f"retrace_guard({self.name})"

    def _cache_size(self) -> int | None:
        probe = getattr(self.fn, "_cache_size", None)
        if probe is None:
            return None
        try:
            return int(probe())
        except Exception:                          # pragma: no cover
            return None

    def __call__(self, *args, **kwargs):
        out = self.fn(*args, **kwargs)
        size = self._cache_size()
        if size is not None:
            prev, self._last_size = self._last_size, size
            if prev is not None and size > prev:
                self.retraces += size - prev
                metrics.counter(
                    "psi_retraces_total",
                    "silent jit recompiles caught by retrace_guard",
                    labelnames=("fn",)).labels(fn=self.name).inc(size - prev)
                from . import log
                log.event("retrace",
                          f"{self.name} silently recompiled "
                          f"(jit cache {prev} -> {size})",
                          level="warning" if self.warn else "info",
                          fn=self.name, cache_size=size)
        return out

    def __getattr__(self, item):                   # passthrough (lower, ...)
        return getattr(self.fn, item)


def retrace_guard(fn, name: str | None = None, *,
                  warn: bool = True) -> RetraceGuard:
    """Wrap a jitted entry point; see :class:`RetraceGuard`."""
    return RetraceGuard(fn, name, warn=warn)
