from . import checkpoint
