"""Sharded, atomic checkpointing (no orbax in the container — built here).

Layout:  <dir>/step_<n>/host_<i>.npz  +  <dir>/step_<n>/MANIFEST.json
Writes go to ``step_<n>.tmp`` and are renamed only after the manifest is
fsynced — a torn write can never be mistaken for a valid checkpoint, so
restart always finds the last *complete* step (checkpoint/restart
correctness under mid-write failure is tested in tests/test_runtime.py).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "all_steps"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(directory: str, step: int, tree, *, host: int = 0,
         keep: int = 3) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, f"host_{host}.npz"), **flat)
    manifest = dict(step=step, hosts=[host], keys=sorted(flat),
                    shapes={k: list(v.shape) for k, v in flat.items()})
    mpath = os.path.join(tmp, "MANIFEST.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = all_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
                out.append(int(name.removeprefix("step_")))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, template, *, host: int = 0):
    """Restore into the structure of ``template`` (shapes validated)."""
    path = os.path.join(directory, f"step_{step:08d}", f"host_{host}.npz")
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for p, leaf in leaves:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(
        treedef, [l for _, l in zip(leaves, new_leaves)]) if False else \
        jax.tree_util.tree_unflatten(treedef, new_leaves)
