"""Sharded, atomic checkpointing (no orbax in the container — built here).

Layout:  <dir>/step_<n>/host_<i>.npz  +  <dir>/step_<n>/MANIFEST.json
Writes go to ``step_<n>.tmp`` and are renamed only after the manifest is
fsynced — a torn write can never be mistaken for a valid checkpoint, so
restart always finds the last *complete* step (checkpoint/restart
correctness under mid-write failure is tested in tests/test_runtime.py).

Corruption + concurrency hardening (docs/RESILIENCE.md):

* :func:`latest_step` only reports *complete* steps — the manifest must
  parse as JSON and every host shard it lists must exist on disk. A
  truncated manifest or a missing ``host_*.npz`` demotes that step with a
  warning (never an exception) and the previous complete step serves.
* :func:`restore_latest` walks complete steps newest-first and falls back
  on *any* load failure — including the race where a concurrent
  ``save(keep=…)`` GC pruned the step between ``latest_step`` and the
  ``np.load`` (tests/test_resilience.py covers the interleaving).
* :func:`restore` (explicit step) still raises: a caller naming a step
  wants that step or an error, and a shape mismatch against the template
  is a caller bug, not corruption.
"""
from __future__ import annotations

import json
import os
import shutil
import zipfile

import jax
import numpy as np

from ..obs import log as obs_log

__all__ = ["save", "restore", "restore_latest", "latest_step", "all_steps",
           "complete_steps", "load_arrays"]

#: exceptions that mean "this step is corrupt / torn / concurrently pruned"
#: rather than a caller bug — the fallback walkers skip on exactly these
_CORRUPT_ERRORS = (OSError, EOFError, KeyError, ValueError,
                   json.JSONDecodeError, zipfile.BadZipFile)


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def save(directory: str, step: int, tree, *, host: int = 0,
         keep: int = 3) -> str:
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, f"host_{host}.npz"), **flat)
    manifest = dict(step=step, hosts=[host], keys=sorted(flat),
                    shapes={k: list(v.shape) for k, v in flat.items()})
    mpath = os.path.join(tmp, "MANIFEST.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = all_steps(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    """Every step directory with a MANIFEST.json *present* (not validated —
    the GC uses this; readers should prefer :func:`complete_steps`)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "MANIFEST.json")):
                out.append(int(name.removeprefix("step_")))
    return sorted(out)


def _is_complete(directory: str, step: int) -> bool:
    """A step is complete when its manifest parses and every host shard it
    lists exists. Truncated manifests and missing ``host_*.npz`` (torn
    writes on filesystems without atomic rename, partial copies, …) fail
    here and are skipped by the readers instead of raising."""
    base = os.path.join(directory, f"step_{step:08d}")
    try:
        with open(os.path.join(base, "MANIFEST.json")) as f:
            manifest = json.load(f)
        hosts = manifest.get("hosts", [0])
        return all(os.path.exists(os.path.join(base, f"host_{h}.npz"))
                   for h in hosts)
    except _CORRUPT_ERRORS:
        return False


def complete_steps(directory: str) -> list[int]:
    """Steps whose manifest parses and whose host shards all exist."""
    return [s for s in all_steps(directory) if _is_complete(directory, s)]


def latest_step(directory: str) -> int | None:
    """The newest *complete* step (corrupt/truncated steps are skipped with
    a warning — restart falls back to the previous good one, it never
    crashes on a torn manifest)."""
    for s in reversed(all_steps(directory)):
        if _is_complete(directory, s):
            return s
        obs_log.warn(
            "ckpt_corrupt_step",
            f"checkpoint step {s} in {directory} is corrupt or incomplete "
            "(unparseable MANIFEST.json or missing host shard); falling "
            "back to the previous complete step", category=RuntimeWarning,
            stacklevel=3, step=int(s), directory=directory)
    return None


def load_arrays(directory: str, step: int, *, host: int = 0
                ) -> dict[str, np.ndarray]:
    """The flat ``key → array`` mapping of one host shard, template-free
    (keys are the ``/``-joined tree paths :func:`save` flattened). The
    whole-stack recovery path (repro.resilience.recovery) reconstructs
    mutable host state from this — shapes there are data, not a template."""
    path = os.path.join(directory, f"step_{step:08d}", f"host_{host}.npz")
    with np.load(path) as data:
        return {k: data[k].copy() for k in data.files}


def restore(directory: str, step: int, template, *, host: int = 0):
    """Restore into the structure of ``template`` (shapes validated).

    Raises on a missing/corrupt step or a shape mismatch — callers naming
    an explicit step want that step or an error. Use :func:`restore_latest`
    for the fall-back-to-previous-complete-step behavior."""
    path = os.path.join(directory, f"step_{step:08d}", f"host_{host}.npz")
    data = np.load(path)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    new_leaves = []
    for p, leaf in leaves:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = data[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_latest(directory: str, template, *, host: int = 0):
    """Restore the newest step that actually loads, walking backwards.

    Any load failure — corrupt manifest, truncated npz, a shape that no
    longer matches the template, or the step vanishing because a
    concurrent ``save(keep=…)`` GC pruned it between listing and load —
    demotes that step with a warning and the walk continues. Returns the
    restored tree, or None when no step could be restored."""
    for s in reversed(all_steps(directory)):
        if not _is_complete(directory, s):
            obs_log.warn(
                "ckpt_corrupt_step",
                f"checkpoint step {s} in {directory} is corrupt or "
                "incomplete; trying the previous step",
                category=RuntimeWarning, stacklevel=3,
                step=int(s), directory=directory)
            continue
        try:
            return restore(directory, s, template, host=host)
        except _CORRUPT_ERRORS as e:
            # includes the GC race: _is_complete saw the step, the rmtree
            # landed before np.load — FileNotFoundError is an OSError
            obs_log.warn(
                "ckpt_load_failed",
                f"checkpoint step {s} in {directory} failed to load "
                f"({type(e).__name__}: {e}); trying the previous step",
                category=RuntimeWarning, stacklevel=3,
                step=int(s), directory=directory,
                error=type(e).__name__)
    return None
