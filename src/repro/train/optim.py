"""Pure-JAX optimizers (no optax in the container — built per scope rule).

All optimizers share one interface:

    opt = adamw(schedule, ...)
    state = opt.init(params)
    params, state = opt.apply(grads, state, params)

State pytrees mirror the param tree so pjit shards them identically to the
parameters (critical for the memory budget of the big dry-run cells —
Adafactor is the default for ≥100B configs, AdamW elsewhere; DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "sgd", "adamw", "adafactor", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_schedule",
           "constant_schedule"]

Schedule = Callable[[jax.Array], jax.Array]


# --------------------------------------------------------------------- #
# Schedules
# --------------------------------------------------------------------- #
def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_schedule(lr: float, total_steps: int, warmup: int = 0) -> Schedule:
    def f(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / max(1, warmup))
        decay = jnp.maximum(0.0, 1.0 - (step - warmup) /
                            max(1, total_steps - warmup))
        return lr * warm * jnp.where(step <= warmup, 1.0, decay)
    return f


def cosine_schedule(lr: float, total_steps: int, warmup: int = 0,
                    final_frac: float = 0.1) -> Schedule:
    def f(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, step / max(1, warmup))
        t = jnp.clip((step - warmup) / max(1, total_steps - warmup), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * warm * cos
    return f


# --------------------------------------------------------------------- #
# Utilities
# --------------------------------------------------------------------- #
def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    apply: Callable[[Any, Any, Any], tuple[Any, Any]]
    name: str = "opt"


# --------------------------------------------------------------------- #
# SGD (+momentum)
# --------------------------------------------------------------------- #
def sgd(schedule: Schedule, momentum: float = 0.9,
        clip_norm: float | None = None) -> Optimizer:
    def init(params):
        return dict(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                   params))

    def apply(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        lr = schedule(state["step"])
        m = jax.tree.map(lambda m_, g: momentum * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        params = jax.tree.map(lambda p, m_: (p.astype(jnp.float32) - lr * m_
                                             ).astype(p.dtype), params, m)
        return params, dict(step=state["step"] + 1, m=m)

    return Optimizer(init, apply, "sgd")


# --------------------------------------------------------------------- #
# AdamW with fp32 master weights when params are low precision
# --------------------------------------------------------------------- #
def adamw(schedule: Schedule, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float | None = 1.0,
          keep_master: bool = True) -> Optimizer:
    def init(params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = dict(step=jnp.zeros((), jnp.int32),
                     m=jax.tree.map(zeros32, params),
                     v=jax.tree.map(zeros32, params))
        if keep_master:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return state

    def apply(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr = schedule(step)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            master = master - lr * (u + weight_decay * master)
            return m, v, master

        masters = state.get("master") or jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
        out = jax.tree.map(upd, grads, state["m"], state["v"], masters)
        m = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        master = jax.tree.map(lambda o: o[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        params = jax.tree.map(lambda p, mst: mst.astype(p.dtype),
                              params, master)
        new_state = dict(step=step, m=m, v=v)
        if keep_master:
            new_state["master"] = master
        return params, new_state

    return Optimizer(init, apply, "adamw")


# --------------------------------------------------------------------- #
# Adafactor (factored second moment — the ≥100B-param default)
# --------------------------------------------------------------------- #
def adafactor(schedule: Schedule, eps: float = 1e-30,
              clip_threshold: float = 1.0, decay: float = 0.8,
              weight_decay: float = 0.0,
              clip_norm: float | None = 1.0) -> Optimizer:
    def _is_factored(p):
        return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8

    def init(params):
        def per_param(p):
            if _is_factored(p):
                return dict(vr=jnp.zeros(p.shape[:-1], jnp.float32),
                            vc=jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                         jnp.float32))
            return dict(v=jnp.zeros(p.shape, jnp.float32))
        return dict(step=jnp.zeros((), jnp.int32),
                    stats=jax.tree.map(per_param, params))

    def apply(grads, state, params):
        if clip_norm is not None:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr = schedule(step)
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, stats, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in stats:
                vr = beta * stats["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * stats["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] /
                    (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + eps))
                new_stats = dict(vr=vr, vc=vc)
            else:
                v = beta * stats["v"] + (1 - beta) * g2
                denom = jnp.sqrt(v)
                new_stats = dict(v=v)
            u = g / jnp.maximum(denom, eps)
            # update clipping (Adafactor's RMS rule)
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            p32 = p.astype(jnp.float32)
            p32 = p32 - lr * (u + weight_decay * p32)
            return p32.astype(p.dtype), new_stats

        out = jax.tree.map(upd, grads, state["stats"], params)
        params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        stats = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return params, dict(step=step, stats=stats)

    return Optimizer(init, apply, "adafactor")
