from .optim import (Optimizer, sgd, adamw, adafactor, global_norm,
                    clip_by_global_norm, cosine_schedule, linear_schedule,
                    constant_schedule)
