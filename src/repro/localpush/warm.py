"""O(Δ) residual reseeding — warm restarts that never pay a mat-vec.

A converged push state (x, r≈0, p) plus a platform patch is *almost* a
valid state for the new operators: ``x`` is still a fine iterate, but the
invariant ``r = c + μ ⊙ p − x`` now refers to the patched (c, μ, w, E).
Each helper here applies the corresponding :class:`HostOperators` patch
AND repairs ``(r, p)`` exactly, touching only the affected subgraph:

* activity patch on users U — ``w`` changes at U's followers F, so
  ``p`` changes at the leaders reachable from F (``Δp_i = Σ_f x_f·Δ(1/w_f)``
  over F's out-edges) and ``r`` changes where ``c``, ``μ·p`` moved:
  ``r += Δc + Δ(μ ⊙ p)`` over ``U ∪ heads(F)``.
* edge insert/remove at followers J — retract J's old out-edge
  contributions ``x_j/w_j^old`` and scatter the new ones ``x_j/w_j^new``
  (``c``/``μ`` are untouched, so ``Δr = μ ⊙ Δp``).

Cost: O(Δ · deg) edge work + O(|affected|) vector work — this is the
"resolve after a flash crowd touches the affected subgraph only" path the
:class:`~repro.stream.ingest.StreamIngestor` drains
:meth:`~repro.stream.estimator.RateEstimator` dirty sets into. All
arithmetic is float64 on the host mirror, so repeated patches do not
erode the certificate (see the precision note in
:mod:`repro.localpush.push`).
"""
from __future__ import annotations

import numpy as np

from ..core.operators import HostOperators, _concat_ranges
from .push import PushState, _masked_inv

__all__ = ["apply_activity_patch", "apply_edge_insert", "apply_edge_remove"]


def _out_edges(host: HostOperators,
               nodes: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(heads, counts): concatenated leader lists of ``nodes`` (src-sorted
    spans), copied out so they survive a subsequent edge mutation."""
    lo = np.searchsorted(host.src_by_src, nodes, side="left")
    hi = np.searchsorted(host.src_by_src, nodes, side="right")
    counts = (hi - lo).astype(np.int64)
    return host.dst_by_src[_concat_ranges(lo, hi)].copy(), counts


def _c_at(host: HostOperators, idx: np.ndarray) -> np.ndarray:
    total = host.lam[idx] + host.mu[idx]
    with np.errstate(divide="ignore", invalid="ignore"):
        return np.where(total > 0, host.mu[idx] / total, 0.0)


def _scatter(host: HostOperators, state: PushState,
             heads: np.ndarray, vals: np.ndarray) -> None:
    """Δp at ``heads`` plus the induced Δr = μ ⊙ Δp (duplicate-safe)."""
    if heads.size:
        np.add.at(state.p, heads, vals)
        np.add.at(state.r, heads, host.mu[heads] * vals)


def apply_activity_patch(host: HostOperators, state: PushState, users,
                         lam=None, mu=None) -> int:
    """``host.patch_activity`` + exact (r, p) repair; returns edges touched."""
    uniq = np.unique(np.asarray(users, np.int64).reshape(-1))
    if uniq.size == 0:
        return 0
    # followers of the updated users: contiguous dst-sorted slices
    lo = np.searchsorted(host.dst_by_dst, uniq, side="left")
    hi = np.searchsorted(host.dst_by_dst, uniq, side="right")
    followers = np.unique(host.src_by_dst[_concat_ranges(lo, hi)])
    # activity patches never move edges, so F's out-spans are stable across
    # the patch — snapshot only the reciprocals that will change
    heads, counts = _out_edges(host, followers)
    old_inv = _masked_inv(host.w[followers])
    affected = np.unique(np.concatenate([uniq, heads])) if heads.size else uniq
    old_c = _c_at(host, affected)
    old_mu_p = host.mu[affected] * state.p[affected]

    touched = host.patch_activity(users, lam=lam, mu=mu)

    if heads.size:
        dinv = _masked_inv(host.w[followers]) - old_inv
        np.add.at(state.p, heads, np.repeat(state.x[followers] * dinv,
                                            counts))
    state.r[affected] += ((_c_at(host, affected) - old_c)
                          + host.mu[affected] * state.p[affected] - old_mu_p)
    return touched


def apply_edge_insert(host: HostOperators, state: PushState, src, dst
                      ) -> tuple[np.ndarray, np.ndarray]:
    """``host.patch_edges`` + exact (r, p) repair; returns edges inserted."""
    src_k, dst_k = host.filter_new_edges(src, dst)
    if src_k.size == 0:
        return src_k, dst_k
    J = np.unique(src_k).astype(np.int64)
    old_heads, old_counts = _out_edges(host, J)
    old_inv = _masked_inv(host.w[J])

    host.insert_filtered(src_k, dst_k)

    new_heads, new_counts = _out_edges(host, J)
    new_inv = _masked_inv(host.w[J])
    xj = state.x[J]
    # retract j's contributions at the old weight, emit at the new one
    _scatter(host, state, old_heads, np.repeat(-xj * old_inv, old_counts))
    _scatter(host, state, new_heads, np.repeat(xj * new_inv, new_counts))
    return src_k, dst_k


def apply_edge_remove(host: HostOperators, state: PushState, src, dst
                      ) -> tuple[np.ndarray, np.ndarray]:
    """``host.remove_edges`` + exact (r, p) repair; returns edges removed."""
    cand = np.unique(np.asarray(src, np.int64).reshape(-1))
    if cand.size == 0:
        return (np.empty(0, np.int32),) * 2
    # tombstones may miss; snapshot every candidate's span, filter later
    cand_heads, cand_counts = _out_edges(host, cand)
    cand_inv = _masked_inv(host.w[cand])

    rem_src, rem_dst = host.remove_edges(src, dst)
    if rem_src.size == 0:
        return rem_src, rem_dst

    hit = np.isin(cand, np.unique(rem_src))
    row = np.repeat(np.arange(cand.size), cand_counts)
    keep = hit[row]
    # only actually-shrunk followers scatter: a float retract-and-re-emit
    # of an untouched span would not cancel bitwise and would erode r
    _scatter(host, state, cand_heads[keep],
             (np.repeat(state.x[cand] * -cand_inv, cand_counts))[keep])
    J = cand[hit]
    new_heads, new_counts = _out_edges(host, J)
    _scatter(host, state, new_heads,
             np.repeat(state.x[J] * _masked_inv(host.w[J]), new_counts))
    return rem_src, rem_dst
