"""Certified top-k early stop from the push residual.

The push certificate gives per-node confidence intervals
``ψ_i ∈ [ψ̂_i − E_i, ψ̂_i + E_i]`` (uniform-E from
:func:`repro.localpush.push.cert_scale`, or the tighter per-node radii
from :func:`repro.localpush.push.neumann_error_bound`). The top-k *set*
is exact as soon as every member's lower bound clears every non-member's
upper bound:

    min_{i ∈ top-k} (ψ̂_i − E_i)  >  max_{j ∉ top-k} (ψ̂_j + E_j)
    ⇒  {top-k of ψ̂} = {top-k of ψ_exact}.

With a uniform bound this reduces to the classic margin test
``ψ̂_(k) − ψ̂_(k+1) > 2E``. A ``top_k`` query can stop pushing at that
separation — typically long before the global tolerance — which is the
query-driven termination rule the resource-constrained influence
literature argues for. Note the guarantee is on the *set*; the internal
order of near-tied members may still differ at margins within their
interval widths.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["TopKCertificate", "certify_top_k"]


@dataclasses.dataclass(frozen=True)
class TopKCertificate:
    """Outcome of one rank-separation check against the residual bound."""

    k: int
    indices: np.ndarray       # i64[k] — ψ̂-descending (stable tie-break)
    values: np.ndarray        # f64[k] — ψ̂ at those indices
    err_bound: float | None   # max per-node |ψ_i − ψ̂_i| radius (None: unknown)
    margin: float             # ψ̂_(k) − ψ̂_(k+1) (inf when k ≥ N)
    certified: bool           # intervals separate — top-k set is exact

    def __post_init__(self):
        object.__setattr__(self, "indices",
                           np.asarray(self.indices, np.int64))
        object.__setattr__(self, "values",
                           np.asarray(self.values, np.float64))


def certify_top_k(psi: np.ndarray, k: int,
                  err_bound) -> TopKCertificate:
    """Rank-separation check on the current ψ̂ estimate.

    ``err_bound`` is a scalar uniform per-node error bound, an ``f[N]``
    array of per-node radii, or ``None``; ``None`` (or any non-finite
    radius) means no bound is available and the result cannot certify —
    the indices are still the best current estimate. The reported
    ``err_bound`` field is the max radius.
    """
    psi = np.asarray(psi, np.float64).reshape(-1)
    n = psi.size
    k = max(0, min(int(k), n))
    radii: np.ndarray | None = None
    if err_bound is not None:
        radii = np.broadcast_to(
            np.asarray(err_bound, np.float64), (n,))
    bounded = radii is not None and bool(np.isfinite(radii).all())
    worst = float(radii.max(initial=0.0)) if bounded else None
    if k == 0:
        return TopKCertificate(0, np.empty(0, np.int64), np.empty(0),
                               worst, math.inf, bounded)
    if k >= n:
        order = np.lexsort((np.arange(n), -psi))
        return TopKCertificate(k, order, psi[order],
                               worst, math.inf, bounded)   # whole set
    top = np.argpartition(-psi, k - 1)[:k]
    order = top[np.lexsort((top, -psi[top]))]
    mask = np.ones(n, bool)
    mask[top] = False
    margin = float(psi[order[-1]] - psi[mask].max())
    certified = bool(
        bounded
        and (psi[order] - radii[order]).min()
        > (psi[mask] + radii[mask]).max())
    return TopKCertificate(k, order, psi[order], worst, margin, certified)
