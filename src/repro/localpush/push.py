"""Gauss-Southwell forward push on the Eq. 19 residual — local ψ solves.

Power-ψ iterates the affine contraction ``s ← M s + c`` with
``M[i, j] = μ_i / w_j`` for each follow edge (j → i) (the column form of
``sᵀ ← sᵀA + cᵀ``). Instead of sweeping all N coordinates per iteration,
forward push maintains the *residual decomposition*

    s* = x + (I − M)⁻¹ r          (push invariant)

where ``x`` is the settled part and ``r`` is unpushed mass. Pushing a
follower ``j`` moves its residual into ``x`` and forwards the discounted
remainder to the leaders it follows:

    x_j += r_j;   r_i += μ_i · r_j / w_j   for every i ∈ L(j);   r_j = 0.

Each push strictly shrinks ``‖r‖₁`` by at least ``(1 − α)·|r_j|`` with

    α = ‖M‖₁ = max_j (w_j − Σ_{i∈L(j)} λ_i) / w_j  < 1,

so work concentrates where residual actually lives — after a localized
patch that is the affected subgraph, not the platform.

Certificate (the running Eq. 19-style bound): with the companion vector
``p = push(x)`` (``p_i = Σ_{(j→i)} x_j / w_j``), the served scores are
``ψ̂ = (λ ⊙ p + d)/N`` — an O(N) read, no mat-vec — and

    ‖ψ_exact − ψ̂‖₁ ≤ ‖B‖₁ · ‖r‖₁ / ((1 − α) · N)

(hence per-node too, since l∞ ≤ l1). ``p`` rides the same scatter as ``r``
during pushes (``p_i += r_j / w_j``), which is what makes the certificate
and the certified top-k check (:mod:`repro.localpush.topk`) free of O(M)
work.

Precision: the push state is float64 numpy regardless of the engine's
device dtype. The residual recurrence contracts geometrically with no
floor, but a float32 ``x`` accumulation (or a float32 warm reseed
``r = c + M x − x``, which cancels catastrophically) would make the
certificate anti-conservative near tight tolerances — exactly what a
*certificate* must never be.

Two frontier drivers share the elementary batched push
(:func:`push_nodes`):

* :func:`push_round` — one bucketed round: push every node whose ``|r|``
  is within ``bucket_ratio`` of the current max (a frexp-style magnitude
  bucket — no heap, no per-push priority maintenance).
* :func:`push_scalar` — the pure-Python bucket-queue Gauss-Southwell
  loop, kept as the parity oracle for the vectorized and jitted paths.

:func:`make_frontier_loop` compiles a fixed-frontier-size batched round
(``lax.top_k`` + padded out-edge gather + one segment scatter) into a
``lax.while_loop`` so the inner loop is not Python-bound; its float32
iterate is always re-verified on the host in float64 before any
certificate is emitted (see ``PushEngine``).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.operators import HostOperators

__all__ = ["PushState", "cold_state", "reseed_state", "a_norm", "cert_scale",
           "psi_value", "l1", "push_nodes", "push_round", "push_until",
           "push_scalar", "FrontierOps", "build_frontier_ops",
           "make_frontier_loop"]


# --------------------------------------------------------------------- #
# State + invariant helpers
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class PushState:
    """Mutable float64 push state; arrays are updated in place.

    Invariants (checked by tests/test_localpush.py after every patch):
      * ``s* = x + (I − M)⁻¹ r``
      * ``p = push(x)``  and therefore  ``r = c + μ ⊙ p − x``.
    """

    x: np.ndarray   # f64[N] settled series mass
    r: np.ndarray   # f64[N] unpushed residual
    p: np.ndarray   # f64[N] = push(x), maintained alongside r

    def copy(self) -> "PushState":
        return PushState(self.x.copy(), self.r.copy(), self.p.copy())


def _masked_inv(w: np.ndarray) -> np.ndarray:
    return np.where(w > 0, 1.0 / np.where(w > 0, w, 1.0), 0.0)


def l1(v: np.ndarray) -> float:
    return float(np.abs(v).sum())


def a_norm(host: HostOperators) -> float:
    """α = ‖M‖₁ = max_j (w_j − row_lam_j)/w_j — the push contraction rate.

    Strictly < 1 iff every non-empty news feed carries some λ mass
    (``row_lam_j > 0`` wherever ``w_j > 0``); α = 1 makes the residual
    certificate vacuous, so callers must reject it at prepare time.
    """
    if host.n == 0:
        return 0.0
    return float(((host.w - host.row_lam) * host.inv_w).max())


def cert_scale(host: HostOperators, alpha: float | None = None) -> float:
    """‖B‖₁ / ((1 − α)·N): multiply by ‖r‖₁ for the ψ l1/l∞ error bound."""
    alpha = a_norm(host) if alpha is None else float(alpha)
    if alpha >= 1.0:
        return math.inf
    return host.b_norm / ((1.0 - alpha) * max(1, host.n))


def pernode_cert_scale(host: HostOperators) -> np.ndarray:
    """f64[N] per-node certificate prefactor ρ with ``|ψ_i − ψ̂_i| ≤ ρ_i·S``.

    From ``δψ_i = λ_i/N · Σ_{j→i} δs_j/w_j`` (sum over i's followers j):

        |δψ_i| ≤ λ_i/N · min(g_i·‖δs‖∞, h_i·‖δs‖₁) ≤ λ_i·min(g_i, h_i)/N · S

    with ``g_i = Σ_{j→i} 1/w_j``, ``h_i = max_{j→i} 1/w_j`` and ``S`` any
    upper bound on ``‖δs‖₁`` (:func:`neumann_error_bound` supplies the
    tight one). A node followed by nobody has ρ_i = 0 — its ψ̂ is exact.
    """
    n = host.n
    if n == 0:
        return np.zeros(0)
    g = np.zeros(n)
    h = np.zeros(n)
    contrib = host.inv_w[host.src_by_dst]
    np.add.at(g, host.dst_by_dst, contrib)
    np.maximum.at(h, host.dst_by_dst, contrib)
    return host.lam * np.minimum(g, h) / n


def apply_abs_M(host: HostOperators, v: np.ndarray
                ) -> tuple[np.ndarray, int]:
    """``M·v`` for non-negative ``v``, touching only supp(v)'s out-edges.

    Returns ``(Mv, edge_work)``; the cost is the out-degree sum of v's
    support — O(Δ-neighborhood) while the residual is local, one full
    mat-vec at worst.
    """
    idx = np.nonzero(v)[0]
    out = np.zeros(host.n)
    if idx.size == 0:
        return out, 0
    lo = np.searchsorted(host.src_by_src, idx, side="left")
    hi = np.searchsorted(host.src_by_src, idx, side="right")
    counts = (hi - lo).astype(np.int64)
    total = int(counts.sum())
    if total:
        offs = (np.arange(total, dtype=np.int64)
                - np.repeat(np.cumsum(counts) - counts, counts))
        eidx = np.repeat(lo, counts) + offs
        heads = host.dst_by_src[eidx]
        vals = np.repeat(v[idx] * _masked_inv(host.w[idx]), counts)
        np.add.at(out, heads, host.mu[heads] * vals)
    return out, total


def mass_weights(host: HostOperators) -> np.ndarray:
    """``β_j = (Σ_{i: j→i} μ_i)/w_j`` — the per-source ℓ₁ mass of ``M``.

    For ``v ≥ 0``, ``‖Mv‖₁ = Σ_i μ_i Σ_{j→i} v_j/w_j = Σ_j v_j β_j``: the
    ℓ₁ norm of a product with ``M`` is a support-sized dot product, no
    mat-vec required. O(m) to build once; cache it with the norms.
    """
    row_mu = np.zeros(host.n)
    np.add.at(row_mu, host.src_by_src, host.mu[host.dst_by_src])
    return row_mu * host.inv_w


CERT_TAIL_FRAC = 1e-3
"""Residual-mass fraction the certificate may bound at the worst-case rate.

The heavy entries of ``|r|`` carrying ``1 − CERT_TAIL_FRAC`` of its mass go
through the exact Neumann terms; the dust tail — often supported on most of
the graph while holding almost none of the mass — is charged
``α‖tail‖₁/(1 − α)`` wholesale. Inflates the bound by at most a factor
``1 + CERT_TAIL_FRAC·α/(1 − α)`` on the leading term while keeping each
certificate check local to the heavy support.
"""


def neumann_error_bound(host: HostOperators, r: np.ndarray, *,
                        alpha: float | None = None,
                        pernode: np.ndarray | None = None,
                        beta: np.ndarray | None = None
                        ) -> tuple[np.ndarray, int]:
    """Per-node confidence radii ``E`` with ``|ψ_exact − ψ̂|_i ≤ E_i``.

    The error iterate is ``δs = Σ_t M^t r``; instead of bounding the whole
    series by ``‖r‖₁/(1 − α)`` (α is a worst-case column sum — typically
    orders looser than the mass an actual push loses), the first two terms
    are computed *exactly* over the heavy part ``b`` of ``|r| = b + tail``
    and only the series tails pay the worst-case rate:

        ‖δs‖₁ ≤ ‖r‖₁ + ‖Mb‖₁ + ‖M²b‖₁/(1 − α) + α‖tail‖₁/(1 − α)

    (``M ≥ 0`` elementwise; ``tail`` is the :data:`CERT_TAIL_FRAC` dust).
    Cost: ONE ``M`` application restricted to the heavy support (returned
    as ``edge_work`` so callers account for the certificate the same as
    for pushes) — ``‖Mb‖₁`` and ``‖M²b‖₁ = ‖M(Mb)‖₁`` come from the ``β``
    dot product of :func:`mass_weights`, so no second mat-vec is ever
    paid. The tighter S is what lets a warm top-k query certify while the
    push is still confined to the dirty neighborhood (docs/LOCALPUSH.md).
    """
    alpha = a_norm(host) if alpha is None else float(alpha)
    if pernode is None:
        pernode = pernode_cert_scale(host)
    if beta is None:
        beta = mass_weights(host)
    if alpha >= 1.0:
        return np.full(host.n, math.inf), 0
    absr = np.abs(np.asarray(r, np.float64))
    t0 = float(absr.sum())
    if t0 == 0.0:
        return pernode * 0.0, 0
    order = np.argsort(absr)                       # dust first
    csum = np.cumsum(absr[order])
    cut = int(np.searchsorted(csum, CERT_TAIL_FRAC * t0, side="right"))
    tail_mass = float(csum[cut - 1]) if cut else 0.0
    big = absr.copy()
    big[order[:cut]] = 0.0
    m1, e1 = apply_abs_M(host, big)
    s_mass = (t0 + float(m1.sum()) + float((m1 * beta).sum()) / (1.0 - alpha)
              + alpha * tail_mass / (1.0 - alpha))
    return pernode * s_mass, e1


def psi_value(host: HostOperators, state: PushState) -> np.ndarray:
    """ψ̂ᵀ = (λ ⊙ p + dᵀ)/N from the maintained companion vector — O(N)."""
    _, d = host.cd()
    return (host.lam * state.p + d) / max(1, host.n)


def cold_state(host: HostOperators) -> PushState:
    """x = 0, r = c — the push form of Alg. 2's s₀ = c cold start."""
    c, _ = host.cd()
    n = host.n
    return PushState(x=np.zeros(n), r=c.astype(np.float64, copy=True),
                     p=np.zeros(n))


def reseed_state(host: HostOperators, x: np.ndarray) -> PushState:
    """Restart from an arbitrary node-order iterate (one host mat-vec).

    ``p = push(x)`` is rebuilt exactly, then ``r = c + μ ⊙ p − x`` restores
    the invariant — the honest warm start for a foreign ``s0``. The O(Δ)
    patch reseeds in :mod:`repro.localpush.warm` avoid even this.
    """
    x = np.asarray(x, np.float64).reshape(-1)
    if x.shape != (host.n,):
        raise ValueError(f"s0 must be f[{host.n}] in node order; "
                         f"got {x.shape}")
    p = np.zeros(host.n)
    np.add.at(p, host.dst_by_dst, (x * host.inv_w)[host.src_by_dst])
    c, _ = host.cd()
    return PushState(x=x.copy(), r=c + host.mu * p - x, p=p)


# --------------------------------------------------------------------- #
# Vectorized frontier rounds (the engine's host hot path)
# --------------------------------------------------------------------- #
def push_nodes(host: HostOperators, state: PushState,
               nodes: np.ndarray) -> int:
    """Batched elementary push of ``nodes``; returns edge work (out-degree
    sum). Residuals are zeroed *before* the scatter, so mass a pushed node
    receives from a same-batch neighbour stays in ``r`` for a later round
    (the invariant holds per elementary operation and therefore per batch).
    """
    nodes = np.asarray(nodes, np.int64).reshape(-1)
    if nodes.size == 0:
        return 0
    rf = state.r[nodes].copy()
    state.r[nodes] = 0.0
    state.x[nodes] += rf
    lo = np.searchsorted(host.src_by_src, nodes, side="left")
    hi = np.searchsorted(host.src_by_src, nodes, side="right")
    counts = (hi - lo).astype(np.int64)
    total = int(counts.sum())
    if total:
        # gather each node's contiguous out-edge slice without a Python loop
        offs = (np.arange(total, dtype=np.int64)
                - np.repeat(np.cumsum(counts) - counts, counts))
        eidx = np.repeat(lo, counts) + offs
        heads = host.dst_by_src[eidx]
        vals = np.repeat(rf * _masked_inv(host.w[nodes]), counts)
        np.add.at(state.p, heads, vals)
        np.add.at(state.r, heads, host.mu[heads] * vals)
    return total


def push_round(host: HostOperators, state: PushState, *,
               bucket_ratio: float = 0.5
               ) -> tuple[np.ndarray, int]:
    """One bucketed Gauss-Southwell round: push every node whose ``|r|``
    falls in the top magnitude bucket ``[bucket_ratio·max|r|, max|r|]``.

    Returns ``(nodes_pushed, edge_work)``. When residual is spread platform
    wide the bucket naturally widens to most nodes and the round degrades
    gracefully to a full residual sweep (a Jacobi iteration in push form) —
    the solver's own local-vs-global crossover, with no mode switch.
    """
    absr = np.abs(state.r)
    rmax = float(absr.max()) if absr.size else 0.0
    if rmax <= 0.0:
        return np.empty(0, np.int64), 0
    nodes = np.nonzero(absr >= rmax * bucket_ratio)[0]
    return nodes, push_nodes(host, state, nodes)


def push_until(host: HostOperators, state: PushState, *, tol_r: float,
               max_rounds: int = 100_000, bucket_ratio: float = 0.5
               ) -> tuple[int, int, int]:
    """Drive rounds until ``‖r‖₁ ≤ tol_r``; returns (rounds, pushes, edges)."""
    rounds = pushes = edges = 0
    while rounds < max_rounds and l1(state.r) > tol_r:
        nodes, ew = push_round(host, state, bucket_ratio=bucket_ratio)
        if nodes.size == 0:
            break
        rounds += 1
        pushes += int(nodes.size)
        edges += ew
    return rounds, pushes, edges


# --------------------------------------------------------------------- #
# Pure-Python bucket-queue oracle
# --------------------------------------------------------------------- #
def push_scalar(host: HostOperators, *, tol_r: float,
                state: PushState | None = None,
                max_pushes: int = 1_000_000) -> tuple[PushState, int, int]:
    """One-node-at-a-time Gauss-Southwell with a frexp bucket queue.

    Buckets are keyed by the binary exponent of ``|r_i|`` (power-of-two
    magnitude classes — the scalar analogue of :func:`push_round`'s
    ``bucket_ratio = 0.5`` band); entries are re-filed lazily on pop, so
    there is no heap and no decrease-key. This is the parity oracle the
    vectorized and jitted paths are tested against, not a hot path.

    Returns ``(state, pushes, edge_work)``.
    """
    if state is None:
        state = cold_state(host)
    x, r, p = state.x, state.r, state.p
    mu, w = host.mu, host.w
    sbs, dbs = host.src_by_src, host.dst_by_src

    def bkt(v: float) -> int:
        return math.frexp(v)[1]

    buckets: dict[int, list[int]] = {}
    for i in np.nonzero(r)[0]:
        buckets.setdefault(bkt(abs(float(r[i]))), []).append(int(i))
    norm = l1(r)
    pushes = edge_work = 0
    while norm > tol_r and buckets and pushes < max_pushes:
        k = max(buckets)
        lst = buckets[k]
        if not lst:
            del buckets[k]
            continue
        j = lst.pop()
        rj = float(r[j])
        if rj == 0.0:
            continue                       # stale entry, already absorbed
        kj = bkt(abs(rj))
        if kj != k:
            buckets.setdefault(kj, []).append(j)   # lazy re-file
            continue
        r[j] = 0.0
        x[j] += rj
        norm -= abs(rj)
        pushes += 1
        if w[j] > 0:
            contrib = rj / float(w[j])
            a = int(np.searchsorted(sbs, j, side="left"))
            b = int(np.searchsorted(sbs, j, side="right"))
            for e in range(a, b):
                i = int(dbs[e])
                old = float(r[i])
                new = old + float(mu[i]) * contrib
                r[i] = new
                p[i] += contrib
                norm += abs(new) - abs(old)
                if new != 0.0:
                    buckets.setdefault(bkt(abs(new)), []).append(i)
                edge_work += 1
    return state, pushes, edge_work


# --------------------------------------------------------------------- #
# JAX-jittable batched-frontier rounds
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class FrontierOps:
    """Device-resident padded out-edge table for the jitted push round.

    ``leaders[j]`` holds follower j's leader list padded with the sentinel
    ``n`` (one extra scatter slot absorbs pad traffic, the same trick as the
    kernels' sentinel edge slots); node vectors are in the engine dtype.
    """

    n: int
    dmax: int
    leaders: "object"   # i32[N, dmax] — jax array, sentinel-padded with n
    deg: "object"       # i32[N]
    inv_w: "object"     # f[N]
    mu: "object"        # f[N]


def build_frontier_ops(host: HostOperators, *, dtype) -> FrontierOps:
    import jax.numpy as jnp
    n = host.n
    lo = np.searchsorted(host.src_by_src, np.arange(n), side="left")
    hi = np.searchsorted(host.src_by_src, np.arange(n), side="right")
    deg = (hi - lo).astype(np.int64)
    dmax = int(max(1, deg.max())) if n else 1
    leaders = np.full((n, dmax), n, np.int32)
    total = int(deg.sum())
    if total:
        cols = (np.arange(total, dtype=np.int64)
                - np.repeat(np.cumsum(deg) - deg, deg))
        leaders[np.repeat(np.arange(n), deg), cols] = host.dst_by_src
    return FrontierOps(
        n=n, dmax=dmax,
        leaders=jnp.asarray(leaders),
        deg=jnp.asarray(deg.astype(np.int32)),
        inv_w=jnp.asarray(host.inv_w.astype(np.dtype(jnp.dtype(dtype).name))),
        mu=jnp.asarray(host.mu.astype(np.dtype(jnp.dtype(dtype).name))),
    )


def make_frontier_loop(fops: FrontierOps, *, frontier_size: int):
    """Jitted fixed-frontier push: per round ``lax.top_k(|r|, F)`` picks the
    frontier, one padded gather + segment scatter applies the batched push.

    Returns ``loop(x, r, p, tol_r, max_rounds) -> (x, r, p, rounds,
    edge_work)``. Zero-residual picks are masked (they push nothing), pad
    lanes scatter into the sentinel slot ``n`` which is dropped. The
    edge-work counter counts *real* out-edges of non-masked picks; the
    padded scatter itself costs F·dmax per round — the price of a fixed
    shape, charged to wall clock but not to the locality metric.

    The caller re-derives ``r``/``p`` from ``x`` on the host in float64
    before certifying anything (device dtype may be f32); the loop's own
    ``tol_r`` check is only a steering heuristic, exactly like the async
    backend's unverified chunk gaps.
    """
    import jax
    import jax.numpy as jnp

    F = int(frontier_size)
    if not 1 <= F <= max(1, fops.n):
        raise ValueError(f"frontier_size must be in [1, {fops.n}]; got {F}")
    n = fops.n

    @jax.jit
    def loop(x, r, p, tol_r, max_rounds):
        def cond(st):
            _, r_, _, t, _ = st
            return (jnp.sum(jnp.abs(r_)) > tol_r) & (t < max_rounds)

        def body(st):
            x_, r_, p_, t, ew = st
            vals, nodes = jax.lax.top_k(jnp.abs(r_), F)
            live = vals > 0
            rf = jnp.where(live, r_[nodes], 0.0)
            r_ = r_.at[nodes].add(-rf)         # zero the pushed residuals
            x_ = x_.at[nodes].add(rf)
            contrib = rf * fops.inv_w[nodes]                    # [F]
            heads = fops.leaders[nodes]                         # [F, dmax]
            sheet = jnp.broadcast_to(contrib[:, None], heads.shape)
            delta = (jnp.zeros(n + 1, r_.dtype)
                     .at[heads.reshape(-1)].add(sheet.reshape(-1)))[:n]
            p_ = p_ + delta
            r_ = r_ + fops.mu * delta
            ew = ew + jnp.sum(jnp.where(live, fops.deg[nodes], 0))
            return x_, r_, p_, t + 1, ew

        return jax.lax.while_loop(
            cond, body,
            (x, r, p, jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32)))

    return loop
