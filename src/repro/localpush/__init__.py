"""Local residual-push ψ solver with certified top-k early stop.

Sub-modules:
  * :mod:`repro.localpush.push` — Gauss-Southwell forward push on the
    Eq. 19 residual (bucketed frontier, scalar oracle, jitted rounds) and
    the running certificate ``‖ψ_exact − ψ̂‖ ≤ scale·‖r‖₁``.
  * :mod:`repro.localpush.topk` — rank-separation certificates: stop as
    soon as the k-th and (k+1)-th confidence intervals separate.
  * :mod:`repro.localpush.warm` — O(Δ) residual reseeding under activity
    and edge patches (no mat-vec warm restarts).
  * :mod:`repro.localpush.engine` — the registered ``backend="push"``
    :class:`~repro.core.engine.PsiEngine`.
  * ``python -m repro.localpush.check`` — the CI smoke gate.

See docs/LOCALPUSH.md for the invariant and certificate derivations.
"""
from .engine import PushEngine
from .push import (PushState, a_norm, cert_scale, cold_state, mass_weights,
                   neumann_error_bound, pernode_cert_scale, psi_value,
                   push_round, push_scalar, push_until, reseed_state)
from .topk import TopKCertificate, certify_top_k

__all__ = ["PushEngine", "PushState", "TopKCertificate", "a_norm",
           "cert_scale", "certify_top_k", "cold_state", "mass_weights",
           "neumann_error_bound", "pernode_cert_scale", "psi_value",
           "push_round", "push_scalar", "push_until", "reseed_state"]
