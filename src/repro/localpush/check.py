"""Push-backend smoke check — the CI gate for the local-query claims.

Cold-solves a power-law platform with the push engine, perturbs a small
dirty set, warm-resolves a certified top-k through the maintained handle,
and verifies the three properties the subsystem sells:

1. the certified top-k *set* equals the exact (LU-solve) top-k,
2. the warm push stayed local (touched node fraction below a budget),
3. the certificate upper-bounds the true float64 ψ error.

Exit status 0 on success, 1 with a diagnostic on any violation::

    PYTHONPATH=src python -m repro.localpush.check \
        --n 1500 --m 9000 --dirty-frac 0.01 --k 50
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from ..core import exact_psi, heterogeneous, make_engine
from ..core.activity import Activity
from ..graphs import powerlaw_configuration


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--n", type=int, default=1500)
    ap.add_argument("--m", type=int, default=9000)
    ap.add_argument("--dirty-frac", type=float, default=0.01)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--drift", type=float, default=1.02,
                    help="dirty users' λ multiplier (streaming rate drift; "
                    "the locality claim is about drift-sized deltas, not "
                    "order-of-magnitude shocks)")
    ap.add_argument("--tol", type=float, default=1e-9)
    ap.add_argument("--max-touched-frac", type=float, default=0.20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    g = powerlaw_configuration(args.n, args.m, seed=args.seed)
    act = heterogeneous(g.n, seed=args.seed + 1)
    eng = make_engine("push", graph=g, activity=act)
    cold = eng.run(tol=args.tol)

    rng = np.random.default_rng(args.seed + 2)
    dirty = rng.choice(g.n, size=max(1, int(args.dirty_frac * g.n)),
                       replace=False)
    eng.patch_activity(dirty, lam=act.lam[dirty] * args.drift)
    _, cert = eng.run_top_k(args.k, tol=args.tol, s0=cold.s)
    stats = eng.last_run_stats

    lam2 = act.lam.copy()
    lam2[dirty] = act.lam[dirty] * args.drift
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    exact_top = set(np.argsort(-psi_true, kind="stable")[:args.k].tolist())
    true_err = float(np.abs(eng.last_psi_host - psi_true).max())
    bound = eng.psi_error_bound()

    failures = []
    if not cert.certified:
        failures.append("top-k certificate did not close "
                        f"(margin={cert.margin:.3e}, bound={cert.err_bound})")
    if set(cert.indices.tolist()) != exact_top:
        failures.append("certified top-k set != exact top-k")
    if stats["touched_frac"] >= args.max_touched_frac:
        failures.append(f"push touched {stats['touched_frac']:.1%} of nodes "
                        f"(budget {args.max_touched_frac:.0%})")
    if bound is None or true_err > bound:
        failures.append(f"certificate {bound} < true f64 error {true_err:.3e}")

    print(f"push smoke: n={g.n} m={g.m} dirty={dirty.size} k={args.k} | "
          f"rounds={stats['rounds']} edge_work={stats['edge_work']} "
          f"touched={stats['touched_frac']:.1%} | "
          f"true_err={true_err:.3e} <= cert={bound if bound is None else f'{bound:.3e}'} | "
          f"certified={cert.certified}")
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
