"""``backend="push"`` — the local residual-push engine.

A drop-in :class:`~repro.core.engine.PsiEngine`: global tol-driven solves
terminate on the residual form of Eq. 19 (``scale·‖r‖₁/(1 − α) ≤ tol``
implies ``scale·‖Δs‖₁ ≤ tol`` for every further sweep, and bounds the
*distance to the fixed point* rather than one step's movement — strictly
stronger), while :meth:`PushEngine.run_top_k` stops as soon as the
residual confidence intervals separate rank k from k+1
(:mod:`repro.localpush.topk`).

What makes it local:

* **Warm identity handle** — ``run(s0=...)`` with the exact ``s`` object
  the engine last returned (what :class:`~repro.core.incremental.PsiService`
  passes) resumes the maintained float64 ``(x, r, p)`` state: zero reseed
  cost. A foreign ``s0`` pays one honest host mat-vec
  (:func:`repro.localpush.push.reseed_state`).
* **O(Δ) patch hooks** — ``patch_activity`` / ``patch_edges`` /
  ``unpatch_edges`` route through :mod:`repro.localpush.warm`, repairing
  ``(r, p)`` on the affected subgraph only, so a resolve after a flash
  crowd pushes only where residual was actually created.
* **Honest accounting** — ``matvecs`` reports push edge-work in mat-vec
  equivalents (``⌈edge_work / M⌉`` + reseed/verification sweeps + the
  epilogue slot), the same currency every other backend reports;
  ``last_run_stats`` carries the raw counters the ``local_query``
  benchmark records.

``frontier="jit"`` runs rounds as a compiled ``lax.while_loop``
(fixed-size ``lax.top_k`` frontier) in the engine dtype, then *always*
re-derives ``(r, p)`` from ``x`` on the host in float64 before emitting a
gap or certificate — the verification-sweep pattern of the async backend.
The certificate is never produced from unverified device state.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from ..core.activity import Activity
from ..core.engine import EngineState, PsiEngine, register_backend
from ..graphs.structure import Graph
from ..core.power_psi import PsiResult
from ..obs import convergence as obs_convergence
from ..obs import explain as obs_explain
from . import push, warm
from .topk import TopKCertificate, certify_top_k

__all__ = ["PushEngine"]


@register_backend("push")
class PushEngine(PsiEngine):
    """Gauss-Southwell forward-push backend (see module docstring).

    Args:
      frontier: ``"bucket"`` (vectorized host rounds, float64 end to end)
        or ``"jit"`` (compiled fixed-frontier rounds + float64 host
        verification tail).
      frontier_size: nodes pushed per jitted round (clipped to N).
      bucket_ratio: magnitude band of a bucket round — push every node
        with ``|r| ≥ bucket_ratio·max|r|``; 0.5 matches the scalar
        oracle's frexp buckets.
    """

    def __init__(self, *, frontier: str = "bucket", frontier_size: int = 128,
                 bucket_ratio: float = 0.5, **kw):
        super().__init__(**kw)
        if self.criterion.norm != "l1":
            raise ValueError("push backend certifies via the l1 residual "
                             f"bound; got norm={self.criterion.norm!r}")
        if self.accelerate:
            raise ValueError(
                "push backend has no Aitken composition (the residual "
                "decomposition is not a plain iterate sequence); run "
                "accelerate on a sweep backend")
        if frontier not in ("bucket", "jit"):
            raise ValueError(f"frontier must be 'bucket' or 'jit'; "
                             f"got {frontier!r}")
        if not 0.0 < bucket_ratio <= 1.0:
            raise ValueError(f"bucket_ratio must be in (0, 1]; "
                             f"got {bucket_ratio}")
        self.frontier = frontier
        self.frontier_size = int(frontier_size)
        self.bucket_ratio = float(bucket_ratio)
        self._alpha = 0.0
        self._state: push.PushState | None = None
        self._warm_handle = None
        self._fops = None
        self._floop = None
        self.last_certificate: float | None = None
        self.last_psi_host: np.ndarray | None = None
        self.last_run_stats: dict = {}

    # -- lifecycle ------------------------------------------------------ #
    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        self._base_prepare(graph, activity)
        self._refresh_norms()
        self._state = None
        self._warm_handle = None
        self._fops = None
        self._floop = None
        self.last_certificate = None
        self.last_run_stats = {}
        return EngineState(s=push.cold_state(self.host))

    def _refresh_norms(self) -> None:
        self._alpha = push.a_norm(self.host)
        if self._alpha >= 1.0:
            raise ValueError(
                "push backend needs α = max_j (w_j − Σλ)/w_j < 1 (some λ "
                "mass in every non-empty feed) for a finite residual "
                f"certificate; got α = {self._alpha}")
        # per-node certificate prefactors depend on (λ, w): O(M) refresh
        # whenever either is patched
        self._pernode = push.pernode_cert_scale(self.host)
        self._beta = push.mass_weights(self.host)

    # -- gap / certificate helpers -------------------------------------- #
    def _gap_of(self, state: push.PushState) -> float:
        scale = self.criterion.scale(self.host.b_norm)
        return scale * push.l1(state.r) / (1.0 - self._alpha)

    def psi_error_bound(self) -> float | None:
        """Certified per-node |ψ − ψ̂| bound of the last run's returned ψ
        (None before any run or after a patch invalidated it)."""
        return self.last_certificate

    def step(self, state: EngineState) -> EngineState:
        """One bucketed frontier round with the shared gap rule."""
        st = state.s
        if not isinstance(st, push.PushState):
            raise TypeError("push engine state carries a PushState; pass "
                            "the state returned by prepare()/step()")
        push.push_round(self.host, st, bucket_ratio=self.bucket_ratio)
        return EngineState(s=st, gap=self._gap_of(st), t=state.t + 1)

    # -- solves --------------------------------------------------------- #
    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        state, reseed_mv = self._restart(s0)
        res, _ = self._drive(state, tol=tol, max_iter=max_iter, k=None,
                             reseed_mv=reseed_mv)
        return res

    def run_top_k(self, k: int, *, tol=None, max_iter=None, s0=None
                  ) -> tuple[PsiResult, TopKCertificate]:
        """Solve only far enough to certify the top-k set.

        Stops at rank separation (certificate) or at the global tolerance,
        whichever first; the returned result's ``converged`` stays honest
        (False on a certified-but-early exit — the *set* is exact, the
        scores are only err_bound-accurate).
        """
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        state, reseed_mv = self._restart(s0)
        return self._drive(state, tol=tol, max_iter=max_iter, k=int(k),
                           reseed_mv=reseed_mv)

    def _restart(self, s0) -> tuple[push.PushState, int]:
        if s0 is None:
            return push.cold_state(self.host), 0
        if self._state is not None and s0 is self._warm_handle:
            return self._state, 0          # maintained state: O(Δ) restart
        return push.reseed_state(self.host, np.asarray(s0, np.float64)), 1

    def _drive(self, state: push.PushState, *, tol: float, max_iter: int,
               k: int | None, reseed_mv: int
               ) -> tuple[PsiResult, TopKCertificate | None]:
        host = self.host
        scale = self.criterion.scale(host.b_norm)
        denom = 1.0 - self._alpha
        rounds = pushes = ew = cew = 0
        extra_mv = reseed_mv
        touched = np.zeros(host.n, bool)
        cert: TopKCertificate | None = None

        if self.frontier == "jit" and host.m > 0 and scale > 0:
            j_rounds, j_ew = self._jit_phase(state, tol * denom / scale,
                                             max_iter)
            rounds += j_rounds
            ew += j_ew
            if j_rounds:
                extra_mv += 1              # float64 host verification sweep

        # Certificate checks cost two support-local mat-vecs, so they run
        # on a geometric cadence: first chance, then only once ‖r‖₁ has
        # halved since the last check — O(log) checks per run, and the
        # radii shrink ∝ residual mass so nothing can be missed for long.
        next_check_mass = np.inf
        while True:
            l1r = push.l1(state.r)
            gap = scale * l1r / denom
            if gap <= tol:
                break
            if (k is not None and rounds % self.check_every == 0
                    and l1r <= next_check_mass):
                radii, cert_ew = push.neumann_error_bound(
                    host, state.r, alpha=self._alpha,
                    pernode=self._pernode, beta=self._beta)
                ew += cert_ew              # certificate work is real work
                cew += cert_ew
                cert = certify_top_k(push.psi_value(host, state), k, radii)
                if cert.certified:
                    break
                next_check_mass = 0.5 * l1r
            if rounds >= max_iter:
                break
            nodes, e = push.push_round(host, state,
                                       bucket_ratio=self.bucket_ratio)
            if nodes.size == 0:
                break                      # residual exactly zero
            touched[nodes] = True
            pushes += int(nodes.size)
            ew += e
            rounds += 1

        psi_host = push.psi_value(host, state)
        radii, cert_ew = push.neumann_error_bound(
            host, state.r, alpha=self._alpha, pernode=self._pernode,
            beta=self._beta)
        ew += cert_ew
        cew += cert_ew
        err = float(radii.max(initial=0.0))
        self.last_certificate = err if np.isfinite(err) else None
        if k is not None:
            cert = certify_top_k(psi_host, k, radii)
        np_dtype = np.dtype(jnp.dtype(self.dtype).name)
        res = self._result(jnp.asarray(psi_host.astype(np_dtype)),
                           jnp.asarray(state.x.astype(np_dtype)),
                           gap, rounds, tol)
        m = max(1, host.m)
        res = dataclasses.replace(
            res, matvecs=jnp.asarray(-(-ew // m) + extra_mv + 1, jnp.int32))
        self._state = state
        self._warm_handle = res.s
        # float64 host ψ — what the certificate actually covers (the device
        # copy adds a dtype-cast error outside the bound's scope)
        self.last_psi_host = psi_host
        self.last_run_stats = dict(
            rounds=rounds, pushes=pushes, edge_work=ew, cert_edge_work=cew,
            reseed_matvecs=extra_mv, nodes_touched=int(touched.sum()),
            touched_frac=float(touched.mean()) if host.n else 0.0,
            certified=bool(cert.certified) if cert is not None else None)
        obs_convergence.record_push(edge_work=ew, cert_edge_work=cew)
        if k is not None:
            # the early-stop outcome belongs in the decision trail: what a
            # certified exit saved (or failed to save) vs exhausting to tol
            certified = bool(cert.certified) if cert is not None else False
            sweeps_eq = float(-(-ew // m))     # edge-work in sweep units
            obs_explain.record_decision(
                "early_stop", "PushEngine.run_top_k",
                inputs=dict(n=host.n, m=host.m, k=int(k), tol=tol),
                chosen=("certified_early_stop" if certified
                        else "exhausted_to_tol"),
                candidates=[
                    obs_explain.Candidate(
                        "certified_early_stop", est=float(ew), unit="edges",
                        chosen=certified,
                        detail=dict(rounds=rounds,
                                    sweep_equiv=round(sweeps_eq, 2))),
                    obs_explain.Candidate(
                        "exhausted_to_tol", est=None, chosen=not certified,
                        detail=dict(gap=f"{gap:.3g}")),
                ],
                note=f"touched_frac={self.last_run_stats['touched_frac']:.3g}"
                     f" cert_edge_work={cew}")
        return res, cert

    # -- jitted frontier phase ------------------------------------------ #
    def _jit_phase(self, state: push.PushState, tol_r: float,
                   max_rounds: int) -> tuple[int, int]:
        """Run compiled rounds toward ``tol_r`` (floored at the device
        dtype's resolution), then restore the float64 invariant from x."""
        np_dtype = np.dtype(jnp.dtype(self.dtype).name)
        eps = float(np.finfo(np_dtype).eps)
        floor = 64.0 * eps * (push.l1(state.x) + push.l1(state.r))
        target = max(tol_r, floor)
        if push.l1(state.r) <= target:
            return 0, 0
        if self._fops is None:
            self._fops = push.build_frontier_ops(self.host, dtype=self.dtype)
            self._floop = push.make_frontier_loop(
                self._fops,
                frontier_size=min(self.frontier_size, max(1, self.host.n)))
        x, r, p, t, ew = self._floop(
            jnp.asarray(state.x.astype(np_dtype)),
            jnp.asarray(state.r.astype(np_dtype)),
            jnp.asarray(state.p.astype(np_dtype)),
            jnp.asarray(target, np_dtype),
            jnp.asarray(max_rounds, jnp.int32))
        verified = push.reseed_state(self.host, np.asarray(x, np.float64))
        state.x[:] = verified.x
        state.r[:] = verified.r
        state.p[:] = verified.p
        return int(t), int(ew)

    # -- O(Δ) delta hooks ----------------------------------------------- #
    def patch_activity(self, users, lam=None, mu=None) -> bool:
        if self._state is None:
            self.host.patch_activity(users, lam=lam, mu=mu)
        else:
            warm.apply_activity_patch(self.host, self._state, users,
                                      lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        self._refresh_norms()
        self.last_certificate = None       # served ψ no longer covered
        return True

    def patch_edges(self, src, dst) -> bool:
        if self._state is None:
            self.host.patch_edges(src, dst)
        else:
            warm.apply_edge_insert(self.host, self._state, src, dst)
        self._after_edge_mutation()
        return True

    def unpatch_edges(self, src, dst) -> bool:
        if self._state is None:
            removed, _ = self.host.remove_edges(src, dst)
        else:
            removed, _ = warm.apply_edge_remove(self.host, self._state,
                                                src, dst)
        if removed.size:
            self._after_edge_mutation()
        return True

    def _after_edge_mutation(self) -> None:
        self._graph_stale = True
        self.ops = self.host.to_device(self.dtype)
        self._refresh_norms()
        self._fops = None                  # padded leader table grew/shrank
        self._floop = None
        self.last_certificate = None
