"""Bounded-staleness model and the stale-corrected Eq. 19 gap certificate.

The Power-ψ iteration is an affine contraction (ρ(A) < 1, §III-B), so the
asynchronous "chaotic relaxation" theorem of Chazan–Miranker applies: the
fixed point is reached even when each chunk's update reads *stale* values of
the other chunks, as long as the staleness is bounded. :class:`StalenessBound`
pins that bound: no chunk's epoch may lag the fastest chunk by more than
``tau`` epochs, so every partial a step consumes is at most ``tau`` epochs
old.

Termination under staleness needs care. The synchronous Eq. 19 rule stops at
``‖B‖·‖s_t − s_{t−1}‖₁ ≤ ε`` — but an asynchronously assembled gap sums
per-chunk deltas measured at *different* epochs, and a chunk that happens to
be ``σ`` epochs behind under-reports the true residual by up to a factor
``ρ^σ`` (its delta has contracted σ fewer times than it pretends). The
certificate therefore:

* records the epoch **spread** of the contributing per-chunk gaps;
* **inflates** the observed gap by the contraction factor, ``gap · ρ^{−σ}``
  (ρ < 1 ⇒ the inflation is ≥ 1, i.e. pessimistic);
* only marks the result **trusted** when every contributing partial is
  within ``tau`` — a τ-violating assembly is *rejected* outright
  (``trusted = False``), whatever its inflated value says.

The scheduler (:mod:`repro.asyncexec.scheduler`) uses an accepted
certificate only to *gate* the synchronous verification sweep; the final
convergence decision is always a true same-epoch Eq. 19 gap, so the
certificate being a conservative heuristic (ρ is estimated online) can delay
but never corrupt termination.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["StalenessBound", "GapCertificate", "certify_gap", "RhoEstimator"]


@dataclasses.dataclass(frozen=True)
class StalenessBound:
    """Maximum epoch lag the scheduler tolerates.

    ``tau = 0`` degenerates to bulk-synchronous execution (every chunk must
    sit at the common epoch before any may advance — a barrier per epoch);
    ``tau ≥ 1`` lets fast chunks run ahead and stragglers fall behind by up
    to ``tau`` epochs before anyone waits.

    ``rho`` is the contraction factor used by the certificate's inflation.
    ``None`` (the default) estimates it online from observed per-epoch gap
    ratios (:class:`RhoEstimator`); a paper-style a-priori bound (e.g. the
    sub-stochastic row-sum bound on A) can be pinned explicitly.
    """

    tau: int = 2
    rho: float | None = None

    def __post_init__(self):
        if self.tau < 0:
            raise ValueError(f"tau must be >= 0; got {self.tau}")
        if self.rho is not None and not (0.0 < self.rho < 1.0):
            raise ValueError(f"rho must be in (0, 1); got {self.rho}")


@dataclasses.dataclass(frozen=True)
class GapCertificate:
    """The stale-corrected Eq. 19 verdict for one assembled global gap."""

    raw_gap: float          # scale · Σ_k latest per-chunk ‖Δs_k‖₁
    certified_gap: float    # raw_gap · ρ^{−spread} (pessimistic correction)
    spread: int             # max − min contributing epoch
    trusted: bool           # every contributing partial within τ
    rho: float              # contraction factor the inflation used

    def accepts(self, tol: float) -> bool:
        """True when the certified (inflated) gap crosses ``tol`` *and* the
        assembly respected the staleness bound. A τ-violating gap is never
        accepted — the scheduler must re-tighten the pipeline first."""
        return self.trusted and self.certified_gap <= tol


def certify_gap(chunk_gaps, chunk_epochs, *, bound: StalenessBound,
                rho: float, scale: float = 1.0) -> GapCertificate:
    """Assemble per-chunk gaps (tagged with the epoch each was measured at)
    into one certified global gap under ``bound``.

    ``chunk_gaps[k]`` is the raw l1 delta of chunk k's latest completed
    step; ``chunk_epochs[k]`` the epoch that step landed on. ``scale`` is
    the Eq. 19 ``‖B‖`` factor (1.0 for an unscaled driver-style gap).
    """
    gaps = np.asarray(chunk_gaps, np.float64)
    epochs = np.asarray(chunk_epochs, np.int64)
    if gaps.size == 0 or gaps.size != epochs.size:
        raise ValueError("need one (gap, epoch) pair per chunk")
    spread = int(epochs.max() - epochs.min())
    raw = float(scale * gaps.sum())
    rho = float(min(max(rho, 1e-6), 1.0 - 1e-9))
    certified = raw * rho ** (-float(spread))
    return GapCertificate(raw_gap=raw, certified_gap=certified,
                          spread=spread, trusted=spread <= bound.tau,
                          rho=rho)


class RhoEstimator:
    """Online contraction-factor estimate from successive global gaps.

    Feeds on gaps observed whenever the *minimum* epoch advances (so the
    ratio spans one genuine global contraction step). The estimate is the
    **minimum** of the recent ratios — the conservative direction: the
    inflation ``ρ^{−σ}`` *grows* as ρ̂ shrinks, so under-estimating ρ
    over-corrects the certified gap (at worst delaying certification; an
    over-estimate would certify gaps the true residual exceeds). Clamped to
    [floor, cap] so one noisy transient ratio can neither blow the
    inflation up unboundedly nor disable it.
    """

    def __init__(self, *, init: float = 0.9, window: int = 8,
                 floor: float = 0.05, cap: float = 0.999):
        self.init = init
        self.window = int(window)
        self.floor = floor
        self.cap = cap
        self.reset()

    def reset(self) -> None:
        self._prev: float | None = None
        self._ratios: list[float] = []

    def update(self, gap: float) -> None:
        if self._prev is not None and self._prev > 0 and np.isfinite(gap):
            r = gap / self._prev
            if np.isfinite(r) and r > 0:
                self._ratios.append(float(r))
                del self._ratios[:-self.window]
        self._prev = float(gap)

    @property
    def value(self) -> float:
        if not self._ratios:
            return self.init
        return float(min(max(min(self._ratios), self.floor), self.cap))
