"""`AsyncPsiDriver` — the fault-tolerant front end of the bounded-staleness
scheduler, with the same checkpoint/restart + elastic contract as the
synchronous :class:`~repro.runtime.psi_driver.PsiDriver`.

The one structural difference from the sync driver: async state is not just
the board — it is the board *plus the per-chunk epoch vector*. Checkpoints
carry both, so a restart resumes the skewed pipeline exactly where it was
(straggler lag and all) instead of collapsing it to a synchronous snapshot;
the only lost work is whatever was in flight when the failure hit.

The elastic analogue of ``PsiDriver.remesh`` is :meth:`AsyncPsiDriver.rechunk`:
the board converts through node order into a new chunk decomposition and the
new pipeline warm-starts from it (epochs restart at a uniform zero — an
epoch vector is meaningless across a chunk-count change, the contraction
progress lives entirely in the board).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from ..core.operators import HostOperators
from ..graphs.structure import Graph
from ..obs import convergence as obs_convergence
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..runtime.psi_driver import DriverReport, PsiDriverBase
from .scheduler import AsyncChunkScheduler, ChunkedOperators
from .staleness import StalenessBound

__all__ = ["AsyncPsiDriver", "AsyncDriverReport"]


@dataclasses.dataclass
class AsyncDriverReport(DriverReport):
    """`DriverReport` plus the async-only observability fields."""

    max_staleness: int = 0            # max observed epoch spread
    overlap_efficiency: float = 0.0   # Σ worker busy time / wall (>1 ⇒ overlap)
    sync_sweeps: int = 0              # synchronous verification sweeps run
    rejected_certificates: int = 0    # under-tol gaps refused for τ-violation
    epochs: np.ndarray | None = None  # final per-chunk epoch vector
    tau: int = 0
    converged: bool = True            # certified + sync-verified under tol


class AsyncPsiDriver(PsiDriverBase):
    """Overlapped Power-ψ execution with bounded-staleness certificates.

    Same call surface as :class:`~repro.runtime.psi_driver.PsiDriver`:
    ``run(tol=..., max_iter=..., fail_hook=...)`` → a report, plus the
    elastic :meth:`rechunk`.

    **Hook semantics** (the fault-injection harness in
    :mod:`repro.resilience.faults` is built on exactly these contracts —
    see docs/RESILIENCE.md):

    * ``fail_hook(tick) -> bool`` — polled once per *epoch-floor advance*
      (the async analogue of the sync driver's per-chunk index; it is NOT
      called once per chunk step, so under heavy skew several chunk steps
      share one tick). Returning True simulates a whole-process crash: the
      in-memory board and epoch vector are dropped and restored from the
      last complete checkpoint (``ckpt_dir`` required for the restore to
      find anything; without it the restart silently resumes cold). The
      hook runs on the scheduling thread — keep it cheap.
    * ``delay_hook(chunk, epoch) -> seconds`` — a *straggler*: the chunk's
      worker sleeps that long before computing, holding its slice at the
      old epoch. The staleness bound τ then throttles the rest of the
      pipeline; a hang longer than the supervisor's attempt deadline is
      indistinguishable from a dead worker and is escalated there.
    * ``read_hook(reader, neighbor, epochs) -> lag`` — forces ``reader``'s
      next step to consume ``neighbor``'s slice from ``lag`` epochs ago,
      served from the epoch-tagged history ring (lag is clamped to
      ``[0, τ]`` — the harness can exercise the certificate's staleness
      correction but cannot fake a τ-violation the bound would forbid).
      Production runs leave it None: reads are latest-snapshot and their
      staleness arises only from genuine pipeline skew.

    ``host=`` shares an existing :class:`HostOperators` mirror instead of
    building one from (graph, activity) — the crash-recovery path and
    :meth:`rechunk` use it so the successor sees bit-identical w/row_lam
    accumulators (a rebuild from the re-exported graph would re-sum them
    in a different order and drift by ulps, breaking fixed-point parity).
    """

    def __init__(self, graph: Graph | None = None, activity=None, *,
                 num_chunks: int = 4,
                 tau: int = 2, ckpt_dir: str | None = None,
                 ckpt_every: int = 8, deadline_factor: float = 3.0,
                 dtype=jnp.float32, max_workers: int | None = None,
                 delay_hook: Callable[[int, int], float] | None = None,
                 read_hook=None, host: HostOperators | None = None):
        super().__init__(ckpt_dir=ckpt_dir, deadline_factor=deadline_factor)
        if host is None and (graph is None or activity is None):
            raise ValueError("AsyncPsiDriver needs (graph, activity) "
                             "or host=")
        self.num_chunks = int(num_chunks)
        self.tau = int(tau)
        self.ckpt_every = int(ckpt_every)
        self.dtype = dtype
        self.max_workers = max_workers
        self.delay_hook = delay_hook
        self.read_hook = read_hook
        self.host = (host if host is not None
                     else HostOperators.from_graph(graph, activity))
        self.ops = self.host.to_device(dtype)
        self.chunked = ChunkedOperators(self.host, num_chunks, dtype=dtype)
        self.sched = AsyncChunkScheduler(
            self.chunked, bound=StalenessBound(tau), max_workers=max_workers,
            delay_hook=delay_hook, read_hook=read_hook)
        self._warm_s: np.ndarray | None = None   # node order, set by rechunk

    @classmethod
    def from_engine(cls, engine, **kw) -> "AsyncPsiDriver":
        """Build a driver from a prepared ``async`` PsiEngine (inherits its
        chunk count and staleness bound)."""
        if getattr(engine, "sched", None) is None:
            raise ValueError("engine has no async scheduler state; "
                             "use make_engine('async', graph=..., ...)")
        kw.setdefault("num_chunks", engine.num_chunks)
        kw.setdefault("tau", engine.tau)
        kw.setdefault("dtype", engine.dtype)
        kw.setdefault("max_workers", engine.max_workers)
        kw.setdefault("delay_hook", engine.delay_hook)
        kw.setdefault("read_hook", engine.read_hook)
        return cls(engine.graph, engine.activity, **kw)

    # -- mutations between runs (O(Δ), reuse the scheduler's hooks) ------ #
    def patch_activity(self, users, lam=None, mu=None) -> None:
        self.host.patch_activity(users, lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        self.sched.patch_node_arrays()

    def patch_edges(self, src, dst) -> None:
        src, dst = self.host.patch_edges(src, dst)
        self.ops = self.host.to_device(self.dtype)
        if src.size:
            self.sched.patch_edges(src, dst)

    def remove_edges(self, src, dst) -> None:
        """Unfollow tombstones: delete from the host mirror and rebuild the
        touched chunks (same generation-guarded path as an insert)."""
        src, dst = self.host.remove_edges(src, dst)
        if src.size:
            self.ops = self.host.to_device(self.dtype)
            self.sched.patch_edges(src, dst)

    # -- execution ------------------------------------------------------- #
    def run(self, *, tol: float = 1e-8, max_iter: int = 2000,
            fail_hook: Callable[[int], bool] | None = None,
            epoch_hook: Callable[[int], None] | None = None,
            warm: bool = False) -> AsyncDriverReport:
        """Drive the pipeline to a certified + sync-verified ``tol``.

        The gap convention matches ``PsiDriver.run``: raw l1 (no ‖B‖
        scaling). ``max_iter`` bounds per-chunk epochs — comparable to the
        sync driver's iteration budget since one epoch of every chunk is
        one global iteration's worth of work.

        ``epoch_hook(min_epoch)`` fires on every epoch-floor advance and
        may call the driver's generation-guarded patch hooks
        (``patch_activity`` / ``patch_edges`` / ``remove_edges``) while the
        pipeline is live — the streaming ingestor's mid-flight entry point
        (repro.stream): a patch marks in-flight gap records untrusted, so
        termination is always certified on the *patched* operators.

        ``warm=True`` restarts the pipeline from the current board instead
        of the cold s₀ = c — the serving re-resolve path after O(Δ)
        patches (a ``rechunk`` warm carry, when staged, takes precedence).
        """
        sched = self.sched
        self._reset_tracking()
        if self._warm_s is not None:
            sched.reset(s0=self._warm_s)     # one-shot, like PsiDriver
            self._warm_s = None
        elif warm:
            # serving re-resolve: restart the pipeline from the current
            # board (≈ the previous fixed point after an O(Δ) patch) — the
            # streaming ingestor's warm path. The first run's board is
            # still the cold s₀ = c, so warm=True is always safe.
            sched.reset(s0=np.asarray(self.chunked.node_order(sched.board)))
        else:
            sched.reset()
        restarts = 0
        tick = 0
        last_ckpt = 0
        np_dtype = np.dtype(jnp.dtype(self.dtype).name)
        self._ckpt_save(0, dict(**sched.export_state(), it=np.int64(0)))

        def on_epoch(s: AsyncChunkScheduler, min_epoch: int) -> None:
            nonlocal restarts, tick, last_ckpt
            tick += 1
            if epoch_hook is not None:
                epoch_hook(min_epoch)
            if self.ckpt_dir and min_epoch >= last_ckpt + self.ckpt_every:
                self._ckpt_save(min_epoch, dict(**s.export_state(),
                                                it=np.int64(min_epoch)))
                last_ckpt = min_epoch
            if fail_hook is not None and fail_hook(tick):
                restarts += 1
                data = self._ckpt_restore_latest(dict(
                    s=np.zeros(self.chunked.n_pad, np_dtype),
                    epochs=np.zeros(self.num_chunks, np.int64),
                    it=np.int64(0)))
                if data is not None:
                    # the epoch vector rides in the checkpoint: the restart
                    # resumes the *skewed* pipeline, not a sync collapse
                    s.request_restore(data["s"], data["epochs"])
                    last_ckpt = int(data["it"])

        rec = obs_convergence.begin("async_driver")
        with obs_trace.span("async.run", tau=self.tau,
                            num_chunks=self.num_chunks) as sp:
            out = sched.run(tol=tol, max_epochs=max_iter, scale=1.0,
                            epoch_callback=on_epoch)
            sp.sync(out.s)
        obs_convergence.finish(rec, iterations=int(out.epochs.max()),
                               gap=out.gap, converged=bool(out.converged),
                               duration_s=sp.duration_s)
        obs_metrics.gauge(
            "psi_async_overlap_efficiency",
            "sum of worker busy seconds / wall seconds (>1 means overlap)"
        ).set(out.overlap_efficiency)
        obs_metrics.gauge("psi_async_max_staleness",
                          "max epoch spread seen by the last async run"
                          ).set(out.max_staleness)
        # step_log is per-run (cleared at run entry) and includes drained
        # steps; sync verification sweeps run on the main thread and are
        # reported via sync_sweeps, not per-step durations
        for chunk, _epoch, dur in sched.step_log:
            self._note_duration(chunk, dur)
        s_node = jnp.asarray(self.chunked.node_order(out.s), self.dtype)
        psi = np.asarray(self.ops.psi_epilogue(s_node))
        return AsyncDriverReport(
            iterations=int(out.epochs.max()), gap=out.gap,
            chunks=out.total_steps, restarts=restarts,
            slow_chunks=self._slow, psi=psi,
            chunk_durations=self._durations,
            slow_chunk_events=self._slow_events,
            max_staleness=out.max_staleness,
            overlap_efficiency=out.overlap_efficiency,
            sync_sweeps=out.sync_sweeps,
            rejected_certificates=out.rejected_certificates,
            epochs=out.epochs, tau=self.tau, converged=bool(out.converged))

    # ------------------------------------------------------------------ #
    def rechunk(self, num_chunks: int, *, tau: int | None = None
                ) -> "AsyncPsiDriver":
        """Elastic re-chunk: carry the board across a chunk-count change
        (the async analogue of ``PsiDriver.remesh``). The next ``run``
        warm-starts the new pipeline from the converted board."""
        s_node = self.chunked.node_order(self.sched.board)
        # host= (not graph()/activity() re-export): the successor inherits
        # the same accumulator state, so the fixed point is bit-identical
        driver = AsyncPsiDriver(
            host=self.host,
            num_chunks=num_chunks, tau=self.tau if tau is None else tau,
            ckpt_dir=self.ckpt_dir, ckpt_every=self.ckpt_every,
            deadline_factor=self.deadline_factor, dtype=self.dtype,
            max_workers=self.max_workers, delay_hook=self.delay_hook,
            read_hook=self.read_hook)
        driver._warm_s = np.asarray(s_node)
        return driver
