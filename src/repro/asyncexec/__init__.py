"""Bounded-staleness asynchronous Power-ψ execution (docs/ASYNC.md).

The contraction ρ(A) < 1 tolerates bounded-stale partials (Chazan–Miranker
chaotic relaxation), so chunk updates need not barrier every epoch:

* :mod:`staleness`  — the τ-lag model and the stale-corrected Eq. 19 gap
  certificate (ρ-inflation, τ-violation rejection).
* :mod:`scheduler`  — :class:`ChunkedOperators` (dst-row chunk decomposition
  of the iteration) and :class:`AsyncChunkScheduler` (epoch-tagged
  overlapped dispatch, straggler absorption, mid-flight O(Δ) patches).
* :mod:`executor`   — :class:`AsyncPsiDriver`, the checkpoint/restart +
  elastic front end sharing :class:`~repro.runtime.psi_driver.PsiDriverBase`
  with the synchronous driver.

The ``"async"`` engine backend (``make_engine("async", ...)``) delegates to
the scheduler, so `PsiService` and every parity harness can run it like any
other backend.
"""
from .executor import AsyncDriverReport, AsyncPsiDriver
from .scheduler import (AsyncChunkScheduler, ChunkArgs, ChunkedOperators,
                        SchedulerRun, make_chunk_step)
from .staleness import (GapCertificate, RhoEstimator, StalenessBound,
                        certify_gap)

__all__ = [
    "AsyncChunkScheduler", "AsyncDriverReport", "AsyncPsiDriver",
    "ChunkArgs", "ChunkedOperators", "GapCertificate", "RhoEstimator",
    "SchedulerRun", "StalenessBound", "certify_gap", "make_chunk_step",
]
