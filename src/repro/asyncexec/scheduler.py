"""Bounded-staleness chunk scheduler for Power-ψ.

The global Alg. 2 iteration ``s' = μ ⊙ ((s ⊙ 1/w) P) + c`` decomposes by
*destination* rows into C chunks: chunk k owns the contiguous node range
``[k·q, (k+1)·q)`` and its update reads the whole board (every chunk's
latest published slice) but writes only its own slice. Run synchronously,
one sweep of all chunks *is* one global iteration (the per-chunk
``segment_sum``s partition the edge set, so the chunk l1 gaps sum to the
global l1 gap bit-for-bit in f64 and to rounding in f32).

:class:`AsyncChunkScheduler` removes the barrier between those chunk steps:

* **epoch tags + double-buffered board** — every chunk carries an epoch
  counter; its step output is published into the shared board (a fresh
  functional array per publish, so in-flight readers keep their consistent
  snapshot) tagged with the new epoch.
* **overlapped dispatch** — the scheduling thread submits every eligible
  chunk to a worker pool and *never* blocks on device values
  (``block_until_ready``-free: workers force their own results; the main
  thread only composes already-materialized buffers).
* **straggler absorption** — a chunk may be dispatched while up to
  ``tau`` epochs behind the fastest chunk (:class:`StalenessBound`); a slow
  worker therefore stalls the pipeline only when someone would otherwise
  run more than ``tau`` ahead, instead of stalling every epoch the way a
  bulk-synchronous barrier does. ``tau = 0`` recovers exactly the
  barriered schedule — the apples-to-apples baseline the benchmarks use.
* **mid-flight patches** — ``patch_node_arrays`` / ``patch_edges`` swap the
  affected chunks' operator args between that chunk's epochs without
  draining the pipeline; a generation counter marks pre-patch gap records
  untrusted so the certificate never terminates on stale operators.

Termination: per-chunk gaps are assembled into a
:class:`~repro.asyncexec.staleness.GapCertificate`; when the certificate
*accepts* (within-τ spread, certified ρ-inflated gap ≤ tol) the scheduler
drains in-flight work and runs one synchronous verification sweep — the
final convergence decision is always a true same-epoch Eq. 19 gap, so the
answer is bitwise-checkable against the synchronous solvers' rule.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..core.operators import HostOperators
from ..obs import convergence as obs_convergence
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .staleness import (GapCertificate, RhoEstimator, StalenessBound,
                        certify_gap)

__all__ = ["ChunkArgs", "ChunkedOperators", "AsyncChunkScheduler",
           "SchedulerRun", "make_chunk_step"]


@dataclasses.dataclass(frozen=True)
class ChunkArgs:
    """Device args of one chunk's step (a pytree; shapes uniform across
    chunks so one compiled step serves all of them)."""

    src: jax.Array        # i32[e_max] board index of edge src; sentinel n_pad
    dst_local: jax.Array  # i32[e_max] dst − k·q in [0, q); sentinel q
    mu: jax.Array         # f[q]
    c: jax.Array          # f[q]
    inv_w: jax.Array      # f[n_pad] — shared (same array object every chunk)
    start: jax.Array      # i32 scalar: board offset k·q


jax.tree_util.register_dataclass(
    ChunkArgs,
    data_fields=["src", "dst_local", "mu", "c", "inv_w", "start"],
    meta_fields=[])


def make_chunk_step(q: int):
    """The pure per-chunk step ``(ChunkArgs, board) -> (s_k_new, raw_gap_k)``.

    Identical math to one dst-row block of the reference iteration: gather
    the board through 1/w, sorted segment-sum onto the chunk's q nodes,
    μ/c epilogue, l1 delta against the chunk's current board slice.
    """

    def chunk_step(args: ChunkArgs, board: jax.Array):
        s_pre = jnp.concatenate(
            [board * args.inv_w, jnp.zeros((1,), board.dtype)])
        contrib = s_pre[args.src]
        t = jax.ops.segment_sum(contrib, args.dst_local, num_segments=q + 1,
                                indices_are_sorted=True)[:q]
        s_new = args.mu * t + args.c
        s_old = jax.lax.dynamic_slice(board, (args.start,), (q,))
        return s_new, jnp.sum(jnp.abs(s_new - s_old))

    return chunk_step


class ChunkedOperators:
    """Host-buildable, incrementally patchable chunk decomposition.

    Built from the same mutable :class:`HostOperators` mirror the engines
    patch, so the O(Δ) serving hooks compose: an activity patch refreshes
    only the O(N) node vectors; an edge patch rebuilds only the touched
    chunks' edge arrays (the dst-sorted host view makes each chunk's edges
    one contiguous slice). ``e_max`` is lane-padded with sentinel slots;
    only a genuine chunk overflow regrows it (one retrace).
    """

    def __init__(self, host: HostOperators, num_chunks: int, *,
                 dtype=jnp.float32, lane_pad: int = 128):
        if num_chunks < 1:
            raise ValueError(f"num_chunks must be >= 1; got {num_chunks}")
        self.host = host
        self.num_chunks = int(num_chunks)
        self.dtype = dtype
        self.lane_pad = int(lane_pad)
        self.n = host.n
        self.q = -(-host.n // self.num_chunks)
        self.n_pad = self.q * self.num_chunks
        self._np_dtype = np.dtype(jnp.dtype(dtype).name)
        self.e_max = 0
        self.args: list[ChunkArgs] = [None] * self.num_chunks
        self._refresh_node_pads()
        self.refresh_edges()

    # -- layout converters ---------------------------------------------- #
    def _pad(self, v: np.ndarray) -> np.ndarray:
        out = np.zeros(self.n_pad, self._np_dtype)
        out[: self.n] = v.astype(self._np_dtype)
        return out

    def board_from_node_order(self, s) -> jax.Array:
        return jnp.asarray(self._pad(np.asarray(s)))

    def node_order(self, board) -> np.ndarray:
        return np.asarray(board)[: self.n]

    @property
    def board0(self) -> jax.Array:
        """Cold start s₀ = c (pad nodes at 0, where μ = c = 0 keeps them)."""
        c, _ = self.host.cd()
        return jnp.asarray(self._pad(c))

    # -- (re)builds ------------------------------------------------------ #
    def _refresh_node_pads(self) -> None:
        c, _ = self.host.cd()
        self._inv_w_pad = jnp.asarray(self._pad(self.host.inv_w))
        self._mu_pad = self._pad(self.host.mu)
        self._c_pad = self._pad(c)

    def _chunk_bounds(self) -> tuple[np.ndarray, np.ndarray]:
        edges = np.arange(self.num_chunks + 1, dtype=np.int64) * self.q
        cut = np.searchsorted(self.host.dst_by_dst, edges, side="left")
        return cut[:-1], cut[1:]

    def _build_chunk(self, k: int, lo: int, hi: int) -> ChunkArgs:
        cnt = hi - lo
        src = np.full(self.e_max, self.n_pad, np.int32)
        dstl = np.full(self.e_max, self.q, np.int32)
        src[:cnt] = self.host.src_by_dst[lo:hi]
        dstl[:cnt] = self.host.dst_by_dst[lo:hi] - k * self.q
        sl = slice(k * self.q, (k + 1) * self.q)
        return ChunkArgs(
            src=jnp.asarray(src), dst_local=jnp.asarray(dstl),
            mu=jnp.asarray(self._mu_pad[sl]), c=jnp.asarray(self._c_pad[sl]),
            inv_w=self._inv_w_pad, start=jnp.asarray(k * self.q, jnp.int32))

    def refresh_edges(self, touched_chunks=None) -> bool:
        """Rebuild the edge arrays of ``touched_chunks`` (all when None)
        from the host mirror. Returns True when ``e_max`` grew (shape
        change — the compiled step retraces once)."""
        lo, hi = self._chunk_bounds()
        need = int((hi - lo).max()) if self.num_chunks else 0
        grew = need > self.e_max
        if grew or self.e_max == 0:
            self.e_max = max(-(-max(need, 1) // self.lane_pad)
                             * self.lane_pad, self.lane_pad)
            touched_chunks = None            # every chunk's shape changed
        ks = (range(self.num_chunks) if touched_chunks is None
              else sorted(set(int(k) for k in touched_chunks)))
        for k in ks:
            self.args[k] = self._build_chunk(k, int(lo[k]), int(hi[k]))
        return grew

    def refresh_node_arrays(self, touched_chunks=None) -> None:
        """Post-``patch_activity`` refresh: new μ/c slices + the shared
        1/w board vector (the latter changes every chunk's args, but it is
        one shared device array — O(N) once, not O(C·N))."""
        self._refresh_node_pads()
        for k in range(self.num_chunks):
            sl = slice(k * self.q, (k + 1) * self.q)
            self.args[k] = dataclasses.replace(
                self.args[k], mu=jnp.asarray(self._mu_pad[sl]),
                c=jnp.asarray(self._c_pad[sl]), inv_w=self._inv_w_pad)

    def chunks_of_nodes(self, nodes) -> np.ndarray:
        return np.unique(np.asarray(nodes, np.int64) // self.q)


@dataclasses.dataclass
class SchedulerRun:
    """Outcome of one :meth:`AsyncChunkScheduler.run`."""

    s: jax.Array                 # final board (padded layout)
    epochs: np.ndarray           # per-chunk epoch vector at exit
    gap: float                   # true synchronous Eq. 19 gap (scaled)
    converged: bool
    total_steps: int             # chunk-steps consumed (incl. sweeps)
    sync_sweeps: int             # verification sweeps run
    max_staleness: int           # max observed epoch spread
    overlap_efficiency: float    # Σ worker busy time / wall-clock (>1 ⇒ overlap)
    wall_s: float
    rejected_certificates: int   # gaps under tol refused for τ-violation
    certificate: GapCertificate | None


class AsyncChunkScheduler:
    """Overlapped bounded-staleness execution of a :class:`ChunkedOperators`.

    ``delay_hook(chunk, epoch) -> seconds`` injects a simulated straggler
    (slept inside that chunk's worker — the knob the benchmarks and tests
    turn). ``read_hook(reader, neighbor, epochs) -> lag`` forces the reader
    to consume ``neighbor``'s slice from ``lag`` epochs ago (served from the
    epoch-tagged history ring) — the staleness-injection harness the
    property tests drive; production reads take the latest board snapshot
    and their staleness arises only from genuine pipeline skew.
    """

    def __init__(self, chunked: ChunkedOperators, *,
                 bound: StalenessBound | None = None,
                 max_workers: int | None = None,
                 delay_hook: Callable[[int, int], float] | None = None,
                 read_hook: Callable[[int, int, np.ndarray], int]
                 | None = None):
        self.chunked = chunked
        self.bound = bound or StalenessBound()
        self.max_workers = max_workers
        self.delay_hook = delay_hook
        self.read_hook = read_hook
        self._step = jax.jit(make_chunk_step(chunked.q))
        # no buffer donation here: the board must outlive the publish
        # (in-flight readers hold snapshots up to τ epochs old — that IS
        # the double buffering) and the (q,)-shaped chunk result can never
        # alias the (n_pad,)-shaped output, so donating would be a no-op
        self._publish_jit = jax.jit(
            lambda board, s_new, start: jax.lax.dynamic_update_slice(
                board, s_new, (start,)))
        self._rho = RhoEstimator(init=self.bound.rho or 0.9)
        # per-run worker-step forensics, cleared at each run() entry
        self.step_log: list[tuple[int, int, float]] = []   # (chunk, epoch, s)
        self.patches_applied = 0
        self._restore: tuple[np.ndarray, np.ndarray] | None = None
        self._cancelled = False
        self.reset()

    # -- state ----------------------------------------------------------- #
    @property
    def num_chunks(self) -> int:
        return self.chunked.num_chunks

    def reset(self, s0=None, epochs=None) -> None:
        self.board = (self.chunked.board0 if s0 is None
                      else self.chunked.board_from_node_order(s0)
                      if np.shape(s0) == (self.chunked.n,)
                      else jnp.asarray(s0))
        self.epochs = (np.zeros(self.num_chunks, np.int64) if epochs is None
                       else np.asarray(epochs, np.int64).copy())
        self._gaps: list[tuple[float, int, int] | None] = (
            [None] * self.num_chunks)                 # (raw, epoch, gen)
        self._gen = getattr(self, "_gen", 0)
        self._history: list[dict[int, np.ndarray]] = [
            {} for _ in range(self.num_chunks)]
        if self.read_hook is not None:
            self._snapshot_history()
        self._rho.reset()

    def _rho_value(self) -> float:
        """A user-pinned a-priori ρ governs the certificate outright; the
        online estimate only fills in when no bound was given."""
        return self.bound.rho if self.bound.rho is not None \
            else self._rho.value

    def export_state(self) -> dict:
        """Checkpointable async state: the board *and* the epoch vector —
        a restart resumes the skewed pipeline exactly, not an approximation
        of it (in-flight steps are the only lost work)."""
        return dict(s=np.asarray(self.board), epochs=self.epochs.copy())

    def request_restore(self, s: np.ndarray, epochs: np.ndarray) -> None:
        """Ask the run loop to drop in-flight work and resume from a
        checkpointed (board, epoch-vector) pair (callable from
        ``epoch_callback``)."""
        self._restore = (np.asarray(s), np.asarray(epochs, np.int64))

    def cancel(self) -> None:
        """Cooperatively abort the current :meth:`run` — thread-safe, so a
        watchdog (e.g. the resilience supervisor's per-attempt deadline
        timer) can call it while the scheduling thread is inside the loop.
        The run returns its current (unconverged) state at the next loop
        check; hung workers are abandoned to the pool rather than joined,
        so a stuck chunk cannot hold the deadline hostage."""
        self._cancelled = True

    @property
    def cancelled(self) -> bool:
        """True when the last :meth:`run` exited via :meth:`cancel`."""
        return self._cancelled

    # -- mid-flight patches ---------------------------------------------- #
    def patch_node_arrays(self, users=None) -> None:
        """Adopt a host-side activity patch without draining the pipeline:
        args swap now, in-flight steps finish against the old operators and
        their gap records are generation-marked so the certificate ignores
        them (their published slices are just one more bounded-stale
        iterate, which the contraction absorbs)."""
        self.chunked.refresh_node_arrays()
        self._gen += 1

    def patch_edges(self, src, dst) -> None:
        """Adopt a host-side edge patch; only the touched dst chunks'
        edge arrays rebuild (O(edges-in-chunk) host work, O(Δ) chunks).
        Node pads refresh first — a new edge (j → i) changed w_j, so the
        shared 1/w board vector must be current before any chunk rebuild."""
        touched = self.chunked.chunks_of_nodes(dst)
        self.chunked.refresh_node_arrays()
        self.chunked.refresh_edges(touched)
        self._gen += 1

    # -- execution -------------------------------------------------------- #
    def _worker(self, k: int, args: ChunkArgs, board: jax.Array,
                delay: float, epoch: int = -1):
        # the span both times the step (shared clock — step_log, the
        # psi_chunk_seconds histogram and the trace agree) and exercises
        # per-thread span stacks: workers run in the scheduler's pool;
        # the (chunk, epoch) attrs let the profiler's critical-path walk
        # name which chunk chain bounds wall-clock
        with obs_trace.span("async.step", chunk=k, epoch=epoch) as sp:
            if delay and delay > 0:
                time.sleep(float(delay))
            s_new, gap = self._step(args, board)
            raw = float(gap)                 # forces the step in the worker
        return s_new, raw, sp.duration_s

    def _publish(self, k: int, s_new: jax.Array) -> None:
        if self.read_hook is not None:
            self._history[k][int(self.epochs[k]) + 1] = np.asarray(s_new)
            for e in sorted(self._history[k])[:-(self.bound.tau + 2)]:
                del self._history[k][e]
        self.board = self._publish_jit(
            self.board, s_new, jnp.asarray(k * self.chunked.q, jnp.int32))
        self.epochs[k] += 1

    def _snapshot_history(self) -> None:
        host = np.asarray(self.board)
        q = self.chunked.q
        for k in range(self.num_chunks):
            self._history[k][int(self.epochs[k])] = host[k * q:(k + 1) * q]

    def _compose_read(self, reader: int) -> jax.Array:
        """History-served board for the staleness-injection harness."""
        q = self.chunked.q
        parts = []
        for j in range(self.num_chunks):
            lag = 0 if j == reader else int(
                self.read_hook(reader, j, self.epochs))
            lag = max(0, min(lag, self.bound.tau))
            have = sorted(self._history[j])
            want = int(self.epochs[j]) - lag
            epoch = max([e for e in have if e <= want], default=have[0])
            parts.append(self._history[j][epoch])
        return jnp.asarray(np.concatenate(parts))

    def sync_sweep(self, board=None):
        """One *synchronous* global iteration: every chunk steps against the
        same input board. Returns ``(new_board, raw_l1_gap)`` — the exact
        Alg. 2 step + Eq. 19 gap the synchronous backends compute."""
        board = self.board if board is None else board
        outs = [self._step(self.chunked.args[k], board)
                for k in range(self.num_chunks)]
        new = board
        raw = 0.0
        for k, (s_new, g) in enumerate(outs):
            new = self._publish_jit(
                new, s_new, jnp.asarray(k * self.chunked.q, jnp.int32))
            raw += float(g)
        return new, raw

    def run(self, *, tol: float, max_epochs: int = 10_000,
            scale: float = 1.0, s0=None,
            epoch_callback: Callable[["AsyncChunkScheduler", int], None]
            | None = None) -> SchedulerRun:
        """Drive the pipeline until a certified + verified Eq. 19 stop.

        ``epoch_callback(scheduler, min_epoch)`` fires whenever the epoch
        *floor* advances — the async analogue of the sync driver's
        between-chunk hook point (checkpointing, failure injection via
        :meth:`request_restore`, elastic decisions).
        """
        C = self.num_chunks
        tau = self.bound.tau
        if s0 is not None:
            self.reset(s0=s0)
        busy = 0.0
        total_steps = 0
        sync_sweeps = 0
        max_stale = 0
        rejected = 0
        cert: GapCertificate | None = None
        converged = False
        gap = float("inf")
        self.step_log.clear()            # per-run forensics (see driver)
        self._cancelled = False          # a prior run's cancel doesn't carry
        t_start = time.perf_counter()
        inflight: dict[int, tuple] = {}
        pool = ThreadPoolExecutor(max_workers=self.max_workers or C)
        try:
            while True:
                if self._cancelled:
                    break
                min_e = int(self.epochs.min())
                for k in range(C):
                    if k in inflight or self.epochs[k] >= max_epochs:
                        continue
                    if self.epochs[k] - min_e > tau:
                        continue                      # bounded staleness
                    next_epoch = int(self.epochs[k]) + 1
                    delay = (self.delay_hook(k, next_epoch)
                             if self.delay_hook else 0.0)
                    board_read = (self._compose_read(k)
                                  if self.read_hook is not None
                                  else self.board)
                    inflight[k] = (pool.submit(
                        self._worker, k, self.chunked.args[k], board_read,
                        delay, next_epoch), self._gen)
                if not inflight:
                    break                             # epoch budget exhausted
                # bounded wait: a hung worker (fault injection, a wedged
                # device) must not block the cancel check above forever
                wait([f for f, _ in inflight.values()],
                     return_when=FIRST_COMPLETED, timeout=0.2)
                for k in [k for k, (f, _) in inflight.items() if f.done()]:
                    fut, gen = inflight.pop(k)
                    s_new, raw, dur = fut.result()
                    self._publish(k, s_new)
                    self._gaps[k] = (raw, int(self.epochs[k]), gen)
                    self.step_log.append((k, int(self.epochs[k]), dur))
                    busy += dur
                    total_steps += 1
                spread = int(self.epochs.max() - self.epochs.min())
                max_stale = max(max_stale, spread)
                obs_metrics.gauge(
                    "psi_async_epoch_spread",
                    "current max-min per-chunk epoch skew").set(spread)
                new_min = int(self.epochs.min())
                if new_min > min_e and epoch_callback is not None:
                    epoch_callback(self, new_min)
                if self._restore is not None:
                    s, e = self._restore
                    self._restore = None
                    for f, _ in inflight.values():    # discard lost work
                        f.cancel()
                    wait([f for f, _ in inflight.values()])
                    inflight.clear()
                    self.reset(s0=jnp.asarray(s), epochs=e)
                    continue
                if any(g is None or g[2] != self._gen for g in self._gaps):
                    continue                          # pre-patch / cold gaps
                cert = certify_gap(
                    [g[0] for g in self._gaps], [g[1] for g in self._gaps],
                    bound=self.bound, rho=self._rho_value(), scale=scale)
                if not cert.trusted:
                    # mid-epoch skew is routine (completions land one at a
                    # time); only a gap that would have *certified* on
                    # magnitude but was refused for staleness is a real
                    # rejection event
                    if cert.certified_gap <= tol:
                        rejected += 1
                        obs_metrics.counter(
                            "psi_async_rejected_certificates_total",
                            "stale-refused certificates that passed on "
                            "magnitude").inc()
                    continue
                obs_convergence.record_gap(total_steps, raw=cert.raw_gap,
                                           certified=cert.certified_gap)
                self._rho.update(cert.raw_gap)
                if cert.certified_gap > tol:
                    continue
                # certificate accepted → drain + synchronous verification
                wait([f for f, _ in inflight.values()])
                for k in [k for k, (f, _) in inflight.items() if f.done()]:
                    fut, gen = inflight.pop(k)
                    s_new, raw, dur = fut.result()
                    self._publish(k, s_new)
                    self._gaps[k] = (raw, int(self.epochs[k]), gen)
                    self.step_log.append((k, int(self.epochs[k]), dur))
                    busy += dur
                    total_steps += 1
                self.board, raw_sync = self.sync_sweep()
                self.epochs[:] = int(self.epochs.max()) + 1
                e_now = int(self.epochs[0])
                self._gaps = [(raw_sync / C, e_now, self._gen)] * C
                if self.read_hook is not None:
                    self._snapshot_history()
                sync_sweeps += 1
                total_steps += C
                gap = scale * raw_sync
                # the sealing sweep's gap is the *verified* Eq. 19 gap
                obs_convergence.record_gap(total_steps, raw=raw_sync,
                                           certified=gap)
                self._rho.update(gap)
                if gap <= tol:
                    converged = True
                    break
        finally:
            if self._cancelled:
                # abandon hung workers: drop queued steps, don't join the
                # running ones — their results are never published (inflight
                # is dead after return) and the threads drain in background
                for f, _ in inflight.values():
                    f.cancel()
                pool.shutdown(wait=False, cancel_futures=True)
            else:
                pool.shutdown(wait=True)
        wall = time.perf_counter() - t_start
        if not converged and gap == float("inf") and self._gaps[0]:
            gap = scale * sum(g[0] for g in self._gaps if g)
        return SchedulerRun(
            s=self.board, epochs=self.epochs.copy(), gap=float(gap),
            converged=converged, total_steps=total_steps,
            sync_sweeps=sync_sweeps, max_staleness=max_stale,
            overlap_efficiency=busy / max(wall, 1e-9), wall_s=wall,
            rejected_certificates=rejected, certificate=cert)
