"""Edge-form ψ-score operators.

All four matrices of the paper (Table I) are functions of the edge list and
the activity rates, and every product the algorithms need reduces to one
gather → segment-sum → scale pattern:

    w_j       = Σ_{ℓ∈L(j)} (λ_ℓ + μ_ℓ)                    (news-feed rate)
    A[j, i]   = μ_i / w_j   · 1{i ∈ L(j)}
    B[j, i]   = λ_i / w_j   · 1{i ∈ L(j)}
    c_i       = μ_i / (λ_i + μ_i)
    d_i       = λ_i / (λ_i + μ_i)

Left mat-vec (Power-ψ):   (sᵀA)_i = μ_i Σ_{(j→i)∈E} s_j / w_j
Right mat-vec (Power-NF): (A p)_j = (1/w_j) Σ_{(j→i)∈E} μ_i p_i

Both share the gather/scatter; only the scatter axis differs (dst vs src).
We therefore store the edge list twice, each sorted by its scatter axis, so
XLA's scatter runs in sorted mode.

Nodes with no leaders (w_j = 0) have empty A/B rows — handled by a masked
reciprocal, exactly matching the linear-system semantics of the paper.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.structure import Graph
from .activity import Activity

__all__ = ["PsiOperators", "build_operators"]


@dataclasses.dataclass(frozen=True)
class PsiOperators:
    """Device-resident edge-form operators for one (graph, activity) pair."""

    n: int
    m: int
    # edges sorted by dst — scatter axis of the left mat-vec
    src_by_dst: jax.Array  # int32[M]
    dst_by_dst: jax.Array  # int32[M]
    # edges sorted by src — scatter axis of the right mat-vec
    src_by_src: jax.Array  # int32[M]
    dst_by_src: jax.Array  # int32[M]
    lam: jax.Array         # f[N]
    mu: jax.Array          # f[N]
    inv_w: jax.Array       # f[N], 0 where w == 0
    c: jax.Array           # f[N] = μ/(λ+μ)
    d: jax.Array           # f[N] = λ/(λ+μ)
    b_norm: jax.Array      # scalar ‖B‖ used by Alg. 2's termination rule

    # ------------------------------------------------------------------ #
    @property
    def dtype(self):
        return self.lam.dtype

    def push(self, s: jax.Array) -> jax.Array:
        """Shared left gather/scatter: t_i = Σ_{(j→i)} s_j / w_j.

        ``sᵀA = μ ⊙ t`` and ``sᵀB = λ ⊙ t`` — one scatter serves both, which
        is the fused epilogue trick recorded in EXPERIMENTS.md §Perf.
        """
        contrib = (s * self.inv_w)[self.src_by_dst]
        return jax.ops.segment_sum(contrib, self.dst_by_dst, self.n,
                                   indices_are_sorted=True)

    def left_matvec(self, s: jax.Array) -> jax.Array:
        """sᵀA as a column vector."""
        return self.mu * self.push(s)

    def psi_epilogue(self, s: jax.Array) -> jax.Array:
        """ψᵀ = (sᵀB + dᵀ)/N  (Eq. 12 epilogue)."""
        return (self.lam * self.push(s) + self.d) / self.n

    def right_matvec(self, p: jax.Array) -> jax.Array:
        """A p — used by the Power-NF baseline. Supports batched p [N, K]."""
        vals = (self.mu * p.T).T[self.dst_by_src]
        agg = jax.ops.segment_sum(vals, self.src_by_src, self.n,
                                  indices_are_sorted=True)
        return (self.inv_w * agg.T).T

    def b_columns(self, origins: jax.Array) -> jax.Array:
        """Dense [N, K] slice of B for a chunk of origin users (Power-NF)."""
        k = origins.shape[0]
        # edge (j -> i): b[j, col] = λ_i / w_j where i == origins[col]
        hit = self.dst_by_src[:, None] == origins[None, :]        # [M, K]
        vals = jnp.where(hit, self.lam[self.dst_by_src][:, None], 0.0)
        agg = jax.ops.segment_sum(vals, self.src_by_src, self.n,
                                  indices_are_sorted=True)         # [N, K]
        return (self.inv_w[:, None] * agg).astype(self.dtype)


jax.tree_util.register_dataclass(
    PsiOperators,
    data_fields=["src_by_dst", "dst_by_dst", "src_by_src", "dst_by_src",
                 "lam", "mu", "inv_w", "c", "d", "b_norm"],
    meta_fields=["n", "m"],
)


def _induced_l1T_norm(n, src, dst, lam, inv_w) -> np.ndarray:
    """max_j Σ_{i∈L(j)} λ_i / w_j — the operator norm with ‖sᵀB‖₁ ≤ ‖B‖‖s‖₁."""
    row = np.zeros(n, lam.dtype)
    np.add.at(row, src, lam[dst])
    return (row * inv_w).max() if n else np.asarray(0.0, lam.dtype)


def build_operators(graph: Graph, activity: Activity, *,
                    dtype=jnp.float32) -> PsiOperators:
    """Precompute the edge-form operators on host, then place on device."""
    if activity.n != graph.n:
        raise ValueError("activity/graph size mismatch")
    np_dtype = np.dtype(jnp.dtype(dtype).name)
    lam = activity.lam.astype(np_dtype)
    mu = activity.mu.astype(np_dtype)
    total = lam + mu
    # w_j = Σ_{leaders i of j} (λ_i + μ_i): scatter (λ+μ)[dst] onto src
    w = np.zeros(graph.n, np_dtype)
    np.add.at(w, graph.src, total[graph.dst])
    inv_w = np.where(w > 0, 1.0 / np.where(w > 0, w, 1.0), 0.0).astype(np_dtype)
    with np.errstate(divide="ignore", invalid="ignore"):
        c = np.where(total > 0, mu / total, 0.0).astype(np_dtype)
        d = np.where(total > 0, lam / total, 0.0).astype(np_dtype)
    b_norm = _induced_l1T_norm(graph.n, graph.src, graph.dst, lam, inv_w)

    s_d, d_d = graph.edges_by_dst
    s_s, d_s = graph.edges_by_src
    dev = partial(jnp.asarray)
    return PsiOperators(
        n=graph.n, m=graph.m,
        src_by_dst=dev(s_d), dst_by_dst=dev(d_d),
        src_by_src=dev(s_s), dst_by_src=dev(d_s),
        lam=dev(lam), mu=dev(mu), inv_w=dev(inv_w),
        c=dev(c), d=dev(d),
        b_norm=jnp.asarray(b_norm, dtype),
    )


# ---------------------------------------------------------------------- #
# Dense forms — oracles for tests and the exact solver (small N only).
# ---------------------------------------------------------------------- #
def dense_operators(graph: Graph, activity: Activity):
    """Return (A, B, c, d) as dense float64 numpy arrays."""
    n = graph.n
    lam = activity.lam.astype(np.float64)
    mu = activity.mu.astype(np.float64)
    total = lam + mu
    w = np.zeros(n)
    np.add.at(w, graph.src, total[graph.dst])
    inv_w = np.where(w > 0, 1.0 / np.where(w > 0, w, 1.0), 0.0)
    A = np.zeros((n, n))
    B = np.zeros((n, n))
    A[graph.src, graph.dst] = mu[graph.dst] * inv_w[graph.src]
    B[graph.src, graph.dst] = lam[graph.dst] * inv_w[graph.src]
    with np.errstate(divide="ignore", invalid="ignore"):
        c = np.where(total > 0, mu / total, 0.0)
        d = np.where(total > 0, lam / total, 0.0)
    return A, B, c, d
