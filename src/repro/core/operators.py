"""Edge-form ψ-score operators.

All four matrices of the paper (Table I) are functions of the edge list and
the activity rates, and every product the algorithms need reduces to one
gather → segment-sum → scale pattern:

    w_j       = Σ_{ℓ∈L(j)} (λ_ℓ + μ_ℓ)                    (news-feed rate)
    A[j, i]   = μ_i / w_j   · 1{i ∈ L(j)}
    B[j, i]   = λ_i / w_j   · 1{i ∈ L(j)}
    c_i       = μ_i / (λ_i + μ_i)
    d_i       = λ_i / (λ_i + μ_i)

Left mat-vec (Power-ψ):   (sᵀA)_i = μ_i Σ_{(j→i)∈E} s_j / w_j
Right mat-vec (Power-NF): (A p)_j = (1/w_j) Σ_{(j→i)∈E} μ_i p_i

Both share the gather/scatter; only the scatter axis differs (dst vs src).
We therefore store the edge list twice, each sorted by its scatter axis, so
XLA's scatter runs in sorted mode.

Nodes with no leaders (w_j = 0) have empty A/B rows — handled by a masked
reciprocal, exactly matching the linear-system semantics of the paper.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.structure import Graph
from .activity import Activity

__all__ = ["PsiOperators", "build_operators", "HostOperators"]


@dataclasses.dataclass(frozen=True)
class PsiOperators:
    """Device-resident edge-form operators for one (graph, activity) pair."""

    n: int
    m: int
    # edges sorted by dst — scatter axis of the left mat-vec
    src_by_dst: jax.Array  # int32[M]
    dst_by_dst: jax.Array  # int32[M]
    # edges sorted by src — scatter axis of the right mat-vec
    src_by_src: jax.Array  # int32[M]
    dst_by_src: jax.Array  # int32[M]
    lam: jax.Array         # f[N]
    mu: jax.Array          # f[N]
    inv_w: jax.Array       # f[N], 0 where w == 0
    c: jax.Array           # f[N] = μ/(λ+μ)
    d: jax.Array           # f[N] = λ/(λ+μ)
    b_norm: jax.Array      # scalar ‖B‖ used by Alg. 2's termination rule

    # ------------------------------------------------------------------ #
    @property
    def dtype(self):
        return self.lam.dtype

    def push(self, s: jax.Array) -> jax.Array:
        """Shared left gather/scatter: t_i = Σ_{(j→i)} s_j / w_j.

        ``sᵀA = μ ⊙ t`` and ``sᵀB = λ ⊙ t`` — one scatter serves both, which
        is the fused epilogue trick recorded in EXPERIMENTS.md §Perf.
        """
        contrib = (s * self.inv_w)[self.src_by_dst]
        return jax.ops.segment_sum(contrib, self.dst_by_dst, self.n,
                                   indices_are_sorted=True)

    def left_matvec(self, s: jax.Array) -> jax.Array:
        """sᵀA as a column vector."""
        return self.mu * self.push(s)

    def psi_epilogue(self, s: jax.Array) -> jax.Array:
        """ψᵀ = (sᵀB + dᵀ)/N  (Eq. 12 epilogue)."""
        return (self.lam * self.push(s) + self.d) / self.n

    def right_matvec(self, p: jax.Array) -> jax.Array:
        """A p — used by the Power-NF baseline. Supports batched p [N, K]."""
        vals = (self.mu * p.T).T[self.dst_by_src]
        agg = jax.ops.segment_sum(vals, self.src_by_src, self.n,
                                  indices_are_sorted=True)
        return (self.inv_w * agg.T).T

    def b_columns(self, origins: jax.Array) -> jax.Array:
        """Dense [N, K] slice of B for a chunk of origin users (Power-NF)."""
        k = origins.shape[0]
        # edge (j -> i): b[j, col] = λ_i / w_j where i == origins[col]
        hit = self.dst_by_src[:, None] == origins[None, :]        # [M, K]
        vals = jnp.where(hit, self.lam[self.dst_by_src][:, None], 0.0)
        agg = jax.ops.segment_sum(vals, self.src_by_src, self.n,
                                  indices_are_sorted=True)         # [N, K]
        return (self.inv_w[:, None] * agg).astype(self.dtype)


jax.tree_util.register_dataclass(
    PsiOperators,
    data_fields=["src_by_dst", "dst_by_dst", "src_by_src", "dst_by_src",
                 "lam", "mu", "inv_w", "c", "d", "b_norm"],
    meta_fields=["n", "m"],
)


def _induced_l1T_norm(n, src, dst, lam, inv_w) -> np.ndarray:
    """max_j Σ_{i∈L(j)} λ_i / w_j — the operator norm with ‖sᵀB‖₁ ≤ ‖B‖‖s‖₁."""
    row = np.zeros(n, lam.dtype)
    np.add.at(row, src, lam[dst])
    return (row * inv_w).max() if n else np.asarray(0.0, lam.dtype)


def build_operators(graph: Graph, activity: Activity, *,
                    dtype=jnp.float32) -> PsiOperators:
    """Precompute the edge-form operators on host, then place on device."""
    if activity.n != graph.n:
        raise ValueError("activity/graph size mismatch")
    np_dtype = np.dtype(jnp.dtype(dtype).name)
    lam = activity.lam.astype(np_dtype)
    mu = activity.mu.astype(np_dtype)
    total = lam + mu
    # w_j = Σ_{leaders i of j} (λ_i + μ_i): scatter (λ+μ)[dst] onto src
    w = np.zeros(graph.n, np_dtype)
    np.add.at(w, graph.src, total[graph.dst])
    inv_w = np.where(w > 0, 1.0 / np.where(w > 0, w, 1.0), 0.0).astype(np_dtype)
    with np.errstate(divide="ignore", invalid="ignore"):
        c = np.where(total > 0, mu / total, 0.0).astype(np_dtype)
        d = np.where(total > 0, lam / total, 0.0).astype(np_dtype)
    b_norm = _induced_l1T_norm(graph.n, graph.src, graph.dst, lam, inv_w)

    s_d, d_d = graph.edges_by_dst
    s_s, d_s = graph.edges_by_src
    dev = partial(jnp.asarray)
    return PsiOperators(
        n=graph.n, m=graph.m,
        src_by_dst=dev(s_d), dst_by_dst=dev(d_d),
        src_by_src=dev(s_s), dst_by_src=dev(d_s),
        lam=dev(lam), mu=dev(mu), inv_w=dev(inv_w),
        c=dev(c), d=dev(d),
        b_norm=jnp.asarray(b_norm, dtype),
    )


# ---------------------------------------------------------------------- #
# Mutable host mirror — O(Δ) incremental patches for the serving runtime.
# ---------------------------------------------------------------------- #
def _concat_ranges(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    if lo.size == 0:
        return np.empty(0, np.int64)
    return np.concatenate([np.arange(l, h) for l, h in zip(lo, hi)])


def _validate_rates(lam: np.ndarray | None, mu: np.ndarray | None) -> None:
    """Reject NaN/Inf and negative rates at the mutation boundary.

    The ``Activity`` constructor validates full vectors at build time, but
    incremental patches bypass it — a single poisoned λ would silently
    corrupt the w/row_lam accumulators of every follower it touches (and a
    NaN never washes out of an incremental sum). Raise *before* any state
    is mutated so a rejected patch leaves the operators untouched.
    """
    for name, arr in (("lam", lam), ("mu", mu)):
        if arr is None:
            continue
        arr = np.asarray(arr)
        if not np.all(np.isfinite(arr)):
            raise ValueError(
                f"non-finite {name} in activity patch "
                f"(offending values include "
                f"{arr[~np.isfinite(arr)][:3].tolist()})")
        if np.any(arr < 0):
            raise ValueError(
                f"negative {name} in activity patch (rates are event "
                f"intensities ≥ 0; offending values include "
                f"{arr[arr < 0][:3].tolist()})")


def _dedup_keep_last(users: np.ndarray, *cols: np.ndarray):
    """Unique users, keeping the *last* occurrence of each (update semantics)."""
    rev = users[::-1]
    uniq, first_rev = np.unique(rev, return_index=True)
    out_cols = tuple(None if c is None else np.asarray(c)[::-1][first_rev]
                     for c in cols)
    return uniq, out_cols


@dataclasses.dataclass
class HostOperators:
    """Host-side (float64, numpy) mirror of the edge-form operator arrays.

    Unlike :func:`build_operators` this state is *mutable* and supports
    incremental patches that cost O(Δ) edge work plus O(N) vector work —
    no edge re-sort, no full reconstruction:

      * :meth:`patch_activity` — λ/μ updates touch only the followers of the
        updated users (``w``/``row_lam`` scatter over those edges).
      * :meth:`patch_edges` — new follow edges are merged into the two sorted
        edge views with ``np.searchsorted`` + ``np.insert`` (one memmove, no
        re-sort of the M existing edges).
      * :meth:`remove_edges` — unfollow tombstones delete from both sorted
        views; touched followers' ``w``/``row_lam`` are recomputed exactly
        (a follower losing its last leader must hit w = 0, not a residue).

    ``to_device`` materializes a fresh :class:`PsiOperators` from the current
    arrays; the float64 host accumulators keep repeated incremental patches
    free of drift before the cast to the device dtype.
    """

    n: int
    lam: np.ndarray          # f64[N]
    mu: np.ndarray           # f64[N]
    src_by_dst: np.ndarray   # i32[M] — dst-sorted view
    dst_by_dst: np.ndarray   # i32[M]
    src_by_src: np.ndarray   # i32[M] — src-sorted view
    dst_by_src: np.ndarray   # i32[M]
    w: np.ndarray            # f64[N] news-feed rates
    row_lam: np.ndarray      # f64[N] Σ_{i∈L(j)} λ_i (the ‖B‖ numerator)

    # ------------------------------------------------------------------ #
    @classmethod
    def from_graph(cls, graph: Graph, activity: Activity) -> "HostOperators":
        if activity.n != graph.n:
            raise ValueError("activity/graph size mismatch")
        lam = activity.lam.astype(np.float64).copy()
        mu = activity.mu.astype(np.float64).copy()
        total = lam + mu
        w = np.zeros(graph.n)
        np.add.at(w, graph.src, total[graph.dst])
        row_lam = np.zeros(graph.n)
        np.add.at(row_lam, graph.src, lam[graph.dst])
        s_d, d_d = graph.edges_by_dst
        s_s, d_s = graph.edges_by_src
        return cls(n=graph.n, lam=lam, mu=mu,
                   src_by_dst=s_d.copy(), dst_by_dst=d_d.copy(),
                   src_by_src=s_s.copy(), dst_by_src=d_s.copy(),
                   w=w, row_lam=row_lam)

    @property
    def m(self) -> int:
        return int(self.src_by_dst.shape[0])

    @property
    def inv_w(self) -> np.ndarray:
        return np.where(self.w > 0, 1.0 / np.where(self.w > 0, self.w, 1.0),
                        0.0)

    @property
    def b_norm(self) -> float:
        return float((self.row_lam * self.inv_w).max()) if self.n else 0.0

    def activity(self) -> Activity:
        return Activity(self.lam.copy(), self.mu.copy())

    def cd(self) -> tuple[np.ndarray, np.ndarray]:
        """The paper's c = μ/(λ+μ), d = λ/(λ+μ) with silent-user masking —
        the one place the zero-total reciprocal rule lives (the fleet's
        padded lane arrays reuse it)."""
        total = self.lam + self.mu
        with np.errstate(divide="ignore", invalid="ignore"):
            c = np.where(total > 0, self.mu / total, 0.0)
            d = np.where(total > 0, self.lam / total, 0.0)
        return c, d

    def graph(self) -> Graph:
        """Rebuild a Graph view (src-sorted order, already deduped)."""
        return Graph(self.n, self.src_by_src.copy(), self.dst_by_src.copy())

    # ------------------------------------------------------------------ #
    def patch_activity(self, users: np.ndarray, lam: np.ndarray | None = None,
                       mu: np.ndarray | None = None) -> int:
        """Apply λ/μ updates; returns the number of edges touched (Δ)."""
        users = np.asarray(users, np.int64).reshape(-1)
        if lam is not None:     # scalars / length-1 broadcast, like fancy
            lam = np.broadcast_to(np.asarray(lam, np.float64), users.shape)
        if mu is not None:      # indexing assignment did before the refactor
            mu = np.broadcast_to(np.asarray(mu, np.float64), users.shape)
        users, (lam, mu) = _dedup_keep_last(users, lam, mu)
        _validate_rates(lam, mu)
        new_lam = self.lam[users] if lam is None else lam
        new_mu = self.mu[users] if mu is None else mu
        dl = new_lam - self.lam[users]
        dt = dl + (new_mu - self.mu[users])
        self.lam[users] = new_lam
        self.mu[users] = new_mu
        # followers of each updated user form a contiguous dst-sorted slice
        lo = np.searchsorted(self.dst_by_dst, users, side="left")
        hi = np.searchsorted(self.dst_by_dst, users, side="right")
        idx = _concat_ranges(lo, hi)
        counts = hi - lo
        fol = self.src_by_dst[idx]
        np.add.at(self.w, fol, np.repeat(dt, counts))
        np.add.at(self.row_lam, fol, np.repeat(dl, counts))
        return int(counts.sum())

    def filter_new_edges(self, src: np.ndarray,
                         dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """The edges :meth:`patch_edges` would actually insert — self-loops
        and duplicates (in-batch or vs existing) dropped — *without*
        mutating anything. Capacity pre-checks (e.g. the distributed
        backend's ``on_overflow='raise'``) rely on probing before the host
        mirror is committed."""
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        key = src.astype(np.int64) * self.n + dst
        _, uniq_idx = np.unique(key, return_index=True)
        src, dst = src[uniq_idx], dst[uniq_idx]
        fresh = np.ones(src.size, bool)
        for k, (s, d) in enumerate(zip(src, dst)):     # Δ is small in serving
            a = np.searchsorted(self.src_by_src, s, side="left")
            b = np.searchsorted(self.src_by_src, s, side="right")
            if np.any(self.dst_by_src[a:b] == d):
                fresh[k] = False
        return src[fresh], dst[fresh]

    def patch_edges(self, src: np.ndarray,
                    dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Merge new follow edges; returns the (src, dst) actually inserted
        (self-loops and duplicates — in-batch or vs existing — are dropped)."""
        src, dst = self.filter_new_edges(src, dst)
        return self.insert_filtered(src, dst)

    def insert_filtered(self, src: np.ndarray,
                        dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Commit edges that already passed :meth:`filter_new_edges` —
        callers that probed first (capacity pre-checks) skip the second
        per-edge dedup scan this way."""
        if src.size == 0:
            return src, dst
        # merge into the dst-sorted view
        o = np.argsort(dst, kind="stable")
        ins = np.searchsorted(self.dst_by_dst, dst[o], side="right")
        self.src_by_dst = np.insert(self.src_by_dst, ins, src[o])
        self.dst_by_dst = np.insert(self.dst_by_dst, ins, dst[o])
        # merge into the src-sorted view
        o2 = np.argsort(src, kind="stable")
        ins2 = np.searchsorted(self.src_by_src, src[o2], side="right")
        self.src_by_src = np.insert(self.src_by_src, ins2, src[o2])
        self.dst_by_src = np.insert(self.dst_by_src, ins2, dst[o2])
        # rate accumulators: each new edge (j → i) adds i's rates to j's feed
        np.add.at(self.w, src, self.lam[dst] + self.mu[dst])
        np.add.at(self.row_lam, src, self.lam[dst])
        return src, dst

    def remove_edges(self, src: np.ndarray,
                     dst: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Delete existing follow edges; returns the (src, dst) actually
        removed (pairs not present are ignored — an unfollow tombstone may
        refer to an edge that never materialized or was already dropped).

        O(Δ·log M) searches plus one memmove per sorted view. The touched
        followers' ``w`` / ``row_lam`` accumulators are *recomputed* from
        their remaining leader lists rather than decremented: a follower
        whose last leader disappears must land on w = 0 **exactly** (the
        masked reciprocal treats w ≤ 0 as "no feed"), and a float64
        subtraction of previously-added totals can leave a tiny residue
        whose reciprocal would be catastrophic.
        """
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        if src.size:
            key = src.astype(np.int64) * self.n + dst
            _, uniq = np.unique(key, return_index=True)
            src, dst = src[uniq], dst[uniq]
        hit_s: list[int] = []
        hit = np.zeros(src.size, bool)
        for k, (s, d) in enumerate(zip(src, dst)):   # Δ is small in serving
            a = np.searchsorted(self.src_by_src, s, side="left")
            b = np.searchsorted(self.src_by_src, s, side="right")
            j = np.nonzero(self.dst_by_src[a:b] == d)[0]
            if j.size:
                hit_s.append(int(a + j[0]))
                hit[k] = True
        src, dst = src[hit], dst[hit]
        if src.size == 0:
            return src, dst
        hit_d: list[int] = []
        for s, d in zip(src, dst):
            a = np.searchsorted(self.dst_by_dst, d, side="left")
            b = np.searchsorted(self.dst_by_dst, d, side="right")
            j = np.nonzero(self.src_by_dst[a:b] == s)[0]
            hit_d.append(int(a + j[0]))
        self.src_by_src = np.delete(self.src_by_src, hit_s)
        self.dst_by_src = np.delete(self.dst_by_src, hit_s)
        self.src_by_dst = np.delete(self.src_by_dst, hit_d)
        self.dst_by_dst = np.delete(self.dst_by_dst, hit_d)
        for j in np.unique(src):
            a = np.searchsorted(self.src_by_src, j, side="left")
            b = np.searchsorted(self.src_by_src, j, side="right")
            leaders = self.dst_by_src[a:b]
            self.w[j] = float((self.lam[leaders] + self.mu[leaders]).sum())
            self.row_lam[j] = float(self.lam[leaders].sum())
        return src, dst

    # ------------------------------------------------------------------ #
    def _node_arrays(self, dtype) -> dict:
        """The O(N) activity-derived device vectors (not the edge indices)."""
        np_dtype = np.dtype(jnp.dtype(dtype).name)
        c, d = self.cd()
        return dict(
            lam=jnp.asarray(self.lam.astype(np_dtype)),
            mu=jnp.asarray(self.mu.astype(np_dtype)),
            inv_w=jnp.asarray(self.inv_w.astype(np_dtype)),
            c=jnp.asarray(c.astype(np_dtype)),
            d=jnp.asarray(d.astype(np_dtype)),
            b_norm=jnp.asarray(self.b_norm, dtype),
        )

    def to_device(self, dtype=jnp.float32) -> PsiOperators:
        return PsiOperators(
            n=self.n, m=self.m,
            src_by_dst=jnp.asarray(self.src_by_dst),
            dst_by_dst=jnp.asarray(self.dst_by_dst),
            src_by_src=jnp.asarray(self.src_by_src),
            dst_by_src=jnp.asarray(self.dst_by_src),
            **self._node_arrays(dtype),
        )

    def refresh_node_arrays(self, ops: PsiOperators,
                            dtype=jnp.float32) -> PsiOperators:
        """Post-``patch_activity`` refresh: re-upload only the O(N) node
        vectors, reusing the device-resident O(M) edge indices (an activity
        patch never touches them)."""
        return dataclasses.replace(ops, **self._node_arrays(dtype))


# ---------------------------------------------------------------------- #
# Dense forms — oracles for tests and the exact solver (small N only).
# ---------------------------------------------------------------------- #
def dense_operators(graph: Graph, activity: Activity):
    """Return (A, B, c, d) as dense float64 numpy arrays."""
    n = graph.n
    lam = activity.lam.astype(np.float64)
    mu = activity.mu.astype(np.float64)
    total = lam + mu
    w = np.zeros(n)
    np.add.at(w, graph.src, total[graph.dst])
    inv_w = np.where(w > 0, 1.0 / np.where(w > 0, w, 1.0), 0.0)
    A = np.zeros((n, n))
    B = np.zeros((n, n))
    A[graph.src, graph.dst] = mu[graph.dst] * inv_w[graph.src]
    B[graph.src, graph.dst] = lam[graph.dst] * inv_w[graph.src]
    with np.errstate(divide="ignore", invalid="ignore"):
        c = np.where(total > 0, mu / total, 0.0)
        d = np.where(total > 0, lam / total, 0.0)
    return A, B, c, d
