"""Unified Power-ψ solver abstraction: one protocol, three backends.

Before this module the repo had four disjoint solver loops (``power_psi``,
``kernels.ops.PsiKernelEngine``, ``DistributedPsi.run_to_convergence`` and the
``PsiService`` rebuild path), each with its own while-loop, convergence rule
and warm-start story. ``PsiEngine`` folds them behind one contract:

    prepare(graph, activity) -> EngineState     # build operators, s₀ = c
    step(state) -> EngineState                  # one Alg. 2 iteration
    run(tol=..., max_iter=..., s0=...) -> PsiResult
    epilogue(s) -> psi                          # ψᵀ = (sᵀB + dᵀ)/N

Backends are registered by name and constructed through
:func:`make_engine`:

  * ``reference``   — the edge-form ``segment_sum`` iteration of
    :mod:`repro.core.power_psi` (works everywhere, float64-capable).
  * ``pallas``      — the fused TPU ``power_step`` Pallas kernel
    (interpret mode off-TPU); absorbs the old ``PsiKernelEngine``.
  * ``distributed`` — the 2-D block-cyclic ``shard_map`` schedule of
    :class:`repro.core.distributed.DistributedPsi`, driven in host-side
    chunks exactly like ``runtime/psi_driver.py``.

All backends share one :class:`ConvergenceCriterion` — ε on ‖B‖·‖Δs‖ per
Eq. 19 — and report interchangeable :class:`~repro.core.power_psi.PsiResult`
values (``s`` always returned in node order so a result from one backend can
warm-start any other). Engines also expose the O(Δ) delta-rebuild hooks
(``patch_activity`` / ``patch_edges``) the serving layer
(:class:`repro.core.incremental.PsiService`) is built on; a hook returns
``False`` when the backend cannot patch incrementally and the caller should
fall back to a full ``prepare``.

Registering a new backend (see docs/ENGINE.md)::

    @register_backend("mine")
    class MyEngine(PsiEngine):
        ...
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.structure import Graph
from .activity import Activity
from .operators import HostOperators, PsiOperators
from .power_psi import _NORMS, PsiResult

__all__ = ["ConvergenceCriterion", "EngineState", "PsiEngine",
           "ReferenceEngine", "PallasEngine", "DistributedEngine",
           "make_engine", "register_backend", "available_backends"]


# --------------------------------------------------------------------- #
# Shared convergence contract
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ConvergenceCriterion:
    """Alg. 2 termination rule, identical across backends.

    Stop when ``scale · ‖s_t − s_{t−1}‖_norm ≤ tol`` with ``scale = ‖B‖``
    when ``use_b_norm`` (Eq. 19: the ψ trajectory then moved ≤ tol/N), else
    1. ``matvecs`` accounting is shared too: one sparse mat-vec per
    iteration plus one for the ψ epilogue.
    """

    tol: float = 1e-9
    max_iter: int = 10_000
    norm: str = "l1"
    use_b_norm: bool = True

    def __post_init__(self):
        if self.norm not in _NORMS:
            raise ValueError(f"unknown norm {self.norm!r}; "
                             f"choose from {sorted(_NORMS)}")

    def norm_fn(self):
        return _NORMS[self.norm]

    def scale(self, b_norm) -> float:
        return float(b_norm) if self.use_b_norm else 1.0

    def resolve(self, tol: float | None,
                max_iter: int | None) -> tuple[float, int]:
        return (self.tol if tol is None else float(tol),
                self.max_iter if max_iter is None else int(max_iter))


@dataclasses.dataclass
class EngineState:
    """Backend-agnostic iteration state. ``s`` lives in the backend's native
    layout (node order / padded / sharded src layout)."""

    s: Any
    gap: float = float("inf")
    t: int = 0


# --------------------------------------------------------------------- #
# Protocol + registry
# --------------------------------------------------------------------- #
class PsiEngine(abc.ABC):
    """One (graph, activity) pair's solver; see module docstring."""

    name: str = "abstract"

    def __init__(self, *, dtype=jnp.float32,
                 criterion: ConvergenceCriterion | None = None):
        self.dtype = dtype
        self.criterion = criterion or ConvergenceCriterion()
        self._graph: Graph | None = None
        self._graph_stale = False
        self.host: HostOperators | None = None
        self.ops: PsiOperators | None = None

    @property
    def graph(self) -> Graph | None:
        if self._graph_stale:                # edges patched since last look
            self._graph = self.host.graph()
            self._graph_stale = False
        return self._graph

    # -- lifecycle ------------------------------------------------------ #
    @abc.abstractmethod
    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        """Build device operators; returns the cold-start state (s₀ = c)."""

    @abc.abstractmethod
    def run(self, *, tol: float | None = None, max_iter: int | None = None,
            s0: np.ndarray | jax.Array | None = None) -> PsiResult:
        """Iterate to the criterion; ``s0`` (node order) warm-starts."""

    def epilogue(self, s) -> jax.Array:
        """ψᵀ = (sᵀB + dᵀ)/N from a node-order series vector."""
        return self.ops.psi_epilogue(jnp.asarray(np.asarray(s), self.dtype))

    # -- delta rebuild hooks (serving runtime) -------------------------- #
    def patch_activity(self, users, lam=None, mu=None) -> bool:
        """O(Δ) activity patch; False → caller must re-``prepare``."""
        return False

    def patch_edges(self, src, dst) -> bool:
        """O(Δ) edge insertion; False → caller must re-``prepare``."""
        return False

    # -- shared helpers ------------------------------------------------- #
    @property
    def activity(self) -> Activity:
        return self.host.activity()

    def _base_prepare(self, graph: Graph, activity: Activity) -> None:
        self._graph = graph
        self._graph_stale = False
        self.host = HostOperators.from_graph(graph, activity)
        self.ops = self.host.to_device(self.dtype)

    def _scale(self) -> jax.Array:
        return (self.ops.b_norm if self.criterion.use_b_norm
                else jnp.asarray(1.0, self.ops.dtype))

    def _step_args(self):
        """What the engine's jitted ``one_step(args, s)`` closure consumes."""
        return self.ops

    def step(self, state: EngineState) -> EngineState:
        """One Alg. 2 iteration ``s ← sᵀA + c`` with the shared gap rule."""
        s_new, raw = self._step_jit(self._step_args(), state.s)
        return EngineState(s=s_new, gap=float(self._scale()) * float(raw),
                           t=state.t + 1)

    def _s0_node_order(self, s0) -> jax.Array:
        if s0 is None:
            return self.ops.c
        s0 = jnp.asarray(np.asarray(s0), self.dtype)
        if s0.shape != (self.ops.n,):
            raise ValueError(f"s0 must be f[{self.ops.n}] in node order; "
                             f"got {s0.shape}")
        return s0

    def _result(self, psi, s, gap, t, tol) -> PsiResult:
        return PsiResult(psi=psi, s=s, iterations=jnp.asarray(t, jnp.int32),
                         gap=jnp.asarray(gap, self.dtype),
                         converged=jnp.asarray(float(gap) <= tol),
                         matvecs=jnp.asarray(int(t) + 1, jnp.int32))


_REGISTRY: dict[str, type[PsiEngine]] = {}


def register_backend(name: str):
    """Class decorator: make the engine constructible by ``make_engine(name)``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def available_backends() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_engine(backend: str = "reference", *, graph: Graph | None = None,
                activity: Activity | None = None, **opts) -> PsiEngine:
    """Factory: construct (and, when given a graph, prepare) a backend."""
    try:
        cls = _REGISTRY[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"available: {available_backends()}") from None
    engine = cls(**opts)
    if graph is not None:
        if activity is None:
            raise ValueError("graph given without activity")
        engine.prepare(graph, activity)
    return engine


# --------------------------------------------------------------------- #
# Shared while-loop builder — operators travel as pytree *arguments* so a
# delta patch never retraces: the jit cache keys on array shapes only
# (activity patches and sentinel-slot edge inserts preserve shapes).
# --------------------------------------------------------------------- #
def _make_loop(step_with_gap):
    """``step_with_gap(args, s) -> (s_new, raw_gap)`` →
    jitted ``loop(args, s0, scale, tol, max_iter) -> (s, gap, t)``."""

    @jax.jit
    def loop(args, s0, scale, tol, max_iter):
        def cond(st):
            _, gap, t = st
            return (gap > tol) & (t < max_iter)

        def body(st):
            s, _, t = st
            s_new, raw = step_with_gap(args, s)
            return s_new, scale * raw, t + 1

        return jax.lax.while_loop(
            cond, body, (s0, jnp.asarray(jnp.inf, s0.dtype),
                         jnp.asarray(0, jnp.int32)))

    return loop


# --------------------------------------------------------------------- #
# reference — edge-form segment_sum iteration (power_psi semantics)
# --------------------------------------------------------------------- #
@register_backend("reference")
class ReferenceEngine(PsiEngine):
    """The paper-faithful Alg. 2 loop on :class:`PsiOperators`."""

    def __init__(self, **kw):
        super().__init__(**kw)
        nrm = self.criterion.norm_fn()

        def one_step(ops, s):
            s_new = ops.mu * ops.push(s) + ops.c
            return s_new, nrm(s_new - s)

        self._loop = _make_loop(one_step)
        self._step_jit = jax.jit(one_step)

    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        self._base_prepare(graph, activity)
        return EngineState(s=self.ops.c)

    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        s, gap, t = self._loop(
            self.ops, self._s0_node_order(s0), self._scale(),
            jnp.asarray(tol, self.ops.dtype),
            jnp.asarray(max_iter, jnp.int32))
        return self._result(self.ops.psi_epilogue(s), s, gap, t, tol)

    def patch_activity(self, users, lam=None, mu=None) -> bool:
        self.host.patch_activity(users, lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        return True

    def patch_edges(self, src, dst) -> bool:
        self.host.patch_edges(src, dst)
        self._graph_stale = True
        self.ops = self.host.to_device(self.dtype)   # edge arrays grew
        return True


# --------------------------------------------------------------------- #
# pallas — fused TPU power_step kernel (absorbs PsiKernelEngine)
# --------------------------------------------------------------------- #
@register_backend("pallas")
class PallasEngine(PsiEngine):
    """Alg. 2 driven by the fused Pallas edge-tile kernel.

    The kernel computes the raw L1 gap on-chip, so the criterion's norm must
    be ``l1`` (the paper's choice). Activity patches only refresh the padded
    node vectors; edge patches are placed into free sentinel slots of the
    edge-tile format and fall back to an edge-tile rebuild (never a full
    operator rebuild) when a tile overflows.
    """

    def __init__(self, *, tile: int = 256, e1: int = 8, e2: int = 128,
                 interpret: bool | None = None, **kw):
        super().__init__(**kw)
        if self.criterion.norm != "l1":
            raise ValueError("pallas backend computes the gap on-chip in l1; "
                             f"got norm={self.criterion.norm!r}")
        from ..kernels.ops import default_interpret, power_step
        self.tile, self.e1, self.e2 = tile, e1, e2
        self.interpret = (default_interpret() if interpret is None
                          else interpret)
        interp = self.interpret

        def one_step(args, s):
            fmt, inv_w_g, mu_pad, c_pad = args
            return power_step(s, inv_w_g, mu_pad, c_pad, fmt,
                              interpret=interp)

        self._loop = _make_loop(one_step)
        self._step_jit = jax.jit(one_step)

    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        from ..kernels.formats import build_edge_tiles
        from ..kernels.ops import DeviceEdgeTiles
        self._base_prepare(graph, activity)
        self.fmt_host = build_edge_tiles(graph, tile=self.tile, e1=self.e1,
                                         e2=self.e2)
        self.fmt = DeviceEdgeTiles.from_format(self.fmt_host)
        self._refresh_padded()
        return EngineState(s=self.fmt.pad_node_vector(self.ops.c))

    def _refresh_padded(self) -> None:
        f = self.fmt
        self._mu_pad = f.pad_node_vector(self.ops.mu)
        self._c_pad = f.pad_node_vector(self.ops.c)
        self._inv_w_gather = f.pad_gather_source(self.ops.inv_w)

    def _step_args(self):
        return (self.fmt, self._inv_w_gather, self._mu_pad, self._c_pad)

    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        s_init = self.fmt.pad_node_vector(self._s0_node_order(s0))
        s, gap, t = self._loop(self._step_args(), s_init, self._scale(),
                               jnp.asarray(tol, self.ops.dtype),
                               jnp.asarray(max_iter, jnp.int32))
        s_n = s[0, :self.fmt.n]
        return self._result(self.ops.psi_epilogue(s_n), s_n, gap, t, tol)

    # -- delta rebuilds ------------------------------------------------- #
    def patch_activity(self, users, lam=None, mu=None) -> bool:
        self.host.patch_activity(users, lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        self._refresh_padded()
        return True

    def patch_edges(self, src, dst) -> bool:
        from ..kernels.formats import build_edge_tiles
        from ..kernels.ops import DeviceEdgeTiles
        src, dst = self.host.patch_edges(src, dst)
        self._graph_stale = True
        slots = self._insert_into_tiles(src, dst)
        if slots is None:
            # a tile ran out of sentinel slots — rebuild the edge-tile
            # format only (the operator arrays stay incrementally patched;
            # the shape change means the next run() retraces once)
            self.fmt_host = build_edge_tiles(self.graph, tile=self.tile,
                                             e1=self.e1, e2=self.e2)
            self.fmt = DeviceEdgeTiles.from_format(self.fmt_host)
        elif slots:
            # fast path: scatter the few new slots into the device-resident
            # format instead of re-uploading all M edges
            src_idx, dst_local = self.fmt.src_idx, self.fmt.dst_local
            for b, slot, s_id, d_loc in slots:
                i, j = divmod(slot, self.e2)
                src_idx = src_idx.at[b, i, j].set(s_id)
                dst_local = dst_local.at[b, i, j].set(d_loc)
            self.fmt = dataclasses.replace(self.fmt, src_idx=src_idx,
                                           dst_local=dst_local)
        self.ops = self.host.to_device(self.dtype)   # edge arrays grew
        self._refresh_padded()
        return True

    def _insert_into_tiles(self, src: np.ndarray, dst: np.ndarray):
        """Place new edges into free (sentinel) slots of their dst tile.

        Mutates the host format in place and returns the placed
        ``(block, flat_slot, src_id, dst_local)`` tuples, or ``None`` when
        some tile has no free slot left (caller rebuilds the format)."""
        f = self.fmt_host
        n, tile = f.n, f.tile
        flat_src = f.src_idx.reshape(f.num_blocks, -1)
        flat_dstl = f.dst_local.reshape(f.num_blocks, -1)
        placed = []
        for s, d in zip(src, dst):
            t = int(d) // tile
            blocks = np.nonzero(f.block_tile == t)[0]
            for b in blocks:
                free = np.nonzero(flat_src[b] == n)[0]
                if free.size:
                    slot = int(free[0])
                    flat_src[b, slot] = s
                    flat_dstl[b, slot] = int(d) - t * tile
                    placed.append((int(b), slot, int(s), int(d) - t * tile))
                    break
            else:
                return None
        return placed


# --------------------------------------------------------------------- #
# distributed — 2-D block-cyclic shard_map schedule, host-chunked
# --------------------------------------------------------------------- #
@register_backend("distributed")
class DistributedEngine(PsiEngine):
    """Sharded Power-ψ over a (data, model) mesh.

    The device program is a fixed-shape ``chunk_iters``-step scan; the
    criterion is evaluated on the host between chunks (iteration counts are
    therefore multiples of ``chunk_iters``), exactly the
    ``runtime/psi_driver.py`` schedule. The gap norm must be ``l1`` (what the
    sharded step psums). ``s`` is converted to/from node order at the API
    boundary so results interchange with the other backends.
    """

    def __init__(self, *, mesh=None, chunk_iters: int = 16, **kw):
        super().__init__(**kw)
        if self.criterion.norm != "l1":
            raise ValueError("distributed backend psums an l1 gap; "
                             f"got norm={self.criterion.norm!r}")
        self.mesh = mesh
        self.chunk_iters = chunk_iters
        self.dist = None

    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        from .distributed import DistributedPsi
        self._base_prepare(graph, activity)
        if self.mesh is None:
            self.mesh = jax.make_mesh((len(jax.devices()), 1),
                                      ("data", "model"))
        self.dist = DistributedPsi.from_graph(graph, activity, self.mesh,
                                              dtype=self.dtype)
        self._run_chunk = self.dist.make_run(chunk_iters=self.chunk_iters)
        self._one_step = jax.jit(self.dist.make_step())
        self._epi = jax.jit(self.dist.make_epilogue())
        return EngineState(s=self.dist.arrays.c_src)

    def step(self, state: EngineState) -> EngineState:
        s_new, gap = self._one_step(state.s, self.dist.arrays)
        scale = self.criterion.scale(self.host.b_norm)
        return EngineState(s=s_new, gap=scale * float(gap), t=state.t + 1)

    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        part = self.dist.part
        if s0 is None:
            s = self.dist.arrays.c_src
        else:
            s_host = np.asarray(np.asarray(s0),
                                np.dtype(jnp.dtype(self.dtype).name))
            s = jax.device_put(
                part.to_src_layout(s_host),
                jax.sharding.NamedSharding(
                    self.mesh,
                    jax.sharding.PartitionSpec(self.dist.src_axes, None)))
        scale = self.criterion.scale(self.host.b_norm)
        it, gap = 0, float("inf")
        while it < max_iter and gap > tol:
            s, gap_dev = self._run_chunk(s, self.dist.arrays)
            it += self.chunk_iters
            gap = scale * float(gap_dev)
        psi_piece = self._epi(s, self.dist.arrays)
        psi = part.from_src_layout(
            np.asarray(psi_piece).reshape(part.d, -1))
        s_node = part.from_src_layout(np.asarray(jax.device_get(s)))
        return self._result(jnp.asarray(psi, self.dtype),
                            jnp.asarray(s_node, self.dtype), gap, it, tol)

    def patch_activity(self, users, lam=None, mu=None) -> bool:
        # partition and edge layouts are untouched; only the activity-derived
        # device arrays are rebuilt (no re-partition, no edge re-sort)
        self.host.patch_activity(users, lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        self.dist.arrays = self.dist.build_arrays(self.graph, self.activity)
        return True
