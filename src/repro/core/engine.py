"""Unified Power-ψ solver abstraction: one protocol, five backends.

Before this module the repo had four disjoint solver loops (``power_psi``,
``kernels.ops.PsiKernelEngine``, ``DistributedPsi.run_to_convergence`` and the
``PsiService`` rebuild path), each with its own while-loop, convergence rule
and warm-start story. ``PsiEngine`` folds them behind one contract:

    prepare(graph, activity) -> EngineState     # build operators, s₀ = c
    step(state) -> EngineState                  # one Alg. 2 iteration
    run(tol=..., max_iter=..., s0=...) -> PsiResult
    epilogue(s) -> psi                          # ψᵀ = (sᵀB + dᵀ)/N

Backends are registered by name and constructed through
:func:`make_engine`:

  * ``reference``   — the edge-form ``segment_sum`` iteration of
    :mod:`repro.core.power_psi` (works everywhere, float64-capable).
  * ``pallas``      — the TPU Pallas kernels (interpret mode off-TPU) in one
    of two execution regimes: the fused edge-tile ``power_step`` kernel
    (hyper-sparse graphs) or the BSR/MXU ``bsr_spmv`` kernel (clustered
    graphs); pick with ``regime=`` or hand over a
    :class:`~repro.kernels.autotune.RegimePlan`.
  * ``auto``        — a ``pallas`` engine whose regime and tile parameters
    are chosen per graph by the :mod:`repro.kernels.autotune` planner
    (measured-occupancy cost model, optional one-shot micro-benchmark,
    process-level plan cache).
  * ``accelerated`` — the ``reference`` iteration wrapped in the on-device
    Aitken extrapolation loop (see :func:`_make_accelerated_loop`); any
    other backend opts in with ``accelerate=True``.
  * ``distributed`` — the 2-D block-cyclic ``shard_map`` schedule of
    :class:`repro.core.distributed.DistributedPsi`, driven in host-side
    chunks exactly like ``runtime/psi_driver.py``; ``accelerate=True``
    applies the Aitken jump at chunk granularity
    (:class:`ChunkExtrapolator`).
  * ``async``       — the bounded-staleness overlapped chunk scheduler of
    :mod:`repro.asyncexec`: per-chunk epoch tags, straggler absorption up
    to ``tau`` epochs, termination gated by the stale-corrected Eq. 19
    certificate and sealed by a synchronous verification sweep
    (docs/ASYNC.md).
  * ``push``        — the Gauss-Southwell residual-push solver of
    :mod:`repro.localpush`: work proportional to where residual lives
    (O(Δ·deg) after localized patches, certified top-k early stop via
    ``run_top_k``), with the running bound
    ``‖ψ_exact − ψ̂‖₁ ≤ ‖B‖·‖r‖₁/((1−α)·N)`` as the termination rule
    (docs/LOCALPUSH.md).

All backends share one :class:`ConvergenceCriterion` — ε on ‖B‖·‖Δs‖ per
Eq. 19 — and report interchangeable :class:`~repro.core.power_psi.PsiResult`
values (``s`` always returned in node order so a result from one backend can
warm-start any other). Engines also expose the O(Δ) delta-rebuild hooks
(``patch_activity`` / ``patch_edges``) the serving layer
(:class:`repro.core.incremental.PsiService`) is built on; a hook returns
``False`` when the backend cannot patch incrementally and the caller should
fall back to a full ``prepare``.

Registering a new backend (see docs/ENGINE.md)::

    @register_backend("mine")
    class MyEngine(PsiEngine):
        ...
"""
from __future__ import annotations

import abc
import dataclasses
import functools
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.structure import Graph
from ..obs import convergence as obs_convergence
from ..obs import log as obs_log
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .activity import Activity
from .operators import HostOperators, PsiOperators
from .power_psi import _NORMS, PsiResult

__all__ = ["ConvergenceCriterion", "EngineState", "PsiEngine",
           "ReferenceEngine", "PallasEngine", "AutoEngine",
           "AcceleratedEngine", "DistributedEngine", "AsyncEngine",
           "ChunkExtrapolator",
           "make_engine", "register_backend", "available_backends",
           "make_reference_step", "make_dense_step", "make_edge_tile_step",
           "make_batched_loop"]


# --------------------------------------------------------------------- #
# Shared convergence contract
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class ConvergenceCriterion:
    """Alg. 2 termination rule, identical across backends.

    Stop when ``scale · ‖s_t − s_{t−1}‖_norm ≤ tol`` with ``scale = ‖B‖``
    when ``use_b_norm`` (Eq. 19: the ψ trajectory then moved ≤ tol/N), else
    1. ``matvecs`` accounting is shared too: one sparse mat-vec per
    iteration plus one for the ψ epilogue.
    """

    tol: float = 1e-9
    max_iter: int = 10_000
    norm: str = "l1"
    use_b_norm: bool = True

    def __post_init__(self):
        if self.norm not in _NORMS:
            raise ValueError(f"unknown norm {self.norm!r}; "
                             f"choose from {sorted(_NORMS)}")

    def norm_fn(self):
        return _NORMS[self.norm]

    def scale(self, b_norm) -> float:
        return float(b_norm) if self.use_b_norm else 1.0

    def resolve(self, tol: float | None,
                max_iter: int | None) -> tuple[float, int]:
        return (self.tol if tol is None else float(tol),
                self.max_iter if max_iter is None else int(max_iter))


@dataclasses.dataclass
class EngineState:
    """Backend-agnostic iteration state. ``s`` lives in the backend's native
    layout (node order / padded / sharded src layout)."""

    s: Any
    gap: float = float("inf")
    t: int = 0


# --------------------------------------------------------------------- #
# Protocol + registry
# --------------------------------------------------------------------- #
def _instrument_run(run):
    """Wrap a backend's ``run`` with the telemetry plane (repro.obs).

    Applied automatically by :meth:`PsiEngine.__init_subclass__` to every
    backend that defines its own ``run`` — one instrumentation point for
    all current and future backends, including out-of-package ones like
    ``repro.localpush``. When every obs sink is null the wrapper is one
    boolean check and a tail call; otherwise it opens an ``engine.run``
    span + a convergence record around the resolve. Instrumentation only
    *reads* the result (and syncs it, which the drivers did anyway), so
    the returned ψ/s are bitwise identical either way.
    """

    @functools.wraps(run)
    def wrapped(self, *args, **kwargs):
        tracker = obs_convergence.get_tracker()
        tracer = obs_trace.get_tracer()
        if not (tracker.enabled or tracer.enabled or obs_metrics.enabled()):
            return run(self, *args, **kwargs)
        rec = tracker.begin(self.name,
                            tenant=getattr(self, "obs_tenant", None))
        with obs_trace.span("engine.run", backend=self.name) as sp:
            try:
                res = run(self, *args, **kwargs)
            except BaseException:
                tracker.finish(rec, converged=False,
                               duration_s=sp.duration_s)
                raise
            sp.sync(res.s)
        tracker.finish(rec, iterations=int(res.iterations),
                       gap=float(res.gap), converged=bool(res.converged),
                       duration_s=sp.duration_s,
                       psi_error_bound=self.psi_error_bound())
        return res

    wrapped._obs_instrumented = True
    return wrapped


class PsiEngine(abc.ABC):
    """One (graph, activity) pair's solver; see module docstring.

    Loop-shaping options shared by every backend:

    * ``accelerate`` — wrap the backend's step in the on-device Aitken
      extrapolation loop (``distributed`` applies it at chunk granularity).
    * ``extrapolate_every`` — target plain iterations between jump attempts.
    * ``check_every`` — evaluate the convergence gap every k-th iteration;
      the k−1 intermediate gap reductions are dead code XLA eliminates, so
      the O(N) norm is amortized over k steps. ``iterations`` then lands on
      a multiple of k (overshoot < k, never undershoot). Ignored by
      ``distributed`` (its cadence is ``chunk_iters``) and by accelerated
      loops (their verify-after-jump pairing fixes the cadence at 2).
    """

    name: str = "abstract"

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        run = cls.__dict__.get("run")
        if run is not None and not getattr(run, "_obs_instrumented", False):
            cls.run = _instrument_run(run)

    def __init__(self, *, dtype=jnp.float32,
                 criterion: ConvergenceCriterion | None = None,
                 accelerate: bool = False, extrapolate_every: int = 8,
                 check_every: int = 1):
        self.dtype = dtype
        self.criterion = criterion or ConvergenceCriterion()
        self.accelerate = bool(accelerate)
        self.extrapolate_every = int(extrapolate_every)
        self.check_every = max(1, int(check_every))
        self._graph: Graph | None = None
        self._graph_stale = False
        self.host: HostOperators | None = None
        self.ops: PsiOperators | None = None

    @property
    def graph(self) -> Graph | None:
        if self._graph_stale:                # edges patched since last look
            self._graph = self.host.graph()
            self._graph_stale = False
        return self._graph

    # -- lifecycle ------------------------------------------------------ #
    @abc.abstractmethod
    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        """Build device operators; returns the cold-start state (s₀ = c)."""

    @abc.abstractmethod
    def run(self, *, tol: float | None = None, max_iter: int | None = None,
            s0: np.ndarray | jax.Array | None = None) -> PsiResult:
        """Iterate to the criterion; ``s0`` (node order) warm-starts."""

    def epilogue(self, s) -> jax.Array:
        """ψᵀ = (sᵀB + dᵀ)/N from a node-order series vector."""
        return self.ops.psi_epilogue(jnp.asarray(np.asarray(s), self.dtype))

    # -- delta rebuild hooks (serving runtime) -------------------------- #
    def patch_activity(self, users, lam=None, mu=None) -> bool:
        """O(Δ) activity patch; False → caller must re-``prepare``."""
        return False

    def patch_edges(self, src, dst) -> bool:
        """O(Δ) edge insertion; False → caller must re-``prepare``."""
        return False

    def unpatch_edges(self, src, dst) -> bool:
        """Edge *removal* (unfollow tombstones); False → caller must
        re-``prepare`` from a filtered graph. Backends whose device format
        cannot shrink incrementally keep the default."""
        return False

    # -- certified serving (see docs/LOCALPUSH.md) ---------------------- #
    def psi_error_bound(self) -> float | None:
        """Certified per-node ``|ψ_exact − ψ_served|`` bound for the last
        ``run``'s returned ψ, or None when the backend cannot certify one
        (the Eq. 19 gap bounds one step's *movement*, not the distance to
        the fixed point). The ``push`` backend overrides this with its
        residual certificate; :class:`~repro.core.incremental.RankingCache`
        and the stream freshness report consume it."""
        return None

    # -- shared helpers ------------------------------------------------- #
    @property
    def activity(self) -> Activity:
        return self.host.activity()

    def _base_prepare(self, graph: Graph, activity: Activity) -> None:
        self._graph = graph
        self._graph_stale = False
        self.host = HostOperators.from_graph(graph, activity)
        self.ops = self.host.to_device(self.dtype)

    def _install_loops(self, one_step) -> None:
        """Build ``self._loop`` / ``self._step_jit`` from the backend's
        ``one_step(args, s) -> (s_new, raw_gap)`` closure, honoring the
        ``accelerate`` / ``check_every`` loop-shaping options.

        ``one_step`` is also kept on the engine as the public ``one_step``
        attribute: it is *pure* in ``(args, s)`` (operators travel as pytree
        arguments), so callers may ``jax.vmap`` it over a stacked batch of
        same-shape operator pytrees — the contract the multi-tenant fleet
        (:mod:`repro.serving`) builds its batched solver on via
        :func:`make_batched_loop`."""
        self.one_step = one_step
        if self.accelerate:
            loop = _make_accelerated_loop(
                one_step, extrapolate_every=self.extrapolate_every)
        else:
            loop = _make_loop(one_step, check_every=self.check_every)
        # count silent recompiles of the solver loop (e.g. the shape change
        # of a format rebuild after a patch_edges overflow)
        self._loop = obs_trace.retrace_guard(loop, name=f"{self.name}.loop")
        self._step_jit = jax.jit(one_step)

    def _scale(self) -> jax.Array:
        return (self.ops.b_norm if self.criterion.use_b_norm
                else jnp.asarray(1.0, self.ops.dtype))

    def _step_args(self):
        """What the engine's jitted ``one_step(args, s)`` closure consumes."""
        return self.ops

    def step(self, state: EngineState) -> EngineState:
        """One Alg. 2 iteration ``s ← sᵀA + c`` with the shared gap rule."""
        s_new, raw = self._step_jit(self._step_args(), state.s)
        return EngineState(s=s_new, gap=float(self._scale()) * float(raw),
                           t=state.t + 1)

    def _s0_node_order(self, s0) -> jax.Array:
        if s0 is None:
            return self.ops.c
        s0 = jnp.asarray(np.asarray(s0), self.dtype)
        if s0.shape != (self.ops.n,):
            raise ValueError(f"s0 must be f[{self.ops.n}] in node order; "
                             f"got {s0.shape}")
        return s0

    def _result(self, psi, s, gap, t, tol) -> PsiResult:
        return PsiResult(psi=psi, s=s, iterations=jnp.asarray(t, jnp.int32),
                         gap=jnp.asarray(gap, self.dtype),
                         converged=jnp.asarray(float(gap) <= tol),
                         matvecs=jnp.asarray(int(t) + 1, jnp.int32))


_REGISTRY: dict[str, type[PsiEngine]] = {}


def register_backend(name: str):
    """Class decorator: make the engine constructible by ``make_engine(name)``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def _ensure_plugin_backends() -> None:
    """Import out-of-package backends that self-register on import.

    ``repro.localpush`` imports this module, so a bottom-of-file import
    here would deadlock whenever ``repro.localpush`` is the entry point
    (its partially-initialized module would be re-entered before
    ``PushEngine`` exists). Deferring to first registry *use* keeps both
    import orders cycle-free."""
    from .. import localpush  # noqa: F401  (registers backend="push")


def available_backends() -> tuple[str, ...]:
    _ensure_plugin_backends()
    return tuple(sorted(_REGISTRY))


def _accepted_options(cls: type[PsiEngine]) -> set[str]:
    """Every named keyword the backend's ``__init__`` chain accepts."""
    import inspect
    names: set[str] = set()
    for klass in cls.__mro__:
        init = klass.__dict__.get("__init__")
        if init is None:
            continue
        for p in inspect.signature(init).parameters.values():
            if p.name != "self" and p.kind in (p.KEYWORD_ONLY,
                                               p.POSITIONAL_OR_KEYWORD):
                names.add(p.name)
    return names


def make_engine(backend: str = "reference", *, graph: Graph | None = None,
                activity: Activity | None = None, **opts) -> PsiEngine:
    """Factory: construct (and, when given a graph, prepare) a backend."""
    _ensure_plugin_backends()
    try:
        cls = _REGISTRY[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"available: {available_backends()}") from None
    unknown = set(opts) - _accepted_options(cls)
    if unknown:
        # a mistyped option — or an option that belongs to a different
        # backend (e.g. mesh= on reference); point at the full registry
        raise ValueError(
            f"unknown engine option(s) {sorted(unknown)} for backend "
            f"{backend!r} (accepts: {sorted(_accepted_options(cls))}); "
            f"available backends: {available_backends()}")
    engine = cls(**opts)
    if graph is not None:
        if activity is None:
            raise ValueError("graph given without activity")
        engine.prepare(graph, activity)
    return engine


# --------------------------------------------------------------------- #
# Shared while-loop builders — operators travel as pytree *arguments* so a
# delta patch never retraces: the jit cache keys on array shapes only
# (activity patches and sentinel-slot edge inserts preserve shapes).
# --------------------------------------------------------------------- #
def _make_loop(step_with_gap, *, check_every: int = 1):
    """``step_with_gap(args, s) -> (s_new, raw_gap)`` →
    jitted ``loop(args, s0, scale, tol, max_iter) -> (s, gap, t)``.

    With ``check_every=k`` each while-loop body advances k iterations and
    only the k-th raw gap feeds the termination test — the k−1 discarded
    gaps are dead code, so backends whose norm is a separate O(N) reduce
    (``reference``, the BSR regime) pay for it once per k steps. ``t``
    advances in multiples of k (it can overshoot the minimal iteration
    count by < k, never undershoot the tolerance).
    """
    k = max(1, int(check_every))

    @jax.jit
    def loop(args, s0, scale, tol, max_iter):
        def cond(st):
            _, gap, t = st
            return (gap > tol) & (t < max_iter)

        def body(st):
            s, _, t = st
            for _ in range(k - 1):          # unrolled; gaps DCE'd by XLA
                s, _ = step_with_gap(args, s)
            s_new, raw = step_with_gap(args, s)
            return s_new, scale * raw, t + k

        return jax.lax.while_loop(
            cond, body, (s0, jnp.asarray(jnp.inf, s0.dtype),
                         jnp.asarray(0, jnp.int32)))

    return loop


def make_batched_loop(step_with_gap, *, check_every: int = 1):
    """Vmapped, convergence-masked fleet loop over independent lanes.

    ``step_with_gap`` is the same pure ``(args, s) -> (s_new, raw_gap)``
    closure the solo loops consume (an engine's public ``one_step``); every
    leaf of ``args`` and ``s`` gains a leading lane axis.  Returns a jitted

        loop(args, s0, scale, tol, max_iter, active0) -> (s, gap, t)

    with per-lane ``scale`` / ``gap`` / ``t``.  Each lane runs the solo
    termination rule independently: a lane whose gap crosses ``tol`` (or
    whose ``t`` hits ``max_iter``) *freezes* — ``jnp.where`` keeps its
    series vector bitwise intact while the remaining lanes keep stepping —
    and the whole loop exits when no lane is active.  ``active0`` masks
    lanes out from the start (clean tenants sharing a bucket with a dirty
    one never move at all), which is what makes a converged tenant's ψ
    bit-stable across its neighbours' re-solves.

    Per-lane iteration counts match the solo ``_make_loop`` semantics,
    including the ``check_every=k`` cadence (``t`` lands on a multiple of
    k for every lane that ran).
    """
    k = max(1, int(check_every))
    vstep = jax.vmap(step_with_gap)

    @jax.jit
    def loop(args, s0, scale, tol, max_iter, active0):
        lane_shape = (s0.shape[0],) + (1,) * (s0.ndim - 1)

        def cond(st):
            return jnp.any(st[-1])

        def body(st):
            s, gap, t, active = st
            s_k = s
            for _ in range(k - 1):          # unrolled; gaps DCE'd by XLA
                s_k, _ = vstep(args, s_k)
            s_new, raw = vstep(args, s_k)
            gap_new = scale * raw
            s_next = jnp.where(active.reshape(lane_shape), s_new, s)
            gap_next = jnp.where(active, gap_new, gap)
            t_next = jnp.where(active, t + k, t)
            active_next = active & (gap_new > tol) & (t_next < max_iter)
            return s_next, gap_next, t_next, active_next

        lanes = s0.shape[0]
        s, gap, t, _ = jax.lax.while_loop(
            cond, body,
            (s0, jnp.full((lanes,), jnp.inf, s0.dtype),
             jnp.zeros((lanes,), jnp.int32), active0))
        return s, gap, t

    return loop


def make_reference_step(norm: str = "l1"):
    """The pure Alg. 2 step ``(PsiOperators, s) -> (s_new, raw_gap)``.

    Stateless and therefore vmappable: stack the data fields of several
    same-shape :class:`~repro.core.operators.PsiOperators` along a leading
    lane axis (meta ``n`` / ``m`` shared) and the step batches.  Padded
    lanes are inert by construction — zero-rate pad nodes keep ``s = 0``
    and sentinel edges (``dst == n``) are dropped by the segment-sum.
    """
    nrm = _NORMS[norm]

    def one_step(ops, s):
        s_new = ops.mu * ops.push(s) + ops.c
        return s_new, nrm(s_new - s)

    return one_step


def make_dense_step(norm: str = "l1"):
    """The pure dense-matvec Alg. 2 step over ``(E, 1/w, μ, c)`` args.

    ``E`` is the {0,1} follower→leader adjacency (``E[j, i] = 1`` iff j
    follows i), so one matvec computes the push ``t = (s ⊙ 1/w) E`` and the
    step is ``μ ⊙ t + c`` — identical math to the edge form, but a single
    (batched) GEMV instead of a gather/scatter chain.  This is the fleet's
    regime for *small* buckets: a stack of tiny tenants turns into one
    ``[B, n, n]`` batched matvec (BLAS on CPU, MXU on TPU), which beats B
    independent scatter pipelines by a wide margin exactly where the
    multi-tenant batching case lives.  O(n²) memory per lane — the fleet
    only auto-selects it under its ``dense_max_n`` threshold.
    """
    nrm = _NORMS[norm]

    def one_step(args, s):
        E, inv_w, mu, c = args
        s_new = mu * ((s * inv_w) @ E) + c
        return s_new, nrm(s_new - s)

    return one_step


def make_edge_tile_step(interpret: bool):
    """The pure fused edge-tile step over ``(fmt, 1/w, μ, c)`` args.

    Same calling convention as :func:`make_reference_step` but in the
    pallas edge-tile regime's native padded ``[1, n_pad]`` layout; the args
    tuple is ``(DeviceEdgeTiles, inv_w_gather, mu_pad, c_pad)``.  The
    pallas call batches under ``jax.vmap`` (the batch axis becomes a grid
    dimension), which is how the fleet runs many tenants per device
    through one kernel launch.
    """
    from ..kernels.ops import power_step

    def one_step(args, s):
        fmt, inv_w_g, mu_pad, c_pad = args
        return power_step(s, inv_w_g, mu_pad, c_pad, fmt,
                          interpret=interpret)

    return one_step


def _make_accelerated_loop(step_with_gap, *, extrapolate_every: int = 8):
    """Aitken / geometric-series extrapolation around *any* backend step.

    Same calling convention as :func:`_make_loop`. Each while-loop body
    consumes exactly two mat-vecs and advances either two plain iterations
    or one extrapolated jump plus its verification step:

        s₁ = step(s);  Δ = s₁ − s;  r = ‖Δ_t‖/‖Δ_{t−1}‖
        s_x = s₁ + Δ · r/(1−r)      every ~extrapolate_every iterations,
                                    while contracting (0 < r < 0.999) and
                                    far from tolerance (gap > 100·tol)
        s₂ = step(s_x)              # verification (or second plain step)

    The termination gap is *always* ``scale·‖s₂ − s_x‖`` — measured across
    a genuine plain iteration — so the Eq. 19 guarantee survives every
    jump; the whole loop is one ``lax.while_loop`` on device (no host sync
    per jump). A jump that fails to shrink the gap is reverted and disables
    all future jumps (degrades to plain Power-ψ at one wasted mat-vec); a
    stalled ratio (r ≈ 1, a floating-point period-2 cycle) triggers a
    Krasnoselskii averaging kick, which is always safe for a contraction.

    The returned ``t`` counts mat-vecs actually consumed. Precision note:
    near a dtype's fixed-point floor a jump can land in a basin whose plain
    fp32 iteration limit-cycles at ‖Δs‖ ≈ 1e-6; request tolerances
    ≥ ~100·ulp for fp32, or run float64 as the paper's ε = 1e-9 sweeps do.
    """
    kb = max(1, int(extrapolate_every) // 2)  # loop bodies between attempts

    @jax.jit
    def loop(args, s0, scale, tol, max_iter):
        def cond(st):
            _, _, gap, t, _, _ = st
            return (gap > tol) & (t < max_iter)

        def body(st):
            s, prev_dn, _, t, j, enabled = st
            s1, raw1 = step_with_gap(args, s)
            delta = s1 - s
            gap_plain = scale * raw1
            r = raw1 / jnp.maximum(prev_dn, 1e-30)
            far = gap_plain > 100.0 * tol
            do_jump = ((j % kb == kb - 1) & (r > 0.0) & (r < 0.999)
                       & far & enabled)
            jump = jnp.where(do_jump, r / (1.0 - r), 0.0)
            s_x = s1 + delta * jump           # == s₁ when not jumping
            s2, raw2 = step_with_gap(args, s_x)
            gap_ver = scale * raw2
            bad = do_jump & (gap_ver >= gap_plain)
            enabled = enabled & ~bad
            s_next = jnp.where(bad, s1, s2)
            gap = jnp.where(bad, gap_plain, gap_ver)
            dn_next = jnp.where(bad, raw1, raw2)
            stall = (~do_jump) & (r > 0.999) & jnp.isfinite(r)
            s_next = jnp.where(stall, 0.5 * (s_x + s2), s_next)
            return s_next, dn_next, gap, t + 2, j + 1, enabled

        s, _, gap, t, _, _ = jax.lax.while_loop(
            cond, body,
            (s0, jnp.asarray(jnp.inf, s0.dtype),
             jnp.asarray(jnp.inf, s0.dtype), jnp.asarray(0, jnp.int32),
             jnp.asarray(0, jnp.int32), jnp.asarray(True)))
        return s, gap, t

    return loop


class ChunkExtrapolator:
    """Host-side Aitken jump between fixed-shape device chunks.

    The ``distributed`` backend (and ``runtime/psi_driver.py``) evaluate
    convergence between ``chunk_iters``-step device scans; this helper
    extrapolates across chunk *endpoints*: the per-chunk contraction ratio
    is ρ^chunk_iters, so the remaining tail after chunk t sums to
    Δ_t · r/(1−r) exactly as in the per-iteration loop. Eq. 19 survives
    because the termination gap is always produced by the *next* chunk's
    plain steps (≥ 1 plain iteration after any jump). A chunk whose gap
    fails to shrink disables all future jumps — no revert is needed since
    the chunk's plain steps already re-contracted the iterate.

    **Epoch-consistency guard** (async executors): the geometric-tail
    formula assumes Δ = s_out − s_in spans a *uniform* number of
    contraction applications on every coordinate. Under bounded-staleness
    execution a chunk endpoint can mix per-chunk epochs; callers pass the
    endpoint pair's ``epoch_spread`` (max − min contributing chunk epoch)
    and the extrapolator only jumps on same-epoch pairs (``spread == 0``),
    dropping its ratio history otherwise — a mixed-epoch Δ is not one
    contraction sample and must not seed r.
    """

    def __init__(self, tol: float, *, guard: float = 100.0):
        self.tol = tol
        self.guard = guard
        self.reset()

    def reset(self) -> None:
        """Forget history (e.g. after a checkpoint restore)."""
        self._prev_dn: float | None = None
        self._gap_prev = float("inf")
        self.enabled = True
        self.jumps = 0

    def advance(self, s_in, s_out, gap: float, *, epoch_spread: int = 0):
        """Map a finished chunk (input → output, scaled gap) to the next
        chunk's start vector, possibly extrapolated. ``epoch_spread != 0``
        marks the endpoints as epoch-inconsistent: no jump fires and the
        Δ-ratio history resets (synchronous callers pass the default 0)."""
        if not self.enabled:
            return s_out
        if epoch_spread != 0:
            # mixed-epoch Δ poisons both the ratio history and the
            # gap-progress baseline — drop them, keep only `enabled`
            self._prev_dn = None
            self._gap_prev = float("inf")
            return s_out
        if gap >= self._gap_prev:             # jump/stall did not help
            self.enabled = False
            obs_convergence.record_aitken(False)
            return s_out
        self._gap_prev = gap
        dn = float(jnp.sum(jnp.abs(s_out - s_in)))
        r = 0.0 if not self._prev_dn else dn / self._prev_dn
        self._prev_dn = dn
        if 0.0 < r < 0.999 and gap > self.guard * self.tol:
            self.jumps += 1
            obs_convergence.record_aitken(True)
            return s_out + (s_out - s_in) * (r / (1.0 - r))
        return s_out


# --------------------------------------------------------------------- #
# reference — edge-form segment_sum iteration (power_psi semantics)
# --------------------------------------------------------------------- #
@register_backend("reference")
class ReferenceEngine(PsiEngine):
    """The paper-faithful Alg. 2 loop on :class:`PsiOperators`."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self._install_loops(make_reference_step(self.criterion.norm))

    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        self._base_prepare(graph, activity)
        return EngineState(s=self.ops.c)

    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        s, gap, t = self._loop(
            self.ops, self._s0_node_order(s0), self._scale(),
            jnp.asarray(tol, self.ops.dtype),
            jnp.asarray(max_iter, jnp.int32))
        return self._result(self.ops.psi_epilogue(s), s, gap, t, tol)

    def patch_activity(self, users, lam=None, mu=None) -> bool:
        self.host.patch_activity(users, lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        return True

    def patch_edges(self, src, dst) -> bool:
        self.host.patch_edges(src, dst)
        self._graph_stale = True
        self.ops = self.host.to_device(self.dtype)   # edge arrays grew
        return True

    def unpatch_edges(self, src, dst) -> bool:
        removed, _ = self.host.remove_edges(src, dst)
        if removed.size:
            self._graph_stale = True
            self.ops = self.host.to_device(self.dtype)  # edge arrays shrank
        return True


@register_backend("accelerated")
class AcceleratedEngine(ReferenceEngine):
    """Aitken-extrapolated ``reference`` iteration — the ROADMAP's fourth
    registered backend. Identical math to the historical
    ``core.accelerated.power_psi_accelerated`` entry point, now expressed
    as the engine-level loop composition every backend can opt into
    (``make_engine("pallas", accelerate=True)``, …).

    ``iterations`` / ``matvecs`` count mat-vecs actually consumed — the
    honest currency an extrapolated loop is judged in.
    """

    def __init__(self, **kw):
        kw["accelerate"] = True
        super().__init__(**kw)


# --------------------------------------------------------------------- #
# pallas — fused TPU kernels in two execution regimes (absorbs
# PsiKernelEngine; BSR promoted from ablation to first-class regime)
# --------------------------------------------------------------------- #
@register_backend("pallas")
class PallasEngine(PsiEngine):
    """Alg. 2 driven by the Pallas TPU kernels.

    Two execution regimes share the engine (see kernels/formats.py and
    docs/AUTOTUNE.md):

    * ``edge_tile`` — the fused ``power_step`` kernel: dst-sorted edge
      blocks scatter into node tiles, the gap is computed on-chip. Native
      state layout is the padded ``[1, n_pad]`` node vector.
    * ``bsr``       — the ``bsr_spmv`` dense-tile MXU kernel with the μ/c
      epilogue and L1 gap composed around it by XLA. Native layout is the
      node-order ``f[n]`` vector.

    Both regimes compute the gap in ``l1`` (the paper's choice), so the
    criterion's norm must be ``l1``. Activity patches refresh only node
    vectors; edge patches go into free sentinel slots (edge-tile, via an
    O(Δ) per-tile free-slot cursor) or existing dense tiles (BSR) and fall
    back to a regime-format rebuild — never a full operator rebuild — when
    a tile/block overflows.
    """

    def __init__(self, *, regime: str = "edge_tile", tile: int = 256,
                 e1: int = 8, e2: int = 128, ts: int = 128, td: int = 128,
                 interpret: bool | None = None, plan=None, **kw):
        super().__init__(**kw)
        if self.criterion.norm != "l1":
            raise ValueError("pallas backend computes the gap in l1; "
                             f"got norm={self.criterion.norm!r}")
        from ..kernels.ops import default_interpret
        self.interpret = (default_interpret() if interpret is None
                          else interpret)
        self.tile, self.e1, self.e2 = tile, e1, e2
        self.ts, self.td = ts, td
        if plan is not None:
            self._apply_plan(plan)
        else:
            self._set_regime(regime)

    # -- regime plumbing ------------------------------------------------ #
    def _apply_plan(self, plan) -> None:
        """Adopt a :class:`~repro.kernels.autotune.RegimePlan`."""
        if plan.regime == "edge_tile":
            self.tile, self.e1, self.e2 = plan.tile, plan.e1, plan.e2
        else:
            self.ts, self.td = plan.ts, plan.td
        self._set_regime(plan.regime)

    def _set_regime(self, regime: str) -> None:
        if regime not in ("edge_tile", "bsr"):
            raise ValueError(f"unknown pallas regime {regime!r}; "
                             "choose edge_tile or bsr")
        self.regime = regime
        interp = self.interpret
        if regime == "edge_tile":
            one_step = make_edge_tile_step(interp)
        else:
            from ..kernels.ops import bsr_spmv

            def one_step(args, s):
                fmt, inv_w, mu, c = args
                s_new = mu * bsr_spmv(s * inv_w, fmt, interpret=interp) + c
                return s_new, jnp.sum(jnp.abs(s_new - s))

        self._install_loops(one_step)

    def _build_format(self, graph: Graph) -> None:
        if self.regime == "edge_tile":
            from ..kernels.formats import build_edge_tiles
            from ..kernels.ops import DeviceEdgeTiles
            self.fmt_host = build_edge_tiles(graph, tile=self.tile,
                                             e1=self.e1, e2=self.e2)
            self.fmt = DeviceEdgeTiles.from_format(self.fmt_host)
            self._rebuild_tile_cursor()
            self._refresh_padded()
        else:
            from ..kernels.formats import build_bsr
            from ..kernels.ops import DeviceBsr
            self.fmt_host = build_bsr(
                graph, ts=self.ts, td=self.td,
                dtype=np.dtype(jnp.dtype(self.dtype).name))
            self.fmt = DeviceBsr.from_format(self.fmt_host)
            self._rebuild_bsr_block_map()

    def _to_native(self, v: jax.Array) -> jax.Array:
        return (self.fmt.pad_node_vector(v) if self.regime == "edge_tile"
                else v)

    def _from_native(self, s: jax.Array) -> jax.Array:
        return s[0, :self.fmt.n] if self.regime == "edge_tile" else s

    # -- lifecycle ------------------------------------------------------ #
    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        self._base_prepare(graph, activity)
        self._build_format(graph)
        return EngineState(s=self._to_native(self.ops.c))

    def _refresh_padded(self) -> None:
        f = self.fmt
        self._mu_pad = f.pad_node_vector(self.ops.mu)
        self._c_pad = f.pad_node_vector(self.ops.c)
        self._inv_w_gather = f.pad_gather_source(self.ops.inv_w)

    def _step_args(self):
        if self.regime == "edge_tile":
            return (self.fmt, self._inv_w_gather, self._mu_pad, self._c_pad)
        return (self.fmt, self.ops.inv_w, self.ops.mu, self.ops.c)

    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        s_init = self._to_native(self._s0_node_order(s0))
        s, gap, t = self._loop(self._step_args(), s_init, self._scale(),
                               jnp.asarray(tol, self.ops.dtype),
                               jnp.asarray(max_iter, jnp.int32))
        s_n = self._from_native(s)
        return self._result(self.ops.psi_epilogue(s_n), s_n, gap, t, tol)

    # -- delta rebuilds ------------------------------------------------- #
    def patch_activity(self, users, lam=None, mu=None) -> bool:
        self.host.patch_activity(users, lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        if self.regime == "edge_tile":
            self._refresh_padded()
        return True

    def patch_edges(self, src, dst) -> bool:
        src, dst = self.host.patch_edges(src, dst)
        self._graph_stale = True
        if self.regime == "edge_tile":
            self._patch_edges_edge_tile(src, dst)
        else:
            self._patch_edges_bsr(src, dst)
        self.ops = self.host.to_device(self.dtype)   # edge arrays grew
        if self.regime == "edge_tile":
            self._refresh_padded()
        return True

    # -- edge-tile regime: O(Δ) sentinel-slot inserts -------------------- #
    def _rebuild_tile_cursor(self) -> None:
        """Per-tile free-slot cursor, computed once per format build.

        ``build_edge_tiles`` fills each node tile's block span contiguously
        from its first slot, and cursor inserts preserve that invariant —
        so a tile's free sentinel slots are exactly the tail of its span
        and placing an edge is O(1): no per-edge scan over blocks/slots.
        """
        f = self.fmt_host
        used_per_block = (f.src_idx.reshape(f.num_blocks, -1)
                          != f.n).sum(axis=1)
        self._tile_first_block = np.searchsorted(
            f.block_tile, np.arange(f.num_tiles))
        blocks_per_tile = np.bincount(f.block_tile, minlength=f.num_tiles)
        self._tile_capacity = blocks_per_tile.astype(np.int64) * f.eblk
        self._tile_used = np.bincount(
            f.block_tile, weights=used_per_block,
            minlength=f.num_tiles).astype(np.int64)

    def _insert_into_tiles(self, src: np.ndarray, dst: np.ndarray):
        """Place new edges into free (sentinel) slots of their dst tile.

        O(Δ) total via the per-tile cursor. Mutates the host format in
        place and returns the placed ``(block, flat_slot, src_id,
        dst_local)`` tuples, or ``None`` when any tile would overflow (the
        caller rebuilds the format; nothing is mutated in that case)."""
        f = self.fmt_host
        tile, eblk = f.tile, f.eblk
        tiles_of = np.asarray(dst, np.int64) // tile
        need = np.bincount(tiles_of, minlength=f.num_tiles)
        if np.any(self._tile_used + need > self._tile_capacity):
            return None
        flat_src = f.src_idx.reshape(f.num_blocks, -1)
        flat_dstl = f.dst_local.reshape(f.num_blocks, -1)
        placed = []
        for s, d, t in zip(src, dst, tiles_of):
            t = int(t)
            u = int(self._tile_used[t])
            b = int(self._tile_first_block[t]) + u // eblk
            slot = u % eblk
            d_loc = int(d) - t * tile
            flat_src[b, slot] = s
            flat_dstl[b, slot] = d_loc
            placed.append((b, slot, int(s), d_loc))
            self._tile_used[t] = u + 1
        return placed

    def _patch_edges_edge_tile(self, src: np.ndarray,
                               dst: np.ndarray) -> None:
        slots = self._insert_into_tiles(src, dst)
        if slots is None:
            # a tile ran out of sentinel slots — rebuild the edge-tile
            # format only (the operator arrays stay incrementally patched;
            # the shape change means the next run() retraces once)
            self._build_format(self.graph)
        elif slots:
            # fast path: one batched scatter of the new slots into the
            # device-resident format instead of re-uploading all M edges
            b, slot, s_id, d_loc = (np.asarray(x) for x in zip(*slots))
            i, j = np.divmod(slot, self.e2)
            src_idx = self.fmt.src_idx.at[b, i, j].set(
                jnp.asarray(s_id, jnp.int32))
            dst_local = self.fmt.dst_local.at[b, i, j].set(
                jnp.asarray(d_loc, jnp.int32))
            self.fmt = dataclasses.replace(self.fmt, src_idx=src_idx,
                                           dst_local=dst_local)

    # -- BSR regime: dense-tile increments ------------------------------ #
    def _rebuild_bsr_block_map(self) -> None:
        f = self.fmt_host
        self._bsr_blocks = {
            (int(st), int(dt)): b
            for b, (st, dt) in enumerate(zip(f.src_tile, f.dst_tile))}

    def _patch_edges_bsr(self, src: np.ndarray, dst: np.ndarray) -> None:
        if src.size == 0:
            return
        f = self.fmt_host
        st = np.asarray(src, np.int64) // f.ts
        dt = np.asarray(dst, np.int64) // f.td
        if any((int(a), int(b)) not in self._bsr_blocks
               for a, b in zip(st, dt)):
            # a brand-new (src_tile, dst_tile) block — rebuild the BSR
            # format (shape change → one retrace), never the operators
            self._build_format(self.graph)
            return
        b = np.asarray([self._bsr_blocks[(int(a), int(c))]
                        for a, c in zip(st, dt)])
        r = np.asarray(src, np.int64) % f.ts
        c = np.asarray(dst, np.int64) % f.td
        np.add.at(f.tiles, (b, r, c), 1.0)
        self.fmt = dataclasses.replace(
            self.fmt, tiles=self.fmt.tiles.at[b, r, c].add(1.0))


@register_backend("auto")
class AutoEngine(PallasEngine):
    """``pallas`` with the regime chosen per graph by the autotuner.

    ``prepare`` asks :func:`repro.kernels.autotune.plan_regime` for the
    cheapest execution plan (cost model by default; ``microbench=True``
    times one step of every candidate). Plans are memoized in
    the process-level :data:`~repro.kernels.autotune.PLAN_CACHE` keyed by
    graph *structure*, so ``patch_activity`` / warm re-``prepare`` cycles
    never re-plan, and the compiled solver loop is only rebuilt when the
    plan actually changes.

    Every ``run`` closes the calibration loop: the resolve's measured
    per-step wall time is fed to :mod:`repro.obs.calibrate` as a
    (modeled bytes, measured µs) sample for the plan's regime, so
    model-only planning converges toward this machine's measured
    rankings (``calibrate=False`` opts out). Feeding is independent of
    the obs sinks — it is planner input, not telemetry.
    """

    def __init__(self, *, microbench: bool = False, plan_cache=None,
                 calibrate: bool = True, **kw):
        kw.pop("regime", None)          # the planner owns the regime
        self.microbench = bool(microbench)
        self.calibrate = bool(calibrate)
        self._plan_cache = plan_cache
        self.plan = None
        super().__init__(**kw)

    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        from ..kernels import autotune
        cache = (autotune.PLAN_CACHE if self._plan_cache is None
                 else self._plan_cache)
        plan = autotune.plan_regime(
            graph, microbench=self.microbench, dtype=self.dtype,
            interpret=self.interpret, cache=cache,
            calibration=(None if not self.calibrate else
                         autotune._USE_GLOBAL))
        if plan != self.plan:
            self.plan = plan
            self._apply_plan(plan)
        return super().prepare(graph, activity)

    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        t0 = time.perf_counter()
        res = super().run(tol=tol, max_iter=max_iter, s0=s0)
        wall = time.perf_counter() - t0
        it = int(res.iterations)
        # a >3-iteration resolve amortizes compile/dispatch overhead enough
        # for wall/iter to stand in for the step-span time the model predicts
        if (self.calibrate and self.plan is not None and it > 3
                and wall > 0.0 and self.plan.est_bytes > 0.0):
            from ..obs import calibrate as obs_calibrate
            obs_calibrate.get_store().observe(
                self.plan.regime, self.plan.est_bytes, wall / it * 1e6,
                source="step_span")
        return res
    # super().run is already the instrumented PallasEngine.run — marking
    # this thin timer prevents a second nested span/record per resolve
    run._obs_instrumented = True


# --------------------------------------------------------------------- #
# distributed — 2-D block-cyclic shard_map schedule, host-chunked
# --------------------------------------------------------------------- #
@register_backend("distributed")
class DistributedEngine(PsiEngine):
    """Sharded Power-ψ over a (data, model) mesh.

    The device program is a fixed-shape ``chunk_iters``-step scan; the
    criterion is evaluated on the host between chunks (iteration counts are
    therefore multiples of ``chunk_iters``), exactly the
    ``runtime/psi_driver.py`` schedule. The gap norm must be ``l1`` (what the
    sharded step psums). ``s`` is converted to/from node order at the API
    boundary so results interchange with the other backends.

    ``accelerate=True`` applies the Aitken jump at *chunk* granularity via
    :class:`ChunkExtrapolator` (the on-device per-iteration loop would break
    the fixed-shape scan contract). ``patch_edges`` is a block-local O(Δ)
    insert into the node-stable 2-D partition; a genuine block overflow
    (``e_max`` exceeded) is handled per ``on_overflow``:

    * ``"regrow"`` (default) — warn naming the overflowing block and the
      required capacity, rebuild the partitioned device arrays from the
      already-patched host graph at the grown ``e_max``, and return True
      (the patch *succeeded*; callers never see a silent no-op).
    * ``"raise"`` — raise :class:`~repro.core.distributed.BlockOverflowError`
      (block, ``e_max``, required capacity) for callers that budget
      capacity themselves.
    """

    def __init__(self, *, mesh=None, chunk_iters: int = 16,
                 on_overflow: str = "regrow", **kw):
        super().__init__(**kw)
        if self.criterion.norm != "l1":
            raise ValueError("distributed backend psums an l1 gap; "
                             f"got norm={self.criterion.norm!r}")
        if on_overflow not in ("regrow", "raise"):
            raise ValueError(f"on_overflow must be 'regrow' or 'raise'; "
                             f"got {on_overflow!r}")
        self.mesh = mesh
        self.chunk_iters = chunk_iters
        self.on_overflow = on_overflow
        self.dist = None

    def _install_dist(self, dist) -> None:
        self.dist = dist
        self._run_chunk = dist.make_run(chunk_iters=self.chunk_iters)
        self._one_step = jax.jit(dist.make_step())
        self._epi = jax.jit(dist.make_epilogue())

    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        from .distributed import DistributedPsi
        self._base_prepare(graph, activity)
        if self.mesh is None:
            self.mesh = jax.make_mesh((len(jax.devices()), 1),
                                      ("data", "model"))
        self._install_dist(DistributedPsi.from_graph(
            graph, activity, self.mesh, dtype=self.dtype))
        return EngineState(s=self.dist.arrays.c_src)

    def step(self, state: EngineState) -> EngineState:
        s_new, gap = self._one_step(state.s, self.dist.arrays)
        scale = self.criterion.scale(self.host.b_norm)
        return EngineState(s=s_new, gap=scale * float(gap), t=state.t + 1)

    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        part = self.dist.part
        if s0 is None:
            s = self.dist.arrays.c_src
        else:
            s_host = np.asarray(np.asarray(s0),
                                np.dtype(jnp.dtype(self.dtype).name))
            s = jax.device_put(
                part.to_src_layout(s_host),
                jax.sharding.NamedSharding(
                    self.mesh,
                    jax.sharding.PartitionSpec(self.dist.src_axes, None)))
        scale = self.criterion.scale(self.host.b_norm)
        extrap = ChunkExtrapolator(tol) if self.accelerate else None
        it, gap = 0, float("inf")
        while it < max_iter and gap > tol:
            s_new, gap_dev = self._run_chunk(s, self.dist.arrays)
            it += self.chunk_iters
            raw = float(gap_dev)
            gap = scale * raw
            # the host already read this gap — record it, free of syncs
            obs_convergence.record_gap(it, raw=raw, certified=gap)
            s = extrap.advance(s, s_new, gap) if extrap else s_new
        psi_piece = self._epi(s, self.dist.arrays)
        psi = part.from_src_layout(
            np.asarray(psi_piece).reshape(part.d, -1))
        s_node = part.from_src_layout(np.asarray(jax.device_get(s)))
        return self._result(jnp.asarray(psi, self.dtype),
                            jnp.asarray(s_node, self.dtype), gap, it, tol)

    def patch_activity(self, users, lam=None, mu=None) -> bool:
        # partition and edge layouts are untouched; only the activity-derived
        # device arrays are rebuilt (no re-partition, no edge re-sort)
        self.host.patch_activity(users, lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        self.dist.arrays = self.dist.build_arrays(self.graph, self.activity)
        return True

    def patch_edges(self, src, dst) -> bool:
        """Block-local edge insert into the node-stable 2-D partition.

        The node → (row, col) ownership map depends only on (n, d, mo, q),
        so a new edge lands in exactly one block; it is merged dst-sorted
        into that block's host slice (sentinels stay at the tail) and the
        touched block rows + 1/w entries are scattered into the device
        arrays — no re-partition, no O(M) rebuild. A genuine ``e_max``
        block overflow regrows the partition (with a warning naming the
        block and required capacity) or raises
        :class:`~repro.core.distributed.BlockOverflowError`, per the
        engine's ``on_overflow`` option — never a silent no-op.
        """
        from .distributed import BlockOverflowError, DistributedPsi
        p = self.dist.part
        nc, q = p.nc, p.q
        # probe (no mutation) first: on_overflow='raise' must leave the
        # host mirror untouched, or a caught-and-retried patch would dedup
        # against the half-applied state and silently skip the device insert
        src_k, dst_k = self.host.filter_new_edges(src, dst)
        if src_k.size == 0:
            return True
        s64 = src_k.astype(np.int64)
        d64 = dst_k.astype(np.int64)
        c_of_src = s64 // nc
        off = s64 - c_of_src * nc
        row = off // q
        src_loc = (c_of_src * q + (off - row * q)).astype(np.int32)
        col = d64 // nc
        dst_loc = (d64 - col * nc).astype(np.int32)
        add = np.zeros((p.d, p.mo), np.int64)
        np.add.at(add, (row, col), 1)
        over = p.e_counts + add > p.e_max
        if np.any(over):
            # name the *worst* overflowing block so the reported required
            # capacity belongs to the block in the message
            need = p.e_counts + add
            r_o, c_o = (int(x) for x in
                        np.unravel_index(int(np.argmax(need)), need.shape))
            required = int(need[r_o, c_o])
            if self.on_overflow == "raise":
                raise BlockOverflowError((r_o, c_o), int(p.e_max), required)
            # structured + counted (obs_events_total{event=block_overflow_
            # regrow}) AND still a RuntimeWarning, exactly as before
            obs_log.warn(
                "block_overflow_regrow",
                f"distributed patch_edges: block (row={r_o}, col={c_o}) "
                f"overflows e_max={int(p.e_max)} (insert requires capacity "
                f">= {required}); regrowing the partition from the patched "
                f"graph", category=RuntimeWarning,
                row=r_o, col=c_o, e_max=int(p.e_max), required=required)
            # commit the edges to the host mirror, then repartition once at
            # the grown e_max (one retrace, no second data path)
            self.host.insert_filtered(src_k, dst_k)
            self._graph_stale = True
            self._install_dist(DistributedPsi.from_graph(
                self.graph, self.activity, self.mesh, dtype=self.dtype))
            self.ops = self.host.to_device(self.dtype)
            return True
        self.host.insert_filtered(src_k, dst_k)
        self._graph_stale = True
        a = self.dist.arrays
        new_src_local, new_dst_local = a.src_local, a.dst_local
        for r, c in {(int(r), int(c)) for r, c in zip(row, col)}:
            sel = (row == r) & (col == c)
            s_row = p.src_local[r, c]
            d_row = p.dst_local[r, c]
            cnt = int(p.e_counts[r, c])
            for sl, dl in sorted(zip(src_loc[sel], dst_loc[sel]),
                                 key=lambda e: e[1]):
                ins = int(np.searchsorted(d_row[:cnt], dl, side="right"))
                s_row[ins + 1:cnt + 1] = s_row[ins:cnt].copy()
                d_row[ins + 1:cnt + 1] = d_row[ins:cnt].copy()
                s_row[ins], d_row[ins] = sl, dl
                cnt += 1
            p.e_counts[r, c] = cnt
            new_src_local = new_src_local.at[r, c].set(jnp.asarray(s_row))
            new_dst_local = new_dst_local.at[r, c].set(jnp.asarray(d_row))
        # 1/w changed only at the src endpoints of the new edges
        g = np.unique(s64)
        c_of = g // nc
        off_g = g - c_of * nc
        r_g = off_g // q
        loc_g = c_of * q + (off_g - r_g * q)
        vals = jnp.asarray(self.host.inv_w[g], a.inv_w_src.dtype)
        self.dist.arrays = dataclasses.replace(
            a, src_local=new_src_local, dst_local=new_dst_local,
            inv_w_src=a.inv_w_src.at[r_g, loc_g].set(vals))
        self.ops = self.host.to_device(self.dtype)   # epilogue consistency
        return True


# --------------------------------------------------------------------- #
# async — bounded-staleness overlapped chunk scheduler (repro.asyncexec)
# --------------------------------------------------------------------- #
@register_backend("async")
class AsyncEngine(PsiEngine):
    """Power-ψ through the bounded-staleness chunk scheduler.

    The node set splits into ``num_chunks`` dst-row chunks; each carries an
    epoch counter and steps against the latest published board without a
    global barrier — a chunk may run up to ``tau`` epochs ahead of the
    slowest one (``tau=0`` is exactly the bulk-synchronous schedule).
    Termination is gated by the stale-corrected Eq. 19 certificate and
    always sealed by a synchronous verification sweep, so results are
    interchangeable with every other backend (docs/ASYNC.md).

    ``delay_hook(chunk, epoch) -> seconds`` injects simulated stragglers;
    ``read_hook(reader, neighbor, epochs) -> lag`` forces reads from the
    epoch history (the staleness-injection test harness). The gap norm is
    ``l1`` (what the chunk deltas sum to).
    """

    def __init__(self, *, num_chunks: int = 4, tau: int = 2,
                 max_workers: int | None = None, delay_hook=None,
                 read_hook=None, lane_pad: int = 128, **kw):
        super().__init__(**kw)
        if self.criterion.norm != "l1":
            raise ValueError("async backend sums per-chunk l1 gaps; "
                             f"got norm={self.criterion.norm!r}")
        if self.accelerate:
            raise ValueError(
                "async backend has no Aitken composition (a mixed-epoch Δ "
                "is not a contraction sample — see ChunkExtrapolator's "
                "epoch guard); run accelerate on a synchronous backend")
        from ..asyncexec.staleness import StalenessBound
        StalenessBound(tau)                  # validate tau eagerly
        self.num_chunks = int(num_chunks)
        self.tau = int(tau)
        self.max_workers = max_workers
        self.delay_hook = delay_hook
        self.read_hook = read_hook
        self.lane_pad = int(lane_pad)
        self.sched = None
        self.chunked = None

    def prepare(self, graph: Graph, activity: Activity) -> EngineState:
        from ..asyncexec.scheduler import (AsyncChunkScheduler,
                                           ChunkedOperators)
        from ..asyncexec.staleness import StalenessBound
        self._base_prepare(graph, activity)
        self.chunked = ChunkedOperators(self.host, self.num_chunks,
                                        dtype=self.dtype,
                                        lane_pad=self.lane_pad)
        self.sched = AsyncChunkScheduler(
            self.chunked, bound=StalenessBound(self.tau),
            max_workers=self.max_workers, delay_hook=self.delay_hook,
            read_hook=self.read_hook)
        return EngineState(s=self.chunked.board0)

    def step(self, state: EngineState) -> EngineState:
        """One *synchronous* sweep of every chunk — the protocol-level step
        (the overlap lives in ``run``, not here)."""
        board, raw = self.sched.sync_sweep(jnp.asarray(state.s))
        return EngineState(s=board, gap=float(self._scale()) * raw,
                           t=state.t + 1)

    def run(self, *, tol=None, max_iter=None, s0=None) -> PsiResult:
        tol, max_iter = self.criterion.resolve(tol, max_iter)
        self.sched.reset(s0=None if s0 is None
                         else np.asarray(self._s0_node_order(s0)))
        out = self.sched.run(tol=tol, max_epochs=max_iter,
                             scale=float(self._scale()))
        self.last_run = out                  # staleness/overlap observability
        s_node = jnp.asarray(self.chunked.node_order(out.s), self.dtype)
        t = int(out.epochs.max())
        res = self._result(self.ops.psi_epilogue(s_node), s_node, out.gap,
                           t, tol)
        # converged comes from the scheduler, not gap ≤ tol: an epoch-budget
        # exit reports the latest *stale* gap sum, which may under-report
        # the true residual and must never claim convergence unverified
        return dataclasses.replace(
            res, converged=jnp.asarray(bool(out.converged)),
            # honest currency: chunk-steps / chunks-per-sweep, + epilogue
            matvecs=jnp.asarray(
                -(-out.total_steps // self.num_chunks) + 1, jnp.int32))

    # -- delta hooks (mid-flight capable at the scheduler level) --------- #
    def patch_activity(self, users, lam=None, mu=None) -> bool:
        self.host.patch_activity(users, lam=lam, mu=mu)
        self.ops = self.host.refresh_node_arrays(self.ops, self.dtype)
        self.sched.patch_node_arrays()
        return True

    def patch_edges(self, src, dst) -> bool:
        src, dst = self.host.patch_edges(src, dst)
        self._graph_stale = True
        self.ops = self.host.to_device(self.dtype)
        if src.size:
            self.sched.patch_edges(src, dst)
        return True

    def unpatch_edges(self, src, dst) -> bool:
        src, dst = self.host.remove_edges(src, dst)
        if src.size:
            self._graph_stale = True
            self.ops = self.host.to_device(self.dtype)
            # same touched-chunk rebuild as an insert: the scheduler's
            # patch hook re-reads the (already shrunk) host mirror
            self.sched.patch_edges(src, dst)
        return True
