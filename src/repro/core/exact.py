"""Exact ψ-score via direct sparse solve — the ψ_true oracle of Exp. 1–2.

sᵀ = cᵀ(I − A)⁻¹  ⇔  (I − A)ᵀ s = c, solved with a sparse LU (SciPy), then
ψᵀ = (sᵀB + dᵀ)/N. Feasible up to ~10⁵ nodes; the paper uses DBLP (12 591)
for exactly this reason.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp
import scipy.sparse.linalg as spla

from ..graphs.structure import Graph
from .activity import Activity

__all__ = ["exact_psi"]


def exact_psi(graph: Graph, activity: Activity) -> tuple[np.ndarray, np.ndarray]:
    """Return (ψ_true, s_true) in float64."""
    n = graph.n
    lam = activity.lam.astype(np.float64)
    mu = activity.mu.astype(np.float64)
    total = lam + mu
    w = np.zeros(n)
    np.add.at(w, graph.src, total[graph.dst])
    inv_w = np.where(w > 0, 1.0 / np.where(w > 0, w, 1.0), 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        c = np.where(total > 0, mu / total, 0.0)
        d = np.where(total > 0, lam / total, 0.0)

    # Aᵀ[i, j] = A[j, i] = μ_i / w_j for each follow edge (j → i)
    at = sp.csr_matrix(
        (mu[graph.dst] * inv_w[graph.src], (graph.dst, graph.src)),
        shape=(n, n))
    s = spla.spsolve(sp.identity(n, format="csr") - at, c)

    # ψᵀ = (sᵀB + dᵀ)/N with (sᵀB)_i = λ_i Σ_{j→i} s_j / w_j
    push = np.zeros(n)
    np.add.at(push, graph.dst, s[graph.src] * inv_w[graph.src])
    psi = (lam * push + d) / n
    return psi, s
