"""Power-NF (Algorithm 1 of [10]) — the state-of-the-art baseline.

Solves the news-feed fixed point ``p_i = A p_i + b_i`` *per origin user i*
(N systems of size N), then maps to walls via ``q_i = C p_i + d_i`` and
averages to get ψ_i. This is the method the paper beats; we implement it
faithfully so Experiments 1–3 can reproduce the comparison.

Faithfulness notes:
  * each origin has its *own* convergence loop (per-column gap & stop);
  * the mat-vec count is per-origin — a chunk iteration with K active
    columns costs K mat-vecs, matching a sequential Alg. 1 run;
  * chunking over origins is purely an execution-layout choice (the paper's
    own library loops origins one by one; we vectorize the loop body).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .operators import PsiOperators

__all__ = ["PowerNFResult", "power_nf"]


@dataclasses.dataclass(frozen=True)
class PowerNFResult:
    psi: np.ndarray
    matvecs: int            # total N-vector mat-vecs across all origins
    max_iterations: int     # worst per-origin iteration count


@partial(jax.jit, static_argnames=("tol", "max_iter"))
def _chunk_solve(ops: PsiOperators, origins: jax.Array, *, tol: float,
                 max_iter: int):
    """Solve p_i = A p_i + b_i for a chunk of origins, per-column stopping."""
    bc = ops.b_columns(origins)                     # [N, K]
    k = origins.shape[0]

    def cond(state):
        _, active, _, t = state
        return jnp.any(active) & (t < max_iter)

    def body(state):
        p, active, matvecs, t = state
        p_new = ops.right_matvec(p) + bc            # [N, K]
        gaps = jnp.sum(jnp.abs(p_new - p), axis=0)  # per-column L1 (paper)
        p = jnp.where(active[None, :], p_new, p)    # frozen columns keep value
        matvecs = matvecs + jnp.sum(active, dtype=jnp.int32)
        active = active & (gaps > tol)
        return p, active, matvecs, t + 1

    p0 = bc                                          # Alg. 1: p_i ← b_i
    state = (p0, jnp.ones((k,), bool), jnp.asarray(0, jnp.int32),
             jnp.asarray(0, jnp.int32))
    p, _, matvecs, t = jax.lax.while_loop(cond, body, state)
    # ψ_i = (1/N)(Σ_n c_n p_i^(n) + d_i)   [q_i = C p_i + d_i, then average]
    psi = (ops.c @ p + ops.d[origins]) / ops.n
    return psi, matvecs, t


def power_nf(ops: PsiOperators, *, tol: float = 1e-9, max_iter: int = 10_000,
             chunk: int = 256, origins: np.ndarray | None = None
             ) -> PowerNFResult:
    """Run Algorithm 1 for all origins (or a subset) in column chunks."""
    all_origins = (np.arange(ops.n, dtype=np.int32)
                   if origins is None else np.asarray(origins, np.int32))
    psi = np.zeros(all_origins.shape[0], np.dtype(jnp.dtype(ops.dtype).name))
    total_mv = 0
    worst_t = 0
    for lo in range(0, all_origins.shape[0], chunk):
        sel = all_origins[lo:lo + chunk]
        p_chunk, mv, t = _chunk_solve(ops, jnp.asarray(sel), tol=tol,
                                      max_iter=max_iter)
        psi[lo:lo + sel.shape[0]] = np.asarray(p_chunk)
        total_mv += int(mv)
        worst_t = max(worst_t, int(t))
    return PowerNFResult(psi=psi, matvecs=total_mv, max_iterations=worst_t)
