"""Power-ψ (Algorithm 2 of the paper): fast approximation of the ψ-score.

One left power iteration ``sᵀ ← sᵀA + cᵀ`` starting from ``s₀ = c``, with the
termination rule ``‖B‖ · ‖s_t − s_{t−1}‖ ≤ ε`` which by Eq. (19) guarantees
the ψ trajectory moved less than ε/N, followed by the single epilogue
``ψᵀ = (sᵀB + dᵀ)/N``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .operators import PsiOperators

__all__ = ["PsiResult", "power_psi", "power_psi_fixed", "make_power_psi_step"]

_NORMS = {
    "l1": lambda x: jnp.sum(jnp.abs(x)),
    "l2": lambda x: jnp.sqrt(jnp.sum(x * x)),
    "linf": lambda x: jnp.max(jnp.abs(x)),
}


@dataclasses.dataclass(frozen=True)
class PsiResult:
    psi: jax.Array          # f[N] — the influence scores
    s: jax.Array            # f[N] — converged series Σ cᵀAᵗ
    iterations: jax.Array   # i32 scalar — power iterations run
    gap: jax.Array          # final ‖B‖·‖Δs‖ value
    converged: jax.Array    # bool scalar
    matvecs: jax.Array      # i32 — sparse mat-vecs consumed (incl. epilogue)


def make_power_psi_step(ops: PsiOperators):
    """One Alg. 2 iteration: s ← sᵀA + c (shared-push edge form)."""

    def step(s: jax.Array) -> jax.Array:
        return ops.mu * ops.push(s) + ops.c

    return step


def power_psi(ops: PsiOperators, *, tol: float = 1e-9, max_iter: int = 10_000,
              norm: str = "l1", s0: jax.Array | None = None,
              use_b_norm: bool = True) -> PsiResult:
    """Run Algorithm 2 to the requested s-tolerance.

    Args:
      ops: precomputed edge-form operators.
      tol: ε of Alg. 2 (on ‖B‖·‖Δs‖ when ``use_b_norm`` else on ‖Δs‖).
      max_iter: safety bound on iterations.
      norm: 'l1' (paper's choice), 'l2' or 'linf'.
      s0: warm-start vector (incremental serving); defaults to c per Alg. 2.
      use_b_norm: keep the paper's ‖B‖ factor inside the gap.
    """
    nrm = _NORMS[norm]
    step = make_power_psi_step(ops)
    scale = ops.b_norm if use_b_norm else jnp.asarray(1.0, ops.dtype)
    init_s = ops.c if s0 is None else jnp.asarray(s0, ops.dtype)

    @jax.jit
    def run(s_init):
        def cond(state):
            _, gap, t = state
            return (gap > tol) & (t < max_iter)

        def body(state):
            s, _, t = state
            s_new = step(s)
            gap = scale * nrm(s_new - s)
            return s_new, gap, t + 1

        s, gap, t = jax.lax.while_loop(
            cond, body, (s_init, jnp.asarray(jnp.inf, ops.dtype),
                         jnp.asarray(0, jnp.int32)))
        psi = ops.psi_epilogue(s)
        return psi, s, gap, t

    psi, s, gap, t = run(init_s)
    return PsiResult(psi=psi, s=s, iterations=t, gap=gap,
                     converged=gap <= tol, matvecs=t + 1)


@partial(jax.jit, static_argnums=(1,))
def power_psi_fixed(ops: PsiOperators, num_iters: int,
                    s0: jax.Array | None = None):
    """Fixed-iteration scan variant (for lowering/dry-runs and ablations).

    Returns (psi, s, per-iteration L1 gaps ‖Δs‖ — *without* the ‖B‖ factor).
    """
    step = make_power_psi_step(ops)

    def body(s, _):
        s_new = step(s)
        return s_new, jnp.sum(jnp.abs(s_new - s))

    init = ops.c if s0 is None else s0
    s, gaps = jax.lax.scan(body, init, None, length=num_iters)
    return ops.psi_epilogue(s), s, gaps
