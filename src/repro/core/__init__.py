"""ψ-score core: the paper's contribution (Power-ψ) plus baselines."""
from .activity import Activity, RATE_FLOOR, heterogeneous, homogeneous
from .operators import (PsiOperators, HostOperators, build_operators,
                        dense_operators)
from .power_psi import PsiResult, power_psi, power_psi_fixed
from .power_nf import PowerNFResult, power_nf
from .pagerank import PageRankResult, build_pagerank_ops, pagerank
from .exact import exact_psi
from .engine import (ConvergenceCriterion, EngineState, PsiEngine,
                     make_engine, register_backend, available_backends,
                     make_reference_step, make_dense_step,
                     make_edge_tile_step, make_batched_loop)
from .incremental import PsiService, RankingCache, RankedQueries
from .accelerated import power_psi_accelerated

__all__ = [
    "Activity", "RATE_FLOOR", "heterogeneous", "homogeneous",
    "PsiOperators", "HostOperators", "build_operators", "dense_operators",
    "PsiResult", "power_psi", "power_psi_fixed",
    "PowerNFResult", "power_nf",
    "PageRankResult", "build_pagerank_ops", "pagerank",
    "exact_psi", "PsiService", "RankingCache", "RankedQueries",
    "power_psi_accelerated",
    "ConvergenceCriterion", "EngineState", "PsiEngine",
    "make_engine", "register_backend", "available_backends",
    "make_reference_step", "make_dense_step", "make_edge_tile_step",
    "make_batched_loop",
]
