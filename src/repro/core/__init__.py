"""ψ-score core: the paper's contribution (Power-ψ) plus baselines."""
from .activity import Activity, heterogeneous, homogeneous
from .operators import PsiOperators, build_operators, dense_operators
from .power_psi import PsiResult, power_psi, power_psi_fixed
from .power_nf import PowerNFResult, power_nf
from .pagerank import PageRankResult, build_pagerank_ops, pagerank
from .exact import exact_psi
from .incremental import PsiService
from .accelerated import power_psi_accelerated

__all__ = [
    "Activity", "heterogeneous", "homogeneous",
    "PsiOperators", "build_operators", "dense_operators",
    "PsiResult", "power_psi", "power_psi_fixed",
    "PowerNFResult", "power_nf",
    "PageRankResult", "build_pagerank_ops", "pagerank",
    "exact_psi", "PsiService", "power_psi_accelerated",
]
