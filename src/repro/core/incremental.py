"""Incremental ψ-score serving runtime — backend-pluggable, delta-rebuilt.

The Alg. 2 iteration is an affine contraction (ρ(A) < 1), so after a graph or
activity update the fixed point moves continuously; restarting the power
iteration from the previous s* instead of c needs only
O(log(‖Δs*‖/ε) / log(1/ρ)) iterations — typically a handful for small updates.

:class:`PsiService` is built on the unified :class:`~repro.core.engine.PsiEngine`
abstraction: any registered backend (``reference``, ``pallas``, ``auto``,
``accelerated``, ``distributed``) serves queries, every backend warm-starts from the previous
fixed point, and mutations go through the engines' O(Δ) delta hooks
(``patch_activity`` / ``patch_edges``) instead of a full operator rebuild.
:class:`RankingCache` is the batched query layer shared with
``launch/serve.py`` and ``runtime/psi_driver.py``: the descending order is
computed once per fixed point and memoized until the next mutation.

Since the multi-tenant fleet (:mod:`repro.serving`) landed, the read-side
surface lives in the :class:`RankedQueries` mixin and ``PsiService`` is just
its single-engine instantiation — the fleet's per-tenant
:class:`~repro.serving.fleet.TenantView` is the other one, obtained here via
:meth:`PsiService.from_fleet` so serving code can swap a dedicated engine
for a fleet lane without touching its query sites.
"""
from __future__ import annotations

import numpy as np

from ..graphs.structure import Graph
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .activity import Activity
from .engine import PsiEngine, make_engine
from .operators import _validate_rates
from .power_psi import PsiResult

__all__ = ["PsiService", "RankingCache", "RankedQueries"]


class RankingCache:
    """Batched query layer over one ψ fixed point.

    Memoizes the descending sort (one ``argsort`` per fixed point, not per
    query); ``top_k`` uses ``jax.lax.top_k`` so a device-resident ψ never
    round-trips through a host sort for small k.

    ``err_bound`` is the solve's certified per-node ``|ψ_exact − ψ|``
    bound when the engine produced one
    (:meth:`~repro.core.engine.PsiEngine.psi_error_bound`); it powers
    :meth:`top_k_certified` — rank-stability statements about the *exact*
    scores, served from the approximate ones.
    """

    def __init__(self, psi, *, err_bound: float | None = None):
        self._psi_dev = psi                       # jax array (or numpy)
        self._psi = np.asarray(psi)
        self.err_bound = err_bound
        self._order: np.ndarray | None = None
        self._rank: np.ndarray | None = None

    @property
    def psi(self) -> np.ndarray:
        return self._psi

    def scores_batch(self, users: np.ndarray) -> np.ndarray:
        return self._psi[np.asarray(users)]

    def top_k(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        k = min(int(k), self._psi.size)           # clip like argsort[:k] did
        if self._order is not None:               # sort already paid for
            idx = self._order[:k]
            return idx, self._psi[idx]
        import jax
        import jax.numpy as jnp
        vals, idx = jax.lax.top_k(jnp.asarray(self._psi_dev), k)
        return np.asarray(idx), np.asarray(vals)

    def rank_of(self, users: np.ndarray) -> np.ndarray:
        self._ensure_order()
        return self._rank[np.asarray(users)]

    def top_k_certified(self, k: int):
        """:class:`~repro.localpush.topk.TopKCertificate` for the served ψ.

        ``certified`` is True only when the cache carries an error bound
        and the k/k+1 margin clears it — i.e. the returned *set* provably
        equals the exact top-k. Without a bound (non-certifying backends)
        the indices are still served, honestly marked uncertified.
        """
        from ..localpush.topk import certify_top_k
        bound = self.err_bound
        if bound is not None and self._psi.dtype != np.float64:
            # the certificate covers the solver's float64 ψ; a lower-precision
            # served copy adds one cast rounding per node on top of it
            bound = float(bound) + float(np.finfo(self._psi.dtype).eps) \
                * float(np.abs(self._psi).max(initial=0.0))
        return certify_top_k(self._psi, k, bound)

    def _ensure_order(self) -> None:
        if self._order is None:
            self._order = np.argsort(-self._psi, kind="stable")
            rank = np.empty_like(self._order)
            rank[self._order] = np.arange(self._order.size)
            self._rank = rank


class RankedQueries:
    """Read-side ψ-query surface over an abstract ``_query()``.

    Subclasses provide ``_query() -> RankingCache`` (fresh for the current
    fixed point); the mixin supplies the four canonical reads so a
    dedicated :class:`PsiService` and a fleet lane
    (:class:`repro.serving.fleet.TenantView`) are interchangeable at every
    query site.
    """

    def _obs_cache_state(self) -> str:
        """'hit' when this read will be served from a memoized ranking,
        'miss' when it must (re)build one. Overridable by subclasses whose
        cache lives elsewhere (the fleet's per-lane views)."""
        return "hit" if getattr(self, "_cache", None) is not None else "miss"

    def _read(self, op: str, fn):
        """Every public read funnels through here: latency histogram
        (``psi_query_seconds{op=}``), cache hit ratio, staleness-at-read
        counter, and a ``query`` span — all skipped in one branch when the
        telemetry plane is dark."""
        reg = obs_metrics.get_registry()
        if getattr(reg, "null", False) and not obs_trace.get_tracer().enabled:
            return fn(self._query())
        state = self._obs_cache_state()
        stale = bool(getattr(self, "stale", False))
        with obs_trace.span("query", op=op, cache=state) as sp:
            out = fn(self._query())
        # remembered for explain(): the facts of the most recent read
        self._last_read = dict(op=op, cache=state, stale=stale,
                               seconds=sp.duration_s)
        reg.histogram("psi_query_seconds",
                      "read-side ψ query latency (seconds)",
                      labelnames=("op",)).labels(op=op).observe(sp.duration_s)
        reg.counter("psi_query_cache_total",
                    "ranking-cache outcome at read time",
                    labelnames=("result",)).labels(result=state).inc()
        if stale:
            reg.counter("psi_query_stale_reads_total",
                        "reads served from a fixed point with deferred "
                        "patches pending").inc()
        return out

    def scores(self) -> np.ndarray:
        return self._read("scores", lambda c: c.psi)

    def scores_batch(self, users: np.ndarray) -> np.ndarray:
        """ψ for a batch of users (no ranking sort paid)."""
        return self._read("scores_batch", lambda c: c.scores_batch(users))

    def top_k(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        return self._read("top_k", lambda c: c.top_k(k))

    def top_k_certified(self, k: int):
        """Top-k plus its rank-stability certificate (see
        :meth:`RankingCache.top_k_certified`)."""
        return self._read("top_k_certified", lambda c: c.top_k_certified(k))

    def rank_of(self, users: np.ndarray) -> np.ndarray:
        return self._read("rank_of", lambda c: c.rank_of(users))

    def explain(self, *, op: str | None = None) -> str:
        """EXPLAIN-ANALYZE tree for the last resolve + query.

        Assembles the decision trail recorded by the planner stack
        (:mod:`repro.obs.explain`) — plan candidates, prunes, cache state,
        predicted vs measured cost, calibration factors — together with
        the owning resolve's convergence record, the last read's funnel
        facts (op, cache, staleness, wall time), and the served
        certificate bound.  Pure read: rendering never touches the engine
        state or the device.
        """
        from ..obs import calibrate as obs_calibrate
        from ..obs import convergence as obs_convergence
        from ..obs import explain as obs_explain
        g = getattr(self, "graph", None)
        decisions = obs_explain.decisions_for(
            n=getattr(g, "n", None), m=getattr(g, "m", None))
        tenant = getattr(self, "tenant_id", None)
        tracker = obs_convergence.get_tracker()
        series = tracker.series(tenant) or (
            tracker.series(None) if tenant is not None else [])
        resolve = series[-1] if series else None
        query = dict(getattr(self, "_last_read", None) or {})
        if op is not None:
            query["op"] = op
        cache = getattr(self, "_cache", None)
        if cache is not None and cache.err_bound is not None:
            query.setdefault("err_bound", f"{cache.err_bound:.3g}")
        query.setdefault("stale", bool(getattr(self, "stale", False)))
        store = obs_calibrate.get_store()
        extra = (dict(calibration_env=store.env,
                      calibration_samples=len(store),
                      calibration_generation=store.generation)
                 if len(store) else None)
        backend = getattr(self, "backend", "?")
        return obs_explain.explain_tree(
            header=f"EXPLAIN ANALYZE — power-ψ [backend={backend}]",
            resolve=resolve, decisions=decisions, query=query or None,
            extra=extra)


class PsiService(RankedQueries):
    """Maintains ψ-scores for a mutable (graph, activity) pair.

    Args:
      graph, activity: the initial platform state.
      tol / max_iter: shared convergence criterion for every (re)solve.
      backend: engine name — ``reference`` (default), ``pallas``, ``auto``,
        ``accelerated``, ``distributed``, ``async`` or ``push`` (local
        residual push with certified top-k; see docs/LOCALPUSH.md); see
        :func:`repro.core.engine.make_engine`.
      accelerate: opt the chosen backend into the Aitken-extrapolated loop
        (chunk-level for ``distributed``); ``accelerated`` implies it.
      check_every: gap-evaluation cadence of the solver loop (see
        docs/AUTOTUNE.md); 1 keeps the per-iteration check.
      engine_opts: extra backend kwargs (``tile=...``, ``mesh=...``,
        ``microbench=...``, ...).
    """

    def __init__(self, graph: Graph, activity: Activity, *, tol: float = 1e-8,
                 max_iter: int = 10_000, backend: str = "reference",
                 accelerate: bool = False, check_every: int = 1,
                 dtype=None, engine_opts: dict | None = None):
        import jax.numpy as jnp
        self.tol = tol
        self.max_iter = max_iter
        opts = dict(engine_opts or {})
        if accelerate:
            opts.setdefault("accelerate", True)
        if check_every != 1:
            opts.setdefault("check_every", check_every)
        self._engine: PsiEngine = make_engine(
            backend, graph=graph, activity=activity,
            dtype=dtype or jnp.float32, **opts)
        self._last: PsiResult | None = None
        self._cache: RankingCache | None = None
        self._pending = False            # deferred patches awaiting resolve
        self._early = False              # last solve stopped at a top-k cert
        self._dirty = 0                  # patched rows/edges since last solve

    @classmethod
    def from_fleet(cls, fleet, tenant_id: str):
        """A single-tenant serving view over a fleet lane.

        Returns a :class:`~repro.serving.fleet.TenantView` — the same
        query/mutation surface as a ``PsiService`` but solved inside the
        fleet's vmapped batch (so one device amortizes across tenants).
        """
        return fleet.view(tenant_id)

    # -- queries -------------------------------------------------------- #
    @property
    def backend(self) -> str:
        return self._engine.name

    @property
    def engine(self) -> PsiEngine:
        return self._engine

    @property
    def graph(self) -> Graph:
        return self._engine.graph

    def last_iterations(self) -> int:
        self._query()
        return int(self._last.iterations)

    @property
    def last_result(self) -> PsiResult | None:
        """The most recent solve's :class:`PsiResult` (None before the
        first solve) — measured gap/converged/matvecs observability for
        serving and benchmark code; does not trigger a solve."""
        return self._last

    # -- mutations (each warm-starts from the previous s*) --------------- #
    # ``resolve=False`` defers the warm re-solve: patches accumulate at the
    # engine level and the *stale* RankingCache keeps serving until
    # :meth:`resolve` — the contract the streaming ingestor's freshness
    # policy is built on (repro.stream; staleness is certified there).
    # An empty delta is a true no-op: no engine touch, no cache epoch
    # invalidation, no spurious re-solve (the ingestor coalesces event
    # windows that may net out to nothing).
    def update_activity(self, users: np.ndarray, lam: np.ndarray | None = None,
                        mu: np.ndarray | None = None, *,
                        resolve: bool = True) -> None:
        users = np.asarray(users).reshape(-1)
        if users.size == 0:
            return
        # reject NaN/Inf/negative rates here, before any engine is touched:
        # every backend's patch path must see only finite ≥ 0 rates, and a
        # rejected patch must leave the service serving its current fixed
        # point (HostOperators.patch_activity re-checks as a second wall)
        _validate_rates(lam, mu)
        if not self._engine.patch_activity(users, lam=lam, mu=mu):
            self._full_rebuild(activity=self._patched_activity(users, lam, mu))
        self._pending = True
        self._dirty += int(users.size)
        if resolve:
            self._resolve()

    def add_edges(self, src: np.ndarray, dst: np.ndarray, *,
                  resolve: bool = True) -> None:
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        if src.size == 0:
            return
        if not self._engine.patch_edges(src, dst):
            g = self._engine.graph
            merged = Graph(
                g.n, np.concatenate([g.src, src]),
                np.concatenate([g.dst, dst]),
                name=g.name).dedup()
            self._full_rebuild(graph=merged)
        self._pending = True
        self._dirty += int(src.size)
        if resolve:
            self._resolve()

    def remove_edges(self, src: np.ndarray, dst: np.ndarray, *,
                     resolve: bool = True) -> None:
        """Delete follow edges (unfollow tombstones); pairs not present are
        ignored. Backends without an incremental shrink hook re-``prepare``
        from the filtered graph (warm start still carries over)."""
        src = np.asarray(src, np.int32).reshape(-1)
        dst = np.asarray(dst, np.int32).reshape(-1)
        if src.size == 0:
            return
        if not self._engine.unpatch_edges(src, dst):
            g = self._engine.graph
            keep = ~np.isin(g.src.astype(np.int64) * g.n + g.dst,
                            src.astype(np.int64) * g.n + dst)
            self._full_rebuild(graph=Graph(g.n, g.src[keep], g.dst[keep],
                                           name=g.name))
        self._pending = True
        self._dirty += int(src.size)
        if resolve:
            self._resolve()

    @property
    def stale(self) -> bool:
        """True when deferred patches have not been re-solved yet (queries
        then serve the previous fixed point's ranking)."""
        return self._pending

    def resolve(self) -> None:
        """Warm re-solve to the full tolerance if any deferred patch is
        pending, nothing was solved yet, or the last solve stopped early at
        a top-k certificate (query-driven resolution leaves scores only
        err_bound-accurate; ``resolve`` restores the global contract)."""
        if self._pending or self._last is None or self._early:
            self._resolve()

    def top_k_certified(self, k: int):
        """Certified top-k, resolved only as far as the query demands.

        With a pending delta and a backend that exposes ``run_top_k`` (the
        ``push`` engine), the warm re-solve stops at rank separation
        instead of the global tolerance — the certified *set* is exact
        while the edge-work stays proportional to the dirty region and the
        requested k. Other backends (or a fresh state) fall through to the
        cache path, which certifies against the engine's
        :meth:`~repro.core.engine.PsiEngine.psi_error_bound`.
        """
        if ((self._pending or self._last is None)
                and hasattr(self._engine, "run_top_k")):
            self._plan_query(k)
            with obs_trace.span("query", op="top_k_certified",
                                cache="early_stop") as sp:
                prev_s = None if self._last is None else self._last.s
                self._last, cert = self._engine.run_top_k(
                    k, tol=self.tol, max_iter=self.max_iter, s0=prev_s)
                self._cache = RankingCache(
                    self._last.psi, err_bound=self._engine.psi_error_bound())
                self._pending = False
                self._dirty = 0
                self._early = not bool(self._last.converged)
            obs_metrics.histogram(
                "psi_query_seconds", "read-side ψ query latency (seconds)",
                labelnames=("op",)) \
                .labels(op="top_k_certified").observe(sp.duration_s)
            return cert
        return RankedQueries.top_k_certified(self, k)

    # -- internals ------------------------------------------------------ #
    def _plan_query(self, k: int | None) -> None:
        """Record the push-vs-global solver plan for a certified query.

        Advisory: the engine already committed to its backend, so the
        :func:`~repro.kernels.autotune.choose_solver` verdict only lands
        in the decision log (``serve --explain`` shows what the planner
        *would* pick from the measured dirty fraction and k) — pure host
        arithmetic over counts the service already tracks, no device work
        and no behaviour change.
        """
        host = getattr(self._engine, "host", None)
        if host is None or host.n <= 0:
            return
        import types

        from ..kernels.autotune import choose_solver
        k = host.n if k is None else max(int(k), 1)  # full resolve ≡ k=n
        choose_solver(types.SimpleNamespace(n=host.n, m=host.m),
                      dirty_frac=min(1.0, self._dirty / host.n),
                      k_frac=min(1.0, k / host.n))

    def _patched_activity(self, users, lam, mu) -> Activity:
        act = self._engine.activity
        new_lam, new_mu = act.lam.copy(), act.mu.copy()
        if lam is not None:
            new_lam[np.asarray(users)] = lam
        if mu is not None:
            new_mu[np.asarray(users)] = mu
        return Activity(new_lam, new_mu)

    def _full_rebuild(self, graph: Graph | None = None,
                      activity: Activity | None = None) -> None:
        self._engine.prepare(graph or self._engine.graph,
                             activity or self._engine.activity)

    def _resolve(self) -> None:
        self._plan_query(None)                    # log the solver verdict
        prev_s = None if self._last is None else self._last.s
        self._last = self._engine.run(tol=self.tol, max_iter=self.max_iter,
                                      s0=prev_s)
        self._cache = None                        # ranking invalidated
        self._pending = False
        self._dirty = 0
        self._early = False

    def _query(self) -> RankingCache:
        if self._last is None:
            self._last = self._engine.run(tol=self.tol,
                                          max_iter=self.max_iter)
            self._cache = None
        if self._cache is None:
            self._cache = RankingCache(
                self._last.psi,
                err_bound=self._engine.psi_error_bound())
        return self._cache
