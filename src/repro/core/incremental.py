"""Incremental ψ-score service — warm-started recomputation for serving.

The Alg. 2 iteration is an affine contraction (ρ(A) < 1), so after a graph or
activity update the fixed point moves continuously; restarting the power
iteration from the previous s* instead of c needs only
O(log(‖Δs*‖/ε) / log(1/ρ)) iterations — typically a handful for small updates.
This powers ``examples/influence_service.py`` and is also the fault-tolerance
story for the distributed runner: s is the *entire* algorithm state, so a
restart from the last checkpointed s is exact, not approximate.
"""
from __future__ import annotations

import numpy as np

from ..graphs.structure import Graph
from .activity import Activity
from .operators import build_operators
from .power_psi import PsiResult, power_psi

__all__ = ["PsiService"]


class PsiService:
    """Maintains ψ-scores for a mutable (graph, activity) pair."""

    def __init__(self, graph: Graph, activity: Activity, *, tol: float = 1e-8,
                 dtype=None):
        import jax.numpy as jnp
        self._dtype = dtype or jnp.float32
        self.tol = tol
        self._graph = graph
        self._activity = activity
        self._ops = build_operators(graph, activity, dtype=self._dtype)
        self._last: PsiResult | None = None

    # -- queries -------------------------------------------------------- #
    @property
    def graph(self) -> Graph:
        return self._graph

    def scores(self) -> np.ndarray:
        return np.asarray(self._ensure().psi)

    def top_k(self, k: int) -> tuple[np.ndarray, np.ndarray]:
        psi = self.scores()
        idx = np.argsort(-psi)[:k]
        return idx, psi[idx]

    def rank_of(self, users: np.ndarray) -> np.ndarray:
        order = np.argsort(-self.scores(), kind="stable")
        rank = np.empty_like(order)
        rank[order] = np.arange(order.size)
        return rank[np.asarray(users)]

    def last_iterations(self) -> int:
        return int(self._ensure().iterations)

    # -- mutations (each warm-starts from the previous s*) --------------- #
    def update_activity(self, users: np.ndarray, lam: np.ndarray | None = None,
                        mu: np.ndarray | None = None) -> None:
        new_lam = self._activity.lam.copy()
        new_mu = self._activity.mu.copy()
        if lam is not None:
            new_lam[np.asarray(users)] = lam
        if mu is not None:
            new_mu[np.asarray(users)] = mu
        self._activity = Activity(new_lam, new_mu)
        self._rebuild()

    def add_edges(self, src: np.ndarray, dst: np.ndarray) -> None:
        g = self._graph
        self._graph = Graph(
            g.n, np.concatenate([g.src, np.asarray(src, np.int32)]),
            np.concatenate([g.dst, np.asarray(dst, np.int32)]),
            name=g.name).dedup()
        self._rebuild()

    # -- internals ------------------------------------------------------ #
    def _rebuild(self) -> None:
        self._ops = build_operators(self._graph, self._activity,
                                    dtype=self._dtype)
        prev_s = None if self._last is None else self._last.s
        self._last = power_psi(self._ops, tol=self.tol, s0=prev_s)

    def _ensure(self) -> PsiResult:
        if self._last is None:
            self._last = power_psi(self._ops, tol=self.tol)
        return self._last
