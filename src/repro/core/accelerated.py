"""Beyond-paper: extrapolation-accelerated Power-ψ.

The paper's §Related-Works flags Chebyshev/Push-style acceleration as future
work. True Chebyshev needs a real spectrum (directed A has complex
eigenvalues), so we use the safe variant for affine contractions: Aitken /
geometric-series extrapolation on the iterate sequence.

For s_{t+1} = s_t A + c the error e_t = s_t − s* satisfies e_{t+1} = e_t A.
Once the iteration enters its dominant-eigenvalue regime, successive
differences Δ_t = s_{t+1} − s_t shrink by ρ per step with a stable direction,
so the remaining tail sums to Δ_t·ρ/(1−ρ):

    s* ≈ s_{t+1} + Δ_{t+1} · r/(1 − r),   r = ‖Δ_{t+1}‖₁/‖Δ_t‖₁

The loop itself now lives in :func:`repro.core.engine._make_accelerated_loop`
— an engine-level composition that wraps *any* backend's jitted step, so the
``accelerated`` registered backend and the ``accelerate=True`` opt-in of the
``pallas``/``auto`` engines share one implementation (and the whole thing
stays a single on-device ``lax.while_loop``: no host sync per jump). Every
jump is verified with a plain iteration whose gap drives termination, so the
Eq. 19 guarantee holds; a non-improving jump is reverted and disables future
jumps (degrades to plain Power-ψ), and a stalled ratio triggers the
Krasnoselskii averaging kick. See the loop builder's docstring for details.

Measured on the DBLP stand-in (float64, benchmarks/exp2): heterogeneous
45 → ~34 mat-vecs (−24..27%), homogeneous 165 → 85..120 (−27..48%) at
ε = 1e-9, answers identical to ~1e-15. Precision note: near a dtype's
fixed-point floor a jump can land in a basin whose *plain* fp32 iteration
limit-cycles at ‖Δs‖ ≈ 1e-6; request tolerances ≥ ~100·ulp for fp32, or use
float64 as the paper's ε = 1e-9 sweeps do.

This module keeps the historical functional entry point; prefer
``make_engine("accelerated", graph=..., activity=...)`` in new code.
"""
from __future__ import annotations

import jax.numpy as jnp

from .operators import PsiOperators
from .power_psi import PsiResult

__all__ = ["power_psi_accelerated"]


def power_psi_accelerated(ops: PsiOperators, *, tol: float = 1e-9,
                          max_iter: int = 10_000,
                          extrapolate_every: int = 8,
                          use_b_norm: bool = True) -> PsiResult:
    """Alg. 2 with periodic Aitken extrapolation (beyond-paper).

    ``iterations`` / ``matvecs`` count mat-vecs actually consumed (each
    verification step included) — the honest currency for an extrapolated
    loop.
    """
    from .engine import _make_accelerated_loop

    def one_step(a, s):
        s_new = a.mu * a.push(s) + a.c
        return s_new, jnp.sum(jnp.abs(s_new - s))

    loop = _make_accelerated_loop(one_step,
                                  extrapolate_every=extrapolate_every)
    scale = ops.b_norm if use_b_norm else jnp.asarray(1.0, ops.dtype)
    s, gap, t = loop(ops, ops.c, scale,
                     jnp.asarray(tol, ops.dtype),
                     jnp.asarray(max_iter, jnp.int32))
    return PsiResult(psi=ops.psi_epilogue(s), s=s, iterations=t, gap=gap,
                     converged=gap <= tol, matvecs=t + 1)
