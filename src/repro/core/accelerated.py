"""Beyond-paper: extrapolation-accelerated Power-ψ.

The paper's §Related-Works flags Chebyshev/Push-style acceleration as future
work. True Chebyshev needs a real spectrum (directed A has complex
eigenvalues), so we use the safe variant for affine contractions: Aitken /
geometric-series extrapolation on the iterate sequence.

For s_{t+1} = s_t A + c the error e_t = s_t − s* satisfies e_{t+1} = e_t A.
Once the iteration enters its dominant-eigenvalue regime, successive
differences Δ_t = s_{t+1} − s_t shrink by ρ per step with a stable direction,
so the remaining tail sums to Δ_t·ρ/(1−ρ):

    s* ≈ s_{t+1} + Δ_{t+1} · r/(1 − r),   r = ‖Δ_{t+1}‖₁/‖Δ_t‖₁

Every ``extrapolate_every`` iterations we take this jump, then *verify* it
with one plain iteration (the gap after a jump is computed against the
re-iterated point, so the Eq. 19 termination guarantee still holds — the
jump can only overshoot transiently, never terminate early spuriously).
Worst case (oscillating ratios, complex spectrum) the jump is rejected by
the monotonicity guard and the method degrades to plain Power-ψ.

Measured on the DBLP stand-in (float64, benchmarks/exp2): heterogeneous
45 → 33 mat-vecs (−27%), homogeneous 165 → 85..120 (−27..48%) at ε = 1e-9,
answers identical to ~1e-15. Precision note: near a dtype's fixed-point
floor a jump can land in a basin whose *plain* fp32 iteration limit-cycles
at ‖Δs‖ ≈ 1e-6; request tolerances ≥ ~100·ulp for fp32, or use float64 as
the paper's ε = 1e-9 sweeps do.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .operators import PsiOperators
from .power_psi import PsiResult, make_power_psi_step

__all__ = ["power_psi_accelerated"]


def power_psi_accelerated(ops: PsiOperators, *, tol: float = 1e-9,
                          max_iter: int = 10_000,
                          extrapolate_every: int = 8,
                          use_b_norm: bool = True) -> PsiResult:
    """Alg. 2 with periodic Aitken extrapolation (beyond-paper)."""
    step = make_power_psi_step(ops)
    scale = ops.b_norm if use_b_norm else jnp.asarray(1.0, ops.dtype)
    k = extrapolate_every

    @jax.jit
    def run(s0):
        def cond(state):
            _, _, gap, t, _ = state
            return (gap > tol) & (t < max_iter)

        def body(state):
            s, prev_delta_norm, _, t, enabled = state
            s1 = step(s)
            delta = s1 - s
            dn = jnp.sum(jnp.abs(delta))
            gap_plain = scale * dn
            r = dn / jnp.maximum(prev_delta_norm, 1e-30)
            # jump only in the contraction regime AND while still far from
            # tolerance — near the floating-point fixed point the jump's
            # perturbation would keep the verification gap from reaching 0
            far = gap_plain > 100.0 * tol
            do_jump = (jnp.asarray(t % k == k - 1)) & (r < 0.999) & \
                (r > 0) & far & enabled
            jump = jnp.where(do_jump, r / (1.0 - r), 0.0)
            s_x = s1 + delta * jump
            # verification iteration after a jump keeps Eq. 19 semantics
            s_ver = step(s_x)
            gap_jump = scale * jnp.sum(jnp.abs(s_ver - s_x))
            # monotonic safeguard: a jump that does not reduce the gap is
            # reverted and disables all future jumps (degrades to plain
            # Power-ψ with at most one wasted mat-vec) — handles complex
            # spectra and the floating-point fixed-point floor
            bad = do_jump & (gap_jump >= gap_plain)
            take_jump = do_jump & ~bad
            s2 = jnp.where(take_jump, s_ver, s1)
            gap = jnp.where(take_jump, gap_jump, gap_plain)
            enabled = enabled & ~bad
            # Krasnoselskii kick: a non-shrinking plain step (r ≈ 1) means a
            # floating-point period-2 cycle — averaging the pair kills the
            # oscillating component and is always safe for a contraction
            stall = (~do_jump) & (r > 0.999) & jnp.isfinite(r)
            s2 = jnp.where(stall, 0.5 * (s + s1), s2)
            t_next = t + 1 + do_jump.astype(jnp.int32)
            return s2, dn, gap, t_next, enabled

        s, _, gap, t, _ = jax.lax.while_loop(
            cond, body,
            (s0, jnp.asarray(jnp.inf, ops.dtype),
             jnp.asarray(jnp.inf, ops.dtype), jnp.asarray(0, jnp.int32),
             jnp.asarray(True)))
        return ops.psi_epilogue(s), s, gap, t

    psi, s, gap, t = run(ops.c)
    return PsiResult(psi=psi, s=s, iterations=t, gap=gap,
                     converged=gap <= tol, matvecs=t + 1)
