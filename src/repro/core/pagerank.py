"""PageRank power method (Eq. 22) — the speed yardstick of the paper.

πᵀ_t = α πᵀ_{t−1} W + (1−α)/N 1ᵀ with W = D_out⁻¹ L (row-normalized
follower→leader adjacency; rows of dangling users are zero, making W
sub-stochastic — exactly the structure ψ's A has in the homogeneous case,
so ψ(λ=const, μ=const) == PageRank(α = μ/(λ+μ)) holds verbatim [10, Thm 5].
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..graphs.structure import Graph

__all__ = ["PageRankResult", "PageRankOps", "build_pagerank_ops", "pagerank"]


@dataclasses.dataclass(frozen=True)
class PageRankOps:
    n: int
    src_by_dst: jax.Array
    dst_by_dst: jax.Array
    inv_outdeg: jax.Array   # 1/outdeg, 0 for dangling


jax.tree_util.register_dataclass(
    PageRankOps, data_fields=["src_by_dst", "dst_by_dst", "inv_outdeg"],
    meta_fields=["n"])


@dataclasses.dataclass(frozen=True)
class PageRankResult:
    pi: jax.Array
    iterations: jax.Array
    gap: jax.Array
    converged: jax.Array
    matvecs: jax.Array


def build_pagerank_ops(graph: Graph, *, dtype=jnp.float32) -> PageRankOps:
    np_dtype = np.dtype(jnp.dtype(dtype).name)
    outdeg = graph.out_degree.astype(np_dtype)
    inv = np.where(outdeg > 0, 1.0 / np.where(outdeg > 0, outdeg, 1), 0.0)
    s_d, d_d = graph.edges_by_dst
    return PageRankOps(n=graph.n, src_by_dst=jnp.asarray(s_d),
                       dst_by_dst=jnp.asarray(d_d),
                       inv_outdeg=jnp.asarray(inv.astype(np_dtype)))


def pagerank(ops: PageRankOps, *, alpha: float = 0.85, tol: float = 1e-9,
             max_iter: int = 10_000, pi0: jax.Array | None = None
             ) -> PageRankResult:
    dtype = ops.inv_outdeg.dtype
    teleport = jnp.asarray((1.0 - alpha) / ops.n, dtype)
    a = jnp.asarray(alpha, dtype)

    def step(pi):
        contrib = (pi * ops.inv_outdeg)[ops.src_by_dst]
        agg = jax.ops.segment_sum(contrib, ops.dst_by_dst, ops.n,
                                  indices_are_sorted=True)
        return a * agg + teleport

    @jax.jit
    def run(pi_init):
        def cond(state):
            _, gap, t = state
            return (gap > tol) & (t < max_iter)

        def body(state):
            pi, _, t = state
            pi_new = step(pi)
            return pi_new, jnp.sum(jnp.abs(pi_new - pi)), t + 1

        return jax.lax.while_loop(
            cond, body, (pi_init, jnp.asarray(jnp.inf, dtype),
                         jnp.asarray(0, jnp.int32)))

    init = (jnp.full((ops.n,), 1.0 / ops.n, dtype)
            if pi0 is None else jnp.asarray(pi0, dtype))
    pi, gap, t = run(init)
    return PageRankResult(pi=pi, iterations=t, gap=gap,
                          converged=gap <= tol, matvecs=t)
