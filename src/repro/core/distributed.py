"""Distributed Power-ψ: shard_map over the production mesh (DESIGN.md §4).

One iteration on the (pod ×) data × model mesh:

  1. local push       — gather s·(1/w) by local src ids, sorted segment-sum
                        onto the local dst block                 [compute]
  2. psum_scatter     — reduce partials over the src axis; the scattered
                        slice IS piece (r, c) of the block-cyclic src layout
                        (zero on-device reshuffling)            [collective]
  3. epilogue         — s'_piece = μ_piece ⊙ t_piece + c_piece   [compute]
  4. all_gather       — over the model axis: row r reassembles its full
                        block-cyclic shard of s'                [collective]
  5. gap              — local L1 of Δs, psum over the src axis   [scalar]

Per-device comm per iteration: Nc floats reduced + N/d gathered — the
bandwidth-optimal 2-D SpMV schedule. The multi-pod mesh folds "pod" into the
src axis, so step 2's reduction is hierarchical (intra-pod ICI first,
inter-pod DCI second) under XLA's multi-axis psum.

Fault tolerance: s is the *entire* algorithm state (a few MB), checkpointed
every ``ckpt_every`` outer chunks by the driver in ``runtime/``; restart
warm-starts the contraction exactly (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..compat import shard_map

from ..graphs.partition import Partition2D, partition_2d
from ..graphs.structure import Graph
from .activity import Activity

__all__ = ["DistributedPsi", "DistPsiArrays", "PartialReduction",
           "BlockOverflowError"]


class BlockOverflowError(RuntimeError):
    """An edge insert does not fit a partition block's ``e_max`` capacity.

    Carries which (row, col) block overflowed and the capacity the insert
    would need, so callers can regrow the partition deliberately instead of
    guessing from a silent failure.
    """

    def __init__(self, block: tuple[int, int], e_max: int, required: int):
        self.block = block
        self.e_max = e_max
        self.required = required
        super().__init__(
            f"distributed edge block (row={block[0]}, col={block[1]}) "
            f"overflows e_max={e_max}: the insert requires capacity "
            f">= {required}; regrow the partition (re-prepare) or construct "
            f"the engine with on_overflow='regrow'")


@dataclasses.dataclass(frozen=True)
class PartialReduction:
    """Explicit handle between the dispatch and finalize halves of one
    sharded iteration: the un-psummed per-device dst partials plus the
    iterate they were pushed from (the finalize half needs it for the gap).

    Produced by :meth:`DistributedPsi.make_dispatch`, consumed by
    :meth:`DistributedPsi.make_finalize`; composing the two is bit-identical
    to the fused :meth:`DistributedPsi.make_step` program. The split exists
    so an overlapped executor can issue the next dispatch (pure local
    compute) while a previous finalize (the collective half) is still in
    flight.
    """

    partial_t: jax.Array   # f[d, mo, nc] — pre-reduction dst partials
    s_in: jax.Array        # f[d, local]  — src-layout iterate the push read


jax.tree_util.register_dataclass(
    PartialReduction, data_fields=["partial_t", "s_in"], meta_fields=[])


@dataclasses.dataclass(frozen=True)
class DistPsiArrays:
    """Device arrays for the sharded iteration (a pytree)."""
    src_local: jax.Array   # i32[d, mo, e_max]
    dst_local: jax.Array   # i32[d, mo, e_max]
    inv_w_src: jax.Array   # f[d, mo·q]   block-cyclic src layout
    mu_piece: jax.Array    # f[d, mo, q]
    c_piece: jax.Array     # f[d, mo, q]
    c_src: jax.Array       # f[d, mo·q]   s₀ in src layout
    lam_piece: jax.Array   # f[d, mo, q]  for the ψ epilogue
    d_piece: jax.Array     # f[d, mo, q]


jax.tree_util.register_dataclass(
    DistPsiArrays,
    data_fields=["src_local", "dst_local", "inv_w_src", "mu_piece",
                 "c_piece", "c_src", "lam_piece", "d_piece"],
    meta_fields=[])


class DistributedPsi:
    """Power-ψ sharded over a ("data","model") or ("pod","data","model") mesh."""

    def __init__(self, part: Partition2D, mesh: Mesh, *, dtype=jnp.float32,
                 arrays: DistPsiArrays | None = None):
        self.part = part
        self.mesh = mesh
        self.dtype = dtype
        axes = mesh.axis_names
        if axes[-2:] != ("data", "model"):
            raise ValueError(f"mesh must end in (data, model); got {axes}")
        self.src_axes = axes[:-1]        # ("data",) or ("pod","data")
        d_mesh = int(np.prod([mesh.shape[a] for a in self.src_axes]))
        if d_mesh != part.d or mesh.shape["model"] != part.mo:
            raise ValueError("partition grid does not match mesh shape")
        self.arrays = arrays

    # ------------------------------------------------------------------ #
    @classmethod
    def from_graph(cls, graph: Graph, activity: Activity, mesh: Mesh, *,
                   dtype=jnp.float32) -> "DistributedPsi":
        axes = mesh.axis_names
        d = int(np.prod([mesh.shape[a] for a in axes[:-1]]))
        part = partition_2d(graph, d, mesh.shape["model"])
        self = cls(part, mesh, dtype=dtype)
        self.arrays = self.build_arrays(graph, activity)
        return self

    def build_arrays(self, graph: Graph, activity: Activity) -> DistPsiArrays:
        """Host-side operator build in partitioned layouts → device."""
        p = self.part
        np_dtype = np.dtype(jnp.dtype(self.dtype).name)
        lam = activity.lam.astype(np_dtype)
        mu = activity.mu.astype(np_dtype)
        total = lam + mu
        w = np.zeros(graph.n, np_dtype)
        np.add.at(w, graph.src, total[graph.dst])
        inv_w = np.where(w > 0, 1.0 / np.where(w > 0, w, 1), 0).astype(np_dtype)
        with np.errstate(divide="ignore", invalid="ignore"):
            c = np.where(total > 0, mu / total, 0.0).astype(np_dtype)
            dd = np.where(total > 0, lam / total, 0.0).astype(np_dtype)

        put = partial(self._put)
        return DistPsiArrays(
            src_local=put(p.src_local, P(self.src_axes, "model")),
            dst_local=put(p.dst_local, P(self.src_axes, "model")),
            inv_w_src=put(p.to_src_layout(inv_w), P(self.src_axes)),
            mu_piece=put(p.to_piece_layout(mu), P(self.src_axes, "model")),
            c_piece=put(p.to_piece_layout(c), P(self.src_axes, "model")),
            c_src=put(p.to_src_layout(c), P(self.src_axes)),
            lam_piece=put(p.to_piece_layout(lam), P(self.src_axes, "model")),
            d_piece=put(p.to_piece_layout(dd), P(self.src_axes, "model")),
        )

    def _put(self, host: np.ndarray, spec: P) -> jax.Array:
        # leading host dim(s) split over the named axes; trailing dims local
        full_spec = P(*spec, *([None] * (host.ndim - len(spec))))
        return jax.device_put(
            host, NamedSharding(self.mesh, full_spec))

    # ------------------------------------------------------------------ #
    def input_specs(self):
        """ShapeDtypeStructs for the dry-run (no allocation)."""
        p = self.part
        e = p.e_max
        sd = jax.ShapeDtypeStruct
        i32, f = jnp.int32, self.dtype
        return dict(
            src_local=sd((p.d, p.mo, e), i32),
            dst_local=sd((p.d, p.mo, e), i32),
            inv_w_src=sd((p.d, p.mo * p.q), f),
            mu_piece=sd((p.d, p.mo, p.q), f),
            c_piece=sd((p.d, p.mo, p.q), f),
            c_src=sd((p.d, p.mo * p.q), f),
            lam_piece=sd((p.d, p.mo, p.q), f),
            d_piece=sd((p.d, p.mo, p.q), f),
        )

    def shardings(self):
        src_axes = self.src_axes
        row = NamedSharding(self.mesh, P(src_axes, None))
        grid = NamedSharding(self.mesh, P(src_axes, "model", None))
        return dict(src_local=grid, dst_local=grid, inv_w_src=row,
                    mu_piece=grid, c_piece=grid, c_src=row,
                    lam_piece=grid, d_piece=grid)

    # ------------------------------------------------------------------ #
    def _arr_specs(self) -> DistPsiArrays:
        """Partition specs of the array pytree inside every shard_map."""
        src_axes = self.src_axes
        grid = P(src_axes, "model", None)
        row = P(src_axes, None)
        return DistPsiArrays(
            src_local=grid, dst_local=grid, inv_w_src=row, mu_piece=grid,
            c_piece=grid, c_src=row, lam_piece=grid, d_piece=grid)

    @staticmethod
    def _local_push(s, a: DistPsiArrays, nc: int) -> jax.Array:
        """Dispatch half's local math (inside shard_map, shapes [1, ...]):
        gather s·(1/w) by local src ids, sorted segment-sum onto the local
        dst block. Pure compute — no collectives."""
        s_loc = s[0]
        src_ids = a.src_local[0, 0]
        dst_ids = a.dst_local[0, 0]
        s_pre = jnp.concatenate(
            [s_loc * a.inv_w_src[0], jnp.zeros((1,), s.dtype)])
        return jax.ops.segment_sum(
            s_pre[src_ids], dst_ids, nc + 1, indices_are_sorted=True)[:nc]

    @staticmethod
    def _local_finish(partial_t, s, a: DistPsiArrays, src_axes):
        """Finalize half's local math: psum_scatter the partials (the
        scattered slice IS piece (r, c)), μ/c epilogue, all_gather over the
        model axis, psummed l1 gap against the input iterate."""
        t_piece = jax.lax.psum_scatter(
            partial_t, src_axes, scatter_dimension=0, tiled=True)
        s_new_piece = a.mu_piece[0, 0] * t_piece + a.c_piece[0, 0]
        s_new = jax.lax.all_gather(
            s_new_piece, "model", axis=0, tiled=True)[None]
        gap_local = jnp.sum(jnp.abs(s_new - s))
        gap = jax.lax.psum(gap_local, src_axes)
        return s_new, gap

    def make_step(self):
        """shard_map'd single iteration: (s_src, arrays) → (s'_src, gap).

        The fused composition of :meth:`make_dispatch` and
        :meth:`make_finalize` in one program (XLA overlaps the next tile's
        gather with the previous collective where it can); the split halves
        below expose the same math with an explicit
        :class:`PartialReduction` boundary for overlapped executors.
        """
        src_axes = self.src_axes
        nc = self.part.nc

        def local_step(s, a: DistPsiArrays):
            partial_t = self._local_push(s, a, nc)
            return self._local_finish(partial_t, s, a, src_axes)

        return shard_map(
            local_step, mesh=self.mesh,
            in_specs=(P(src_axes, None), self._arr_specs()),
            out_specs=(P(src_axes, None), P()))

    def make_dispatch(self):
        """Compute-only half: (s_src, arrays) → :class:`PartialReduction`.

        No collectives are issued — the returned handle carries the
        un-psummed per-device dst partials (and the iterate, for the
        finalize gap), so a scheduler can dispatch the *next* chunk's local
        push before this handle's reduction has drained.
        """
        src_axes = self.src_axes
        nc = self.part.nc

        def local_dispatch(s, a: DistPsiArrays):
            partial_t = self._local_push(s, a, nc)
            return PartialReduction(partial_t=partial_t[None, None], s_in=s)

        return shard_map(
            local_dispatch, mesh=self.mesh,
            in_specs=(P(src_axes, None), self._arr_specs()),
            out_specs=PartialReduction(
                partial_t=P(src_axes, "model", None),
                s_in=P(src_axes, None)))

    def make_finalize(self):
        """Collective half: (:class:`PartialReduction`, arrays) →
        (s'_src, gap). psum_scatter + epilogue + all_gather + gap psum —
        exactly the tail of :meth:`make_step`."""
        src_axes = self.src_axes

        def local_finalize(h: PartialReduction, a: DistPsiArrays):
            return self._local_finish(h.partial_t[0, 0], h.s_in, a, src_axes)

        return shard_map(
            local_finalize, mesh=self.mesh,
            in_specs=(PartialReduction(
                partial_t=P(src_axes, "model", None),
                s_in=P(src_axes, None)), self._arr_specs()),
            out_specs=(P(src_axes, None), P()))

    def make_epilogue(self):
        """ψ from converged s: one more push, then (λ⊙t + d)/N, dst layout."""
        src_axes = self.src_axes
        nc, n = self.part.nc, self.part.n

        def local_epilogue(s, a: DistPsiArrays):
            partial_t = self._local_push(s, a, nc)
            t_piece = jax.lax.psum_scatter(
                partial_t, src_axes, scatter_dimension=0, tiled=True)
            psi_piece = (a.lam_piece[0, 0] * t_piece + a.d_piece[0, 0]) / n
            return psi_piece[None, None]

        return shard_map(
            local_epilogue, mesh=self.mesh,
            in_specs=(P(src_axes, None), self._arr_specs()),
            out_specs=P(src_axes, "model", None))

    # ------------------------------------------------------------------ #
    def make_run(self, *, chunk_iters: int = 8, unroll: bool = False):
        """(s, arrays) → (s', gap): ``chunk_iters`` fused steps + final gap.

        The driver loops chunks until gap ≤ tol, checkpointing s between
        chunks (runtime/psi_driver.py); keeping the while on the host makes
        the device program a fixed-shape scan — required for the dry-run and
        friendlier to multi-pod SPMD.
        """
        step = self.make_step()

        @jax.jit
        def run(s, arrays):
            def body(carry, _):
                s, _ = carry
                s_new, gap = step(s, arrays)
                return (s_new, gap), None

            (s_fin, gap), _ = jax.lax.scan(
                body, (s, jnp.asarray(jnp.inf, s.dtype)), None,
                length=chunk_iters, unroll=chunk_iters if unroll else 1)
            return s_fin, gap

        return run

    def run_to_convergence(self, *, tol: float = 1e-9, max_iter: int = 2000,
                           chunk_iters: int = 16, b_norm: float | None = None):
        """Host-driven convergence loop. Returns (psi [n], iters, gap)."""
        if self.arrays is None:
            raise ValueError("no device arrays; use from_graph()")
        run = self.make_run(chunk_iters=chunk_iters)
        epi = jax.jit(self.make_epilogue())
        s = self.arrays.c_src
        scale = 1.0 if b_norm is None else b_norm
        it = 0
        gap = np.inf
        while it < max_iter:
            s, gap_dev = run(s, self.arrays)
            it += chunk_iters
            gap = float(gap_dev) * scale
            if gap <= tol:
                break
        psi_piece = epi(s, self.arrays)          # [d, mo, q] dst-piece layout
        psi = self.part.from_src_layout(
            np.asarray(psi_piece).reshape(self.part.d, -1))
        return psi, it, gap


class DistributedPsi1D:
    """Paper-faithful distributed baseline (§III: 'can even be calculated
    distributedly'): edges sharded across all devices, s **replicated**,
    one full-vector psum per iteration.

    This is the natural 1-D reading of the paper's distribution remark.
    EXPERIMENTS.md §Perf compares it against the 2-D block-cyclic schedule
    (DistributedPsi): the 1-D psum moves ~2·N·4 B per device per iteration
    versus the 2-D scheme's Nc·4 (reduce-scatter) + N/d·4 (all-gather) —
    a ~2·min(d, mo)× collective reduction at equal math.
    """

    def __init__(self, graph: Graph, activity: Activity, mesh: Mesh, *,
                 dtype=jnp.float32, spec_only: bool = False,
                 n: int | None = None, m: int | None = None):
        self.mesh = mesh
        self.dtype = dtype
        self.axes = tuple(mesh.axis_names)
        self.n_dev = int(np.prod([mesh.shape[a] for a in self.axes]))
        if spec_only:
            self.n = n
            self.n_pad = -(-n // 128) * 128
            self.e_max = -(-int(np.ceil(m / self.n_dev * 1.3)) // 128) * 128
            self.arrays = None
            return
        self.n = graph.n
        self.n_pad = -(-graph.n // 128) * 128
        np_dtype = np.dtype(jnp.dtype(dtype).name)
        act_l = activity.lam.astype(np_dtype)
        act_m = activity.mu.astype(np_dtype)
        total = act_l + act_m
        w = np.zeros(graph.n, np_dtype)
        np.add.at(w, graph.src, total[graph.dst])
        inv_w = np.where(w > 0, 1.0 / np.where(w > 0, w, 1), 0)
        pad = lambda v: np.concatenate(
            [v.astype(np_dtype), np.zeros(self.n_pad - graph.n, np_dtype)])
        with np.errstate(divide="ignore", invalid="ignore"):
            c = np.where(total > 0, act_m / total, 0.0)
        # edges round-robin over devices, dst-sorted within each shard
        src, dst = graph.edges_by_dst
        per = -(-graph.m // self.n_dev)
        self.e_max = -(-per // 128) * 128
        es = np.full((self.n_dev, self.e_max), self.n_pad, np.int32)
        ed = np.full((self.n_dev, self.e_max), self.n_pad, np.int32)
        for i in range(self.n_dev):
            sl = slice(i * per, min((i + 1) * per, graph.m))
            k = sl.stop - sl.start
            es[i, :k] = src[sl]
            ed[i, :k] = dst[sl]
        flat = P(self.axes)
        self.arrays = dict(
            src=jax.device_put(es.reshape(self.n_dev, self.e_max),
                               NamedSharding(mesh, P(self.axes, None))),
            dst=jax.device_put(ed.reshape(self.n_dev, self.e_max),
                               NamedSharding(mesh, P(self.axes, None))),
            inv_w=jax.device_put(pad(inv_w), NamedSharding(mesh, P())),
            mu=jax.device_put(pad(act_m), NamedSharding(mesh, P())),
            c=jax.device_put(pad(c), NamedSharding(mesh, P())))

    def make_step(self):
        n_pad = self.n_pad
        axes = self.axes

        def local_step(s, src, dst, inv_w, mu, c):
            s_pre = jnp.concatenate(
                [s * inv_w, jnp.zeros((1,), s.dtype)])
            partial = jax.ops.segment_sum(
                s_pre[src[0]], dst[0], n_pad + 1,
                indices_are_sorted=True)[:n_pad]
            t = jax.lax.psum(partial, axes)            # full-vector AR
            return mu * t + c
        # NOTE: the convergence gap is computed by the caller from
        # (s_new, s_old) — returning a replicated scalar second output from
        # this shard_map deadlocks the XLA CPU in-process communicator
        # (runtime quirk; compile is fine either way).

        return shard_map(
            local_step, mesh=self.mesh,
            in_specs=(P(), P(self.axes, None), P(self.axes, None),
                      P(), P(), P()),
            out_specs=P())

    def input_specs(self):
        sd = jax.ShapeDtypeStruct
        return dict(
            s=sd((self.n_pad,), self.dtype),
            src=sd((self.n_dev, self.e_max), jnp.int32),
            dst=sd((self.n_dev, self.e_max), jnp.int32),
            inv_w=sd((self.n_pad,), self.dtype),
            mu=sd((self.n_pad,), self.dtype),
            c=sd((self.n_pad,), self.dtype))

    def shardings(self):
        e = NamedSharding(self.mesh, P(self.axes, None))
        r = NamedSharding(self.mesh, P())
        return dict(s=r, src=e, dst=e, inv_w=r, mu=r, c=r)
