"""User activity models: posting rate λ and re-posting rate μ per user.

The paper evaluates two regimes (§V):
  (i)  heterogeneous — λ, μ i.i.d. uniform in (0, 1);
  (ii) homogeneous   — λ = 0.15, μ = 0.85 for everyone, in which case
       ψ == PageRank with damping α = μ/(λ+μ) = 0.85 ([10, Thm 5]).

The paper's model assumes λ^(n), μ^(n) > 0; this container is deliberately
one notch laxer and only *rejects negative* rates: a "silent" user with
λ = μ = 0 is representable (the operators mask the degenerate
c = μ/(λ+μ), d = λ/(λ+μ) normalization to 0 — see
``HostOperators.cd`` — which is also how the fleet's padded lanes stay
inert). Paths that need the paper's strict positivity — notably the
streaming estimator's cold-start users, where λ+μ = 0 would zero a user's
c/d row and silently pin ψ contributions — clamp through
:meth:`Activity.floored` with the shared :data:`RATE_FLOOR`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Activity", "heterogeneous", "homogeneous", "RATE_FLOOR"]

#: Strictly-positive clamp for rates that must not be zero (cold-start
#: users in the streaming estimator, explicit `Activity.floored()` calls).
#: Matches the lower bound of `heterogeneous`'s default (low, high) range,
#: so a floored cold-start user is indistinguishable from the paper's
#: least-active heterogeneous user.
RATE_FLOOR = 1e-3


@dataclasses.dataclass(frozen=True)
class Activity:
    lam: np.ndarray  # posting frequency λ^(n) ≥ 0 (paper assumes > 0)
    mu: np.ndarray   # re-posting frequency μ^(n) ≥ 0 (paper assumes > 0)

    def __post_init__(self):
        if self.lam.shape != self.mu.shape:
            raise ValueError("λ/μ shape mismatch")
        if not (np.all(np.isfinite(self.lam))
                and np.all(np.isfinite(self.mu))):
            raise ValueError("activity rates must be finite")
        if np.any(self.lam < 0) or np.any(self.mu < 0):
            raise ValueError("activity rates must be non-negative")

    @property
    def n(self) -> int:
        return int(self.lam.shape[0])

    @property
    def total(self) -> np.ndarray:
        return self.lam + self.mu

    def astype(self, dtype) -> "Activity":
        return Activity(self.lam.astype(dtype), self.mu.astype(dtype))

    def floored(self, floor: float = RATE_FLOOR) -> "Activity":
        """A strictly-positive copy: both rates clamped to ≥ ``floor``.

        Guarantees λ+μ ≥ 2·floor for every user, so the ψ iteration's
        c = μ/(λ+μ) normalization is non-degenerate everywhere — the
        paper's λ, μ > 0 assumption restored by an explicit clamp. The
        streaming estimator applies the same floor to cold-start users.
        """
        if floor <= 0:
            raise ValueError(f"floor must be > 0; got {floor}")
        return Activity(np.maximum(self.lam, floor),
                        np.maximum(self.mu, floor))


def heterogeneous(n: int, *, seed: int = 0, low: float = 1e-3,
                  high: float = 1.0) -> Activity:
    """i.i.d. uniform rates in (low, high) — regime (i) of the paper."""
    rng = np.random.default_rng(seed)
    return Activity(rng.uniform(low, high, n), rng.uniform(low, high, n))


def homogeneous(n: int, *, lam: float = 0.15, mu: float = 0.85) -> Activity:
    """Uniform rates — regime (ii); ψ reduces to PageRank(α=μ/(λ+μ))."""
    return Activity(np.full(n, lam), np.full(n, mu))
