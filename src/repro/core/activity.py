"""User activity models: posting rate λ and re-posting rate μ per user.

The paper evaluates two regimes (§V):
  (i)  heterogeneous — λ, μ i.i.d. uniform in (0, 1);
  (ii) homogeneous   — λ = 0.15, μ = 0.85 for everyone, in which case
       ψ == PageRank with damping α = μ/(λ+μ) = 0.85 ([10, Thm 5]).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["Activity", "heterogeneous", "homogeneous"]


@dataclasses.dataclass(frozen=True)
class Activity:
    lam: np.ndarray  # posting frequency λ^(n) > 0
    mu: np.ndarray   # re-posting frequency μ^(n) > 0

    def __post_init__(self):
        if self.lam.shape != self.mu.shape:
            raise ValueError("λ/μ shape mismatch")
        if np.any(self.lam < 0) or np.any(self.mu < 0):
            raise ValueError("activity rates must be non-negative")

    @property
    def n(self) -> int:
        return int(self.lam.shape[0])

    @property
    def total(self) -> np.ndarray:
        return self.lam + self.mu

    def astype(self, dtype) -> "Activity":
        return Activity(self.lam.astype(dtype), self.mu.astype(dtype))


def heterogeneous(n: int, *, seed: int = 0, low: float = 1e-3,
                  high: float = 1.0) -> Activity:
    """i.i.d. uniform rates in (low, high) — regime (i) of the paper."""
    rng = np.random.default_rng(seed)
    return Activity(rng.uniform(low, high, n), rng.uniform(low, high, n))


def homogeneous(n: int, *, lam: float = 0.15, mu: float = 0.85) -> Activity:
    """Uniform rates — regime (ii); ψ reduces to PageRank(α=μ/(λ+μ))."""
    return Activity(np.full(n, lam), np.full(n, mu))
