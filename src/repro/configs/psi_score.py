"""The paper's own 'architecture': distributed Power-psi iteration configs."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PsiConfig:
    name: str
    dataset: str = "twitter"       # graphs.datasets key or rmat<scale>
    tol: float = 1e-9
    chunk_iters: int = 16
    dtype: str = "float32"


def config(reduced: bool = False) -> PsiConfig:
    if reduced:
        return PsiConfig(name="psi-reduced", dataset="tiny", chunk_iters=4)
    return PsiConfig(name="psi-score", dataset="twitter")
