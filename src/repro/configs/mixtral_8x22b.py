"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, SWA W=4096.
"""
from repro.models.transformer import LMConfig, MoECfg


def config(reduced: bool = False) -> LMConfig:
    if reduced:
        import jax.numpy as jnp
        return LMConfig(name="mixtral-8x22b-reduced", n_layers=2, d_model=64,
                        n_heads=8, n_kv_heads=2, d_ff=128, vocab=256,
                        moe=MoECfg(4, 2), sliding_window=64,
                        dtype=jnp.float32, param_dtype=jnp.float32)
    return LMConfig(name="mixtral-8x22b", n_layers=56, d_model=6144,
                    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768,
                    moe=MoECfg(8, 2), sliding_window=4096,
                    optimizer="adafactor", accum_steps=8)
