"""TinyLlama-1.1B — llama2-arch small [arXiv:2401.02385; hf].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000, SwiGLU, RoPE.
"""
from repro.models.transformer import LMConfig


def config(reduced: bool = False) -> LMConfig:
    if reduced:
        import jax.numpy as jnp
        return LMConfig(name="tinyllama-1.1b-reduced", n_layers=2,
                        d_model=64, n_heads=8, n_kv_heads=2, d_ff=176,
                        vocab=256, dtype=jnp.float32, param_dtype=jnp.float32)
    # fsdp off: 1.1B params + AdamW state fit per TP shard (~1 GB) — pure
    # TP+DP avoids the per-step weight all-gathers (EXPERIMENTS.md §Perf)
    return LMConfig(name="tinyllama-1.1b", n_layers=22, d_model=2048,
                    n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000,
                    rope_theta=1e4, accum_steps=4, fsdp=False)
