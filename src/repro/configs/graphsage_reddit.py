"""GraphSAGE [arXiv:1706.02216] — mean agg, fanout (25, 10), reddit-scale."""
from repro.models.gnn.sage import SageConfig


def config(reduced: bool = False) -> SageConfig:
    if reduced:
        return SageConfig(name="graphsage-reduced", n_layers=2, d_hidden=16,
                          d_feat=8, n_classes=3, sample_sizes=(4, 3))
    return SageConfig(name="graphsage-reddit", n_layers=2, d_hidden=128,
                      aggregator="mean", sample_sizes=(25, 10))
