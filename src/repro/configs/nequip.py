"""NequIP [arXiv:2101.03164] — E(3) tensor products, l_max=2, 8 RBF, rc=5."""
from repro.models.gnn.nequip import NequIPConfig


def config(reduced: bool = False) -> NequIPConfig:
    if reduced:
        return NequIPConfig(name="nequip-reduced", n_layers=2, d_hidden=8,
                            l_max=1, n_rbf=4, d_feat=8)
    return NequIPConfig(name="nequip", n_layers=5, d_hidden=32, l_max=2,
                        n_rbf=8, cutoff=5.0)
