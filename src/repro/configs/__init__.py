from .registry import ShapeCfg, ArchEntry, get_arch, list_archs, ARCHS
