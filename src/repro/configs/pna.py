"""PNA [arXiv:2004.05718] — 4 aggregators × 3 scalers, d_hidden=75."""
from repro.models.gnn.pna import PNAConfig


def config(reduced: bool = False) -> PNAConfig:
    if reduced:
        return PNAConfig(name="pna-reduced", n_layers=2, d_hidden=16,
                         d_feat=8, n_classes=3)
    return PNAConfig(name="pna", n_layers=4, d_hidden=75)
