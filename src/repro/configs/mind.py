"""MIND [arXiv:1904.08030] — multi-interest capsule retrieval.

embed_dim=64, 4 interests, 3 routing iterations; 4M-row item table
(row-sharded over "model"), 128k-row profile-tag table via EmbeddingBag.
"""
from repro.models.recsys.mind import MINDConfig


def config(reduced: bool = False) -> MINDConfig:
    if reduced:
        return MINDConfig(name="mind-reduced", n_items=2048, n_profile=512,
                          embed_dim=16, hist_len=10, n_neg=32)
    return MINDConfig(name="mind", n_items=4_194_304, n_profile=131_072,
                      embed_dim=64, n_interests=4, capsule_iters=3,
                      hist_len=50, n_neg=1024)
