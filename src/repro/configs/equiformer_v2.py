"""EquiformerV2 [arXiv:2306.12059] — eSCN SO(2) conv, l_max=6, m_max=2."""
from repro.models.gnn.equiformer_v2 import EquiformerV2Config


def config(reduced: bool = False) -> EquiformerV2Config:
    if reduced:
        return EquiformerV2Config(name="equiformer-v2-reduced", n_layers=2,
                                  d_hidden=16, l_max=2, m_max=1, n_heads=4,
                                  n_rbf=4, d_feat=8)
    return EquiformerV2Config(name="equiformer-v2", n_layers=12,
                              d_hidden=128, l_max=6, m_max=2, n_heads=8,
                              n_rbf=8, cutoff=5.0)
