"""Yi-9B — llama-arch GQA [arXiv:2403.04652; hf].

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, SwiGLU, RoPE.
"""
from repro.models.transformer import LMConfig


def config(reduced: bool = False) -> LMConfig:
    if reduced:
        import jax.numpy as jnp
        return LMConfig(name="yi-9b-reduced", n_layers=3, d_model=96,
                        n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
                        dtype=jnp.float32, param_dtype=jnp.float32)
    return LMConfig(name="yi-9b", n_layers=48, d_model=4096, n_heads=32,
                    n_kv_heads=4, d_ff=11008, vocab=64000, rope_theta=1e4,
                    accum_steps=4)
