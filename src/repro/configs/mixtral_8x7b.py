"""Mixtral-8x7B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, SWA W=4096.
"""
from repro.models.transformer import LMConfig, MoECfg


def config(reduced: bool = False) -> LMConfig:
    if reduced:
        import jax.numpy as jnp
        return LMConfig(name="mixtral-8x7b-reduced", n_layers=2, d_model=64,
                        n_heads=8, n_kv_heads=2, d_ff=128, vocab=256,
                        moe=MoECfg(4, 2), sliding_window=64,
                        dtype=jnp.float32, param_dtype=jnp.float32)
    return LMConfig(name="mixtral-8x7b", n_layers=32, d_model=4096,
                    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
                    moe=MoECfg(8, 2), sliding_window=4096, accum_steps=4)
