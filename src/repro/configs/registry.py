"""Architecture registry: ``--arch <id>`` → config + shapes + family glue.

Every assigned architecture (10) plus the paper's own ``psi`` configs are
selectable here. ``reduced=True`` returns the CPU-smoke variant of the same
family (small widths/depths, tiny vocab/tables/graphs) used by tests; the
full configs are exercised via the dry-run only (ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

__all__ = ["ShapeCfg", "ArchEntry", "get_arch", "list_archs", "ARCHS"]


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str                  # train | prefill | decode | full_graph |
    #                            minibatch | molecule | serve | retrieval
    params: dict[str, Any]
    skip: str | None = None    # reason, if this (arch, shape) is skipped


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str                # lm | gnn | recsys | psi
    module: str                # configs module defining config(reduced)
    shapes: tuple[ShapeCfg, ...]

    def config(self, reduced: bool = False):
        mod = importlib.import_module(self.module)
        return mod.config(reduced=reduced)


def _lm_shapes(*, full_attention: bool) -> tuple[ShapeCfg, ...]:
    skip = ("pure full-attention arch: 500k dense decode excluded per "
            "assignment; sub-quadratic (SWA) archs run it"
            if full_attention else None)
    return (
        ShapeCfg("train_4k", "train", dict(seq_len=4096, global_batch=256)),
        ShapeCfg("prefill_32k", "prefill",
                 dict(seq_len=32768, global_batch=32)),
        ShapeCfg("decode_32k", "decode",
                 dict(seq_len=32768, global_batch=128)),
        ShapeCfg("long_500k", "decode",
                 dict(seq_len=524288, global_batch=1), skip=skip),
    )


_GNN_SHAPES = (
    ShapeCfg("full_graph_sm", "full_graph",
             dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    ShapeCfg("minibatch_lg", "minibatch",
             dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                  fanout=(15, 10))),
    ShapeCfg("ogb_products", "full_graph",
             dict(n_nodes=2449029, n_edges=61859140, d_feat=100)),
    ShapeCfg("molecule", "molecule",
             dict(n_nodes=30, n_edges=64, batch=128)),
)

_RECSYS_SHAPES = (
    ShapeCfg("train_batch", "train", dict(batch=65536)),
    ShapeCfg("serve_p99", "serve", dict(batch=512)),
    ShapeCfg("serve_bulk", "serve", dict(batch=262144)),
    ShapeCfg("retrieval_cand", "retrieval",
             dict(batch=1, n_candidates=1_000_000)),
)

_PSI_SHAPES = (
    ShapeCfg("twitter_scale", "psi_iterate", dict(dataset="twitter")),
    ShapeCfg("rmat24", "psi_iterate", dict(dataset="rmat24")),
)

ARCHS: dict[str, ArchEntry] = {
    e.arch_id: e for e in [
        ArchEntry("tinyllama-1.1b", "lm", "repro.configs.tinyllama_1_1b",
                  _lm_shapes(full_attention=True)),
        ArchEntry("yi-9b", "lm", "repro.configs.yi_9b",
                  _lm_shapes(full_attention=True)),
        ArchEntry("nemotron-4-340b", "lm", "repro.configs.nemotron_4_340b",
                  _lm_shapes(full_attention=True)),
        ArchEntry("mixtral-8x22b", "lm", "repro.configs.mixtral_8x22b",
                  _lm_shapes(full_attention=False)),
        ArchEntry("mixtral-8x7b", "lm", "repro.configs.mixtral_8x7b",
                  _lm_shapes(full_attention=False)),
        ArchEntry("pna", "gnn", "repro.configs.pna", _GNN_SHAPES),
        ArchEntry("equiformer-v2", "gnn", "repro.configs.equiformer_v2",
                  _GNN_SHAPES),
        ArchEntry("nequip", "gnn", "repro.configs.nequip", _GNN_SHAPES),
        ArchEntry("graphsage-reddit", "gnn", "repro.configs.graphsage_reddit",
                  _GNN_SHAPES),
        ArchEntry("mind", "recsys", "repro.configs.mind", _RECSYS_SHAPES),
        ArchEntry("psi-score", "psi", "repro.configs.psi_score", _PSI_SHAPES),
    ]
}


def get_arch(arch_id: str) -> ArchEntry:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def list_archs() -> list[str]:
    return sorted(ARCHS)
