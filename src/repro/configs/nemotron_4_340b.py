"""Nemotron-4-340B — GQA, squared-ReLU FFN [arXiv:2402.16819; unverified].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
Adafactor + aggressive grad accumulation: the 340B-param memory envelope
(DESIGN.md §2; per-device bytes recorded in EXPERIMENTS.md §Dry-run).
"""
from repro.models.transformer import LMConfig


def config(reduced: bool = False) -> LMConfig:
    if reduced:
        import jax.numpy as jnp
        return LMConfig(name="nemotron-4-340b-reduced", n_layers=2,
                        d_model=96, n_heads=8, n_kv_heads=2, d_ff=384,
                        vocab=512, act="sq_relu", dtype=jnp.float32,
                        param_dtype=jnp.float32)
    return LMConfig(name="nemotron-4-340b", n_layers=96, d_model=18432,
                    n_heads=96, n_kv_heads=8, d_ff=73728, vocab=256000,
                    d_head=192, act="sq_relu", optimizer="adafactor",
                    accum_steps=16, q_block=256, k_block=512)
