"""2-D edge-block partitioning for the distributed Power-ψ (DESIGN.md §4).

Mesh axes ("data", "model") ≡ (src rows, dst columns); multi-pod folds "pod"
into the src axis. Layouts (N padded to d·mo·q):

* **dst layout** — contiguous blocks: column c owns nodes [c·Nc, (c+1)·Nc),
  Nc = N_pad / mo. The local scatter of the push lands here.
* **src (block-cyclic) layout** — row r owns pieces {c·Nc + r·q .. +q} for all
  c; local index ℓ = c·q + j. Chosen so that a ``psum_scatter`` over "data"
  of the dst-layout result *is already* piece (r, c) of the src layout — the
  re-distribution between iterations becomes psum_scatter + all_gather with
  zero index shuffling on device (SUMMA-style SpMV with block-cyclic vectors).

Edges are grouped host-side by (row, col), dst-sorted within the group (so
the device segment-sum runs in sorted mode) and padded to the global max
block size with sentinels (src → local sentinel slot holding 0, dst → Nc,
dropped by num_segments=Nc+1).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .structure import Graph

__all__ = ["Partition2D", "partition_2d"]


@dataclasses.dataclass(frozen=True)
class Partition2D:
    n: int
    n_pad: int
    d: int                  # src rows (pod × data for multi-pod)
    mo: int                 # dst columns
    q: int                  # piece length = n_pad / (d · mo)
    src_local: np.ndarray   # i32[d, mo, e_max]; sentinel = local_src_n
    dst_local: np.ndarray   # i32[d, mo, e_max]; sentinel = nc
    e_counts: np.ndarray    # i64[d, mo] true edge counts per block

    @property
    def nc(self) -> int:
        return self.mo and self.n_pad // self.mo

    @property
    def local_src_n(self) -> int:
        return self.mo * self.q

    @property
    def e_max(self) -> int:
        return int(self.src_local.shape[-1])

    @property
    def imbalance(self) -> float:
        """max/mean edges per device — straggler indicator."""
        mean = max(1.0, float(self.e_counts.mean()))
        return float(self.e_counts.max()) / mean

    # ----- layout converters (host side) ------------------------------- #
    def to_src_layout(self, vec: np.ndarray, fill: float = 0.0) -> np.ndarray:
        """f[n] → f[d, mo·q] in the block-cyclic src layout."""
        v = self._pad(vec, fill)
        # node g = c*nc + r*q + j  →  (r, c*q + j)
        v3 = v.reshape(self.mo, self.d, self.q)       # [c, r, j]
        return np.ascontiguousarray(v3.transpose(1, 0, 2)
                                    ).reshape(self.d, self.mo * self.q)

    def to_piece_layout(self, vec: np.ndarray, fill: float = 0.0
                        ) -> np.ndarray:
        """f[n] → f[d, mo, q]: value of piece (r, c)."""
        v = self._pad(vec, fill)
        return np.ascontiguousarray(
            v.reshape(self.mo, self.d, self.q).transpose(1, 0, 2))

    def from_src_layout(self, arr: np.ndarray) -> np.ndarray:
        """f[d, mo·q] → f[n]."""
        v3 = np.asarray(arr).reshape(self.d, self.mo, self.q).transpose(1, 0, 2)
        return v3.reshape(self.n_pad)[: self.n]

    def _pad(self, vec: np.ndarray, fill: float) -> np.ndarray:
        out = np.full(self.n_pad, fill, vec.dtype)
        out[: self.n] = vec
        return out


def partition_2d(graph: Graph, d: int, mo: int, *,
                 lane_pad: int = 128) -> Partition2D:
    """Partition edges onto a d×mo logical device grid."""
    n = graph.n
    q = -(-n // (d * mo))
    n_pad = d * mo * q
    nc = n_pad // mo

    src, dst = graph.src.astype(np.int64), graph.dst.astype(np.int64)
    # src owner under the block-cyclic layout
    c_of_src = src // nc
    off = src - c_of_src * nc
    row = off // q
    src_loc = c_of_src * q + (off - row * q)
    # dst owner under the contiguous layout
    col = dst // nc
    dst_loc = dst - col * nc

    dev = row * mo + col
    order = np.lexsort((dst_loc, dev))                # device-major, dst-sorted
    dev_s, src_s, dst_s = dev[order], src_loc[order], dst_loc[order]
    counts = np.bincount(dev_s, minlength=d * mo).reshape(d, mo)
    e_max = max(int(counts.max()), 1)
    e_max = -(-e_max // lane_pad) * lane_pad          # lane-align blocks

    flat_src = np.full((d * mo, e_max), mo * q, np.int32)   # sentinel
    flat_dst = np.full((d * mo, e_max), nc, np.int32)       # sentinel
    starts = np.concatenate([[0], np.cumsum(counts.reshape(-1))])[:-1]
    pos = np.arange(dev_s.size) - starts[dev_s]
    flat_src[dev_s, pos] = src_s
    flat_dst[dev_s, pos] = dst_s

    return Partition2D(n=n, n_pad=n_pad, d=d, mo=mo, q=q,
                       src_local=flat_src.reshape(d, mo, e_max),
                       dst_local=flat_dst.reshape(d, mo, e_max),
                       e_counts=counts)
