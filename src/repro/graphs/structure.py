"""Directed-graph substrate.

Edge convention (matches the paper): an edge ``(j, i)`` means *user j follows
user i*; ``i`` is a **leader** of ``j`` and ``j`` is a **follower** of ``i``.
Arrays ``src`` hold the follower endpoint ``j`` and ``dst`` the leader
endpoint ``i``.

The ψ-score left mat-vec pushes mass along follow edges (src → dst), so the
canonical on-device layout is sorted-by-dst (CSC-like) which makes the
``segment_sum`` scatter sorted. A sorted-by-src (CSR-like) view is kept for
the right mat-vec used by the Power-NF baseline and for neighbour sampling.
"""
from __future__ import annotations

import dataclasses
from functools import cached_property

import numpy as np

__all__ = ["Graph"]


@dataclasses.dataclass(frozen=True)
class Graph:
    """Immutable directed graph held in host (numpy) memory.

    Attributes:
      n: number of nodes.
      src: int32[M] follower endpoint of each edge.
      dst: int32[M] leader endpoint of each edge.
      name: optional human-readable tag.
    """

    n: int
    src: np.ndarray
    dst: np.ndarray
    name: str = "graph"

    def __post_init__(self):
        if self.src.shape != self.dst.shape:
            raise ValueError("src/dst length mismatch")
        for arr, tag in ((self.src, "src"), (self.dst, "dst")):
            if arr.size and (arr.min() < 0 or arr.max() >= self.n):
                raise ValueError(f"{tag} ids out of range [0, {self.n})")
        object.__setattr__(self, "src", np.asarray(self.src, np.int32))
        object.__setattr__(self, "dst", np.asarray(self.dst, np.int32))

    # ------------------------------------------------------------------ #
    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @cached_property
    def out_degree(self) -> np.ndarray:
        """#leaders of each node (|L(j)|)."""
        return np.bincount(self.src, minlength=self.n).astype(np.int32)

    @cached_property
    def in_degree(self) -> np.ndarray:
        """#followers of each node."""
        return np.bincount(self.dst, minlength=self.n).astype(np.int32)

    # -- sorted views --------------------------------------------------- #
    @cached_property
    def _dst_order(self) -> np.ndarray:
        return np.argsort(self.dst, kind="stable")

    @cached_property
    def _src_order(self) -> np.ndarray:
        return np.argsort(self.src, kind="stable")

    @cached_property
    def edges_by_dst(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) with dst ascending — scatter-friendly for left matvec."""
        o = self._dst_order
        return self.src[o], self.dst[o]

    @cached_property
    def edges_by_src(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) with src ascending — for right matvec / sampling."""
        o = self._src_order
        return self.src[o], self.dst[o]

    @cached_property
    def csr_indptr(self) -> np.ndarray:
        """indptr over nodes for the by-src view (neighbour lists = leaders)."""
        return np.concatenate(
            [[0], np.cumsum(self.out_degree)]).astype(np.int64)

    @cached_property
    def csc_indptr(self) -> np.ndarray:
        """indptr over nodes for the by-dst view (neighbour lists = followers)."""
        return np.concatenate(
            [[0], np.cumsum(self.in_degree)]).astype(np.int64)

    def leaders_of(self, j: int) -> np.ndarray:
        s, d = self.edges_by_src
        lo, hi = self.csr_indptr[j], self.csr_indptr[j + 1]
        return d[lo:hi]

    def followers_of(self, i: int) -> np.ndarray:
        s, d = self.edges_by_dst
        lo, hi = self.csc_indptr[i], self.csc_indptr[i + 1]
        return s[lo:hi]

    # ------------------------------------------------------------------ #
    def dedup(self) -> "Graph":
        """Remove self-loops and duplicate edges (paper's model has neither)."""
        keep = self.src != self.dst
        src, dst = self.src[keep], self.dst[keep]
        key = src.astype(np.int64) * self.n + dst
        _, idx = np.unique(key, return_index=True)
        return Graph(self.n, src[idx], dst[idx], name=self.name)

    def reverse(self) -> "Graph":
        return Graph(self.n, self.dst.copy(), self.src.copy(),
                     name=f"{self.name}-rev")

    def to_dense(self) -> np.ndarray:
        """Dense follower→leader adjacency L[j, i] = 1 iff j follows i."""
        a = np.zeros((self.n, self.n), np.float64)
        a[self.src, self.dst] = 1.0
        return a

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Graph({self.name!r}, n={self.n}, m={self.m})"
