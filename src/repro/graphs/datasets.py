"""Dataset registry: seeded synthetic stand-ins for the paper's Konect graphs.

Table II of the paper:

| name     | type     | nodes   | edges   |
|----------|----------|---------|---------|
| dblp     | citation | 12 591  | 49 743  |
| twitter  | social   | 465 017 | 834 797 |
| facebook | social   | 63 731  | 817 035 |
| hepph    | citation | 34 546  | 421 578 |

The container is offline, so we rebuild graphs with matched (N, M) and
heavy-tailed degree distributions (erased configuration model, oversampled so
the post-dedup edge count lands within ~1% of the target). Every graph is
fully determined by its seed.
"""
from __future__ import annotations

from .generators import powerlaw_configuration, rmat, erdos_renyi
from .structure import Graph

__all__ = ["load_dataset", "DATASETS"]

# name -> (n, m, exponent_out, exponent_in, seed)
DATASETS: dict[str, tuple[int, int, float, float, int]] = {
    "dblp": (12_591, 49_743, 2.6, 2.4, 1),
    "facebook": (63_731, 817_035, 2.2, 2.1, 2),
    "twitter": (465_017, 834_797, 2.5, 2.2, 3),
    "hepph": (34_546, 421_578, 2.2, 2.1, 4),
}


def load_dataset(name: str, *, seed: int | None = None) -> Graph:
    """Instantiate a synthetic stand-in with the paper's (N, M)."""
    key = name.lower()
    if key.startswith("rmat"):
        scale = int(key.removeprefix("rmat"))
        return rmat(scale, seed=seed or 7, name=key)
    if key == "tiny":                       # quick smoke graph
        return erdos_renyi(64, 256, seed=seed or 11, name="tiny")
    if key not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    n, m, eo, ei, s = DATASETS[key]
    # oversample: erased configuration model loses ~2-6% to dedup
    g = powerlaw_configuration(n, int(m * 1.08), exponent_out=eo,
                               exponent_in=ei, seed=seed if seed is not None
                               else s, name=key)
    if g.m > m:  # trim deterministically to the exact published edge count
        import numpy as np
        rng = np.random.default_rng(0xC0FFEE ^ (seed if seed is not None else s))
        idx = rng.permutation(g.m)[:m]
        g = Graph(n, g.src[idx], g.dst[idx], name=key)
    return g
