"""Seeded synthetic graph generators.

The Konect datasets used by the paper are not available offline, so the
benchmark suite rebuilds *degree-matched stand-ins* with these generators
(see ``datasets.py``). All generators are vectorized numpy and comfortably
produce 10^8-edge graphs.
"""
from __future__ import annotations

import numpy as np

from .structure import Graph

__all__ = [
    "erdos_renyi", "barabasi_albert", "powerlaw_configuration", "rmat",
    "clustered_blocks",
]


def erdos_renyi(n: int, m: int, *, seed: int = 0, name: str = "er") -> Graph:
    """Directed G(n, m): m distinct uniform random edges, no self-loops."""
    rng = np.random.default_rng(seed)
    # oversample to survive dedup
    factor = 1.3
    src = dst = None
    while True:
        k = int(m * factor) + 16
        s = rng.integers(0, n, k, dtype=np.int64)
        d = rng.integers(0, n, k, dtype=np.int64)
        keep = s != d
        s, d = s[keep], d[keep]
        key = s * n + d
        _, idx = np.unique(key, return_index=True)
        if idx.size >= m:
            idx = idx[rng.permutation(idx.size)[:m]]
            src, dst = s[idx], d[idx]
            break
        factor *= 1.5
    return Graph(n, src.astype(np.int32), dst.astype(np.int32), name=name)


def _powerlaw_degrees(n: int, m: int, exponent: float,
                      rng: np.random.Generator, max_frac: float = 0.02
                      ) -> np.ndarray:
    """Integer degree sequence ~ Zipf(exponent) rescaled to sum ≈ m."""
    raw = rng.zipf(exponent, n).astype(np.float64)
    raw = np.minimum(raw, max(2.0, max_frac * n))
    deg = np.maximum(0, np.round(raw * (m / raw.sum()))).astype(np.int64)
    # fix the total exactly
    diff = m - int(deg.sum())
    if diff != 0:
        idx = rng.integers(0, n, abs(diff))
        np.add.at(deg, idx, 1 if diff > 0 else -1)
        deg = np.maximum(deg, 0)
        diff = m - int(deg.sum())
        if diff > 0:                       # leftover from clipping at 0
            idx = rng.integers(0, n, diff)
            np.add.at(deg, idx, 1)
    return deg


def powerlaw_configuration(n: int, m: int, *, exponent_out: float = 2.3,
                           exponent_in: float = 2.1, seed: int = 0,
                           name: str = "plconf") -> Graph:
    """Directed configuration model with heavy-tailed in/out degrees.

    Stub-matching: out-stubs and in-stubs are independently shuffled and
    paired; self-loops/multi-edges are dropped (standard erased configuration
    model), so the realized edge count is slightly below ``m`` — the dataset
    registry compensates by oversampling a few percent.
    """
    rng = np.random.default_rng(seed)
    dout = _powerlaw_degrees(n, m, exponent_out, rng)
    din = _powerlaw_degrees(n, m, exponent_in, rng)
    src = np.repeat(np.arange(n, dtype=np.int64), dout)
    dst = np.repeat(np.arange(n, dtype=np.int64), din)
    rng.shuffle(src)
    rng.shuffle(dst)
    k = min(src.size, dst.size)
    src, dst = src[:k], dst[:k]
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, idx = np.unique(key, return_index=True)
    return Graph(n, src[idx].astype(np.int32), dst[idx].astype(np.int32),
                 name=name)


def barabasi_albert(n: int, m_per_node: int, *, seed: int = 0,
                    name: str = "ba") -> Graph:
    """Directed preferential attachment (new node follows m existing)."""
    rng = np.random.default_rng(seed)
    n0 = max(m_per_node, 2)
    src_l: list[np.ndarray] = [np.repeat(np.arange(1, n0), 1)]
    dst_l: list[np.ndarray] = [np.zeros(n0 - 1, np.int64)]
    targets = np.concatenate([np.arange(n0), np.zeros(n0 - 1, np.int64)])
    for v in range(n0, n):
        picks = targets[rng.integers(0, targets.size, m_per_node)]
        picks = np.unique(picks)
        src_l.append(np.full(picks.size, v, np.int64))
        dst_l.append(picks)
        targets = np.concatenate([targets, picks, np.full(picks.size, v)])
    src = np.concatenate(src_l)
    dst = np.concatenate(dst_l)
    return Graph(n, src.astype(np.int32), dst.astype(np.int32), name=name)


def clustered_blocks(n: int, m: int, *, block: int = 128, p_in: float = 0.95,
                     seed: int = 0, name: str = "clustered") -> Graph:
    """Community-structured graph with id-aligned blocks of ``block`` nodes.

    A fraction ``p_in`` of edges falls inside a node's own block, the rest
    are uniform — the dense-diagonal regime where the BSR/MXU format's tile
    occupancy is high (the regime-autotuner's counterpoint to the
    hyper-sparse configuration models; see kernels/autotune.py).
    """
    rng = np.random.default_rng(seed)
    # feasibility: the retry loop below can only terminate if m distinct
    # edges exist under the block structure
    sizes = np.diff(np.append(np.arange(0, n, block), n))
    intra_cap = int((sizes * (sizes - 1)).sum())
    cap = intra_cap if p_in >= 1.0 else n * (n - 1)
    if m > cap:
        raise ValueError(f"m={m} exceeds the {cap} distinct edges possible "
                         f"for n={n}, block={block}, p_in={p_in}")
    factor = 1.3
    while True:
        k = int(m * factor) + 16
        src = rng.integers(0, n, k, dtype=np.int64)
        b0 = (src // block) * block
        bsize = np.minimum(block, n - b0)          # last block may be short
        intra = b0 + rng.integers(0, 1 << 30, k, dtype=np.int64) % bsize
        inter = rng.integers(0, n, k, dtype=np.int64)
        dst = np.where(rng.random(k) < p_in, intra, inter)
        keep = src != dst
        src, dst = src[keep], dst[keep]
        key = src * n + dst
        _, idx = np.unique(key, return_index=True)
        if idx.size >= m:
            idx = idx[rng.permutation(idx.size)[:m]]
            return Graph(n, src[idx].astype(np.int32),
                         dst[idx].astype(np.int32), name=name)
        factor *= 1.5


def rmat(scale: int, edge_factor: int = 16, *, a: float = 0.57,
         b: float = 0.19, c: float = 0.19, seed: int = 0,
         name: str = "rmat") -> Graph:
    """R-MAT / Kronecker generator (Graph500 parameters by default).

    Produces the skewed, community-ish structure of real social graphs;
    used for the twitter-scale distributed dry-runs.
    """
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        go_right = (r >= a) & (r < ab) | (r >= abc)      # column bit
        go_down = r >= ab                                 # row bit
        src |= go_down.astype(np.int64) << bit
        dst |= go_right.astype(np.int64) << bit
    keep = src != dst
    src, dst = src[keep], dst[keep]
    key = src * n + dst
    _, idx = np.unique(key, return_index=True)
    return Graph(n, src[idx].astype(np.int32), dst[idx].astype(np.int32),
                 name=name)
