"""Uniform k-hop fanout neighbour sampler (GraphSAGE ``minibatch_lg``).

Host-side numpy over the CSR neighbour lists — a real sampler, not a stub:
per hop, each frontier node draws ``fanout`` neighbours uniformly with
replacement (matching the original GraphSAGE implementation); the union of
sampled nodes forms the subgraph, re-labelled to local ids and padded to the
static worst-case (batch · Π fanouts) so the compiled step has fixed shapes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .structure import Graph

__all__ = ["SampledSubgraph", "fanout_sample", "subgraph_budget"]


@dataclasses.dataclass(frozen=True)
class SampledSubgraph:
    node_ids: np.ndarray     # i64[n_pad] global ids (sentinel −1 on pads)
    src: np.ndarray          # i32[e_pad] local sender (sentinel n_pad)
    dst: np.ndarray          # i32[e_pad] local receiver (sorted, sentinel)
    seed_mask: np.ndarray    # bool[n_pad]
    node_mask: np.ndarray    # bool[n_pad]
    n_pad: int
    e_pad: int


def subgraph_budget(batch_nodes: int, fanout: tuple[int, ...]
                    ) -> tuple[int, int]:
    """Worst-case (nodes, edges) for static padding."""
    n = batch_nodes
    tot_n = batch_nodes
    tot_e = 0
    for f in fanout:
        e = n * f
        tot_e += e
        n = e
        tot_n += e
    return tot_n, tot_e


def fanout_sample(graph: Graph, seeds: np.ndarray, fanout: tuple[int, ...],
                  *, seed: int = 0) -> SampledSubgraph:
    rng = np.random.default_rng(seed)
    src_sorted, dst_sorted = graph.edges_by_src
    indptr = graph.csr_indptr
    n_pad, e_pad = subgraph_budget(seeds.shape[0], fanout)

    frontier = seeds.astype(np.int64)
    all_nodes = [frontier]
    edges_s: list[np.ndarray] = []
    edges_d: list[np.ndarray] = []
    for f in fanout:
        deg = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
        has = deg > 0
        draw = rng.integers(0, np.maximum(deg, 1)[:, None],
                            (frontier.shape[0], f))
        idx = indptr[frontier][:, None] + draw            # [F, f]
        nbrs = dst_sorted[np.minimum(idx, dst_sorted.shape[0] - 1)]
        nbrs = np.where(has[:, None], nbrs, -1)
        # message edge: neighbour (sender) → frontier node (receiver)
        edges_s.append(nbrs.reshape(-1))
        edges_d.append(np.repeat(frontier, f))
        frontier = nbrs.reshape(-1)
        frontier = frontier[frontier >= 0]
        all_nodes.append(frontier)

    nodes = np.concatenate(all_nodes)
    nodes = nodes[nodes >= 0]
    uniq, inv = np.unique(nodes, return_inverse=True)
    n_local = uniq.shape[0]
    lookup = {int(g): i for i, g in enumerate(uniq)}

    es = np.concatenate(edges_s)
    ed = np.concatenate(edges_d)
    valid = es >= 0
    es, ed = es[valid], ed[valid]
    es_l = np.fromiter((lookup[int(g)] for g in es), np.int32, es.shape[0])
    ed_l = np.fromiter((lookup[int(g)] for g in ed), np.int32, ed.shape[0])
    order = np.argsort(ed_l, kind="stable")
    es_l, ed_l = es_l[order], ed_l[order]

    node_ids = np.full(n_pad, -1, np.int64)
    node_ids[:n_local] = uniq
    src = np.full(e_pad, n_pad, np.int32)
    dst = np.full(e_pad, n_pad, np.int32)
    src[:es_l.shape[0]] = es_l
    dst[:ed_l.shape[0]] = ed_l
    seed_mask = np.zeros(n_pad, bool)
    for s in seeds:
        seed_mask[lookup[int(s)]] = True
    node_mask = np.zeros(n_pad, bool)
    node_mask[:n_local] = True
    return SampledSubgraph(node_ids=node_ids, src=src, dst=dst,
                           seed_mask=seed_mask, node_mask=node_mask,
                           n_pad=n_pad, e_pad=e_pad)
