"""Graph substrate: structure, generators, datasets, partitioning, sampling."""
from .structure import Graph
from .generators import (erdos_renyi, barabasi_albert,
                         powerlaw_configuration, rmat, clustered_blocks)
from .datasets import load_dataset, DATASETS

__all__ = ["Graph", "erdos_renyi", "barabasi_albert",
           "powerlaw_configuration", "rmat", "clustered_blocks",
           "load_dataset", "DATASETS"]
