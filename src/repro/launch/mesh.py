"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
``--xla_force_host_platform_device_count=512`` before first jax init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi-pod adds a leading pod axis (2×)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


class HW:
    """TPU v5e hardware constants for the roofline (per chip)."""
    PEAK_BF16_FLOPS = 197e12      # FLOP/s
    HBM_BW = 819e9                # B/s
    ICI_BW = 50e9                 # B/s per link (~3 links usable per axis)
    HBM_BYTES = 16 * 2 ** 30
    VMEM_BYTES = 128 * 2 ** 20
