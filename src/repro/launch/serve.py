"""Serving launcher: batched ψ-score queries or LM decode, per family.

    PYTHONPATH=src python -m repro.launch.serve --arch psi-score --requests 4
    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --requests 2 --gen-len 8

Observability (docs/OBSERVABILITY.md): ``--metrics-port`` exposes the live
registry over HTTP (now incl. ``/healthz`` + ``/slo``), ``--trace-out``
records every pipeline span to JSONL, ``--metrics-dump`` writes one
self-describing snapshot (fingerprint + metrics + convergence
trajectories) at exit. The analysis layer rides the same flags:
``--slo`` judges the run against the default SLO catalog (burn-rate
alerts + verdict epilogue), ``--watch`` arms pre-emptive convergence
anomaly detection (incl. the seeded α-drift pre-emption scenario in the
chaos drill), ``--profile-out`` writes flamegraph folded stacks + the
async critical path. The full drill:

    PYTHONPATH=src python -m repro.launch.serve --arch psi-score \
        --stream burst --chaos --slo --watch \
        --metrics-dump metrics.json --trace-out trace.jsonl \
        --profile-out profile.folded
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def _serve_fleet(args) -> None:
    """Multi-tenant ψ serving: K tenants on one TenantFleet, the request
    loop routed round-robin across them (docs/SERVING.md)."""
    from ..core import heterogeneous
    from ..graphs import clustered_blocks, powerlaw_configuration
    from ..serving import BucketPolicy, TenantFleet

    policy = (BucketPolicy.from_spec(args.bucket_sizes)
              if args.bucket_sizes else BucketPolicy())
    backend = args.backend or "auto"
    if backend not in ("auto", "dense", "reference", "pallas"):
        raise SystemExit(f"--tenants needs a fleet backend "
                         f"(auto|dense|reference|pallas); got {backend!r}")
    if args.accelerate:
        raise SystemExit("--accelerate is not supported with --tenants > 1 "
                         "(the fleet's masked batch loop has no Aitken "
                         "composition yet)")
    fleet = TenantFleet(backend=backend, tol=1e-8, policy=policy,
                        check_every=args.check_every,
                        microbench=args.microbench)
    tids = []
    t0 = time.perf_counter()
    for k in range(args.tenants):
        if k % 2 == 0:                        # alternate graph regimes
            g = powerlaw_configuration(2_000, 12_000, seed=100 + k)
        else:
            g = clustered_blocks(1_024, 10_000, block=128, p_in=0.9,
                                 seed=100 + k)
        act = heterogeneous(g.n, seed=200 + k)
        tid = f"tenant{k}"
        spec = fleet.admit(tid, g, act)
        tids.append(tid)
        print(f"[serve] admitted {tid}: n={g.n} m={g.m} → {spec}")
    fleet.solve()
    print(f"[serve] fleet[{fleet.backend}] warm in "
          f"{time.perf_counter() - t0:.2f}s; occupancy:")
    for spec, acct in fleet.occupancy().items():
        print(f"[serve]   {spec}: {acct['tenants']} tenants "
              f"regime={acct['regime']} "
              f"node_occ={acct['node_occupancy']:.2f} "
              f"edge_occ={acct['edge_occupancy']:.2f}")
    frontier = fleet.frontier
    rng = np.random.default_rng(0)
    for r in range(args.requests):
        tid = tids[r % len(tids)]             # round-robin across tenants
        n = fleet.stats(tid)["n"]
        users = rng.integers(0, n, args.batch)
        t0 = time.perf_counter()
        scores = frontier.scores_batch([tid] * args.batch, users)
        top, _ = frontier.top_k(tid, args.top_k)
        print(f"[serve] req {r} → {tid}: users={users.tolist()} "
              f"psi={np.round(scores, 8).tolist()} "
              f"top-{args.top_k}={top.tolist()} "
              f"({(time.perf_counter() - t0) * 1e3:.1f} ms)")
        if r == args.requests // 2:           # live update mid-traffic
            u = int(users[0])
            t0 = time.perf_counter()
            fleet.patch_activity(tid, np.asarray([u]), lam=np.asarray([5.0]))
            fleet.solve()
            print(f"[serve] delta update {tid} user {u}: re-converged in "
                  f"{fleet.stats(tid)['iterations']} warm iterations "
                  f"({(time.perf_counter() - t0) * 1e3:.1f} ms); "
                  f"co-tenant lanes untouched")
    top = frontier.global_top_k(args.top_k)
    print(f"[serve] fleet-wide top-{args.top_k}: "
          + ", ".join(f"{t}/{u}@{s:.2e}" for t, u, s in top))


def _serve_stream(args) -> None:
    """Streaming ψ serving: a live event log (posts / reposts / follows /
    unfollows) drives online λ/μ estimation and coalesced O(Δ) patches
    against a PsiService; the freshness policy decides when to re-resolve
    versus serve the existing ranking with certified staleness
    (docs/STREAMING.md)."""
    import jax.numpy as jnp

    from ..core import Activity, PsiService, RATE_FLOOR, heterogeneous, \
        make_engine
    from ..graphs import powerlaw_configuration
    from ..stream import (FreshnessPolicy, StreamIngestor, burst_stream,
                          flash_crowd_stream, poisson_stream)

    n, m = 2_000, 12_000
    g = powerlaw_configuration(n, m, seed=7)
    truth = heterogeneous(n, seed=8)
    horizon = args.stream_events / float(truth.total.sum())
    if args.stream == "poisson":
        log = poisson_stream(truth, horizon, seed=9, graph=g)
    elif args.stream == "burst":
        rng = np.random.default_rng(9)
        log = burst_stream(truth, horizon, seed=9,
                           burst_users=rng.integers(0, n, 16),
                           burst_factor=10.0)
    else:
        log = flash_crowd_stream(g, truth, horizon, seed=9,
                                 new_followers=96, churn=0.3)
    backend = args.backend or "reference"
    # the platform starts cold: every user at the RATE_FLOOR clamp; the
    # stream teaches the estimator the true rates event by event
    cold = Activity(np.full(n, RATE_FLOOR), np.full(n, RATE_FLOOR))
    svc = PsiService(g, cold, tol=1e-8, backend=backend,
                     check_every=args.check_every, dtype=jnp.float64)
    args._svc = svc                          # for the --explain epilogue
    half_life = args.half_life if args.half_life else horizon / 2
    ing = StreamIngestor(
        svc, half_life=half_life, topk=args.top_k,
        policy=FreshnessPolicy(coalesce=64,
                               resolve_every=args.resolve_every))
    print(f"[serve] stream={args.stream}: {len(log)} events over "
          f"{horizon:.1f}s event-time ({log.counts()}), half_life="
          f"{half_life:.1f}s, resolve_every={args.resolve_every} events, "
          f"backend={svc.backend}")
    t0 = time.perf_counter()
    rep = ing.ingest(log)
    wall = time.perf_counter() - t0
    print(f"[serve] ingested {rep.events_total} events in {wall:.2f}s "
          f"({rep.events_total / wall:.0f} ev/s sustained) — "
          f"{rep.resolves} resolves, top-{args.top_k} churn history "
          f"{[round(c, 2) for c in ing.churn_history]}")
    print(f"[serve] freshness: staleness={rep.staleness_events} events / "
          f"{rep.staleness_seconds:.1f}s, dirty_mass={rep.dirty_mass:.2e}, "
          f"certified(max_events=0)={rep.certify(max_events=0)}")
    top, vals = ing.top_k(args.top_k)
    print(f"[serve] top-{args.top_k}: {top.tolist()}")
    # batched query traffic against the resolved service (populates the
    # psi_query_seconds / cache-hit telemetry the obs epilogue summarizes)
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    for _ in range(args.requests):
        users = rng.integers(0, n, args.batch)
        svc.scores_batch(users)
        svc.rank_of(users)
        svc.top_k(args.top_k)
    print(f"[serve] {args.requests} query rounds (batch {args.batch} + "
          f"rank + top-{args.top_k}) in "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms")
    # parity + estimation quality vs the generator's ground truth
    batch = make_engine("reference", graph=svc.graph,
                        activity=svc.engine.activity,
                        dtype=jnp.float64).run(tol=1e-8)
    err = float(np.abs(svc.scores() - np.asarray(batch.psi)).max())
    lam_hat, mu_hat = ing.estimator().rates()
    rate_err = (np.abs(lam_hat - truth.lam).sum()
                + np.abs(mu_hat - truth.mu).sum()) \
        / float(truth.total.sum())
    # Poisson information floor: ~0.8·√(2n/events) l1 relative error is the
    # best ANY estimator can do from this many events over this many users
    floor = 0.8 * (2 * n / max(1, rep.events_total)) ** 0.5
    print(f"[serve] psi parity vs from-scratch batch: {err:.2e}; "
          f"estimator l1 rate err vs ground truth: {rate_err:.1%} "
          f"(Poisson information floor at {len(log)} events / {n} users "
          f"≈ {floor:.0%})")


def _serve_chaos(args) -> None:
    """Chaos drill: run the seeded fault-injection scenario from
    ``repro.resilience.check`` against the full serving stack — streaming
    ingestion, whole-stack checkpoints, a mid-stream crash, exactly-once
    replay, the supervised-resolve ladder — and print the
    ResilienceReport (docs/RESILIENCE.md)."""
    from ..resilience.check import run_chaos

    t0 = time.perf_counter()
    report, metrics = run_chaos(seed=args.chaos_seed)
    print(f"[serve] chaos drill ({metrics['dtype']}, "
          f"n={metrics['n']} m={metrics['m']} "
          f"events={metrics['events']}) in "
          f"{time.perf_counter() - t0:.2f}s")
    print(f"[serve] recovered at offset {metrics['offset']} "
          f"(checkpoint step {metrics['recovered_step']}), "
          f"{metrics['restarts']} mid-run restarts, "
          f"parity vs fault-free fixed point: "
          f"{metrics['parity_err']:.2e} (tol {metrics['psi_tol']:g})")
    print(f"[serve] recovery overhead {metrics['recovery_overhead']:.2f}x "
          f"fault-free wall, mttr {metrics['mttr_s'] * 1e3:.0f} ms, "
          f"{metrics['degraded_served']} degraded answers served "
          "(staleness-tagged)")
    print(report.summary())


def _serve_watch(args) -> None:
    """Seeded pre-emption scenario (``--watch``): a deterministic schedule
    of μ-raising patches marches the contraction modulus α = ‖M‖₁ toward
    the sentinel wall. The baseline arm shows the α sentinel *would* trip
    at some patch step; the watched arm's trend projection flags the drift
    strictly earlier, stops the escalation, and the supervisor consumes
    the advice as a pre-emptive sync sweep — a certified answer is served
    and the sentinel never fires (docs/RESILIENCE.md)."""
    from ..asyncexec import AsyncPsiDriver
    from ..core import heterogeneous
    from ..graphs import powerlaw_configuration
    from ..obs.watch import ConvergenceWatch
    from ..resilience.health import Sentinels, alpha_norm
    from ..resilience.supervisor import ResilientResolver

    n, m, wall = 400, 2_400, 0.995
    factors = [1.35] * 16                      # deterministic μ escalation

    def build():
        g = powerlaw_configuration(n, m, seed=13)
        return AsyncPsiDriver(g, heterogeneous(n, seed=14),
                              num_chunks=3, tau=2)

    def patch(drv, f):
        users = np.arange(n)
        drv.host.patch_activity(users, mu=drv.host.mu[users] * f)

    # arm 1 (baseline, no watch): walk the schedule until the sentinel
    # trips — this is the incident the watch must get ahead of
    drv = build()
    sent = Sentinels(alpha_max=wall)
    trip_step = trip_alpha = None
    for step, f in enumerate(factors):
        patch(drv, f)
        if sent.check_alpha(drv.host) is not None:
            trip_step, trip_alpha = step, alpha_norm(drv.host)
            break
    if trip_step is None:
        raise SystemExit("[watch] drill broken: the μ schedule never "
                         "reached the α sentinel wall")
    print(f"[watch] baseline arm: α sentinel trips at patch {trip_step} "
          f"(α={trip_alpha:.4f} ≥ {wall})")

    # arm 2 (watched): same schedule, but every patch feeds the watch;
    # the projected trend flags the drift before the wall and the
    # supervisor pre-empts with a certified sync sweep
    drv = build()
    watch = getattr(args, "_watch", None) or ConvergenceWatch()
    watch_sent = Sentinels(alpha_max=wall)
    resolver = ResilientResolver(drv, tol=1e-6, max_iter=4_000,
                                 attempt_deadline_s=60.0,
                                 sentinels=watch_sent, watch=watch)
    watch.consume_advice()        # drop advice left over from earlier phases
    flag_step = None
    for step, f in enumerate(factors):
        patch(drv, f)
        watch.observe_alpha(alpha_norm(drv.host))
        if watch.advice().sync_sweep:
            flag_step = step               # control action: stop escalating
            break
    if flag_step is None or flag_step >= trip_step:
        raise SystemExit(
            f"[watch] drill FAILED: watch flagged at "
            f"{flag_step} vs sentinel trip at {trip_step}")
    out = resolver.resolve()
    preempted = list(resolver.report.preemptions)
    trips = [str(t) for t in watch_sent.trips]
    print(f"[watch] watched arm: α-drift flagged at patch {flag_step} "
          f"(α={alpha_norm(drv.host):.4f} < {wall}), "
          f"{trip_step - flag_step} patches ahead of the baseline trip")
    print(f"[watch] supervisor pre-empted: preemptions={preempted}, "
          f"escalation={out.escalation!r}, degraded={out.degraded}, "
          f"err_bound={out.psi_error_bound:.2e}, "
          f"sentinel trips in watched arm: {trips or 'none'}")
    if not preempted or trips:
        raise SystemExit("[watch] drill FAILED: expected a pre-emption "
                         "and zero sentinel trips in the watched arm")


def _serve_driver(args) -> None:
    """Driver-level ψ serving: the fault-tolerant chunk executors — the
    bulk-synchronous ``runtime/psi_driver.py`` or the bounded-staleness
    ``repro.asyncexec`` pipeline — followed by the shared query layer."""
    import jax

    from ..core import heterogeneous
    from ..graphs import powerlaw_configuration

    g = powerlaw_configuration(10_000, 70_000, seed=5)
    act = heterogeneous(g.n, seed=6)
    tol = 1e-7
    t0 = time.perf_counter()
    if args.executor == "async":
        from ..asyncexec import AsyncPsiDriver
        drv = AsyncPsiDriver(g, act, num_chunks=args.num_chunks,
                             tau=args.staleness_tau)
        rep = drv.run(tol=tol)
        print(f"[serve] executor=async chunks={args.num_chunks} "
              f"tau={args.staleness_tau}: {rep.iterations} epochs "
              f"gap={rep.gap:.2e} in {time.perf_counter() - t0:.2f}s; "
              f"max_staleness={rep.max_staleness} "
              f"overlap={rep.overlap_efficiency:.2f}x "
              f"verify_sweeps={rep.sync_sweeps}")
    else:
        from ..core.distributed import DistributedPsi
        from ..runtime import PsiDriver
        mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))
        drv = PsiDriver(DistributedPsi.from_graph(g, act, mesh),
                        chunk_iters=16)
        rep = drv.run(tol=tol)
        print(f"[serve] executor=sync chunk_iters=16: {rep.iterations} "
              f"iterations gap={rep.gap:.2e} in "
              f"{time.perf_counter() - t0:.2f}s")
    # straggler forensics: measured durations + the deadline that tripped
    if rep.chunk_durations:
        durs = np.asarray(rep.chunk_durations)
        print(f"[serve] {durs.size} chunk steps: median="
              f"{np.median(durs) * 1e3:.1f} ms max={durs.max() * 1e3:.1f} ms")
    for ev in rep.slow_chunk_events:
        print(f"[serve] slow chunk {ev.chunk}: {ev.duration * 1e3:.1f} ms "
              f"exceeded deadline {ev.deadline * 1e3:.1f} ms")
    if not rep.slow_chunk_events:
        print("[serve] no chunk exceeded its deadline")
    q = rep.queries()
    rng = np.random.default_rng(0)
    for r in range(args.requests):
        users = rng.integers(0, g.n, args.batch)
        t0 = time.perf_counter()
        scores = q.scores_batch(users)
        top, _ = q.top_k(args.top_k)
        print(f"[serve] req {r}: users={users.tolist()} "
              f"psi={np.round(scores, 8).tolist()} "
              f"top-{args.top_k}={top.tolist()} "
              f"({(time.perf_counter() - t0) * 1e3:.1f} ms)")


def _obs_epilogue(args) -> None:
    """When any obs flag was given: print the human summary the acceptance
    drill asks for (query p50/p99, events/s, cache hit ratio, gap
    trajectory, retraces, MTTR, SLO verdicts, top hotspots) and write the
    registry dump + trace file + folded-stacks profile."""
    if not (args.metrics_port or args.metrics_dump or args.trace_out
            or getattr(args, "slo", False) or getattr(args, "watch", False)
            or getattr(args, "profile_out", None)
            or getattr(args, "explain", False)
            or getattr(args, "explain_out", None)):
        return
    from .. import obs
    from ..obs import convergence as obs_convergence
    from ..obs import metrics as obs_metrics
    from ..obs import trace as obs_trace

    reg = obs_metrics.get_registry()

    def pooled(name):
        fam = reg.get(name)
        if fam is None or getattr(fam, "kind", "") != "histogram":
            return None
        m = fam.merged()
        return m if m.count else None

    def total(name):
        fam = reg.get(name)
        return (sum(ch.value for _, ch in fam.children())
                if fam is not None else 0.0)

    q = pooled("psi_query_seconds")
    if q is not None:
        print(f"[obs] query latency: p50={q.quantile(0.5) * 1e3:.2f} ms "
              f"p99={q.quantile(0.99) * 1e3:.2f} ms over {q.count} queries")
    evs = reg.value("psi_stream_ingest_events_per_s")
    if evs:
        print(f"[obs] stream ingest: {evs:.0f} ev/s "
              f"({int(total('psi_stream_events_total'))} events, "
              f"{int(total('psi_stream_resolves_total'))} resolves)")
    cache = reg.get("psi_query_cache_total")
    if cache is not None:
        tot = sum(ch.value for _, ch in cache.children())
        hits = reg.value("psi_query_cache_total", result="hit") or 0.0
        if tot:
            print(f"[obs] query cache: hit ratio {hits / tot:.1%} "
                  f"({int(hits)}/{int(tot)})")
    tracker = obs_convergence.get_tracker()
    for tenant in tracker.tenants():
        recs = tracker.series(tenant)
        if not recs:
            continue
        last = recs[-1]
        pts = sum(len(r.points) for r in recs)
        tag = "" if tenant == "_default" else f" tenant={tenant}"
        print(f"[obs] convergence{tag}: {len(recs)} resolves, "
              f"{pts} gap-trajectory points; last [{last.backend}] "
              f"{last.iterations} iters gap={last.gap:.2e}")
    retraces = total("psi_retraces_total")
    print(f"[obs] silent jit retraces: {int(retraces)}")
    mttr = pooled("psi_resilience_mttr_seconds")
    if mttr is not None:
        print(f"[obs] resilience: {mttr.count} recoveries, "
              f"mttr mean={mttr.sum / mttr.count * 1e3:.0f} ms "
              f"p99={mttr.quantile(0.99) * 1e3:.0f} ms; "
              f"{int(total('psi_resilience_degraded_served_total'))} "
              f"degraded answers")
    slo_engine = getattr(args, "_slo_engine", None)
    if slo_engine is not None:
        stop = getattr(args, "_slo_stop", None)
        if stop is not None:
            stop.set()                      # quiesce the background ticker
        slo_engine.tick()                   # one final synchronous sample
        for line in slo_engine.summary():
            print(f"[slo] {line}")
    watch = getattr(args, "_watch", None)
    if watch is not None:
        ws = watch.summary()
        print(f"[watch] {ws['signals']} anomaly signal(s): "
              f"{ws['by_kind'] or '{}'}")
    tracer = obs_trace.get_tracer()
    if getattr(tracer, "enabled", False) \
            and (getattr(args, "profile_out", None)
                 or getattr(args, "slo", False)):
        from ..obs.profile import Profile
        prof = Profile.from_tracer(tracer)
        if prof.records:
            print("[profile] top hotspots (self time):")
            for h in prof.hotspots(5):
                split = (f" dispatch={h['dispatch_s'] * 1e3:.1f}ms "
                         f"sync={h['sync_s'] * 1e3:.1f}ms"
                         if h["dispatch_s"] or h["sync_s"] else "")
                print(f"[profile]   {h['frame']}: "
                      f"self={h['self_s'] * 1e3:.1f}ms "
                      f"total={h['total_s'] * 1e3:.1f}ms "
                      f"x{h['count']}{split}")
            cp = prof.critical_path()
            if cp.steps:
                print(f"[profile] {cp.describe()}")
            if getattr(args, "profile_out", None):
                prof.write_folded(args.profile_out)
                print(f"[profile] folded stacks -> {args.profile_out}")
    if getattr(args, "explain", False) or getattr(args, "explain_out", None):
        from ..obs import calibrate as obs_calibrate
        svc = getattr(args, "_svc", None)
        if svc is None:
            print("[explain] no PsiService ran in this mode; "
                  "nothing to explain")
        else:
            tree = svc.explain()
            print(tree)
            if getattr(args, "explain_out", None):
                with open(args.explain_out, "w") as fh:
                    fh.write(tree + "\n")
                print(f"[explain] decision trail -> {args.explain_out}")
        if getattr(args, "calibration_out", None):
            obs_calibrate.get_store().save(args.calibration_out)
            print(f"[explain] calibration store -> {args.calibration_out}")
    if args.metrics_dump:
        obs.dump(args.metrics_dump)
        print(f"[obs] registry dump -> {args.metrics_dump}")
    if getattr(tracer, "enabled", False) and args.trace_out:
        tracer.flush()
        chrome = args.trace_out + ".chrome.json"
        tracer.export_chrome(chrome)
        print(f"[obs] trace -> {args.trace_out} "
              f"({len(tracer.spans)} spans retained, "
              f"{tracer.dropped} dropped); chrome view -> {chrome}")
    if args.metrics_port:
        print(f"[obs] /metrics, /metrics.json, /healthz and /slo still "
              f"live on port {args.metrics_port} until the process exits")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen-len", type=int, default=8)
    ap.add_argument("--backend", default=None,
                    help="ψ solver backend (see repro.core.engine): "
                         "reference | pallas | auto | accelerated | "
                         "distributed (default reference); with "
                         "--tenants > 1 a fleet regime: auto | dense | "
                         "reference | pallas (default auto)")
    ap.add_argument("--accelerate", action="store_true",
                    help="wrap the backend's step in the Aitken-"
                         "extrapolated loop (docs/AUTOTUNE.md)")
    ap.add_argument("--check-every", type=int, default=1,
                    help="evaluate the convergence gap every k-th "
                         "iteration (amortizes the O(N) reduction)")
    ap.add_argument("--microbench", action="store_true",
                    help="auto backend: time one step of every regime "
                         "candidate instead of trusting the cost model")
    ap.add_argument("--tenants", type=int, default=1,
                    help="psi-score only: serve K independent (graph, "
                         "activity) tenants from one TenantFleet "
                         "(docs/SERVING.md); 1 keeps the single-tenant "
                         "PsiService path")
    ap.add_argument("--bucket-sizes", default=None,
                    help="comma list of node-capacity rungs for the fleet "
                         "bucket policy, e.g. '512,2048,8192'")
    ap.add_argument("--executor", default=None, choices=("sync", "async"),
                    help="psi-score only: run the fault-tolerant chunk "
                         "driver instead of PsiService — sync (bulk-"
                         "synchronous runtime/psi_driver.py) or async "
                         "(bounded-staleness repro.asyncexec pipeline; "
                         "docs/ASYNC.md)")
    ap.add_argument("--staleness-tau", type=int, default=2,
                    help="async executor: max epoch lag a chunk may fall "
                         "behind (0 = barriered, i.e. sync semantics)")
    ap.add_argument("--num-chunks", type=int, default=4,
                    help="async executor: dst-row chunks in the pipeline")
    ap.add_argument("--stream", default=None,
                    choices=("poisson", "burst", "flash"),
                    help="psi-score only: replay a synthetic live event "
                         "log (posts/reposts/follows) through the "
                         "StreamIngestor → online λ/μ estimation → "
                         "continuously-fresh ψ (docs/STREAMING.md)")
    ap.add_argument("--stream-events", type=int, default=4_000,
                    help="approximate event count of the synthetic stream")
    ap.add_argument("--half-life", type=float, default=None,
                    help="estimator decay half-life in event-time seconds "
                         "(default: half the stream horizon)")
    ap.add_argument("--resolve-every", type=int, default=1_000,
                    help="freshness policy: re-resolve psi every N "
                         "ingested events (serve stale in between)")
    ap.add_argument("--top-k", type=int, default=3)
    ap.add_argument("--chaos", action="store_true",
                    help="psi-score only: run the seeded fault-injection "
                         "drill (crashes, torn checkpoints, poisoned "
                         "patches, corrupted event feeds) and print the "
                         "ResilienceReport (docs/RESILIENCE.md)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed of the FaultPlan the drill injects")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose the live metrics registry over HTTP "
                         "(/metrics Prometheus text, /metrics.json) on "
                         "this localhost port")
    ap.add_argument("--metrics-dump", default=None,
                    help="write one obs snapshot (environment fingerprint "
                         "+ metrics + convergence trajectories + recent "
                         "events) to this JSON path at exit")
    ap.add_argument("--trace-out", default=None,
                    help="record every pipeline span to this JSONL path "
                         "(+ a .chrome.json trace_event export at exit)")
    ap.add_argument("--slo", action="store_true",
                    help="judge the run against the default SLO catalog "
                         "(query p99, freshness, certified error, "
                         "degraded ratio): background burn-rate ticker, "
                         "verdict epilogue, /slo endpoint")
    ap.add_argument("--watch", action="store_true",
                    help="arm pre-emptive convergence anomaly detection "
                         "(repro.obs.watch); with --chaos also runs the "
                         "seeded α-drift pre-emption scenario")
    ap.add_argument("--profile-out", default=None,
                    help="write flamegraph folded stacks of the span "
                         "stream to this path (+ hotspot/critical-path "
                         "epilogue)")
    ap.add_argument("--explain", action="store_true",
                    help="psi paths: print the EXPLAIN-ANALYZE decision "
                         "trail for the last resolve — plan chosen, "
                         "alternatives rejected and why, predicted vs "
                         "measured cost, cache hits, staleness, certified "
                         "error (docs/AUTOTUNE.md)")
    ap.add_argument("--explain-out", default=None,
                    help="also write the explain tree to this text path "
                         "(implies --explain)")
    ap.add_argument("--calibration-out", default=None,
                    help="persist the cost-model calibration store "
                         "(per-regime correction factors) to this JSON "
                         "path at exit")
    args = ap.parse_args()
    if args.explain_out:
        args.explain = True

    if args.trace_out or args.metrics_port or args.profile_out:
        from .. import obs
        if args.trace_out:
            obs.configure(trace_out=args.trace_out)
        elif args.profile_out:
            # profiler needs retained spans; an in-memory tracer suffices
            obs.configure(tracer=obs.Tracer(None))
        if args.metrics_port:
            obs.start_http_server(args.metrics_port)
            print(f"[obs] metrics on "
                  f"http://127.0.0.1:{args.metrics_port}/metrics "
                  "(+ /metrics.json /healthz /slo)")
    args._slo_engine = None
    args._slo_stop = None
    args._watch = None
    if args.slo:
        import threading
        from ..obs.slo import DRILL_TIME_SCALE, SLOEngine, default_slos
        engine = SLOEngine(default_slos(), time_scale=DRILL_TIME_SCALE)
        engine.install()                     # /slo endpoint
        stop = threading.Event()

        def _ticker():
            while not stop.wait(0.05):
                engine.tick()

        threading.Thread(target=_ticker, name="slo-ticker",
                         daemon=True).start()
        args._slo_engine, args._slo_stop = engine, stop
        print("[slo] default catalog armed "
              f"(windows scaled x{DRILL_TIME_SCALE:g} to drill time)")
    if args.watch:
        from ..obs.watch import ConvergenceWatch
        args._watch = ConvergenceWatch()
        args._watch.attach()                 # digest every finished resolve
        print("[watch] convergence watch attached to the resolve stream")

    import jax
    import jax.numpy as jnp
    from ..configs import get_arch

    entry = get_arch(args.arch)
    mesh = jax.make_mesh((len(jax.devices()), 1), ("data", "model"))

    if entry.family == "psi" and (args.chaos or args.stream):
        # --stream X --chaos is the combined drill: streaming ingestion
        # and the fault ladder feed one registry, dumped once at the end
        if args.stream:
            _serve_stream(args)
        if args.chaos:
            _serve_chaos(args)
        if args.watch and args.chaos:
            _serve_watch(args)
        if args._slo_engine is not None:
            # multi-window burn alerts need sustained evidence: give the
            # ticker a moment to accumulate the slow window post-fault
            deadline = time.perf_counter() + 5.0
            while time.perf_counter() < deadline:
                if args._slo_engine.report()["alerts_total"] >= 1:
                    break
                time.sleep(0.1)
        _obs_epilogue(args)
        return

    if entry.family == "psi" and args.executor:
        _serve_driver(args)
        _obs_epilogue(args)
        return

    if entry.family == "psi" and args.tenants > 1:
        _serve_fleet(args)
        _obs_epilogue(args)
        return

    if entry.family == "psi":
        from ..graphs import powerlaw_configuration
        from ..core import heterogeneous, PsiService
        g = powerlaw_configuration(10_000, 70_000, seed=5)
        act = heterogeneous(g.n, seed=6)
        t0 = time.perf_counter()
        backend = args.backend or "reference"
        engine_opts = {"microbench": True} if (
            backend == "auto" and args.microbench) else None
        svc = PsiService(g, act, tol=1e-8, backend=backend,
                         accelerate=args.accelerate,
                         check_every=args.check_every,
                         engine_opts=engine_opts)
        args._svc = svc                      # for the --explain epilogue
        regime = getattr(svc.engine, "regime", None)
        print(f"[serve] backend={svc.backend}"
              + (f" regime={regime}" if regime else "")
              + (" accelerated" if args.accelerate else ""))
        svc.scores()
        print(f"[serve] backend={svc.backend} warm in "
              f"{time.perf_counter() - t0:.2f}s "
              f"({svc.last_iterations()} iterations)")
        top, vals = svc.top_k(args.top_k)
        print(f"[serve] top-{args.top_k}: {top.tolist()}")
        rng = np.random.default_rng(0)
        for r in range(args.requests):
            users = rng.integers(0, g.n, args.batch)
            t0 = time.perf_counter()
            ranks = svc.rank_of(users)        # cached order after req 0
            scores = svc.scores_batch(users)
            print(f"[serve] req {r}: users={users.tolist()} "
                  f"ranks={ranks.tolist()} "
                  f"psi={np.round(scores, 8).tolist()} "
                  f"({(time.perf_counter() - t0) * 1e3:.1f} ms)")
            if r == args.requests // 2:       # live update mid-traffic
                u = int(users[0])
                t0 = time.perf_counter()
                svc.update_activity(np.asarray([u]),
                                    lam=np.asarray([act.lam[u] * 20]))
                print(f"[serve] delta update user {u}: re-converged in "
                      f"{svc.last_iterations()} warm iterations "
                      f"({(time.perf_counter() - t0) * 1e3:.1f} ms)")
        _obs_epilogue(args)
        return

    if entry.family == "lm":
        from ..models.transformer import (init_params, make_prefill,
                                          make_decode_step)
        cfg = entry.config(reduced=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        prefill = jax.jit(make_prefill(cfg, mesh))
        decode = jax.jit(make_decode_step(cfg, mesh))
        rng = np.random.default_rng(1)
        for r in range(args.requests):
            prompt = jnp.asarray(rng.integers(0, cfg.vocab,
                                              (args.batch, 16)))
            t0 = time.perf_counter()
            cache, logits = prefill(params, prompt)
            toks = [jnp.argmax(logits, -1)]
            for _ in range(args.gen_len - 1):
                cache, logits = decode(params, cache, toks[-1])
                toks.append(jnp.argmax(logits, -1))
            out = np.stack([np.asarray(t) for t in toks], 1)
            print(f"[serve] req {r}: generated {out.shape} in "
                  f"{time.perf_counter() - t0:.2f}s; sample={out[0].tolist()}")
        return

    if entry.family == "recsys":
        from ..models.recsys import mind
        cfg = entry.config(reduced=True)
        params = mind.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        for r in range(args.requests):
            B = args.batch
            hist = jnp.asarray(rng.integers(0, cfg.n_items,
                                            (B, cfg.hist_len)))
            mask = jnp.asarray(rng.random((B, cfg.hist_len)) > 0.2)
            pids = jnp.asarray(rng.integers(0, cfg.n_profile, (B * 4,)))
            bags = jnp.asarray(np.repeat(np.arange(B), 4))
            t0 = time.perf_counter()
            u = mind.user_interests(params, hist, mask, pids, bags, cfg,
                                    mesh)
            cands = jnp.asarray(rng.integers(0, cfg.n_items, (1000,)))
            scores = mind.retrieval_scores(params, u[0], cands, cfg, mesh)
            top = np.asarray(jnp.argsort(-scores)[:5])
            print(f"[serve] req {r}: top-5 items {top.tolist()} "
                  f"({(time.perf_counter() - t0) * 1e3:.1f} ms)")
        return

    raise SystemExit("gnn archs are training workloads; use launch.train")


if __name__ == "__main__":
    main()
