"""Cell builders: (arch × shape × mesh) → jit-able fn + arg structs +
shardings + roofline metadata.

Every assigned architecture/shape pair becomes a ``Cell``; ``dryrun.py``
lowers & compiles it, and ``benchmarks/roofline.py`` combines the compiled
cost/memory analyses with the ``probe`` cells (layer-count L and L+1
variants) to get exact per-layer FLOPs — XLA's cost analysis does not
multiply while-loop bodies by trip count, so scan-based models need the
differential probe (measured: scan(10 matmuls) reports 1 matmul of FLOPs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.registry import ArchEntry, ShapeCfg
from ..models.transformer import model as lm
from ..models.gnn import sage, pna, nequip, equiformer_v2
from ..models.gnn.common import GraphBatch
from ..models.recsys import mind
from ..train import optim

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable
    args: tuple                        # pytree of ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any
    meta: dict                         # model_flops, multipliers, notes
    probes: list["Cell"] | None = None  # L / L+1 differential probes


def _ns(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _dp(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh):
    return int(np.prod([mesh.shape[a] for a in _dp(mesh)]))


def _batch_spec(mesh, batch, *trailing):
    dp = _dp(mesh)
    if batch % max(1, _dp_size(mesh)) == 0:
        return P(dp, *trailing)
    return P(None, *trailing)


# ===================================================================== #
# LM family
# ===================================================================== #
def _lm_param_structs(cfg):
    return jax.eval_shape(lambda k: lm.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def _opt_for(cfg):
    sched = optim.cosine_schedule(3e-4, 10_000, 200)
    if cfg.optimizer == "adafactor":
        return optim.adafactor(sched)
    return optim.adamw(sched)


def _opt_state_specs(cfg, pspecs, pstructs):
    """Optimizer state shardings mirroring the parameter shardings."""
    if cfg.optimizer == "adafactor":
        def stats_spec(spec, pstruct):
            nd = len(pstruct.shape)
            sp = list(spec) + [None] * (nd - len(spec))
            factored = (nd >= 2 and pstruct.shape[-1] >= 8
                        and pstruct.shape[-2] >= 8)
            if factored:
                return dict(vr=P(*sp[:-1]), vc=P(*(sp[:-2] + [sp[-1]])))
            return dict(v=P(*sp))

        stats = jax.tree.map(stats_spec, pspecs, pstructs,
                             is_leaf=lambda x: isinstance(x, P))
        return dict(step=P(), stats=stats)
    # adamw
    return dict(step=P(), m=pspecs, v=pspecs, master=pspecs)


def _effective_accum(cfg, mesh, batch):
    a = cfg.accum_steps
    dp = max(1, _dp_size(mesh))
    while a > 1 and (batch % a != 0 or (batch // a) % dp != 0):
        a //= 2
    return max(1, a)


def build_lm_cell(entry: ArchEntry, shape: ShapeCfg, mesh,
                  *, probe_layers: int | None = None) -> Cell:
    cfg = entry.config()
    p = shape.params
    if probe_layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=probe_layers, accum_steps=1,
                                  unroll_layers=True)
    batch = p["global_batch"]
    seq = p["seq_len"]
    dp = _dp(mesh)

    pstructs = _lm_param_structs(cfg)
    pspecs = lm.param_specs(cfg, mesh)
    pshard = _ns(mesh, pspecs)

    if shape.kind == "train":
        cfg = (cfg if probe_layers is not None else dataclasses.replace(
            cfg, accum_steps=_effective_accum(cfg, mesh, batch)))
        if probe_layers is not None:
            batch = max(_dp_size(mesh), batch // max(
                1, _effective_accum(entry.config(), mesh, batch)))
        opt = _opt_for(cfg)
        ostructs = jax.eval_shape(opt.init, pstructs)
        ospecs = _opt_state_specs(cfg, pspecs, pstructs)
        oshard = _ns(mesh, ospecs)
        bspec = dict(tokens=_batch_spec(mesh, batch, None),
                     labels=_batch_spec(mesh, batch, None))
        bstructs = dict(tokens=SDS((batch, seq), jnp.int32),
                        labels=SDS((batch, seq), jnp.int32))
        fn = lm.make_train_step(cfg, mesh, opt)
        tokens = batch * seq
        eff_ctx = min(seq, cfg.sliding_window or seq)
        attn_flops = 6 * tokens * eff_ctx * cfg.q_dim   # fwd 2·T·ctx·d, ×3 bwd
        meta = dict(kind="train",
                    model_flops=6 * lm.active_params(cfg) * tokens
                    + attn_flops,
                    layers=cfg.n_layers, accum=cfg.accum_steps,
                    tokens=tokens, params=lm.count_params(cfg))
        return Cell(entry.arch_id, shape.name, fn,
                    (pstructs, ostructs, bstructs),
                    (pshard, oshard, _ns(mesh, bspec)),
                    (pshard, oshard, None), meta)

    if shape.kind == "prefill":
        fn = lm.make_prefill(cfg, mesh)
        bstructs = SDS((batch, seq), jnp.int32)
        bspec = _batch_spec(mesh, batch, None)
        c = min(seq, cfg.sliding_window or seq)
        cache_spec = dict(
            k=P(None, *_batch_spec(mesh, batch, None, None, None)),
            v=P(None, *_batch_spec(mesh, batch, None, None, None)),
            pos=_batch_spec(mesh, batch, None), t=P())
        eff_ctx = min(seq, cfg.sliding_window or seq)
        meta = dict(kind="prefill",
                    model_flops=2 * lm.active_params(cfg) * batch * seq
                    + 2 * batch * seq * eff_ctx * cfg.q_dim,
                    layers=cfg.n_layers, tokens=batch * seq,
                    params=lm.count_params(cfg))
        return Cell(entry.arch_id, shape.name, fn, (pstructs, bstructs),
                    (pshard, NamedSharding(mesh, bspec)),
                    (_ns(mesh, cache_spec), NamedSharding(
                        mesh, _batch_spec(mesh, batch, None))), meta)

    # decode
    fn = lm.make_decode_step(cfg, mesh)
    c = min(seq, cfg.sliding_window or seq)
    cache_structs = dict(
        k=SDS((cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.head_dim),
              cfg.dtype),
        v=SDS((cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.head_dim),
              cfg.dtype),
        pos=SDS((batch, c), jnp.int32),
        t=SDS((), jnp.int32))
    cache_spec = dict(
        k=P(None, *_batch_spec(mesh, batch, None, None, None)),
        v=P(None, *_batch_spec(mesh, batch, None, None, None)),
        pos=_batch_spec(mesh, batch, None), t=P())
    tok_structs = SDS((batch,), jnp.int32)
    meta = dict(kind="decode",
                model_flops=2 * lm.active_params(cfg) * batch
                + 2 * 2 * cfg.n_layers * batch * c * cfg.kv_dim,
                layers=cfg.n_layers, tokens=batch, cache_len=c,
                params=lm.count_params(cfg))
    return Cell(entry.arch_id, shape.name, fn,
                (pstructs, cache_structs, tok_structs),
                (pshard, _ns(mesh, cache_spec),
                 NamedSharding(mesh, _batch_spec(mesh, batch))),
                (_ns(mesh, cache_spec), NamedSharding(
                    mesh, _batch_spec(mesh, batch, None))), meta)


# ===================================================================== #
# GNN family
# ===================================================================== #
_GNN_MODS = {"pna": pna, "graphsage-reddit": sage, "nequip": nequip,
             "equiformer-v2": equiformer_v2}
_GEOMETRIC = {"nequip", "equiformer-v2"}


def _pad_to(x: int, mult: int = 2048) -> int:
    return -(-x // mult) * mult


def _gnn_shape_dims(shape: ShapeCfg) -> dict:
    """Static padded dims; padding uses sentinel edges / masked nodes."""
    p = shape.params
    if shape.kind == "full_graph":
        n, e = _pad_to(p["n_nodes"]), _pad_to(2 * p["n_edges"])
        return dict(n=n, e=e, d_feat=p["d_feat"],
                    n_classes=47 if n > 10 ** 6 else 7,
                    n_graphs=1, kind="node_class")
    if shape.kind == "minibatch":
        from ..graphs.sampler import subgraph_budget
        n, e = subgraph_budget(p["batch_nodes"], p["fanout"])
        return dict(n=_pad_to(n), e=_pad_to(e), d_feat=602, n_classes=41,
                    n_graphs=1, kind="node_class")
    # molecule
    n = _pad_to(p["n_nodes"] * p["batch"])
    e = _pad_to(2 * p["n_edges"] * p["batch"])
    return dict(n=n, e=e, d_feat=16, n_classes=1, n_graphs=p["batch"],
                kind="graph")


def _gnn_cfg_for(entry, dims):
    cfg = entry.config()
    kw = dict(d_feat=dims["d_feat"])
    if entry.arch_id in ("pna", "graphsage-reddit"):
        kw["n_classes"] = dims["n_classes"]
    else:
        kw["out_kind"] = dims["kind"]
        kw["n_classes"] = dims["n_classes"] if dims["kind"] != "graph" else 1
    if entry.arch_id in ("pna", "graphsage-reddit"):
        kw["out_kind"] = "graph" if dims["kind"] == "graph" else "node"
        kw["n_classes"] = dims["n_classes"]
    return dataclasses.replace(cfg, **kw)


def build_gnn_cell(entry: ArchEntry, shape: ShapeCfg, mesh) -> Cell:
    dims = _gnn_shape_dims(shape)
    mod = _GNN_MODS[entry.arch_id]
    cfg = _gnn_cfg_for(entry, dims)
    n, e = dims["n"], dims["e"]
    geometric = entry.arch_id in _GEOMETRIC
    dp = _dp(mesh)

    if dims["kind"] == "graph":
        labels = SDS((dims["n_graphs"],), jnp.float32)
        label_spec = P(None)
    else:
        labels = SDS((n,), jnp.int32)
        label_spec = P(dp) if n % _dp_size(mesh) == 0 else P(None)

    node_sp = P(dp) if n % _dp_size(mesh) == 0 else P(None)
    edge_sp = P(dp) if e % _dp_size(mesh) == 0 else P(None)
    batch_structs = GraphBatch(
        n=n,
        x=SDS((n, dims["d_feat"]), jnp.float32),
        src=SDS((e,), jnp.int32), dst=SDS((e,), jnp.int32),
        pos=SDS((n, 3), jnp.float32) if geometric else None,
        node_mask=SDS((n,), jnp.bool_),
        graph_ids=SDS((n,), jnp.int32) if dims["n_graphs"] > 1 else None,
        n_graphs=dims["n_graphs"],
        labels=labels,
        seed_mask=SDS((n,), jnp.bool_) if shape.kind == "minibatch" else None)
    batch_spec = GraphBatch(
        n=n,
        x=P(*node_sp, None), src=edge_sp, dst=edge_sp,
        pos=P(*node_sp, None) if geometric else None,
        node_mask=node_sp,
        graph_ids=node_sp if dims["n_graphs"] > 1 else None,
        n_graphs=dims["n_graphs"], labels=label_spec,
        seed_mask=node_sp if shape.kind == "minibatch" else None)

    pstructs = jax.eval_shape(lambda k: mod.init_params(cfg, k),
                              jax.random.PRNGKey(0))
    pspecs = jax.tree.map(lambda _: P(), pstructs)     # replicated params
    opt = optim.adamw(optim.cosine_schedule(1e-3, 10_000, 100))
    ostructs = jax.eval_shape(opt.init, pstructs)
    ospecs = jax.tree.map(lambda _: P(), ostructs)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(mod.loss_fn)(params, batch, cfg)
        params, opt_state = opt.apply(grads, opt_state, params)
        return params, opt_state, loss

    # analytic model flops (dominant message/feature matmuls, fwd+bwd ~3x)
    model_flops = _gnn_model_flops(entry.arch_id, cfg, n, e)
    meta = dict(kind="gnn_train", model_flops=model_flops, nodes=n, edges=e,
                layers=cfg.n_layers)
    return Cell(entry.arch_id, shape.name, train_step,
                (pstructs, ostructs, batch_structs),
                (_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, batch_spec)),
                (_ns(mesh, pspecs), _ns(mesh, ospecs), None), meta)


def _gnn_model_flops(arch, cfg, n, e):
    L = cfg.n_layers
    if arch == "graphsage-reddit":
        h = cfg.d_hidden
        per = 2 * n * (cfg.d_feat * h + h * h)
        return 3 * L * (per + e * h)
    if arch == "pna":
        h = cfg.d_hidden
        return 3 * L * (2 * n * (13 * h) * h + 4 * e * h)
    if arch == "nequip":
        C = cfg.d_hidden
        n_paths = len(nequip.paths_for(cfg.l_max))
        per_edge = n_paths * (2 * cfg.l_max + 1) ** 2 * C * 2
        return 3 * L * e * per_edge
    # equiformer-v2
    C = cfg.d_hidden
    lm_, mm = cfg.l_max, cfg.m_max
    n0 = lm_ + 1
    so2 = 2 * ((n0 * C) ** 2 + 2 * sum(
        ((lm_ - m + 1) * C) ** 2 * 2 for m in range(1, mm + 1)))
    wigner = sum(2 * (2 * l + 1) ** 2 * C for l in range(lm_ + 1))
    return 3 * cfg.n_layers * e * (so2 + 2 * wigner)


# ===================================================================== #
# RecSys family
# ===================================================================== #
def build_recsys_cell(entry: ArchEntry, shape: ShapeCfg, mesh) -> Cell:
    cfg = entry.config()
    p = shape.params
    dp = _dp(mesh)
    pstructs = jax.eval_shape(lambda k: mind.init_params(cfg, k),
                              jax.random.PRNGKey(0))
    pspecs = mind.param_specs(cfg, mesh)
    pshard = _ns(mesh, pspecs)
    d = cfg.embed_dim

    if shape.kind == "train":
        b = p["batch"]
        opt = optim.adamw(optim.cosine_schedule(1e-3, 10_000, 100))
        ostructs = jax.eval_shape(opt.init, pstructs)
        ospecs = dict(step=P(), m=pspecs, v=pspecs, master=pspecs)
        bstructs = dict(
            hist_ids=SDS((b, cfg.hist_len), jnp.int32),
            hist_mask=SDS((b, cfg.hist_len), jnp.bool_),
            profile_ids=SDS((b * cfg.profile_tags,), jnp.int32),
            profile_bags=SDS((b * cfg.profile_tags,), jnp.int32),
            pos_ids=SDS((b,), jnp.int32),
            neg_ids=SDS((b, cfg.n_neg), jnp.int32))
        bspec = dict(hist_ids=_batch_spec(mesh, b, None),
                     hist_mask=_batch_spec(mesh, b, None),
                     profile_ids=_batch_spec(mesh, b * cfg.profile_tags),
                     profile_bags=_batch_spec(mesh, b * cfg.profile_tags),
                     pos_ids=_batch_spec(mesh, b),
                     neg_ids=_batch_spec(mesh, b, None))

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(mind.train_loss)(
                params, batch, cfg, mesh)
            params, opt_state = opt.apply(grads, opt_state, params)
            return params, opt_state, loss

        lookups = b * (cfg.hist_len + 1 + cfg.n_neg + cfg.profile_tags)
        flops = 3 * (b * (2 * cfg.hist_len * d * d * (cfg.capsule_iters + 1)
                          + cfg.n_neg * d) + lookups * d)
        meta = dict(kind="train", model_flops=flops, lookups=lookups,
                    batch=b)
        return Cell(entry.arch_id, shape.name, train_step,
                    (pstructs, ostructs, bstructs),
                    (pshard, _ns(mesh, ospecs), _ns(mesh, bspec)),
                    (pshard, _ns(mesh, ospecs), None), meta)

    if shape.kind == "serve":
        b = p["batch"]
        bstructs = (SDS((b, cfg.hist_len), jnp.int32),
                    SDS((b, cfg.hist_len), jnp.bool_),
                    SDS((b * cfg.profile_tags,), jnp.int32),
                    SDS((b * cfg.profile_tags,), jnp.int32))
        bspec = (_batch_spec(mesh, b, None), _batch_spec(mesh, b, None),
                 _batch_spec(mesh, b * cfg.profile_tags),
                 _batch_spec(mesh, b * cfg.profile_tags))

        def serve(params, hist, mask, pids, pbags):
            return mind.user_interests(params, hist, mask, pids, pbags,
                                       cfg, mesh)

        flops = b * 2 * cfg.hist_len * d * d * (cfg.capsule_iters + 1)
        meta = dict(kind="serve", model_flops=flops, batch=b)
        return Cell(entry.arch_id, shape.name, serve,
                    (pstructs, *bstructs),
                    (pshard, *(NamedSharding(mesh, s) for s in bspec)),
                    NamedSharding(mesh, _batch_spec(mesh, b, None, None)),
                    meta)

    # retrieval: 1 user × n_candidates
    nc = p["n_candidates"]
    inter = SDS((cfg.n_interests, d), jnp.float32)
    cands = SDS((nc,), jnp.int32)

    def retrieve(params, interests, cand_ids):
        return mind.retrieval_scores(params, interests, cand_ids, cfg, mesh)

    meta = dict(kind="retrieval",
                model_flops=2 * nc * d * cfg.n_interests, candidates=nc)
    return Cell(entry.arch_id, shape.name, retrieve,
                (pstructs, inter, cands),
                (pshard, NamedSharding(mesh, P(None, None)),
                 NamedSharding(mesh, P(None))),
                NamedSharding(mesh, P(None)), meta)


# ===================================================================== #
# psi family (the paper itself)
# ===================================================================== #
def build_psi_cell(entry: ArchEntry, shape: ShapeCfg, mesh,
                   *, probe_iters: int | None = None) -> Cell:
    from ..core.distributed import DistributedPsi
    from ..graphs.partition import Partition2D
    cfg = entry.config()
    name = shape.params["dataset"]
    n, m = _psi_graph_dims(name)
    axes = mesh.axis_names
    d = int(np.prod([mesh.shape[a] for a in axes[:-1]]))
    mo = mesh.shape["model"]
    q = -(-n // (d * mo))
    e_max = int(np.ceil(m / (d * mo) * 2.0 / 128)) * 128 + 128
    placeholder = np.broadcast_to(np.zeros((1,), np.int32),
                                  (d, mo, e_max))      # no allocation
    part = Partition2D(
        n=n, n_pad=d * mo * q, d=d, mo=mo, q=q,
        src_local=placeholder, dst_local=placeholder,
        e_counts=np.zeros((d, mo), np.int64))
    dist = DistributedPsi(part, mesh)
    run = dist.make_run(chunk_iters=probe_iters or cfg.chunk_iters,
                        unroll=probe_iters is not None)

    sd = jax.ShapeDtypeStruct
    specs = dict(
        src_local=sd((d, mo, e_max), jnp.int32),
        dst_local=sd((d, mo, e_max), jnp.int32),
        inv_w_src=sd((d, mo * q), jnp.float32),
        mu_piece=sd((d, mo, q), jnp.float32),
        c_piece=sd((d, mo, q), jnp.float32),
        c_src=sd((d, mo * q), jnp.float32),
        lam_piece=sd((d, mo, q), jnp.float32),
        d_piece=sd((d, mo, q), jnp.float32))
    from ..core.distributed import DistPsiArrays
    arr_structs = DistPsiArrays(**specs)
    shardings = dist.shardings()
    arr_shard = DistPsiArrays(**shardings)
    s_struct = sd((d, mo * q), jnp.float32)
    s_shard = shardings["c_src"]

    def fn(s, arrays):
        return run(s, arrays)

    iters = probe_iters or cfg.chunk_iters
    meta = dict(kind="psi_iterate", nodes=n, edges=m, iters=iters,
                model_flops=iters * 3 * m)     # gather·mul + scatter-add per edge
    return Cell(entry.arch_id, shape.name, fn, (s_struct, arr_structs),
                (s_shard, arr_shard), (s_shard, None), meta)


def _psi_graph_dims(name: str) -> tuple[int, int]:
    from ..graphs.datasets import DATASETS
    if name.startswith("rmat"):
        scale = int(name.removeprefix("rmat"))
        return (1 << scale), (1 << scale) * 16
    n, m, *_ = DATASETS[name]
    return n, m


# ===================================================================== #
# Dispatcher
# ===================================================================== #
def build_cell(entry: ArchEntry, shape: ShapeCfg, mesh) -> Cell:
    if entry.family == "lm":
        cell = build_lm_cell(entry, shape, mesh)
        cell.probes = [build_lm_cell(entry, shape, mesh, probe_layers=1),
                       build_lm_cell(entry, shape, mesh, probe_layers=2)]
        return cell
    if entry.family == "gnn":
        return build_gnn_cell(entry, shape, mesh)
    if entry.family == "recsys":
        return build_recsys_cell(entry, shape, mesh)
    if entry.family == "psi":
        cell = build_psi_cell(entry, shape, mesh)
        cell.probes = [build_psi_cell(entry, shape, mesh, probe_iters=1),
                       build_psi_cell(entry, shape, mesh, probe_iters=2)]
        return cell
    raise ValueError(entry.family)
