"""Production training launcher: ``--arch <id>`` → sharded train loop.

On this CPU container it runs reduced configs end-to-end (the full configs
are exercised via dryrun.py); on a real slice the same entrypoint binds the
production mesh, per-host data sharding, checkpoint/restart and the
straggler monitor.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 20
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config (default on this container)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--mesh", default="host", choices=["host"],
                    help="'host': all local devices as (data, model)=(n,1)")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from ..configs import get_arch
    from ..ckpt import checkpoint
    from ..data import TokenPipeline
    from ..train import adamw, adafactor, cosine_schedule

    entry = get_arch(args.arch)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev, 1), ("data", "model"))

    if entry.family == "lm":
        from ..models.transformer import (init_params, make_train_step,
                                          param_specs)
        cfg = entry.config(reduced=args.reduced or True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = (adafactor if cfg.optimizer == "adafactor" else adamw)(
            cosine_schedule(3e-3, args.steps, max(1, args.steps // 10)))
        state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, mesh, opt))
        pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                             global_batch=args.batch, seed=0)
        start = 0
        if args.resume and args.ckpt_dir and \
                checkpoint.latest_step(args.ckpt_dir) is not None:
            start = checkpoint.latest_step(args.ckpt_dir)
            data = checkpoint.restore(args.ckpt_dir, start,
                                      dict(p=params, o=state))
            params, state = data["p"], data["o"]
            print(f"[train] resumed at step {start}")
        durations = []
        for step in range(start, args.steps):
            b = pipe.batch(step)
            t0 = time.perf_counter()
            params, state, loss = step_fn(
                params, state, dict(tokens=jnp.asarray(b["tokens"]),
                                    labels=jnp.asarray(b["labels"])))
            loss = float(loss)
            dt = time.perf_counter() - t0
            if durations and dt > 3.0 * float(np.median(durations)):
                print(f"[train] straggler flag at step {step}: "
                      f"{dt:.2f}s vs median {np.median(durations):.2f}s")
            durations.append(dt)
            print(f"[train] step {step} loss {loss:.4f} ({dt:.2f}s)")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                checkpoint.save(args.ckpt_dir, step + 1,
                                dict(p=params, o=state))
        return

    if entry.family == "gnn":
        from ..launch.specs import _GNN_MODS
        from ..graphs import erdos_renyi
        from ..models.gnn.common import batch_from_graph
        mod = _GNN_MODS[entry.arch_id]
        cfg = entry.config(reduced=True)
        rng = np.random.default_rng(0)
        g = erdos_renyi(200, 1200, seed=1)
        geometric = entry.arch_id in ("nequip", "equiformer-v2")
        out_kind = getattr(cfg, "out_kind", "node")
        labels = (np.zeros(1, np.float32) if out_kind == "graph"
                  else rng.integers(0, cfg.n_classes, g.n))
        batch = batch_from_graph(
            g, rng.normal(size=(g.n, cfg.d_feat)).astype(np.float32),
            labels=labels,
            pos=rng.normal(size=(g.n, 3)).astype(np.float32)
            if geometric else None)
        params = mod.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw(cosine_schedule(3e-3, args.steps, 2))
        state = opt.init(params)

        @jax.jit
        def step_fn(p, st, b):
            loss, grads = jax.value_and_grad(mod.loss_fn)(p, b, cfg)
            p, st = opt.apply(grads, st, p)
            return p, st, loss

        for step in range(args.steps):
            params, state, loss = step_fn(params, state, batch)
            print(f"[train] step {step} loss {float(loss):.4f}")
        return

    if entry.family == "recsys":
        from ..models.recsys import mind
        cfg = entry.config(reduced=True)
        rng = np.random.default_rng(0)
        B = args.batch
        batch = dict(
            hist_ids=jnp.asarray(rng.integers(0, cfg.n_items,
                                              (B, cfg.hist_len))),
            hist_mask=jnp.asarray(rng.random((B, cfg.hist_len)) > 0.2),
            profile_ids=jnp.asarray(rng.integers(0, cfg.n_profile, (B * 4,))),
            profile_bags=jnp.asarray(np.repeat(np.arange(B), 4)),
            pos_ids=jnp.asarray(rng.integers(0, cfg.n_items, (B,))),
            neg_ids=jnp.asarray(rng.integers(0, cfg.n_items,
                                             (B, cfg.n_neg))))
        params = mind.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw(cosine_schedule(1e-2, args.steps, 2))
        state = opt.init(params)

        @jax.jit
        def step_fn(p, st, b):
            loss, grads = jax.value_and_grad(mind.train_loss)(p, b, cfg,
                                                              mesh)
            p, st = opt.apply(grads, st, p)
            return p, st, loss

        for step in range(args.steps):
            params, state, loss = step_fn(params, state, batch)
            print(f"[train] step {step} loss {float(loss):.4f}")
        return

    raise SystemExit(f"--arch {args.arch}: use runtime.PsiDriver / "
                     "examples/distributed_dryrun_demo.py for the psi family")


if __name__ == "__main__":
    main()
