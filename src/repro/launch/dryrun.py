import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init); everything below may import jax freely.

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes and dump memory/cost/collective artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k --mesh both --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all

Per cell the artifact JSON records:
  * ok / error, compile seconds,
  * cost_analysis flops & bytes (plus L and L+1 probe values → per-layer
    deltas for the scan-aware roofline),
  * memory_analysis per-device bytes (argument/output/temp/peak),
  * per-collective-type byte counts parsed from the post-SPMD HLO, split
    by whether the op sits inside a while body (→ multiplied by the
    config's trip count in the roofline).
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import numpy as np

from ..configs.registry import ARCHS, get_arch
from .mesh import make_production_mesh
from .specs import Cell, build_cell

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_BODY_RE = re.compile(r"body=%?([\w.\-]+)")


def parse_collectives(hlo_text: str) -> dict:
    """Per-type {top: bytes, in_while: bytes, count} from post-SPMD HLO.

    Two passes: (1) collect while-body computation names from ``body=``
    attributes; (2) attribute each collective's result bytes to top-level
    or while-body scope. (While bodies execute trip-count times; the
    roofline uses unrolled probes for exact per-iteration numbers and this
    split as the cross-check.)
    """
    body_names = set(_BODY_RE.findall(hlo_text))
    out = {c: dict(top=0, in_while=0, count=0) for c in _COLLECTIVES}
    computation = ""
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if (stripped.startswith("%") or stripped.startswith("ENTRY")) \
                and stripped.endswith("{"):
            name = stripped.split()[0].lstrip("%")
            if stripped.startswith("ENTRY"):
                name = stripped.split()[1].lstrip("%") \
                    if len(stripped.split()) > 1 else "entry"
            computation = name.split("(")[0]
            continue
        for coll in _COLLECTIVES:
            if f" {coll}(" in stripped or f"{coll}-start(" in stripped:
                lhs = stripped.split(f" {coll}")[0]
                b = _shape_bytes(lhs)
                key = ("in_while" if computation in body_names else "top")
                out[coll][key] += b
                out[coll]["count"] += 1
                break
    return out


def run_cell(cell: Cell, mesh, mesh_name: str, *, with_probes: bool = True,
             print_analysis: bool = False) -> dict:
    rec = dict(arch=cell.arch, shape=cell.shape, mesh=mesh_name,
               meta={k: v for k, v in cell.meta.items()}, ok=False)
    try:
        t0 = time.time()
        jfn = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                      out_shardings=cell.out_shardings)
        lowered = jfn.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec.update(lower_s=round(t_lower, 2), compile_s=round(t_compile, 2))

        ca = compiled.cost_analysis() or {}
        rec["cost"] = dict(flops=float(ca.get("flops", 0.0)),
                           bytes_accessed=float(ca.get("bytes accessed",
                                                       0.0)))
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = dict(
                argument_bytes=int(ma.argument_size_in_bytes),
                output_bytes=int(ma.output_size_in_bytes),
                temp_bytes=int(ma.temp_size_in_bytes),
                peak_bytes=int(ma.peak_memory_in_bytes),
                generated_code_bytes=int(ma.generated_code_size_in_bytes))
            if print_analysis:
                print(f"  memory_analysis: peak={ma.peak_memory_in_bytes:,}"
                      f" args={ma.argument_size_in_bytes:,}"
                      f" temp={ma.temp_size_in_bytes:,}")
        except Exception as e:  # pragma: no cover
            rec["memory_error"] = repr(e)
        hlo = compiled.as_text()
        rec["collectives"] = parse_collectives(hlo)
        rec["hlo_bytes"] = len(hlo)
        if print_analysis:
            print(f"  cost_analysis: flops={rec['cost']['flops']:.3e} "
                  f"bytes={rec['cost']['bytes_accessed']:.3e}")
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
        return rec

    if with_probes and cell.probes:
        rec["probes"] = []
        for pc in cell.probes:
            pr = run_cell(pc, mesh, mesh_name, with_probes=False)
            rec["probes"].append(dict(
                layers=pc.meta.get("layers", pc.meta.get("iters")),
                ok=pr["ok"], cost=pr.get("cost"),
                collectives=pr.get("collectives"),
                error=pr.get("error")))
    return rec


def iter_cells(arch_ids, shape_filter=None):
    for arch_id in arch_ids:
        entry = get_arch(arch_id)
        for shape in entry.shapes:
            if shape_filter and shape.name != shape_filter:
                continue
            yield entry, shape


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    arch_ids = [args.arch] if args.arch else sorted(ARCHS)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("pod2x16x16", make_production_mesh(multi_pod=True)))

    os.makedirs(args.out, exist_ok=True)
    n_ok = n_fail = n_skip = 0
    for entry, shape in iter_cells(arch_ids, args.shape):
        for mesh_name, mesh in meshes:
            tag = f"{entry.arch_id}__{shape.name}__{mesh_name}"
            path = os.path.join(args.out, tag + ".json")
            if shape.skip:
                rec = dict(arch=entry.arch_id, shape=shape.name,
                           mesh=mesh_name, skipped=shape.skip, ok=True)
                n_skip += 1
            else:
                print(f"[dryrun] {tag}", flush=True)
                try:
                    cell = build_cell(entry, shape, mesh)
                except Exception as e:
                    rec = dict(arch=entry.arch_id, shape=shape.name,
                               mesh=mesh_name, ok=False,
                               error=f"build: {type(e).__name__}: {e}",
                               traceback=traceback.format_exc()[-3000:])
                    n_fail += 1
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"  BUILD FAIL: {rec['error']}")
                    continue
                rec = run_cell(cell, mesh, mesh_name,
                               with_probes=not args.no_probes,
                               print_analysis=True)
                if rec["ok"]:
                    n_ok += 1
                    print(f"  ok ({rec.get('compile_s', 0):.1f}s compile)")
                else:
                    n_fail += 1
                    print(f"  FAIL: {rec['error']}")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
