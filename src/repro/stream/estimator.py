"""Online λ/μ estimation from event timestamps.

One exponentially-decayed counter per (user, rate): with decay constant
``α = ln 2 / half_life``, the decayed count of a user's posts at time t is

    N̂(t) = Σ_{events i ≤ t} exp(−α · (t − t_i))

maintained lazily — one multiply-add per event, O(1) per event per user.
For a stationary Poisson clock of rate λ the expectation is exactly

    E[N̂(t)] = λ · W(t),   W(t) = (1 − e^{−α (t − t₀)}) / α,

so the *bias-corrected* estimator  λ̂(t) = N̂(t) / W(t)  is unbiased for
every t > t₀ (not just after a burn-in): at small t it degrades gracefully
to the windowed MLE count/elapsed, and as t → ∞ it becomes the classic
EWMA rate α·N̂ with relative standard deviation √(α / 2λ). Replaying a
stream generated from ground-truth rates therefore *converges to those
rates* — ``activity.heterogeneous`` / ``homogeneous`` are fixed points of
generate → estimate, which is exactly what the parity tests assert. Pick
``half_life`` ≫ 1/λ for tight stationary estimates, or short to track
bursts (docs/STREAMING.md quantifies the trade-off).

Cold start: a user with no observed events has N̂ = 0; the estimate is
clamped to :data:`~repro.core.activity.RATE_FLOOR` (both rates), keeping
λ+μ strictly positive so the ψ iteration's c = μ/(λ+μ) normalization never
degenerates (see ``Activity.floored``).

Dirty-set tracking: the estimator remembers which users saw events since
the last :meth:`drain` and what rates the serving target currently holds
(``synced``). ``drain`` returns exactly the (users, λ̂, μ̂, mass) delta the
ingestor turns into one batched O(Δ) ``update_activity`` patch;
:meth:`pending_mass` is the l1 distance between estimated and synced rates
over the dirty set — the freshness policy's resolve trigger.
"""
from __future__ import annotations

import math

import numpy as np

from ..core.activity import RATE_FLOOR, Activity
from .events import Post, Repost

__all__ = ["RateEstimator"]


class RateEstimator:
    """Per-user decayed-count λ/μ estimator with dirty-set tracking.

    Args:
      n: number of users (fixed; events must reference ids < n).
      half_life: decay half-life in event-time units. ``inf`` is allowed
        and yields the pure count/elapsed MLE (no forgetting).
      floor: strictly-positive clamp for cold-start / silent users.
      t0: event-time origin of the stream.
    """

    def __init__(self, n: int, *, half_life: float = 64.0,
                 floor: float = RATE_FLOOR, t0: float = 0.0):
        if half_life <= 0:
            raise ValueError(f"half_life must be > 0; got {half_life}")
        if floor <= 0:
            raise ValueError(f"floor must be > 0; got {floor}")
        self.n = int(n)
        self.half_life = float(half_life)
        self.alpha = math.log(2.0) / half_life   # 0.0 when half_life = inf
        self.floor = float(floor)
        self.t0 = float(t0)
        self.t = float(t0)                       # latest event time seen
        self.events = 0
        # row 0: posts (λ), row 1: reposts (μ); decayed to self._last[u]
        self._cnt = np.zeros((2, self.n))
        self._last = np.full(self.n, float(t0))
        self._touched = np.zeros(self.n, bool)
        # what the serving target currently holds (floored layout)
        self._synced = np.full((2, self.n), self.floor)

    # -- ingest ---------------------------------------------------------- #
    def observe(self, event) -> None:
        """Count one :class:`Post` / :class:`Repost` clock tick."""
        if isinstance(event, Post):
            self._tick(0, event.t, event.user)
        elif isinstance(event, Repost):
            self._tick(1, event.t, event.user)
        else:
            raise TypeError(f"RateEstimator counts Post/Repost events; "
                            f"got {type(event).__name__}")

    def observe_post(self, t: float, user: int) -> None:
        self._tick(0, t, user)

    def observe_repost(self, t: float, user: int) -> None:
        self._tick(1, t, user)

    def _tick(self, kind: int, t: float, user: int) -> None:
        if not 0 <= user < self.n:
            raise ValueError(f"user {user} out of range [0, {self.n})")
        if not math.isfinite(t):
            # a NaN timestamp would poison _last/_cnt and from there every
            # drained rate — reject at the boundary, state untouched
            raise ValueError(f"non-finite event timestamp {t!r} "
                             f"for user {user}")
        dt = t - self._last[user]
        if dt < 0:                   # same-window jitter: clamp, don't grow
            dt = 0.0
        if self.alpha:
            self._cnt[:, user] *= math.exp(-self.alpha * dt)
        self._cnt[kind, user] += 1.0
        self._last[user] = max(self._last[user], t)
        self.t = max(self.t, t)
        self._touched[user] = True
        self.events += 1

    # -- estimates ------------------------------------------------------- #
    def _normalizer(self, t: float) -> float:
        """W(t) = (1 − e^{−α(t−t₀)})/α — the unbiasedness denominator."""
        elapsed = max(0.0, t - self.t0)
        if self.alpha == 0.0:
            return elapsed
        return -math.expm1(-self.alpha * elapsed) / self.alpha

    def _rates_at(self, t: float, users: np.ndarray) -> np.ndarray:
        """f64[2, |users|] floored (λ̂, μ̂) at query time ``t``."""
        w = self._normalizer(t)
        if w <= 0.0:
            return np.full((2, users.shape[0]), self.floor)
        decay = (np.exp(-self.alpha * np.maximum(0.0, t - self._last[users]))
                 if self.alpha else 1.0)
        return np.maximum(self._cnt[:, users] * decay / w, self.floor)

    def rates(self, t: float | None = None
              ) -> tuple[np.ndarray, np.ndarray]:
        """Current (λ̂, μ̂) vectors (floored), decayed to ``t`` (default:
        the latest event time seen)."""
        est = self._rates_at(self._at(t), np.arange(self.n))
        return est[0], est[1]

    def activity(self, t: float | None = None) -> Activity:
        """The estimated :class:`Activity` (strictly positive by floor)."""
        lam, mu = self.rates(t)
        return Activity(lam, mu)

    # -- dirty-set / sync ------------------------------------------------ #
    def _at(self, t: float | None) -> float:
        """The shared clock read: ``t=None`` means "now" = the latest event
        time seen. :meth:`pending_mass` and :meth:`drain` both resolve
        their default through this one helper, so a pending-mass probe
        followed by a drain at the same (default) instant measures the
        *same* rates — the mass reported equals the mass drained."""
        return self.t if t is None else float(t)

    @property
    def dirty(self) -> np.ndarray:
        """Users with events since the last :meth:`drain` (ascending)."""
        return np.nonzero(self._touched)[0]

    def pending_mass(self, t: float | None = None) -> float:
        """l1 rate mass of the dirty set at time ``t`` (default "now", the
        same clock read :meth:`drain` uses — see :meth:`_at`):

            Σ_dirty |λ̂(t) − λ_synced| + |μ̂(t) − μ_synced|

        Unit: events per event-time unit (a rate, same unit as λ/μ) summed
        over users and both rate kinds — the l1 distance between the
        estimated and the serving-side rate vectors. This is the freshness
        policy's ``max_dirty_mass`` fuel and the scale of the residual the
        push backend reseeds from a drained patch (docs/LOCALPUSH.md)."""
        users = self.dirty
        if users.size == 0:
            return 0.0
        est = self._rates_at(self._at(t), users)
        return float(np.abs(est - self._synced[:, users]).sum())

    def drain(self, t: float | None = None
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray, float]:
        """(users, λ̂, μ̂, mass) of the dirty set; marks it synced, clears.

        The first three fields are exactly one batched ``update_activity``
        patch; ``mass`` is the pre-sync :meth:`pending_mass` of the same
        set (computed here from the one rate evaluation, so callers that
        account for unresolved mass don't pay a second pass). An empty
        stream window drains to empty arrays and zero mass (the serving
        fast path makes that a true no-op).
        """
        users = self.dirty
        if users.size == 0:
            return users, np.empty(0), np.empty(0), 0.0
        est = self._rates_at(self._at(t), users)
        if not np.all(np.isfinite(est)):
            # belt to _tick's suspenders: no drained patch may ever carry a
            # non-finite rate into update_activity/patch_activity
            raise ValueError("non-finite rate estimate in drain; the "
                             "estimator state is corrupt (was a non-finite "
                             "timestamp injected around validation?)")
        mass = float(np.abs(est - self._synced[:, users]).sum())
        self._synced[:, users] = est
        self._touched[users] = False
        return users, est[0].copy(), est[1].copy(), mass

    # -- persistence (crash recovery) ------------------------------------ #
    def state_dict(self) -> dict[str, np.ndarray]:
        """The complete mutable state as flat numpy arrays — checkpointable
        through ``ckpt.checkpoint`` alongside the solver board. Estimator
        state depends only on the *event order*, not on drain boundaries,
        so a restore + exactly-once replay from the persisted offset lands
        on bit-identical rates (repro.resilience.recovery relies on this).
        """
        return dict(
            cnt=self._cnt.copy(), last=self._last.copy(),
            touched=self._touched.copy(), synced=self._synced.copy(),
            scalars=np.asarray([self.t, self.t0, float(self.events)]),
        )

    def load_state(self, state: dict[str, np.ndarray]) -> None:
        """Restore :meth:`state_dict` output (shapes must match ``n``)."""
        cnt = np.asarray(state["cnt"], np.float64)
        if cnt.shape != (2, self.n):
            raise ValueError(f"estimator state is for n={cnt.shape[-1]}, "
                             f"this estimator has n={self.n}")
        self._cnt = cnt.copy()
        self._last = np.asarray(state["last"], np.float64).copy()
        self._touched = np.asarray(state["touched"], bool).copy()
        self._synced = np.asarray(state["synced"], np.float64).copy()
        t, t0, events = np.asarray(state["scalars"], np.float64)
        self.t, self.t0, self.events = float(t), float(t0), int(events)

    def sync_to(self, activity: Activity) -> None:
        """Declare the target's current rates (e.g. its admission-time
        prior) so ``pending_mass`` measures true divergence from day one."""
        if activity.n != self.n:
            raise ValueError("activity/estimator size mismatch")
        self._synced[0] = activity.lam
        self._synced[1] = activity.mu
