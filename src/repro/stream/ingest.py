"""StreamIngestor: event log → batched O(Δ) patches → continuously-fresh ψ.

The pipeline this module closes (docs/STREAMING.md):

    events (Post / Repost / Follow / Unfollow / TenantEvent)
      → RateEstimator        (online λ̂/μ̂, per-user dirty set)
      → coalescing window    (``FreshnessPolicy.coalesce`` events)
      → one batched patch    (``update_activity`` + ``add_edges`` +
                              ``remove_edges`` against the serving target)
      → freshness policy     (re-resolve every N events / Δt event-time /
                              dirty-mass threshold — else keep serving the
                              existing ranking with certified staleness)

Three serving targets share the ingestor through thin adapters:

* :class:`~repro.core.incremental.PsiService` — patches apply with
  ``resolve=False`` (deferred); ``resolve()`` warm re-solves; between
  resolves the stale :class:`~repro.core.incremental.RankingCache` serves.
* :class:`~repro.serving.fleet.TenantFleet` — ``TenantEvent``s route to
  per-tenant lanes, each with its **own** estimator; patches use the
  fleet's native deferred dirty-marking and one ``fleet.solve()`` batches
  every dirty lane per resolve. (Frontier reads are fresh-on-read by the
  fleet's contract; the policy here governs the proactive solve cadence.)
* :class:`~repro.asyncexec.executor.AsyncPsiDriver` — between runs,
  patches go through the driver's O(Δ) hooks and ``resolve()`` warm-runs
  the pipeline; **mid-flight**, attach the source and call :meth:`pump`
  from the driver's ``epoch_hook`` — patches land through the
  generation-guarded scheduler hooks while chunks are in flight, and the
  staleness certificate guarantees termination happens on the patched
  operators (see ``tests/test_async.py``'s interleaving property).

Unfollow tombstones: inside one coalescing window the last operation on an
edge wins (follow→unfollow nets to nothing new; unfollow→follow nets to
the plain insert); a tombstone of a materialized edge becomes an edge
*removal* patch (``HostOperators.remove_edges``).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import numpy as np

from ..core.activity import RATE_FLOOR
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from .estimator import RateEstimator
from .events import Follow, Post, Repost, TenantEvent, Unfollow
from .freshness import FreshnessPolicy, FreshnessReport

__all__ = ["StreamIngestor"]

_DONE = object()


# --------------------------------------------------------------------- #
# Target adapters — one uniform patch/resolve/query surface
# --------------------------------------------------------------------- #
class _ServiceTarget:
    """Single-lane adapter over a PsiService (deferred-resolve patches)."""

    multi = False

    def __init__(self, svc):
        self.svc = svc

    def n_of(self, key) -> int:
        return self.svc.graph.n

    def activity_of(self, key):
        return self.svc.engine.activity

    def apply_activity(self, key, users, lam, mu) -> None:
        self.svc.update_activity(users, lam=lam, mu=mu, resolve=False)

    def apply_add_edges(self, key, src, dst) -> None:
        self.svc.add_edges(src, dst, resolve=False)

    def apply_remove_edges(self, key, src, dst) -> None:
        self.svc.remove_edges(src, dst, resolve=False)

    def resolve(self) -> None:
        self.svc.resolve()

    def needs_resolve(self) -> bool:
        """True when a query could NOT serve the existing stale ranking —
        i.e. it would trigger a solve the ingestor's freshness accounting
        would otherwise miss (here: never solved at all)."""
        return self.svc.last_result is None

    def top_k(self, k: int):
        return self.svc.top_k(k)

    def topk_ids(self, k: int) -> tuple:
        return tuple(int(u) for u in self.svc.top_k(k)[0])

    def psi_of(self, key) -> np.ndarray:
        return self.svc.scores()

    def psi_error_bound(self):
        """The engine's certificate for the *served* fixed point — only
        meaningful while no patch has been applied since it was issued
        (certifying engines self-invalidate on patches, and the ingestor
        additionally gates on zero unresolved events)."""
        if self.svc.last_result is None:
            return None
        return self.svc.engine.psi_error_bound()


class _FleetTarget:
    """Per-tenant-lane adapter over a TenantFleet (native deferral)."""

    multi = True

    def __init__(self, fleet):
        self.fleet = fleet

    def n_of(self, tid) -> int:
        return self.fleet.stats(tid)["n"]       # raises for unknown tenants

    def activity_of(self, tid):
        return self.fleet.activity(tid)

    def apply_activity(self, tid, users, lam, mu) -> None:
        self.fleet.patch_activity(tid, users, lam=lam, mu=mu)

    def apply_add_edges(self, tid, src, dst) -> None:
        self.fleet.patch_edges(tid, src, dst)

    def apply_remove_edges(self, tid, src, dst) -> None:
        self.fleet.remove_edges(tid, src, dst)

    def resolve(self) -> None:
        self.fleet.solve()

    def needs_resolve(self) -> bool:
        # frontier reads are fresh-on-read (they solve dirty lanes
        # internally), so any stale tenant means a query IS a resolve —
        # route it through the ingestor so the freshness counters reset
        return any(self.fleet.stats(t)["staleness"] > 0
                   for t in self.fleet.tenant_ids)

    def top_k(self, k: int):
        return self.fleet.frontier.global_top_k(k)

    def topk_ids(self, k: int) -> tuple:
        return tuple((tid, int(u))
                     for tid, u, _ in self.fleet.frontier.global_top_k(k))

    def psi_of(self, tid) -> np.ndarray:
        return self.fleet.psi(tid)

    def psi_error_bound(self):
        return None          # vmapped lanes carry no residual certificate


class _AsyncDriverTarget:
    """Single-lane adapter over an AsyncPsiDriver (patch between or during
    runs; ``resolve`` warm-runs the bounded-staleness pipeline)."""

    multi = False

    def __init__(self, drv, resolve_opts: dict):
        self.drv = drv
        self.opts = dict(tol=1e-8)
        self.opts.update(resolve_opts)
        self.last_report = None
        self._cache = None

    def n_of(self, key) -> int:
        return self.drv.host.n

    def activity_of(self, key):
        return self.drv.host.activity()

    def apply_activity(self, key, users, lam, mu) -> None:
        self.drv.patch_activity(users, lam=lam, mu=mu)

    def apply_add_edges(self, key, src, dst) -> None:
        self.drv.patch_edges(src, dst)

    def apply_remove_edges(self, key, src, dst) -> None:
        self.drv.remove_edges(src, dst)

    def resolve(self) -> None:
        from ..core.incremental import RankingCache
        self.last_report = self.drv.run(warm=True, **self.opts)
        self._cache = RankingCache(self.last_report.psi)

    def needs_resolve(self) -> bool:
        return self._cache is None             # never resolved yet

    def top_k(self, k: int):
        return self._cache.top_k(k)

    def topk_ids(self, k: int) -> tuple:
        return tuple(int(u) for u in self._cache.top_k(k)[0])

    def psi_of(self, key) -> np.ndarray:
        return self._cache.psi

    def psi_error_bound(self):
        return None          # the async gap certifies movement, not distance


def _adapt(target, resolve_opts: dict):
    from ..core.incremental import PsiService
    if isinstance(target, PsiService):
        return _ServiceTarget(target)
    try:
        from ..serving.fleet import TenantFleet
    except ImportError:                          # pragma: no cover
        TenantFleet = ()
    if TenantFleet and isinstance(target, TenantFleet):
        return _FleetTarget(target)
    try:
        from ..asyncexec.executor import AsyncPsiDriver
    except ImportError:                          # pragma: no cover
        AsyncPsiDriver = ()
    if AsyncPsiDriver and isinstance(target, AsyncPsiDriver):
        return _AsyncDriverTarget(target, resolve_opts)
    raise TypeError(
        f"unsupported ingest target {type(target).__name__!r}; supported: "
        "PsiService, TenantFleet, AsyncPsiDriver")


# --------------------------------------------------------------------- #
# Lane state + the ingestor
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class _Lane:
    """One estimator + coalescing buffer (one per tenant; one total for
    single-target ingestion)."""

    est: RateEstimator
    edge_ops: dict = dataclasses.field(default_factory=dict)  # (s,d)→add?
    buffered: int = 0                 # events since the last flush
    unresolved_mass: float = 0.0      # applied-but-unresolved l1 rate mass
    unresolved_users: set = dataclasses.field(default_factory=set)


class StreamIngestor:
    """Coalesce a live event stream into batched O(Δ) ψ patches.

    Args:
      target: a ``PsiService``, ``TenantFleet`` or ``AsyncPsiDriver``.
      half_life / floor: estimator parameters (see ``estimator.py``).
      policy: flush + resolve cadence (:class:`FreshnessPolicy`).
      topk: ranking depth tracked for the churn-between-resolves metric
        (0 disables churn tracking).
      t0: event-time origin.
      resolve_opts: extra kwargs for the async driver's ``run`` (e.g.
        ``dict(tol=1e-9)``); ignored by the other targets, which own their
        tolerance.
    """

    def __init__(self, target, *, half_life: float = 64.0,
                 floor: float = RATE_FLOOR,
                 policy: FreshnessPolicy | None = None, topk: int = 10,
                 t0: float = 0.0, resolve_opts: dict | None = None):
        self._adapter = _adapt(target, resolve_opts or {})
        self.policy = policy or FreshnessPolicy()
        self.half_life = float(half_life)
        self.floor = float(floor)
        self.topk = int(topk)
        self.t0 = float(t0)
        self._lanes: dict = {}
        self.events_total = 0
        self._buffered = 0                 # across lanes, since last flush
        self._resolved_events = 0          # events_total at the last resolve
        self._event_t = self.t0
        self._resolve_t = self.t0
        self.resolves = 0
        self.churn_history: list[float] = []
        self._last_churn: float | None = None
        self._prev_topk: tuple | None = None
        self._source: Iterator | None = None
        # per-event metric children cached per registry identity: the hot
        # path then pays one dict hit + one counter inc per event, and a
        # registry swap (obs.configure / obs.disable) re-resolves lazily
        self._obs_reg = None
        self._obs_kind: dict = {}

    def _obs_count_event(self, kind: str) -> None:
        reg = obs_metrics.get_registry()
        if reg is not self._obs_reg:
            fam = reg.counter("psi_stream_events_total",
                              "ingested events by kind",
                              labelnames=("kind",))
            self._obs_kind = {k: fam.labels(kind=k)
                              for k in ("post", "repost", "follow",
                                        "unfollow")}
            self._obs_reg = reg
        self._obs_kind[kind].inc()

    # -- lanes ----------------------------------------------------------- #
    def _lane(self, key) -> _Lane:
        lane = self._lanes.get(key)
        if lane is None:
            est = RateEstimator(self._adapter.n_of(key),
                                half_life=self.half_life, floor=self.floor,
                                t0=self.t0)
            est.sync_to(self._adapter.activity_of(key))
            lane = self._lanes[key] = _Lane(est=est)
        return lane

    def estimator(self, tenant: str | None = None) -> RateEstimator:
        """The (per-tenant) estimator lane, created on first access."""
        return self._lane(tenant).est

    # -- ingestion ------------------------------------------------------- #
    def submit(self, event) -> None:
        """Ingest one event; flushes / resolves per the freshness policy."""
        self._submit(event, allow_resolve=True)

    def _submit(self, event, *, allow_resolve: bool) -> None:
        if isinstance(event, TenantEvent):
            if not self._adapter.multi:
                raise TypeError("TenantEvent routing needs a TenantFleet "
                                f"target; got a {type(event).__name__} on a "
                                "single-tenant ingestor")
            key, ev = event.tenant, event.event
        else:
            if self._adapter.multi:
                raise TypeError("fleet ingestion routes TenantEvents; got a "
                                f"bare {type(event).__name__}")
            key, ev = None, event
        lane = self._lane(key)
        self._event_t = max(self._event_t, float(ev.t))
        if isinstance(ev, (Post, Repost)):
            lane.est.observe(ev)
            self._obs_count_event("repost" if isinstance(ev, Repost)
                                  else "post")
        elif isinstance(ev, Follow):
            lane.edge_ops[(int(ev.follower), int(ev.leader))] = True
            self._obs_count_event("follow")
        elif isinstance(ev, Unfollow):
            lane.edge_ops[(int(ev.follower), int(ev.leader))] = False
            self._obs_count_event("unfollow")
        else:
            raise TypeError(f"unknown event type {type(ev).__name__}")
        lane.buffered += 1
        self._buffered += 1
        self.events_total += 1
        if self._buffered >= self.policy.coalesce:
            self.flush()
        if allow_resolve and self._policy_due():
            self.resolve()

    def _policy_due(self) -> bool:
        """Per-event resolve check, cheap by construction: the event-count
        and event-time triggers need two scalars each; the full
        FreshnessReport (O(dirty-set) mass/user accounting) is only built
        when the dirty-mass trigger is enabled."""
        p = self.policy
        if (p.resolve_every is not None
                and self.events_total - self._resolved_events
                >= p.resolve_every):
            return True
        if (p.resolve_seconds is not None
                and self._event_t - self._resolve_t >= p.resolve_seconds):
            return True
        if p.max_dirty_mass is None:
            return False
        return p.due(self.freshness())

    def flush(self) -> None:
        """Apply every buffered window as batched O(Δ) patches (no solve).

        A window that nets out to nothing (e.g. only follow+unfollow pairs
        of the same edge) applies *no* patch at all — the serving layers'
        empty-delta fast paths guarantee no cache invalidation.
        """
        if self._buffered:
            obs_metrics.histogram(
                "psi_stream_flush_events",
                "events coalesced per flush window",
                buckets=(1, 2, 5, 10, 20, 50, 100, 200, 500, 1000, 5000)
            ).observe(self._buffered)
        for key, lane in self._lanes.items():
            if lane.buffered == 0 and not lane.edge_ops:
                continue
            users, lam, mu, mass = lane.est.drain(self._event_t)
            lane.unresolved_mass += mass
            if users.size:
                lane.unresolved_users.update(int(u) for u in users)
                self._adapter.apply_activity(key, users, lam, mu)
            if lane.edge_ops:
                rm = [(s, d) for (s, d), add in lane.edge_ops.items()
                      if not add]
                add = [(s, d) for (s, d), a in lane.edge_ops.items() if a]
                lane.edge_ops.clear()
                if rm:
                    self._adapter.apply_remove_edges(
                        key, np.asarray([e[0] for e in rm], np.int32),
                        np.asarray([e[1] for e in rm], np.int32))
                if add:
                    self._adapter.apply_add_edges(
                        key, np.asarray([e[0] for e in add], np.int32),
                        np.asarray([e[1] for e in add], np.int32))
            lane.buffered = 0
        self._buffered = 0

    def resolve(self) -> None:
        """Flush, re-solve ψ on the target, and reset freshness counters
        (records top-k churn against the previous resolve)."""
        self.flush()
        # lag at the moment the resolve fires = how far the served ψ had
        # fallen behind the event-time watermark
        obs_metrics.gauge(
            "psi_stream_watermark_lag_seconds",
            "event-time lag of the served psi when the resolve fired"
        ).set(self._event_t - self._resolve_t)
        with obs_trace.span("stream.resolve",
                            unresolved=self.events_total
                            - self._resolved_events):
            self._adapter.resolve()
        obs_metrics.counter("psi_stream_resolves_total",
                            "stream-triggered target re-solves").inc()
        self.resolves += 1
        self._resolve_t = self._event_t
        self._resolved_events = self.events_total
        for lane in self._lanes.values():
            lane.unresolved_mass = 0.0
            lane.unresolved_users.clear()
        if self.topk > 0:
            ids = self._adapter.topk_ids(self.topk)
            if self._prev_topk is not None and ids:
                k = max(len(ids), len(self._prev_topk))
                churn = 1.0 - len(set(ids) & set(self._prev_topk)) / k
                self._last_churn = churn
                self.churn_history.append(churn)
            self._prev_topk = ids

    def ingest(self, source: Iterable, *, limit: int | None = None,
               resolve_at_end: bool = True) -> FreshnessReport:
        """Replay a source end-to-end under the freshness policy."""
        start_events = self.events_total
        with obs_trace.span("stream.ingest") as sp:
            for i, ev in enumerate(source):
                if limit is not None and i >= limit:
                    break
                self.submit(ev)
            self.flush()
            if resolve_at_end:
                self.resolve()
        done = self.events_total - start_events
        if done and sp.duration_s > 0:
            obs_metrics.gauge(
                "psi_stream_ingest_events_per_s",
                "wall-clock event throughput of the last ingest() replay"
            ).set(done / sp.duration_s)
        return self.freshness()

    # -- persisted offset (crash recovery) -------------------------------- #
    @property
    def offset(self) -> int:
        """Events consumed so far — the replay cursor a stack checkpoint
        persists. Checkpoints are taken at *flushed* points (buffered = 0,
        no pending edge ops), so a recovery that replays the event log from
        this offset reconstructs exactly the un-applied suffix; the
        estimator's :meth:`~repro.stream.estimator.RateEstimator.state_dict`
        carries the applied prefix (repro.resilience.recovery composes the
        two)."""
        return int(self.events_total)

    def fast_forward(self, offset: int, *, event_t: float | None = None
                     ) -> None:
        """Declare that the first ``offset`` events of the stream are
        already reflected in this ingestor's state (restored estimator +
        restored serving target) — the recovery path's half of the
        exactly-once contract: events before the offset are never
        re-applied, events after it arrive via normal :meth:`submit` /
        :meth:`pump` replay. Only valid on a quiescent ingestor (nothing
        buffered, nothing ingested yet through this instance)."""
        if self._buffered or self.events_total:
            raise RuntimeError("fast_forward on a non-quiescent ingestor "
                               f"(buffered={self._buffered}, "
                               f"events_total={self.events_total})")
        self.events_total = int(offset)
        self._resolved_events = int(offset)
        if event_t is not None:
            self._event_t = float(event_t)
            self._resolve_t = float(event_t)

    # -- mid-flight feeding (async driver epoch_hook) -------------------- #
    def attach(self, source: Iterable) -> None:
        """Stage a source for incremental :meth:`pump` consumption."""
        self._source = iter(source)

    @property
    def exhausted(self) -> bool:
        return self._source is None

    def pump(self, max_events: int = 64) -> int:
        """Ingest up to ``max_events`` from the attached source, applying
        patches but **never resolving** — the caller's live pipeline (e.g.
        an AsyncPsiDriver mid-run, via ``epoch_hook``) is the resolver.
        Returns the number of events consumed (0 once exhausted)."""
        if self._source is None:
            return 0
        n = 0
        while n < max_events:
            ev = next(self._source, _DONE)
            if ev is _DONE:
                self._source = None
                break
            self._submit(ev, allow_resolve=False)
            n += 1
        if n:
            self.flush()
        return n

    # -- freshness + queries --------------------------------------------- #
    def freshness(self) -> FreshnessReport:
        mass = sum(l.unresolved_mass for l in self._lanes.values())
        dirty = set()
        for key, lane in self._lanes.items():
            mass += lane.est.pending_mass(self._event_t)
            dirty.update((key, u) for u in lane.unresolved_users)
            dirty.update((key, int(u)) for u in lane.est.dirty)
        unresolved = self.events_total - self._resolved_events
        # a numerical certificate only covers the served ψ while nothing
        # has been ingested on top of the operators it was proved against
        bound = (self._adapter.psi_error_bound()
                 if unresolved == 0 else None)
        if obs_metrics.enabled():
            obs_metrics.gauge("psi_stream_dirty_mass",
                              "applied-but-unresolved l1 rate mass"
                              ).set(mass)
            obs_metrics.gauge("psi_stream_dirty_users",
                              "distinct users awaiting a resolve"
                              ).set(len(dirty))
            obs_metrics.gauge("psi_stream_unresolved_events",
                              "events ingested since the last resolve"
                              ).set(unresolved)
            # keep the freshness SLO's signal live between resolves:
            # the current lag of the served ψ behind the event watermark
            obs_metrics.gauge(
                "psi_stream_watermark_lag_seconds",
                "event-time lag of the served psi when the resolve fired"
            ).set(self._event_t - self._resolve_t)
            # the certified-ψ-error SLO reads this gauge; only a bound
            # that still covers the served answer is published
            if bound is not None:
                obs_metrics.gauge(
                    "psi_certified_error_bound",
                    "Eq. 19 certified sup-norm bound of the last served "
                    "answer").set(bound)
        return FreshnessReport(
            event_time=self._event_t, resolve_time=self._resolve_t,
            events_total=self.events_total, events_buffered=self._buffered,
            events_unresolved=unresolved,
            dirty_users=len(dirty), dirty_mass=mass, resolves=self.resolves,
            topk_churn=self._last_churn, psi_error_bound=bound)

    def top_k(self, k: int, *, max_events: int | None = None,
              max_seconds: float | None = None,
              max_dirty_mass: float | None = None,
              max_psi_error: float | None = None):
        """Query the served ranking, demanding at most the given staleness:
        if the current :class:`FreshnessReport` fails ``certify``, the
        ingestor resolves first (otherwise the stale ranking serves). A
        query the target could only answer by solving anyway (never solved,
        or a fleet with stale lanes — frontier reads are fresh-on-read)
        also routes through :meth:`resolve`, so the freshness counters
        always describe the ranking actually served. ``max_psi_error``
        additionally demands a certified numerical bound on the served ψ
        (only certifying backends — ``push`` — can serve stale under it)."""
        if (self._adapter.needs_resolve()
                or not self.freshness().certify(
                    max_events=max_events, max_seconds=max_seconds,
                    max_dirty_mass=max_dirty_mass,
                    max_psi_error=max_psi_error)):
            self.resolve()
        return self._adapter.top_k(k)

    def psi(self, tenant: str | None = None) -> np.ndarray:
        """The target's current ψ (tenant-scoped on a fleet; resolves
        through the freshness accounting when the target has no served
        fixed point to answer from)."""
        if self._adapter.needs_resolve():
            self.resolve()
        return self._adapter.psi_of(tenant)
