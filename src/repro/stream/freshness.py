"""Freshness accounting for streamed ψ serving.

Between two resolves the served :class:`~repro.core.incremental.RankingCache`
is *stale by design* — events have been ingested (and possibly applied as
O(Δ) patches) but ψ has not been re-solved. This module makes that
staleness a first-class, certifiable quantity instead of an accident:

* :class:`FreshnessReport` — an immutable snapshot of how far the served
  ranking lags the event stream: events applied-but-unresolved, events
  still buffered, the estimator's dirty mass, event-time staleness, and
  the top-k churn measured between the last two resolves (how much the
  head of the ranking actually moved — the user-visible cost of serving
  stale). ``certify(...)`` answers a query's ``max_staleness`` demand.
* :class:`FreshnessPolicy` — when the ingestor flushes patches
  (``coalesce`` events per batched patch) and when it re-resolves:
  every ``resolve_every`` events, every ``resolve_seconds`` of event
  time, or when the estimator's dirty mass crosses
  ``max_dirty_mass`` — whichever fires first. All three triggers are
  optional; disabling all of them makes resolution purely query-driven
  (``StreamIngestor.top_k(..., max_events=...)``) or manual.
"""
from __future__ import annotations

import dataclasses

__all__ = ["FreshnessPolicy", "FreshnessReport"]


@dataclasses.dataclass(frozen=True)
class FreshnessReport:
    """How far the served ranking lags the ingested stream."""

    event_time: float        # latest event time ingested
    resolve_time: float      # event time when ψ was last resolved
    events_total: int        # events ingested over the stream's lifetime
    events_buffered: int     # ingested but not yet applied as patches
    events_unresolved: int   # ingested since the last resolve (incl. buffered)
    dirty_users: int         # users whose estimated rates are unsynced
    dirty_mass: float        # l1(estimated − synced rates) over dirty users
    resolves: int            # resolves performed so far
    topk_churn: float | None = None   # 1 − overlap/k between last 2 resolves
    # certified per-node |ψ_exact − ψ_served| bound of the serving solve
    # (engine residual certificate, see docs/LOCALPUSH.md); None when the
    # backend cannot certify one or events arrived since it was issued —
    # a bound must never outlive the operators it was proved against
    psi_error_bound: float | None = None

    @property
    def staleness_events(self) -> int:
        return self.events_unresolved

    @property
    def staleness_seconds(self) -> float:
        return max(0.0, self.event_time - self.resolve_time)

    def certify(self, *, max_events: int | None = None,
                max_seconds: float | None = None,
                max_dirty_mass: float | None = None,
                max_psi_error: float | None = None) -> bool:
        """True iff the served ranking meets every given staleness bound
        (an unset bound is not demanded; no bounds → trivially fresh).

        ``max_psi_error`` demands a *certified* numerical bound: it fails
        whenever ``psi_error_bound`` is absent, not merely when it is
        large — an uncertified ranking cannot satisfy a certificate
        demand."""
        if max_events is not None and self.staleness_events > max_events:
            return False
        if max_seconds is not None and self.staleness_seconds > max_seconds:
            return False
        if max_dirty_mass is not None and self.dirty_mass > max_dirty_mass:
            return False
        if max_psi_error is not None and (
                self.psi_error_bound is None
                or self.psi_error_bound > max_psi_error):
            return False
        return True


@dataclasses.dataclass(frozen=True)
class FreshnessPolicy:
    """When the ingestor patches and when it re-resolves.

    Args:
      coalesce: buffered events per batched patch flush (the O(Δ) patch
        granularity; 1 applies every event immediately).
      resolve_every: re-resolve after this many ingested events (None
        disables the event-count trigger).
      resolve_seconds: re-resolve when the served fixed point is this many
        event-time seconds behind the stream (None disables).
      max_dirty_mass: re-resolve when the unresolved l1 rate mass (applied
        patches the served ψ has not absorbed, plus the estimator's
        pending dirty mass) crosses this threshold (None disables).
    """

    coalesce: int = 64
    resolve_every: int | None = 512
    resolve_seconds: float | None = None
    max_dirty_mass: float | None = None

    def __post_init__(self):
        if self.coalesce < 1:
            raise ValueError(f"coalesce must be >= 1; got {self.coalesce}")

    def due(self, report: FreshnessReport) -> bool:
        """Does ``report`` trip any resolve trigger?"""
        if (self.resolve_every is not None
                and report.events_unresolved >= self.resolve_every):
            return True
        if (self.resolve_seconds is not None
                and report.staleness_seconds >= self.resolve_seconds):
            return True
        if (self.max_dirty_mass is not None
                and report.dirty_mass >= self.max_dirty_mass):
            return True
        return False
