"""Streaming ingestion: live event log → online λ/μ → continuously-fresh ψ.

The paper's workload is a *platform*: posts, re-posts and follows arrive as
a stream, not as a pre-estimated Activity over a frozen Graph. This package
closes that gap end to end (docs/STREAMING.md):

* :mod:`events`    — typed replayable event log (``Post`` / ``Repost`` /
  ``Follow`` / ``Unfollow`` tombstone / ``TenantEvent``) plus seeded
  synthetic generators (stationary Poisson clocks, posting bursts,
  flash crowds with follower churn).
* :mod:`estimator` — online λ/μ estimation from event timestamps via
  bias-corrected exponentially-decayed counters (provably unbiased on
  stationary streams — the generators' ground truth is a fixed point),
  with per-user dirty-set tracking.
* :mod:`ingest`    — :class:`StreamIngestor`: coalesces events into
  batched O(Δ) patches against a ``PsiService``, a ``TenantFleet``
  (``TenantEvent`` lane routing) or an ``AsyncPsiDriver`` (mid-flight via
  its generation-guarded hooks), resolving per the freshness policy.
* :mod:`freshness` — :class:`FreshnessPolicy` (when to patch / re-solve)
  and :class:`FreshnessReport` (certifiable staleness of the served
  ranking: unresolved events, dirty rate mass, top-k churn).

``python -m repro.stream.check`` replays a fixed synthetic log and asserts
estimator accuracy + ψ-parity against a from-scratch batch solve (the CI
smoke); ``launch/serve.py --stream <scenario>`` is the serving entry point.
"""
from .estimator import RateEstimator
from .events import (EventSource, Follow, Post, ReplayLog, Repost,
                     TenantEvent, Unfollow, burst_stream,
                     flash_crowd_stream, poisson_stream, tenant_interleave)
from .freshness import FreshnessPolicy, FreshnessReport
from .ingest import StreamIngestor

__all__ = [
    "EventSource", "Follow", "FreshnessPolicy", "FreshnessReport", "Post",
    "RateEstimator", "ReplayLog", "Repost", "StreamIngestor", "TenantEvent",
    "Unfollow", "burst_stream", "flash_crowd_stream", "poisson_stream",
    "tenant_interleave",
]
