"""Streaming replay check: the CI smoke for the ingestion subsystem.

Two stages over fixed seeded logs (deterministic → the thresholds are
asserted against known-good values, not statistical hopes):

1. **Rate recovery** — a ~2k-event stationary Poisson log over a small,
   highly-active user set (per-rate accuracy is information-limited at
   1/√(events per rate), so the smoke concentrates events on few users);
   asserts the l1-aggregate relative error of (λ̂, μ̂) vs ground truth is
   within ``--rate-tol`` (default 5%).
2. **ψ-parity + throughput** — a flash-crowd log (posts + follows +
   unfollow churn) ingested through a float64 ``PsiService`` under the
   freshness policy; asserts the streamed ψ after the final resolve
   matches a from-scratch batch solve on the final (graph,
   estimated-activity) state within ``--psi-tol`` (default 1e-6), and
   prints sustained events/s.

Exit code 0 iff both stages pass:

    PYTHONPATH=src python -m repro.stream.check --events 2000
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

# run with JAX_ENABLE_X64=1 for the float64 parity oracle (the CI smoke
# does); without it the ψ-parity stage still passes its 1e-6 tolerance
# comfortably in float32.


def rate_recovery(events: int, seed: int, half_life_factor: float) -> dict:
    from repro.core.activity import Activity
    from repro.stream import RateEstimator, poisson_stream

    rng = np.random.default_rng(seed)
    n = 4
    truth = Activity(rng.uniform(0.3, 1.0, n), rng.uniform(0.3, 1.0, n))
    horizon = events / float(truth.total.sum())
    log = poisson_stream(truth, horizon, seed=seed + 1)
    est = RateEstimator(n, half_life=half_life_factor * horizon)
    for ev in log:
        est.observe(ev)
    lam, mu = est.rates(horizon)
    err = (np.abs(lam - truth.lam).sum() + np.abs(mu - truth.mu).sum()) \
        / float(truth.total.sum())
    return dict(events=len(log), n=n, horizon=horizon, rate_err=float(err))


def psi_parity(events: int, seed: int, resolve_every: int) -> dict:
    import jax.numpy as jnp

    from repro.core import Activity, heterogeneous, make_engine
    from repro.core.activity import RATE_FLOOR
    from repro.core.incremental import PsiService
    from repro.graphs import powerlaw_configuration
    from repro.stream import (FreshnessPolicy, StreamIngestor,
                              flash_crowd_stream)

    n, m = 512, 3_000
    g = powerlaw_configuration(n, m, seed=seed)
    truth = heterogeneous(n, seed=seed + 1)
    horizon = events / float(truth.total.sum())
    log = flash_crowd_stream(g, truth, horizon, new_followers=48,
                             churn=0.3, seed=seed + 2)
    cold = Activity(np.full(n, RATE_FLOOR), np.full(n, RATE_FLOOR))
    svc = PsiService(g, cold, tol=1e-9, dtype=jnp.float64)
    ing = StreamIngestor(svc, half_life=horizon / 2,
                         policy=FreshnessPolicy(coalesce=64,
                                                resolve_every=resolve_every))
    t0 = time.perf_counter()
    rep = ing.ingest(log)
    wall = time.perf_counter() - t0
    # from-scratch batch oracle on the final (graph, estimated-activity)
    batch = make_engine("reference", graph=svc.graph,
                        activity=svc.engine.activity,
                        dtype=jnp.float64).run(tol=1e-9)
    psi_err = float(np.abs(svc.scores() - np.asarray(batch.psi)).max())
    return dict(events=len(log), n=n, m_final=svc.graph.m, wall_s=wall,
                events_per_s=len(log) / wall, resolves=rep.resolves,
                psi_err=psi_err,
                topk_churn=max(ing.churn_history, default=0.0))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=2_000)
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--rate-tol", type=float, default=0.05)
    ap.add_argument("--psi-tol", type=float, default=1e-6)
    ap.add_argument("--resolve-every", type=int, default=500)
    ap.add_argument("--half-life-factor", type=float, default=2.0,
                    help="estimator half-life as a multiple of the horizon")
    args = ap.parse_args(argv)

    ok = True
    r = rate_recovery(args.events, args.seed, args.half_life_factor)
    good = r["rate_err"] <= args.rate_tol
    ok &= good
    print(f"[stream-check] rate recovery: {r['events']} events over "
          f"{r['n']} users, l1 rel err={r['rate_err']:.4f} "
          f"(tol {args.rate_tol}) {'OK' if good else 'FAIL'}")

    p = psi_parity(args.events, args.seed, args.resolve_every)
    good = p["psi_err"] <= args.psi_tol
    ok &= good
    print(f"[stream-check] psi parity: {p['events']} events on n={p['n']} "
          f"(m_final={p['m_final']}), {p['resolves']} resolves, "
          f"{p['events_per_s']:.0f} ev/s, "
          f"topk_churn={p['topk_churn']:.2f}, "
          f"psi_err={p['psi_err']:.2e} (tol {args.psi_tol:.0e}) "
          f"{'OK' if good else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
