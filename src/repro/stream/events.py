"""Typed, replayable event log for the streaming ingestion subsystem.

The ψ-score is a function of the platform's *activity* — who posts, who
re-posts, who follows whom (PAPER §II) — yet everything upstream of this
module consumes that activity pre-digested into an
:class:`~repro.core.activity.Activity` (λ/μ vectors) and a frozen
:class:`~repro.graphs.structure.Graph`. A live platform produces neither:
it produces an *event log*. This module is the shared vocabulary for that
log:

* :class:`Post` / :class:`Repost`     — activity clock ticks of one user
  (the raw material the online λ/μ estimator counts; ``estimator.py``).
* :class:`Follow` / :class:`Unfollow` — graph mutations. ``Unfollow`` is a
  *tombstone*: the ingestor nets it against a pending ``Follow`` of the
  same edge inside one coalescing window, and otherwise turns it into an
  edge removal patch (``ingest.py``).
* :class:`TenantEvent`                — routes any of the above to one
  tenant lane of a :class:`~repro.serving.fleet.TenantFleet`.

An :class:`EventSource` is simply an iterable that yields the same
time-ordered event sequence on *every* iteration — deterministic replay is
the contract the parity acceptance tests lean on (replay + resolve must
match a from-scratch solve on the final state, so the log must be
re-playable against the batch oracle). :class:`ReplayLog` is the canonical
tuple-backed source; the synthetic generators below all return one.

Generators (all seeded, all pure numpy):

* :func:`poisson_stream`     — stationary ground-truth clocks: user ``u``
  posts as a Poisson process of rate λ_u and re-posts at rate μ_u over a
  fixed horizon (conditional-uniform sampling of arrival times). This is
  the stream the estimator must provably invert — see ``estimator.py``.
* :func:`burst_stream`       — ``poisson_stream`` plus a piecewise-constant
  posting burst: selected users post at ``burst_factor``·λ inside a window.
* :func:`flash_crowd_stream` — the graph-churn scenario: a celebrity gains
  followers mid-stream (``Follow``), the new fans run a repost storm, and a
  fraction churns out afterwards (``Unfollow`` tombstones).
* :func:`tenant_interleave`  — time-merge per-tenant sources into one
  ``TenantEvent`` stream for fleet ingestion.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator, Protocol, runtime_checkable

import numpy as np

from ..core.activity import Activity
from ..graphs.structure import Graph

__all__ = ["Post", "Repost", "Follow", "Unfollow", "TenantEvent",
           "EventSource", "ReplayLog", "poisson_stream", "burst_stream",
           "flash_crowd_stream", "tenant_interleave"]


@dataclasses.dataclass(frozen=True)
class Post:
    """User ``user`` published an original post at event time ``t``."""

    t: float
    user: int


@dataclasses.dataclass(frozen=True)
class Repost:
    """User ``user`` re-posted from their news feed at ``t``.

    ``origin`` optionally names the author of the re-shared post (−1 when
    unknown); the rate estimator only needs the (t, user) clock tick.
    """

    t: float
    user: int
    origin: int = -1


@dataclasses.dataclass(frozen=True)
class Follow:
    """``follower`` started following ``leader`` (edge follower→leader)."""

    t: float
    follower: int
    leader: int


@dataclasses.dataclass(frozen=True)
class Unfollow:
    """Tombstone: ``follower`` stopped following ``leader``.

    Inside one coalescing window it cancels a pending :class:`Follow` of
    the same edge; against an already-materialized edge it becomes an edge
    *removal* patch (``HostOperators.remove_edges``).
    """

    t: float
    follower: int
    leader: int


@dataclasses.dataclass(frozen=True)
class TenantEvent:
    """Wrapper routing ``event`` to tenant ``tenant`` of a fleet."""

    tenant: str
    event: "Post | Repost | Follow | Unfollow"

    @property
    def t(self) -> float:
        return self.event.t


@runtime_checkable
class EventSource(Protocol):
    """Anything that yields the same time-ordered events every iteration."""

    def __iter__(self) -> Iterator: ...


@dataclasses.dataclass(frozen=True)
class ReplayLog:
    """Materialized, immutable event sequence — trivially replayable."""

    events: tuple

    def __iter__(self) -> Iterator:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __getitem__(self, i):
        return self.events[i]

    @property
    def span(self) -> tuple[float, float]:
        """(first, last) event time; (0, 0) when empty."""
        if not self.events:
            return 0.0, 0.0
        return self.events[0].t, self.events[-1].t

    def counts(self) -> dict:
        """Event-type histogram (``{'Post': k, ...}``)."""
        out: dict[str, int] = {}
        for ev in self.events:
            key = type(ev.event if isinstance(ev, TenantEvent)
                       else ev).__name__
            out[key] = out.get(key, 0) + 1
        return out

    @classmethod
    def from_events(cls, events: Iterable) -> "ReplayLog":
        """Time-sort (stable) a collection of events into a log."""
        return cls(tuple(sorted(events, key=lambda e: e.t)))


# --------------------------------------------------------------------- #
# Synthetic generators
# --------------------------------------------------------------------- #
def _poisson_ticks(rates: np.ndarray, horizon: float, t0: float,
                   rng: np.random.Generator
                   ) -> tuple[np.ndarray, np.ndarray]:
    """(times, users) of merged Poisson clocks, one per user.

    Conditional on the count N_u ~ Poisson(rate_u · horizon), the arrival
    times of a homogeneous Poisson process are i.i.d. uniform on the
    window — so the whole fan of clocks samples in two vectorized draws.
    """
    counts = rng.poisson(np.maximum(rates, 0.0) * horizon)
    users = np.repeat(np.arange(rates.shape[0], dtype=np.int64), counts)
    times = t0 + rng.random(users.shape[0]) * horizon
    return times, users


def _repost_origins(graph: Graph | None, users: np.ndarray,
                    rng: np.random.Generator) -> np.ndarray:
    """A random leader of each re-posting user (−1 if none / no graph)."""
    origins = np.full(users.shape[0], -1, np.int64)
    if graph is None or users.size == 0:
        return origins
    _, leaders = graph.edges_by_src
    indptr = graph.csr_indptr
    deg = (indptr[users + 1] - indptr[users]).astype(np.int64)
    has = deg > 0
    pick = indptr[users[has]] + (rng.random(int(has.sum()))
                                 * deg[has]).astype(np.int64)
    origins[has] = leaders[pick]
    return origins


def poisson_stream(activity: Activity, horizon: float, *, seed: int = 0,
                   t0: float = 0.0, graph: Graph | None = None) -> ReplayLog:
    """Stationary ground-truth stream: posts at λ_u, re-posts at μ_u.

    The estimator's convergence target: replaying this log through
    :class:`~repro.stream.estimator.RateEstimator` recovers ``activity``
    (λ̂ → λ, μ̂ → μ as events accumulate — the generator's rates are the
    estimator's fixed point; see the estimator's unbiasedness note).
    ``graph`` (optional) only decorates reposts with a plausible origin.
    """
    rng = np.random.default_rng(seed)
    pt, pu = _poisson_ticks(activity.lam, horizon, t0, rng)
    rt, ru = _poisson_ticks(activity.mu, horizon, t0, rng)
    ro = _repost_origins(graph, ru, rng)
    events = [Post(float(t), int(u)) for t, u in zip(pt, pu)]
    events += [Repost(float(t), int(u), int(o))
               for t, u, o in zip(rt, ru, ro)]
    return ReplayLog.from_events(events)


def burst_stream(activity: Activity, horizon: float, *,
                 burst_users: np.ndarray, burst_factor: float = 8.0,
                 window: tuple[float, float] | None = None, seed: int = 0,
                 t0: float = 0.0, graph: Graph | None = None) -> ReplayLog:
    """Piecewise-constant posting burst over a stationary background.

    ``burst_users`` post at ``burst_factor · λ`` inside ``window``
    (default: the middle third of the horizon) — the scenario that
    exercises the estimator's half-life: short half-lives track the burst,
    long ones smooth it toward the time-average.
    """
    rng = np.random.default_rng(seed)
    base = poisson_stream(activity, horizon, seed=seed + 1, t0=t0,
                          graph=graph)
    w0, w1 = window if window is not None else (t0 + horizon / 3.0,
                                                t0 + 2.0 * horizon / 3.0)
    users = np.asarray(burst_users, np.int64).reshape(-1)
    extra_rate = activity.lam[users] * max(0.0, burst_factor - 1.0)
    bt, bi = _poisson_ticks(extra_rate, w1 - w0, w0, rng)
    extra = [Post(float(t), int(users[i])) for t, i in zip(bt, bi)]
    return ReplayLog.from_events(list(base) + extra)


def flash_crowd_stream(graph: Graph, activity: Activity, horizon: float, *,
                       celebrity: int | None = None,
                       new_followers: int = 64, storm_mu: float = 4.0,
                       churn: float = 0.25,
                       window: tuple[float, float] | None = None,
                       seed: int = 0, t0: float = 0.0) -> ReplayLog:
    """Graph-churn scenario: a flash crowd forms around one celebrity.

    Inside ``window`` (default: middle third), ``new_followers`` users who
    do not yet follow ``celebrity`` (default: the max in-degree node) emit
    ``Follow`` events at uniform times and run a repost storm (extra
    reposts of the celebrity at rate ``storm_mu``). After the window a
    ``churn`` fraction of them emits ``Unfollow`` tombstones. The
    background is the stationary :func:`poisson_stream` of ``activity``.
    """
    rng = np.random.default_rng(seed)
    if celebrity is None:
        celebrity = int(np.argmax(graph.in_degree))
    w0, w1 = window if window is not None else (t0 + horizon / 3.0,
                                                t0 + 2.0 * horizon / 3.0)
    already = set(graph.followers_of(celebrity).tolist()) | {celebrity}
    pool = np.asarray([u for u in range(graph.n) if u not in already],
                      np.int64)
    fans = rng.permutation(pool)[:min(new_followers, pool.size)]
    follow_t = np.sort(w0 + rng.random(fans.size) * (w1 - w0))
    events: list = [Follow(float(t), int(u), int(celebrity))
                    for t, u in zip(follow_t, fans)]
    # repost storm: each fan re-posts the celebrity at storm_mu from the
    # moment it follows until the window closes
    for t_f, u in zip(follow_t, fans):
        k = rng.poisson(storm_mu * max(0.0, w1 - t_f))
        ts = t_f + rng.random(k) * max(1e-12, w1 - t_f)
        events += [Repost(float(t), int(u), int(celebrity)) for t in ts]
    # churn: a fraction of the crowd unfollows after the window
    n_churn = int(round(churn * fans.size))
    churners = rng.permutation(fans)[:n_churn]
    churn_t = w1 + rng.random(n_churn) * max(1e-12, t0 + horizon - w1)
    events += [Unfollow(float(t), int(u), int(celebrity))
               for t, u in zip(churn_t, churners)]
    base = poisson_stream(activity, horizon, seed=seed + 1, t0=t0,
                          graph=graph)
    return ReplayLog.from_events(list(base) + events)


def tenant_interleave(sources: dict[str, EventSource]) -> ReplayLog:
    """Merge per-tenant sources into one time-ordered TenantEvent log."""
    events = [TenantEvent(tid, ev) for tid, src in sources.items()
              for ev in src]
    return ReplayLog.from_events(events)
