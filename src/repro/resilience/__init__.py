"""Fault injection, health sentinels, supervised resolves, and
crash-consistent recovery for the ψ serving stack (see docs/RESILIENCE.md).

Layered like the failures it handles:

* :mod:`~repro.resilience.faults` — the seeded chaos harness
  (:class:`FaultPlan` → :class:`FaultClock` → production hook points).
* :mod:`~repro.resilience.health` — numerical sentinels (non-finite,
  α = ‖M‖₁ ≥ 1, gap growth, certificate storms) + quarantine wrappers.
* :mod:`~repro.resilience.supervisor` — :class:`ResilientResolver`'s
  deadline/retry/escalation ladder ending in tagged degraded serving.
* :mod:`~repro.resilience.recovery` — whole-stack checkpoints + exactly-
  once replay back to the fault-free fixed point.
* :mod:`~repro.resilience.check` — the end-to-end chaos acceptance gate
  (``python -m repro.resilience.check``).
"""
from .faults import POISON_KINDS, FaultClock, FaultPlan, FaultyFeed
from .health import (LaneQuarantine, Sentinels, SentinelTrip, ServiceGuard,
                     alpha_norm, psi_residual_bound)
from .recovery import (ExactlyOnceReplay, RecoveredStack, StackCheckpointer,
                       reconcile, recover)
from .supervisor import (AttemptTimeout, ResilienceReport, ResilientResolver,
                         ResolveFailure, ResolveOutcome, SentinelFailure)

__all__ = [
    "FaultPlan", "FaultClock", "FaultyFeed", "POISON_KINDS",
    "SentinelTrip", "Sentinels", "alpha_norm", "psi_residual_bound",
    "LaneQuarantine", "ServiceGuard",
    "ResilientResolver", "ResolveOutcome", "ResilienceReport",
    "ResolveFailure", "AttemptTimeout", "SentinelFailure",
    "ExactlyOnceReplay", "StackCheckpointer", "RecoveredStack",
    "recover", "reconcile",
]
