"""Seeded end-to-end chaos acceptance check (CI smoke gate).

Runs the same streaming workload twice — once fault-free (the oracle),
once under a seeded :class:`~repro.resilience.faults.FaultPlan` that
injects worker crashes, forced-stale reads, a torn stack checkpoint, a
NaN-poisoned patch, and a duplicated/reordered/dropped event feed — then
crashes the faulted stack mid-stream, recovers it from its newest
*complete* checkpoint, replays the log suffix exactly-once, and demands
**fixed-point parity**: the recovered stack's ψ must match the fault-free
run's to solver precision (f64: ``max|Δψ| ≤ 1e-12``). It also exercises
the supervisor ladder deterministically (a transient hang that a retry
absorbs, then a permanent hang that degrades to a staleness-tagged
last-known-good answer) and asserts the final
:class:`~repro.resilience.supervisor.ResilienceReport` shows **zero
unsurvived faults**.

Run (CI uses exactly this)::

    JAX_ENABLE_X64=1 PYTHONPATH=src python -m repro.resilience.check

Under f32 (no x64 flag) the parity threshold relaxes to the f32 noise
floor; the fault schedule is identical either way.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time

import numpy as np
import jax.numpy as jnp
from jax import dtypes

from ..asyncexec.executor import AsyncPsiDriver
from ..core import heterogeneous
from ..graphs import powerlaw_configuration
from ..stream.events import flash_crowd_stream
from ..stream.freshness import FreshnessPolicy
from ..stream.ingest import StreamIngestor
from .faults import FaultPlan
from .recovery import ExactlyOnceReplay, StackCheckpointer, recover, reconcile
from .supervisor import ResilienceReport, ResilientResolver

__all__ = ["run_chaos", "main"]

X64 = dtypes.canonicalize_dtype(np.float64) == np.float64
# no mid-stream solves: the check drives flush/solve boundaries itself
_NO_RESOLVE = FreshnessPolicy(coalesce=32, resolve_every=10 ** 9)


def _fresh_stack(graph, activity, *, num_chunks, tau, dtype,
                 read_hook=None, ckpt_dir=None):
    driver = AsyncPsiDriver(graph, activity, num_chunks=num_chunks, tau=tau,
                            dtype=dtype, ckpt_dir=ckpt_dir,
                            read_hook=read_hook)
    ing = StreamIngestor(driver, policy=_NO_RESOLVE)
    return driver, ing


def run_chaos(*, n: int = 300, m: int = 1800, horizon: float = 4.0,
              seed: int = 0, num_chunks: int = 4, tau: int = 2,
              solver_tol: float | None = None,
              psi_tol: float | None = None,
              workdir: str | None = None) -> tuple[ResilienceReport, dict]:
    """One full chaos scenario; returns (report, metrics) and raises
    AssertionError on any violated resilience contract."""
    if solver_tol is None:
        solver_tol = 1e-13 if X64 else 1e-6
    if psi_tol is None:
        psi_tol = 1e-12 if X64 else 2e-4
    dtype = jnp.float64 if X64 else jnp.float32
    tmp_ctx = tempfile.TemporaryDirectory() if workdir is None else None
    root = tmp_ctx.name if tmp_ctx else workdir

    g = powerlaw_configuration(n, m, seed=seed + 50)
    act = heterogeneous(g.n, seed=seed + 51)
    log = flash_crowd_stream(g, act, horizon, seed=seed + 52)
    total = len(log)

    # ---- oracle: the fault-free fixed point -------------------------- #
    t0 = time.perf_counter()
    drv_o, ing_o = _fresh_stack(g, act, num_chunks=num_chunks, tau=tau,
                                dtype=dtype)
    ing_o.ingest(log, resolve_at_end=False)
    ing_o.flush()
    reconcile(drv_o, ing_o)
    rep_o = drv_o.run(tol=solver_tol, max_iter=4000, warm=True)
    assert rep_o.converged, "oracle run failed to converge"
    psi_ref = np.asarray(rep_o.psi, np.float64)
    oracle_wall = time.perf_counter() - t0

    # ---- chaos: same workload under a seeded fault schedule ---------- #
    plan = FaultPlan(seed=seed, crash_every=13, stale_chunk=1, stale_lag=8,
                     torn_after_saves=1, poison_kind="nan",
                     dup_every=41, drop_every=53, reorder_window=5)
    clock = plan.clock()
    t0 = time.perf_counter()
    drv_c, ing_c = _fresh_stack(g, act, num_chunks=num_chunks, tau=tau,
                                dtype=dtype)
    stack_dir = f"{root}/stack_ckpt"
    stacker = StackCheckpointer(stack_dir, keep=3)

    cut = int(total * 0.75)                     # the "process dies" point
    ckpt_every_ev = max(20, total // 6)
    replay1 = ExactlyOnceReplay(log, clock.wrap_source(log))
    delivered, step = 0, 0
    for ev in replay1:
        assert ev is log[delivered], (
            f"exactly-once prefix broke at event {delivered}")
        ing_c.submit(ev)
        delivered += 1
        if delivered % ckpt_every_ev == 0 and delivered <= cut:
            step += 1
            stacker.save(step, drv_c, ing_c)
        if delivered >= cut:
            break                               # crash: drop all live state
    assert step >= 2, f"need >=2 checkpoints before the crash; got {step}"
    del drv_c, ing_c

    # tear the newest checkpoint (torn write) before recovery touches it
    assert clock.tear_checkpoint(stack_dir), "tear did not fire"

    stack = recover(stack_dir, dtype=dtype, policy=_NO_RESOLVE,
                    ckpt_dir=f"{root}/driver_ckpt",
                    read_hook=clock.read_hook())
    assert stack.step < step, (
        f"recovery used the torn step {step}; expected a fallback")
    clock.note_survived("torn_ckpt", clock.injected["torn_ckpt"])
    assert stack.offset == stack.step * ckpt_every_ev

    # replay the un-applied suffix through the same corrupted transport
    replay2 = ExactlyOnceReplay(
        log, clock.wrap_source(log, start=stack.offset), start=stack.offset)
    suffix = []
    for ev in replay2:
        suffix.append(ev)
        stack.ingestor.submit(ev)
    stack.ingestor.flush()
    assert suffix == list(log)[stack.offset:], "exactly-once suffix mismatch"
    for kind in ("dup", "reorder", "drop"):     # delivery parity proven
        clock.note_survived(kind, clock.injected[kind])

    # a NaN-poisoned patch must die at the validation wall
    users = np.arange(min(8, g.n))
    pu, pl, pm = clock.poison_patch(users, stack.driver.host.lam[users],
                                    stack.driver.host.mu[users])
    try:
        stack.driver.patch_activity(pu, lam=pl, mu=pm)
        raise AssertionError("poisoned patch was accepted")
    except ValueError:
        clock.note_survived("poison", clock.injected["poison"])

    # converge under periodic crash+restore, then the supervised resolve
    reconcile(stack.driver, stack.ingestor)
    rep_c = stack.driver.run(tol=solver_tol, max_iter=4000, warm=True,
                             fail_hook=clock.fail_hook())
    assert rep_c.converged, "chaos run failed to converge under crashes"
    assert rep_c.restarts >= 1, "crash schedule never fired"
    resolver = ResilientResolver(stack.driver, tol=solver_tol,
                                 max_iter=4000, attempt_deadline_s=120.0)
    out = resolver.resolve(warm=True)
    assert not out.degraded and out.escalation == "none"
    psi_chaos = np.asarray(out.psi, np.float64)
    chaos_wall = time.perf_counter() - t0

    parity_err = float(np.abs(psi_chaos - psi_ref).max())
    assert parity_err <= psi_tol, (
        f"recovered fixed point drifted: max|dpsi| = {parity_err:.3e} "
        f"> {psi_tol:g}")
    # parity is the proof the crash/staleness defenses worked
    clock.note_survived("crash", clock.injected["crash"])
    clock.note_survived("stale_read", clock.injected["stale_read"])

    # ---- supervisor ladder: transient hang -> retry; permanent -> ---- #
    # ---- degraded serving with an honest staleness tag --------------- #
    clock2 = FaultPlan(seed=seed + 1, hang_chunk=0, hang_epoch=1,
                       hang_delay_s=1.0).clock()
    inner = clock2.delay_hook()
    hang_budget = [0]                         # how many more calls hang

    def gated(chunk: int, epoch: int) -> float:
        if hang_budget[0] > 0:
            d = inner(chunk, epoch)
            if d:
                hang_budget[0] -= 1
            return d
        return 0.0

    drv_h = AsyncPsiDriver(g, act, num_chunks=2, tau=1, dtype=dtype,
                           delay_hook=gated)
    sup = ResilientResolver(drv_h, tol=1e-6, max_iter=2000,
                            attempt_deadline_s=None, max_retries=1,
                            backoff_s=0.01, allow_rechunk=False,
                            allow_sync=False)
    first = sup.resolve(warm=False)           # healthy: seeds last-known-good
    assert not first.degraded
    sup.attempt_deadline_s = 0.35
    hang_budget[0] = 1                        # one timed-out attempt, then ok
    retried = sup.resolve(warm=True)
    assert not retried.degraded and retried.escalation == "retry"
    assert sup.report.recoveries >= 1 and sup.report.mttr_samples
    hang_budget[0] = 10 ** 9                  # wedged for good
    sup.max_retries = 0
    degraded = sup.resolve(warm=True)
    assert degraded.degraded and degraded.escalation == "degraded"
    assert degraded.freshness is not None
    assert degraded.freshness.staleness_seconds >= 0.0
    assert degraded.psi_error_bound is not None
    assert np.isfinite(degraded.psi_error_bound)
    assert degraded.ranking.err_bound == degraded.psi_error_bound
    hang_budget[0] = 0
    clock2.note_survived("hang", clock2.injected["hang"])

    # ---- the ledger -------------------------------------------------- #
    report = ResilienceReport()
    report.merge_clock(clock).merge_clock(clock2)
    for r in (resolver.report, sup.report):
        report.retries += r.retries
        report.escalations += r.escalations
        report.degraded_served += r.degraded_served
        report.recoveries += r.recoveries
        report.mttr_samples += r.mttr_samples

    for kind in ("crash", "stale_read", "torn_ckpt", "poison",
                 "dup", "reorder", "drop", "hang"):
        assert report.injected.get(kind, 0) >= 1, (
            f"fault class {kind!r} never injected — the schedule is broken")
    assert not report.unsurvived, f"unsurvived faults: {report.unsurvived}"

    metrics = dict(
        n=g.n, m=g.m, events=total, offset=stack.offset,
        dtype="float64" if X64 else "float32",
        solver_tol=solver_tol, psi_tol=psi_tol, parity_err=parity_err,
        oracle_wall_s=oracle_wall, chaos_wall_s=chaos_wall,
        recovery_overhead=chaos_wall / max(oracle_wall, 1e-9),
        restarts=int(rep_c.restarts), recovered_step=stack.step,
        refetched=replay1.refetched + replay2.refetched,
        duplicates_suppressed=(replay1.duplicates_suppressed
                               + replay2.duplicates_suppressed),
        mttr_s=report.mttr_s, degraded_served=report.degraded_served,
    )
    if tmp_ctx is not None:
        tmp_ctx.cleanup()
    return report, metrics


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="seeded chaos acceptance check for the psi stack")
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--m", type=int, default=1800)
    ap.add_argument("--horizon", type=float, default=4.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--psi-tol", type=float, default=None)
    ap.add_argument("--json", type=str, default=None,
                    help="dump metrics to this path")
    args = ap.parse_args(argv)

    print(f"[resilience-check] dtype={'float64' if X64 else 'float32'} "
          f"n={args.n} m={args.m} horizon={args.horizon} seed={args.seed}")
    try:
        report, metrics = run_chaos(n=args.n, m=args.m,
                                    horizon=args.horizon, seed=args.seed,
                                    psi_tol=args.psi_tol)
    except AssertionError as e:
        print(f"[resilience-check] FAIL: {e}")
        return 1
    print(f"[resilience-check] events={metrics['events']} "
          f"recovered@offset={metrics['offset']} "
          f"restarts={metrics['restarts']} "
          f"parity_err={metrics['parity_err']:.3e} "
          f"(tol {metrics['psi_tol']:g})")
    print(f"[resilience-check] oracle={metrics['oracle_wall_s']:.2f}s "
          f"chaos={metrics['chaos_wall_s']:.2f}s "
          f"overhead={metrics['recovery_overhead']:.2f}x "
          f"mttr={metrics['mttr_s'] * 1e3:.0f}ms")
    print(report.summary())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(dict(metrics=metrics,
                           injected=dict(report.injected),
                           survived=dict(report.survived)), f, indent=2)
    print("[resilience-check] PASS")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
