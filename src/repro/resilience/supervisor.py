"""Self-healing resolve supervision: deadlines, retries, escalation,
degraded serving.

:class:`ResilientResolver` wraps an :class:`~repro.asyncexec.executor
.AsyncPsiDriver`'s resolve path so that *no single fault makes a query
unanswerable*. Each resolve climbs an escalation ladder, stopping at the
first rung that produces a healthy converged fixed point:

1. **retry** — up to ``1 + max_retries`` async attempts, each under a
   per-attempt wall-clock deadline (a ``threading.Timer`` cooperatively
   cancels the scheduler — a hung chunk cannot hold the deadline hostage)
   with bounded exponential backoff between attempts.
2. **rechunk / τ-tighten** — rebuild the pipeline with ``tau = 0`` (the
   barriered schedule: no staleness, no certificate rejections; the board
   carries over warm through ``rechunk``'s exact host sharing).
3. **async → sync sweep** — abandon overlap entirely: one synchronous
   ``reference``-engine solve from the current host operators. No thread
   pool, no staleness — the most boring possible execution.
4. **serve degraded** — give up on *this* resolve and serve the last known
   good fixed point, honestly tagged: the outcome's freshness report
   carries the wall-clock staleness and the last good solve's certified
   ``psi_error_bound`` (:func:`~repro.resilience.health.psi_residual_bound`),
   flowing through the same :class:`~repro.core.incremental.RankingCache` /
   ``FreshnessReport.certify`` machinery every fresh answer uses. A
   degraded answer is never silently passed off as fresh.

Every resolve's health is sentinel-checked (non-finite ψ/gap, runaway gap,
certificate storms) before it is accepted — a fast wrong answer is a
failure, not a success. The resolver accumulates a
:class:`ResilienceReport`; ``launch/serve.py --chaos`` prints one.
"""
from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from ..core.incremental import RankingCache
from ..obs import log as obs_log
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..stream.freshness import FreshnessReport
from .health import Sentinels, psi_residual_bound

__all__ = ["ResilientResolver", "ResolveOutcome", "ResilienceReport",
           "ResolveFailure", "AttemptTimeout", "SentinelFailure"]


class ResolveFailure(RuntimeError):
    """One resolve attempt failed (did not converge within its budget)."""


class AttemptTimeout(ResolveFailure):
    """The per-attempt deadline cancelled the scheduler."""


class SentinelFailure(ResolveFailure):
    """The attempt produced a result a health sentinel refused."""


@dataclasses.dataclass
class ResolveOutcome:
    """What one supervised resolve actually served."""

    ranking: RankingCache            # the served fixed point (+ err_bound)
    degraded: bool                   # True ⇒ last-known-good, not fresh
    escalation: str                  # 'none'|'retry'|'rechunk'|'sync'|'degraded'
    attempts: int                    # attempts consumed (all rungs)
    psi_error_bound: float | None    # certified |ψ_exact − ψ_served| bound
    freshness: FreshnessReport | None = None   # staleness tag (degraded ⇒ set)
    report: object | None = None     # the winning attempt's driver report

    @property
    def psi(self) -> np.ndarray:
        return self.ranking.psi


@dataclasses.dataclass
class ResilienceReport:
    """Fleet-level chaos accounting: what was injected, what survived, and
    what surviving cost. ``injected``/``survived`` are per-fault-class
    counters (usually a :class:`~repro.resilience.faults.FaultClock`'s);
    the rest is the supervisor's own ledger."""

    injected: dict = dataclasses.field(default_factory=dict)
    survived: dict = dataclasses.field(default_factory=dict)
    retries: int = 0
    escalations: list = dataclasses.field(default_factory=list)
    preemptions: list = dataclasses.field(default_factory=list)
    degraded_served: int = 0
    recoveries: int = 0
    mttr_samples: list = dataclasses.field(default_factory=list)

    @property
    def mttr_s(self) -> float:
        """Mean time-to-recovery over incidents that recovered (0 if none)."""
        return (float(np.mean(self.mttr_samples))
                if self.mttr_samples else 0.0)

    @property
    def unsurvived(self) -> dict:
        """Fault classes with injected > survived — must be empty for a
        passing chaos run."""
        out = {}
        for kind, n in dict(self.injected).items():
            missing = int(n) - int(self.survived.get(kind, 0))
            if missing > 0:
                out[kind] = missing
        return out

    def merge_clock(self, clock) -> "ResilienceReport":
        """Fold a FaultClock's counters into this report (additive)."""
        for k, v in clock.injected.items():
            self.injected[k] = self.injected.get(k, 0) + int(v)
        for k, v in clock.survived.items():
            self.survived[k] = self.survived.get(k, 0) + int(v)
        return self

    def summary(self) -> str:
        lines = ["ResilienceReport"]
        kinds = sorted(set(self.injected) | set(self.survived))
        for kind in kinds:
            i = int(self.injected.get(kind, 0))
            s = int(self.survived.get(kind, 0))
            mark = "ok" if s >= i else f"UNSURVIVED x{i - s}"
            lines.append(f"  {kind:<12} injected={i:<4d} survived={s:<4d} "
                         f"[{mark}]")
        lines.append(f"  retries={self.retries} "
                     f"escalations={self.escalations or '[]'} "
                     f"preemptions={self.preemptions or '[]'} "
                     f"degraded_served={self.degraded_served} "
                     f"recoveries={self.recoveries} "
                     f"mttr={self.mttr_s * 1e3:.1f}ms")
        return "\n".join(lines)


class ResilientResolver:
    """Supervised resolve path over an ``AsyncPsiDriver`` (see module doc).

    Args:
      driver: the async driver to supervise (replaced in place when the
        rechunk rung fires — read it back via ``.driver``).
      tol / max_iter: the convergence contract each attempt must meet.
      attempt_deadline_s: per-attempt wall-clock budget (None = no
        deadline; attempts are then bounded only by ``max_iter``).
      max_retries: extra same-configuration attempts before escalating.
      backoff_s / backoff_factor: exponential backoff between retries.
      allow_rechunk / allow_sync: enable ladder rungs 2 and 3.
      sentinels: health checks applied to every candidate result.
      freshness_fn: optional ``() -> FreshnessReport`` (e.g. a
        ``StreamIngestor.freshness``) used to tag degraded answers with
        real stream staleness; without it a wall-clock-staleness report is
        synthesized.
      watch: optional :class:`~repro.obs.watch.ConvergenceWatch` — its
        latched advice is consumed at the top of every resolve and can
        *pre-empt* the ladder: ``tighten_tau`` re-chunks to τ = 0 before
        the first attempt (ahead of a certificate storm tripping the
        sentinel), ``sync_sweep`` goes straight to the synchronous rung
        (ahead of an α-drift / plateau trip). The watch also digests
        every attempt's driver report and failures, closing the loop.
    """

    def __init__(self, driver, *, tol: float = 1e-8, max_iter: int = 2000,
                 attempt_deadline_s: float | None = 30.0,
                 max_retries: int = 2, backoff_s: float = 0.05,
                 backoff_factor: float = 2.0, allow_rechunk: bool = True,
                 allow_sync: bool = True,
                 sentinels: Sentinels | None = None,
                 freshness_fn=None, watch=None):
        self.driver = driver
        self.tol = float(tol)
        self.max_iter = int(max_iter)
        self.attempt_deadline_s = attempt_deadline_s
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.backoff_factor = float(backoff_factor)
        self.allow_rechunk = allow_rechunk
        self.allow_sync = allow_sync
        self.sentinels = sentinels or Sentinels()
        self.freshness_fn = freshness_fn
        self.watch = watch
        self.report = ResilienceReport()
        self._last_good: RankingCache | None = None
        self._last_good_wall: float = time.time()

    # -- one supervised resolve ------------------------------------------ #
    def resolve(self, *, warm: bool = True) -> ResolveOutcome:
        obs_metrics.counter(
            "psi_resilience_resolves_total",
            "supervised resolves (degraded-ratio denominator)").inc()
        with obs_trace.span("resilience.resolve"):
            return self._resolve(warm=warm)

    def _resolve(self, *, warm: bool) -> ResolveOutcome:
        attempts = 0
        first_failure: float | None = None
        failures: list[str] = []

        # rung 0: pre-emptive action the watch advised before anything
        # has failed — act while the run is still healthy, not after
        advice = (self.watch.consume_advice()
                  if self.watch is not None else None)
        if advice:
            if (advice.tighten_tau and self.allow_rechunk
                    and getattr(self.driver, "tau", 0) > 0):
                self._note_preemption("rechunk", advice.reasons)
                self.driver = self.driver.rechunk(
                    self.driver.num_chunks, tau=0)
            elif advice.sync_sweep and self.allow_sync:
                self._note_preemption("sync", advice.reasons)
                attempts += 1
                try:
                    rep = self._attempt_sync()
                    return self._accept(rep, attempts, None, "none")
                except ResolveFailure as e:
                    failures.append(f"preemptive sync: {e}")
                    first_failure = obs_trace.now()

        # rung 1: retry with backoff
        for i in range(1 + self.max_retries):
            if i:
                self.report.retries += 1
                obs_metrics.counter(
                    "psi_resilience_retries_total",
                    "same-configuration resolve retries (ladder rung 1)",
                ).inc()
                time.sleep(self.backoff_s * self.backoff_factor ** (i - 1))
            attempts += 1
            try:
                rep = self._attempt_async(warm=warm)
                return self._accept(rep, attempts, first_failure,
                                    "none" if not failures else "retry")
            except ResolveFailure as e:
                failures.append(f"attempt {attempts}: {e}")
                first_failure = first_failure or obs_trace.now()

        # rung 2: rechunk with τ = 0 (barriered — no staleness to certify)
        if self.allow_rechunk:
            self._note_escalation("rechunk")
            self.driver = self.driver.rechunk(self.driver.num_chunks, tau=0)
            attempts += 1
            try:
                rep = self._attempt_async(warm=True)   # board carried over
                return self._accept(rep, attempts, first_failure, "rechunk")
            except ResolveFailure as e:
                failures.append(f"rechunk: {e}")

        # rung 3: synchronous sweep (no pool, no staleness, no overlap)
        if self.allow_sync:
            self._note_escalation("sync")
            attempts += 1
            try:
                rep = self._attempt_sync()
                return self._accept(rep, attempts, first_failure, "sync")
            except ResolveFailure as e:
                failures.append(f"sync: {e}")

        # rung 4: serve degraded from the last known good fixed point
        return self._degrade(attempts, failures)

    def _note_escalation(self, rung: str) -> None:
        self.report.escalations.append(rung)
        obs_metrics.counter(
            "psi_resilience_escalations_total",
            "ladder escalations past the retry rung", ["rung"],
        ).labels(rung=rung).inc()
        obs_log.event("resolve_escalation",
                      f"resolve escalated to the {rung} rung",
                      level="warning", rung=rung)

    def _note_preemption(self, action: str, reasons: tuple) -> None:
        self.report.preemptions.append(action)
        obs_metrics.counter(
            "psi_resilience_preemptions_total",
            "watch-advised actions taken before any failure", ["action"],
        ).labels(action=action).inc()
        obs_log.event("resolve_preempted",
                      f"watch advice pre-empted the ladder: {action} "
                      f"(reasons: {', '.join(reasons) or 'unspecified'})",
                      action=action, reasons=list(reasons))

    # -- attempts --------------------------------------------------------- #
    def _attempt_async(self, *, warm: bool):
        sched = self.driver.sched
        timer = None
        if self.attempt_deadline_s is not None:
            timer = threading.Timer(self.attempt_deadline_s, sched.cancel)
            timer.daemon = True
            timer.start()
        try:
            rep = self.driver.run(tol=self.tol, max_iter=self.max_iter,
                                  warm=warm)
        finally:
            if timer is not None:
                timer.cancel()
        if self.watch is not None:
            self.watch.observe_report(rep)
        if not rep.converged and sched.cancelled:
            if self.watch is not None:
                self.watch.observe_failure(
                    "timeout", f"deadline {self.attempt_deadline_s}s")
            raise AttemptTimeout(
                f"deadline {self.attempt_deadline_s}s cancelled the "
                f"scheduler at gap {rep.gap:.3g}")
        trip = self.sentinels.check_report(rep)
        if trip is not None:
            raise SentinelFailure(str(trip))
        if not rep.converged:
            raise ResolveFailure(f"epoch budget exhausted at gap "
                                 f"{rep.gap:.3g} > tol {self.tol:g}")
        return rep

    def _attempt_sync(self):
        from ..core.engine import make_engine
        host = self.driver.host
        eng = make_engine("reference", graph=host.graph(),
                          activity=host.activity(), dtype=self.driver.dtype)
        res = eng.run(tol=self.tol, max_iter=self.max_iter)
        trip = self.sentinels.check_array("psi", res.psi)
        if trip is not None:
            raise SentinelFailure(str(trip))
        if not bool(res.converged):
            raise ResolveFailure(f"sync sweep exhausted max_iter at gap "
                                 f"{float(res.gap):.3g}")
        # the engine's gap is Eq. 19-scaled (·‖B‖); the residual bound
        # wants the raw l1 step — unscale through the host's b_norm
        b = host.b_norm
        raw_gap = float(res.gap) / b if b > 0 else 0.0
        return _SyncResult(psi=np.asarray(res.psi), gap=raw_gap,
                           converged=True)

    # -- outcomes --------------------------------------------------------- #
    def _accept(self, rep, attempts: int, first_failure: float | None,
                escalation: str) -> ResolveOutcome:
        bound = psi_residual_bound(self.driver.host, float(rep.gap))
        cache = RankingCache(np.asarray(rep.psi), err_bound=bound)
        self._last_good = cache
        self._last_good_wall = time.time()
        if bound is not None:
            obs_metrics.gauge(
                "psi_certified_error_bound",
                "Eq. 19 certified sup-norm bound of the last served "
                "answer").set(bound)
        if first_failure is not None:
            # MTTR on the shared span clock: first failure → first accepted
            # answer (the same measurement ResilienceReport.mttr_s averages)
            mttr = obs_trace.now() - first_failure
            self.report.recoveries += 1
            self.report.mttr_samples.append(mttr)
            obs_metrics.histogram(
                "psi_resilience_mttr_seconds",
                "first failure to first accepted answer, per incident",
            ).observe(mttr)
            obs_log.event("resolve_recovered",
                          f"resolve recovered via {escalation} "
                          f"after {mttr * 1e3:.1f}ms", escalation=escalation)
        return ResolveOutcome(ranking=cache, degraded=False,
                              escalation=escalation, attempts=attempts,
                              psi_error_bound=bound, report=rep)

    def _degrade(self, attempts: int, failures: list[str]) -> ResolveOutcome:
        if self._last_good is None:
            raise ResolveFailure(
                "every ladder rung failed and no previous fixed point "
                "exists to degrade to:\n  " + "\n  ".join(failures))
        self._note_escalation("degraded")
        self.report.degraded_served += 1
        obs_metrics.counter(
            "psi_resilience_degraded_served_total",
            "answers served from the last known good fixed point",
        ).inc()
        bound = self._last_good.err_bound
        if bound is not None:
            obs_metrics.gauge(
                "psi_certified_error_bound",
                "Eq. 19 certified sup-norm bound of the last served "
                "answer").set(bound)
        now = time.time()
        if self.freshness_fn is not None:
            fr = dataclasses.replace(self.freshness_fn(),
                                     psi_error_bound=bound)
        else:
            # wall-clock staleness tag: the served point is this many real
            # seconds old, with the bound it was certified with back then
            fr = FreshnessReport(
                event_time=now, resolve_time=self._last_good_wall,
                events_total=0, events_buffered=0, events_unresolved=0,
                dirty_users=0, dirty_mass=0.0, resolves=0,
                psi_error_bound=bound)
        return ResolveOutcome(ranking=self._last_good, degraded=True,
                              escalation="degraded", attempts=attempts,
                              psi_error_bound=bound, freshness=fr,
                              report=None)


@dataclasses.dataclass(frozen=True)
class _SyncResult:
    """Duck-typed driver report for the sync-sweep rung (raw-gap field)."""

    psi: np.ndarray
    gap: float
    converged: bool
