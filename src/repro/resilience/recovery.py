"""Crash-consistent recovery of the whole serving stack, with exactly-once
event replay.

PR 4 gave the solver crash tolerance (epoch-vector checkpoints of the
async board); PR 5 gave the platform a replayable event log. This module
composes the two halves into the ROADMAP's "crash-recovery of the *whole*
serving stack":

* :class:`StackCheckpointer` — one atomic checkpoint of everything the
  stack cannot recompute: the async board + epoch vector, the mutable
  :class:`~repro.core.operators.HostOperators` mirror (rates, both sorted
  edge views, the float64 w/row_lam accumulators — bit-exact, because a
  rebuild from a re-exported graph would re-sum them in a different order),
  the :class:`~repro.stream.estimator.RateEstimator` state, and the event
  **offset**: how many events of the log are already reflected in all of
  the above. Checkpoints are only taken at *flushed* points (the save
  flushes first) so the offset cleanly partitions the log into
  applied-prefix / to-replay-suffix — no event is half-applied.
* :class:`ExactlyOnceReplay` — repairs an at-least-zero transport into
  exactly-once delivery: duplicate sequence numbers are suppressed,
  out-of-order arrivals are held in a reorder buffer, and dropped offsets
  are re-fetched from the authoritative :class:`~repro.stream.events
  .ReplayLog`. The delivered stream is provably ``log[start:]``, verbatim.
* :func:`recover` / :meth:`StackCheckpointer.recover` — rebuild the stack
  from the newest *complete* checkpoint (torn steps fall back, see
  ``ckpt.checkpoint``), replay ``log[offset:]`` through the exactly-once
  layer, and the result reaches the **same fixed point as the fault-free
  run**: the estimator state depends only on the event order (not on
  flush/crash boundaries), so after a :func:`reconcile` sweep the final
  operators agree to ulps and ψ to solver tolerance — the parity the
  chaos acceptance test (f64 ψ err ≤ 1e-12) measures.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np

from ..asyncexec.executor import AsyncPsiDriver
from ..ckpt import checkpoint
from ..core.activity import RATE_FLOOR
from ..core.operators import HostOperators
from ..stream.events import ReplayLog
from ..stream.freshness import FreshnessPolicy
from ..stream.ingest import StreamIngestor

__all__ = ["ExactlyOnceReplay", "StackCheckpointer", "RecoveredStack",
           "recover", "reconcile"]


class ExactlyOnceReplay:
    """Exactly-once delivery of ``log[start:]`` over a faulty (seq, event)
    feed (e.g. a :class:`~repro.resilience.faults.FaultyFeed`).

    Guarantee: iterating yields exactly the events ``log[start:]``, once
    each, in order — regardless of duplication, bounded reordering, or
    drops in the feed. Three mechanisms, one per failure mode:

    * **dedup**: a sequence number below the delivery cursor (or already
      buffered) is a duplicate — suppressed.
    * **reorder buffer**: a sequence number ahead of the cursor is held
      until the gap before it closes.
    * **re-fetch**: when the feed ends (or the buffer is drained) with
      gaps remaining, the missing offsets are read from the authoritative
      log — the "consumer re-reads the partition from its committed
      offset" half of exactly-once semantics. The log is the durable
      source of truth; the feed is just the lossy transport in front.

    Counters (``duplicates_suppressed`` / ``reordered_held`` /
    ``refetched``) are observability, not the correctness argument — the
    chaos check asserts delivery parity directly.
    """

    def __init__(self, log: ReplayLog, feed, *, start: int = 0):
        self.log = log
        self.feed = feed
        self.start = int(start)
        self.duplicates_suppressed = 0
        self.reordered_held = 0
        self.refetched = 0
        self.delivered = 0

    def __iter__(self) -> Iterator:
        cursor = self.start
        pending: dict[int, object] = {}
        for seq, ev in self.feed:
            seq = int(seq)
            if seq < cursor or seq in pending:
                self.duplicates_suppressed += 1
                continue
            if seq > cursor:
                self.reordered_held += 1
                pending[seq] = ev
                continue
            self.delivered += 1
            yield ev
            cursor += 1
            while cursor in pending:
                self.delivered += 1
                yield pending.pop(cursor)
                cursor += 1
        # feed exhausted: anything not delivered was dropped (or stuck
        # behind a drop in the buffer) — re-fetch from the log
        for seq in range(cursor, len(self.log)):
            if seq in pending:
                ev = pending.pop(seq)
            else:
                ev = self.log[seq]
                self.refetched += 1
            self.delivered += 1
            yield ev


@dataclasses.dataclass
class RecoveredStack:
    """What :func:`recover` hands back: a live driver + ingestor pair
    positioned at ``offset``, ready to replay ``log[offset:]``."""

    driver: AsyncPsiDriver
    ingestor: StreamIngestor
    step: int            # checkpoint step restored
    offset: int          # events already reflected in the restored state

    def replay(self, log: ReplayLog, feed=None, *,
               resolve: bool = False) -> ExactlyOnceReplay:
        """Replay the un-applied suffix exactly-once (``feed`` defaults to
        the pristine enumerated log — pass a FaultyFeed to exercise the
        transport-repair path)."""
        if feed is None:
            feed = ((seq, log[seq]) for seq in range(self.offset, len(log)))
        replay = ExactlyOnceReplay(log, feed, start=self.offset)
        for ev in replay:
            self.ingestor.submit(ev)
        self.ingestor.flush()
        if resolve:
            self.ingestor.resolve()
        return replay


class StackCheckpointer:
    """Atomic whole-stack checkpoints over ``ckpt.checkpoint``.

    One checkpoint = one flat array tree holding board + epochs + offset +
    host mirror + estimator state. ``save`` flushes the ingestor first
    (checkpoint-at-quiescence: the offset means "everything before me is
    fully applied, nothing after me is"), then writes atomically (tmp dir
    + fsynced manifest + rename) so a crash mid-save can only ever lose
    the step being written, never corrupt a previous one.
    """

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = int(keep)
        self.saves = 0

    def save(self, step: int, driver: AsyncPsiDriver,
             ingestor: StreamIngestor) -> str:
        ingestor.flush()                 # quiescence: offset is a clean cut
        host = driver.host
        est = ingestor.estimator()
        tree = dict(
            board=driver.chunked.node_order(driver.sched.board).copy(),
            epochs=driver.sched.epochs.copy(),
            offset=np.int64(ingestor.offset),
            event_t=np.float64(ingestor._event_t),
            num_chunks=np.int64(driver.num_chunks),
            tau=np.int64(driver.tau),
            host_n=np.int64(host.n),
            host_lam=host.lam.copy(), host_mu=host.mu.copy(),
            host_w=host.w.copy(), host_row_lam=host.row_lam.copy(),
            host_src_by_dst=host.src_by_dst.copy(),
            host_dst_by_dst=host.dst_by_dst.copy(),
            host_src_by_src=host.src_by_src.copy(),
            host_dst_by_src=host.dst_by_src.copy(),
            **{f"est_{k}": v for k, v in est.state_dict().items()},
        )
        path = checkpoint.save(self.directory, step, tree, keep=self.keep)
        self.saves += 1
        return path

    def recover(self, *, dtype=jnp.float32, half_life: float = 64.0,
                floor: float = RATE_FLOOR,
                policy: FreshnessPolicy | None = None,
                resolve_opts: dict | None = None,
                ckpt_dir: str | None = None,
                delay_hook=None, read_hook=None) -> RecoveredStack:
        return recover(self.directory, dtype=dtype, half_life=half_life,
                       floor=floor, policy=policy,
                       resolve_opts=resolve_opts, ckpt_dir=ckpt_dir,
                       delay_hook=delay_hook, read_hook=read_hook)


def recover(directory: str, *, dtype=jnp.float32, half_life: float = 64.0,
            floor: float = RATE_FLOOR,
            policy: FreshnessPolicy | None = None,
            resolve_opts: dict | None = None, ckpt_dir: str | None = None,
            delay_hook=None, read_hook=None) -> RecoveredStack:
    """Rebuild the serving stack from the newest complete checkpoint in
    ``directory`` (corrupt/torn steps are skipped with a warning — the
    hardened ``ckpt.checkpoint`` walkers do the falling back).

    Raises FileNotFoundError when no complete checkpoint exists at all —
    there is nothing principled to recover to, and inventing a cold state
    would silently violate the exactly-once contract.
    """
    step = checkpoint.latest_step(directory)
    if step is None:
        raise FileNotFoundError(
            f"no complete stack checkpoint in {directory}")
    data = checkpoint.load_arrays(directory, step)

    host = HostOperators(
        n=int(data["host_n"]),
        lam=np.asarray(data["host_lam"], np.float64),
        mu=np.asarray(data["host_mu"], np.float64),
        src_by_dst=np.asarray(data["host_src_by_dst"], np.int32),
        dst_by_dst=np.asarray(data["host_dst_by_dst"], np.int32),
        src_by_src=np.asarray(data["host_src_by_src"], np.int32),
        dst_by_src=np.asarray(data["host_dst_by_src"], np.int32),
        w=np.asarray(data["host_w"], np.float64),
        row_lam=np.asarray(data["host_row_lam"], np.float64),
    )
    driver = AsyncPsiDriver(
        host=host, num_chunks=int(data["num_chunks"]),
        tau=int(data["tau"]), dtype=dtype, ckpt_dir=ckpt_dir,
        delay_hook=delay_hook, read_hook=read_hook)
    # resume the *skewed* pipeline exactly: board + per-chunk epoch vector,
    # and stage the board as the next run's one-shot warm start so the
    # first post-recovery resolve continues from it (run() always resets)
    board = np.asarray(data["board"])
    driver.sched.reset(s0=board, epochs=np.asarray(data["epochs"], np.int64))
    driver._warm_s = board

    offset = int(data["offset"])
    event_t = float(data["event_t"])
    ingestor = StreamIngestor(driver, half_life=half_life, floor=floor,
                              policy=policy, t0=event_t,
                              resolve_opts=resolve_opts or {})
    est = ingestor.estimator()           # creates the lane…
    est.load_state({k.removeprefix("est_"): v
                    for k, v in data.items() if k.startswith("est_")})
    ingestor.fast_forward(offset, event_t=event_t)
    return RecoveredStack(driver=driver, ingestor=ingestor, step=int(step),
                          offset=offset)


def reconcile(driver: AsyncPsiDriver, ingestor: StreamIngestor) -> None:
    """Pin the operators to the estimator's full current rate vector.

    Estimator state is a pure function of the event order, but the
    *drained* rates also depend on when each drain happened — so two runs
    with different flush/crash boundaries hold operators that differ by
    decay-evaluation times even after ingesting identical streams. One
    full-width patch from ``est.activity()`` (both runs evaluate it at the
    same final event time) collapses that path dependence: after
    reconciliation the fault-free and the recovered stack solve the same
    operators, and fixed-point parity is exact rather than approximate.
    """
    est = ingestor.estimator()
    act = est.activity()
    driver.patch_activity(np.arange(driver.host.n), lam=act.lam, mu=act.mu)
