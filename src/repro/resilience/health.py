"""Numerical health sentinels + quarantine for the ψ serving stack.

The Power-ψ iteration is safe *because* it is a contraction: its iteration
matrix M (the left action of A) has induced l1 norm

    α = ‖M‖₁ = max_j Σ_{i∈L(j)} μ_i / w_j  < 1

whenever any leader set carries post rate (w_j ≥ Σ μ over leaders, with
equality only when every leader's λ is zero). Every convergence statement,
staleness certificate, and error bound in this codebase divides by (1−α) —
so the two things that can silently destroy the stack are (a) a non-finite
value entering the iterate/operators and (b) a patch pushing α to 1. This
module watches for exactly those, plus their downstream symptoms (a gap
that grows instead of contracting, a certificate-rejection storm), and
*quarantines* the offender instead of letting it propagate:

* :class:`Sentinels` — the checks themselves, returning a
  :class:`SentinelTrip` instead of raising (the caller decides the blast
  radius).
* :class:`LaneQuarantine` — wraps a ``TenantFleet``: a tripped lane
  freezes and keeps serving its last-known-good scores while every other
  tenant stays live.
* :class:`ServiceGuard` — wraps a ``PsiService``: rejected patches are
  counted and dropped; a post-resolve trip rolls the service back to the
  last complete checkpoint (rates + cold re-solve).

See docs/RESILIENCE.md for how these compose with the supervisor ladder.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..ckpt import checkpoint
from ..core.operators import HostOperators

__all__ = ["SentinelTrip", "Sentinels", "alpha_norm", "psi_residual_bound",
           "LaneQuarantine", "ServiceGuard"]


@dataclasses.dataclass(frozen=True)
class SentinelTrip:
    """One tripped sentinel: what fired, the value that fired it, context."""

    kind: str        # 'non_finite' | 'alpha' | 'gap_growth' | 'cert_storm'
    value: float
    detail: str

    def __str__(self) -> str:
        return f"[{self.kind}] {self.detail} (value={self.value:.6g})"


def alpha_norm(host: HostOperators) -> float:
    """α = ‖M‖₁ = max_j Σ_{i∈L(j)} μ_i / w_j of the *current* host mirror —
    the contraction modulus every certificate divides by. Computed exactly
    like ``HostOperators.b_norm`` but over μ (the iteration matrix) rather
    than λ (the epilogue matrix)."""
    if host.n == 0:
        return 0.0
    row_mu = np.zeros(host.n)
    np.add.at(row_mu, host.src_by_src, host.mu[host.dst_by_src])
    return float((row_mu * host.inv_w).max())


def psi_residual_bound(host: HostOperators, raw_gap: float) -> float | None:
    """Certified per-node ``|ψ_exact − ψ_served|`` from a measured raw l1
    gap ``‖s_{k+1} − s_k‖₁`` (Eq. 19, unscaled).

    Contraction gives ``‖s_k − s*‖₁ ≤ raw_gap / (1 − α)``; the epilogue
    ψᵀ = (sᵀB + dᵀ)/N then bounds each node by

        |ψ_i − ψ*_i| ≤ ‖s_k − s*‖₁ · max_{(j→i)∈E} (λ_i / w_j) / N.

    Returns None when no finite certificate exists (α ≥ 1, or a non-finite
    gap) — an honest "uncertifiable", never a wrong number. This is what
    tags degraded-mode answers (supervisor) with a ``psi_error_bound``.
    """
    a = alpha_norm(host)
    if not (np.isfinite(a) and a < 1.0 and np.isfinite(raw_gap)):
        return None
    if host.m == 0:
        return 0.0
    max_b = float(
        (host.lam[host.dst_by_src] * host.inv_w[host.src_by_src]).max())
    return float(raw_gap / (1.0 - a) * max_b / max(host.n, 1))


class Sentinels:
    """The health checks. Stateless except for the gap-growth window.

    Args:
      alpha_max: trip when post-patch α reaches this (default 1.0 — the
        hard divergence wall; serve-side wrappers may pass e.g. 0.999).
      gap_window: consecutive gap *increases* before the growth sentinel
        trips (a contraction's gap shrinks on average; K strict increases
        in a row means the operators are no longer contracting).
      cert_storm: rejected-certificate count in one run that trips the
        staleness sentinel (the pipeline keeps producing under-tol gaps
        that fail τ-validation — it is spinning, not converging).
    """

    def __init__(self, *, alpha_max: float = 1.0, gap_window: int = 8,
                 cert_storm: int = 50):
        self.alpha_max = float(alpha_max)
        self.gap_window = int(gap_window)
        self.cert_storm = int(cert_storm)
        self._gap_prev: float | None = None
        self._gap_rises = 0
        self.trips: list[SentinelTrip] = []

    def _trip(self, kind: str, value: float, detail: str) -> SentinelTrip:
        trip = SentinelTrip(kind, float(value), detail)
        self.trips.append(trip)
        return trip

    def reset_gap(self) -> None:
        self._gap_prev = None
        self._gap_rises = 0

    # -- checks (None = healthy) ----------------------------------------- #
    def check_array(self, name: str, arr) -> SentinelTrip | None:
        arr = np.asarray(arr)
        if arr.size and not np.all(np.isfinite(arr)):
            bad = int(np.sum(~np.isfinite(arr)))
            return self._trip("non_finite", float("nan"),
                              f"{bad} non-finite entries in {name}")
        return None

    def check_alpha(self, host: HostOperators) -> SentinelTrip | None:
        a = alpha_norm(host)
        if not np.isfinite(a) or a >= self.alpha_max:
            return self._trip("alpha", a,
                              f"post-patch α = ‖M‖₁ = {a:.6g} ≥ "
                              f"{self.alpha_max:g}: iteration no longer a "
                              "contraction")
        return None

    def check_gap(self, gap: float) -> SentinelTrip | None:
        if not np.isfinite(gap):
            return self._trip("non_finite", gap, "non-finite Eq. 19 gap")
        if self._gap_prev is not None and gap > self._gap_prev:
            self._gap_rises += 1
            if self._gap_rises >= self.gap_window:
                rises = self._gap_rises
                self.reset_gap()
                return self._trip("gap_growth", gap,
                                  f"Eq. 19 gap grew {rises} checks in a row")
        else:
            self._gap_rises = 0
        self._gap_prev = float(gap)
        return None

    def check_report(self, report) -> SentinelTrip | None:
        """Post-run triage of a driver/scheduler report: non-finite ψ or
        gap, then a certificate-rejection storm."""
        trip = self.check_array("psi", report.psi)
        if trip is None:
            trip = self.check_gap(float(report.gap))
        if trip is None:
            rej = int(getattr(report, "rejected_certificates", 0))
            if rej >= self.cert_storm:
                trip = self._trip("cert_storm", rej,
                                  f"{rej} under-tol certificates rejected "
                                  "for τ-violation in one run")
        return trip


# --------------------------------------------------------------------- #
# Quarantine wrappers
# --------------------------------------------------------------------- #
class LaneQuarantine:
    """Sentinel-guarded patch/serve surface over a :class:`TenantFleet`.

    A poisoned patch against one tenant must not take the fleet down: a
    patch that fails validation is dropped with the lane state untouched;
    a patch that passes validation but trips the α sentinel is *reverted*
    (the pre-patch rates are re-applied) — and in both cases the lane
    **freezes**: it keeps serving the scores it served last, while every
    other lane keeps patching and solving normally. ``unfreeze`` lifts the
    quarantine after the operator investigates.
    """

    def __init__(self, fleet, *, sentinels: Sentinels | None = None):
        self.fleet = fleet
        self.sentinels = sentinels or Sentinels()
        self._frozen: dict[str, np.ndarray] = {}   # tid → last-good ψ
        self.rejected_patches = 0
        self.reverted_patches = 0

    # -- state ----------------------------------------------------------- #
    @property
    def frozen(self) -> tuple:
        return tuple(sorted(self._frozen))

    def is_frozen(self, tenant_id: str) -> bool:
        return tenant_id in self._frozen

    def unfreeze(self, tenant_id: str) -> None:
        self._frozen.pop(tenant_id, None)

    def _freeze(self, tenant_id: str) -> None:
        if tenant_id not in self._frozen:
            # the lane state is healthy here (rejected patches never
            # mutated; reverted patches were rolled back) so the fleet's
            # own solve produces the last-known-good scores to pin
            self._frozen[tenant_id] = np.array(self.fleet.psi(tenant_id))

    # -- guarded mutations ------------------------------------------------ #
    def patch_activity(self, tenant_id: str, users, lam=None, mu=None) -> bool:
        """Apply one tenant's activity patch under quarantine rules.
        Returns True if the patch took, False if it was rejected/reverted
        (lane frozen either way on failure)."""
        if tenant_id in self._frozen:
            self.rejected_patches += 1
            return False
        rec_host = self._rec_host(tenant_id)
        users_arr = np.asarray(users, np.int64).reshape(-1)
        old_lam = rec_host.lam[users_arr].copy()
        old_mu = rec_host.mu[users_arr].copy()
        try:
            self.fleet.patch_activity(tenant_id, users, lam=lam, mu=mu)
        except ValueError:
            # validation wall: nothing mutated — freeze and keep serving
            self.rejected_patches += 1
            self._freeze(tenant_id)
            return False
        trip = self.sentinels.check_alpha(rec_host)
        if trip is not None:
            # α-poison passed validation (finite, ≥ 0): revert the rates,
            # then freeze with the pre-patch scores
            self.fleet.patch_activity(tenant_id, users_arr,
                                      lam=old_lam, mu=old_mu)
            self.reverted_patches += 1
            self._freeze(tenant_id)
            return False
        return True

    # -- guarded reads ---------------------------------------------------- #
    def psi(self, tenant_id: str) -> np.ndarray:
        """The tenant's scores — last-known-good while frozen, live else."""
        if tenant_id in self._frozen:
            return self._frozen[tenant_id].copy()
        return self.fleet.psi(tenant_id)

    def top_k(self, tenant_id: str, k: int) -> tuple[np.ndarray, np.ndarray]:
        psi = self.psi(tenant_id)
        idx = np.argsort(-psi, kind="stable")[: int(k)]
        return idx, psi[idx]

    def _rec_host(self, tenant_id: str) -> HostOperators:
        return self.fleet._rec(tenant_id).host


class ServiceGuard:
    """Sentinel-guarded mutation surface over a :class:`PsiService` with
    checkpoint rollback.

    Every healthy resolve checkpoints (rates + served ψ) through
    ``ckpt.checkpoint`` (atomic, GC'd, corruption-hardened). A patch that
    fails validation is dropped (service untouched, still serving). A
    patch that passes validation but leaves the post-resolve state tripped
    (non-finite ψ, α ≥ 1, runaway gap) triggers :meth:`rollback`: the last
    complete checkpoint's rates are re-applied and ψ is re-solved *cold*
    (a NaN-poisoned warm start would never wash out of the iteration).
    """

    def __init__(self, svc, ckpt_dir: str, *,
                 sentinels: Sentinels | None = None, keep: int = 4):
        self.svc = svc
        self.ckpt_dir = ckpt_dir
        self.sentinels = sentinels or Sentinels()
        self.keep = int(keep)
        self._step = 0
        self.rejected_patches = 0
        self.rollbacks = 0
        svc.resolve()                     # ensure a served fixed point…
        self.checkpoint()                 # …and a rollback point for it

    @property
    def n(self) -> int:
        return self.svc.graph.n

    def checkpoint(self) -> None:
        act = self.svc.engine.activity
        self._step += 1
        checkpoint.save(self.ckpt_dir, self._step,
                        dict(lam=np.asarray(act.lam, np.float64),
                             mu=np.asarray(act.mu, np.float64),
                             psi=np.asarray(self.svc.scores(), np.float64)),
                        keep=self.keep)

    def update_activity(self, users, lam=None, mu=None) -> bool:
        """Guarded patch + resolve; True if the service accepted it and
        stayed healthy, False if it was rejected or rolled back."""
        try:
            self.svc.update_activity(users, lam=lam, mu=mu, resolve=True)
        except ValueError:
            self.rejected_patches += 1     # validation wall: state untouched
            return False
        trip = self._health_trip()
        if trip is not None:
            self.rollback()
            return False
        self.checkpoint()
        return True

    def _health_trip(self) -> SentinelTrip | None:
        res = self.svc.last_result
        trip = self.sentinels.check_array("psi", res.psi)
        if trip is None:
            trip = self.sentinels.check_gap(float(res.gap))
        if trip is None:
            host = HostOperators.from_graph(self.svc.graph,
                                            self.svc.engine.activity)
            trip = self.sentinels.check_alpha(host)
        return trip

    def rollback(self) -> None:
        """Restore the last complete checkpoint: rates back, cold re-solve
        (warm state may be NaN/blown-up — it is discarded, not trusted)."""
        tmpl = dict(lam=np.zeros(self.n), mu=np.zeros(self.n),
                    psi=np.zeros(self.n))
        data = checkpoint.restore_latest(self.ckpt_dir, tmpl)
        if data is None:
            raise RuntimeError("rollback requested but no complete "
                               f"checkpoint exists in {self.ckpt_dir}")
        self.rollbacks += 1
        self.sentinels.reset_gap()
        self.svc._last = None              # poisoned warm start: discard
        self.svc._cache = None
        self.svc.update_activity(np.arange(self.n),
                                 lam=data["lam"], mu=data["mu"],
                                 resolve=True)

    def scores(self) -> np.ndarray:
        return self.svc.scores()
