"""Deterministic, seeded fault injection for the ψ serving stack.

A :class:`FaultPlan` is a frozen *schedule* of faults; :meth:`FaultPlan.clock`
instantiates it into a :class:`FaultClock` — the stateful harness that
plugs into the stack's existing extension points (nothing here monkeypatches
anything; every injection goes through a hook the production code already
exposes, so the faulted code path IS the production code path):

====================  =====================================================
fault class           injection point
====================  =====================================================
``crash``             ``AsyncPsiDriver.run(fail_hook=clock.fail_hook())`` —
                      drop in-memory state, restore from last checkpoint
``hang``              ``AsyncPsiDriver(delay_hook=clock.delay_hook())`` —
                      one chunk's worker sleeps (straggler / wedged device)
``stale_read``        ``AsyncPsiDriver(read_hook=clock.read_hook())`` —
                      force maximum-τ stale reads of one chunk's slice
``torn_ckpt``         ``clock.tear_checkpoint(dir)`` — truncate the newest
                      step's MANIFEST.json mid-file (torn write)
``poison``            ``clock.poison_patch(users, lam, mu)`` — corrupt a
                      pending activity patch (NaN / Inf / negative / an
                      α≥1-inducing rate blow-up)
``dup``/``reorder``/  ``clock.wrap_source(log)`` — a sequence-numbered feed
``drop``              that duplicates, shuffles (bounded window), and drops
                      events (at-least-zero delivery; the exactly-once
                      replay layer in ``recovery.py`` repairs it)
====================  =====================================================

Determinism: every random choice draws from one ``np.random.default_rng``
seeded by the plan, and every hook's decision depends only on its call
arguments and that stream — two runs of the same plan against the same
workload inject byte-identical fault schedules (the chaos tests and the CI
smoke gate rely on this).

Accounting: the clock counts ``injected[kind]``; *survival* is declared by
the verification layer (``note_survived``) once the corresponding defense
is proven to have worked — e.g. stream faults are survived exactly when
the exactly-once replay delivered the pristine log. The pair feeds the
:class:`~repro.resilience.supervisor.ResilienceReport`.
"""
from __future__ import annotations

import dataclasses
import os
from collections import Counter
from typing import Iterator

import numpy as np

from ..ckpt import checkpoint
from ..stream.events import ReplayLog

__all__ = ["FaultPlan", "FaultClock", "FaultyFeed", "POISON_KINDS"]

POISON_KINDS = ("nan", "inf", "negative", "alpha")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A reproducible fault schedule (all classes optional; 0/None = off).

    Args:
      seed: the one seed behind every random choice the clock makes.
      crash_every: ``fail_hook`` returns True every this-many ticks
        (epoch-floor advances) — simulated process crash + restore.
      hang_chunk / hang_epoch / hang_delay_s: chunk ``hang_chunk`` sleeps
        ``hang_delay_s`` seconds before its step at epoch ``hang_epoch``
        (and every ``hang_epoch`` epochs after, keeping the straggler hot).
      stale_chunk / stale_lag: every reader of ``stale_chunk``'s slice is
        forced ``stale_lag`` epochs behind (clamped to τ by the scheduler).
      torn_after_saves: ``tear_checkpoint`` arms after this many calls —
        the n-th call actually tears (one torn write per plan).
      poison_kind: what :meth:`FaultClock.poison_patch` injects.
      dup_every / reorder_window / drop_every: event-feed corruption — every
        ``dup_every``-th delivered event is delivered twice, delivery order
        is shuffled inside a ``reorder_window``-sized buffer, and every
        ``drop_every``-th event is silently dropped.
    """

    seed: int = 0
    crash_every: int = 0
    hang_chunk: int | None = None
    hang_epoch: int = 5
    hang_delay_s: float = 0.25
    stale_chunk: int | None = None
    stale_lag: int = 8
    torn_after_saves: int = 0
    poison_kind: str = "nan"
    dup_every: int = 0
    reorder_window: int = 0
    drop_every: int = 0

    def __post_init__(self):
        if self.poison_kind not in POISON_KINDS:
            raise ValueError(f"poison_kind must be one of {POISON_KINDS}; "
                             f"got {self.poison_kind!r}")

    def clock(self) -> "FaultClock":
        return FaultClock(self)


class FaultClock:
    """One run's stateful instantiation of a :class:`FaultPlan`."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.injected: Counter = Counter()
        self.survived: Counter = Counter()
        self._saves_seen = 0
        self._torn_done = False

    def note_survived(self, kind: str, n: int = 1) -> None:
        """Credit ``n`` survived faults of ``kind`` — called by the layer
        that *verified* the defense worked, never by the injector itself."""
        self.survived[kind] += int(n)

    # -- async-driver hooks ---------------------------------------------- #
    def fail_hook(self):
        """``fail_hook(tick) -> bool`` for ``AsyncPsiDriver.run``: a crash
        every ``crash_every`` epoch-floor ticks."""
        every = self.plan.crash_every

        def hook(tick: int) -> bool:
            if every and tick % every == 0:
                self.injected["crash"] += 1
                return True
            return False

        return hook

    def delay_hook(self):
        """``delay_hook(chunk, epoch) -> seconds``: a recurring hang of one
        chunk's worker."""
        p = self.plan

        def hook(chunk: int, epoch: int) -> float:
            if (p.hang_chunk is not None and chunk == p.hang_chunk
                    and p.hang_epoch and epoch % p.hang_epoch == 0):
                self.injected["hang"] += 1
                return p.hang_delay_s
            return 0.0

        return hook

    def read_hook(self):
        """``read_hook(reader, neighbor, epochs) -> lag``: force stale
        reads of one chunk's slice (scheduler clamps to τ)."""
        p = self.plan

        def hook(reader: int, neighbor: int, epochs: np.ndarray) -> int:
            if p.stale_chunk is not None and neighbor == p.stale_chunk:
                self.injected["stale_read"] += 1
                return p.stale_lag
            return 0

        return hook

    # -- checkpoint corruption ------------------------------------------- #
    def tear_checkpoint(self, directory: str) -> bool:
        """Tear the *newest* complete step: truncate its MANIFEST.json
        mid-file, as a crash halfway through a non-atomic write would.
        Arms on the ``torn_after_saves``-th call; tears once per plan.
        Returns True when a tear actually happened."""
        if not self.plan.torn_after_saves or self._torn_done:
            return False
        self._saves_seen += 1
        if self._saves_seen < self.plan.torn_after_saves:
            return False
        steps = checkpoint.complete_steps(directory)
        if not steps:
            return False
        mpath = os.path.join(directory, f"step_{steps[-1]:08d}",
                             "MANIFEST.json")
        with open(mpath) as f:
            text = f.read()
        # truncating a JSON object anywhere before its closing brace is
        # guaranteed unparseable — exactly the torn write being simulated
        with open(mpath, "w") as f:
            f.write(text[: max(1, len(text) // 2)])
        self._torn_done = True
        self.injected["torn_ckpt"] += 1
        return True

    # -- patch poisoning -------------------------------------------------- #
    def poison_patch(self, users, lam, mu):
        """Corrupt one entry of a pending activity patch per ``poison_kind``.

        ``nan`` / ``inf`` / ``negative`` must be rejected at the mutation
        boundary (``_validate_rates``); ``alpha`` passes those checks —
        finite, non-negative — but blows a user's μ up enough to push
        α = ‖M‖₁ toward/over 1, the divergence only the post-patch health
        sentinel (:func:`repro.resilience.health.alpha_norm`) can catch.
        """
        users = np.asarray(users, np.int64).reshape(-1).copy()
        lam = np.asarray(lam, np.float64).reshape(-1).copy()
        mu = np.asarray(mu, np.float64).reshape(-1).copy()
        k = int(self.rng.integers(users.size))
        kind = self.plan.poison_kind
        if kind == "nan":
            lam[k] = np.nan
        elif kind == "inf":
            mu[k] = np.inf
        elif kind == "negative":
            lam[k] = -abs(lam[k]) - 1.0
        else:                                    # 'alpha': finite, ≥ 0, huge
            mu[k] = 1e12
        self.injected["poison"] += 1
        return users, lam, mu

    # -- event-feed corruption -------------------------------------------- #
    def wrap_source(self, log: ReplayLog, *, start: int = 0) -> "FaultyFeed":
        """A sequence-numbered feed of ``log[start:]`` with seeded
        duplication, bounded reordering, and drops."""
        return FaultyFeed(log, self, start=start)


class FaultyFeed:
    """Yields ``(seq, event)`` pairs of ``log[start:]`` — corrupted.

    ``seq`` is the event's absolute index in the log (the at-least-once
    transport's offset); downstream, :class:`ExactlyOnceReplay
    <repro.resilience.recovery.ExactlyOnceReplay>` dedups on it, reorders
    through it, and re-fetches dropped offsets from the authoritative log.
    Iterating twice replays the identical corruption (fresh rng from the
    plan seed + a per-feed salt, so multiple feeds of one clock differ
    deterministically).
    """

    def __init__(self, log: ReplayLog, clock: FaultClock, *, start: int = 0):
        self.log = log
        self.clock = clock
        self.start = int(start)
        self._salt = int(clock.rng.integers(2 ** 31))

    def __iter__(self) -> Iterator[tuple]:
        p = self.clock.plan
        rng = np.random.default_rng((p.seed, self._salt))
        buf: list[tuple[int, object]] = []
        emitted = 0
        seen = 0

        def corrupt_emit(item):
            nonlocal emitted
            emitted += 1
            yield item
            if p.dup_every and emitted % p.dup_every == 0:
                self.clock.injected["dup"] += 1
                yield item

        for seq in range(self.start, len(self.log)):
            seen += 1
            if p.drop_every and seen % p.drop_every == 0:
                self.clock.injected["drop"] += 1
                continue
            buf.append((seq, self.log[seq]))
            if len(buf) > max(1, p.reorder_window):
                k = int(rng.integers(len(buf)))
                if buf[k][0] != min(b[0] for b in buf):
                    self.clock.injected["reorder"] += 1
                yield from corrupt_emit(buf.pop(k))
        while buf:
            k = int(rng.integers(len(buf)))
            if buf[k][0] != min(b[0] for b in buf):
                self.clock.injected["reorder"] += 1
            yield from corrupt_emit(buf.pop(k))
