from .tokens import TokenPipeline, PsiWeightedSampler
