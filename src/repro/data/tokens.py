"""Deterministic synthetic token pipeline (shardable, restart-exact).

Every batch is a pure function of (seed, step, host), so data order is
reproducible across restarts and elastic re-meshes — the data-side half of
the fault-tolerance story. Token statistics are Zipf-like to keep the
softmax/embedding access patterns realistic.

``PsiWeightedSampler`` is the paper-technique integration (DESIGN.md §5):
documents are attributed to synthetic users and sampled ∝ ψ-score, i.e.
training data is curated by user influence.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TokenPipeline", "PsiWeightedSampler"]


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        raw = rng.zipf(self.zipf_a, (self.global_batch, self.seq_len + 1))
        toks = (raw - 1) % self.vocab
        return dict(tokens=toks[:, :-1].astype(np.int32),
                    labels=toks[:, 1:].astype(np.int32))

    def host_batch(self, step: int, host: int, n_hosts: int
                   ) -> dict[str, np.ndarray]:
        full = self.batch(step)
        per = self.global_batch // n_hosts
        sl = slice(host * per, (host + 1) * per)
        return {k: v[sl] for k, v in full.items()}


class PsiWeightedSampler:
    """Sample document owners ∝ ψ-score (influence-curated data mixing)."""

    def __init__(self, psi: np.ndarray, *, temperature: float = 1.0,
                 seed: int = 0):
        w = np.asarray(psi, np.float64) ** (1.0 / max(temperature, 1e-6))
        self._p = w / w.sum()
        self._rng = np.random.default_rng(seed)

    def sample_users(self, k: int) -> np.ndarray:
        return self._rng.choice(self._p.shape[0], size=k, p=self._p)

    def mixture_stats(self, k: int = 10_000) -> dict:
        users = self.sample_users(k)
        uniq = np.unique(users).size
        return dict(unique_users=int(uniq),
                    top1_share=float(np.bincount(users).max() / k))
