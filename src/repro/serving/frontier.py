"""Cross-tenant query frontier: one ranking surface over a whole fleet.

:class:`FleetRankingCache` is the fleet's analogue of the single-tenant
:class:`repro.core.incremental.RankingCache`: it memoizes one descending
order per (tenant, fixed point) and exposes the batched read surface the
serving loop actually issues —

* ``scores_batch(tenant_ids, users)`` — aligned (tenant, user) pairs in one
  call, grouped per tenant internally so each tenant's ψ is touched once;
* ``top_k(tenant_id, k)`` / ``rank_of(tenant_id, users)`` — per-tenant
  rankings off the memoized order;
* ``global_top_k(k)`` — the fleet-wide frontier: the k highest-ψ users
  across *all* tenants (per-tenant ``lax.top_k`` prefilter, then one merge
  of ≤ T·k candidates);
* ``staleness(tenant_id)`` / ``epoch(tenant_id)`` — how many mutations a
  tenant's served ψ is behind, without forcing a solve.

Every query method (except the staleness probes) first lets the fleet
re-solve whatever is dirty, so reads are always against fresh fixed points;
a tenant whose epoch did not move keeps its cached sort (and its bitwise
ψ — clean lanes are masked out of the batched loop entirely).
"""
from __future__ import annotations

import numpy as np

from ..core.incremental import RankingCache

__all__ = ["FleetRankingCache"]


class FleetRankingCache:
    """Batched ranking queries across every tenant of a fleet."""

    def __init__(self, fleet):
        self._fleet = fleet
        self._caches: dict[str, tuple[int, RankingCache]] = {}

    # -- staleness / epoch probes (no solve triggered) ------------------- #
    def epoch(self, tenant_id: str) -> int:
        return self._fleet._rec(tenant_id).epoch

    def staleness(self, tenant_id: str) -> int:
        """Mutations applied since the served ψ was solved (0 = fresh)."""
        return self._fleet._rec(tenant_id).staleness

    def drop(self, tenant_id: str) -> None:
        """Forget a tenant's cached ranking (fleet calls this on evict)."""
        self._caches.pop(tenant_id, None)

    # -- per-tenant cache ------------------------------------------------ #
    def ranking(self, tenant_id: str) -> RankingCache:
        """The tenant's memoized RankingCache, refreshed iff its ψ moved."""
        self._fleet.solve()
        rec = self._fleet._rec(tenant_id)
        entry = self._caches.get(tenant_id)
        if entry is None or entry[0] != rec.solved_epoch:
            entry = (rec.solved_epoch, RankingCache(rec.psi))
            self._caches[tenant_id] = entry
        return entry[1]

    # -- queries --------------------------------------------------------- #
    def scores_batch(self, tenant_ids, users) -> np.ndarray:
        """ψ for aligned (tenant, user) pairs — one fleet solve, one pass
        over each distinct tenant."""
        tenant_ids = list(tenant_ids)
        users = np.asarray(users)
        if users.shape != (len(tenant_ids),):
            raise ValueError(f"users must align with tenant_ids: "
                             f"{users.shape} vs {len(tenant_ids)}")
        self._fleet.solve()
        out = np.empty(len(tenant_ids),
                       np.dtype(self._fleet._np_dtype))
        tids = np.asarray(tenant_ids, object)
        for tid in set(tenant_ids):
            sel = np.where(tids == tid)[0]
            out[sel] = self.ranking(tid).scores_batch(users[sel])
        return out

    def top_k(self, tenant_id: str, k: int) -> tuple[np.ndarray, np.ndarray]:
        return self.ranking(tenant_id).top_k(k)

    def rank_of(self, tenant_id: str, users) -> np.ndarray:
        return self.ranking(tenant_id).rank_of(np.asarray(users))

    def global_top_k(self, k: int) -> list[tuple[str, int, float]]:
        """The k most influential (tenant, user, ψ) triples fleet-wide."""
        self._fleet.solve()
        cands: list[tuple[float, str, int]] = []
        for tid in self._fleet.tenant_ids:
            idx, vals = self.ranking(tid).top_k(k)
            cands.extend((float(v), tid, int(u))
                         for u, v in zip(idx, vals))
        cands.sort(key=lambda c: (-c[0], c[1], c[2]))
        return [(tid, user, score) for score, tid, user in cands[:k]]
