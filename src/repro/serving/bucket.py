"""Size-bucket policy for multi-tenant batched serving.

A fleet multiplexes many independent (graph, activity) tenants onto one
device by stacking their padded operator arrays along a lane axis and
running the Power-ψ iteration vmapped over that axis.  Lanes can only stack
when their arrays share a shape, so every tenant is padded up to a
**bucket**: a ``(n_pad, e_pad)`` capacity pair drawn from a small ladder of
sizes.  The ladder trades two costs against each other:

* too few rungs → tiny tenants share buckets with huge ones and burn HBM /
  flops on padding (low *occupancy*);
* too many rungs → every bucket shape compiles its own batched solver and
  admits few co-tenants to amortize it over.

:class:`BucketPolicy` owns that ladder.  Node capacities come from an
explicit ascending tuple (extended by doubling past the last rung, so any
graph is admissible); edge capacities are geometric levels
``edge_quantum · edge_growth^k``, which leaves every tenant headroom for
O(Δ) edge inserts before it must *rebucket* — migrate, warm state intact,
to the next rung (:meth:`BucketPolicy.needs_rebucket`).
"""
from __future__ import annotations

import dataclasses

__all__ = ["BucketSpec", "BucketPolicy"]


@dataclasses.dataclass(frozen=True, order=True)
class BucketSpec:
    """One rung of the ladder: padded node / edge capacities of a batch."""

    n_pad: int
    e_pad: int

    def fits(self, n: int, m: int) -> bool:
        return n <= self.n_pad and m <= self.e_pad

    def node_occupancy(self, n: int) -> float:
        return n / self.n_pad

    def edge_occupancy(self, m: int) -> float:
        return m / self.e_pad

    def __str__(self) -> str:
        return f"bucket[n≤{self.n_pad}, m≤{self.e_pad}]"


class BucketPolicy:
    """Maps a tenant's (n, m) to the smallest bucket that holds it.

    Args:
      node_sizes: ascending node-capacity rungs.  A graph larger than the
        last rung gets a doubled extension (the ladder is open-ended).
      edge_quantum: smallest edge capacity.
      edge_growth: geometric factor between edge rungs (> 1); the average
        edge padding waste is bounded by ``edge_growth − 1``.
      lane_quantum: batch sizes are rounded up to a multiple of this, so a
        bucket's compiled loop survives small membership churn (the padded
        lanes are inert — zero operators converge in one masked step).
    """

    def __init__(self, node_sizes: tuple[int, ...] = (256, 1024, 4096,
                                                      16_384, 65_536),
                 *, edge_quantum: int = 1024, edge_growth: float = 2.0,
                 lane_quantum: int = 1):
        if not node_sizes or list(node_sizes) != sorted(set(node_sizes)):
            raise ValueError("node_sizes must be ascending and non-empty")
        if min(node_sizes) < 1 or edge_quantum < 1:
            raise ValueError("capacities must be positive")
        if edge_growth <= 1.0:
            raise ValueError("edge_growth must exceed 1")
        self.node_sizes = tuple(int(s) for s in node_sizes)
        self.edge_quantum = int(edge_quantum)
        self.edge_growth = float(edge_growth)
        self.lane_quantum = max(1, int(lane_quantum))

    @classmethod
    def from_spec(cls, spec: str, **kw) -> "BucketPolicy":
        """Parse a ``--bucket-sizes``-style comma list, e.g. ``"512,4096"``."""
        sizes = tuple(int(tok) for tok in spec.replace(" ", "").split(",")
                      if tok)
        return cls(sizes, **kw)

    # ------------------------------------------------------------------ #
    def node_capacity(self, n: int) -> int:
        for size in self.node_sizes:
            if n <= size:
                return size
        cap = self.node_sizes[-1]
        while cap < n:                       # open-ended: keep doubling
            cap *= 2
        return cap

    def edge_capacity(self, m: int) -> int:
        cap = self.edge_quantum
        while cap < m:
            cap = int(cap * self.edge_growth)
        return cap

    def bucket_for(self, n: int, m: int) -> BucketSpec:
        if n < 1:
            raise ValueError("empty graph has no bucket")
        return BucketSpec(self.node_capacity(n),
                          self.edge_capacity(max(1, m)))

    def needs_rebucket(self, spec: BucketSpec, n: int, m: int) -> bool:
        """True once growth has escaped ``spec`` — time to migrate."""
        return not spec.fits(n, m)

    def lanes_padded(self, count: int) -> int:
        q = self.lane_quantum
        return max(q, -(-count // q) * q)

    # ------------------------------------------------------------------ #
    def occupancy(self, spec: BucketSpec,
                  tenants: list[tuple[int, int]]) -> dict:
        """Accounting for one bucket: how much of the padded batch is real.

        ``tenants`` is a list of (n, m) pairs; returns node/edge/lane
        occupancy fractions plus the padded lane count the batch compiles
        for.  The fleet surfaces this per bucket so an operator can see
        which rungs are wasting device memory.
        """
        lanes = self.lanes_padded(len(tenants)) if tenants else 0
        if not tenants:
            return dict(tenants=0, lanes=0, node_occupancy=0.0,
                        edge_occupancy=0.0, lane_occupancy=0.0)
        node = sum(spec.node_occupancy(n) for n, _ in tenants) / len(tenants)
        edge = sum(spec.edge_occupancy(m) for _, m in tenants) / len(tenants)
        return dict(tenants=len(tenants), lanes=lanes,
                    node_occupancy=node, edge_occupancy=edge,
                    lane_occupancy=len(tenants) / lanes)
