"""Multi-tenant batched ψ-score serving (see docs/SERVING.md).

``TenantFleet`` multiplexes many independent (graph, activity) tenants onto
one device: tenants are size-bucketed into padded batches
(:mod:`repro.serving.bucket`), each bucket solves as one vmapped
convergence-masked Power-ψ loop (:mod:`repro.serving.fleet`), and queries go
through the cross-tenant ranking frontier (:mod:`repro.serving.frontier`).
"""
from .bucket import BucketPolicy, BucketSpec
from .fleet import TenantFleet, TenantView
from .frontier import FleetRankingCache

__all__ = ["BucketPolicy", "BucketSpec", "TenantFleet", "TenantView",
           "FleetRankingCache"]
