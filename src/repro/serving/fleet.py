"""TenantFleet: many (graph, activity) tenants multiplexed onto one device.

The single-tenant serving story (:class:`repro.core.incremental.PsiService`)
leaves the device idle between solves; a platform scoring many communities /
topics at once wants the opposite — one resident solver amortized across a
*fleet* of independent tenants.  The fleet gets there in three moves:

1. **Size-bucketing** (:mod:`repro.serving.bucket`): tenants are padded to a
   small ladder of ``(n_pad, e_pad)`` capacities so same-bucket operator
   arrays stack along a lane axis.  Pad nodes carry zero rates and pad edges
   point at the out-of-range sentinel the segment-sum drops — inert by
   construction.
2. **Vmapped masked iteration**: one bucket solves as a single
   :func:`repro.core.engine.make_batched_loop` call — the backend's pure
   ``one_step`` vmapped over lanes inside one ``lax.while_loop``, each lane
   honoring the solo convergence rule.  A converged lane *freezes bitwise*
   (``jnp.where`` keeps its series vector) while neighbours keep stepping;
   lanes that were already clean when the solve started never move at all.
3. **Warm-state continuity**: every mutation goes through the tenant's own
   O(Δ) :class:`~repro.core.operators.HostOperators` mirror, re-solves warm
   from the previous fixed point, and — when edge growth escapes the bucket
   — the tenant *rebuckets* into the next capacity rung carrying its series
   vector along, so even a migration re-converges in a handful of
   iterations.

Three batched execution regimes are supported — ``dense`` (per-lane {0,1}
adjacency consumed as one batched GEMV: BLAS on CPU, MXU on TPU — the clear
winner for buckets of *small* tenants, where B independent gather/scatter
pipelines lose to a single ``[B, n, n]`` matvec), ``reference`` (vmapped
edge-form segment-sum — works everywhere, any dtype, O(m) memory) and
``pallas`` (the fused edge-tile kernel vmapped across lanes; tile
parameters planned once per *bucket shape* via
:func:`repro.kernels.autotune.plan_for_bucket` and shared by every
same-bucket tenant).  ``auto`` picks per bucket: ``dense`` under the
``dense_max_n`` memory threshold, otherwise ``pallas`` on TPU /
``reference`` elsewhere.  Queries go through
:class:`repro.serving.frontier.FleetRankingCache`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from ..core.activity import Activity
from ..obs import convergence as obs_convergence
from ..obs import explain as obs_explain
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace
from ..core.engine import (make_batched_loop, make_dense_step,
                           make_edge_tile_step, make_reference_step)
from ..core.incremental import RankedQueries
from ..core.operators import HostOperators, PsiOperators
from ..graphs.structure import Graph
from .bucket import BucketPolicy, BucketSpec

__all__ = ["TenantFleet", "TenantView"]

_BACKENDS = ("auto", "dense", "reference", "pallas")


@dataclasses.dataclass
class _Tenant:
    """Host-side record of one admitted tenant."""

    tid: str
    host: HostOperators
    n: int
    spec: BucketSpec
    epoch: int = 0              # bumped on every mutation
    solved_epoch: int = -1      # epoch the stored ψ corresponds to
    s_host: np.ndarray | None = None   # node-order warm start, length n
    psi: np.ndarray | None = None
    iterations: int = 0
    gap: float = float("inf")
    converged: bool = False
    rebuckets: int = 0

    @property
    def staleness(self) -> int:
        return self.epoch - self.solved_epoch if self.solved_epoch >= 0 \
            else self.epoch + 1


@dataclasses.dataclass
class _Bucket:
    """Device-side batch of one bucket shape (lane order = ``order``)."""

    spec: BucketSpec
    regime: str = ""                           # resolved at stack time
    order: list = dataclasses.field(default_factory=list)
    restack: bool = True                       # membership/shape changed
    refresh: dict = dataclasses.field(default_factory=dict)  # tid → kind
    args: Any = None                           # batched step args
    s: Any = None                              # batched native state
    scale: Any = None                          # f[B] per-lane ‖B‖
    inv_n: Any = None                          # f[B] 1/n_real (0 on pads)
    lam: Any = None                            # epilogue vectors
    d: Any = None                              # (dense / pallas regimes)
    nb: int = 0                                # pallas block capacity
    plan: Any = None


class TenantFleet:
    """Admit / evict / patch tenants; solve them in vmapped batches.

    Args:
      backend: ``dense`` (batched GEMV — small buckets), ``reference``
        (vmapped segment-sum), ``pallas`` (vmapped fused edge-tile kernel)
        or ``auto`` (per-bucket choice under ``dense_max_n``).
      tol / max_iter: shared convergence criterion (Eq. 19 rule with the
        per-tenant ‖B‖ scale unless ``use_b_norm=False``).
      policy: the :class:`BucketPolicy` sizing ladder.
      check_every: gap-evaluation cadence of the batched loop.
      dense_max_n: largest ``n_pad`` the ``auto`` backend will run dense
        (O(n²) lane memory is the constraint).
      microbench: time edge-tile candidates when planning a bucket
        (``pallas`` regime) instead of trusting the cost model.
      tile / e1 / e2: explicit edge-tile parameters (skip planning).
      plan_cache: override the process-level autotune plan cache.
    """

    def __init__(self, *, backend: str = "auto", tol: float = 1e-8,
                 max_iter: int = 10_000, dtype=None,
                 policy: BucketPolicy | None = None, norm: str = "l1",
                 use_b_norm: bool = True, check_every: int = 1,
                 dense_max_n: int = 1024, interpret: bool | None = None,
                 microbench: bool = False, tile: int | None = None,
                 e1: int | None = None, e2: int | None = None,
                 plan_cache=None):
        import jax.numpy as jnp
        if backend not in _BACKENDS:
            raise ValueError(f"unknown fleet backend {backend!r}; "
                             f"available: {_BACKENDS}")
        if backend in ("pallas", "auto") and norm != "l1":
            raise ValueError("the pallas regime computes its gap in l1; "
                             f"got norm={norm!r}")
        self.backend = backend
        self.norm = norm
        self.tol = float(tol)
        self.max_iter = int(max_iter)
        self.dtype = dtype or jnp.float32
        self._np_dtype = np.dtype(jnp.dtype(self.dtype).name)
        self.policy = policy or BucketPolicy()
        self.use_b_norm = bool(use_b_norm)
        self.check_every = int(check_every)
        self.dense_max_n = int(dense_max_n)
        self.microbench = bool(microbench)
        self._tile_override = ((tile, e1, e2)
                               if None not in (tile, e1, e2) else None)
        self._plan_cache = plan_cache
        if interpret is None:
            from ..kernels.ops import default_interpret
            interpret = default_interpret()
        self.interpret = bool(interpret)
        self._machinery: dict[str, tuple] = {}   # regime → (loop, epilogue)
        self._tenants: dict[str, _Tenant] = {}
        self._buckets: dict[BucketSpec, _Bucket] = {}
        self._frontier = None
        self.solves = 0                  # batched loop launches
        self.lane_solves = 0             # lanes actually iterated

    # -- regime machinery ------------------------------------------------ #
    def _regime_for(self, spec: BucketSpec) -> str:
        if self.backend != "auto":
            regime, rule = self.backend, f"backend={self.backend!r} pinned"
        elif spec.n_pad <= self.dense_max_n:
            regime = "dense"
            rule = f"n_pad {spec.n_pad} ≤ dense_max_n {self.dense_max_n}"
        else:
            import jax
            platform = jax.default_backend()
            regime = "pallas" if platform == "tpu" else "reference"
            rule = (f"n_pad {spec.n_pad} > dense_max_n {self.dense_max_n}, "
                    f"platform={platform}")
        obs_explain.record_decision(
            "bucket_regime", "TenantFleet._regime_for",
            inputs=dict(n_pad=int(spec.n_pad), e_pad=int(spec.e_pad),
                        backend=self.backend,
                        dense_max_n=self.dense_max_n),
            chosen=regime, source="model",
            candidates=[obs_explain.Candidate(
                name, chosen=(name == regime),
                detail=(dict(rule=rule) if name == regime else {}))
                for name in ("dense", "reference", "pallas")])
        return regime

    def _loop_and_epilogue(self, regime: str) -> tuple:
        """The (batched loop, batched epilogue) pair of one regime, built
        lazily and shared by every bucket the regime serves."""
        import jax
        if regime in self._machinery:
            return self._machinery[regime]
        if regime == "reference":
            one_step = make_reference_step(self.norm)

            def _epi(ops, s, lam, d, inv_n):
                return (lam * ops.push(s) + d) * inv_n
        elif regime == "dense":
            one_step = make_dense_step(self.norm)

            def _epi(args, s, lam, d, inv_n):
                E, inv_w, _, _ = args
                return (lam * ((s * inv_w) @ E) + d) * inv_n
        else:
            one_step = make_edge_tile_step(self.interpret)
            interp = self.interpret

            def _epi(args, s, lam, d, inv_n):
                from ..kernels.ops import edge_spmv
                fmt, inv_w_g, _, _ = args
                s_pre = s[0, :fmt.n] * inv_w_g[0, :fmt.n]
                t = edge_spmv(s_pre, fmt, interpret=interp)
                return (lam * t + d) * inv_n

        # guard the batched loop: bucket-shape churn that recompiles it is
        # exactly the silent cost the retrace counter exists to surface.
        # warn=False — the loop is shared across bucket shapes, so a second
        # bucket's first compile is expected (still counted, not alerted)
        pair = (obs_trace.retrace_guard(
                    make_batched_loop(one_step,
                                      check_every=self.check_every),
                    name=f"fleet.{regime}.loop", warn=False),
                jax.jit(jax.vmap(_epi)))
        self._machinery[regime] = pair
        return pair

    # -- introspection --------------------------------------------------- #
    def __len__(self) -> int:
        return len(self._tenants)

    def __contains__(self, tenant_id: str) -> bool:
        return tenant_id in self._tenants

    @property
    def tenant_ids(self) -> tuple[str, ...]:
        return tuple(self._tenants)

    @property
    def frontier(self):
        """The cross-tenant query layer (lazily constructed)."""
        if self._frontier is None:
            from .frontier import FleetRankingCache
            self._frontier = FleetRankingCache(self)
        return self._frontier

    def view(self, tenant_id: str) -> "TenantView":
        """A PsiService-shaped single-tenant view (see TenantView)."""
        self._rec(tenant_id)
        return TenantView(self, tenant_id)

    def spec_of(self, tenant_id: str) -> BucketSpec:
        return self._rec(tenant_id).spec

    def stats(self, tenant_id: str) -> dict:
        r = self._rec(tenant_id)
        return dict(n=r.n, m=r.host.m, spec=r.spec, epoch=r.epoch,
                    solved_epoch=r.solved_epoch, staleness=r.staleness,
                    iterations=r.iterations, gap=r.gap,
                    converged=r.converged, rebuckets=r.rebuckets)

    def occupancy(self) -> dict:
        """Per-bucket padding accounting (see BucketPolicy.occupancy)."""
        out = {}
        for spec, bucket in sorted(self._buckets.items()):
            pairs = [(self._tenants[t].n, self._tenants[t].host.m)
                     for t in bucket.order]
            acct = self.policy.occupancy(spec, pairs)
            acct["regime"] = bucket.regime or self._regime_for(spec)
            if bucket.plan is not None:
                acct["plan"] = bucket.plan.params()
            out[spec] = acct
        return out

    # -- tenant lifecycle ------------------------------------------------ #
    def admit(self, tenant_id: str, graph: Graph, activity: Activity, *,
              s0: np.ndarray | None = None) -> BucketSpec:
        """Register a tenant; it solves lazily at the next query/solve.

        ``s0`` optionally warm-starts the first solve (e.g. a series vector
        migrated from another fleet or a solo engine's ``PsiResult.s``).

        The graph is deduped on the way in (the paper's model has neither
        self-loops nor multi-edges, and the execution regimes would
        otherwise disagree on duplicate counting — the dense adjacency is
        {0,1} while the edge form sums every occurrence).
        """
        if tenant_id in self._tenants:
            raise ValueError(f"tenant {tenant_id!r} already admitted")
        graph = graph.dedup()
        host = HostOperators.from_graph(graph, activity)
        spec = self.policy.bucket_for(graph.n, graph.m)
        rec = _Tenant(tid=tenant_id, host=host, n=graph.n, spec=spec)
        if s0 is not None:
            s0 = np.asarray(s0, self._np_dtype).reshape(-1)
            if s0.shape != (graph.n,):
                raise ValueError(f"s0 must be f[{graph.n}]; got {s0.shape}")
            rec.s_host = s0.copy()
        self._tenants[tenant_id] = rec
        self._join_bucket(rec)
        obs_metrics.gauge(
            "psi_fleet_tenants",
            "tenants currently admitted to the fleet"
        ).set(len(self._tenants))
        return spec

    def evict(self, tenant_id: str) -> np.ndarray | None:
        """Drop a tenant; returns its last ψ (None if never solved)."""
        rec = self._rec(tenant_id)
        self._leave_bucket(rec)
        del self._tenants[tenant_id]
        if self._frontier is not None:
            self._frontier.drop(tenant_id)
        obs_metrics.gauge(
            "psi_fleet_tenants",
            "tenants currently admitted to the fleet"
        ).set(len(self._tenants))
        return rec.psi

    def patch_activity(self, tenant_id: str, users, lam=None,
                       mu=None) -> None:
        """O(Δ) λ/μ patch on one tenant; its lane re-solves warm.

        An empty user set is a true no-op — the tenant stays clean, its
        epoch does not advance and no lane refresh is scheduled (the
        streaming ingestor's empty coalescing windows rely on this).
        """
        users = np.asarray(users).reshape(-1)
        if users.size == 0:
            return
        rec = self._rec(tenant_id)
        rec.host.patch_activity(users, lam=lam, mu=mu)
        self._mark_dirty(rec, "activity")

    def patch_edges(self, tenant_id: str, src, dst) -> None:
        """Edge insert on one tenant; rebuckets when growth escapes the
        bucket's edge capacity (warm state migrates with the tenant)."""
        rec = self._rec(tenant_id)
        kept_src, _ = rec.host.patch_edges(np.asarray(src, np.int32),
                                           np.asarray(dst, np.int32))
        if kept_src.size == 0:
            return
        if self.policy.needs_rebucket(rec.spec, rec.n, rec.host.m):
            self._leave_bucket(rec)
            rec.spec = self.policy.bucket_for(rec.n, rec.host.m)
            rec.rebuckets += 1
            rec.epoch += 1
            self._join_bucket(rec)
            obs_metrics.counter(
                "psi_fleet_rebuckets_total",
                "tenants migrated to a larger capacity rung").inc()
        else:
            self._mark_dirty(rec, "edges")

    def remove_edges(self, tenant_id: str, src, dst) -> None:
        """Edge removal (unfollow tombstones) on one tenant; absent pairs
        are ignored. Shrinking never rebuckets — the bucket spec is an
        upper bound — so this is always a lane-local refresh."""
        rec = self._rec(tenant_id)
        kept_src, _ = rec.host.remove_edges(np.asarray(src, np.int32),
                                            np.asarray(dst, np.int32))
        if kept_src.size == 0:
            return
        self._mark_dirty(rec, "edges")

    def activity(self, tenant_id: str) -> Activity:
        """The tenant's current λ/μ rates (host-mirror copy)."""
        return self._rec(tenant_id).host.activity()

    def invalidate(self) -> None:
        """Forget all solver state: the next solve is cold (s₀ = c).

        The stacked device operators are kept — only the iterate resets —
        so a post-invalidate solve measures pure solver work, exactly like
        a solo engine's cold ``run()`` over prebuilt operators.
        """
        for bucket in self._buckets.values():
            if bucket.args is not None and not bucket.restack \
                    and not bucket.refresh:
                bucket.s = self._cold_state(bucket)
            else:
                # pending lane refreshes (or no stack at all): the kept
                # args would be stale — rebuild from the host mirrors
                bucket.restack = True
                bucket.args = bucket.s = None
            bucket.refresh.clear()
        for rec in self._tenants.values():
            rec.s_host = None
            rec.solved_epoch = -1

    def _cold_state(self, bucket: _Bucket):
        """The batched cold-start iterate s₀ = c in the regime's layout."""
        if bucket.regime == "reference":
            return bucket.args.c
        return bucket.args[3]          # dense: c vectors; pallas: c_pad

    # -- solving --------------------------------------------------------- #
    def solve(self, *, force: bool = False) -> int:
        """Re-solve every bucket with a stale tenant; returns lanes run.

        Per bucket this is ONE vmapped masked loop launch: dirty lanes
        iterate from their warm state, clean lanes are masked inactive and
        stay bitwise frozen (their recomputed ψ is bit-identical).
        """
        import jax.numpy as jnp
        ran = 0
        for spec in sorted(self._buckets):
            bucket = self._buckets[spec]
            recs = [self._tenants[t] for t in bucket.order]
            dirty = [r.solved_epoch < r.epoch for r in recs]
            if not (any(dirty) or force):
                continue
            if bucket.restack:
                self._stack_bucket(bucket)
            elif bucket.refresh:
                self._apply_refresh(bucket)
                if bucket.restack:          # refresh escalated (block growth)
                    self._stack_bucket(bucket)
            loop, _ = self._loop_and_epilogue(bucket.regime)
            lanes = bucket.s.shape[0]
            active0 = np.zeros(lanes, bool)
            active0[:len(recs)] = [d or force for d in dirty]
            with obs_trace.span("fleet.solve", spec=str(spec),
                                regime=bucket.regime,
                                lanes=int(active0.sum())) as sp:
                s, gap, t = loop(
                    bucket.args, bucket.s, bucket.scale,
                    jnp.asarray(self.tol, self.dtype),
                    jnp.asarray(self.max_iter, jnp.int32),
                    jnp.asarray(active0))
                sp.sync(s)
            bucket.s = s
            obs_metrics.gauge(
                "psi_fleet_lane_occupancy",
                "admitted lanes / lane capacity of the bucket",
                labelnames=("spec",)).labels(spec=str(spec)) \
                .set(len(recs) / max(lanes, 1))
            psi = np.asarray(self._run_epilogue(bucket))
            gap, t = np.asarray(gap), np.asarray(t)
            tracker = obs_convergence.get_tracker()
            for lane, rec in enumerate(recs):
                if active0[lane]:
                    # clean lanes keep their stored ψ untouched (their
                    # frozen iterate would reproduce it bit-for-bit anyway)
                    rec.psi = psi[lane, :rec.n].copy()
                    rec.iterations = int(t[lane])
                    rec.gap = float(gap[lane])
                    rec.converged = rec.gap <= self.tol
                    ran += 1
                    if tracker.enabled:
                        # one endpoint-only record per re-solved tenant —
                        # the per-tenant convergence time series
                        tracker.finish(
                            tracker.begin("fleet", tenant=rec.tid),
                            iterations=rec.iterations, gap=rec.gap,
                            converged=rec.converged,
                            duration_s=sp.duration_s)
                rec.solved_epoch = rec.epoch
            self.solves += 1
            obs_metrics.counter("psi_fleet_solves_total",
                                "batched bucket loop launches").inc()
        self.lane_solves += ran
        if ran:
            obs_metrics.counter("psi_fleet_lane_solves_total",
                                "lanes actually iterated").inc(ran)
        return ran

    def psi(self, tenant_id: str) -> np.ndarray:
        """This tenant's ψ vector (solving first if anything is stale)."""
        self.solve()
        return self._rec(tenant_id).psi

    def series(self, tenant_id: str) -> np.ndarray | None:
        """The tenant's current node-order series vector s (warm state)."""
        rec = self._rec(tenant_id)
        self._sync_bucket(self._buckets[rec.spec])
        return rec.s_host

    def last_iterations(self, tenant_id: str) -> int:
        self.solve()
        return self._rec(tenant_id).iterations

    # -- internals: bookkeeping ------------------------------------------ #
    def _rec(self, tenant_id: str) -> _Tenant:
        try:
            return self._tenants[tenant_id]
        except KeyError:
            raise KeyError(f"unknown tenant {tenant_id!r}; admitted: "
                           f"{sorted(self._tenants)}") from None

    def _mark_dirty(self, rec: _Tenant, kind: str) -> None:
        rec.epoch += 1
        bucket = self._buckets[rec.spec]
        if not bucket.restack:
            prev = bucket.refresh.get(rec.tid)
            bucket.refresh[rec.tid] = ("edges" if "edges" in (kind, prev)
                                       else kind)

    def _join_bucket(self, rec: _Tenant) -> None:
        bucket = self._buckets.get(rec.spec)
        if bucket is None:
            bucket = self._buckets[rec.spec] = _Bucket(spec=rec.spec)
        self._invalidate_stack(bucket)
        bucket.order.append(rec.tid)

    def _leave_bucket(self, rec: _Tenant) -> None:
        bucket = self._buckets[rec.spec]
        self._invalidate_stack(bucket)
        bucket.order.remove(rec.tid)
        bucket.refresh.pop(rec.tid, None)
        if not bucket.order:
            del self._buckets[rec.spec]

    def _invalidate_stack(self, bucket: _Bucket) -> None:
        """Membership is changing: preserve warm states, drop device batch."""
        self._sync_bucket(bucket)
        bucket.restack = True
        bucket.refresh.clear()
        bucket.args = bucket.s = None

    def _sync_bucket(self, bucket: _Bucket) -> None:
        """Pull each lane's series vector back to its tenant record."""
        if bucket.s is None:
            return
        s_node = np.asarray(self._node_order(bucket))
        for lane, tid in enumerate(bucket.order):
            rec = self._tenants[tid]
            rec.s_host = s_node[lane, :rec.n].copy()

    def _node_order(self, bucket: _Bucket):
        if bucket.regime == "pallas":
            return bucket.s[:, 0, :bucket.spec.n_pad]
        return bucket.s

    # -- internals: per-tenant padded arrays ----------------------------- #
    def _node_arrays(self, rec: _Tenant | None,
                     n_pad: int) -> tuple[dict, float, float]:
        """(padded node vectors, ‖B‖, 1/n) for one lane; zeros for a pad
        lane (``rec is None``) — inert under the masked loop."""
        names = ("lam", "mu", "inv_w", "c", "d")
        if rec is None:
            return ({k: np.zeros(n_pad, self._np_dtype) for k in names},
                    0.0, 0.0)
        h = rec.host
        c, d = h.cd()
        out = {}
        for name, v in zip(names, (h.lam, h.mu, h.inv_w, c, d)):
            buf = np.zeros(n_pad, self._np_dtype)
            buf[:rec.n] = v
            out[name] = buf
        return out, float(h.b_norm), 1.0 / rec.n

    def _edge_arrays(self, rec: _Tenant | None,
                     spec: BucketSpec) -> tuple[np.ndarray, np.ndarray]:
        """dst-sorted edges padded to e_pad; pad slots scatter out-of-range
        (``dst == n_pad``), which the segment-sum drops."""
        src = np.zeros(spec.e_pad, np.int32)
        dst = np.full(spec.e_pad, spec.n_pad, np.int32)
        if rec is not None:
            m = rec.host.m
            src[:m] = rec.host.src_by_dst
            dst[:m] = rec.host.dst_by_dst
        return src, dst

    def _lane_s0(self, rec: _Tenant | None, node: dict,
                 n_pad: int) -> np.ndarray:
        if rec is None or rec.s_host is None:
            return node["c"]                    # cold start: s₀ = c
        buf = np.zeros(n_pad, self._np_dtype)
        buf[:rec.n] = rec.s_host.astype(self._np_dtype)
        return buf

    # -- internals: stacking --------------------------------------------- #
    def _stack_bucket(self, bucket: _Bucket) -> None:
        import jax.numpy as jnp
        spec = bucket.spec
        bucket.regime = self._regime_for(spec)
        recs: list[_Tenant | None] = [self._tenants[t] for t in bucket.order]
        recs += [None] * (self.policy.lanes_padded(len(recs)) - len(recs))
        nodes, b_norms, inv_ns, s0s = [], [], [], []
        for rec in recs:
            node, b_norm, inv_n = self._node_arrays(rec, spec.n_pad)
            nodes.append(node)
            b_norms.append(b_norm)
            inv_ns.append(inv_n)
            s0s.append(self._lane_s0(rec, node, spec.n_pad))
        bucket.inv_n = jnp.asarray(np.asarray(inv_ns, self._np_dtype))
        bucket.scale = (jnp.asarray(np.asarray(b_norms, self._np_dtype))
                        if self.use_b_norm
                        else jnp.ones(len(recs), self.dtype))
        bucket.lam = jnp.asarray(np.stack([n["lam"] for n in nodes]))
        bucket.d = jnp.asarray(np.stack([n["d"] for n in nodes]))
        if bucket.regime == "reference":
            self._stack_reference(bucket, recs, nodes, s0s)
        elif bucket.regime == "dense":
            self._stack_dense(bucket, recs, nodes, s0s)
        else:
            self._stack_pallas(bucket, recs, nodes, s0s)
        bucket.restack = False
        bucket.refresh.clear()

    def _stack_reference(self, bucket, recs, nodes, s0s) -> None:
        import jax.numpy as jnp
        spec = bucket.spec
        edges = [self._edge_arrays(rec, spec) for rec in recs]
        src = jnp.asarray(np.stack([e[0] for e in edges]))
        dst = jnp.asarray(np.stack([e[1] for e in edges]))
        stacked = {k: jnp.asarray(np.stack([n[k] for n in nodes]))
                   for k in nodes[0]}
        # the by-src views alias the by-dst arrays: the batched step and
        # epilogue only ever use the dst-sorted scatter
        bucket.args = PsiOperators(
            n=spec.n_pad, m=spec.e_pad, src_by_dst=src, dst_by_dst=dst,
            src_by_src=src, dst_by_src=dst, b_norm=bucket.scale, **stacked)
        bucket.s = jnp.asarray(np.stack(s0s))

    def _dense_adjacency(self, rec: _Tenant | None,
                         n_pad: int) -> np.ndarray:
        E = np.zeros((n_pad, n_pad), self._np_dtype)
        if rec is not None:
            E[rec.host.src_by_dst, rec.host.dst_by_dst] = 1.0
        return E

    def _stack_dense(self, bucket, recs, nodes, s0s) -> None:
        import jax.numpy as jnp
        spec = bucket.spec
        E = jnp.asarray(np.stack(
            [self._dense_adjacency(rec, spec.n_pad) for rec in recs]))
        vecs = {k: jnp.asarray(np.stack([n[k] for n in nodes]))
                for k in ("inv_w", "mu", "c")}
        bucket.args = (E, vecs["inv_w"], vecs["mu"], vecs["c"])
        bucket.s = jnp.asarray(np.stack(s0s))

    def _stack_pallas(self, bucket, recs, nodes, s0s) -> None:
        import jax.numpy as jnp

        from ..kernels.formats import pad_edge_tile_blocks
        from ..kernels.ops import DeviceEdgeTiles
        spec = bucket.spec
        tile, e1, e2 = self._bucket_plan(bucket, recs)
        fmts = [self._tenant_format(rec, spec, tile, e1, e2) for rec in recs]
        nb = max(f.num_blocks for f in fmts)
        bucket.nb = max(bucket.nb, -(-nb // 4) * 4)   # monotone, quantized
        fmts = [pad_edge_tile_blocks(f, bucket.nb) for f in fmts]
        data = {k: jnp.asarray(np.stack([getattr(f, k) for f in fmts]))
                for k in ("src_idx", "dst_local", "block_tile",
                          "block_first", "block_last")}
        ref = DeviceEdgeTiles.from_format(fmts[0])
        meta = {k: getattr(ref, k) for k in
                ("n", "n_pad", "n_gather", "tile", "e1", "e2", "num_tiles")}
        fmt = DeviceEdgeTiles(**meta, **data)
        n_fmt, n_g = ref.n_pad, ref.n_gather

        def pad_row(v, width):
            buf = np.zeros((1, width), self._np_dtype)
            buf[0, :v.shape[0]] = v
            return buf

        inv_w_g = jnp.asarray(np.stack(
            [pad_row(n["inv_w"], n_g) for n in nodes]))
        mu_pad = jnp.asarray(np.stack(
            [pad_row(n["mu"], n_fmt) for n in nodes]))
        c_pad = jnp.asarray(np.stack(
            [pad_row(n["c"], n_fmt) for n in nodes]))
        bucket.args = (fmt, inv_w_g, mu_pad, c_pad)
        bucket.s = jnp.asarray(np.stack(
            [pad_row(s0, n_fmt) for s0 in s0s]))

    def _bucket_plan(self, bucket: _Bucket,
                     recs) -> tuple[int, int, int]:
        """Edge-tile parameters shared by every tenant of this bucket."""
        if self._tile_override is not None:
            return self._tile_override
        if bucket.plan is None:
            from ..kernels import autotune
            rep = next((r for r in recs if r is not None), None)
            graph = (rep.host.graph() if rep is not None
                     else Graph(bucket.spec.n_pad, np.empty(0, np.int32),
                                np.empty(0, np.int32)))
            cache = (autotune.PLAN_CACHE if self._plan_cache is None
                     else self._plan_cache)
            bucket.plan = autotune.plan_for_bucket(
                graph, n_pad=bucket.spec.n_pad, e_pad=bucket.spec.e_pad,
                microbench=self.microbench, dtype=self.dtype,
                interpret=self.interpret, cache=cache)
        return bucket.plan.tile, bucket.plan.e1, bucket.plan.e2

    def _tenant_format(self, rec: _Tenant | None, spec: BucketSpec,
                       tile: int, e1: int, e2: int):
        from ..kernels.formats import build_edge_tiles
        if rec is None:
            gp = Graph(spec.n_pad, np.empty(0, np.int32),
                       np.empty(0, np.int32))
        else:
            gp = Graph(spec.n_pad, rec.host.src_by_dst.copy(),
                       rec.host.dst_by_dst.copy())
        return build_edge_tiles(gp, tile=tile, e1=e1, e2=e2)

    # -- internals: lane refresh (no restack) ---------------------------- #
    def _apply_refresh(self, bucket: _Bucket) -> None:
        import jax.numpy as jnp
        spec = bucket.spec
        for tid, kind in list(bucket.refresh.items()):
            lane = bucket.order.index(tid)
            rec = self._tenants[tid]
            node, b_norm, _ = self._node_arrays(rec, spec.n_pad)
            if self.use_b_norm:
                bucket.scale = bucket.scale.at[lane].set(b_norm)
            bucket.lam = bucket.lam.at[lane].set(jnp.asarray(node["lam"]))
            bucket.d = bucket.d.at[lane].set(jnp.asarray(node["d"]))
            if bucket.regime == "reference":
                ops = bucket.args
                repl = {k: getattr(ops, k).at[lane].set(jnp.asarray(v))
                        for k, v in node.items()}
                repl["b_norm"] = bucket.scale
                if kind == "edges":
                    src, dst = self._edge_arrays(rec, spec)
                    s_new = ops.src_by_dst.at[lane].set(jnp.asarray(src))
                    d_new = ops.dst_by_dst.at[lane].set(jnp.asarray(dst))
                    repl.update(src_by_dst=s_new, dst_by_dst=d_new,
                                src_by_src=s_new, dst_by_src=d_new)
                bucket.args = dataclasses.replace(ops, **repl)
            elif bucket.regime == "dense":
                E, inv_w, mu, c = bucket.args
                if kind == "edges":
                    E = E.at[lane].set(jnp.asarray(
                        self._dense_adjacency(rec, spec.n_pad)))
                bucket.args = (
                    E, inv_w.at[lane].set(jnp.asarray(node["inv_w"])),
                    mu.at[lane].set(jnp.asarray(node["mu"])),
                    c.at[lane].set(jnp.asarray(node["c"])))
            else:
                fmt, inv_w_g, mu_pad, c_pad = bucket.args
                if kind == "edges":
                    from ..kernels.formats import pad_edge_tile_blocks
                    tile, e1, e2 = self._bucket_plan(bucket, [rec])
                    f = self._tenant_format(rec, spec, tile, e1, e2)
                    if f.num_blocks > bucket.nb:
                        # block capacity outgrown — full restack; sync the
                        # device batch first so every lane (this one and
                        # its clean co-tenants) restacks from its current
                        # series vector, not a stale or cold one
                        self._invalidate_stack(bucket)
                        return
                    f = pad_edge_tile_blocks(f, bucket.nb)
                    fmt = dataclasses.replace(
                        fmt,
                        **{k: getattr(fmt, k).at[lane].set(
                            jnp.asarray(getattr(f, k)))
                           for k in ("src_idx", "dst_local", "block_tile",
                                     "block_first", "block_last")})

                def row(v, width):
                    buf = np.zeros((1, width), self._np_dtype)
                    buf[0, :v.shape[0]] = v
                    return jnp.asarray(buf)

                inv_w_g = inv_w_g.at[lane].set(row(node["inv_w"],
                                                   inv_w_g.shape[-1]))
                mu_pad = mu_pad.at[lane].set(row(node["mu"],
                                                 mu_pad.shape[-1]))
                c_pad = c_pad.at[lane].set(row(node["c"], c_pad.shape[-1]))
                bucket.args = (fmt, inv_w_g, mu_pad, c_pad)
        bucket.refresh.clear()

    def _run_epilogue(self, bucket: _Bucket):
        _, epilogue = self._loop_and_epilogue(bucket.regime)
        return epilogue(bucket.args, bucket.s, bucket.lam, bucket.d,
                        bucket.inv_n)


class TenantView(RankedQueries):
    """A PsiService-shaped thin view over one fleet tenant.

    Carries the full single-tenant serving surface — ``scores`` /
    ``scores_batch`` / ``top_k`` / ``rank_of`` plus the mutation pair
    ``update_activity`` / ``add_edges`` — but owns no solver: every call
    delegates to the shared fleet (and therefore batches with whatever
    co-tenants are dirty).  Obtained via ``fleet.view(tid)`` or
    :meth:`repro.core.incremental.PsiService.from_fleet`.
    """

    def __init__(self, fleet: TenantFleet, tenant_id: str):
        self._fleet = fleet
        self.tenant_id = tenant_id

    @property
    def backend(self) -> str:
        return f"fleet[{self._fleet.backend}]"

    @property
    def graph(self) -> Graph:
        return self._fleet._rec(self.tenant_id).host.graph()

    def update_activity(self, users, lam=None, mu=None) -> None:
        self._fleet.patch_activity(self.tenant_id, users, lam=lam, mu=mu)

    def add_edges(self, src, dst) -> None:
        self._fleet.patch_edges(self.tenant_id, src, dst)

    def remove_edges(self, src, dst) -> None:
        self._fleet.remove_edges(self.tenant_id, src, dst)

    def last_iterations(self) -> int:
        return self._fleet.last_iterations(self.tenant_id)

    @property
    def stale(self) -> bool:
        """True when mutations are pending a fleet solve (the next read
        triggers it — unlike PsiService, views never serve stale)."""
        return self._fleet._rec(self.tenant_id).staleness > 0

    def _obs_cache_state(self) -> str:
        rec = self._fleet._rec(self.tenant_id)
        entry = self._fleet.frontier._caches.get(self.tenant_id)
        fresh = entry is not None and entry[0] == rec.solved_epoch
        return "hit" if fresh and rec.staleness == 0 else "miss"

    def _query(self):
        return self._fleet.frontier.ranking(self.tenant_id)
