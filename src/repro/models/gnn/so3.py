"""SO(3) representation machinery for the equivariant GNNs.

Everything is *self-consistent by construction*:

  * complex Clebsch–Gordan via the Racah formula (float64 numpy, memoized),
  * complex→real change of basis U_l,
  * real Wigner matrices D^l(α, β) evaluated in pure real arithmetic
    (column-phase trick — TPU-friendly, no complex dtypes in the graph),
  * real spherical harmonics defined FROM the Wigner matrices:
    Y_l(r̂) = √((2l+1)/4π) · D^l(φ, θ)[:, m=0], which guarantees the
    Y ↔ D ↔ CG conventions agree (validated by the equivariance property
    tests in tests/test_so3.py).

The Wigner small-d is evaluated as a polynomial in (cos β/2, sin β/2) with
precomputed coefficient tensors, so the per-edge evaluation is a handful of
dense einsums — the TPU-native replacement for e3nn's gather-heavy kernels
(DESIGN.md §3).
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["real_cg", "wigner_d_beta", "wigner_real", "sph_harm_all",
           "irreps_dim", "l_offsets", "m_truncation_index"]


def irreps_dim(l_max: int) -> int:
    return (l_max + 1) ** 2


def l_offsets(l_max: int) -> list[int]:
    return [l * l for l in range(l_max + 1)]


# --------------------------------------------------------------------- #
# Complex CG (Racah) and the real basis
# --------------------------------------------------------------------- #
@lru_cache(maxsize=None)
def _fact(n: int) -> float:
    return float(math.factorial(n))


def _cg_complex(j1: int, m1: int, j2: int, m2: int, j3: int, m3: int
                ) -> float:
    if m3 != m1 + m2 or not (abs(j1 - j2) <= j3 <= j1 + j2):
        return 0.0
    pref = math.sqrt(
        (2 * j3 + 1) * _fact(j3 + j1 - j2) * _fact(j3 - j1 + j2) *
        _fact(j1 + j2 - j3) / _fact(j1 + j2 + j3 + 1))
    pref *= math.sqrt(
        _fact(j3 + m3) * _fact(j3 - m3) / (_fact(j1 + m1) * _fact(j1 - m1) *
                                           _fact(j2 + m2) * _fact(j2 - m2)))
    total = 0.0
    for k in range(max(0, j2 + m3 - j1), min(j3 - j1 + j2, j3 + m3) + 1):
        total += ((-1) ** (k + j2 + m2) * _fact(j2 + j3 + m1 - k) *
                  _fact(j1 - m1 + k) /
                  (_fact(k) * _fact(j3 - j1 + j2 - k) * _fact(j3 + m3 - k) *
                   _fact(k + j1 - j2 - m3)))
    return pref * total


@lru_cache(maxsize=None)
def _u_matrix(l: int) -> np.ndarray:
    """Complex→real change of basis: Y^real = U @ Y^complex.

    Row order: m' = −l..l (sin components negative, cos positive).
    """
    k = 2 * l + 1
    u = np.zeros((k, k), np.complex128)
    u[l, l] = 1.0
    s2 = 1.0 / math.sqrt(2.0)
    for m in range(1, l + 1):
        u[l + m, l + m] = (-1) ** m * s2        # cos row ← Y_m
        u[l + m, l - m] = s2                    # cos row ← Y_{−m}
        u[l - m, l - m] = 1j * s2               # sin row ← Y_{−m}
        u[l - m, l + m] = -1j * (-1) ** m * s2  # sin row ← Y_m
    return u


@lru_cache(maxsize=None)
def real_cg(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis coupling tensor C[m1', m2', m3'] (float64).

    Defined so that for real Wigner matrices D:
      C · (D^{l1} x) ⊗ (D^{l2} y) = D^{l3} (C · x ⊗ y).
    The complex CG picks up a phase under the real transform; we take the
    component (real or imaginary) that carries the weight and verify
    equivariance in tests.
    """
    k1, k2, k3 = 2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1
    cg = np.zeros((k1, k2, k3), np.complex128)
    for m1 in range(-l1, l1 + 1):
        for m2 in range(-l2, l2 + 1):
            m3 = m1 + m2
            if abs(m3) <= l3:
                cg[l1 + m1, l2 + m2, l3 + m3] = _cg_complex(
                    l1, m1, l2, m2, l3, m3)
    u1, u2, u3 = _u_matrix(l1), _u_matrix(l2), _u_matrix(l3)
    # C_real = (U1 ⊗ U2) C (U3)^†  with the CG viewed as map (m1,m2)→m3
    creal = np.einsum("ac,bd,cde,fe->abf", u1, u2, cg, np.conj(u3))
    re, im = np.real(creal), np.imag(creal)
    if np.abs(im).max() > np.abs(re).max():
        return np.ascontiguousarray(im)
    return np.ascontiguousarray(re)


# --------------------------------------------------------------------- #
# Wigner small-d polynomial tables
# --------------------------------------------------------------------- #
@lru_cache(maxsize=None)
def _d_tables(l: int):
    """Coefficients/exponents so that d[mp, m] = Σ_t coef·c^pc·s^ps."""
    k = 2 * l + 1
    terms: list[tuple[int, int, float, int, int]] = []
    for mp in range(-l, l + 1):
        for m in range(-l, l + 1):
            pref = math.sqrt(_fact(l + mp) * _fact(l - mp) *
                             _fact(l + m) * _fact(l - m))
            for s in range(max(0, m - mp), min(l + m, l - mp) + 1):
                denom = (_fact(l + m - s) * _fact(s) * _fact(mp - m + s) *
                         _fact(l - mp - s))
                coef = (-1) ** (mp - m + s) * pref / denom
                pc = 2 * l + m - mp - 2 * s
                ps = mp - m + 2 * s
                terms.append((l + mp, l + m, coef, pc, ps))
    idx = np.array([(t[0], t[1]) for t in terms], np.int32)
    coef = np.array([t[2] for t in terms], np.float64)
    pc = np.array([t[3] for t in terms], np.int32)
    ps = np.array([t[4] for t in terms], np.int32)
    return k, idx, coef, pc, ps


def wigner_d_beta(l: int, cos_beta: jax.Array) -> jax.Array:
    """Real small-d matrix d^l(β): [..., 2l+1, 2l+1] from cos β."""
    k, idx, coef, pc, ps = _d_tables(l)
    cb2 = jnp.sqrt(jnp.clip((1 + cos_beta) / 2, 0, 1))
    sb2 = jnp.sqrt(jnp.clip((1 - cos_beta) / 2, 0, 1))
    # [..., T] term values
    vals = (jnp.asarray(coef, cos_beta.dtype) *
            cb2[..., None] ** jnp.asarray(pc, cos_beta.dtype) *
            sb2[..., None] ** jnp.asarray(ps, cos_beta.dtype))
    out = jnp.zeros(cos_beta.shape + (k, k), cos_beta.dtype)
    return out.at[..., idx[:, 0], idx[:, 1]].add(vals)


@lru_cache(maxsize=None)
def _u_parts(l: int):
    u = _u_matrix(l)
    return (np.ascontiguousarray(np.real(u)),
            np.ascontiguousarray(np.imag(u)))


def wigner_real(l: int, alpha: jax.Array, cos_beta: jax.Array) -> jax.Array:
    """Real Wigner matrix D^l(α, β, γ=0): [..., 2l+1, 2l+1].

    D^r = Re( U · diag(e^{−imα}) · d(β) · U^† ), evaluated with real
    arithmetic only (Mr/Mi column-phase decomposition).
    """
    ur, ui = _u_parts(l)
    ur = jnp.asarray(ur, alpha.dtype)
    ui = jnp.asarray(ui, alpha.dtype)
    m = jnp.arange(-l, l + 1, dtype=alpha.dtype)
    ca = jnp.cos(alpha[..., None] * m)       # [..., K]
    sa = jnp.sin(alpha[..., None] * m)
    # M = U diag(e^{-imα}):  M[:, m] = U[:, m]·(cos − i sin)
    mr = ur * ca[..., None, :] + ui * sa[..., None, :]
    mi = ui * ca[..., None, :] - ur * sa[..., None, :]
    d = wigner_d_beta(l, cos_beta)           # [..., K, K]
    # V = U^† → Vr = urᵀ, Vi = −uiᵀ;  Re(M d V) = Mr d Vr − Mi d Vi
    vr, vi = ur.T, -ui.T
    md_r = mr @ d
    md_i = mi @ d
    return md_r @ vr - md_i @ vi


def rotation_angles(rhat: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(α=φ, cos β=cos θ) of the rotation R(φ,θ) with R·ẑ = r̂."""
    x, y, z = rhat[..., 0], rhat[..., 1], rhat[..., 2]
    return jnp.arctan2(y, x), jnp.clip(z, -1.0, 1.0)


def sph_harm_all(l_max: int, rhat: jax.Array) -> list[jax.Array]:
    """Real orthonormal spherical harmonics [Y_0, …, Y_{l_max}].

    Y_l(r̂) = √((2l+1)/4π) · D^l(φ, θ)[:, m=0] — consistent with
    ``wigner_real`` by construction. Each element: [..., 2l+1].
    """
    alpha, cb = rotation_angles(rhat)
    out = []
    for l in range(l_max + 1):
        d = wigner_real(l, alpha, cb)
        out.append(math.sqrt((2 * l + 1) / (4 * math.pi)) * d[..., :, l])
    return out


def rotate_to_frame(x_l: jax.Array, d_l: jax.Array, inverse: bool = False
                    ) -> jax.Array:
    """Apply D (or Dᵀ) blockwise: x [..., K, C], D [..., K, K]."""
    if inverse:
        return jnp.einsum("...km,...kc->...mc", d_l, x_l)
    return jnp.einsum("...mk,...kc->...mc", d_l, x_l)


def m_truncation_index(l_max: int, m_max: int) -> np.ndarray:
    """Flat irrep indices with |m| ≤ m_max (eSCN truncation)."""
    idx = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            if abs(m) <= m_max:
                idx.append(l * l + l + m)
    return np.asarray(idx, np.int32)
