"""2-D sharded message passing — the GNN collective hillclimb (§Perf).

The baseline GNN cells let GSPMD partition ``segment_sum`` over edge/node
arrays, which materializes gather operands with all-gathers (the
graphsage-reddit/ogb_products cell is the most collective-bound in the
baseline table). This module reuses the ψ-score 2-D block-cyclic partition
(DESIGN.md §4) for *feature matrices*: device (r, c) owns the edges with
src ∈ block-cyclic row r, dst ∈ contiguous column block c, and one layer of
mean-aggregation costs exactly

    psum_scatter [Nc, F]  over the src rows   (reduce of local partials)
  + all_gather   [N/d, F] over the columns    (reassemble the row shard)

per layer — the same bandwidth-optimal schedule as the ψ push, versus the
baseline's full-activation all-gathers. ``GraphSAGE`` is the instantiated
consumer (sharded_sage_apply); the pattern generalizes to any src-feature
message function.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ...compat import shard_map
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ...graphs.partition import Partition2D, partition_2d

__all__ = ["ShardedGraph", "build_sharded_graph", "make_sage_layer",
           "sharded_sage_apply"]


@dataclasses.dataclass(frozen=True)
class ShardedGraph:
    """Edge blocks + degree tables in the 2-D layouts (a pytree)."""
    src_local: jax.Array    # i32[d, mo, e_max] block-cyclic src ids
    dst_local: jax.Array    # i32[d, mo, e_max] contiguous dst ids
    deg_piece: jax.Array    # f[d, mo, q] in-degree in piece layout


jax.tree_util.register_dataclass(
    ShardedGraph, data_fields=["src_local", "dst_local", "deg_piece"],
    meta_fields=[])


def build_sharded_graph(graph, mesh: Mesh, *, bidirectional: bool = True
                        ) -> tuple[Partition2D, ShardedGraph]:
    axes = mesh.axis_names
    d = int(np.prod([mesh.shape[a] for a in axes[:-1]]))
    mo = mesh.shape[axes[-1]]
    g = graph
    if bidirectional:
        from ...graphs.structure import Graph
        g = Graph(g.n, np.concatenate([g.src, g.dst]),
                  np.concatenate([g.dst, g.src]), name=g.name)
    part = partition_2d(g, d, mo)
    deg = np.zeros(part.n_pad, np.float32)
    np.add.at(deg[: g.n], g.dst, 1.0)
    src_axes = axes[:-1]
    grid = NamedSharding(mesh, P(src_axes, axes[-1], None))
    sg = ShardedGraph(
        src_local=jax.device_put(part.src_local, grid),
        dst_local=jax.device_put(part.dst_local, grid),
        deg_piece=jax.device_put(part.to_piece_layout(deg), grid))
    return part, sg


def make_sage_layer(part: Partition2D, mesh: Mesh):
    """One mean-aggregate + dense update layer on 2-D sharded features.

    x: f[d, local_n, F] (block-cyclic src layout, sharded over src axes,
    replicated over the column axis). weights replicated. Returns same
    layout. Collectives: one psum_scatter + one all_gather of features.
    """
    axes = mesh.axis_names
    src_axes = axes[:-1]
    col_axis = axes[-1]
    nc = part.nc
    q = part.q

    def local(x, sg: ShardedGraph, w_self, b_self, w_neigh, b_neigh):
        x_loc = x[0]                               # [local_n, F]
        f = x_loc.shape[-1]
        src_ids = sg.src_local[0, 0]
        dst_ids = sg.dst_local[0, 0]
        x_pad = jnp.concatenate([x_loc, jnp.zeros((1, f), x.dtype)], 0)
        msgs = x_pad[src_ids]                      # [e_max, F]
        partial = jax.ops.segment_sum(
            msgs, dst_ids, nc + 1, indices_are_sorted=True)[:nc]
        agg_piece = jax.lax.psum_scatter(
            partial, src_axes, scatter_dimension=0, tiled=True)  # [q, F]
        mean_piece = agg_piece / jnp.maximum(sg.deg_piece[0, 0][:, None], 1)
        # self features of this piece = local slice c·q … (c+1)·q of row r
        c_idx = jax.lax.axis_index(col_axis)
        self_piece = jax.lax.dynamic_slice_in_dim(x_loc, c_idx * q, q, 0)
        h = jax.nn.relu(self_piece @ w_self + b_self +
                        mean_piece @ w_neigh + b_neigh)
        h = h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True),
                            1e-6)
        # reassemble this row's block-cyclic shard for the next layer
        return jax.lax.all_gather(h, col_axis, axis=0, tiled=True)[None]

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(src_axes, None, None),
                  ShardedGraph(src_local=P(src_axes, col_axis, None),
                               dst_local=P(src_axes, col_axis, None),
                               deg_piece=P(src_axes, col_axis, None)),
                  P(None, None), P(None), P(None, None), P(None)),
        out_specs=P(src_axes, None, None))


def sharded_sage_apply(params, x_src_layout, part: Partition2D, sg,
                       mesh: Mesh, cfg):
    """Full sharded GraphSAGE forward: features stay 2-D sharded end-to-end.

    x_src_layout: f[d, local_n, d_feat] (see Partition2D.to_src_layout).
    Returns logits in the same layout.
    """
    h = x_src_layout
    for lyr in params["layers"]:
        layer_fn = make_sage_layer(part, mesh)
        h = layer_fn(h, sg, lyr["w_self"]["w"], lyr["w_self"]["b"],
                     lyr["w_neigh"]["w"], lyr["w_neigh"]["b"])
    return jnp.einsum("dnf,fc->dnc", h, params["head"]["w"]) + \
        params["head"]["b"]
