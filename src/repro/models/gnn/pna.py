"""PNA — Principal Neighbourhood Aggregation [arXiv:2004.05718].

Per layer: 4 aggregators (mean, max, min, std) × 3 degree scalers
(identity, amplification log(d+1)/δ, attenuation δ/log(d+1)) concatenated
(12·F) → linear tower, residual + norm. δ = mean of log(d+1) over the
training graph (passed in via config or computed from the batch).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import GraphBatch, dense_init, segment_agg

__all__ = ["PNAConfig", "init_params", "apply", "loss_fn"]

_AGGS = ("mean", "max", "min", "std")


@dataclasses.dataclass(frozen=True)
class PNAConfig:
    name: str = "pna"
    n_layers: int = 4
    d_hidden: int = 75
    d_feat: int = 1433
    n_classes: int = 7
    delta: float = 2.5            # avg log-degree normalizer
    out_kind: str = "node"        # node | graph
    dtype: object = jnp.float32


def init_params(cfg: PNAConfig, key: jax.Array) -> dict:
    keys = jax.random.split(key, cfg.n_layers + 2)
    enc = dense_init(keys[0], cfg.d_feat, cfg.d_hidden, cfg.dtype)
    layers = [dense_init(keys[i + 1], 12 * cfg.d_hidden + cfg.d_hidden,
                         cfg.d_hidden, cfg.dtype)
              for i in range(cfg.n_layers)]
    head = dense_init(keys[-1], cfg.d_hidden, cfg.n_classes, cfg.dtype)
    return dict(enc=enc, layers=layers, head=head)


def apply(params, batch: GraphBatch, cfg: PNAConfig) -> jax.Array:
    h = batch.x.astype(cfg.dtype) @ params["enc"]["w"] + params["enc"]["b"]
    deg = jax.ops.segment_sum(
        jnp.ones_like(batch.dst, cfg.dtype), batch.dst,
        num_segments=batch.n + 1, indices_are_sorted=True)[:batch.n]
    logd = jnp.log(deg + 1.0)
    scalers = (jnp.ones_like(logd), logd / cfg.delta,
               cfg.delta / jnp.maximum(logd, 1e-2))
    def layer(h, lyr):
        msgs = h[batch.src]
        aggs = [segment_agg(msgs, batch.dst, batch.n, a) for a in _AGGS]
        feats = [a * s[:, None] for a in aggs for s in scalers]
        z = jnp.concatenate([h] + feats, axis=-1)
        return h + jax.nn.silu(z @ lyr["w"] + lyr["b"])

    for lyr in params["layers"]:
        h = jax.checkpoint(layer)(h, lyr)
    return h @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params, batch: GraphBatch, cfg: PNAConfig) -> jax.Array:
    logits = apply(params, batch, cfg)
    if cfg.out_kind == "graph":
        from .common import graph_pool
        pooled = graph_pool(logits, batch, "mean")
        return jnp.mean(jnp.square(pooled[:, 0] - batch.labels))
    labels = batch.labels
    mask = (batch.node_mask if batch.node_mask is not None
            else jnp.ones((batch.n,), bool)).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[:, None], axis=-1)[:, 0]
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
