"""EquiformerV2 — equivariant graph attention via eSCN convolutions
[arXiv:2306.12059].

The eSCN insight implemented TPU-natively (DESIGN.md §3): rotating each edge's
irrep features into the edge frame makes the tensor-product convolution
block-diagonal in m, reducing the O(L⁶) CG contraction to O(L³) dense
matmuls — exactly the MXU regime. Per block:

  1. equivariant RMS norm (per-l, learned per-channel scale),
  2. rotate src/dst features to the edge frame with real Wigner matrices
     (``so3.wigner_real``), truncated to |m| ≤ m_max (columns sliced from D,
     so the truncation costs nothing),
  3. SO(2) convolution: one dense matmul per m (complex-structured W_r/W_i
     pairs for m > 0), modulated by a radial MLP,
  4. multi-head attention: logits from the m=0 (scalar) channels of src ⊕
     dst → segment-softmax over incoming edges,
  5. rotate messages back, scatter-sum onto destinations, per-l output
     linear, residual; then a gated equivariant FFN.

Wigner matrices are computed once per forward and shared across layers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from . import so3
from .common import GraphBatch, dense_init, graph_pool, mlp_apply, mlp_init
from .nequip import _bessel

__all__ = ["EquiformerV2Config", "init_params", "apply", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class EquiformerV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 16
    out_kind: str = "graph"        # graph | node | node_class
    n_classes: int = 1
    dtype: object = jnp.float32


def _m_layout(l_max: int, m_max: int):
    """Truncated per-l kept-m columns and per-m row groups."""
    kept_cols = []      # per l: indices of kept m within [0, 2l+1)
    trunc_lm = []       # (l, m) in truncated row order
    for l in range(l_max + 1):
        cols = [l + m for m in range(-min(l, m_max), min(l, m_max) + 1)]
        kept_cols.append(np.asarray(cols, np.int32))
        trunc_lm += [(l, m) for m in range(-min(l, m_max), min(l, m_max) + 1)]
    groups = {}
    for m in range(-m_max, m_max + 1):
        groups[m] = np.asarray(
            [i for i, (l, mm) in enumerate(trunc_lm) if mm == m], np.int32)
    km = len(trunc_lm)
    return kept_cols, groups, km


def init_params(cfg: EquiformerV2Config, key: jax.Array) -> dict:
    C, L, M = cfg.d_hidden, cfg.l_max, cfg.m_max
    _, groups, km = _m_layout(L, M)
    n0 = groups[0].shape[0]                       # #l's at m=0 (= L+1)
    keys = iter(jax.random.split(
        key, 8 + cfg.n_layers * (2 * (L + 1) + 2 * M + 6)))
    embed = dense_init(next(keys), cfg.d_feat, C, cfg.dtype)
    layers = []
    for _ in range(cfg.n_layers):
        lp = dict(
            norm_scale=jnp.ones((L + 1, C), cfg.dtype),
            w0=dense_init(next(keys), n0 * C, n0 * C, cfg.dtype),
            alpha=mlp_init(next(keys), [2 * n0 * C, 64, cfg.n_heads],
                           cfg.dtype),
            radial=mlp_init(next(keys), [cfg.n_rbf, 32, (M + 1) * C],
                            cfg.dtype),
            out={f"l{l}": dense_init(next(keys), C, C, cfg.dtype)
                 for l in range(L + 1)},
            ffn_gate=dense_init(next(keys), C, L * C, cfg.dtype),
            ffn={f"l{l}": dense_init(next(keys), C, C, cfg.dtype)
                 for l in range(L + 1)},
        )
        for m in range(1, M + 1):
            nm = groups[m].shape[0]
            lp[f"w{m}r"] = dense_init(next(keys), nm * C, nm * C, cfg.dtype)
            lp[f"w{m}i"] = dense_init(next(keys), nm * C, nm * C, cfg.dtype)
        layers.append(lp)
    head = mlp_init(next(keys), [C, 64, cfg.n_classes], cfg.dtype)
    return dict(embed=embed, layers=layers, head=head)


def _so2_conv(xt, lp, groups, C, m_max, radial):
    """xt: [E, Km, C] edge-frame features → same shape. radial: [E, M+1, C]."""
    e = xt.shape[0]
    out = jnp.zeros_like(xt)
    g0 = groups[0]
    n0 = g0.shape[0]
    y0 = (xt[:, g0].reshape(e, n0 * C) @ lp["w0"]["w"] + lp["w0"]["b"])
    out = out.at[:, g0].set(
        y0.reshape(e, n0, C) * radial[:, 0][:, None, :])
    for m in range(1, m_max + 1):
        gp, gn = groups[m], groups[-m]
        nm = gp.shape[0]
        a = xt[:, gp].reshape(e, nm * C)
        b = xt[:, gn].reshape(e, nm * C)
        wr, wi = lp[f"w{m}r"]["w"], lp[f"w{m}i"]["w"]
        yp = (a @ wr - b @ wi).reshape(e, nm, C)
        yn = (a @ wi + b @ wr).reshape(e, nm, C)
        scale = radial[:, m][:, None, :]
        out = out.at[:, gp].set(yp * scale)
        out = out.at[:, gn].set(yn * scale)
    return out


def apply(params, batch: GraphBatch, cfg: EquiformerV2Config) -> jax.Array:
    n, C, L, M, H = (batch.n, cfg.d_hidden, cfg.l_max, cfg.m_max,
                     cfg.n_heads)
    kept_cols, groups, km = _m_layout(L, M)
    groups = {m: jnp.asarray(g) for m, g in groups.items()}
    offs = so3.l_offsets(L)

    pos = batch.pos.astype(cfg.dtype)
    pos_p = jnp.concatenate([pos, jnp.zeros((1, 3), cfg.dtype)], 0)
    rvec = pos_p[batch.src] - pos_p[batch.dst]
    dist = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    rhat = rvec / jnp.maximum(dist[:, None], 1e-9)
    rbf = _bessel(dist, cfg.n_rbf, cfg.cutoff)

    # Wigner matrices per l, truncated columns — once per forward
    alpha_ang, cb = so3.rotation_angles(rhat)
    dws = []
    for l in range(L + 1):
        d = so3.wigner_real(l, alpha_ang, cb)          # [E, 2l+1, 2l+1]
        dws.append(d[:, :, jnp.asarray(kept_cols[l])])  # [E, 2l+1, kl]

    # features: flat irreps [N, (L+1)^2, C]
    x = jnp.zeros((n, (L + 1) ** 2, C), cfg.dtype)
    x = x.at[:, 0].set(batch.x.astype(cfg.dtype) @ params["embed"]["w"]
                       + params["embed"]["b"])

    for lp in params["layers"]:
        # --- equivariant norm --------------------------------------- #
        xs = []
        for l in range(L + 1):
            blk = x[:, offs[l]:offs[l] + 2 * l + 1]
            rms = jnp.sqrt(jnp.mean(jnp.square(blk), axis=(1, 2),
                                    keepdims=True) + 1e-6)
            xs.append(blk / rms * lp["norm_scale"][l][None, None, :])
        xn = jnp.concatenate(xs, axis=1)
        xn_p = jnp.concatenate([xn, jnp.zeros((1, (L + 1) ** 2, C),
                                              cfg.dtype)], 0)

        # --- rotate into edge frames (truncated) ---------------------- #
        def to_frame(feats):
            parts = []
            for l in range(L + 1):
                blk = feats[:, offs[l]:offs[l] + 2 * l + 1]
                parts.append(jnp.einsum("eak,eac->ekc", dws[l], blk))
            return jnp.concatenate(parts, axis=1)      # [E, Km, C]

        xs_src = to_frame(xn_p[batch.src])
        xs_dst = to_frame(xn_p[batch.dst])

        # --- attention logits from scalar (m=0) channels -------------- #
        g0 = groups[0]
        feat = jnp.concatenate(
            [xs_src[:, g0].reshape(-1, (L + 1) * C),
             xs_dst[:, g0].reshape(-1, (L + 1) * C)], axis=-1)
        logits = mlp_apply(lp["alpha"], feat)           # [E, H]
        from .common import segment_softmax
        att = segment_softmax(logits, batch.dst, n)     # [E, H]

        # --- SO(2) conv value + heads --------------------------------- #
        radial = mlp_apply(lp["radial"], rbf).reshape(-1, M + 1, C)
        val = _so2_conv(xs_src, lp, groups, C, M, radial)  # [E, Km, C]
        val = val.reshape(val.shape[0], km, H, C // H)
        msg = (val * att[:, None, :, None]).reshape(-1, km, C)

        # --- rotate back + aggregate ---------------------------------- #
        agg = jnp.zeros((n + 1, (L + 1) ** 2, C), cfg.dtype)
        col = 0
        for l in range(L + 1):
            kl = kept_cols[l].shape[0]
            blk = jnp.einsum("eak,ekc->eac", dws[l], msg[:, col:col + kl])
            agg = agg.at[batch.dst, offs[l]:offs[l] + 2 * l + 1].add(blk)
            col += kl
        agg = agg[:n]

        # per-l output linear + residual
        upd = []
        for l in range(L + 1):
            blk = agg[:, offs[l]:offs[l] + 2 * l + 1]
            upd.append(jnp.einsum("nmc,cd->nmd", blk, lp["out"][f"l{l}"]["w"]))
        x = x + jnp.concatenate(upd, axis=1)

        # --- gated equivariant FFN ------------------------------------ #
        scal = x[:, 0]
        gates = jax.nn.sigmoid(scal @ lp["ffn_gate"]["w"]
                               + lp["ffn_gate"]["b"]).reshape(n, L, C)
        f = []
        for l in range(L + 1):
            blk = x[:, offs[l]:offs[l] + 2 * l + 1]
            h = jnp.einsum("nmc,cd->nmd", blk, lp["ffn"][f"l{l}"]["w"])
            if l == 0:
                h = jax.nn.silu(h + lp["ffn"][f"l{l}"]["b"][None, None, :])
            else:
                h = h * gates[:, l - 1][:, None, :]
            f.append(h)
        x = x + jnp.concatenate(f, axis=1)

    return mlp_apply(params["head"], x[:, 0])


def loss_fn(params, batch: GraphBatch, cfg: EquiformerV2Config) -> jax.Array:
    out = apply(params, batch, cfg)
    if cfg.out_kind == "graph":
        pooled = graph_pool(out, batch, "sum")[:, 0]
        return jnp.mean(jnp.square(pooled - batch.labels))
    if cfg.out_kind == "node_class":
        logz = jax.scipy.special.logsumexp(out, axis=-1)
        gold = jnp.take_along_axis(
            out, jnp.clip(batch.labels, 0)[:, None], axis=-1)[:, 0]
        mask = (batch.node_mask if batch.node_mask is not None else
                jnp.ones((batch.n,), bool)).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    mask = (batch.node_mask if batch.node_mask is not None else
            jnp.ones((batch.n,), bool)).astype(jnp.float32)
    return jnp.sum(jnp.square(out[:, 0] - batch.labels) * mask) / \
        jnp.maximum(mask.sum(), 1.0)
