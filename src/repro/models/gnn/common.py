"""Shared GNN substrate: graph batches, segment aggregation, MLPs.

Message passing is built on ``jax.ops.segment_*`` over an edge-index →
node scatter (JAX has no CSR/CSC sparse — this IS part of the system, per
the assignment). Edges are dst-sorted with sentinel padding (src = dst = n;
the sentinel row is dropped by aggregating into n+1 segments).

The same edge layout feeds the Pallas ``seg_mm`` kernel (kernels/seg_mm.py)
— the GNN aggregation and the ψ-score push share one kernel regime
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GraphBatch", "segment_agg", "segment_softmax", "graph_pool",
           "mlp_init", "mlp_apply", "dense_init", "batch_from_graph",
           "pad_graph_batch"]


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """One (possibly batched/padded) graph. n = #node slots (incl. pad)."""
    n: int                      # static node count (padded)
    x: jax.Array                # f[n, d_feat] node features (pad rows zero)
    src: jax.Array              # i32[e] sender; sentinel = n
    dst: jax.Array              # i32[e] receiver (sorted); sentinel = n
    pos: jax.Array | None = None        # f[n, 3] positions (geometric nets)
    node_mask: jax.Array | None = None  # bool[n] valid nodes
    graph_ids: jax.Array | None = None  # i32[n] for batched-graph pooling
    n_graphs: int = 1
    labels: jax.Array | None = None     # i32[n] or f[n_graphs, ...]
    seed_mask: jax.Array | None = None  # bool[n] readout nodes (minibatch)


jax.tree_util.register_dataclass(
    GraphBatch,
    data_fields=["x", "src", "dst", "pos", "node_mask", "graph_ids",
                 "labels", "seed_mask"],
    meta_fields=["n", "n_graphs"])


def segment_agg(values: jax.Array, dst: jax.Array, n: int, kind: str,
                *, indices_are_sorted: bool = True) -> jax.Array:
    """Aggregate edge rows onto nodes. kind ∈ {sum, mean, max, min, std}."""
    kw = dict(num_segments=n + 1, indices_are_sorted=indices_are_sorted)
    if kind == "sum":
        return jax.ops.segment_sum(values, dst, **kw)[:n]
    if kind == "mean":
        s = jax.ops.segment_sum(values, dst, **kw)[:n]
        cnt = jax.ops.segment_sum(jnp.ones_like(dst, values.dtype), dst,
                                  **kw)[:n]
        return s / jnp.maximum(cnt[..., None] if values.ndim > 1 else cnt, 1)
    if kind == "max":
        m = jax.ops.segment_max(values, dst, **kw)[:n]
        return jnp.where(jnp.isfinite(m), m, 0.0)
    if kind == "min":
        m = jax.ops.segment_min(values, dst, **kw)[:n]
        return jnp.where(jnp.isfinite(m), m, 0.0)
    if kind == "std":
        mean = segment_agg(values, dst, n, "mean")
        sq = segment_agg(values * values, dst, n, "mean")
        return jnp.sqrt(jnp.maximum(sq - mean * mean, 1e-8))
    raise ValueError(kind)


def segment_softmax(logits: jax.Array, dst: jax.Array, n: int) -> jax.Array:
    """Edge-wise softmax normalized per destination node."""
    kw = dict(num_segments=n + 1, indices_are_sorted=True)
    mx = jax.ops.segment_max(logits, dst, **kw)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    e = jnp.exp(logits - mx[dst])
    z = jax.ops.segment_sum(e, dst, **kw)
    return e / jnp.maximum(z[dst], 1e-20)


def graph_pool(values: jax.Array, batch: GraphBatch, kind: str = "sum"
               ) -> jax.Array:
    """Pool node values per graph (molecule shape)."""
    gid = (batch.graph_ids if batch.graph_ids is not None
           else jnp.zeros((batch.n,), jnp.int32))
    if batch.node_mask is not None:
        values = values * batch.node_mask[:, None].astype(values.dtype)
    out = jax.ops.segment_sum(values, gid, num_segments=batch.n_graphs)
    if kind == "mean":
        cnt = jax.ops.segment_sum(
            (batch.node_mask.astype(values.dtype)
             if batch.node_mask is not None
             else jnp.ones((batch.n,), values.dtype)),
            gid, num_segments=batch.n_graphs)
        out = out / jnp.maximum(cnt[:, None], 1)
    return out


# --------------------------------------------------------------------- #
# Tiny functional-MLP helpers
# --------------------------------------------------------------------- #
def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(d_in)
    return dict(w=jax.random.normal(key, (d_in, d_out), dtype) * scale,
                b=jnp.zeros((d_out,), dtype))


def mlp_init(key, dims: list[int], dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, a, b, dtype) for k, a, b in
            zip(keys, dims[:-1], dims[1:])]


def mlp_apply(layers, x, act=jax.nn.silu, final_act=False):
    for i, lyr in enumerate(layers):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(layers) - 1 or final_act:
            x = act(x)
    return x


# --------------------------------------------------------------------- #
# Batch builders
# --------------------------------------------------------------------- #
def batch_from_graph(graph, x: np.ndarray, *, labels=None, pos=None,
                     bidirectional: bool = True) -> GraphBatch:
    """Host Graph → device GraphBatch (dst-sorted, sentinel-padded)."""
    src, dst = graph.src, graph.dst
    if bidirectional:
        src, dst = (np.concatenate([src, graph.dst]),
                    np.concatenate([dst, graph.src]))
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    return GraphBatch(
        n=graph.n, x=jnp.asarray(x),
        src=jnp.asarray(src, jnp.int32), dst=jnp.asarray(dst, jnp.int32),
        pos=None if pos is None else jnp.asarray(pos),
        labels=None if labels is None else jnp.asarray(labels),
        node_mask=jnp.ones((graph.n,), bool))


def pad_graph_batch(b: GraphBatch, n_pad: int, e_pad: int) -> GraphBatch:
    """Pad to static (n_pad, e_pad) with sentinel edges and zero rows."""
    dn = n_pad - b.n
    de = e_pad - b.src.shape[0]
    pad_row = lambda a: (None if a is None else
                         jnp.concatenate([a, jnp.zeros((dn,) + a.shape[1:],
                                                       a.dtype)]))
    return GraphBatch(
        n=n_pad,
        x=pad_row(b.x),
        src=jnp.concatenate([b.src, jnp.full((de,), n_pad, jnp.int32)]),
        dst=jnp.concatenate([b.dst, jnp.full((de,), n_pad, jnp.int32)]),
        pos=pad_row(b.pos),
        node_mask=(jnp.concatenate([b.node_mask, jnp.zeros((dn,), bool)])
                   if b.node_mask is not None else
                   jnp.concatenate([jnp.ones((b.n,), bool),
                                    jnp.zeros((dn,), bool)])),
        graph_ids=(None if b.graph_ids is None else
                   jnp.concatenate([b.graph_ids,
                                    jnp.zeros((dn,), jnp.int32)])),
        n_graphs=b.n_graphs,
        labels=b.labels,
        seed_mask=(None if b.seed_mask is None else
                   jnp.concatenate([b.seed_mask, jnp.zeros((dn,), bool)])))
