"""GraphSAGE [arXiv:1706.02216] — mean aggregator, fanout-sampled training.

h_v^{k+1} = σ( W_self h_v ⊕ W_neigh · mean_{u∈N(v)} h_u )   (concat variant)

Node classification head; the ``minibatch_lg`` shape consumes subgraphs from
``graphs.sampler.fanout_sample`` and reads out seed nodes only.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import GraphBatch, dense_init, mlp_apply, segment_agg

__all__ = ["SageConfig", "init_params", "apply", "loss_fn"]


@dataclasses.dataclass(frozen=True)
class SageConfig:
    name: str = "graphsage-reddit"
    n_layers: int = 2
    d_hidden: int = 128
    aggregator: str = "mean"
    sample_sizes: tuple[int, ...] = (25, 10)
    d_feat: int = 602
    n_classes: int = 41
    out_kind: str = "node"        # node | graph (molecule shape)
    dtype: object = jnp.float32


def init_params(cfg: SageConfig, key: jax.Array) -> dict:
    keys = jax.random.split(key, 2 * cfg.n_layers + 1)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        layers.append(dict(
            w_self=dense_init(keys[2 * i], d_in, cfg.d_hidden, cfg.dtype),
            w_neigh=dense_init(keys[2 * i + 1], d_in, cfg.d_hidden,
                               cfg.dtype)))
        d_in = cfg.d_hidden
    head = dense_init(keys[-1], cfg.d_hidden, cfg.n_classes, cfg.dtype)
    return dict(layers=layers, head=head)


def apply(params, batch: GraphBatch, cfg: SageConfig) -> jax.Array:
    """→ logits f[n, n_classes]."""
    h = batch.x.astype(cfg.dtype)

    def layer(h, lyr):
        msgs = h[batch.src]
        agg = segment_agg(msgs, batch.dst, batch.n, cfg.aggregator)
        h = jax.nn.relu(
            h @ lyr["w_self"]["w"] + lyr["w_self"]["b"] +
            agg @ lyr["w_neigh"]["w"] + lyr["w_neigh"]["b"])
        # L2 normalize as in the paper
        return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True),
                               1e-6)

    for lyr in params["layers"]:
        h = jax.checkpoint(layer)(h, lyr)
    return h @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params, batch: GraphBatch, cfg: SageConfig) -> jax.Array:
    logits = apply(params, batch, cfg)
    if cfg.out_kind == "graph":
        from .common import graph_pool
        pooled = graph_pool(logits, batch, "mean")
        return jnp.mean(jnp.square(pooled[:, 0] - batch.labels))
    labels = batch.labels
    mask = (batch.seed_mask if batch.seed_mask is not None
            else batch.node_mask)
    mask = (mask if mask is not None
            else jnp.ones((batch.n,), bool)).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.clip(labels, 0)[:, None], axis=-1)[:, 0]
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
