"""NequIP — E(3)-equivariant interatomic potentials [arXiv:2101.03164].

Features are direct sums of real irreps {l: [N, 2l+1, C]} (l ≤ l_max = 2,
uniform multiplicity C = d_hidden). One interaction block:

  message  m_e^{l3} = Σ_{paths (l1,l2)} R_path(|r_e|) · CG^{l1 l2 l3}
                       · (x_src^{l1} ⊗ Y^{l2}(r̂_e))
  update   x^{l} ← SelfLinear_l( x^l + Σ_{e→v} m_e^l ),  gate nonlinearity
           (scalars: SiLU; l>0: sigmoid(scalar gates) scaling)

Radial R: Bessel basis (n_rbf) with polynomial cutoff envelope → MLP →
per-(path, channel) weights. Output: per-node scalar (energy) readout, or
graph-pooled regression for the ``molecule`` shape.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import so3
from .common import GraphBatch, dense_init, mlp_apply, mlp_init, graph_pool

__all__ = ["NequIPConfig", "init_params", "apply", "loss_fn", "paths_for"]


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    d_hidden: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 16              # input scalar features (species embed)
    out_kind: str = "graph"       # graph | node | node_class
    n_classes: int = 1
    dtype: object = jnp.float32


def paths_for(l_max: int) -> list[tuple[int, int, int]]:
    """All (l_in, l_filter, l_out) with every l ≤ l_max and CG-compatible."""
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l_max, l1 + l2) + 1):
                out.append((l1, l2, l3))
    return out


def _bessel(r, n_rbf, cutoff):
    """Bessel RBF with smooth polynomial envelope (DimeNet-style)."""
    rc = cutoff
    x = jnp.clip(r / rc, 1e-5, 1.0)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    rbf = jnp.sqrt(2.0 / rc) * jnp.sin(n * jnp.pi * x[..., None]) / (
        x[..., None] * rc)
    p = 6.0
    env = (1 - (p + 1) * (p + 2) / 2 * x ** p + p * (p + 2) * x ** (p + 1)
           - p * (p + 1) / 2 * x ** (p + 2))
    return rbf * env[..., None]


def init_params(cfg: NequIPConfig, key: jax.Array) -> dict:
    C = cfg.d_hidden
    paths = paths_for(cfg.l_max)
    keys = iter(jax.random.split(
        key, 6 + cfg.n_layers * (cfg.l_max + 4)))
    embed = dense_init(next(keys), cfg.d_feat, C, cfg.dtype)
    layers = []
    for _ in range(cfg.n_layers):
        radial = mlp_init(next(keys), [cfg.n_rbf, 32, len(paths) * C],
                          cfg.dtype)
        self_lin = {f"l{l}": dense_init(next(keys), C, C, cfg.dtype)
                    for l in range(cfg.l_max + 1)}
        gates = dense_init(next(keys), C, cfg.l_max * C, cfg.dtype)
        layers.append(dict(radial=radial, self_lin=self_lin, gates=gates))
    head = mlp_init(next(keys), [C, 32, cfg.n_classes], cfg.dtype)
    return dict(embed=embed, layers=layers, head=head)


def apply(params, batch: GraphBatch, cfg: NequIPConfig) -> jax.Array:
    """→ per-node output [n, n_classes] (pool for graph tasks in loss)."""
    n, C = batch.n, cfg.d_hidden
    paths = paths_for(cfg.l_max)
    pos = batch.pos.astype(cfg.dtype)
    # pad a sentinel row so src/dst == n is safe
    pos_p = jnp.concatenate([pos, jnp.zeros((1, 3), cfg.dtype)], 0)
    rvec = pos_p[batch.src] - pos_p[batch.dst]
    dist = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    rhat = rvec / jnp.maximum(dist[:, None], 1e-9)
    ys = so3.sph_harm_all(cfg.l_max, rhat)          # per l: [E, 2l+1]
    rbf = _bessel(dist, cfg.n_rbf, cfg.cutoff)      # [E, n_rbf]

    # features: x[l] : [n, 2l+1, C]
    x = {0: (batch.x.astype(cfg.dtype) @ params["embed"]["w"]
             + params["embed"]["b"])[:, None, :]}
    for l in range(1, cfg.l_max + 1):
        x[l] = jnp.zeros((n, 2 * l + 1, C), cfg.dtype)

    cg = {p: jnp.asarray(so3.real_cg(*p), cfg.dtype) for p in paths}

    for lyr in params["layers"]:
        w = mlp_apply(lyr["radial"], rbf).reshape(-1, len(paths), C)  # [E,P,C]
        agg = {l: jnp.zeros((n + 1, 2 * l + 1, C), cfg.dtype)
               for l in range(cfg.l_max + 1)}
        xp = {l: jnp.concatenate(
            [x[l], jnp.zeros((1, 2 * l + 1, C), cfg.dtype)], 0)
            for l in x}
        for pi, (l1, l2, l3) in enumerate(paths):
            xs = xp[l1][batch.src]                   # [E, 2l1+1, C]
            msg = jnp.einsum("pqr,epc,eq->erc", cg[(l1, l2, l3)], xs, ys[l2])
            msg = msg * w[:, pi][:, None, :]
            agg[l3] = agg[l3].at[batch.dst].add(msg)
        gates = jax.nn.sigmoid(
            x[0][:, 0, :] @ lyr["gates"]["w"] + lyr["gates"]["b"]
        ).reshape(n, cfg.l_max, C)
        new_x = {}
        for l in range(cfg.l_max + 1):
            h = x[l] + agg[l][:n]
            h = jnp.einsum("nmc,cd->nmd", h, lyr["self_lin"][f"l{l}"]["w"]) \
                + (lyr["self_lin"][f"l{l}"]["b"] if l == 0 else 0.0)
            if l == 0:
                h = jax.nn.silu(h)
            else:
                h = h * gates[:, l - 1][:, None, :]
            new_x[l] = h
        x = new_x

    return mlp_apply(params["head"], x[0][:, 0, :])


def loss_fn(params, batch: GraphBatch, cfg: NequIPConfig) -> jax.Array:
    out = apply(params, batch, cfg)
    if cfg.out_kind == "graph":
        pooled = graph_pool(out, batch, "sum")[:, 0]
        return jnp.mean(jnp.square(pooled - batch.labels))
    if cfg.out_kind == "node_class":
        logz = jax.scipy.special.logsumexp(out, axis=-1)
        gold = jnp.take_along_axis(
            out, jnp.clip(batch.labels, 0)[:, None], axis=-1)[:, 0]
        mask = (batch.node_mask if batch.node_mask is not None else
                jnp.ones((batch.n,), bool)).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    mask = (batch.node_mask if batch.node_mask is not None else
            jnp.ones((batch.n,), bool)).astype(jnp.float32)
    return jnp.sum(jnp.square(out[:, 0] - batch.labels) * mask) / \
        jnp.maximum(mask.sum(), 1.0)
