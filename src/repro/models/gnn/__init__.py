"""GNN family: PNA, GraphSAGE, NequIP, EquiformerV2 (+ SO(3) machinery)."""
from .common import (GraphBatch, segment_agg, segment_softmax, graph_pool,
                     batch_from_graph, pad_graph_batch)
from . import so3, sage, pna, nequip, equiformer_v2
