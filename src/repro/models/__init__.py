"""Model zoo: transformer LM family, GNN family, recsys family."""
