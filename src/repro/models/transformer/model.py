"""Decoder-only transformer family (TinyLlama / Yi / Nemotron / Mixtral).

Pure functional JAX: params are pytrees stacked over layers and consumed by
``lax.scan`` (keeps HLO size O(1) in depth — essential for compiling 96-layer
configs on the 512-device dry-run), with ``jax.checkpoint`` around the layer
body for activation rematerialization.

Features per the assigned configs:
  * GQA attention (n_kv_heads < n_heads) with RoPE,
  * flash-style blocked attention (see ``attention.py``) — banded O(S·W)
    schedule for sliding-window configs (Mixtral long_500k),
  * SwiGLU or squared-ReLU (Nemotron) FFN,
  * top-2 MoE (Mixtral) with TP-sharded experts and local token dispatch
    inside a nested shard_map (DESIGN.md: no all-to-all at E=8 ≤ TP=16),
  * grad accumulation + remat for the ≥100B-param memory envelope.

Sharding is GSPMD-style: pjit + with_sharding_constraint. Axis vocabulary:
batch → ("pod","data") (present axes only), TP (heads / d_ff / vocab) →
"model", FSDP (the other matrix dim of each weight) → ("pod","data").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from ...compat import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from .attention import attention

__all__ = ["MoECfg", "LMConfig", "init_params", "param_specs", "forward",
           "loss_fn", "make_train_step", "make_prefill", "make_decode_step",
           "init_cache", "cache_specs", "count_params", "active_params"]

TP = "model"


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _dp_size(mesh) -> int:
    import numpy as _np
    return int(_np.prod([mesh.shape[a] for a in dp_axes(mesh)])) if         dp_axes(mesh) else 1


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    act: str = "swiglu"                  # "swiglu" | "sq_relu"
    moe: MoECfg | None = None
    sliding_window: int | None = None
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: Any = jnp.bfloat16            # activation/compute dtype
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    accum_steps: int = 1
    optimizer: str = "adamw"             # "adafactor" for the ≥100B cells
    q_block: int = 512                   # flash attention block sizes
    k_block: int = 1024
    fsdp: bool = True                    # shard weights over the batch axes
    unroll_layers: bool = False          # probe mode: unroll the layer scan

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


def count_params(cfg: LMConfig) -> int:
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    if cfg.moe:
        ffn = cfg.moe.n_experts * (3 if cfg.act == "swiglu" else 2) * d * f \
            + d * cfg.moe.n_experts
    else:
        ffn = (3 if cfg.act == "swiglu" else 2) * d * f
    return cfg.n_layers * (attn + ffn + 2 * d) + 2 * v * d + d


def active_params(cfg: LMConfig) -> int:
    """Params touched per token (MoE: top-k experts) — for MODEL_FLOPS 6ND."""
    d, f = cfg.d_model, cfg.d_ff
    attn = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
    n_ff = (3 if cfg.act == "swiglu" else 2) * d * f
    ffn = (cfg.moe.top_k * n_ff + d * cfg.moe.n_experts) if cfg.moe else n_ff
    return cfg.n_layers * (attn + ffn + 2 * d) + 2 * cfg.vocab * d + d


# --------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------- #
def init_params(cfg: LMConfig, key: jax.Array) -> dict:
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    k = iter(jax.random.split(key, 16))
    pd = cfg.param_dtype

    def dense(key, *shape, scale=None):
        scale = scale or 1.0 / math.sqrt(shape[-2])
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(pd)

    layer = dict(
        wq=dense(next(k), L, d, cfg.q_dim),
        wk=dense(next(k), L, d, cfg.kv_dim),
        wv=dense(next(k), L, d, cfg.kv_dim),
        wo=dense(next(k), L, cfg.q_dim, d),
        norm1=jnp.ones((L, d), pd),
        norm2=jnp.ones((L, d), pd),
    )
    if cfg.moe:
        E = cfg.moe.n_experts
        layer["router"] = dense(next(k), L, d, E)
        layer["w1"] = dense(next(k), L, E, d, f)
        layer["w2"] = dense(next(k), L, E, f, d, scale=1 / math.sqrt(f))
        if cfg.act == "swiglu":
            layer["w3"] = dense(next(k), L, E, d, f)
    else:
        layer["w1"] = dense(next(k), L, d, f)
        layer["w2"] = dense(next(k), L, f, d, scale=1 / math.sqrt(f))
        if cfg.act == "swiglu":
            layer["w3"] = dense(next(k), L, d, f)
    return dict(
        embed=dense(next(k), v, d, scale=1.0),
        lm_head=dense(next(k), d, v),
        final_norm=jnp.ones((d,), pd),
        layers=layer,
    )


def param_specs(cfg: LMConfig, mesh) -> dict:
    """TP on heads/d_ff/vocab; FSDP (other matrix dim) on the batch axes.

    ``cfg.fsdp=False`` (models whose optimizer state fits per TP shard, e.g.
    TinyLlama) keeps weights replicated across the batch axes — saves the
    per-step weight all-gathers entirely (§Perf).
    """
    dp = dp_axes(mesh) if cfg.fsdp else None
    layer = dict(
        wq=P(None, dp, TP),
        wk=P(None, dp, TP),
        wv=P(None, dp, TP),
        wo=P(None, TP, dp),
        norm1=P(None, None),
        norm2=P(None, None),
    )
    if cfg.moe:
        layer["router"] = P(None, None, None)
        layer["w1"] = P(None, None, dp, TP)
        layer["w2"] = P(None, None, TP, dp)
        if cfg.act == "swiglu":
            layer["w3"] = P(None, None, dp, TP)
    else:
        layer["w1"] = P(None, dp, TP)
        layer["w2"] = P(None, TP, dp)
        if cfg.act == "swiglu":
            layer["w3"] = P(None, dp, TP)
    return dict(embed=P(TP, dp), lm_head=P(dp, TP), final_norm=P(None),
                layers=layer)


# --------------------------------------------------------------------- #
# Building blocks
# --------------------------------------------------------------------- #
def _rms_norm(x, scale, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            ).astype(x.dtype) * scale


def _rope(x, positions, theta):
    """x: [B, S, H, Dh]; positions: [B, S] absolute token positions."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = (1.0 / theta) ** (jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


def _moe_ffn(x, lp, cfg: LMConfig, mesh):
    """Top-k MoE: local token dispatch, TP-sharded experts, one psum."""
    import numpy as np
    moe = cfg.moe
    E, K = moe.n_experts, moe.top_k
    b, s, d = x.shape
    swiglu = cfg.act == "swiglu"
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if b % max(1, dp_size) != 0:
        dp = ()          # tiny batches (long_500k B=1): replicate tokens

    def local(x_loc, router, w1, w2, w3):
        tl = x_loc.shape[0] * x_loc.shape[1]
        xf = x_loc.reshape(tl, d)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        gates, eidx = jax.lax.top_k(jax.nn.softmax(logits, -1), K)
        gates = gates / jnp.sum(gates, -1, keepdims=True)
        cap = max(8, int(K * tl / E * moe.capacity_factor))

        flat_e = eidx.reshape(-1)                         # [K·T]
        order = jnp.argsort(flat_e)                       # stable
        tok = order // K
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(K * tl) - starts[sorted_e]
        keep = pos < cap
        slot = jnp.where(keep, sorted_e * cap + pos, E * cap)

        buf = jnp.zeros((E * cap + 1, d), x_loc.dtype).at[slot].set(xf[tok])
        h = buf[:E * cap].reshape(E, cap, d)
        if swiglu:
            hh = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, w1)) * \
                jnp.einsum("ecd,edf->ecf", h, w3)
        else:
            hh = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", h, w1)))
        y = jnp.einsum("ecf,efd->ecd", hh, w2).reshape(E * cap, d)
        y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], 0)
        gath = y[slot] * gates.reshape(-1)[order][:, None].astype(y.dtype)
        out = jnp.zeros((tl, d), x_loc.dtype).at[tok].add(gath)
        out = jax.lax.psum(out, TP)
        return out.reshape(x_loc.shape)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), P(None, None, TP),
                  P(None, TP, None), P(None, None, TP)),
        out_specs=P(dp, None, None)
    )(x, lp["router"], lp["w1"], lp["w2"],
      lp["w3"] if swiglu else lp["w1"])


def _dense_ffn(x, lp, cfg: LMConfig, cst, dp):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ lp["w1"]) * (x @ lp["w3"])
    else:
        h = jnp.square(jax.nn.relu(x @ lp["w1"]))
    # §Perf iteration 1: constraining (None, None, TP) here replicated the
    # batch axis — XLA materialized [B_full, S, ff/TP] f32 activations and
    # all-gathered their gradients (≈2.4 GB/layer/device on tinyllama
    # train_4k). Keeping the batch axes sharded removes those collectives.
    h = cst(h, dp, None, TP)
    return h @ lp["w2"]


def _make_cst(mesh):
    def cst(x, *spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    return cst


def _embed_lookup(embed, tokens, cfg: LMConfig, mesh, dp):
    """Vocab-sharded embedding gather via shard_map (mask + psum).

    §Perf iteration 2 — REFUTED and therefore unused: the hypothesis was
    that XLA's gather backward all-gathers the activation gradient; after
    the iteration-1 fix that all-gather no longer exists (it was fallout of
    the bad FFN constraint), and this form *adds* a psum of x
    (+0.13 GB/device/microbatch). Kept as the recorded negative result.
    """
    if "model" not in mesh.axis_names or             embed.shape[0] % mesh.shape["model"] != 0:
        return embed.astype(cfg.dtype)[tokens]
    rows = embed.shape[0] // mesh.shape["model"]

    def local(tbl, tok):
        r = jax.lax.axis_index(TP)
        rel = tok - r * rows
        ok = (rel >= 0) & (rel < rows)
        x = jnp.take(tbl.astype(cfg.dtype), jnp.clip(rel, 0, rows - 1),
                     axis=0)
        x = x * ok[..., None].astype(cfg.dtype)
        return jax.lax.psum(x, TP)

    tok_spec = P(dp, None) if tokens.ndim == 2 else P(dp)
    out_spec = P(dp, *([None] * tokens.ndim))
    embed_dim_spec = None if not cfg.fsdp else dp_axes(mesh)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(TP, embed_dim_spec), tok_spec),
        out_specs=out_spec)(embed, tokens)


# --------------------------------------------------------------------- #
# Forward (train / prefill)
# --------------------------------------------------------------------- #
def forward(params, tokens, cfg: LMConfig, mesh, *, positions=None):
    """tokens: i32[B, S] → logits f32[B, S, V] (TP-sharded on V)."""
    b, s = tokens.shape
    cst = _make_cst(mesh)
    dp = dp_axes(mesh)
    x = params["embed"].astype(cfg.dtype)[tokens]
    x = cst(x, dp, None, None)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def layer(x, lp):
        h = _rms_norm(x, lp["norm1"], cfg.norm_eps)
        q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
        k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        q = cst(q, dp, None, TP, None)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)
        attn = attention(q, k, v, positions, positions,
                         window=cfg.sliding_window,
                         q_block=cfg.q_block, k_block=cfg.k_block)
        attn = cst(attn, dp, None, TP)
        # (§Perf iteration 3 tried an optimization_barrier here to keep the
        # TP all-reduce in bf16 — refuted: the f32 ARs come from XLA's
        # AllReducePromotion pass, not operand dtype; see EXPERIMENTS.md.)
        x = x + attn @ lp["wo"]
        h2 = _rms_norm(x, lp["norm2"], cfg.norm_eps)
        ffn = (_moe_ffn(h2, lp, cfg, mesh) if cfg.moe
               else _dense_ffn(h2, lp, cfg, cst, dp))
        x = x + ffn
        x = cst(x, dp, None, None)
        return x, None

    body = layer
    if cfg.remat:
        body = jax.checkpoint(
            layer,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"],
                        unroll=cfg.n_layers if cfg.unroll_layers else 1)
    x = _rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(cfg.dtype)
    return cst(logits.astype(jnp.float32), dp, None, TP)


def loss_fn(params, batch, cfg: LMConfig, mesh):
    logits = forward(params, batch["tokens"], cfg, mesh)
    labels = batch["labels"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def make_train_step(cfg: LMConfig, mesh, optimizer):
    """train_step(params, opt_state, batch) → (params, opt_state, loss)."""

    def train_step(params, opt_state, batch):
        def lf(p, mb):
            return loss_fn(p, mb, cfg, mesh)

        if cfg.accum_steps > 1:
            a = cfg.accum_steps

            def split(x):
                return x.reshape(a, x.shape[0] // a, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(lf)(params, mb)
                grad_acc = jax.tree.map(
                    lambda ga, g: ga + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / a
            grads = jax.tree.map(lambda g: g / a, grads)
        else:
            loss, grads = jax.value_and_grad(lf)(params, batch)
        params, opt_state = optimizer.apply(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


# --------------------------------------------------------------------- #
# Serving: prefill + decode with (rolling) KV cache
# --------------------------------------------------------------------- #
def init_cache(cfg: LMConfig, batch: int, max_len: int):
    """Cache length = sliding window when set (rolling buffer), else max_len."""
    c = min(max_len, cfg.sliding_window or max_len)
    zeros = jnp.zeros((cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.head_dim),
                      cfg.dtype)
    return dict(k=zeros, v=zeros,
                pos=jnp.zeros((batch, c), jnp.int32) - 1,
                t=jnp.zeros((), jnp.int32))


def cache_specs(cfg: LMConfig, mesh):
    dp = dp_axes(mesh)
    kv = P(None, dp, None, None, None)
    return dict(k=kv, v=kv, pos=P(dp, None), t=P())


def make_prefill(cfg: LMConfig, mesh, *, max_len: int | None = None):
    """prefill(params, tokens[B, S]) → (cache, logits[B, V] of last token).

    Fills the KV cache for subsequent decoding. Only the last position's
    logits are computed (never the [B, S, V] tensor — with a 256k vocab that
    would be petabytes at the 32k-prefill shape). Sliding-window configs
    keep the last W positions (rolling buffer layout, slot = pos mod W).
    ``max_len`` sizes the cache for subsequent decoding (defaults to the
    prompt length — the pure-prefill benchmark shape).
    """
    cst = _make_cst(mesh)
    dp = dp_axes(mesh)

    def prefill(params, tokens):
        b, s = tokens.shape
        c = min(max_len or s, cfg.sliding_window or max_len or s)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = params["embed"].astype(cfg.dtype)[tokens]
        x = cst(x, dp, None, None)

        def layer(x, lp):
            h = _rms_norm(x, lp["norm1"], cfg.norm_eps)
            q = (h @ lp["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
            k = (h @ lp["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
            v = (h @ lp["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
            q = cst(q, dp, None, TP, None)
            q = _rope(q, positions, cfg.rope_theta)
            k = _rope(k, positions, cfg.rope_theta)
            attn = attention(q, k, v, positions, positions,
                             window=cfg.sliding_window,
                             q_block=cfg.q_block, k_block=cfg.k_block)
            attn = cst(attn, dp, None, TP)
            x = x + attn @ lp["wo"]
            h2 = _rms_norm(x, lp["norm2"], cfg.norm_eps)
            ffn = (_moe_ffn(h2, lp, cfg, mesh) if cfg.moe
                   else _dense_ffn(h2, lp, cfg, cst, dp))
            x = x + ffn
            x = cst(x, dp, None, None)
            # rolling cache: last min(s, c) positions at slot = pos mod c
            if c <= s:
                shift = s % c
                kc = jnp.roll(k[:, -c:], shift, axis=1)
                vc = jnp.roll(v[:, -c:], shift, axis=1)
            else:                      # headroom for subsequent decode
                pad = ((0, 0), (0, c - s), (0, 0), (0, 0))
                kc = jnp.pad(k, pad)
                vc = jnp.pad(v, pad)
            return x, (kc, vc)

        body = layer
        if cfg.remat:
            body = jax.checkpoint(
                layer, policy=jax.checkpoint_policies
                .dots_with_no_batch_dims_saveable)
        x, (ks, vs) = jax.lax.scan(
            body, x, params["layers"],
            unroll=cfg.n_layers if cfg.unroll_layers else 1)
        x = _rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = (x @ params["lm_head"].astype(cfg.dtype))[:, 0]
        if c <= s:
            pos_cache = jnp.roll(jnp.arange(s - c, s, dtype=jnp.int32),
                                 s % c)
        else:
            pos_cache = jnp.concatenate(
                [jnp.arange(s, dtype=jnp.int32),
                 jnp.full((c - s,), -1, jnp.int32)])
        cache = dict(k=ks, v=vs,
                     pos=jnp.broadcast_to(pos_cache, (b, c)),
                     t=jnp.asarray(s, jnp.int32))
        return cache, logits.astype(jnp.float32)

    return prefill


def make_decode_step(cfg: LMConfig, mesh):
    """decode(params, cache, token[B]) → (cache, logits[B, V]).

    One new token against a cache of ``c`` slots; sliding-window configs use
    a rolling buffer (slot = t mod W): cost O(W) regardless of absolute
    position — the sub-quadratic long_500k path.
    """
    cst = _make_cst(mesh)
    dp = dp_axes(mesh)

    def decode(params, cache, token):
        b = token.shape[0]
        t = cache["t"]
        pos = jnp.full((b, 1), t, jnp.int32)
        x = params["embed"].astype(cfg.dtype)[token][:, None]
        x = cst(x, dp, None, None)
        c = cache["k"].shape[2]
        slot = t % c
        pos_cache = jax.lax.dynamic_update_slice(cache["pos"], pos, (0, slot))

        def layer(x, packed):
            lp, kc, vc = packed
            h = _rms_norm(x, lp["norm1"], cfg.norm_eps)
            q = (h @ lp["wq"]).reshape(b, 1, cfg.n_heads, cfg.head_dim)
            k = (h @ lp["wk"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
            v = (h @ lp["wv"]).reshape(b, 1, cfg.n_kv_heads, cfg.head_dim)
            q = cst(q, dp, None, TP, None)
            q = _rope(q, pos, cfg.rope_theta)
            k = _rope(k, pos, cfg.rope_theta)
            kc = jax.lax.dynamic_update_slice(kc, k, (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, slot, 0, 0))
            attn = attention(q, kc, vc, pos, pos_cache,
                             window=cfg.sliding_window,
                             k_valid=pos_cache >= 0)
            attn = cst(attn, dp, None, TP)
            x = x + attn @ lp["wo"]
            h2 = _rms_norm(x, lp["norm2"], cfg.norm_eps)
            ffn = (_moe_ffn(h2, lp, cfg, mesh) if cfg.moe
                   else _dense_ffn(h2, lp, cfg, cst, dp))
            return x + ffn, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(
            layer, x, (params["layers"], cache["k"], cache["v"]),
            unroll=cfg.n_layers if cfg.unroll_layers else 1)
        x = _rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = (x @ params["lm_head"].astype(cfg.dtype))[:, 0]
        new_cache = dict(k=k_new, v=v_new, pos=pos_cache, t=t + 1)
        return new_cache, logits.astype(jnp.float32)

    return decode
