from .model import (MoECfg, LMConfig, init_params, param_specs, forward,
                    loss_fn, make_train_step, make_prefill, make_decode_step,
                    init_cache, cache_specs, count_params, active_params)
from .attention import attention
