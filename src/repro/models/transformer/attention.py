"""Attention paths: dense GQA, blocked (flash-style) causal, banded SWA.

Pure-JAX online-softmax attention (lax.scan over KV blocks) — the memory-
feasible path for 4k–32k sequences; lowers on every backend, which the
512-device dry-run requires (Mosaic kernels cannot compile for the CPU
stand-in devices). Three schedules:

  * ``dense``     — small Sq·Sk and decode (one query against a cache).
  * ``blocked``   — causal full attention: outer scan over q blocks, inner
                    scan over all k blocks with masking. Baseline wastes ~2×
                    FLOPs on fully-masked blocks (recorded as a §Perf
                    hillclimb target).
  * ``banded``    — sliding-window: each q block attends a static-size
                    ``window + q_block`` slice via dynamic_slice — O(S·W)
                    instead of O(S²); this is what makes the Mixtral
                    ``long_500k`` cells sub-quadratic.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["attention"]

_NEG = -1e30


def _mask(q_pos, k_pos, window, k_valid):
    m = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        m &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    if k_valid is not None:
        m &= k_valid[:, None, :]
    return m  # [B, Sq, Sk]


def _dense(q, k, v, q_pos, k_pos, window, k_valid):
    b, sq, hkv, g, dh = q.shape
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) / math.sqrt(dh)
    m = _mask(q_pos, k_pos, window, k_valid)
    scores = jnp.where(m[:, None, None], scores, _NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def _online_block(carry, kblk, vblk, qblk, qp, kp, window, scale):
    """One online-softmax step. carry = (m, l, acc) for the q block."""
    m_prev, l_prev, acc = carry
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                   preferred_element_type=jnp.float32) * scale
    msk = _mask(qp, kp, window, None)
    s = jnp.where(msk[:, None, None], s, _NEG)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[..., None])
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc = acc * alpha[..., None] + jnp.einsum(
        "bhgqk,bkhd->bhgqd", p.astype(qblk.dtype), vblk)
    return m_cur, l_new, acc


def _blocked(q, k, v, q_pos, k_pos, window, q_block, k_block):
    b, sq, hkv, g, dh = q.shape
    sk = k.shape[1]
    nq = sq // q_block
    nk = sk // k_block
    scale = 1.0 / math.sqrt(dh)

    qb = q.reshape(b, nq, q_block, hkv, g, dh).transpose(1, 0, 2, 3, 4, 5)
    qpb = q_pos.reshape(b, nq, q_block).transpose(1, 0, 2)
    kb = k.reshape(b, nk, k_block, hkv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, k_block, hkv, dh).transpose(1, 0, 2, 3, 4)
    kpb = k_pos.reshape(b, nk, k_block).transpose(1, 0, 2)

    def per_q(_, qpack):
        qblk, qp = qpack

        def inner(carry, kpack):
            kblk, vblk, kp = kpack
            return _online_block(carry, kblk, vblk, qblk, qp, kp, window,
                                 scale), None

        init = (jnp.full((b, hkv, g, q_block), _NEG, jnp.float32),
                jnp.zeros((b, hkv, g, q_block), jnp.float32),
                jnp.zeros((b, hkv, g, q_block, dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(inner, init, (kb, vb, kpb))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return None, out.transpose(0, 3, 1, 2, 4)          # [B,qb,hkv,g,dh]

    _, outs = jax.lax.scan(per_q, None, (qb, qpb))          # [nq,B,qb,...]
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, dh)


def _banded(q, k, v, q_pos, k_pos, window, q_block):
    """SWA: q block at offset o attends k slice [o + qb − span, o + qb)."""
    b, sq, hkv, g, dh = q.shape
    sk = k.shape[1]
    nq = sq // q_block
    span = min(sk, window + q_block)
    scale = 1.0 / math.sqrt(dh)
    # pad left so every slice is in range
    pad = span
    kp_full = jnp.pad(k_pos, ((0, 0), (pad, 0)), constant_values=-10 ** 9)
    k_full = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    v_full = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

    def per_q(_, i):
        start = i * q_block                                 # traced
        qblk = jax.lax.dynamic_slice_in_dim(q, start, q_block, 1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, start, q_block, 1)
        ks = jax.lax.dynamic_slice_in_dim(k_full, start + q_block, span, 1)
        vs = jax.lax.dynamic_slice_in_dim(v_full, start + q_block, span, 1)
        kp = jax.lax.dynamic_slice_in_dim(kp_full, start + q_block, span, 1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, ks,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(qp, kp, window, None)
        s = jnp.where(msk[:, None, None], s, _NEG)
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vs)
        return None, out

    _, outs = jax.lax.scan(per_q, None, jnp.arange(nq))
    return outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, dh)


def attention(q, k, v, q_pos, k_pos, *, window: int | None,
              k_valid=None, q_block: int = 512, k_block: int = 1024,
              dense_threshold: int = 2048):
    """GQA attention dispatcher.

    q: [B, Sq, Hq, Dh]; k/v: [B, Sk, Hkv, Dh]. Returns [B, Sq, Hq·Dh].
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    sk = k.shape[1]
    g = hq // hkv
    q5 = q.reshape(b, sq, hkv, g, dh)

    if sq <= 1 or sq * sk <= dense_threshold ** 2 or k_valid is not None:
        out = _dense(q5, k, v, q_pos, k_pos, window, k_valid)
    elif window is not None and sk > 2 * (window + q_block):
        qb = min(q_block, sq)
        out = _banded(q5, k, v, q_pos, k_pos, window, qb)
    else:
        qb = min(q_block, sq)
        kbl = min(k_block, sk)
        qb = math.gcd(qb, sq)
        kbl = math.gcd(kbl, sk)
        out = _blocked(q5, k, v, q_pos, k_pos, window, qb, kbl)
    return out.reshape(b, sq, hq * dh)
