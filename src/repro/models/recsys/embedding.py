"""Embedding substrate for the recsys stack.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — per the
assignment, both are built here as part of the system:

  * ``embedding_bag`` — ragged multi-hot bags via ``jnp.take`` +
    ``jax.ops.segment_sum`` (sum/mean), sentinel-padded.
  * ``sharded_lookup`` — row-sharded tables (P("model", None)) with a
    mask-and-psum lookup inside shard_map: each TP shard gathers the ids it
    owns locally and a single psum reassembles the embedding — the lookup
    (the recsys hot path) never materializes the full table anywhere.
    Gradients flow through as local scatter-adds (autodiff of the gather),
    so optimizer state stays row-sharded too.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...compat import shard_map
from jax.sharding import PartitionSpec as P

__all__ = ["embedding_bag", "sharded_lookup"]


def embedding_bag(table: jax.Array, ids: jax.Array, bag_ids: jax.Array,
                  n_bags: int, *, mode: str = "mean") -> jax.Array:
    """EmbeddingBag: ids i32[n_idx] (sentinel = vocab → zero row),
    bag_ids i32[n_idx] sorted. → f[n_bags, d]."""
    v, d = table.shape
    tbl = jnp.concatenate([table, jnp.zeros((1, d), table.dtype)], 0)
    vals = jnp.take(tbl, jnp.minimum(ids, v), axis=0)
    valid = (ids < v).astype(table.dtype)
    vals = vals * valid[:, None]
    out = jax.ops.segment_sum(vals, bag_ids, num_segments=n_bags,
                              indices_are_sorted=True)
    if mode == "mean":
        cnt = jax.ops.segment_sum(valid, bag_ids, num_segments=n_bags,
                                  indices_are_sorted=True)
        out = out / jnp.maximum(cnt[:, None], 1.0)
    return out


def sharded_lookup(table: jax.Array, ids: jax.Array, mesh,
                   *, batch_axes: tuple[str, ...] = ()) -> jax.Array:
    """Row-sharded embedding lookup: table P("model", None), ids replicated
    or sharded over ``batch_axes``. Returns embeddings sharded like ids."""
    if "model" not in mesh.axis_names:
        return jnp.take(table, ids, axis=0)
    tp = mesh.shape["model"]
    v, d = table.shape
    rows = v // tp

    def local(tbl, ids_loc):
        r = jax.lax.axis_index("model")
        lo = r * rows
        rel = ids_loc - lo
        ok = (rel >= 0) & (rel < rows)
        emb = jnp.take(tbl, jnp.clip(rel, 0, rows - 1), axis=0)
        emb = emb * ok[..., None].astype(emb.dtype)
        return jax.lax.psum(emb, "model")

    ba = tuple(a for a in batch_axes if a in mesh.axis_names)
    id_spec = P(ba, *([None] * (ids.ndim - 1))) if ba else P(
        *([None] * ids.ndim))
    out_spec = P(ba, *([None] * ids.ndim)) if ba else P(
        *([None] * (ids.ndim + 1)))
    return shard_map(
        local, mesh=mesh,
        in_specs=(P("model", None), id_spec),
        out_specs=out_spec)(table, ids)
