"""RecSys family: MIND multi-interest retrieval + embedding substrate."""
from .embedding import embedding_bag, sharded_lookup
from .mind import (MINDConfig, init_params, param_specs, user_interests,
                   train_loss, retrieval_scores)
