"""MIND — Multi-Interest Network with Dynamic routing [arXiv:1904.08030].

User behaviour sequence → item embeddings → **B2I dynamic capsule routing**
(n_interests=4 capsules, 3 routing iterations, squash nonlinearity) →
label-aware attention (train) / max-dot retrieval (serve).

Shapes (assignment): train_batch 65 536 (sampled-softmax training),
serve_p99 512 / serve_bulk 262 144 (interest extraction), retrieval_cand
1 user × 10⁶ candidates (single batched matmul, never a loop).

The item table (4M × 64 here) is row-sharded over "model" via
``embedding.sharded_lookup``; user profile tags go through the ragged
``embedding_bag``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .embedding import embedding_bag, sharded_lookup

__all__ = ["MINDConfig", "init_params", "param_specs", "user_interests",
           "train_loss", "retrieval_scores"]


@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    n_items: int = 4_194_304
    n_profile: int = 131_072
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    hist_len: int = 50
    profile_tags: int = 8          # avg multi-hot tags per user
    n_neg: int = 1024              # sampled-softmax negatives
    pow_p: float = 2.0             # label-aware attention sharpness
    dtype: object = jnp.float32


def init_params(cfg: MINDConfig, key: jax.Array) -> dict:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    d = cfg.embed_dim
    return dict(
        item_emb=jax.random.normal(k1, (cfg.n_items, d), cfg.dtype) * 0.02,
        profile_emb=jax.random.normal(k2, (cfg.n_profile, d),
                                      cfg.dtype) * 0.02,
        bilinear=jax.random.normal(k3, (d, d), cfg.dtype) / np.sqrt(d),
        profile_proj=jax.random.normal(k4, (d, d), cfg.dtype) / np.sqrt(d),
        # fixed (non-trainable by convention) routing-logit init, as in the
        # paper's shared random init
        b_init=jax.random.normal(k5, (cfg.n_interests, cfg.hist_len),
                                 cfg.dtype),
    )


def param_specs(cfg: MINDConfig, mesh):
    from jax.sharding import PartitionSpec as P
    tp = "model" if "model" in mesh.axis_names else None
    return dict(item_emb=P(tp, None), profile_emb=P(tp, None),
                bilinear=P(None, None), profile_proj=P(None, None),
                b_init=P(None, None))


def _squash(z, axis=-1):
    n2 = jnp.sum(jnp.square(z), axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * z / jnp.sqrt(n2 + 1e-9)


def user_interests(params, hist_ids, hist_mask, profile_ids, profile_bags,
                   cfg: MINDConfig, mesh) -> jax.Array:
    """→ interest capsules f[B, K, d].

    hist_ids: i32[B, H]; hist_mask: bool[B, H];
    profile_ids: i32[B·tags] ragged multi-hot; profile_bags: i32[B·tags].
    """
    b = hist_ids.shape[0]
    d, K = cfg.embed_dim, cfg.n_interests
    e = sharded_lookup(params["item_emb"], hist_ids, mesh,
                       batch_axes=("pod", "data"))          # [B, H, d]
    e = e * hist_mask[..., None].astype(e.dtype)
    eh = jnp.einsum("bhd,de->bhe", e, params["bilinear"])    # ê_i

    prof = embedding_bag(params["profile_emb"], profile_ids, profile_bags,
                         b, mode="mean") @ params["profile_proj"]  # [B, d]

    logit_mask = jnp.where(hist_mask[:, None, :], 0.0, -1e30)

    def routing_iter(bk, _):
        w = jax.nn.softmax(bk + logit_mask, axis=1)          # over K
        z = jnp.einsum("bkh,bhe->bke", w, eh)
        u = _squash(z)
        bk = bk + jnp.einsum("bke,bhe->bkh", u, eh)
        return bk, u

    b0 = jnp.broadcast_to(params["b_init"][None], (b, K, cfg.hist_len))
    b0 = jax.lax.stop_gradient(b0)
    bk, us = jax.lax.scan(routing_iter, b0, None, length=cfg.capsule_iters)
    u = us[-1]                                               # [B, K, d]
    return u + prof[:, None, :]                              # profile fusion


def train_loss(params, batch, cfg: MINDConfig, mesh) -> jax.Array:
    """Sampled-softmax loss. batch: hist_ids, hist_mask, profile_ids,
    profile_bags, pos_ids i32[B], neg_ids i32[B, n_neg]."""
    u = user_interests(params, batch["hist_ids"], batch["hist_mask"],
                       batch["profile_ids"], batch["profile_bags"], cfg,
                       mesh)                                  # [B, K, d]
    e_pos = sharded_lookup(params["item_emb"], batch["pos_ids"], mesh,
                           batch_axes=("pod", "data"))        # [B, d]
    e_neg = sharded_lookup(params["item_emb"], batch["neg_ids"], mesh,
                           batch_axes=("pod", "data"))        # [B, n_neg, d]
    # label-aware attention: p_u = Σ_k softmax((u_k · e_pos)^p) u_k
    att = jnp.einsum("bkd,bd->bk", u, e_pos)
    att = jax.nn.softmax(jnp.power(jnp.abs(att), cfg.pow_p) *
                         jnp.sign(att), axis=-1)
    pu = jnp.einsum("bk,bkd->bd", att, u)
    lp = jnp.einsum("bd,bd->b", pu, e_pos)[:, None]           # [B, 1]
    ln = jnp.einsum("bd,bnd->bn", pu, e_neg)                  # [B, n_neg]
    logits = jnp.concatenate([lp, ln], axis=-1)
    return jnp.mean(jax.scipy.special.logsumexp(logits, -1) - logits[:, 0])


def retrieval_scores(params, interests, cand_ids, cfg: MINDConfig, mesh
                     ) -> jax.Array:
    """Score 10⁶ candidates against one user's interests: max over capsules.

    interests: f[K, d]; cand_ids: i32[n_cand] → f[n_cand].
    """
    e = sharded_lookup(params["item_emb"], cand_ids, mesh)    # [n_cand, d]
    scores = jnp.einsum("nd,kd->nk", e, interests)
    return jnp.max(scores, axis=-1)
