"""Multi-tenant serving scenario: one device, a fleet of platforms.

Several independent (graph, activity) tenants — different communities /
topics, different sizes and sparsity regimes — are admitted into one
``TenantFleet``: size-bucketed into padded batches and solved as vmapped
convergence-masked Power-ψ loops (docs/SERVING.md).  The demo shows the
three serving guarantees the fleet makes:

* per-tenant correctness: every tenant's top-k matches a dedicated solve;
* lane isolation: patching one tenant's activity mid-flight leaves every
  co-tenant's ψ **bit-identical** (their lanes are masked out);
* warm continuity: the patched tenant re-converges in a handful of
  iterations from its previous fixed point.

    PYTHONPATH=src python examples/influence_fleet.py [auto|dense|reference|pallas]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import numpy as np

from repro.graphs import clustered_blocks, powerlaw_configuration
from repro.core import heterogeneous, make_engine
from repro.serving import TenantFleet


def main():
    backend = sys.argv[1] if len(sys.argv) > 1 else "auto"
    quick = "--quick" in sys.argv

    # a fleet of communities: hyper-sparse social graphs and clustered
    # block communities, deliberately mixed sizes so several buckets form
    scale = 1 if quick else 4
    tenants = {}
    for k in range(6):
        if k % 2 == 0:
            g = powerlaw_configuration(500 * scale, 3_000 * scale,
                                       seed=40 + k,
                                       name=f"community{k}")
        else:
            g = clustered_blocks(256 * scale, 2_000 * scale, block=64,
                                 p_in=0.9, seed=40 + k)
        tenants[f"community{k}"] = (g, heterogeneous(g.n, seed=70 + k))

    fleet = TenantFleet(backend=backend, tol=1e-8)
    t0 = time.perf_counter()
    for tid, (g, act) in tenants.items():
        spec = fleet.admit(tid, g, act)
        print(f"admit {tid}: n={g.n:5d} m={g.m:6d} → {spec}")
    fleet.solve()
    print(f"\nfleet[{fleet.backend}] solved {len(fleet)} tenants in "
          f"{time.perf_counter() - t0:.2f}s; buckets:")
    for spec, acct in fleet.occupancy().items():
        print(f"  {spec}: {acct['tenants']} tenants regime={acct['regime']} "
              f"node_occ={acct['node_occupancy']:.2f} "
              f"edge_occ={acct['edge_occupancy']:.2f}")

    frontier = fleet.frontier
    print("\nper-tenant top-3 (vs dedicated reference solve):")
    for tid, (g, act) in tenants.items():
        top, vals = frontier.top_k(tid, 3)
        solo = make_engine("reference", graph=g, activity=act).run(tol=1e-8)
        err = np.abs(fleet.psi(tid) - np.asarray(solo.psi)).max()
        print(f"  {tid}: top-3={top.tolist()} "
              f"psi={np.round(vals, 6).tolist()} (L∞ vs solo {err:.1e})")

    # one tenant's leader goes viral mid-flight — co-tenants must not move
    victim = "community1"
    others = {t: fleet.psi(t).copy() for t in tenants if t != victim}
    star = int(frontier.top_k(victim, 1)[0][0])
    t0 = time.perf_counter()
    fleet.patch_activity(victim, np.asarray([star]),
                         lam=np.asarray([tenants[victim][1].lam[star] * 40]))
    fleet.solve()
    print(f"\npatched {victim} user {star} (λ ×40): re-converged in "
          f"{fleet.stats(victim)['iterations']} warm iterations "
          f"({(time.perf_counter() - t0) * 1e3:.1f} ms)")
    frozen = all(np.array_equal(prev, fleet.psi(t))
                 for t, prev in others.items())
    print(f"lane isolation: {len(others)} co-tenant ψ vectors bit-identical "
          f"across the re-solve → {frozen}")
    assert frozen, "a masked lane moved — convergence masking is broken"

    top = frontier.global_top_k(5)
    print("\nfleet-wide top-5 influencers:")
    for t, u, s in top:
        print(f"  {t} user {u}: ψ = {s:.3e}")


if __name__ == "__main__":
    main()
