"""Streaming scenario: a flash crowd, watched live through fresh ψ.

The platform starts *cold* — nobody's posting rates are known (everyone at
the RATE_FLOOR clamp) — and a live event log plays: stationary background
posts/reposts teach the online estimator every user's λ/μ, then a flash
crowd forms around one celebrity (new followers + a repost storm), and a
fraction of the crowd churns away afterwards (unfollow tombstones). The
``StreamIngestor`` coalesces all of it into batched O(Δ) patches and
re-resolves ψ on the freshness-policy cadence, so we can watch the
celebrity's influence rank climb *while the stream is still running* —
and certify exactly how stale every answer was (docs/STREAMING.md).

    PYTHONPATH=src python examples/influence_stream.py [backend] [--quick]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_ENABLE_X64", "1")

import time

import numpy as np


def main():
    backend = next((a for a in sys.argv[1:] if not a.startswith("-")),
                   "reference")
    quick = "--quick" in sys.argv

    import jax.numpy as jnp

    from repro.core import Activity, PsiService, RATE_FLOOR, \
        heterogeneous, make_engine
    from repro.graphs import powerlaw_configuration
    from repro.stream import (FreshnessPolicy, StreamIngestor,
                              flash_crowd_stream)

    n, m, events = (400, 2_400, 1_500) if quick else (2_000, 12_000, 6_000)
    g = powerlaw_configuration(n, m, seed=11)
    truth = heterogeneous(n, seed=12)
    horizon = events / float(truth.total.sum())
    celebrity = int(np.argsort(-g.in_degree)[8])   # mid-pack: room to climb
    log = flash_crowd_stream(g, truth, horizon, celebrity=celebrity,
                             new_followers=max(24, n // 16), storm_mu=6.0,
                             churn=0.4, seed=13)
    print(f"flash crowd around user {celebrity}: {len(log)} events "
          f"({log.counts()}) over {horizon:.1f}s event-time")

    cold = Activity(np.full(n, RATE_FLOOR), np.full(n, RATE_FLOOR))
    svc = PsiService(g, cold, tol=1e-9, backend=backend, dtype=jnp.float64)
    ing = StreamIngestor(
        svc, half_life=horizon / 2, topk=10,
        policy=FreshnessPolicy(coalesce=64, resolve_every=None))

    # drive the stream manually so we can snapshot the celebrity's rank at
    # every resolve (a fixed event cadence, like the serving launcher's)
    resolve_every = max(200, len(log) // 8)
    t0 = time.perf_counter()
    trajectory = []
    for i, ev in enumerate(log):
        ing.submit(ev)
        if (i + 1) % resolve_every == 0:
            ing.resolve()
            rank = int(svc.rank_of(np.asarray([celebrity]))[0])
            rep = ing.freshness()
            trajectory.append((i + 1, rank))
            print(f"  event {i + 1:5d} (t={rep.event_time:6.1f}s): "
                  f"celebrity rank {rank:4d}, "
                  f"churn={rep.topk_churn if rep.topk_churn is None else round(rep.topk_churn, 2)}")
    ing.resolve()
    wall = time.perf_counter() - t0
    final_rank = int(svc.rank_of(np.asarray([celebrity]))[0])
    print(f"\ningested {len(log)} events in {wall:.2f}s "
          f"({len(log) / wall:.0f} ev/s) over {ing.resolves} resolves; "
          f"celebrity rank {trajectory[0][1]} → {final_rank}")
    assert final_rank < trajectory[0][1], \
        "the flash crowd should lift the celebrity's rank"

    # freshness certification: a stale read vs a certified-fresh read
    tail = ing.freshness()
    print(f"freshness at end: staleness={tail.staleness_events} events, "
          f"dirty_mass={tail.dirty_mass:.2e}, "
          f"certified fresh={tail.certify(max_events=0)}")

    # the acceptance invariant: replay + O(Δ) patches == batch recompute
    batch = make_engine("reference", graph=svc.graph,
                        activity=svc.engine.activity,
                        dtype=jnp.float64).run(tol=1e-9)
    err = float(np.abs(svc.scores() - np.asarray(batch.psi)).max())
    print(f"psi parity vs from-scratch batch solve: {err:.2e}")
    assert err <= 1e-8, f"streamed psi diverged from batch: {err}"


if __name__ == "__main__":
    main()
