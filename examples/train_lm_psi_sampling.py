"""End-to-end driver: train a small LM with ψ-weighted data curation.

The paper's technique as a first-class data-layer feature (DESIGN.md §5):
documents belong to synthetic users of a social graph; training batches
sample authors ∝ ψ-score, i.e. influence-curated mixing. Trains a reduced
TinyLlama-family model with the full production substrate — sharded step,
checkpointing, resume.

    PYTHONPATH=src python examples/train_lm_psi_sampling.py \
        --steps 60 --d-model 128 --layers 4
(defaults are CPU-sized; scale flags up on real hardware)
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import heterogeneous, build_operators, power_psi
from repro.graphs import powerlaw_configuration
from repro.data import TokenPipeline, PsiWeightedSampler
from repro.models.transformer import LMConfig, init_params, make_train_step
from repro.train import adamw, cosine_schedule
from repro.ckpt import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--ckpt-dir", default="/tmp/psi_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    # 1. ψ-scores over the author graph → sampling weights
    g = powerlaw_configuration(5000, 40_000, seed=11, name="authors")
    ops = build_operators(g, heterogeneous(g.n, seed=12))
    psi = np.asarray(power_psi(ops, tol=1e-8).psi)
    sampler = PsiWeightedSampler(psi, temperature=1.0, seed=13)
    print("ψ-curation:", sampler.mixture_stats())

    # 2. model + substrate
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = LMConfig(name="psi-lm", n_layers=args.layers,
                   d_model=args.d_model, n_heads=max(2, args.d_model // 32),
                   n_kv_heads=max(1, args.d_model // 64), vocab=args.vocab,
                   d_ff=args.d_model * 3, dtype=jnp.float32,
                   param_dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(cosine_schedule(3e-3, args.steps, max(1, args.steps // 10)))
    state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, mesh, opt))
    pipe = TokenPipeline(vocab=args.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=5)

    start = 0
    if args.resume and checkpoint.latest_step(args.ckpt_dir) is not None:
        start = checkpoint.latest_step(args.ckpt_dir)
        data = checkpoint.restore(args.ckpt_dir, start,
                                  dict(params=params, opt=state))
        params, state = data["params"], data["opt"]
        print(f"resumed from step {start}")

    # 3. train loop: author ids drawn ∝ ψ seed the per-step data stream
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        authors = sampler.sample_users(args.batch)
        raw = pipe.batch(step)
        # author id modulates the stream (stand-in for per-author corpora)
        tok = (raw["tokens"] + authors[:, None]) % args.vocab
        lab = (raw["labels"] + authors[:, None]) % args.vocab
        batch = dict(tokens=jnp.asarray(tok), labels=jnp.asarray(lab))
        params, state, loss = step_fn(params, state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"({(time.perf_counter() - t0):.1f}s)")
        if (step + 1) % 20 == 0:
            checkpoint.save(args.ckpt_dir, step + 1,
                            dict(params=params, opt=state))
    print("done; final checkpoint:",
          checkpoint.save(args.ckpt_dir, args.steps,
                          dict(params=params, opt=state)))


if __name__ == "__main__":
    main()
