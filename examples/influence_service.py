"""Serving scenario: an influence-ranking service with live updates.

Batched queries against a warm ψ-score state; activity/graph updates go
through the engine's O(Δ) delta-rebuild hooks and re-converge from the
previous fixed point in a handful of iterations (contraction warm-start —
the serving story of DESIGN.md §4). Any registered engine backend serves:

    PYTHONPATH=src python examples/influence_service.py [reference|pallas|distributed]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import numpy as np

from repro.graphs import powerlaw_configuration
from repro.core import heterogeneous, PsiService


def main():
    backend = sys.argv[1] if len(sys.argv) > 1 else "reference"
    g = powerlaw_configuration(30_000, 200_000, seed=1, name="platform")
    act = heterogeneous(g.n, seed=2)
    t0 = time.perf_counter()
    svc = PsiService(g, act, tol=1e-8, backend=backend)
    scores = svc.scores()
    print(f"cold start [{svc.backend}]: {time.perf_counter() - t0:.2f}s "
          f"for n={g.n}, m={g.m} ({svc.last_iterations()} iterations)")

    # batched ranking queries — first pays the sort, repeats hit the cache
    users = np.random.default_rng(0).integers(0, g.n, 512)
    t0 = time.perf_counter()
    ranks = svc.rank_of(users)
    print(f"batched rank query (512 users): "
          f"{(time.perf_counter() - t0) * 1e3:.1f} ms")
    t0 = time.perf_counter()
    svc.rank_of(users)
    print(f"  …repeated (cached ranking): "
          f"{(time.perf_counter() - t0) * 1e3:.2f} ms")
    t0 = time.perf_counter()
    svc.scores_batch(users)
    print(f"  …scores_batch (no sort): "
          f"{(time.perf_counter() - t0) * 1e3:.2f} ms")

    top, vals = svc.top_k(3)
    print("top-3:", top.tolist(), np.round(vals, 6).tolist())

    # a user goes viral: posting rate ×50 → warm re-converge
    u = int(users[0])
    before = svc.rank_of(np.asarray([u]))[0]
    t0 = time.perf_counter()
    svc.update_activity(np.asarray([u]),
                        lam=np.asarray([act.lam[u] * 50]))
    dt = time.perf_counter() - t0
    after = svc.rank_of(np.asarray([u]))[0]
    print(f"activity update: rank {before} → {after} in {dt:.2f}s "
          f"({svc.last_iterations()} warm iterations)")

    # new follow edges arrive
    rng = np.random.default_rng(3)
    t0 = time.perf_counter()
    svc.add_edges(rng.integers(0, g.n, 100), np.full(100, u))
    dt = time.perf_counter() - t0
    print(f"+100 followers of user {u}: rank → "
          f"{svc.rank_of(np.asarray([u]))[0]} in {dt:.2f}s "
          f"({svc.last_iterations()} warm iterations)")


if __name__ == "__main__":
    main()
