"""Distributed ψ on a simulated 8-device mesh: exactness, restart, remesh.

    PYTHONPATH=src python examples/distributed_dryrun_demo.py
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import numpy as np
import jax

from repro.graphs import powerlaw_configuration
from repro.core import heterogeneous, build_operators, power_psi
from repro.core.distributed import DistributedPsi
from repro.runtime import PsiDriver


def main():
    g = powerlaw_configuration(20_000, 140_000, seed=3, name="demo")
    act = heterogeneous(g.n, seed=4)
    ref = power_psi(build_operators(g, act), tol=1e-9)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    dist = DistributedPsi.from_graph(g, act, mesh)
    print(f"partition imbalance (straggler indicator): "
          f"{dist.part.imbalance:.3f}")

    with tempfile.TemporaryDirectory() as d:
        drv = PsiDriver(dist, ckpt_dir=d, chunk_iters=16)
        rep = drv.run(tol=1e-7, fail_hook=lambda c: c == 2)
        err = np.abs(rep.psi - np.asarray(ref.psi)).max()
        print(f"2×4 mesh: {rep.iterations} iters, {rep.restarts} restart(s) "
              f"injected+recovered, err vs serial {err:.2e}")

    # elastic: continue the same job on a 4×2 mesh
    run = dist.make_run(chunk_iters=16)
    s_mid, _ = run(dist.arrays.c_src, dist.arrays)
    drv2 = PsiDriver(dist, chunk_iters=16).remesh(
        jax.make_mesh((4, 2), ("data", "model")), g, act, s_mid)
    d2 = drv2.dist
    run2 = d2.make_run(chunk_iters=16)
    s, gap, it = drv2._warm_s, np.inf, 16
    while gap > 1e-7 and it < 400:
        s, gd = run2(s, d2.arrays)
        gap = float(gd)
        it += 16
    epi = jax.jit(d2.make_epilogue())
    psi = d2.part.from_src_layout(
        np.asarray(epi(s, d2.arrays)).reshape(d2.part.d, -1))
    print(f"elastic 2×4→4×2 re-mesh: resumed warm, err "
          f"{np.abs(psi - np.asarray(ref.psi)).max():.2e}")


if __name__ == "__main__":
    main()
