"""Quickstart: compute ψ-scores three ways and compare (60 seconds, CPU).

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.graphs import powerlaw_configuration
from repro.core import (heterogeneous, homogeneous, build_operators,
                        power_psi, power_nf, exact_psi, pagerank,
                        build_pagerank_ops)


def main():
    # a small social platform: 2 000 users, heavy-tailed follows
    g = powerlaw_configuration(2000, 14000, seed=42, name="demo")
    act = heterogeneous(g.n, seed=7)     # per-user posting/re-posting rates
    ops = build_operators(g, act)

    # 1. the paper's Power-ψ (Alg. 2): one linear system, power iteration
    res = power_psi(ops, tol=1e-9)
    print(f"Power-ψ:   {int(res.iterations)} iterations, "
          f"{int(res.matvecs)} mat-vecs")

    # 2. exact solve (the oracle)
    psi_true, _ = exact_psi(g, act)
    err = np.linalg.norm(res.psi - psi_true) / np.linalg.norm(psi_true)
    print(f"            rel. error vs exact: {err:.2e}")

    # 3. the pre-paper baseline (Alg. 1: N systems) on a few origins
    nf = power_nf(ops, tol=1e-9, origins=np.arange(64))
    print(f"Power-NF:  {nf.matvecs} mat-vecs for just 64 of {g.n} users "
          f"(×{g.n // 64} more to finish) — the problem the paper fixes")

    # 4. homogeneous activity ⇒ ψ == PageRank (Thm 5 of [10])
    ops_h = build_operators(g, homogeneous(g.n))
    psi_h = power_psi(ops_h, tol=1e-12).psi
    pr = pagerank(build_pagerank_ops(g), alpha=0.85, tol=1e-12).pi
    print(f"ψ(homog) vs PageRank max diff: "
          f"{float(abs(np.asarray(psi_h) - np.asarray(pr)).max()):.2e}")

    top = np.argsort(-np.asarray(res.psi))[:5]
    print("top-5 influencers:", top.tolist())
    print("  ψ:", np.round(np.asarray(res.psi)[top], 6).tolist())
    print("  in-degree:", g.in_degree[top].tolist(),
          " (rank ≠ pure popularity — activity matters)")


if __name__ == "__main__":
    main()
