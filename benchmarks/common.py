"""Shared benchmark plumbing. One module per paper table/figure."""
from __future__ import annotations

import time

import numpy as np

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time in µs."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def header() -> None:
    print("name,us_per_call,derived")
