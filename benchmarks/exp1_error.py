"""Experiment 1 (Fig. 2 & 3): approximation error vs target tolerance.

For equal x-tolerance, Power-ψ's relative error against the exact ψ must be
≤ the errors of Power-NF and PageRank's power method. Heterogeneous (i) and
homogeneous (ii) activity, DBLP-scale stand-in, float64 (the paper sweeps ε
down to 1e-9, below fp32 resolution).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graphs import load_dataset
from repro.core import (heterogeneous, homogeneous, build_operators,
                        power_psi, power_nf, exact_psi, build_pagerank_ops,
                        pagerank)
from .common import emit, timeit

TOLS = [10.0 ** -k for k in range(1, 10)]
NF_ORIGINS = 512        # Power-NF error measured on an origin subsample


def _rel_err(approx, true):
    return float(np.linalg.norm(approx - true) / np.linalg.norm(true))


def run(quick: bool = False) -> None:
    g = load_dataset("dblp")
    tols = TOLS[:5] if quick else TOLS
    rng = np.random.default_rng(0)
    origins = np.sort(rng.choice(g.n, NF_ORIGINS, replace=False))

    for regime in ("heterogeneous", "homogeneous"):
        act = (heterogeneous(g.n, seed=7) if regime == "heterogeneous"
               else homogeneous(g.n))
        ops = build_operators(g, act, dtype=jnp.float64)
        psi_true, _ = exact_psi(g, act)
        for tol in tols:
            res = power_psi(ops, tol=tol)
            err = _rel_err(np.asarray(res.psi), psi_true)
            emit(f"exp1/{regime}/power_psi/tol={tol:.0e}",
                 float(res.iterations),
                 f"rel_err={err:.3e};matvecs={int(res.matvecs)}")
            nf = power_nf(ops, tol=tol, chunk=256, origins=origins)
            err_nf = _rel_err(nf.psi, psi_true[origins])
            emit(f"exp1/{regime}/power_nf/tol={tol:.0e}",
                 float(nf.max_iterations),
                 f"rel_err={err_nf:.3e};matvecs~={nf.matvecs * g.n // NF_ORIGINS}")
            if regime == "homogeneous":
                pr = pagerank(build_pagerank_ops(g, dtype=jnp.float64),
                              alpha=0.85, tol=tol)
                err_pr = _rel_err(np.asarray(pr.pi), psi_true)
                emit(f"exp1/homogeneous/pagerank/tol={tol:.0e}",
                     float(pr.iterations), f"rel_err={err_pr:.3e}")
        # headline check (paper's claim): at equal tolerance Power-ψ ≤ others
        res9 = power_psi(ops, tol=tols[-1])
        nf9 = power_nf(ops, tol=tols[-1], chunk=256, origins=origins)
        ok = _rel_err(np.asarray(res9.psi), psi_true) <= \
            _rel_err(nf9.psi, psi_true[origins]) * 1.5 + 1e-12
        emit(f"exp1/{regime}/claim_psi_error_leq_nf", 0.0, f"holds={ok}")
