"""Experiment 3 (Tables III & IV): wall-clock scaling across datasets.

Power-ψ and PageRank run to ε=1e-9 on every dataset stand-in; Power-NF is
measured on an origin subsample and extrapolated ×(N/subsample) — running
the true Power-NF on Twitter takes hours (the paper reports 17 411 s),
which is precisely the problem the paper solves.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import load_dataset
from repro.core import (heterogeneous, homogeneous, build_operators,
                        power_psi, power_nf, build_pagerank_ops, pagerank)
from .common import emit, timeit

DATASETS = ["dblp", "hepph", "facebook", "twitter"]
NF_ORIGINS = 64
TOL = 1e-9


def run(quick: bool = False) -> None:
    datasets = DATASETS[:2] if quick else DATASETS
    for name in datasets:
        g = load_dataset(name)
        for regime in ("heterogeneous", "homogeneous"):
            act = (heterogeneous(g.n, seed=3) if regime == "heterogeneous"
                   else homogeneous(g.n))
            ops = build_operators(g, act, dtype=jnp.float64)

            us_psi = timeit(lambda: jax.block_until_ready(
                power_psi(ops, tol=TOL).psi), warmup=1, iters=3)
            emit(f"exp3/{regime}/{name}/power_psi", us_psi,
                 f"n={g.n};m={g.m}")

            origins = np.arange(NF_ORIGINS, dtype=np.int32)
            us_nf = timeit(lambda: power_nf(ops, tol=TOL, chunk=64,
                                            origins=origins),
                           warmup=1, iters=1)
            emit(f"exp3/{regime}/{name}/power_nf_extrap",
                 us_nf * g.n / NF_ORIGINS,
                 f"measured_{NF_ORIGINS}_origins={us_nf:.0f}us;"
                 f"speedup_vs_psi={us_nf * g.n / NF_ORIGINS / us_psi:.0f}x")

            if regime == "homogeneous":
                props = build_pagerank_ops(g, dtype=jnp.float64)
                us_pr = timeit(lambda: jax.block_until_ready(
                    pagerank(props, alpha=0.85, tol=TOL).pi),
                    warmup=1, iters=3)
                emit(f"exp3/homogeneous/{name}/pagerank", us_pr,
                     f"psi_over_pagerank={us_psi / us_pr:.2f}x")
