"""Engine parity benchmark: every registered backend on the same graph.

For each backend: cold solve wall-time, warm (s0 = s*) re-solve wall-time,
and L∞ disagreement of ψ against the ``reference`` backend — the serving
story in one table. Run via ``python -m benchmarks.run --only engines``.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit


def run(quick: bool = False) -> None:
    from repro.graphs import powerlaw_configuration
    from repro.core import heterogeneous, available_backends, make_engine

    n, m = (2_000, 14_000) if quick else (20_000, 140_000)
    g = powerlaw_configuration(n, m, seed=17)
    act = heterogeneous(g.n, seed=18)
    tol = 1e-8

    order = ["reference"] + [b for b in available_backends()
                             if b != "reference"]
    ref_psi = None
    for name in order:
        eng = make_engine(name, graph=g, activity=act)
        res = eng.run(tol=tol)          # compile + converge once
        psi = np.asarray(res.psi)
        if ref_psi is None:
            ref_psi = psi
        linf = np.abs(psi - ref_psi).max()
        cold = timeit(lambda: eng.run(tol=tol), warmup=0, iters=3)
        warm = timeit(lambda: eng.run(tol=tol, s0=res.s), warmup=0, iters=3)
        emit(f"engine/{name}/cold_n{n}", cold,
             f"iters={int(res.iterations)}")
        emit(f"engine/{name}/warm_n{n}", warm, f"linf_vs_ref={linf:.2e}")
