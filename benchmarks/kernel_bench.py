"""Kernel-regime benchmarks: the ψ push in its three implementations.

Wall-time on this container measures the XLA-CPU segment-sum path (the CPU
production path). Pallas kernels execute in interpret mode here — their
numbers are *correctness-path* timings, flagged ``derived=interpret`` (the
TPU performance story is the §Roofline analysis, not CPU wall-time).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.graphs import load_dataset, powerlaw_configuration
from repro.core import heterogeneous, build_operators
from repro.kernels import (build_edge_tiles, build_bsr, DeviceEdgeTiles,
                           DeviceBsr, edge_spmv, bsr_spmv)
from .common import emit, timeit


def run(quick: bool = False) -> None:
    g = load_dataset("dblp") if not quick else powerlaw_configuration(
        2000, 12000, seed=0)
    act = heterogeneous(g.n, seed=1)
    ops = build_operators(g, act)
    s = ops.c

    push = jax.jit(ops.push)
    us = timeit(lambda: jax.block_until_ready(push(s)), warmup=2, iters=5)
    emit(f"kernel/xla_segment_push/{g.name}", us,
         f"m={g.m};gb_s={(g.m * 12 / (us * 1e-6)) / 1e9:.2f}")

    fmt = DeviceEdgeTiles.from_format(build_edge_tiles(g, tile=256))
    s_pre = s * ops.inv_w
    us_k = timeit(lambda: jax.block_until_ready(edge_spmv(s_pre, fmt)),
                  warmup=1, iters=2)
    pad_ratio = fmt.src_idx.size / max(g.m, 1)
    emit(f"kernel/edge_tile_pallas/{g.name}", us_k,
         f"interpret;pad_ratio={pad_ratio:.2f}")

    bfmt_h = build_bsr(g, ts=128, td=128)
    emit(f"kernel/bsr_occupancy/{g.name}", 0.0,
         f"occupancy={bfmt_h.occupancy:.4f};"
         f"tiles={bfmt_h.num_blocks};"
         f"dense_flops_multiplier={1.0 / max(bfmt_h.occupancy, 1e-9):.0f}x")
