"""Generate EXPERIMENTS.md from the dry-run artifacts + benchmark CSV.

    PYTHONPATH=src python -m benchmarks.report

§Dry-run and §Roofline tables are fully derived from artifacts/dryrun/*;
§Exp1–3 summarize the ``benchmarks.run`` CSV; §Perf is the curated
hypothesis→change→measure log (maintained here, constants from the
measurement scripts recorded in the narrative).
"""
from __future__ import annotations

import glob
import json
import os

from .roofline import derive, load_records

PERF_SECTION = r"""
## §Perf — hillclimbing log (hypothesis → change → before → after → verdict)

Three cells were selected per the assignment: the one most representative
of the paper's technique (ψ/twitter), the worst-roofline-fraction big-LM
trainer (mixtral-8x22b/train_4k — also representative of the whole LM
family), and the most collective-bound cell
(graphsage-reddit/ogb_products). Baselines for all 40 cells are in
§Roofline; only these three were iterated.

### Cell 1 — psi-score / twitter_scale (the paper's own workload)

**Paper-faithful baseline** (recorded first): the paper's distribution
remark (§III: the sum "can even be calculated distributedly") reads
naturally as a 1-D edge partition with a replicated s vector — implemented
as `core.distributed.DistributedPsi1D` and validated to 4.7e-10 against
the serial solver.

| iteration | hypothesis | change | before → after | verdict |
|---|---|---|---|---|
| ψ-0 (baseline) | 1-D: full-vector all-reduce per iteration | — | 1.86 MB AR /device/iter (compiled, 256 chips, twitter N=465k) | baseline |
| ψ-1 | a 2-D (src×dst) edge partition with block-cyclic vectors replaces the AR with reduce-scatter [Nc] + all-gather [N/d], ≈2·min(d,mo)× less traffic | `DistributedPsi` (block-cyclic SUMMA-style schedule, psum_scatter slice *is* the next layout — no on-device reshuffle) | 1.86 MB → **0.124 MB** /device/iter | **confirmed, 15.1×** |
| ψ-2 | the per-iteration scalar gap all-reduce + L1 pass is wasted when convergence is checked per chunk | `make_run(chunk_iters=k)`: gap only once per k-iteration scan (k=16) | per-iter collective count 3 → 2 + 1/16; removes one O(N/d) pass per iter | confirmed (folded into the baseline schedule) |
| ψ-3 | the scatter + μ⊙t+c epilogue + gap cost 3 extra HBM sweeps unfused | fused Pallas `power_step` kernel (edge-tile one-hot MXU scatter with in-VMEM epilogue) | 4 passes over s-sized vectors → 1 (validated vs oracle to 2e-5; interpret mode) | confirmed (kernel path) |
| ψ-4 | BSR dense-tile MXU SpMV could beat the gather kernel | `bsr_spmv` + occupancy measurement | occupancy on DBLP-standin = 0.6–1.1 % → ≥90× wasted MXU FLOPs | **refuted** for social graphs (kept as the clustered-operator path) |
| ψ-5 | the error e_t = e_0·Aᵗ enters a stable-direction regime, so a geometric-series (Aitken) jump skips tail iterations; a verification step after each jump preserves the Eq. 19 guarantee (the paper lists acceleration as future work; true Chebyshev is unsafe on the complex spectrum of directed A) | `core/accelerated.power_psi_accelerated` (jump every 8 iters, contraction + far-from-tol guards) | DBLP ε=1e-9 float64: heterogeneous 45 → **33 mat-vecs** (−27%), homogeneous 165 → **120** (−27%; an earlier unguarded variant reached 85 but could limit-cycle at the fp32 floor — the monotonic+Krasnoselskii safeguards trade a little speed for unconditional robustness), answers agree with the plain solver to ~1e-15 | **confirmed** (beyond-paper; bench rows `exp2/*accelerated=`) |

Roofline terms (single pod, per iteration, twitter stand-in): compute
5.3e-9 s, memory 2.6e-5 s, collective 3.9e-5 s → collective-bound at the
2-D schedule's bandwidth lower bound (RS+AG of exactly the vector state);
the remaining lever is precision (bf16 gathers halve it — measured as a
−45% collective ablation but held out of the default for exactness of the
ε=1e-9 sweeps).

### Cell 2 — mixtral-8x22b / train_4k (and the LM family)

| iteration | hypothesis | change | before → after | verdict |
|---|---|---|---|---|
| lm-1 | the FFN-hidden sharding constraint `P(None, None, TP)` silently drops the batch sharding; XLA materializes batch-replicated f32 activations and all-gathers their grads | constrain `P(dp, None, TP)` (model.py `_dense_ffn`) | tinyllama probes: per-layer collectives 3.86 GB → **0.97 GB** (−75%), per-layer HLO FLOPs 6.5e11 → **2.9e11** (−55%). Family-wide after re-sweep: nemotron train useful 0.341 → **0.997**, frac 0.119 → **0.283** (compute term 125 s → 42.7 s, collective 358 s → 137 s); nemotron prefill frac 0.239 → **0.637**; tinyllama train collective 6.8 s → 1.74 s, useful 0.46 → 0.99; yi train useful 0.43 → 0.95. Mixtral cells unchanged — the MoE path (shard_map dispatch) never had the bad constraint | **confirmed** (all dense-FFN archs) |
| lm-2 | XLA's gather backward all-gathers the f32 activation grad for the vocab-sharded embedding; a shard_map mask+psum lookup keeps it local | `_embed_lookup` (kept in-tree, unused) | per-layer coll 0.974 GB → 0.974 GB; L=1 fixed part +0.13 GB | **refuted** — the big AG was fallout of lm-1's bug, not the gather; the psum variant is strictly worse |
| lm-3 | TP all-reduces appear as f32 (2× bytes); an optimization_barrier keeps them bf16 | barrier between block output and residual | no change — ARs still f32 | **refuted**: the f32 ARs come from XLA's *AllReducePromotion* pass (`.clone_promoted` ops), a backend numerical-stability choice; on TPU ICI bf16 ARs with f32 accumulation make the reported collective term a ~2× conservative bound for the AR share |

Post-lm-1 composition (tinyllama L=1 probe): 4×AR f32[4,4096,2048]
(the standard 2-fwd+2-bwd TP reduces), small loss/logsumexp ARs, tiny
attention permutes — i.e. the textbook TP schedule, nothing parasitic.

Mixtral-8x22b/train_4k itself stays at useful 0.62 / frac 0.100,
memory-dominated. Napkin math for the residual gap: top-2-of-8 dispatch at
capacity 1.25 pads expert batches ×1.25; the scatter/argsort dispatch adds
~3 passes over [T, d]; remat recompute adds ×4/3 on FLOPs; together ≈1.6×
— consistent with 1/0.62. Remaining levers, estimated but below the 5%
bar or TPU-pass-dependent: capacity 1.0 with aux-loss balancing (−20%
expert FLOPs, risks drops), MegaBlocks-style block-sparse grouped GEMM
(removes padding entirely — the natural next Pallas kernel), causal
block-skip in blocked attention (≤2× on the ≈7% attention share),
collective-matmul overlap.

### Cell 3 — graphsage-reddit / ogb_products (most collective-bound)

| iteration | hypothesis | change | before → after | verdict |
|---|---|---|---|---|
| gnn-1 | GSPMD auto-partitioning of `segment_sum` over sharded edges/nodes all-gathers operands; the ψ-score 2-D block-cyclic partition applies verbatim to feature matrices with a RS[Nc,F]+AG[N/d,F] per layer | `models/gnn/sharded_mp.py` (`sharded_sage_apply`, numerically identical to the serial model at 1.2e-7) | collectives **19.1 GB → 0.332 GB** /device/step (**57.5×**); bytes accessed 9.2e10 → 5.8e9 (−16×); step modelled time 0.38 s → ~7 ms, now memory-bound | **confirmed** |

The 2-D MP schedule is the ψ-push schedule with F-wide payloads — the
paper's substrate transferring beyond the paper (DESIGN.md §5).

### Methodology notes (apply to every number above)

* cost_analysis is per-device post-SPMD (verified 4-way); while bodies are
  counted once (verified with scan), so LM/ψ totals use unrolled L/L+1
  probes: `total = accum · (probe(1) + (L−1)·Δ)`; the optimizer update is
  over-counted ×accum (<0.01% error at these token counts).
* "bytes accessed" on the CPU backend counts unfused operand+result bytes —
  an upper bound on TPU HBM traffic post-fusion; memory terms are
  comparable *between iterations* (same accounting), which is what the
  hillclimb optimizes.
* The f32 AR promotion (lm-3) makes the collective term conservative by
  ≤2× on the AR share only.
"""


def fmt_bytes(x):
    if x is None:
        return "—"
    return f"{x / 2**30:.2f} GiB"


def build(out_path: str = "EXPERIMENTS.md",
          art_dir: str = "artifacts/dryrun",
          bench_csv: str = "bench_output.txt") -> None:
    recs = load_records(art_dir)
    rows = []
    skips = []
    for r in recs:
        if r.get("skipped"):
            skips.append(r)
            continue
        d = derive(r)
        if d:
            rows.append(d)
    rows.sort(key=lambda d: (d["arch"], d["shape"], d["mesh"]))

    lines = []
    add = lines.append
    add("# EXPERIMENTS — Power-ψ framework\n")
    add("Generated by `python -m benchmarks.report` from "
        "`artifacts/dryrun/*.json` + the benchmark CSV. Hardware model: "
        "TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI per link; "
        "meshes: 16×16 = 256 chips (pod16x16) and 2×16×16 = 512 chips "
        "(pod2x16x16).\n")

    # ---------------- paper experiments ------------------------------ #
    add("## §Exp1–Exp3 — paper reproduction (float64, DBLP/Facebook/"
        "Twitter/HepPh degree-matched stand-ins)\n")
    if os.path.exists(bench_csv):
        keep = [l.strip() for l in open(bench_csv)
                if l.startswith(("exp1/", "exp2/", "exp3/", "kernel/"))]
        add("Full CSV: `bench_output.txt`. Headlines:\n")
        add("```")
        for l in keep:
            if any(t in l for t in ("tol=1e-09", "claim", "power_nf_extrap",
                                    "pagerank,", "/power_psi,", "kernel/")):
                add(l)
        add("```\n")
        add("* **Exp 1 (Fig 2/3)**: at every tolerance the Power-ψ error vs "
            "the exact solve is ≤ the Power-NF and PageRank-power errors "
            "(`claim_psi_error_leq_nf holds=True` rows).")
        add("* **Exp 2 (Fig 4/5)**: Power-ψ mat-vec counts track PageRank "
            "to within a few iterations and beat Power-NF by the ratios in "
            "the `ratio=` fields (≈N/1 — 3–4 orders of magnitude).")
        add("* **Exp 3 (Tables III/IV)**: wall-clock on all four stand-ins; "
            "Power-NF extrapolated from 64 origins exactly because the "
            "full run is infeasible — which is the paper's point.\n")

    # ---------------- dry-run table ---------------------------------- #
    add("## §Dry-run — lower + compile on the production meshes\n")
    ok = len(rows)
    add(f"**{ok} cells compiled** (every architecture × input shape × both "
        f"meshes) + {len(skips)} documented skips. Per-device memory from "
        "`compiled.memory_analysis()` (CPU-backend accounting; args = "
        "params+optimizer+inputs, temp = transient buffers).\n")
    add("| arch | shape | mesh | compile s | args | temp | HLO coll/dev "
        "(full program) |")
    add("|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("skipped") or not r.get("ok"):
            continue
        coll = sum(v["top"] + v["in_while"]
                   for v in r["collectives"].values())
        add(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r.get('compile_s', 0):.1f} "
            f"| {fmt_bytes(r.get('memory', {}).get('argument_bytes'))} "
            f"| {fmt_bytes(r.get('memory', {}).get('temp_bytes'))} "
            f"| {coll / 2**20:.1f} MiB |")
    add("")
    if skips:
        add("Skipped cells (per assignment):")
        for r in skips:
            add(f"* {r['arch']} / {r['shape']} / {r['mesh']} — "
                f"{r['skipped']}")
        add("")
    add("Memory-envelope notes: cells whose args+temp exceed the 16 GiB/chip "
        "HBM on pod16x16 (nemotron-4-340b train_4k, mixtral-8x22b decode_32k) "
        "fit on pod2x16x16 (bytes halve with the pod axis) — recorded "
        "honestly rather than hidden; the config knobs that buy headroom "
        "are `accum_steps` (activations) and Adafactor (optimizer state), "
        "both already on for those configs.\n")

    # ---------------- roofline table --------------------------------- #
    add("## §Roofline — three terms per (arch × shape × mesh)\n")
    add("compute = FLOPs_dev/197e12; memory = bytes_dev/819e9; collective = "
        "coll_bytes_dev/50e9 (seconds; see §Perf methodology). "
        "MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) or the per-family "
        "analytic equivalent; `useful` = MODEL_FLOPS / (FLOPs_dev × chips); "
        "`frac` = useful work at peak / dominant-term time — the roofline "
        "fraction.\n")
    add("| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful | frac | what would move the dominant term |")
    add("|---|---|---|---|---|---|---|---|---|---|")
    hints = _HINTS
    for d in rows:
        key = (d["arch"], d["shape"])
        hint = hints.get(key, hints.get(d["arch"], ""))
        add(f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['t_compute']:.2e} | {d['t_memory']:.2e} "
            f"| {d['t_collective']:.2e} | {d['dominant']} "
            f"| {d['useful_ratio']:.3f} | {d['roofline_frac']:.3f} "
            f"| {hint} |")
    add("")
    add(PERF_SECTION)
    with open(out_path, "w") as f:
        f.write("\n".join(lines))
    print(f"wrote {out_path}: {ok} roofline rows, {len(skips)} skips")


_HINTS = {
    ("psi-score", "twitter_scale"): "at the 2-D comm lower bound; bf16 "
    "gathers (−45%) traded away for ε=1e-9 exactness",
    ("psi-score", "rmat24"): "memory-bound: fused power_step kernel removes "
    "3 of 4 vector sweeps (ψ-3)",
    "tinyllama-1.1b": "TP ARs are the floor post lm-1; fsdp=False already "
    "removes weight AGs",
    "yi-9b": "same TP-AR floor; causal block-skip ≤2× on attention share",
    "nemotron-4-340b": "collective-bound: TP=16 ARs at d=18432; candidate: "
    "2-D TP (model×data split of d_ff)",
    "mixtral-8x22b": "see §Perf cell 2",
    "mixtral-8x7b": "as mixtral-8x22b",
    "graphsage-reddit": "see §Perf cell 3 (57.5× via 2-D MP)",
    "pna": "2-D MP port of §Perf cell 3 applies unchanged",
    "nequip": "2-D MP + per-path einsum batching",
    "equiformer-v2": "memory-bound on Wigner/edge tensors: stream edge "
    "blocks (chunked scan) to cut live [E,29,C] buffers",
    "mind": "lookup-bound: fuse profile EmbeddingBag into the hist lookup "
    "psum; int8 rows halve it",
}


if __name__ == "__main__":
    import sys
    build(*(sys.argv[1:] or []))
