"""The persistent benchmark trajectory: ``BENCH_power_psi.json``.

One canonical record per (backend × graph regime): median wall-time,
iterations and mat-vecs to the target tolerance. Every PR re-runs this and
*appends* a run to the JSON (keyed by label — re-running the same label
replaces it), so speedups and regressions are measured, not asserted:

* ``heterogeneous`` / ``homogeneous`` — the paper's float64 ε = 1e-9
  mat-vec benchmark on a hyper-sparse power-law graph: ``reference`` vs
  the Aitken-``accelerated`` backend (acceptance: ≥ 20 % fewer mat-vecs on
  heterogeneous activity).
* ``hyper_sparse`` / ``clustered`` — the float32 kernel-regime benchmark:
  ``pallas`` pinned to each regime vs the ``auto`` planner (acceptance:
  auto within 10 % of the best hand-picked regime on both graphs).
* ``fleet`` — the multi-tenant serving benchmark: a bucket of small
  tenants solved by one ``TenantFleet`` (vmapped masked batch,
  docs/SERVING.md) vs the same tenants solved sequentially by solo
  ``reference`` engines at the same tolerance (acceptance: ≥ 2×
  tenants-per-second, every fleet ψ within tol of its solo solve).
* ``async_straggler`` — the bounded-staleness executor benchmark
  (docs/ASYNC.md): the same chunk pipeline barriered (``tau=0``) vs
  overlapped (``tau=2``) under a rotating simulated straggler, at matched
  tolerance (acceptance: ≥ 1.3× wall-clock for the overlapped pipeline,
  psi_err vs the synchronous reference recorded and ≤ 1e-8).
* ``streaming`` — the ingestion benchmark (docs/STREAMING.md): a
  flash-crowd event log (posts/reposts/follows/unfollow tombstones)
  replayed through the ``StreamIngestor`` over a float64 ``PsiService``
  under the freshness policy; records sustained events/s, resolves,
  max top-k churn between resolves, and psi_err of the streamed fixed
  point vs a from-scratch batch solve on the final (graph,
  estimated-activity) state (acceptance: psi_err ≤ 1e-6).
* ``local_query`` — the certified top-k benchmark (docs/LOCALPUSH.md):
  drift-sized λ perturbations on 0.1 % / 1 % / 10 % dirty sets, each
  warm-resolved to a certified top-100 by the ``push`` backend through
  its maintained residual handle; records push edge-work as a fraction
  of a global reference warm resolve (mat-vecs × M edges), touched-node
  fraction, certified-vs-exact top-k agreement, and the certificate
  against the true float64 ψ error (acceptance at 0.1 % dirty:
  work_frac ≤ 5 %, agreement = 1.0, certificate ≥ true error on every
  recorded run).
* ``chaos_recovery`` — the resilience drill (docs/RESILIENCE.md): the
  seeded ``FaultPlan`` from ``repro.resilience.check`` (crashes, a stale
  reader, a torn checkpoint, a NaN patch, dup/reorder/drop feed faults)
  driven against the streaming stack, then whole-stack recovery +
  exactly-once replay back to the fault-free fixed point; records the
  chaos wall as a multiple of the fault-free run, mean time to recover,
  restarts, degraded serves, and ψ parity vs the fault-free oracle
  (acceptance: zero unsurvived faults, parity ≤ psi_tol).

Run via ``python -m benchmarks.run --only trajectory`` (add ``--quick`` for
the CI smoke sizes).
"""
from __future__ import annotations

import json
import os
import subprocess
import time

import numpy as np

from benchmarks.common import emit

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_power_psi.json")


def _run_label() -> str:
    if os.environ.get("BENCH_LABEL"):
        return os.environ["BENCH_LABEL"]
    try:
        rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=os.path.dirname(__file__)).stdout.strip()
        return rev or "local"
    except Exception:
        return "local"


def _solve_stats(eng, *, tol: float, iters: int = 5) -> dict:
    res = eng.run(tol=tol)                    # compile + converge once
    eng.run(tol=tol)                          # settle caches
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        eng.run(tol=tol)
        times.append(time.perf_counter() - t0)
    return dict(wall_s=float(np.median(times)),
                iterations=int(res.iterations), matvecs=int(res.matvecs),
                converged=bool(res.converged), gap=float(res.gap))


def _append_run(entries: list[dict], json_path: str, quick: bool) -> None:
    doc = {"schema": 1, "runs": []}
    if os.path.exists(json_path):
        try:
            with open(json_path) as f:
                doc = json.load(f)
        except (json.JSONDecodeError, OSError):
            pass
    label = _run_label()
    # environment fingerprint: a run entry is only comparable to another
    # when the device/jax/x64 context it ran under is recorded next to it
    from repro.obs.env import environment_fingerprint
    doc["runs"] = [r for r in doc.get("runs", []) if r.get("label") != label]
    doc["runs"].append({"label": label, "quick": quick,
                        "environment": environment_fingerprint(),
                        "entries": entries})
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# wrote {len(entries)} entries to {os.path.abspath(json_path)} "
          f"(label={label})")


def run(quick: bool = False, json_path: str = JSON_PATH) -> list[dict]:
    import jax.numpy as jnp

    from repro.core import heterogeneous, homogeneous, make_engine
    from repro.graphs import clustered_blocks, powerlaw_configuration

    entries: list[dict] = []

    def record(graph_name, g, backend, eng, *, tol, dtype):
        stats = _solve_stats(eng, tol=tol)
        regime = getattr(eng, "regime", None)
        entries.append(dict(graph=graph_name, backend=backend,
                            regime=regime, n=g.n, m=g.m, dtype=dtype,
                            tol=tol, **stats))
        emit(f"trajectory/{graph_name}/{backend}",
             stats["wall_s"] * 1e6,
             f"iters={stats['iterations']};matvecs={stats['matvecs']}"
             + (f";regime={regime}" if regime else ""))
        return stats

    # ---- mat-vec trajectory: the paper's float64 ε = 1e-9 sweep -------- #
    n, m = (3_000, 20_000) if quick else (10_000, 70_000)
    g = powerlaw_configuration(n, m, seed=17)
    for regime_name, act in (("heterogeneous", heterogeneous(g.n, seed=18)),
                             ("homogeneous", homogeneous(g.n))):
        base = None
        for backend in ("reference", "accelerated"):
            eng = make_engine(backend, graph=g, activity=act,
                              dtype=jnp.float64)
            stats = record(regime_name, g, backend, eng, tol=1e-9,
                           dtype="float64")
            if backend == "reference":
                base = stats
            else:
                saved = 1.0 - stats["matvecs"] / max(1, base["matvecs"])
                emit(f"trajectory/{regime_name}/matvec_reduction",
                     saved * 100.0,
                     f"{base['matvecs']}->{stats['matvecs']}")

    # ---- regime trajectory: pinned pallas regimes vs the auto planner -- #
    if quick:
        g_sparse = powerlaw_configuration(1_000, 7_000, seed=17)
        g_clust = clustered_blocks(512, 16_000, block=128, p_in=1.0, seed=3)
    else:
        g_sparse = powerlaw_configuration(2_000, 14_000, seed=17)
        g_clust = clustered_blocks(1_024, 60_000, block=128, p_in=1.0,
                                   seed=3)
    for graph_name, g in (("hyper_sparse", g_sparse),
                          ("clustered", g_clust)):
        act = heterogeneous(g.n, seed=18)
        walls = {}
        for backend, opts in (
                ("reference", {}),
                ("pallas[edge_tile]", dict(regime="edge_tile")),
                ("pallas[bsr]", dict(regime="bsr")),
                ("auto", dict(microbench=True))):
            name = backend.split("[")[0]
            eng = make_engine(name, graph=g, activity=act, **opts)
            stats = record(graph_name, g, backend, eng, tol=1e-6,
                           dtype="float32")
            walls[backend] = stats["wall_s"]
        best = min(walls["pallas[edge_tile]"], walls["pallas[bsr]"])
        emit(f"trajectory/{graph_name}/auto_vs_best",
             walls["auto"] / best * 100.0,
             "auto wall as % of best hand-picked regime")

    # ---- async trajectory: bounded-staleness chunks vs the barrier ----- #
    # One chunk per epoch sleeps `delay` (rotating straggler). The tau=0
    # pipeline is the *same code path* forced bulk-synchronous — every
    # epoch pays the straggler; tau=2 lets the delayed chunk fall behind
    # and amortizes the delay across the pipeline (docs/ASYNC.md).
    C = 4
    n_a, m_a = (1_200, 8_000) if quick else (3_000, 20_000)
    delay = 0.015 if quick else 0.02
    tol_a = 1e-9
    g_a = powerlaw_configuration(n_a, m_a, seed=21)
    act_a = heterogeneous(n_a, seed=22)
    psi_sync = np.asarray(make_engine(
        "reference", graph=g_a, activity=act_a,
        dtype=jnp.float64).run(tol=tol_a).psi)

    def rotating_straggler(chunk, epoch):
        return delay if epoch % C == chunk else 0.0

    async_walls = {}
    reps_a = 2 if quick else 3
    for label, tau in (("async[tau=0]", 0), ("async[tau=2]", 2)):
        eng = make_engine("async", graph=g_a, activity=act_a,
                          dtype=jnp.float64, num_chunks=C, tau=tau,
                          delay_hook=rotating_straggler)
        res = eng.run(tol=tol_a)              # compile + converge once
        times = []
        for _ in range(reps_a):
            t0 = time.perf_counter()
            res = eng.run(tol=tol_a)          # cold s₀ = c each rep
            times.append(time.perf_counter() - t0)
        wall = float(np.median(times))
        async_walls[label] = wall
        psi_err = float(np.abs(np.asarray(res.psi) - psi_sync).max())
        entries.append(dict(
            graph="async_straggler", backend=label, regime=f"tau={tau}",
            n=n_a, m=m_a, dtype="float64", tol=tol_a, wall_s=wall,
            iterations=int(res.iterations), matvecs=int(res.matvecs),
            converged=bool(res.converged), gap=float(res.gap),
            psi_err=psi_err, chunks=C, straggler_delay_s=delay,
            max_staleness=int(eng.last_run.max_staleness),
            overlap_efficiency=float(eng.last_run.overlap_efficiency)))
        emit(f"trajectory/async_straggler/{label}", wall * 1e6,
             f"epochs={int(res.iterations)};psi_err={psi_err:.1e}"
             f";max_staleness={int(eng.last_run.max_staleness)}")
    speedup = async_walls["async[tau=0]"] / async_walls["async[tau=2]"]
    entries[-1]["speedup_vs_sync"] = speedup
    emit("trajectory/async_straggler/speedup", speedup * 100.0,
         "overlapped tau=2 wall vs barriered tau=0, % (>130 = acceptance)")

    # ---- fleet trajectory: tenants-per-device batched serving ---------- #
    from repro.serving import TenantFleet

    T = 8
    n_t, m_t = (200, 1_000) if quick else (256, 1_500)
    tol_f = 1e-6
    fleet_tenants = [(powerlaw_configuration(n_t, m_t, seed=30 + i),
                      heterogeneous(n_t, seed=60 + i)) for i in range(T)]
    engines = [make_engine("reference", graph=g, activity=a)
               for g, a in fleet_tenants]
    solo_psi = [np.asarray(eng.run(tol=tol_f).psi) for eng in engines]
    reps = 3 if quick else 5
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for eng in engines:
            eng.run(tol=tol_f)                 # cold s₀ = c each, warm jit
        times.append(time.perf_counter() - t0)
    solo_wall = float(np.median(times))
    fleet = TenantFleet(backend="dense", tol=tol_f)
    for i, (g, a) in enumerate(fleet_tenants):
        fleet.admit(f"t{i}", g, a)
    fleet.solve()                              # compile + converge
    fleet.invalidate()
    fleet.solve()                              # settle the cold-solve path
    times = []
    for _ in range(reps):
        fleet.invalidate()                     # cold s₀ = c, stacks kept
        t0 = time.perf_counter()
        fleet.solve()
        times.append(time.perf_counter() - t0)
    fleet_wall = float(np.median(times))
    psi_err = max(float(np.abs(fleet.psi(f"t{i}") - solo_psi[i]).max())
                  for i in range(T))
    iters = [fleet.stats(f"t{i}")["iterations"] for i in range(T)]
    entries.append(dict(
        graph="fleet", backend="fleet[dense]", regime="dense", n=n_t,
        m=m_t, dtype="float32", tol=tol_f, wall_s=fleet_wall,
        iterations=int(max(iters)), matvecs=int(sum(iters) + T),
        converged=all(fleet.stats(f"t{i}")["converged"] for i in range(T)),
        gap=max(fleet.stats(f"t{i}")["gap"] for i in range(T)),
        tenants=T, wall_s_solo=solo_wall,
        tenants_per_s=T / fleet_wall, tenants_per_s_solo=T / solo_wall,
        speedup=solo_wall / fleet_wall, psi_err=psi_err))
    emit("trajectory/fleet/tenants_per_s", T / fleet_wall * 1.0,
         f"solo={T / solo_wall:.1f}/s;speedup={solo_wall / fleet_wall:.2f}x"
         f";psi_err={psi_err:.1e}")

    # ---- streaming trajectory: event ingest → O(Δ) patches → fresh ψ --- #
    from repro.core import Activity, RATE_FLOOR, PsiService
    from repro.stream import (FreshnessPolicy, StreamIngestor,
                              flash_crowd_stream)

    n_s, m_s, ev_s = ((1_000, 6_000, 2_000) if quick
                      else (3_000, 20_000, 10_000))
    tol_s = 1e-9
    g_s = powerlaw_configuration(n_s, m_s, seed=44)
    truth = heterogeneous(n_s, seed=45)
    horizon = ev_s / float(truth.total.sum())
    log = flash_crowd_stream(g_s, truth, horizon, new_followers=n_s // 16,
                             churn=0.3, seed=46)
    cold = Activity(np.full(n_s, RATE_FLOOR), np.full(n_s, RATE_FLOOR))
    svc = PsiService(g_s, cold, tol=tol_s, dtype=jnp.float64)
    ing = StreamIngestor(
        svc, half_life=horizon / 2,
        policy=FreshnessPolicy(coalesce=64, resolve_every=len(log) // 8))
    t0 = time.perf_counter()
    srep = ing.ingest(log)
    stream_wall = time.perf_counter() - t0
    psi_batch = np.asarray(make_engine(
        "reference", graph=svc.graph, activity=svc.engine.activity,
        dtype=jnp.float64).run(tol=tol_s).psi)
    psi_err = float(np.abs(svc.scores() - psi_batch).max())
    churn_max = max(ing.churn_history, default=0.0)
    last = svc.last_result           # measured, final-resolve values
    entries.append(dict(
        graph="streaming", backend="ingest[reference]", regime="flash_crowd",
        n=n_s, m=svc.graph.m, dtype="float64", tol=tol_s, wall_s=stream_wall,
        iterations=int(last.iterations), matvecs=int(last.matvecs),
        converged=bool(last.converged), gap=float(last.gap),
        events=int(srep.events_total),
        events_per_s=srep.events_total / stream_wall,
        resolves=int(srep.resolves), topk_churn_max=churn_max,
        psi_err=psi_err))
    emit("trajectory/streaming/events_per_s",
         srep.events_total / stream_wall,
         f"{srep.events_total} events;{srep.resolves} resolves"
         f";psi_err={psi_err:.1e};churn_max={churn_max:.2f}"
         " (psi_err<=1e-6 = acceptance)")

    # ---- local-query trajectory: certified top-k push vs global sweep -- #
    from repro.core import exact_psi

    n_q, m_q = (1_200, 8_000) if quick else (2_500, 17_000)
    k_q, drift, tol_q = 100, 1.02, 1e-9
    g_q = powerlaw_configuration(n_q, m_q, seed=50)
    act_q = heterogeneous(n_q, seed=51)
    rng_q = np.random.default_rng(52)
    for frac in (0.001, 0.01, 0.1):
        eng_p = make_engine("push", graph=g_q, activity=act_q)
        cold_q = eng_p.run(tol=tol_q)
        dirty = rng_q.choice(n_q, size=max(1, int(frac * n_q)),
                             replace=False)
        new_lam = act_q.lam[dirty] * drift
        eng_p.patch_activity(dirty, lam=new_lam)
        t0 = time.perf_counter()
        res_q, cert_q = eng_p.run_top_k(k_q, tol=tol_q, s0=cold_q.s)
        wall_q = time.perf_counter() - t0
        stats_q = eng_p.last_run_stats
        push_edges = (stats_q["edge_work"]
                      + stats_q["reseed_matvecs"] * g_q.m)
        # the global alternative: a reference sweep warm-resolving the same
        # patched state from its own converged iterate (mat-vecs × M edges)
        eng_r = make_engine("reference", graph=g_q, activity=act_q,
                            dtype=jnp.float64)
        cold_r = eng_r.run(tol=tol_q)
        eng_r.patch_activity(dirty, lam=new_lam)
        res_r = eng_r.run(tol=tol_q, s0=cold_r.s)
        ref_edges = int(res_r.matvecs) * g_q.m
        lam2 = act_q.lam.copy()
        lam2[dirty] = new_lam
        psi_t, _ = exact_psi(g_q, Activity(lam2, act_q.mu))
        exact_top = set(np.argsort(-psi_t,
                                   kind="stable")[:k_q].tolist())
        agreement = len(set(cert_q.indices.tolist()) & exact_top) / k_q
        # the certificate covers the float64 host ψ
        true_err = float(np.abs(eng_p.last_psi_host - psi_t).max())
        bound_q = eng_p.psi_error_bound()
        work_frac = push_edges / max(1, ref_edges)
        entries.append(dict(
            graph="local_query", backend="push",
            regime=f"dirty={frac:g}", n=n_q, m=g_q.m, dtype="float64",
            tol=tol_q, wall_s=wall_q, iterations=int(res_q.iterations),
            matvecs=int(res_q.matvecs), converged=bool(res_q.converged),
            gap=float(res_q.gap), k=k_q, dirty_frac=frac, drift=drift,
            push_edges=int(push_edges), ref_edges=ref_edges,
            work_frac=work_frac, topk_agreement=agreement,
            certified=bool(cert_q.certified), cert_bound=bound_q,
            true_err=true_err, touched_frac=stats_q["touched_frac"],
            cert_edge_work=int(stats_q["cert_edge_work"])))
        emit(f"trajectory/local_query/dirty={frac:g}",
             work_frac * 100.0,
             f"push edge-work as % of global warm resolve;k={k_q}"
             f";agreement={agreement:.2f};certified={cert_q.certified}"
             f";touched={stats_q['touched_frac']:.1%}"
             f";cert={'none' if bound_q is None else f'{bound_q:.1e}'}"
             f">=err={true_err:.1e}"
             " (0.1% dirty: <=5% = acceptance)")

    # ---- chaos trajectory: seeded faults → recovery → fixed-point parity #
    from repro.resilience.check import run_chaos

    n_c, m_c, hz_c = (200, 1_200, 3.0) if quick else (300, 1_800, 4.0)
    c_report, c_met = run_chaos(n=n_c, m=m_c, horizon=hz_c, seed=0)
    entries.append(dict(
        graph="chaos_recovery", backend="resilience",
        regime="faultplan[seed=0]", n=c_met["n"], m=c_met["m"],
        dtype=c_met["dtype"], tol=c_met["solver_tol"],
        wall_s=c_met["chaos_wall_s"], converged=True,
        gap=c_met["parity_err"], events=c_met["events"],
        recovered_offset=c_met["offset"], restarts=c_met["restarts"],
        parity_err=c_met["parity_err"], psi_tol=c_met["psi_tol"],
        wall_s_fault_free=c_met["oracle_wall_s"],
        recovery_overhead=c_met["recovery_overhead"],
        mttr_s=c_met["mttr_s"], degraded_served=c_met["degraded_served"],
        refetched=c_met["refetched"],
        duplicates_suppressed=c_met["duplicates_suppressed"],
        faults_injected=int(sum(c_report.injected.values())),
        faults_survived=int(sum(c_report.survived.values())),
        unsurvived=len(c_report.unsurvived)))
    emit("trajectory/chaos_recovery/overhead",
         c_met["recovery_overhead"] * 100.0,
         f"chaos+recovery wall as % of fault-free"
         f";parity_err={c_met['parity_err']:.1e}"
         f";mttr={c_met['mttr_s'] * 1e3:.0f}ms"
         f";faults={int(sum(c_report.injected.values()))}"
         f";unsurvived={len(c_report.unsurvived)}"
         " (0 unsurvived = acceptance)")

    _append_run(entries, json_path, quick)
    return entries
