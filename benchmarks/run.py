import os
os.environ.setdefault("JAX_ENABLE_X64", "1")   # paper sweeps ε to 1e-9
# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced tolerance sweeps / small graphs")
    ap.add_argument("--only", default=None,
                    choices=[None, "exp1", "exp2", "exp3", "kernels",
                             "roofline", "engines", "trajectory"])
    args = ap.parse_args()

    from benchmarks.common import header
    from benchmarks import (engine_parity, exp1_error, exp2_matvecs,
                            exp3_runtime, kernel_bench, roofline, trajectory)
    header()
    if args.only in (None, "engines"):
        engine_parity.run(quick=args.quick)
    if args.only in (None, "trajectory"):
        trajectory.run(quick=args.quick)
    if args.only in (None, "exp1"):
        exp1_error.run(quick=args.quick)
    if args.only in (None, "exp2"):
        exp2_matvecs.run(quick=args.quick)
    if args.only in (None, "exp3"):
        exp3_runtime.run(quick=args.quick)
    if args.only in (None, "kernels"):
        kernel_bench.run(quick=args.quick)
    if args.only in (None, "roofline"):
        roofline.run()


if __name__ == '__main__':
    main()
