"""Roofline derivation from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):

  compute    = HLO_FLOPs_per_device / 197e12            [s]
  memory     = HLO_bytes_per_device / 819e9             [s]
  collective = collective_bytes_per_device / 50e9       [s]

cost_analysis is per-device post-SPMD (verified: a 4-way-sharded matmul
reports 1/4 the FLOPs) and does NOT multiply while bodies by trip count
(verified: scan(10 matmuls) reports 1), so scan-based cells (LM, ψ) are
reconstructed from the unrolled L / L+1 probes:

  per_layer  = probe(L=2) − probe(L=1)
  total      = accum · (probe(L=1) + (layers − 1) · per_layer)

(The optimizer update is over-counted ×accum — bounded by
12 FLOPs/param vs ≳6·tokens_micro FLOPs/param of compute, i.e. <0.01%.)
GNN/recsys cells unroll layers in Python, so their full-cell numbers are
already exact.
"""
from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import HW

__all__ = ["derive", "load_records", "run"]


def _coll_bytes(coll: dict) -> float:
    return sum(v["top"] + v["in_while"] for v in coll.values())


def derive(rec: dict) -> dict | None:
    """→ dict with the three terms (seconds), dominant term, flop ratio."""
    if rec.get("skipped") or not rec.get("ok"):
        return None
    meta = rec.get("meta", {})
    chips = 512 if "2x16" in rec["mesh"] else 256

    if rec.get("probes") and all(p["ok"] for p in rec["probes"]):
        p1, p2 = rec["probes"]
        layers = meta.get("layers", meta.get("iters", 1))
        accum = meta.get("accum", 1)

        def reconstruct(get):
            a, b = get(p1), get(p2)
            return accum * (a + (layers - 1) * (b - a))

        flops = reconstruct(lambda p: p["cost"]["flops"])
        mem_bytes = reconstruct(lambda p: p["cost"]["bytes_accessed"])
        coll = reconstruct(lambda p: _coll_bytes(p["collectives"]))
        source = "probes"
    else:
        flops = rec["cost"]["flops"]
        mem_bytes = rec["cost"]["bytes_accessed"]
        coll = _coll_bytes(rec["collectives"])
        source = "full"

    t_compute = flops / HW.PEAK_BF16_FLOPS
    t_memory = mem_bytes / HW.HBM_BW
    t_coll = coll / HW.ICI_BW
    terms = dict(compute=t_compute, memory=t_memory, collective=t_coll)
    dominant = max(terms, key=terms.get)
    total = max(terms.values())
    model_flops = meta.get("model_flops", 0)
    hlo_flops_global = flops * chips
    out = dict(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=chips, source=source,
        flops_per_dev=flops, bytes_per_dev=mem_bytes, coll_per_dev=coll,
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        dominant=dominant,
        model_flops=model_flops,
        hlo_flops_global=hlo_flops_global,
        useful_ratio=(model_flops / hlo_flops_global
                      if hlo_flops_global else 0.0),
        # fraction of roofline: useful work at peak vs modelled step time
        roofline_frac=(model_flops / chips / HW.PEAK_BF16_FLOPS / total
                       if total > 0 and model_flops else 0.0),
        peak_bytes=rec.get("memory", {}).get("peak_bytes"),
        arg_bytes=rec.get("memory", {}).get("argument_bytes"),
        temp_bytes=rec.get("memory", {}).get("temp_bytes"),
    )
    return out


def load_records(art_dir: str = "artifacts/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(art_dir: str = "artifacts/dryrun",
        out_csv: str = "artifacts/roofline.csv") -> list[dict]:
    from .common import emit
    rows = []
    for rec in load_records(art_dir):
        d = derive(rec)
        if d is None:
            tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
            if rec.get("skipped"):
                emit(f"roofline/{tag}", 0.0, "skipped=" +
                     rec["skipped"][:40])
            continue
        rows.append(d)
        tag = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        emit(f"roofline/{tag}",
             max(d["t_compute"], d["t_memory"], d["t_collective"]) * 1e6,
             f"dominant={d['dominant']};frac={d['roofline_frac']:.3f};"
             f"useful={d['useful_ratio']:.3f}")
    if rows:
        os.makedirs(os.path.dirname(out_csv), exist_ok=True)
        keys = list(rows[0].keys())
        with open(out_csv, "w") as f:
            f.write(",".join(keys) + "\n")
            for r in rows:
                f.write(",".join(str(r[k]) for k in keys) + "\n")
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO | roofline frac |\n|" + "---|" * 9)
    lines = [hdr]
    for d in rows:
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['t_compute']:.2e} | {d['t_memory']:.2e} "
            f"| {d['t_collective']:.2e} | {d['dominant']} "
            f"| {d['useful_ratio']:.3f} | {d['roofline_frac']:.3f} |")
    return "\n".join(lines)
