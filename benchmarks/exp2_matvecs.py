"""Experiment 2 (Fig. 4 & 5): #matrix-vector multiplications vs tolerance.

The paper's headline: Power-ψ needs orders of magnitude fewer mat-vecs than
Power-NF and is within a few of PageRank's power method.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.graphs import load_dataset
from repro.core import (heterogeneous, homogeneous, build_operators,
                        power_psi, power_psi_accelerated, power_nf,
                        build_pagerank_ops, pagerank)
from .common import emit

TOLS = [10.0 ** -k for k in range(1, 10)]
NF_ORIGINS = 256


def run(quick: bool = False) -> None:
    g = load_dataset("dblp")
    tols = TOLS[:5] if quick else TOLS
    rng = np.random.default_rng(1)
    origins = np.sort(rng.choice(g.n, NF_ORIGINS, replace=False))

    for regime in ("heterogeneous", "homogeneous"):
        act = (heterogeneous(g.n, seed=7) if regime == "heterogeneous"
               else homogeneous(g.n))
        ops = build_operators(g, act, dtype=jnp.float64)
        for tol in tols:
            mv_psi = int(power_psi(ops, tol=tol).matvecs)
            mv_acc = int(power_psi_accelerated(ops, tol=tol).matvecs)
            nf = power_nf(ops, tol=tol, chunk=256, origins=origins)
            mv_nf = nf.matvecs * g.n // NF_ORIGINS     # extrapolated
            emit(f"exp2/{regime}/tol={tol:.0e}", float(mv_psi),
                 f"power_psi={mv_psi};accelerated={mv_acc};power_nf~={mv_nf};"
                 f"ratio={mv_nf / max(mv_psi, 1):.0f}x")
            if regime == "homogeneous":
                mv_pr = int(pagerank(
                    build_pagerank_ops(g, dtype=jnp.float64), alpha=0.85,
                    tol=tol).matvecs)
                emit(f"exp2/homogeneous/pagerank/tol={tol:.0e}",
                     float(mv_pr), f"psi_vs_pagerank={mv_psi - mv_pr:+d}")
