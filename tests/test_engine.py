"""Unified PsiEngine abstraction: backend parity, delta rebuilds, serving."""
import numpy as np
import pytest

import repro.core.operators as operators_mod
from repro.graphs import clustered_blocks, erdos_renyi, powerlaw_configuration
from repro.core import (Activity, heterogeneous, homogeneous, exact_psi,
                        make_engine, available_backends, ConvergenceCriterion,
                        PsiService, HostOperators, build_operators, power_psi)
from repro.graphs.structure import Graph

BACKENDS = ["reference", "pallas", "auto", "accelerated", "distributed",
            "async", "push"]


@pytest.fixture(scope="module")
def platform():
    g = powerlaw_configuration(500, 3000, seed=42)
    act = heterogeneous(g.n, seed=43)
    psi_true, s_true = exact_psi(g, act)
    return g, act, psi_true, s_true


# --------------------------------------------------------------------- #
# Parity: all registered backends agree with the exact solver
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_parity_with_exact(platform, backend):
    g, act, psi_true, _ = platform
    eng = make_engine(backend, graph=g, activity=act)
    res = eng.run(tol=1e-10)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_warm_start_path(platform, backend):
    """s0 threading: a converged s* re-converges immediately and exactly."""
    g, act, psi_true, _ = platform
    eng = make_engine(backend, graph=g, activity=act)
    cold = eng.run(tol=1e-10)
    warm = eng.run(tol=1e-10, s0=cold.s)
    assert int(warm.iterations) < int(cold.iterations)
    assert np.abs(np.asarray(warm.psi) - psi_true).max() <= 1e-6


@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_step_protocol(platform, backend):
    """prepare → repeated step drives the gap down under the shared rule."""
    g, act, _, _ = platform
    eng = make_engine(backend, graph=g, activity=act)
    state = eng.prepare(g, act)
    for _ in range(5):
        state = eng.step(state)
    assert state.t == 5
    first_gap = state.gap
    for _ in range(10):
        state = eng.step(state)
    assert state.gap < first_gap


def test_epilogue_matches_reference(platform):
    g, act, _, s_true = platform
    ref = make_engine("reference", graph=g, activity=act)
    pal = make_engine("pallas", graph=g, activity=act)
    psi_r = np.asarray(ref.epilogue(s_true.astype(np.float32)))
    psi_p = np.asarray(pal.epilogue(s_true.astype(np.float32)))
    np.testing.assert_allclose(psi_r, psi_p, rtol=1e-6, atol=1e-10)


def test_make_engine_rejects_unknown():
    with pytest.raises(ValueError, match="unknown backend"):
        make_engine("nope")
    assert set(BACKENDS) <= set(available_backends())


def test_criterion_validation():
    with pytest.raises(ValueError, match="unknown norm"):
        ConvergenceCriterion(norm="l7")
    with pytest.raises(ValueError, match="l1"):
        make_engine("pallas", criterion=ConvergenceCriterion(norm="l2"))


def test_reference_engine_matches_power_psi(platform):
    """The refactor is behavior-preserving vs the historical entry point."""
    g, act, _, _ = platform
    eng = make_engine("reference", graph=g, activity=act)
    res_new = eng.run(tol=1e-9)
    res_old = power_psi(build_operators(g, act), tol=1e-9)
    np.testing.assert_allclose(np.asarray(res_new.psi),
                               np.asarray(res_old.psi), rtol=1e-6, atol=1e-12)
    # host operators accumulate in float64 before the device cast, so the
    # tol crossing may land ±1 iteration from the all-float32 build
    assert abs(int(res_new.iterations) - int(res_old.iterations)) <= 1


# --------------------------------------------------------------------- #
# HostOperators: the O(Δ) patch layer
# --------------------------------------------------------------------- #
def test_host_operators_patch_activity_matches_rebuild(platform):
    g, act, _, _ = platform
    hs = HostOperators.from_graph(g, act)
    users = np.asarray([3, 99, 3])                # dup: last write wins
    hs.patch_activity(users, lam=np.asarray([2.0, 0.5, 4.0]))
    lam2 = act.lam.copy()
    lam2[3], lam2[99] = 4.0, 0.5
    fresh = HostOperators.from_graph(g, Activity(lam2, act.mu))
    np.testing.assert_allclose(hs.w, fresh.w, rtol=1e-12)
    np.testing.assert_allclose(hs.row_lam, fresh.row_lam, rtol=1e-12)
    assert abs(hs.b_norm - fresh.b_norm) < 1e-12


def test_host_operators_patch_edges_matches_rebuild(platform):
    g, act, _, _ = platform
    hs = HostOperators.from_graph(g, act)
    new_src = np.asarray([0, 1, 2, 2, 0])
    new_dst = np.asarray([5, 6, 7, 2, 5])         # one self-loop, one dup
    kept_s, kept_d = hs.patch_edges(new_src, new_dst)
    assert kept_s.size <= 4
    g2 = Graph(g.n, np.concatenate([g.src, new_src]),
               np.concatenate([g.dst, new_dst])).dedup()
    fresh = HostOperators.from_graph(g2, act)
    assert hs.m == fresh.m
    np.testing.assert_allclose(np.sort(hs.w), np.sort(fresh.w), rtol=1e-12)
    np.testing.assert_allclose(hs.w, fresh.w, rtol=1e-12)
    # sorted views stay sorted (segment_sum precondition)
    assert np.all(np.diff(hs.dst_by_dst) >= 0)
    assert np.all(np.diff(hs.src_by_src) >= 0)


# --------------------------------------------------------------------- #
# PsiService: delta rebuilds + batched query layer
# --------------------------------------------------------------------- #
def _forbid_full_rebuilds(monkeypatch):
    def boom(*a, **k):
        raise AssertionError("full operator rebuild on the delta path")
    monkeypatch.setattr(operators_mod, "build_operators", boom)
    monkeypatch.setattr(operators_mod.HostOperators, "from_graph",
                        classmethod(lambda cls, *a, **k: boom()))


def test_service_pallas_delta_update_roundtrip(platform, monkeypatch):
    """The acceptance path: PsiService(backend='pallas') absorbs an activity
    update through the O(Δ) patch (no full rebuild) and serves rank_of."""
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9, backend="pallas")
    u = int(svc.top_k(5)[0][-1])
    rank_before = int(svc.rank_of(np.asarray([u]))[0])
    _forbid_full_rebuilds(monkeypatch)
    svc.update_activity(np.asarray([u]), lam=np.asarray([5.0]))
    rank_after = int(svc.rank_of(np.asarray([u]))[0])
    assert rank_after <= rank_before          # posting more can't hurt
    lam2 = act.lam.copy()
    lam2[u] = 5.0
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_service_add_edges_delta(platform, backend, monkeypatch):
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9, backend=backend)
    svc.scores()
    _forbid_full_rebuilds(monkeypatch)
    src = np.asarray([0, 1, 2], np.int32)
    dst = np.asarray([10, 11, 12], np.int32)
    svc.add_edges(src, dst)
    g2 = Graph(g.n, np.concatenate([g.src, src]),
               np.concatenate([g.dst, dst])).dedup()
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


@pytest.mark.parametrize("backend", ["pallas", "distributed"])
def test_service_remove_edges_fallback(platform, backend):
    """Backends without an incremental shrink hook serve removals through
    the filtered-graph re-prepare fallback — and stay exact."""
    g, act, _, _ = platform
    opts = dict(mesh=_mesh_1x1()) if backend == "distributed" else {}
    svc = PsiService(g, act, tol=1e-9, backend=backend, engine_opts=opts)
    svc.scores()
    # remove two real edges plus one absent tombstone (must be a no-op)
    rm_s = np.asarray([g.src[0], g.src[g.m // 2], g.src[1]], np.int32)
    rm_d = np.asarray([g.dst[0], g.dst[g.m // 2],
                       (g.dst[1] + 1) % g.n], np.int32)
    if rm_s[2] == rm_d[2]:                        # avoid accidental self-loop
        rm_d[2] = (rm_d[2] + 1) % g.n
    svc.remove_edges(rm_s, rm_d)
    keep = ~np.isin(g.src.astype(np.int64) * g.n + g.dst,
                    rm_s.astype(np.int64) * g.n + rm_d)
    g2 = Graph(g.n, g.src[keep], g.dst[keep])
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


@pytest.mark.parametrize("backend", ["pallas", "distributed"])
def test_service_interleaved_add_remove_parity(platform, backend):
    """add → remove → add through one service matches a from-scratch solve
    on the final graph (the removal rebuild must not lose earlier adds)."""
    g, act, _, _ = platform
    opts = dict(mesh=_mesh_1x1()) if backend == "distributed" else {}
    svc = PsiService(g, act, tol=1e-9, backend=backend, engine_opts=opts)
    svc.scores()
    add1_s = np.asarray([0, 1], np.int32)
    add1_d = np.asarray([20, 21], np.int32)
    svc.add_edges(add1_s, add1_d)
    svc.remove_edges(np.asarray([0, g.src[0]], np.int32),
                     np.asarray([20, g.dst[0]], np.int32))   # incl. new edge
    add2_s = np.asarray([2], np.int32)
    add2_d = np.asarray([22], np.int32)
    svc.add_edges(add2_s, add2_d)
    g1 = Graph(g.n, np.concatenate([g.src, add1_s]),
               np.concatenate([g.dst, add1_d])).dedup()
    rm = np.asarray([0 * g.n + 20, int(g.src[0]) * g.n + int(g.dst[0])])
    keep = ~np.isin(g1.src.astype(np.int64) * g1.n + g1.dst, rm)
    g2 = Graph(g.n, np.concatenate([g1.src[keep], add2_s]),
               np.concatenate([g1.dst[keep], add2_d])).dedup()
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


def test_service_distributed_backend_serves(platform):
    g, act, psi_true, _ = platform
    svc = PsiService(g, act, tol=1e-9, backend="distributed")
    top, vals = svc.top_k(3)
    assert np.all(np.diff(vals) <= 0)
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


def test_ranking_cache_memoized_and_invalidated(platform, monkeypatch):
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9)
    users = np.asarray([1, 2, 3])
    svc.rank_of(users)
    cache = svc._cache
    assert cache is not None and cache._order is not None
    calls = {"n": 0}
    orig = np.argsort

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(np, "argsort", counting)
    svc.rank_of(users)                       # memoized: no new sort
    svc.top_k(4)                             # reuses the cached order too
    assert calls["n"] == 0
    assert svc._cache is cache
    svc.update_activity(np.asarray([1]), mu=np.asarray([0.9]))
    assert svc._cache is None                # mutation invalidates
    svc.rank_of(users)
    assert calls["n"] >= 1


def test_update_activity_broadcasts_scalar(platform):
    """Pre-refactor API: a scalar (or length-1) rate applies to all users."""
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9)
    users = np.asarray([1, 2, 3])
    svc.update_activity(users, lam=0.5)
    lam2 = act.lam.copy()
    lam2[users] = 0.5
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6
    svc.update_activity(users, mu=np.asarray([0.25]))   # length-1 broadcast
    assert np.isfinite(svc.scores()).all()


def test_top_k_clips_to_n(platform):
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9)
    idx, vals = svc.top_k(g.n + 5)            # uncached path
    assert idx.shape == (g.n,)
    svc.rank_of(np.asarray([0]))              # populate the sorted order
    idx2, _ = svc.top_k(g.n + 5)              # cached path agrees
    assert idx2.shape == (g.n,)


def test_delta_update_does_not_retrace(platform):
    """Activity patches keep array shapes, so the compiled solver loop must
    be reused — the O(Δ) serving claim dies if every update recompiles."""
    g, act, _, _ = platform
    eng = make_engine("reference", graph=g, activity=act)
    eng.run(tol=1e-9)
    compiles = eng._loop._cache_size()
    eng.patch_activity(np.asarray([3]), lam=np.asarray([2.0]))
    eng.run(tol=1e-9)
    assert eng._loop._cache_size() == compiles


def test_service_warm_start_fewer_iterations(platform):
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9)
    cold = svc.last_iterations()
    svc.update_activity(np.asarray([7]), mu=np.asarray([act.mu[7] * 1.01]))
    assert svc.last_iterations() < cold


# --------------------------------------------------------------------- #
# Regime autotuning + acceleration: the auto / accelerated backends
# --------------------------------------------------------------------- #
def _graph_for(kind: str) -> Graph:
    if kind == "hyper_sparse":
        return powerlaw_configuration(600, 4000, seed=11)
    return clustered_blocks(512, 30_000, block=128, p_in=1.0, seed=12)


@pytest.mark.parametrize("act_kind", ["het", "hom"])
@pytest.mark.parametrize("graph_kind", ["hyper_sparse", "clustered"])
@pytest.mark.parametrize("backend", ["auto", "accelerated"])
def test_parity_across_regimes(backend, graph_kind, act_kind):
    """auto/accelerated agree with reference to ≤ 1e-6 on both activity
    regimes × both graph regimes (the clustered graph exercises the BSR
    kernel path, the hyper-sparse one the edge-tile path)."""
    g = _graph_for(graph_kind)
    act = (heterogeneous(g.n, seed=13) if act_kind == "het"
           else homogeneous(g.n))
    ref = make_engine("reference", graph=g, activity=act).run(tol=1e-9)
    eng = make_engine(backend, graph=g, activity=act)
    res = eng.run(tol=1e-9)
    assert np.abs(np.asarray(res.psi) - np.asarray(ref.psi)).max() <= 1e-6
    if backend == "auto":   # the planner must separate the two regimes
        assert eng.regime == ("edge_tile" if graph_kind == "hyper_sparse"
                              else "bsr")


def test_accelerated_backend_fewer_matvecs(platform):
    g, act, _, _ = platform
    ref = make_engine("reference", graph=g, activity=act).run(tol=1e-6)
    acc = make_engine("accelerated", graph=g, activity=act).run(tol=1e-6)
    assert bool(acc.converged)
    assert int(acc.matvecs) < int(ref.matvecs)


def test_pallas_accelerate_opt_in(platform):
    g, act, psi_true, _ = platform
    eng = make_engine("pallas", graph=g, activity=act, accelerate=True)
    res = eng.run(tol=1e-6)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6


def test_check_every_cadence(platform):
    """iterations land on a multiple of k, overshoot < k, same answer."""
    g, act, psi_true, _ = platform
    base = make_engine("reference", graph=g, activity=act).run(tol=1e-9)
    eng = make_engine("reference", graph=g, activity=act, check_every=4)
    res = eng.run(tol=1e-9)
    assert int(res.iterations) % 4 == 0
    assert int(base.iterations) <= int(res.iterations) \
        < int(base.iterations) + 4
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6
    pal = make_engine("pallas", graph=g, activity=act, check_every=3)
    resp = pal.run(tol=1e-9)
    assert int(resp.iterations) % 3 == 0
    assert np.abs(np.asarray(resp.psi) - psi_true).max() <= 1e-6


def test_autotuner_plan_cache_no_replan_on_patch_activity(platform):
    """The regression the serving path depends on: an activity patch (and a
    warm re-prepare over the same graph) must reuse the cached plan and the
    already-compiled solver loop."""
    from repro.kernels.autotune import PlanCache
    g, act, _, _ = platform
    cache = PlanCache()
    eng = make_engine("auto", graph=g, activity=act, plan_cache=cache)
    assert (cache.hits, cache.misses) == (0, 1)
    eng.run(tol=1e-6)
    loop = eng._loop
    compiles = loop._cache_size()
    eng.patch_activity(np.asarray([3]), lam=np.asarray([2.0]))
    eng.run(tol=1e-6)
    assert cache.misses == 1               # no re-plan on the delta path
    assert eng._loop is loop and loop._cache_size() == compiles
    eng.prepare(g, act)                    # full rebuild, same structure
    eng.run(tol=1e-6)
    assert (cache.hits, cache.misses) == (1, 1)
    assert eng._loop is loop and loop._cache_size() == compiles


def test_service_auto_backend_delta_roundtrip(platform, monkeypatch):
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9, backend="auto")
    svc.scores()
    _forbid_full_rebuilds(monkeypatch)
    u = 5
    svc.update_activity(np.asarray([u]), lam=np.asarray([4.0]))
    lam2 = act.lam.copy()
    lam2[u] = 4.0
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


def test_bsr_regime_delta_updates(monkeypatch):
    """BSR-regime pallas absorbs activity and edge patches in place."""
    g = _graph_for("clustered")
    act = heterogeneous(g.n, seed=13)
    svc = PsiService(g, act, tol=1e-9, backend="pallas",
                     engine_opts=dict(regime="bsr"))
    svc.scores()
    _forbid_full_rebuilds(monkeypatch)
    svc.update_activity(np.asarray([2]), mu=np.asarray([0.8]))
    # in-block edge insert (block (0,0) exists) and a cross-block edge
    # that forces the internal format rebuild — both stay correct
    src = np.asarray([0, 3], np.int32)
    dst = np.asarray([7, 400], np.int32)
    svc.add_edges(src, dst)
    g2 = Graph(g.n, np.concatenate([g.src, src]),
               np.concatenate([g.dst, dst])).dedup()
    act2 = Activity(act.lam, np.where(np.arange(g.n) == 2, 0.8, act.mu))
    psi_true, _ = exact_psi(g2, act2)
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


def test_edge_tile_patch_overflow_rebuilds(platform, monkeypatch):
    """Overflowing a node tile's sentinel slots triggers the edge-tile
    format rebuild (never a full operator rebuild) and stays exact."""
    g, act, _, _ = platform
    svc = PsiService(g, act, tol=1e-9, backend="pallas")
    svc.scores()
    eng = svc.engine
    blocks_before = eng.fmt_host.num_blocks
    _forbid_full_rebuilds(monkeypatch)
    # enough new edges into tile 0 (dst < 256) to exhaust its free slots
    need = int((eng._tile_capacity - eng._tile_used)[0]) + 16
    existing = set(zip(g.src.tolist(), g.dst.tolist()))
    rng = np.random.default_rng(0)
    pairs = set()
    while len(pairs) < need:
        s = int(rng.integers(0, g.n))
        d = int(rng.integers(0, min(eng.tile, g.n)))
        if s != d and (s, d) not in existing:
            pairs.add((s, d))
    pairs = sorted(pairs)
    src = np.asarray([p[0] for p in pairs], np.int32)
    dst = np.asarray([p[1] for p in pairs], np.int32)
    svc.add_edges(src, dst)
    assert eng.fmt_host.num_blocks > blocks_before
    g2 = Graph(g.n, np.concatenate([g.src, src]),
               np.concatenate([g.dst, dst])).dedup()
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


# --------------------------------------------------------------------- #
# Distributed delta hook + chunk-level acceleration
# --------------------------------------------------------------------- #
def _mesh_1x1():
    """Pin a 1×1 mesh: partition shapes must not depend on how many host
    devices an earlier test (launch/dryrun) forced into the process."""
    import jax
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def test_distributed_patch_edges_block_local(platform, monkeypatch):
    """The delta hook never re-partitions: new edges are merged into their
    node-stable blocks and only the touched device rows are rewritten."""
    import repro.core.distributed as dist_mod
    g, act, _, _ = platform
    eng = make_engine("distributed", graph=g, activity=act,
                      mesh=_mesh_1x1())
    prev = eng.run(tol=1e-9)

    def boom(*a, **k):
        raise AssertionError("re-partition on the delta path")

    monkeypatch.setattr(dist_mod, "partition_2d", boom)
    _forbid_full_rebuilds(monkeypatch)
    src = np.asarray([0, 1, 2, 0], np.int32)
    dst = np.asarray([10, 11, 12, 10], np.int32)   # dup collapses
    assert eng.patch_edges(src, dst) is True
    res = eng.run(tol=1e-9, s0=prev.s)
    g2 = Graph(g.n, np.concatenate([g.src, src]),
               np.concatenate([g.dst, dst])).dedup()
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6


def test_distributed_patch_edges_overflow_regrows_with_warning():
    """A full block (e_max exhausted) is a genuine overflow: the default
    hook regrows the partition in place — warning with the overflowing
    block and required capacity, never a silent no-op — and stays exact."""
    g = erdos_renyi(100, 256, seed=6)              # e_max == m: zero slack
    act = heterogeneous(g.n, seed=7)
    eng = make_engine("distributed", graph=g, activity=act,
                      mesh=_mesh_1x1())
    prev = eng.run(tol=1e-9)
    assert int(eng.dist.part.e_max) == g.m
    with pytest.warns(RuntimeWarning,
                      match=r"block \(row=0, col=0\).*e_max=256.*>= 257"):
        assert eng.patch_edges(np.asarray([0]), np.asarray([50])) is True
    assert int(eng.dist.part.e_max) > g.m          # capacity actually grew
    res = eng.run(tol=1e-9, s0=prev.s)
    g2 = Graph(g.n, np.concatenate([g.src, [0]]),
               np.concatenate([g.dst, [50]])).dedup()
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6
    # service path rides the regrow transparently
    svc = PsiService(g, act, tol=1e-9, backend="distributed",
                     engine_opts=dict(mesh=_mesh_1x1()))
    svc.scores()
    with pytest.warns(RuntimeWarning):
        svc.add_edges(np.asarray([0]), np.asarray([50]))
    assert np.abs(svc.scores() - psi_true).max() <= 1e-6


def test_distributed_patch_edges_overflow_raise_mode():
    """on_overflow='raise' names the overflowing block and the capacity the
    insert needs (for callers that budget e_max themselves)."""
    from repro.core.distributed import BlockOverflowError
    g = erdos_renyi(100, 256, seed=6)
    act = heterogeneous(g.n, seed=7)
    eng = make_engine("distributed", graph=g, activity=act,
                      mesh=_mesh_1x1(), on_overflow="raise")
    eng.run(tol=1e-9)
    with pytest.raises(BlockOverflowError,
                       match=r"\(row=0, col=0\).*capacity >= 257") as ei:
        eng.patch_edges(np.asarray([0]), np.asarray([50]))
    assert ei.value.block == (0, 0)
    assert ei.value.e_max == 256 and ei.value.required == 257
    # the probe mutated nothing: the host mirror still matches the
    # unpatched graph, so a caught raise leaves the engine consistent
    assert eng.graph.m == g.m
    res = eng.run(tol=1e-9)
    psi_unpatched, _ = exact_psi(g, act)
    assert np.abs(np.asarray(res.psi) - psi_unpatched).max() <= 1e-6
    with pytest.raises(ValueError, match="on_overflow"):
        make_engine("distributed", on_overflow="explode")


def test_distributed_dispatch_finalize_compose(platform):
    """make_dispatch ∘ make_finalize reproduces the fused make_step — the
    explicit PartialReduction boundary the overlapped executors build on."""
    import jax
    from repro.core.distributed import DistributedPsi
    g, act, _, _ = platform
    dist = DistributedPsi.from_graph(g, act, _mesh_1x1())
    step = jax.jit(dist.make_step())
    dispatch = jax.jit(dist.make_dispatch())
    finalize = jax.jit(dist.make_finalize())
    s = dist.arrays.c_src
    for _ in range(3):
        s_fused, gap_fused = step(s, dist.arrays)
        handle = dispatch(s, dist.arrays)
        s_split, gap_split = finalize(handle, dist.arrays)
        np.testing.assert_allclose(np.asarray(s_split),
                                   np.asarray(s_fused), rtol=1e-7, atol=0)
        assert float(gap_split) == pytest.approx(float(gap_fused),
                                                 rel=1e-6)
        s = s_fused


def test_distributed_chunk_accelerate(platform):
    g, act, psi_true, _ = platform
    eng = make_engine("distributed", graph=g, activity=act,
                      accelerate=True, chunk_iters=4, mesh=_mesh_1x1())
    res = eng.run(tol=1e-9)
    assert bool(res.converged)
    assert np.abs(np.asarray(res.psi) - psi_true).max() <= 1e-6


def test_psi_driver_accelerate_inherited(platform):
    from repro.runtime import PsiDriver
    g, act, psi_true, _ = platform
    eng = make_engine("distributed", graph=g, activity=act,
                      accelerate=True, chunk_iters=4, mesh=_mesh_1x1())
    drv = PsiDriver.from_engine(eng)
    assert drv.accelerate is True
    rep = drv.run(tol=1e-11)     # driver gap is unscaled (no ‖B‖ factor)
    assert np.abs(rep.psi - psi_true).max() <= 1e-6
