"""TenantFleet: bucketed multi-tenant batched serving (docs/SERVING.md)."""
import numpy as np
import pytest

from repro.core import (exact_psi, heterogeneous, make_batched_loop,
                        make_engine, make_reference_step, PsiService)
from repro.graphs import clustered_blocks, erdos_renyi, powerlaw_configuration
from repro.graphs.structure import Graph
from repro.serving import BucketPolicy, BucketSpec, TenantFleet

REGIMES = ["dense", "reference", "pallas"]


def _tenants():
    graphs = [powerlaw_configuration(300, 1800, seed=1),
              erdos_renyi(450, 2500, seed=2),
              clustered_blocks(256, 2000, block=64, p_in=0.9, seed=3)]
    acts = [heterogeneous(g.n, seed=10 + i) for i, g in enumerate(graphs)]
    return list(zip(graphs, acts))


@pytest.fixture(scope="module")
def platform():
    tenants = _tenants()
    solo = [np.asarray(make_engine("reference", graph=g, activity=a)
                       .run(tol=1e-8).psi) for g, a in tenants]
    return tenants, solo


def _fleet(backend, **kw):
    kw.setdefault("policy", BucketPolicy((512,), edge_quantum=4096))
    return TenantFleet(backend=backend, tol=1e-8, **kw)


# --------------------------------------------------------------------- #
# Parity: every regime matches the solo reference solve per tenant
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", REGIMES)
def test_fleet_parity_with_solo_reference(platform, backend):
    tenants, solo = platform
    fleet = _fleet(backend)
    for i, (g, a) in enumerate(tenants):
        fleet.admit(f"t{i}", g, a)
    assert fleet.solve() == len(tenants)
    for i, (g, _) in enumerate(tenants):
        psi = fleet.psi(f"t{i}")
        assert psi.shape == (g.n,)
        assert np.abs(psi - solo[i]).max() <= 1e-6
        st = fleet.stats(f"t{i}")
        assert st["converged"] and st["staleness"] == 0


def test_fleet_mixed_buckets_and_occupancy(platform):
    tenants, solo = platform
    policy = BucketPolicy((256, 512), edge_quantum=2048)
    fleet = TenantFleet(backend="dense", tol=1e-8, policy=policy)
    for i, (g, a) in enumerate(tenants):
        fleet.admit(f"t{i}", g, a)
    fleet.solve()
    specs = {fleet.spec_of(f"t{i}") for i in range(len(tenants))}
    assert len(specs) > 1                        # ladder actually separates
    assert {s.n_pad for s in specs} == {256, 512}
    for i in range(len(tenants)):
        assert np.abs(fleet.psi(f"t{i}") - solo[i]).max() <= 1e-6
    occ = fleet.occupancy()
    assert set(occ) == specs
    for acct in occ.values():
        assert 0 < acct["node_occupancy"] <= 1.0
        assert 0 < acct["edge_occupancy"] <= 1.0
        assert acct["lane_occupancy"] == 1.0


# --------------------------------------------------------------------- #
# Convergence masking: converged / clean lanes are bitwise frozen
# --------------------------------------------------------------------- #
def test_batched_loop_freezes_converged_lane():
    """Engine-level guarantee: once a lane's criterion fires, later loop
    iterations must not move it by a single bit."""
    import jax.numpy as jnp
    g_fast = erdos_renyi(200, 600, seed=4)       # converges early
    g_slow = powerlaw_configuration(200, 1600, seed=5)
    act = heterogeneous(200, seed=6)
    fleet = TenantFleet(backend="reference", tol=1e-10,
                        policy=BucketPolicy((256,), edge_quantum=2048))
    fleet.admit("fast", g_fast, act)
    fleet.admit("slow", g_slow, act)
    fleet.solve()
    t_fast = fleet.stats("fast")["iterations"]
    t_slow = fleet.stats("slow")["iterations"]
    assert t_fast != t_slow                      # lanes truly diverge
    bucket = fleet._buckets[fleet.spec_of("fast")]
    loop = make_batched_loop(make_reference_step("l1"))
    s0 = fleet._cold_state(bucket)
    active = jnp.ones(2, bool)
    tol = jnp.asarray(1e-10, jnp.float32)
    cut = min(t_fast, t_slow)
    short = loop(bucket.args, s0, bucket.scale, tol,
                 jnp.asarray(cut, jnp.int32), active)
    full = loop(bucket.args, s0, bucket.scale, tol,
                jnp.asarray(10_000, jnp.int32), active)
    lane = 0 if t_fast < t_slow else 1
    # the early-converged lane froze at `cut`; extra loop bodies ran for
    # the other lane only
    assert np.array_equal(np.asarray(short[0][lane]),
                          np.asarray(full[0][lane]))
    assert not np.array_equal(np.asarray(short[0][1 - lane]),
                              np.asarray(full[0][1 - lane]))
    assert int(full[2][lane]) == min(t_fast, t_slow)
    assert int(full[2][1 - lane]) == max(t_fast, t_slow)


@pytest.mark.parametrize("backend", REGIMES)
def test_clean_tenant_bitstable_under_neighbour_resolves(platform, backend):
    """A clean tenant's ψ must be bit-identical across a co-tenant's
    patch → re-solve cycle (its lane is masked out of the batched loop)."""
    tenants, _ = platform
    fleet = _fleet(backend)
    for i, (g, a) in enumerate(tenants):
        fleet.admit(f"t{i}", g, a)
    fleet.solve()
    frozen = {t: fleet.psi(t).copy() for t in ("t0", "t2")}
    for round_ in range(2):
        fleet.patch_activity("t1", np.asarray([5 + round_]),
                             lam=np.asarray([4.0 + round_]))
        fleet.solve()
        for t, before in frozen.items():
            assert np.array_equal(before, fleet.psi(t))
    assert fleet.stats("t1")["iterations"] > 0


# --------------------------------------------------------------------- #
# Delta patches + warm starts
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", REGIMES)
def test_fleet_patch_activity_parity(platform, backend):
    tenants, _ = platform
    fleet = _fleet(backend)
    for i, (g, a) in enumerate(tenants):
        fleet.admit(f"t{i}", g, a)
    fleet.solve()
    cold = fleet.stats("t1")["iterations"]
    g, act = tenants[1]
    fleet.patch_activity("t1", np.asarray([7]), lam=np.asarray([6.0]))
    lam2 = act.lam.copy()
    lam2[7] = 6.0
    from repro.core import Activity
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert np.abs(fleet.psi("t1") - psi_true).max() <= 1e-6
    assert fleet.stats("t1")["iterations"] < cold    # warm restart


@pytest.mark.parametrize("backend", REGIMES)
def test_fleet_patch_edges_parity(platform, backend):
    tenants, _ = platform
    fleet = _fleet(backend)
    g, act = tenants[0]
    fleet.admit("t0", g, act)
    fleet.solve()
    src = np.asarray([0, 1, 2], np.int32)
    dst = np.asarray([50, 60, 70], np.int32)
    fleet.patch_edges("t0", src, dst)
    g2 = Graph(g.n, np.concatenate([g.src, src]),
               np.concatenate([g.dst, dst])).dedup()
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(fleet.psi("t0") - psi_true).max() <= 1e-6
    assert fleet.stats("t0")["rebuckets"] == 0


@pytest.mark.parametrize("backend", REGIMES)
def test_warm_start_survives_rebucket(backend):
    """Edge growth past the bucket's capacity migrates the tenant to the
    next rung *with* its series vector: the post-migration solve must be a
    warm handful of iterations, not a cold restart."""
    g = erdos_renyi(200, 900, seed=5)
    act = heterogeneous(200, seed=6)
    policy = BucketPolicy((256,), edge_quantum=1024, edge_growth=2.0)
    fleet = TenantFleet(backend=backend, tol=1e-8, policy=policy)
    fleet.admit("a", g, act)
    fleet.solve()
    cold = fleet.stats("a")["iterations"]
    assert fleet.spec_of("a") == BucketSpec(256, 1024)
    rng = np.random.default_rng(0)
    have = set(zip(g.src.tolist(), g.dst.tolist()))
    ns, nd = [], []
    while len(ns) < 200:                     # push m past e_pad = 1024
        s_, d_ = (int(x) for x in rng.integers(0, 200, 2))
        if s_ != d_ and (s_, d_) not in have:
            have.add((s_, d_))
            ns.append(s_)
            nd.append(d_)
    fleet.patch_edges("a", np.asarray(ns, np.int32), np.asarray(nd, np.int32))
    st = fleet.stats("a")
    assert st["rebuckets"] == 1
    assert st["spec"] == BucketSpec(256, 2048)
    fleet.solve()
    g2 = Graph(200, np.concatenate([g.src, ns]),
               np.concatenate([g.dst, nd])).dedup()
    psi_true, _ = exact_psi(g2, act)
    assert np.abs(fleet.psi("a") - psi_true).max() <= 1e-6
    assert fleet.stats("a")["iterations"] < cold


def test_admit_evict_lifecycle(platform):
    tenants, solo = platform
    fleet = _fleet("dense")
    for i, (g, a) in enumerate(tenants):
        fleet.admit(f"t{i}", g, a)
    with pytest.raises(ValueError, match="already admitted"):
        fleet.admit("t0", *tenants[0])
    fleet.solve()
    psi_b = fleet.evict("t1")
    assert psi_b.shape == (tenants[1][0].n,)
    assert fleet.tenant_ids == ("t0", "t2") and len(fleet) == 2
    with pytest.raises(KeyError, match="unknown tenant"):
        fleet.psi("t1")
    # survivors keep serving, still correct after a restack
    fleet.patch_activity("t2", np.asarray([3]), mu=np.asarray([0.7]))
    fleet.solve()
    assert np.abs(fleet.psi("t0") - solo[0]).max() <= 1e-6


def test_admit_dedupes_multi_edges():
    """Duplicate edges must not split the regimes: the dense {0,1}
    adjacency and the edge-form segment-sum only agree on simple graphs,
    so admit() dedupes (matching the paper's model and Graph.dedup)."""
    g_dup = Graph(16, np.asarray([0, 1, 2, 3, 0, 4]),
                  np.asarray([1, 2, 3, 4, 1, 4]))   # dup (0,1) + self-loop
    act = heterogeneous(16, seed=1)
    psis = {}
    for backend in REGIMES:
        fleet = TenantFleet(backend=backend, tol=1e-10,
                            policy=BucketPolicy((256,), edge_quantum=1024))
        fleet.admit("a", g_dup, act)
        psis[backend] = fleet.psi("a")
    psi_true, _ = exact_psi(g_dup.dedup(), act)
    for backend, psi in psis.items():
        assert np.abs(psi - psi_true).max() <= 1e-6, backend


def test_admit_with_warm_s0(platform):
    tenants, _ = platform
    g, act = tenants[0]
    res = make_engine("reference", graph=g, activity=act).run(tol=1e-8)
    fleet = _fleet("reference")
    fleet.admit("warm", g, act, s0=np.asarray(res.s))
    fleet.admit("cold", g, act)
    fleet.solve()
    assert fleet.stats("warm")["iterations"] < fleet.stats("cold")["iterations"]


def test_pallas_block_growth_escalation_preserves_lanes():
    """Edge growth that outgrows the bucket's pallas block capacity (but
    not its edge capacity) forces a full restack; clean co-tenants must
    come back bit-identical and the grown tenant warm + correct."""
    g_a = erdos_renyi(200, 2000, seed=8)
    g_b = erdos_renyi(220, 2000, seed=9)
    act_a, act_b = heterogeneous(200, seed=10), heterogeneous(220, seed=11)
    policy = BucketPolicy((256,), edge_quantum=8192)
    fleet = TenantFleet(backend="pallas", tol=1e-8, policy=policy,
                        tile=256, e1=8, e2=128)
    fleet.admit("a", g_a, act_a)
    fleet.admit("b", g_b, act_b)
    fleet.solve()
    cold = fleet.stats("a")["iterations"]
    psi_b = fleet.psi("b").copy()
    bucket = fleet._buckets[fleet.spec_of("a")]
    nb_before = bucket.nb
    # > nb*eblk − m new edges into the single output tile → block overflow
    rng = np.random.default_rng(1)
    have = set(zip(g_a.src.tolist(), g_a.dst.tolist()))
    ns, nd = [], []
    while len(ns) < nb_before * 1024 - g_a.m + 64:
        s_, d_ = (int(x) for x in rng.integers(0, 200, 2))
        if s_ != d_ and (s_, d_) not in have:
            have.add((s_, d_))
            ns.append(s_)
            nd.append(d_)
    fleet.patch_edges("a", np.asarray(ns, np.int32), np.asarray(nd, np.int32))
    assert fleet.stats("a")["rebuckets"] == 0      # same bucket, more blocks
    fleet.solve()
    assert bucket.nb > nb_before
    g2 = Graph(200, np.concatenate([g_a.src, ns]),
               np.concatenate([g_a.dst, nd])).dedup()
    psi_true, _ = exact_psi(g2, act_a)
    assert np.abs(fleet.psi("a") - psi_true).max() <= 1e-6
    assert fleet.stats("a")["iterations"] < cold   # warm state survived
    assert np.array_equal(psi_b, fleet.psi("b"))   # clean lane untouched


@pytest.mark.parametrize("backend", REGIMES)
def test_invalidate_does_not_drop_pending_patches(platform, backend):
    """A patch made before invalidate() must still reach the device
    operators: the post-invalidate solve has to converge on the *patched*
    platform, not the stale stack."""
    tenants, _ = platform
    g, act = tenants[0]
    fleet = _fleet(backend)
    fleet.admit("a", g, act)
    fleet.solve()
    fleet.patch_activity("a", np.asarray([7]), lam=np.asarray([6.0]))
    fleet.invalidate()
    fleet.solve()
    from repro.core import Activity
    lam2 = act.lam.copy()
    lam2[7] = 6.0
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert np.abs(fleet.psi("a") - psi_true).max() <= 1e-6


# --------------------------------------------------------------------- #
# Frontier: cross-tenant queries, staleness, the PsiService view
# --------------------------------------------------------------------- #
def test_frontier_scores_batch_and_global_top_k(platform):
    tenants, _ = platform
    fleet = _fleet("dense")
    for i, (g, a) in enumerate(tenants):
        fleet.admit(f"t{i}", g, a)
    fr = fleet.frontier
    ids = ["t0", "t1", "t0", "t2"]
    users = np.asarray([3, 4, 5, 6])
    got = fr.scores_batch(ids, users)
    want = [fleet.psi(t)[u] for t, u in zip(ids, users)]
    np.testing.assert_allclose(got, want, rtol=0, atol=0)
    with pytest.raises(ValueError, match="align"):
        fr.scores_batch(["t0"], np.asarray([1, 2]))
    top = fr.global_top_k(5)
    assert len(top) == 5
    scores = [s for _, _, s in top]
    assert scores == sorted(scores, reverse=True)
    best = max((float(fleet.psi(t).max()), t) for t in fleet.tenant_ids)
    assert top[0][0] == best[1] and top[0][2] == pytest.approx(best[0])


def test_frontier_staleness_epoch_tracking(platform):
    tenants, _ = platform
    fleet = _fleet("dense")
    g, a = tenants[0]
    fleet.admit("a", g, a)
    fr = fleet.frontier
    assert fr.staleness("a") == 1 and fr.epoch("a") == 0   # never solved
    fleet.solve()
    assert fr.staleness("a") == 0
    fleet.patch_activity("a", np.asarray([1]), lam=np.asarray([2.0]))
    fleet.patch_activity("a", np.asarray([2]), lam=np.asarray([3.0]))
    assert fr.staleness("a") == 2 and fr.epoch("a") == 2
    fr.top_k("a", 3)                          # query forces freshness
    assert fr.staleness("a") == 0


def test_frontier_ranking_memoized_per_epoch(platform, monkeypatch):
    tenants, _ = platform
    fleet = _fleet("dense")
    fleet.admit("a", *tenants[0])
    fr = fleet.frontier
    fr.rank_of("a", np.asarray([1, 2]))
    calls = {"n": 0}
    orig = np.argsort

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(np, "argsort", counting)
    fr.rank_of("a", np.asarray([3]))          # memoized: no new sort
    fr.top_k("a", 4)
    assert calls["n"] == 0
    fleet.patch_activity("a", np.asarray([1]), mu=np.asarray([0.9]))
    fr.rank_of("a", np.asarray([3]))          # epoch moved: one new sort
    assert calls["n"] >= 1


def test_psi_service_from_fleet_view(platform):
    tenants, solo = platform
    fleet = _fleet("dense")
    for i, (g, a) in enumerate(tenants):
        fleet.admit(f"t{i}", g, a)
    view = PsiService.from_fleet(fleet, "t2")
    assert view.backend == "fleet[dense]"
    assert np.abs(view.scores() - solo[2]).max() <= 1e-6
    idx, vals = view.top_k(3)
    assert np.all(np.diff(vals) <= 0)
    assert view.rank_of(np.asarray([int(idx[0])]))[0] == 0
    g, act = tenants[2]
    view.update_activity(np.asarray([4]), lam=np.asarray([5.0]))
    from repro.core import Activity
    lam2 = act.lam.copy()
    lam2[4] = 5.0
    psi_true, _ = exact_psi(g, Activity(lam2, act.mu))
    assert np.abs(view.scores() - psi_true).max() <= 1e-6
    assert view.last_iterations() > 0
    assert view.graph.n == g.n


# --------------------------------------------------------------------- #
# Bucket policy + construction validation
# --------------------------------------------------------------------- #
def test_bucket_policy_ladder():
    p = BucketPolicy((256, 1024), edge_quantum=1024, edge_growth=2.0)
    assert p.bucket_for(100, 500) == BucketSpec(256, 1024)
    assert p.bucket_for(257, 1025) == BucketSpec(1024, 2048)
    assert p.bucket_for(5000, 3000) == BucketSpec(8192, 4096)  # doubled tail
    assert p.needs_rebucket(BucketSpec(256, 1024), 200, 1025)
    assert not p.needs_rebucket(BucketSpec(256, 1024), 256, 1024)
    with pytest.raises(ValueError, match="ascending"):
        BucketPolicy((512, 256))
    with pytest.raises(ValueError, match="exceed"):
        BucketPolicy((256,), edge_growth=1.0)
    assert BucketPolicy.from_spec("512, 2048").node_sizes == (512, 2048)


def test_bucket_policy_lane_quantum():
    p = BucketPolicy((256,), lane_quantum=4)
    assert p.lanes_padded(1) == 4 and p.lanes_padded(5) == 8
    acct = p.occupancy(BucketSpec(256, 1024), [(200, 900)])
    assert acct["lanes"] == 4 and acct["lane_occupancy"] == 0.25


def test_lane_quantum_pad_lanes_are_inert(platform):
    tenants, solo = platform
    policy = BucketPolicy((512,), edge_quantum=4096, lane_quantum=4)
    fleet = TenantFleet(backend="dense", tol=1e-8, policy=policy)
    fleet.admit("a", *tenants[0])
    fleet.solve()
    assert np.abs(fleet.psi("a") - solo[0]).max() <= 1e-6


def test_fleet_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown fleet backend"):
        TenantFleet(backend="bsr")
    with pytest.raises(ValueError, match="l1"):
        TenantFleet(backend="pallas", norm="l2")


def test_shared_bucket_plan_across_tenants(platform):
    """Same-bucket tenants must reuse one autotune plan (bucket-shape key),
    however many are admitted."""
    from repro.kernels.autotune import PlanCache
    tenants, _ = platform
    cache = PlanCache()
    fleet = TenantFleet(backend="pallas", tol=1e-8, plan_cache=cache,
                        policy=BucketPolicy((512,), edge_quantum=4096))
    for i, (g, a) in enumerate(tenants):
        fleet.admit(f"t{i}", g, a)
    fleet.solve()
    assert cache.misses == 1                 # one plan for the one bucket
    fleet.patch_activity("t0", np.asarray([1]), lam=np.asarray([2.0]))
    fleet.solve()
    assert cache.misses == 1                 # patches never re-plan


# --------------------------------------------------------------------- #
# Satellite: make_engine rejects unknown backend kwargs
# --------------------------------------------------------------------- #
def test_make_engine_rejects_unknown_kwargs():
    from repro.core import available_backends
    with pytest.raises(ValueError, match="unknown engine option"):
        make_engine("reference", tile=128)
    with pytest.raises(ValueError) as exc:
        make_engine("reference", chunk_itres=4)     # typo'd distributed opt
    msg = str(exc.value)
    assert "chunk_itres" in msg
    for name in available_backends():
        assert name in msg                   # the full registry is listed
    # known options still construct fine
    make_engine("pallas", regime="bsr")
    make_engine("reference", check_every=2)
