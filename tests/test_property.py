"""Hypothesis property tests on the system's invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="dev-only dep; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st

from repro.graphs import erdos_renyi
from repro.graphs.structure import Graph
from repro.graphs.partition import partition_2d
from repro.core import (Activity, heterogeneous, build_operators, power_psi,
                        dense_operators, exact_psi)

graph_params = st.tuples(st.integers(10, 120), st.integers(0, 400),
                         st.integers(0, 10_000))


def _mk_graph(n, m, seed):
    m = min(m, n * (n - 1) // 2)
    return erdos_renyi(n, max(1, m), seed=seed)


@given(graph_params)
@settings(max_examples=20, deadline=None)
def test_a_is_substochastic(params):
    """Row sums of A are in [0, 1] — the convergence precondition (§III-B)."""
    n, m, seed = params
    g = _mk_graph(n, m, seed)
    act = heterogeneous(n, seed=seed + 1)
    A, B, c, d = dense_operators(g, act)
    rows = A.sum(axis=1)
    assert np.all(rows <= 1.0 + 1e-9)
    assert np.all(rows >= 0.0)
    # A + B row sums == 1 exactly on rows with leaders
    has = g.out_degree > 0
    np.testing.assert_allclose((A + B).sum(axis=1)[has], 1.0, rtol=1e-9)


@given(graph_params)
@settings(max_examples=15, deadline=None)
def test_psi_bounds_and_agreement(params):
    """ψ ∈ (0, 1]·(1/N)·N = (0, 1]; Power-ψ matches the exact solve."""
    n, m, seed = params
    g = _mk_graph(n, m, seed)
    act = heterogeneous(n, seed=seed + 2)
    ops = build_operators(g, act)
    res = power_psi(ops, tol=1e-11, max_iter=5000)
    psi = np.asarray(res.psi)
    assert np.all(psi >= 0.0) and np.all(psi <= 1.0)
    psi_true, _ = exact_psi(g, act)
    assert np.abs(psi - psi_true).max() < 1e-4


@given(graph_params)
@settings(max_examples=15, deadline=None)
def test_q_columns_are_distributions(params):
    """Σ_i q_i^{(n)} = 1 per wall n (the OSP model conservation law):
    column sums of Q = C·P + D equal 1 for nodes with λ+μ > 0."""
    n, m, seed = params
    g = _mk_graph(n, m, seed)
    act = heterogeneous(n, seed=seed + 3)
    A, B, c, d = dense_operators(g, act)
    P = np.linalg.solve(np.eye(n) - A, B)
    Q = c[:, None] * P + np.diag(d)
    # rows of Q here: Q[n_, i] = q_i^{(n_)}; conservation: Σ_i q_i^{(n)} ≤ 1
    sums = Q.sum(axis=1)
    assert np.all(sums <= 1.0 + 1e-6)


@given(st.integers(20, 400), st.integers(1, 12), st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_partition_layout_roundtrip(n, dm, seed):
    """to_src_layout / from_src_layout are exact inverses."""
    d = 1 + dm % 4
    mo = 1 + (dm // 4) % 3
    g = _mk_graph(n, 3 * n, seed)
    part = partition_2d(g, d, mo)
    rng = np.random.default_rng(seed)
    v = rng.normal(size=n).astype(np.float32)
    round_trip = part.from_src_layout(part.to_src_layout(v))
    np.testing.assert_array_equal(round_trip, v)
    # piece layout reshape equals src layout (the psum_scatter identity)
    pieces = part.to_piece_layout(v)
    np.testing.assert_array_equal(pieces.reshape(part.d, -1),
                                  part.to_src_layout(v))


@given(st.integers(10, 200), st.integers(5, 600), st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_partition_covers_all_edges(n, m, seed):
    g = _mk_graph(n, m, seed)
    part = partition_2d(g, 2, 2)
    assert int(part.e_counts.sum()) == g.m
    # every real edge appears exactly once with valid local ids
    cnt = (part.src_local < part.local_src_n).sum()
    assert cnt == g.m


@given(st.integers(2, 50), st.integers(0, 99))
@settings(max_examples=20, deadline=None)
def test_generator_properties(n, seed):
    m = min(3 * n, n * (n - 1) // 2)
    g = erdos_renyi(n, m, seed=seed)
    assert g.m == m
    assert not np.any(g.src == g.dst)           # no self loops
    key = g.src.astype(np.int64) * g.n + g.dst
    assert np.unique(key).size == g.m           # no duplicate edges


@given(st.lists(st.floats(0.01, 10.0), min_size=3, max_size=30),
       st.integers(0, 999))
@settings(max_examples=20, deadline=None)
def test_activity_scale_invariance(lams, seed):
    """ψ is invariant to a global rescale of all rates (model property:
    only rate *ratios* matter)."""
    n = len(lams)
    g = _mk_graph(n, 2 * n, seed)
    rng = np.random.default_rng(seed)
    mus = rng.uniform(0.1, 2.0, n)
    a1 = Activity(np.asarray(lams), mus)
    a2 = Activity(np.asarray(lams) * 7.3, mus * 7.3)
    p1, _ = exact_psi(g, a1)
    p2, _ = exact_psi(g, a2)
    np.testing.assert_allclose(p1, p2, rtol=1e-8, atol=1e-12)
