"""Beyond-paper Aitken-extrapolated Power-ψ (core/accelerated.py)."""
import numpy as np
import pytest

from repro.graphs import erdos_renyi, powerlaw_configuration
from repro.core import (heterogeneous, homogeneous, build_operators,
                        power_psi, power_psi_accelerated, exact_psi)


@pytest.mark.parametrize("regime", ["het", "hom"])
def test_accelerated_matches_exact_with_fewer_matvecs(regime):
    # fp32 here → tol 1e-6 (a jump can land in a basin whose fp32 plain
    # iteration limit-cycles near 1e-6; the 1e-9 sweeps of the paper run in
    # float64 where this does not occur — see benchmarks/exp2)
    g = powerlaw_configuration(3000, 20000, seed=4)
    act = heterogeneous(g.n, seed=5) if regime == "het" else homogeneous(g.n)
    ops = build_operators(g, act)
    base = power_psi(ops, tol=1e-6)
    acc = power_psi_accelerated(ops, tol=1e-6)
    psi_true, _ = exact_psi(g, act)
    rel_b = np.linalg.norm(np.asarray(base.psi) - psi_true) / \
        np.linalg.norm(psi_true)
    rel_a = np.linalg.norm(np.asarray(acc.psi) - psi_true) / \
        np.linalg.norm(psi_true)
    assert rel_a < max(2 * rel_b, 1e-5)          # no accuracy loss
    assert int(acc.matvecs) < int(base.matvecs)  # strictly fewer mat-vecs
    assert bool(acc.converged)


def test_accelerated_never_terminates_early_spuriously():
    """The Eq. 19 guarantee: gap is always measured after a plain step."""
    g = erdos_renyi(400, 2600, seed=6)
    act = heterogeneous(g.n, seed=7)
    ops = build_operators(g, act)
    for tol in (1e-4, 1e-6, 1e-8):
        acc = power_psi_accelerated(ops, tol=tol)
        base = power_psi(ops, tol=1e-10)
        # ψ from the accelerated run at tolerance `tol` is within the
        # guaranteed band of the converged answer
        delta = np.abs(np.asarray(acc.psi) - np.asarray(base.psi)).sum()
        assert delta <= 10 * tol / g.n * g.n + 1e-6
